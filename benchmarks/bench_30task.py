"""Fig. 4 — 30-task benchmark: MaTU vs MaT-FL, normalized to individual
fine-tuning.  Paper: MaTU 77.4% vs MaT-FL 52.6% normalized."""

from __future__ import annotations

import numpy as np

from benchmarks.common import run_strategy, save_detail, standard_setting, timed
from repro.data.dirichlet import dirichlet_split
from repro.data.synthetic import make_constellation
from repro.fed.simulator import FedConfig, individual_baseline
from repro.fed.testbed import MLPBackbone


def run(quick: bool = False):
    n_tasks = 12 if quick else 30
    con = make_constellation(n_tasks=n_tasks, n_groups=6, feat_dim=32,
                             n_classes=8, conflict_pairs=[(0, 1), (2, 3)],
                             seed=0)
    split = dirichlet_split(n_clients=15, n_tasks=n_tasks, n_classes=8,
                            zeta_t=0.2, tasks_per_client=3, seed=0)
    bb = MLPBackbone(32, hidden=64, lora_rank=8)
    cfg = FedConfig(rounds=8 if quick else 30, local_steps=25, lr=1e-2,
                    eval_every=8 if quick else 30, seed=0)

    ind = individual_baseline(cfg, con, bb)
    rows, detail = [], {"n_tasks": n_tasks, "methods": {}}
    for m in ["matu", "mat-fl"]:
        (hist, _), us = timed(run_strategy, m, con, split, bb, cfg)
        normalized = float(np.mean([
            hist.final_task_acc[t] / max(ind[t], 1e-6) for t in range(n_tasks)]))
        detail["methods"][m] = {"normalized": normalized,
                                "mean_acc": hist.final_mean_acc}
        rows.append((f"fig4/{m}", us, f"norm={normalized:.3f}"))
    detail["individual_mean"] = float(np.mean(list(ind.values())))
    detail["claim_matu_beats_matfl"] = (
        detail["methods"]["matu"]["normalized"]
        > detail["methods"]["mat-fl"]["normalized"])
    save_detail("fig4_30task", detail)
    return {"rows": rows, "detail": detail}
