"""Fig. 6 — (a) conflict task groups; (b) cross-task aggregation ablation.

(a): clients hold fixed 3-task groups with 0/2/3 mutually dissimilar
tasks; MaTU's drop should stay small (<~6% in the paper) while MaT-FL
degrades with conflict count.
(b): full MaTU vs no-cross-task vs uniform cross-task averaging."""

from __future__ import annotations

import numpy as np

from benchmarks.common import run_strategy, save_detail, timed
from repro.data.dirichlet import assign_fixed_groups
from repro.data.synthetic import make_constellation
from repro.fed.simulator import FedConfig, individual_baseline
from repro.fed.testbed import MLPBackbone


def run(quick: bool = False):
    n_tasks = 9
    # groups of 3 tasks: 0=(same group); 2conf=(two conflicting);
    # 3conf=(three mutually dissimilar: conflict pair + neutral)
    con = make_constellation(n_tasks=n_tasks, n_groups=3, feat_dim=32,
                             n_classes=8, conflict_pairs=[(0, 1)], seed=0)
    # task t has group t % 3: g0={0,3,6} g1={1,4,7} g2={2,5,8}
    groups = {
        "no_conflict": [[0, 3, 6]],
        "2_conflict": [[0, 1, 3]],      # two g0 + one conflicting g1
        "3_conflict": [[0, 1, 2]],      # conflict pair + neutral
    }
    bb = MLPBackbone(32, hidden=64, lora_rank=8)
    cfg = FedConfig(rounds=6 if quick else 25, local_steps=25, lr=1e-2,
                    eval_every=6 if quick else 25, seed=0)
    ind = individual_baseline(cfg, con, bb)

    rows, detail = [], {"a": {}, "b": {}}
    for label, gset in groups.items():
        split = assign_fixed_groups(10, gset)
        tasks_used = sorted(set(t for g in gset for t in g))
        for m in ["matu", "mat-fl", "fedper"]:
            (hist, _), us = timed(run_strategy, m, con, split, bb, cfg)
            normalized = float(np.mean([
                hist.final_task_acc[t] / max(ind[t], 1e-6) for t in tasks_used]))
            detail["a"].setdefault(label, {})[m] = normalized
            rows.append((f"fig6a/{label}/{m}", us, f"norm={normalized:.3f}"))

    # (b) cross-task ablation on the 2-conflict group
    split = assign_fixed_groups(10, groups["2_conflict"])
    for variant, kw in [("full", {}), ("no_cross", {"cross_task": False}),
                        ("uniform", {"uniform_cross": True})]:
        (hist, _), us = timed(run_strategy, "matu", con, split, bb, cfg, **kw)
        detail["b"][variant] = hist.final_mean_acc
        rows.append((f"fig6b/{variant}", us, f"acc={hist.final_mean_acc:.3f}"))

    matu_drop = detail["a"]["no_conflict"]["matu"] - detail["a"]["3_conflict"]["matu"]
    matfl_drop = (detail["a"]["no_conflict"]["mat-fl"]
                  - detail["a"]["3_conflict"]["mat-fl"])
    detail["claims"] = {
        "matu_drop": matu_drop,
        "matfl_drop": matfl_drop,
        "matu_more_robust": matu_drop <= matfl_drop + 0.02,
    }
    save_detail("fig6_conflicts", detail)
    return {"rows": rows, "detail": detail}
