"""Kernel micro-benchmarks: µs/call and effective GB/s for the three
Pallas kernels (interpret mode on CPU — correctness-path timing, not TPU
perf) against their jnp references."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import save_detail
from repro.kernels import ref
from repro.kernels.masked_agg import masked_agg_pallas
from repro.kernels.sign_sim import sign_sim_pallas
from repro.kernels.unify import unify_pallas


def _time(fn, args, iters=3):
    jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = False):
    d = 1 << 17 if quick else 1 << 19
    k, n, t = 8, 16, 16
    key = jax.random.PRNGKey(0)
    tv = jax.random.normal(key, (k, d), jnp.float32)
    u = jax.random.normal(key, (n, d), jnp.float32)
    m = (jax.random.uniform(key, (n, d)) > 0.5).astype(jnp.float32)
    lam = jnp.ones((n,))
    gam = jnp.full((n,), 1.0 / n)
    th = jax.random.normal(key, (t, d), jnp.float32)

    rows, detail = [], {}
    cases = [
        ("unify", lambda x: unify_pallas(x, interpret=True), (tv,),
         ref.unify_ref, (tv,), k * d * 4),
        ("masked_agg", lambda a, b, c, e: masked_agg_pallas(a, b, c, e, interpret=True),
         (u, m, lam, gam),
         lambda a, b, c, e: ref.masked_agg_ref(a, b, c, e, 0.4),
         (u, m, lam, gam), 2 * n * d * 4),
        ("sign_sim", lambda x: sign_sim_pallas(x, interpret=True), (th,),
         ref.sign_sim_ref, (th,), t * d * 4),
    ]
    for name, kfn, kargs, rfn, rargs, bytes_in in cases:
        us_k = _time(kfn, kargs)
        us_r = _time(jax.jit(rfn), rargs)
        gbps = bytes_in / (us_k * 1e-6) / 1e9
        rows.append((f"kernel/{name}/pallas_interp", us_k, f"{gbps:.2f}GB/s"))
        rows.append((f"kernel/{name}/jnp_ref", us_r, f"d={d}"))
        detail[name] = {"us_pallas_interp": us_k, "us_ref": us_r,
                        "bytes_in": bytes_in}
    save_detail("kernels", detail)
    return {"rows": rows, "detail": detail}
