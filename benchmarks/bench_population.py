"""Population-scale round benchmark: the chunked-slot engine over a
lazy 10^6-client Dirichlet population.

The grid samples S clients per round from a ``PopulationSplit`` of
10^6 clients (``sample_round`` rejection draws — the population itself
is never materialised) and streams them through
``RoundEngine.round_chunked`` with a discarding sink, so round memory
is O(chunk + T·d) regardless of S.  Rows report end-to-end wall time,
**clients/sec**, and the **peak-RSS delta** over the pre-round
baseline — the acceptance evidence that a ≥10^5-client round at
d = 2^20 stays within the O(chunk) memory budget (≤ 2 GB over
baseline).

Client uploads are wire-format twins cycled from a small pre-built
pool (P distinct bf16 vectors + packed uint32 mask words): the bench
measures SERVER-side ingest/fold/downlink throughput, so client-side
RNG is excluded from both the timed region and the memory budget the
same way bench_round_engine excludes its wire-twin construction.  Task
assignment, data sizes, and sampling still come from the lazy split,
exercised per client per pass (the engine's two-pass contract).

Full mode: d = 2^20, S ∈ {10^4, 10^5}, chunk 128.  Quick: d = 2^14,
S = 2000 — CI-speed.  Detail (including ``host_cores`` and the
baseline RSS) merges into results/bench/population.json.
"""

from __future__ import annotations

import os
import resource
import time
from typing import List

import numpy as np

from benchmarks.common import save_detail
from repro.core.client import ClientUpload
from repro.core.engine import EngineConfig, RoundEngine
from repro.data.dirichlet import PopulationSplit
from repro.kernels import bitpack

POPULATION = 1_000_000
N_TASKS = 8
K_PER_CLIENT = 2
POOL = 16


def _rss_mb() -> float:
    # ru_maxrss is KB on Linux — the high-water mark, so deltas against
    # a pre-round reading bound the round's own footprint from above
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _make_pool(d: int, seed: int = 0) -> List[tuple]:
    """P distinct wire-format (unified bf16, mask words, λ) triples —
    reused round-robin across clients so upload generation stays out
    of the measured server throughput."""
    import ml_dtypes
    rng = np.random.default_rng(seed)
    dw = bitpack.packed_width(d)
    pool = []
    for _ in range(POOL):
        uni = rng.standard_normal(d, dtype=np.float32).astype(
            ml_dtypes.bfloat16)
        words = rng.integers(0, 2**32, (K_PER_CLIENT, dw),
                             dtype=np.uint32)
        lams = rng.random(K_PER_CLIENT, dtype=np.float32) + 0.5
        pool.append((uni, words, lams))
    return pool


def _one_round(engine: RoundEngine, split: PopulationSplit, pool,
               n_sampled: int, chunk: int) -> dict:
    ids = split.sample_round(0, n_sampled)

    def gen():
        for i, c in enumerate(ids):
            c = int(c)
            uni, words, lams = pool[i % POOL]
            ts = split.tasks_for(c)
            yield ClientUpload(c, ts, uni, words[: len(ts)],
                               lams[: len(ts)],
                               split.data_sizes_for(c))

    rss0 = _rss_mb()
    t0 = time.perf_counter()
    _, _, stats = engine.round_chunked(
        gen, chunk_clients=chunk, sink=lambda links: None)
    wall = time.perf_counter() - t0
    return {
        "n_clients": int(stats["n_clients"]),
        "n_chunks": int(stats["n_chunks"]),
        "chunk_clients": chunk,
        "wall_s": wall,
        "clients_per_s": stats["n_clients"] / wall,
        "uplink_bits": int(stats["uplink_bits"]),
        "downlink_bits": int(stats["downlink_bits"]),
        "rss_before_mb": rss0,
        "rss_peak_mb": _rss_mb(),
        "rss_delta_mb": _rss_mb() - rss0,
    }


def run(quick: bool = False) -> dict:
    d = 2**14 if quick else 2**20
    grid = [2_000] if quick else [10_000, 100_000]
    chunk = 128
    split = PopulationSplit(n_clients=POPULATION, n_tasks=N_TASKS,
                            tasks_per_client=K_PER_CLIENT, seed=0)
    engine = RoundEngine(EngineConfig(n_tasks=N_TASKS))
    pool = _make_pool(d)

    baseline_mb = _rss_mb()
    rows, detail = [], {
        "host_cores": os.cpu_count(),
        "population": POPULATION,
        "d": d,
        "baseline_rss_mb": baseline_mb,
    }
    # warm the chunk-step jit signatures off the clock (tiny round)
    _one_round(engine, split, pool, min(2 * chunk, grid[0]), chunk)
    for s in grid:
        r = _one_round(engine, split, pool, s, chunk)
        key = f"population_n{s}_d{d}_c{chunk}"
        detail[key] = r
        rows.append((key, r["wall_s"] * 1e6,
                     f"clients_per_s={r['clients_per_s']:.1f} "
                     f"rss_delta_mb={r['rss_delta_mb']:.0f}"))
    save_detail("population", detail)
    return {"rows": rows, "detail": detail}
