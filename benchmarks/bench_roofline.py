"""§Roofline — three-term roofline per (arch × shape × mesh) from the
dry-run artifacts (results/dryrun/*.json).

    compute_s    = HLO_flops_per_device / PEAK_FLOPS_BF16
    memory_s     = HLO_bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW

Semantics (verified empirically in launch/dryrun.py):
  * compiled.cost_analysis() on the SPMD-partitioned module reports
    per-partition (= per-device) flops and bytes;
  * memory_analysis() is per-device;
  * collective bytes are parsed from the compiled HLO (per-device).

LOOP-TRIP CORRECTION: XLA cost analysis counts a while-loop body ONCE,
not multiplied by its trip count.  Our models scan over layer units, so
flops / bytes / collective bytes are all multiplied here by the unit
count (verified: uncorrected useful-flops ratios land at ≈ n_layers ×
the corrected value).  Ops outside the layer scan (embedding, fused CE,
whose own chunk scan has a different trip count) make this an
approximation — treat absolute seconds as ±30%; the three terms share
the factor, so the DOMINANT-term classification is unaffected.

CPU-backend caveat: XLA:CPU legalizes bf16 arithmetic to f32, which
inflates bytes_accessed (and some temps) by up to 2× vs the TPU
lowering.  We report the raw value and a bf16-corrected estimate
(×0.5 on bytes) — the truth lies between them; the DOMINANT-term
classification is robust to this factor in all but 3 borderline cases,
which are flagged.
"""

from __future__ import annotations

import glob
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import save_detail
from repro.configs.base import ARCH_IDS, SHAPES, load_arch
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                          "results", "dryrun")


def param_counts(arch: str):
    """(N_total, N_active) from shape math only (no allocation)."""
    import numpy as np
    cfg = load_arch(arch)
    model = cfg.build(SHAPES["train_4k"])
    struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    total = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(struct))
    if cfg.n_experts:
        per_expert = 3 * cfg.d_model * cfg.d_ff
        routed = cfg.n_layers * cfg.n_experts * per_expert
        active = total - routed + cfg.n_layers * cfg.top_k * per_expert
    else:
        active = total
    return total, active


def model_flops(arch: str, shape_name: str, n_active: int) -> float:
    """Brief's definition: 6·N_active·D for training, 2·N_active·D for
    forward-only serving steps (D = tokens processed per step)."""
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # decode: ONE token per sequence
    return 2.0 * n_active * tokens


def loop_trips(arch: str) -> int:
    cfg = load_arch(arch)
    if cfg.family == "ssm":
        return cfg.n_layers // 2     # one scan unit = (mLSTM, sLSTM) pair
    return cfg.n_layers


def analyse(record: dict, n_active: int) -> dict:
    n_dev = record["devices"]
    trips = loop_trips(record["arch"])
    flops_dev = (record["cost"]["flops"] or 0.0) * trips
    bytes_dev = (record["cost"]["bytes_accessed"] or 0.0) * trips
    coll_dev = sum(record["collective_bytes_per_device"].values()) * trips

    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    memory_s_bf16 = 0.5 * memory_s          # CPU f32-legalization correction
    collective_s = coll_dev / ICI_BW

    terms = {"compute": compute_s, "memory": memory_s_bf16,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    # is the classification robust to the bf16 correction factor?
    terms_raw = {"compute": compute_s, "memory": memory_s,
                 "collective": collective_s}
    robust = max(terms_raw, key=terms_raw.get) == dominant

    mf = model_flops(record["arch"], record["shape"], n_active)
    mf_dev = mf / n_dev
    useful = mf_dev / flops_dev if flops_dev else 0.0

    advice = {
        "compute": "increase arithmetic efficiency: fuse attention "
                   "(Pallas flash kernel), drop remat recompute on cheap ops",
        "memory": "cut HBM traffic: fuse elementwise chains, keep "
                  "activations bf16 end-to-end, larger attention chunks",
        "collective": "reduce resharding: overlap all-reduce with compute, "
                      "reduce-scatter instead of all-reduce on the residual, "
                      "avoid involuntary SPMD remats (head-aligned layouts)",
    }[dominant]

    return {
        "arch": record["arch"], "shape": record["shape"],
        "mesh": record["mesh"],
        "compute_s": compute_s, "memory_s_raw": memory_s,
        "memory_s_bf16corr": memory_s_bf16, "collective_s": collective_s,
        "dominant": dominant, "dominant_robust_to_dtype_corr": robust,
        "model_flops_per_dev": mf_dev, "hlo_flops_per_dev": flops_dev,
        "useful_flops_ratio": useful,
        "peak_bytes_per_dev": record["memory_per_device"]["peak_bytes"],
        "what_would_move_it": advice,
    }


def run(quick: bool = False):
    rows, table = [], []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*__singlepod.json"))):
        rec = json.load(open(path))
        if rec.get("arch") not in ARCH_IDS:
            continue  # e.g. the matu_round lowering artifact
        if rec.get("status") != "ok":
            if rec.get("status") == "skipped":
                table.append({"arch": rec["arch"], "shape": rec["shape"],
                              "status": "skipped", "reason": rec.get("reason")})
            continue
        _total, active = param_counts(rec["arch"])
        r = analyse(rec, active)
        r["status"] = "ok"
        table.append(r)
        rows.append((f"roofline/{rec['arch']}/{rec['shape']}",
                     0.0,
                     f"dom={r['dominant']};c={r['compute_s']:.2e}s;"
                     f"m={r['memory_s_bf16corr']:.2e}s;"
                     f"x={r['collective_s']:.2e}s;useful={r['useful_flops_ratio']:.2f}"))
    save_detail("roofline", {"table": table})
    return {"rows": rows, "detail": {"table": table}}
