"""Round-engine benchmark: legacy Python-loop ``MaTUServer.round_legacy``
vs the batched, jit-compiled ``RoundEngine`` across (N, T, d) grids.

The legacy path dispatches O(T + N) eager ops per round (per-task
stacking, ``.at[t].set`` copies of the (T, d) accumulator, per-client
re-unification); the engine packs once and runs one fused jitted call.
Engine timing includes packing (the honest end-to-end cost); the jit
warm-up compile is excluded for both (steady-state serving is the
regime the ROADMAP targets).

Full mode tops out at N=32, T=30, d=2^20 — the acceptance grid for the
refactor (≥ 3x speedup on CPU).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_detail
from repro.core.client import ClientUpload
from repro.core.server import MaTUServer, MaTUServerConfig
from repro.core.unify import unify_with_modulators


def _make_uploads(rng, n, n_tasks, d, k_lo, k_hi):
    """Ragged round built host-side (numpy) so setup stays cheap at
    d = 2^20; modulators come from the real client-side unification.
    k_n is drawn from [k_lo, k_hi] — the paper's many-task clients
    hold several tasks each (Table 2 / Fig. 5), which is the regime
    the batched engine targets."""
    ups = []
    for cid in range(n):
        kn = int(rng.integers(k_lo, k_hi + 1))
        tasks = sorted(rng.choice(n_tasks, size=kn, replace=False).tolist())
        tvs = jnp.asarray(rng.standard_normal((kn, d)).astype(np.float32))
        unified, masks, lams = unify_with_modulators(tvs)
        ups.append(ClientUpload(cid, tasks, jax.block_until_ready(unified),
                                masks, lams,
                                rng.integers(32, 256, size=kn).tolist()))
    return ups


def _block_downlinks(downs):
    """Force every device value a round produces — ClientDownlink is a
    plain dataclass (not a pytree), so block on its arrays explicitly
    or async dispatch would let the timer stop before the work runs."""
    for dl in downs.values():
        jax.block_until_ready(dl.unified)
        jax.block_until_ready(dl.masks)
        jax.block_until_ready(dl.lams)


def _time(fn, iters):
    """Best-of-iters wall time in µs — min is the noise-robust statistic
    on a shared/throttled host (both paths get the same treatment)."""
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        _block_downlinks(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run(quick: bool = False):
    grids = ([(8, 8, 1 << 14, 1, 2), (16, 16, 1 << 16, 2, 3)] if quick else
             [(16, 16, 1 << 16, 2, 3), (16, 30, 1 << 18, 2, 3),
              (32, 30, 1 << 20, 3, 4)])
    iters = 4

    rows, detail = [], {}
    for n, n_tasks, d, k_lo, k_hi in grids:
        rng = np.random.default_rng(n * 1000 + n_tasks)
        ups = _make_uploads(rng, n, n_tasks, d, k_lo, k_hi)
        tag = f"N{n}_T{n_tasks}_d{d}"

        legacy = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
        _block_downlinks(legacy.round_legacy(ups))      # warm caches
        us_legacy = _time(lambda: legacy.round_legacy(ups), iters)

        engine = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
        _block_downlinks(engine.round(ups))             # compile warm-up
        us_engine = _time(lambda: engine.round(ups), iters)

        speedup = us_legacy / us_engine
        rows.append((f"round_engine/{tag}/legacy", us_legacy,
                     f"k={k_lo}-{k_hi}"))
        rows.append((f"round_engine/{tag}/engine", us_engine,
                     f"{speedup:.2f}x"))
        detail[tag] = {"us_legacy": us_legacy, "us_engine": us_engine,
                       "speedup": speedup, "n": n, "n_tasks": n_tasks,
                       "d": d, "k_lo": k_lo, "k_hi": k_hi}

    save_detail("round_engine", detail)
    return {"rows": rows, "detail": detail}
