"""Round-engine benchmark: legacy Python-loop ``MaTUServer.round_legacy``
vs the batched ``RoundEngine`` in BOTH slot layouts — the PR 1 bool/fp32
layout and the bit-packed/bf16 wire-format layout — across (N, T, d)
grids, with roofline columns (wire bytes moved, achieved GB/s).

Each engine leg consumes its own wire format end to end: the bool leg
gets fp32/bool uploads (what PR 1's clients produced), the packed leg
gets bf16/uint32 uploads (what ``batched_client_unify`` now emits —
masks never exist as dense bool anywhere on that path).  The wire-twin
construction itself is client-side work and is excluded from the timed
region; everything else (slot packing, the jitted round, downlink
slicing) is timed, warm-compiled, best-of-iters.

``bytes_moved`` is the padded uplink+downlink slot-buffer traffic of
each layout (see ``_round_wire_bytes``), and ``gbps = bytes_moved /
time`` is the achieved wire-streaming rate — the roofline axis the
packed layout moves by shrinking bytes 8x (masks) and 2x (vectors).
The two engine legs are timed with interleaved iterations so both
sample the same throttling windows of a noisy shared host.

Full mode tops out at N=32, T=30, d=2^20 — the acceptance grid for the
wire-format refactor (packed ≥ 1.5x over the PR 1 bool engine on CPU).

With ``--devices N`` (benchmarks.run forces N host devices before jax
initialises) a fourth leg runs the taskvec-SHARDED packed engine on an
N-way mesh and the A/B column reports sharded vs single-device.  On a
CPU host the "devices" are threads carved out of the same socket, so
the ratio measures shard_map overhead + collective cost, not real
multi-chip scaling — the TPU grids read the same columns off real
chips.

With ``--code-masks`` an entropy-coded A/B leg runs on top: the wire
uploads are Golomb-Rice coded (``repro.fed.compression``), the engine
consumes the coded uploads (decoded at the host edge by
``pack_uploads``) and emits coded downlink streams, and the
``coded_ratio`` column reports measured coded uplink bits / raw packed
uplink bits — the real-buffer evidence for the paper's comm-savings
story (≤ 1.0 by construction: the coder escapes to raw + 5-byte
header when Rice would expand).

With ``--pipeline`` a coded multi-round A/B runs through
``RoundEngine.round_stream``: pipelined (two-deep host/device overlap,
double-buffered slot staging) vs the sequential escape hatch, per-round
wall µs each, plus the ``us_host_codec`` / ``us_device_step`` split
measured on the sequential leg (where the phases don't overlap, so
they sum to the wall).  ``host_cores`` is recorded alongside: on a
single-core host the pipeline has no second core to overlap onto and
pipe ≈ seq — the column pair is the evidence either way.

With ``--faults`` a simulator-level A/B runs on top: the buffered
async mode (``FedSimulator(..., systems=...)`` + AsyncMaTUStrategy)
under the issue's fault trace — 30% dropout + 2x-latency stragglers,
staleness cap 4 — vs the synchronous barrier loop on the same
workload.  The ``engine_async`` row reports per-round wall µs with
rounds/sec for both legs; the detail JSON records the fault totals so
the throughput number is auditable against how much work each leg
actually admitted.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_detail
from repro.core.client import ClientUpload
from repro.core.engine import EngineConfig, RoundEngine, _round_up_pow2
from repro.core.server import MaTUServer, MaTUServerConfig
from repro.core.unify import unify_with_modulators
from repro.kernels import bitpack


def _make_uploads(rng, n, n_tasks, d, k_lo, k_hi):
    """Ragged round built host-side (numpy) so setup stays cheap at
    d = 2^20; modulators come from the real client-side unification.
    k_n is drawn from [k_lo, k_hi] — the paper's many-task clients
    hold several tasks each (Table 2 / Fig. 5), which is the regime
    the batched engine targets."""
    ups = []
    for cid in range(n):
        kn = int(rng.integers(k_lo, k_hi + 1))
        tasks = sorted(rng.choice(n_tasks, size=kn, replace=False).tolist())
        tvs = jnp.asarray(rng.standard_normal((kn, d)).astype(np.float32))
        unified, masks, lams = unify_with_modulators(tvs)
        ups.append(ClientUpload(cid, tasks, jax.block_until_ready(unified),
                                masks, lams,
                                rng.integers(32, 256, size=kn).tolist()))
    return ups


def _wire_uploads(ups):
    """The packed leg's inputs: what a wire-format client transmits —
    bf16 unified vector + bit-packed uint32 mask words.  Built once,
    outside the timed region (the batched client path emits this
    directly from the fused unify kernel; bool masks never exist)."""
    out = []
    for u in ups:
        words = jnp.asarray(bitpack.pack_bits_np(np.asarray(u.masks)))
        out.append(ClientUpload(u.client_id, u.task_ids,
                                jax.block_until_ready(
                                    u.unified.astype(jnp.bfloat16)),
                                words, u.lams, u.data_sizes))
    return out


def _round_wire_bytes(ups, packed):
    """Uplink + downlink slot-BUFFER bytes for one round in the given
    layout — the padded tensors the engine actually streams (the
    roofline denominator), derived from shapes via the engine's own
    padding policy.  This deliberately includes padding rows/slots: it
    is traffic, not transmitted bits — per-client transmitted bits are
    ``PackedRound.wire_bits`` / ``ClientUpload.uplink_bits``.  The
    downlink mirrors the uplink tensor shapes."""
    d = int(ups[0].unified.shape[0])
    n_max = _round_up_pow2(len(ups))
    k_max = _round_up_pow2(max(len(u.task_ids) for u in ups))
    if packed:
        up = (2 * n_max * d                               # bf16 unified
              + 4 * n_max * k_max * bitpack.packed_width(d)   # uint32 words
              + 4 * n_max * k_max)                        # fp32 λ
    else:
        up = 4 * n_max * d + n_max * k_max * d + 4 * n_max * k_max
    return 2 * up


def _block_downlinks(downs):
    """Force every device value a round produces — ClientDownlink is a
    plain dataclass (not a pytree), so block on its arrays explicitly
    or async dispatch would let the timer stop before the work runs."""
    for dl in downs.values():
        jax.block_until_ready(dl.unified)
        jax.block_until_ready(dl.masks)
        jax.block_until_ready(dl.lams)


def _time(fn, iters):
    """Best-of-iters wall time in µs — min is the noise-robust statistic
    on a shared/throttled host (all paths get the same treatment)."""
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        _block_downlinks(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _time_interleaved(fns, iters):
    """Best-of-iters for several legs with the iterations interleaved
    (a, b, a, b, …): on a host whose throttle drifts over minutes, each
    leg's min comes from the same time windows, so RATIOS between legs
    stay meaningful even when absolute times wander."""
    best = [float("inf")] * len(fns)
    for _ in range(iters):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            _block_downlinks(fn())
            best[i] = min(best[i], time.perf_counter() - t0)
    return [b * 1e6 for b in best]


def _coded_uploads(wire):
    """The coded leg's inputs: each client's packed word rows entropy-
    coded into one self-describing uint8 stream (client-side work,
    outside the timed region — mirrors ``MaTUClient.run_round`` with
    ``code_masks=True``)."""
    from repro.fed.compression import encode_mask_rows
    out = []
    for u in wire:
        d = int(u.unified.shape[0])
        stream = encode_mask_rows(np.asarray(u.masks), d)
        out.append(ClientUpload(u.client_id, u.task_ids, u.unified,
                                jnp.asarray(stream), u.lams, u.data_sizes))
    return out


def _bench_async_faults(quick: bool):
    """Simulator-level async A/B: per-round wall time of the buffered
    async mode under the fault trace (30% dropout + 2x-latency
    stragglers, staleness cap 4) vs the synchronous barrier loop on
    the same federated workload.  Local training dominates both legs
    equally; the delta is the event-clock + admission-queue + carried-
    state overhead the async server adds per round."""
    from repro.data.dirichlet import dirichlet_split
    from repro.data.synthetic import make_constellation
    from repro.fed.simulator import FedConfig, FedSimulator
    from repro.fed.strategies import AsyncMaTUStrategy, MaTUStrategy
    from repro.fed.systems import ClientSystems, FaultModel
    from repro.fed.testbed import MLPBackbone

    n_tasks, n_clients = 5, 8
    con = make_constellation(n_tasks=n_tasks, n_groups=2, feat_dim=16,
                             n_classes=4, seed=0)
    split = dirichlet_split(n_clients=n_clients, n_tasks=n_tasks,
                            n_classes=4, zeta_t=0.5, tasks_per_client=2,
                            seed=0)
    bb = MLPBackbone(16, hidden=24, lora_rank=4)
    rounds = 4 if quick else 10
    cfg = FedConfig(rounds=rounds, participation=1.0, local_steps=2,
                    batch_size=16, local_data=64, eval_every=rounds,
                    max_staleness=4)
    faults = FaultModel(dropout=0.3, straggler_frac=0.5, straggler_delay=1,
                        seed=3)

    def timed(strategy, systems):
        sim = FedSimulator(cfg, con, split, bb, strategy, systems=systems)
        t0 = time.perf_counter()
        hist = sim.run()
        return time.perf_counter() - t0, hist

    timed(MaTUStrategy(n_tasks, bb.d), None)            # warm jit caches
    timed(AsyncMaTUStrategy(n_tasks, bb.d), ClientSystems(n_clients, faults))
    s_sync, _ = timed(MaTUStrategy(n_tasks, bb.d), None)
    s_async, h_async = timed(AsyncMaTUStrategy(n_tasks, bb.d),
                             ClientSystems(n_clients, faults))
    return {
        "us_per_round_sync": s_sync * 1e6 / rounds,
        "us_per_round_async": s_async * 1e6 / rounds,
        "rounds_per_sec_sync": rounds / s_sync,
        "rounds_per_sec_async": rounds / s_async,
        "async_vs_sync": s_sync / s_async,
        "rounds": rounds,
        "n_clients": n_clients,
        "fault_totals": h_async.total_fault_counts,
    }


def run(quick: bool = False, devices: int = 1, code_masks: bool = False,
        pipeline: bool = False, faults: bool = False):
    grids = ([(8, 8, 1 << 14, 1, 2), (16, 16, 1 << 16, 2, 3)] if quick else
             [(16, 16, 1 << 16, 2, 3), (16, 30, 1 << 18, 2, 3),
              (32, 30, 1 << 20, 3, 4)])
    # the host's throttle drifts over minutes: the A/B legs interleave
    # and take more samples so each leg's min lands in a good window;
    # the (slow) legacy baseline needs fewer
    iters = 10
    legacy_iters = 3

    mesh = None
    if devices > 1:
        from repro.launch.mesh import make_round_mesh
        mesh = make_round_mesh(devices)

    rows, detail = [], {}
    for n, n_tasks, d, k_lo, k_hi in grids:
        rng = np.random.default_rng(n * 1000 + n_tasks)
        ups = _make_uploads(rng, n, n_tasks, d, k_lo, k_hi)
        wire = _wire_uploads(ups)
        tag = f"N{n}_T{n_tasks}_d{d}"

        legacy = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
        _block_downlinks(legacy.round_legacy(ups))      # warm caches
        us_legacy = _time(lambda: legacy.round_legacy(ups), legacy_iters)

        server = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
        engine = server.engine
        # bool/fp32 A/B leg (the PR 1 engine, byte-for-byte) vs the
        # packed wire-format default path (+ the sharded packed engine
        # when a mesh is up), iterations interleaved
        legs = [lambda: engine.round(ups, packed=False)[0],
                lambda: engine.round(wire)[0]]
        if mesh is not None:
            sharded = RoundEngine(EngineConfig(n_tasks=n_tasks), mesh=mesh)
            legs.append(lambda: sharded.round(wire)[0])
        for leg in legs:
            _block_downlinks(leg())                     # warm caches
        times = _time_interleaved(legs, iters)
        us_bool, us_packed = times[0], times[1]
        us_sharded = times[2] if mesh is not None else None

        bytes_bool = _round_wire_bytes(ups, packed=False)
        bytes_packed = _round_wire_bytes(wire, packed=True)
        gbps_bool = bytes_bool / (us_bool * 1e3)
        gbps_packed = bytes_packed / (us_packed * 1e3)
        sp_bool = us_legacy / us_bool
        sp_packed = us_legacy / us_packed
        ab = us_bool / us_packed

        rows.append((f"round_engine/{tag}/legacy", us_legacy,
                     f"k={k_lo}-{k_hi}"))
        rows.append((f"round_engine/{tag}/engine_bool", us_bool,
                     f"{sp_bool:.2f}x {bytes_bool / 1e6:.0f}MB "
                     f"{gbps_bool:.2f}GB/s"))
        rows.append((f"round_engine/{tag}/engine_packed", us_packed,
                     f"{sp_packed:.2f}x ({ab:.2f}x vs bool) "
                     f"{bytes_packed / 1e6:.0f}MB {gbps_packed:.2f}GB/s"))
        detail[tag] = {
            "us_legacy": us_legacy,
            "us_engine_bool": us_bool,
            "us_engine_packed": us_packed,
            "speedup_bool_vs_legacy": sp_bool,
            "speedup_packed_vs_legacy": sp_packed,
            "speedup_packed_vs_bool": ab,
            "bytes_moved_bool": bytes_bool,
            "bytes_moved_packed": bytes_packed,
            "gbps_bool": gbps_bool,
            "gbps_packed": gbps_packed,
            "n": n, "n_tasks": n_tasks, "d": d,
            "k_lo": k_lo, "k_hi": k_hi,
        }
        if us_sharded is not None:
            sh_ab = us_packed / us_sharded
            rows.append((f"round_engine/{tag}/engine_sharded", us_sharded,
                         f"{devices}dev {sh_ab:.2f}x vs single "
                         f"{bytes_packed / 1e6:.0f}MB"))
            detail[tag].update(
                devices=devices,
                us_engine_sharded=us_sharded,
                speedup_sharded_vs_single=sh_ab)

        coded = _coded_uploads(wire) if (code_masks or pipeline) else None
        if code_masks:
            # entropy-coded wire A/B: coded uploads in (decoded at the
            # host edge), coded downlink streams out; the ratio column
            # is measured off the actual byte streams, not a bound
            coded_eng = RoundEngine(EngineConfig(n_tasks=n_tasks))
            leg = lambda: coded_eng.round(coded, code_masks=True)[0]  # noqa: E731
            _block_downlinks(leg())                     # warm caches
            us_coded = _time(leg, max(2, iters // 2))
            raw_up = sum(u.uplink_bits() for u in wire)
            coded_up = sum(u.uplink_bits() for u in coded)
            ratio = coded_up / raw_up
            # mask-only ratio: the term the coder actually shrinks
            raw_mask = sum(8 * 4 * bitpack.packed_width(d) * len(u.task_ids)
                           for u in wire)
            coded_mask = sum(8 * int(u.masks.size) for u in coded)
            rows.append((f"round_engine/{tag}/engine_coded", us_coded,
                         f"coded/raw={ratio:.3f} "
                         f"masks={coded_mask / raw_mask:.3f}"))
            detail[tag].update(
                us_engine_coded=us_coded,
                raw_uplink_bits=raw_up,
                coded_uplink_bits=coded_up,
                coded_ratio=ratio,
                raw_mask_bits=raw_mask,
                coded_mask_bits=coded_mask,
                coded_mask_ratio=coded_mask / raw_mask)

        if pipeline:
            # pipelined vs sequential round_stream over the SAME coded
            # rounds — per-round wall each, host-codec/device split from
            # the sequential leg (phases don't overlap there, so
            # pack+decode+encode+device sums to its wall)
            pipe_eng = RoundEngine(EngineConfig(n_tasks=n_tasks))
            n_rounds = 2 if quick else 4

            def stream_wall(pipe_flag):
                t0 = time.perf_counter()
                phases = []
                for downs, _out, ph in pipe_eng.round_stream(
                        [coded] * n_rounds, code_masks=True,
                        pipeline=pipe_flag):
                    _block_downlinks(downs)
                    phases.append(ph)
                return (time.perf_counter() - t0) * 1e6 / n_rounds, phases

            _block_downlinks(                            # warm caches
                pipe_eng.round(coded, code_masks=True)[0])
            us_stream_seq, seq_ph = stream_wall(False)
            us_pipe, _pipe_ph = stream_wall(True)
            us_codec = float(np.mean([ph.get("pack", 0.0)
                                      + ph.get("decode", 0.0)
                                      + ph.get("encode", 0.0)
                                      for ph in seq_ph]))
            us_dev = float(np.mean([ph["device"] for ph in seq_ph]))
            rows.append((f"round_engine/{tag}/engine_pipelined", us_pipe,
                         f"seq/pipe={us_stream_seq / us_pipe:.2f}x "
                         f"codec={us_codec / 1e3:.0f}ms "
                         f"dev={us_dev / 1e3:.0f}ms "
                         f"cores={os.cpu_count()}"))
            detail[tag].update(
                us_engine_pipelined=us_pipe,
                us_engine_stream_seq=us_stream_seq,
                us_host_codec=us_codec,
                us_device_step=us_dev,
                speedup_pipelined_vs_seq=us_stream_seq / us_pipe,
                pipeline_rounds=n_rounds,
                host_cores=os.cpu_count())

    if faults:
        # async fault-trace A/B (simulator-level; one leg, not per-grid)
        fa = _bench_async_faults(quick)
        rows.append(("round_engine/fed_async/engine_async",
                     fa["us_per_round_async"],
                     f"{fa['rounds_per_sec_async']:.2f}r/s vs sync "
                     f"{fa['rounds_per_sec_sync']:.2f}r/s "
                     f"({fa['async_vs_sync']:.2f}x) "
                     f"admitted={fa['fault_totals']['admitted']} "
                     f"dropped={fa['fault_totals']['dropped']}"))
        detail["fed_async"] = fa

    save_detail("round_engine", detail)
    return {"rows": rows, "detail": detail}
