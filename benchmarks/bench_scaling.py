"""Fig. 5 — scaling tasks per client: (a) communication per round,
(b) normalized accuracy.  Paper: MaTU's comm is ~flat in k (one unified
vector + k·(mask+scalar)); MaT-FL degrades sharply for k>5 while MaTU
holds."""

from __future__ import annotations

import numpy as np

from benchmarks.common import run_strategy, save_detail, timed
from repro.data.dirichlet import dirichlet_split
from repro.data.synthetic import make_constellation
from repro.fed.simulator import FedConfig, individual_baseline
from repro.fed.testbed import MLPBackbone


def run(quick: bool = False):
    n_tasks = 12
    ks = [1, 2, 4] if quick else [1, 2, 4, 8, 12]
    con = make_constellation(n_tasks=n_tasks, n_groups=4, feat_dim=32,
                             n_classes=8, conflict_pairs=[(0, 1)], seed=0)
    bb = MLPBackbone(32, hidden=64, lora_rank=8)
    cfg = FedConfig(rounds=6 if quick else 20, local_steps=20, lr=1e-2,
                    eval_every=6 if quick else 20, seed=0)
    ind = individual_baseline(cfg, con, bb)

    rows, detail = [], {"k": {}, "adapter_per_task_bits_formula": "32*d*k"}
    for k in ks:
        split = dirichlet_split(n_clients=10, n_tasks=n_tasks, n_classes=8,
                                zeta_t=0.5, tasks_per_client=k, seed=k)
        per_k = {}
        for m in ["matu", "mat-fl"]:
            (hist, _), us = timed(run_strategy, m, con, split, bb, cfg)
            normalized = float(np.mean([
                hist.final_task_acc[t] / max(ind[t], 1e-6)
                for t in range(n_tasks)]))
            per_k[m] = {"normalized": normalized,
                        "bits_per_round": hist.mean_uplink_bits}
            rows.append((f"fig5/k={k}/{m}", us,
                         f"norm={normalized:.3f};bits={hist.mean_uplink_bits:.2e}"))
        detail["k"][k] = per_k

    b = {k: detail["k"][k]["matu"]["bits_per_round"] for k in ks}
    detail["claim_comm_subline_in_k"] = (b[ks[-1]] / b[ks[0]]) < ks[-1] / ks[0] * 0.6
    save_detail("fig5_scaling", detail)
    return {"rows": rows, "detail": detail}
