"""Multi-tenant serving bench: requests/sec of the task-routed decode
subsystem vs per-task-checkpoint swapping, plus the resident-bytes win
that is MaTU's serving headline.

Three timed legs over one mixed-task decode batch on the reduced
qwen2 backbone:

* ``serve_dense``  — ModulatorStore + dense-routed adapters (LRU),
  one compiled program for every task mix;
* ``serve_fused``  — ModulatorStore + the fused ``modulated_matmul``
  path (packed mask bits modulated inside the LoRA matmul);
* ``serve_ckpt_swap`` — the baseline a per-task-checkpoint server
  runs: each request decoded B=1 with its task's own adapter.

Storage: ``resident_bytes`` (backbone adapter + unified vector + T
packed modulators) vs T full per-task checkpoints, at T=30 — the
acceptance bar is a >=5x win.  Detail lands in
results/bench/serving.json.
"""

from __future__ import annotations

import time

from benchmarks.common import save_detail


def _timed_reqs(fn, n_requests, *, reps):
    fn()                                    # compile + warm
    t0 = time.perf_counter()
    out = None
    for _ in range(reps):
        out = fn()
    import jax
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    return dt * 1e6 / reps, reps * n_requests / dt


def run(quick: bool = False) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    jax.config.update("jax_platform_name", "cpu")

    from repro.common.tree import TaskVectorSpace, tree_add
    from repro.configs.base import SHAPES, load_arch
    from repro.core.client import ClientUpload
    from repro.core.server import MaTUServer, MaTUServerConfig
    from repro.serve import (GenerationConfig, ModulatorStore,
                             MultiTenantDecoder, generate)

    n_tasks = 30
    batch = 4 if quick else 8
    gen = 4 if quick else 16
    reps = 2 if quick else 5

    cfg = load_arch("qwen2-0.5b").reduced()
    model = cfg.build(SHAPES["decode_32k"])
    params = model.init(jax.random.PRNGKey(0))
    lora0 = model.lora_init(jax.random.PRNGKey(1))
    space = TaskVectorSpace.from_tree(lora0)

    # a real T=30 round over synthetic task vectors (serving is what is
    # being measured, not local training)
    rng = np.random.default_rng(0)
    uploads = [ClientUpload(
        t, [t],
        jnp.asarray(0.05 * rng.standard_normal(space.d), jnp.float32),
        jnp.ones((1, space.d), bool), jnp.ones((1,), jnp.float32), [64],
        fingerprint=space.fingerprint) for t in range(n_tasks)]
    server = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
    server.round(uploads)

    store = ModulatorStore(space, lora0, capacity=batch)
    store.ingest(server.serving_downlink(fingerprint=space.fingerprint))
    rep = store.storage_report()

    gen_cfg = GenerationConfig(max_new_tokens=gen, temperature=0.0)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (batch, 16),
                                 1, cfg.vocab)
    mix = [t % n_tasks for t in range(batch)]
    max_len = int(prompts.shape[1]) + gen + 8

    dense = MultiTenantDecoder(model, params, store, cfg=gen_cfg)
    fused = MultiTenantDecoder(model, params, store, fused=True,
                               cfg=gen_cfg)
    us_dense, rps_dense = _timed_reqs(
        lambda: dense.generate(prompts, mix), batch, reps=reps)
    us_fused, rps_fused = _timed_reqs(
        lambda: fused.generate(prompts, mix), batch, reps=reps)

    # checkpoint-swap baseline: every request decoded alone with its
    # task's materialised adapter (what T independent checkpoints cost)
    adapters = {t: store.adapter(t) for t in set(mix)}
    gen_one = jax.jit(lambda lora, p: generate(
        model, params, lora, p, gen_cfg, max_len=max_len))

    def ckpt_swap():
        out = None
        for r, t in enumerate(mix):
            out = gen_one(adapters[t], prompts[r:r + 1])
        return out

    us_swap, rps_swap = _timed_reqs(ckpt_swap, batch, reps=reps)

    detail = {"serving": {
        "arch": "qwen2-0.5b-reduced", "d": int(space.d),
        "n_tasks": n_tasks, "batch": batch,
        "max_new_tokens": gen,
        "req_per_s_dense": rps_dense,
        "req_per_s_fused": rps_fused,
        "req_per_s_ckpt_swap": rps_swap,
        "compiled_programs_dense": dense.compile_count(),
        "compiled_programs_fused": fused.compile_count(),
        "resident_bytes": int(rep["resident_bytes"]),
        "checkpoint_bytes": int(rep["checkpoint_bytes"]),
        "resident_ratio_T30": rep["ratio"],
    }}
    save_detail("serving", detail)
    assert rep["ratio"] >= 5.0, \
        f"resident-bytes win {rep['ratio']:.2f}x < 5x at T={n_tasks}"
    return {"rows": [
        ("serve_dense", us_dense,
         f"req_s={rps_dense:.1f} B={batch} T={n_tasks}"),
        ("serve_fused", us_fused, f"req_s={rps_fused:.1f}"),
        ("serve_ckpt_swap", us_swap, f"req_s={rps_swap:.1f}"),
        ("serve_storage", 0.0,
         f"T={n_tasks} resident={rep['resident_bytes']} "
         f"ratio={rep['ratio']:.1f}x"),
    ], "detail": detail}


if __name__ == "__main__":
    out = run(quick=True)
    for r in out["rows"]:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
