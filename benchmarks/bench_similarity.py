"""Fig. 2–3 — sign-conflict similarity vs ground-truth task relatedness.

The paper shows the sign-agreement metric recovers the task clusters
found by established transferability metrics (>0.8 Pearson).  Offline
we have the *oracle* relatedness (the generator's rotation cosine), plus
two reference metrics computed from the fine-tuned task vectors:
cosine similarity of weights [Vu et al. 2022] and an L2 task-embedding
distance (WTE stand-in)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import run_strategy, save_detail, standard_setting, timed
from repro.fed.simulator import FedConfig


def run(quick: bool = False):
    con, split, bb = standard_setting(n_tasks=8, n_clients=16, zeta_t=0.0)
    cfg = FedConfig(rounds=8 if quick else 30, local_steps=30, lr=1e-2,
                    eval_every=8 if quick else 30, seed=0)
    (hist, strat), us = timed(run_strategy, "matu", con, split, bb, cfg)

    sign_sim = np.asarray(strat.server.last_similarity)
    tvs = np.asarray(strat.server.last_task_vectors)
    oracle = con.oracle_similarity()

    # reference metrics over fine-tuned task vectors
    unit = tvs / (np.linalg.norm(tvs, axis=1, keepdims=True) + 1e-12)
    cos_sim = unit @ unit.T
    dist = np.linalg.norm(tvs[:, None] - tvs[None, :], axis=-1)
    wte_like = -dist / dist.max()  # higher = more related

    iu = np.triu_indices(con.n_tasks, k=1)

    def pearson(a, b):
        return float(np.corrcoef(a[iu], b[iu])[0, 1])

    detail = {
        "pearson_sign_vs_oracle": pearson(sign_sim, oracle),
        "pearson_sign_vs_cosine": pearson(sign_sim, cos_sim),
        "pearson_sign_vs_wte_like": pearson(sign_sim, wte_like),
        "sign_similarity": sign_sim.tolist(),
        "oracle": oracle.tolist(),
        "groups": [con.group_of(t) for t in range(con.n_tasks)],
    }
    same = [sign_sim[a, b] for a in range(8) for b in range(a + 1, 8)
            if con.group_of(a) == con.group_of(b)]
    diff = [sign_sim[a, b] for a in range(8) for b in range(a + 1, 8)
            if con.group_of(a) != con.group_of(b)]
    detail["group_separation"] = float(np.mean(same) - np.mean(diff))
    save_detail("similarity", detail)

    rows = [
        ("fig2/group_separation", us, f"delta={detail['group_separation']:.3f}"),
        ("fig3/pearson_vs_cosine", 0.0, f"r={detail['pearson_sign_vs_cosine']:.3f}"),
        ("fig3/pearson_vs_oracle", 0.0, f"r={detail['pearson_sign_vs_oracle']:.3f}"),
        ("fig3/pearson_vs_wte", 0.0, f"r={detail['pearson_sign_vs_wte_like']:.3f}"),
    ]
    return {"rows": rows, "detail": detail}
