"""Table 1 — single-task-per-client setting (ζ_t = 0, no overlap).

Paper claim (ordinal): MaTU > FedPer > MaT-FL > FedProx > NTK-FedAvg ≈
FedAvg, with MaTU within a single-digit gap of individual fine-tuning,
at FedAvg-equal bitrate.  We reproduce the ranking on the synthetic
constellation (absolute ViT numbers are not reproducible offline —
DESIGN.md §3)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import run_strategy, save_detail, standard_setting, timed
from repro.fed.simulator import FedConfig, individual_baseline

METHODS = ["matu", "fedavg", "fedprox", "ntk-fedavg", "fedper", "mat-fl"]


def run(quick: bool = False):
    con, split, bb = standard_setting(n_tasks=8, n_clients=16, zeta_t=0.0)
    cfg = FedConfig(rounds=10 if quick else 40, local_steps=30, lr=1e-2,
                    eval_every=10 if quick else 40, participation=1.0, seed=0)

    detail = {"setting": "single-task clients, zeta_t=0", "methods": {}}
    rows = []

    ind = individual_baseline(cfg, con, bb)
    ind_mean = float(np.mean(list(ind.values())))
    detail["individual"] = {"mean_acc": ind_mean, "per_task": ind}

    for m in METHODS:
        (hist, _strat), us = timed(run_strategy, m, con, split, bb, cfg)
        detail["methods"][m] = {
            "mean_acc": hist.final_mean_acc,
            "per_task": hist.final_task_acc,
            "bits_per_round": hist.mean_uplink_bits,
        }
        rows.append((f"table1/{m}", us, f"acc={hist.final_mean_acc:.3f}"))

    rows.append(("table1/individual", 0.0, f"acc={ind_mean:.3f}"))
    accs = {m: detail["methods"][m]["mean_acc"] for m in METHODS}
    detail["claims"] = {
        "matu_beats_fedavg": accs["matu"] > accs["fedavg"],
        "matu_beats_matfl": accs["matu"] > accs["mat-fl"],
        "matu_within_individual": ind_mean - accs["matu"],
    }
    save_detail("table1", detail)
    return {"rows": rows, "detail": detail}
