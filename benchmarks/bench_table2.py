"""Table 2 — multiple-task-per-client setting (ζ_t = 0.5).

Paper claims: MaTU degrades only modestly vs single-task; FedPer
collapses (personalization ≠ many-task); MaTU transmits ONE unified
vector + modulators (≈2.5× lower bpt than adapter-per-task baselines
at k≈2)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import run_strategy, save_detail, standard_setting, timed
from repro.fed.simulator import FedConfig

METHODS = ["matu", "fedavg", "fedprox", "ntk-fedavg", "fedper", "mat-fl"]


def run(quick: bool = False):
    con, split, bb = standard_setting(n_tasks=8, n_clients=16, zeta_t=0.5,
                                      tasks_per_client=2)
    cfg = FedConfig(rounds=10 if quick else 40, local_steps=30, lr=1e-2,
                    eval_every=10 if quick else 40, participation=1.0, seed=0)

    detail = {"setting": "multi-task clients, zeta_t=0.5, k=2", "methods": {}}
    rows = []
    for m in METHODS:
        (hist, _), us = timed(run_strategy, m, con, split, bb, cfg)
        detail["methods"][m] = {
            "mean_acc": hist.final_mean_acc,
            "bits_per_round": hist.mean_uplink_bits,
        }
        rows.append((f"table2/{m}", us,
                     f"acc={hist.final_mean_acc:.3f};bits={hist.mean_uplink_bits:.2e}"))

    acc = {m: detail["methods"][m]["mean_acc"] for m in METHODS}
    bits = {m: detail["methods"][m]["bits_per_round"] for m in METHODS}
    detail["claims"] = {
        "matu_best": acc["matu"] >= max(v for k, v in acc.items() if k != "matu") - 0.02,
        "fedper_collapses": acc["fedper"] < acc["matu"],
        "matu_bitrate_saving_vs_adapter_per_task": bits["fedavg"] / bits["matu"],
    }
    save_detail("table2", detail)
    return {"rows": rows, "detail": detail}
