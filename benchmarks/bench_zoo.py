"""Cross-architecture zoo round: wall-clock and measured wire bits for
a mixed round over the reduced model zoo (one ArchBackbone per family),
every client training through its family's REAL forward and flattening
through its own TaskVectorSpace manifest into the shared slot layout.

Rows land next to the engine rows in results/bench/round_engine.json
(``zoo`` key, per-family d + wire bits + the round wall-clock), so one
file holds the whole server-round story.
"""

from __future__ import annotations

import time

from benchmarks.common import save_detail


def run(quick: bool = False) -> dict:
    import jax

    jax.config.update("jax_platform_name", "cpu")

    from repro.data.dirichlet import FedSplit
    from repro.data.synthetic import make_constellation
    from repro.fed.simulator import FedConfig, FedSimulator
    from repro.fed.strategies import MaTUStrategy
    from repro.fed.testbed import make_zoo_backbones, round_up_d

    families = ["lm", "vit", "ssm", "moe"] if quick else \
        ["lm", "encdec", "vit", "ssm", "moe"]
    n_tasks = 8 if quick else 30
    feat_dim = 32  # == reduced vit patch_dim
    zoo = make_zoo_backbones(feat_dim, families=families)

    con = make_constellation(n_tasks=n_tasks, n_groups=4, feat_dim=feat_dim,
                             n_classes=4, seed=0)
    tasks = [[t] for t in range(n_tasks)]
    split = FedSplit(tasks, {(c, c): None for c in range(n_tasks)},
                     {(c, c): 64 for c in range(n_tasks)})
    bbs = {c: zoo[families[c % len(families)]] for c in range(n_tasks)}
    d = round_up_d(max(b.d for b in bbs.values()))

    cfg = FedConfig(rounds=2, local_steps=2 if quick else 4,
                    batch_size=8, local_data=32, eval_every=2, seed=0)
    strat = MaTUStrategy(n_tasks, d)
    sim = FedSimulator(cfg, con, split, bbs, strat)

    t0 = time.perf_counter()
    hist = sim.run()
    us_round = (time.perf_counter() - t0) * 1e6 / cfg.rounds

    uplink = int(hist.uplink_bits_per_round[-1])
    downlink = int(hist.downlink_bits_per_round[-1])
    detail = {"zoo": {
        "families": families,
        "n_tasks": n_tasks,
        "common_d": d,
        "family_d": {f: int(zoo[f].d) for f in families},
        "fingerprints": {f: zoo[f].fingerprint for f in families},
        "us_per_round": us_round,
        "uplink_bits_per_round": uplink,
        "downlink_bits_per_round": downlink,
        "mean_acc": hist.final_mean_acc,
    }}
    save_detail("round_engine", detail)
    return {"rows": [
        ("zoo_round", us_round,
         f"families={len(families)} T={n_tasks} d={d} "
         f"uplink_bits={uplink}"),
    ], "detail": detail}


if __name__ == "__main__":
    out = run(quick=True)
    for r in out["rows"]:
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
