"""Shared harness for the paper-table benchmarks.

Each bench_* module exposes ``run(quick: bool) -> dict`` returning
{"rows": [(name, us_per_call, derived)], "detail": {...}}; run.py
aggregates the CSV and persists detail JSON under results/bench/.
"""

from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                           "results", "bench")


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def save_detail(name: str, detail: Dict) -> None:
    """Persist a bench's detail dict, merging into any existing
    ``results/bench/<name>.json`` instead of clobbering it — a re-run
    of one leg (say the sharded A/B under ``--devices``) must not drop
    the rows another leg wrote earlier (the
    ``engine_sharded``/``speedup_sharded_vs_single`` regression).  The
    merge is one level deep: legs share top-level grid keys (e.g.
    ``N32_T30_d1048576``) but each writes its own sub-keys, so dict
    values merge per sub-key (new leg wins on conflicts) while scalar
    values replace."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    merged: Dict = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = json.load(f)
        except (json.JSONDecodeError, OSError):
            merged = {}    # unreadable stale file: start fresh
    for k, v in detail.items():
        if isinstance(v, dict) and isinstance(merged.get(k), dict):
            merged[k].update(v)
        else:
            merged[k] = v
    with open(path, "w") as f:
        json.dump(merged, f, indent=2, default=lambda o: float(o)
                  if isinstance(o, (np.floating,)) else str(o))


def standard_setting(n_tasks=8, n_clients=16, zeta_t=0.0, tasks_per_client=None,
                     conflict_pairs=((0, 1),), seed=0):
    from repro.data.dirichlet import dirichlet_split
    from repro.data.synthetic import make_constellation
    from repro.fed.testbed import MLPBackbone

    con = make_constellation(n_tasks=n_tasks, n_groups=3, feat_dim=32,
                             n_classes=8, conflict_pairs=list(conflict_pairs),
                             seed=seed)
    split = dirichlet_split(n_clients=n_clients, n_tasks=n_tasks, n_classes=8,
                            zeta_t=zeta_t, tasks_per_client=tasks_per_client,
                            zeta_c=0.1, seed=seed)
    bb = MLPBackbone(32, hidden=64, lora_rank=8)
    return con, split, bb


def run_strategy(name, con, split, bb, cfg, **strategy_kw):
    from repro.fed.simulator import FedConfig, FedSimulator
    from repro.fed.strategies import STRATEGIES

    cls = STRATEGIES[name]
    if name == "fedper":
        strategy_kw.setdefault("split_point", bb.split_point)
    strat = cls(con.n_tasks, bb.d, **strategy_kw)
    sim = FedSimulator(cfg, con, split, bb, strat)
    hist = sim.run()
    return hist, strat
