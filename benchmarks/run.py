"""Benchmark driver - one module per paper table/figure + kernels +
roofline.  Prints ``name,us_per_call,derived`` CSV; detail JSON lands in
results/bench/.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""

from __future__ import annotations

import argparse
import importlib
import sys
import traceback

BENCHES = [
    "bench_table1",      # Table 1: single-task clients
    "bench_table2",      # Table 2: multi-task clients
    "bench_similarity",  # Fig. 2-3: sign similarity vs relatedness
    "bench_30task",      # Fig. 4: 30-task benchmark
    "bench_scaling",     # Fig. 5: tasks-per-client scaling
    "bench_conflicts",   # Fig. 6: conflict groups + cross-task ablation
    "bench_kernels",     # Pallas kernel microbench
    "bench_round_engine",  # batched RoundEngine vs legacy server loop
    "bench_roofline",    # Roofline from the dry-run artifacts
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced rounds/sizes for CI-speed runs")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    benches = [b for b in BENCHES
               if args.only in (None, b, b.removeprefix("bench_"))]
    print("name,us_per_call,derived")
    failed = []
    for name in benches:
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            out = mod.run(quick=args.quick)
            for row in out["rows"]:
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
            sys.stdout.flush()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
