"""Benchmark driver - one module per paper table/figure + kernels +
roofline.  Prints ``name,us_per_call,derived`` CSV; detail JSON lands in
results/bench/.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
                                            [--devices N] [--code-masks]

``--devices N`` forces N host devices (XLA_FLAGS, set before any jax
import) so benches with a sharded leg (round_engine) can A/B the
taskvec-sharded engine against the single-device one on a CPU host.

``--code-masks`` adds the entropy-coded mask-wire A/B leg to benches
that take a ``code_masks`` kwarg (round_engine): coded uploads +
coded downlink streams, with the measured coded/raw uplink ratio
emitted as a row and recorded in results/bench/round_engine.json.

``--pipeline`` adds the pipelined-vs-sequential ``round_stream`` A/B
leg (plus the ``us_host_codec``/``us_device_step`` split) to benches
that take a ``pipeline`` kwarg (round_engine) — the one-command
reproduction of the pipelined rows in round_engine.json.

``--faults`` adds the async fault-trace A/B leg to benches that take a
``faults`` kwarg (round_engine): rounds/sec of the buffered async
simulator mode under 30% dropout + 2x-latency stragglers vs the
synchronous barrier loop, emitted as the ``engine_async`` row.

``--zoo`` adds the cross-architecture zoo round (bench_zoo): a mixed
round over the reduced model zoo — one real backbone per family, each
client flattening through its own TaskVectorSpace manifest — with the
round wall-clock and measured wire bits merged into
results/bench/round_engine.json under the ``zoo`` key.

``--serving`` adds the multi-tenant serving bench (bench_serving):
requests/sec of the task-routed decode subsystem (dense-routed and
fused) vs per-task-checkpoint swapping, plus the T=30 resident-bytes
ratio, recorded in results/bench/serving.json.
"""

from __future__ import annotations

import argparse
import importlib
import inspect
import os
import sys
import traceback

BENCHES = [
    "bench_table1",      # Table 1: single-task clients
    "bench_table2",      # Table 2: multi-task clients
    "bench_similarity",  # Fig. 2-3: sign similarity vs relatedness
    "bench_30task",      # Fig. 4: 30-task benchmark
    "bench_scaling",     # Fig. 5: tasks-per-client scaling
    "bench_conflicts",   # Fig. 6: conflict groups + cross-task ablation
    "bench_kernels",     # Pallas kernel microbench
    "bench_round_engine",  # batched RoundEngine vs legacy server loop
    "bench_population",  # chunked engine over a 10^6-client population
    "bench_roofline",    # Roofline from the dry-run artifacts
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced rounds/sizes for CI-speed runs")
    ap.add_argument("--only", default=None)
    ap.add_argument("--devices", type=int, default=1,
                    help="force N host devices; benches that take a "
                         "``devices`` kwarg add a sharded A/B leg")
    ap.add_argument("--code-masks", action="store_true",
                    help="add the entropy-coded mask-wire A/B leg to "
                         "benches that take a ``code_masks`` kwarg")
    ap.add_argument("--pipeline", action="store_true",
                    help="add the pipelined round_stream A/B leg to "
                         "benches that take a ``pipeline`` kwarg")
    ap.add_argument("--faults", action="store_true",
                    help="add the async fault-trace A/B leg (rounds/sec "
                         "async vs sync under 30%% dropout + 2x-latency "
                         "stragglers) to benches that take a ``faults`` "
                         "kwarg")
    ap.add_argument("--zoo", action="store_true",
                    help="add the cross-architecture zoo round "
                         "(bench_zoo) to the bench list")
    ap.add_argument("--serving", action="store_true",
                    help="add the multi-tenant serving bench "
                         "(bench_serving) to the bench list")
    args = ap.parse_args()

    if args.devices > 1:
        # must land before the first transitive jax import below —
        # jax locks the device count on first init
        assert "jax" not in sys.modules, "--devices needs jax unimported"
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    all_benches = (BENCHES + (["bench_zoo"] if args.zoo else [])
                   + (["bench_serving"] if args.serving else []))
    benches = [b for b in all_benches
               if args.only in (None, b, b.removeprefix("bench_"))]
    print("name,us_per_call,derived")
    failed = []
    for name in benches:
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            kw = {}
            params = inspect.signature(mod.run).parameters
            if "devices" in params:
                kw["devices"] = args.devices
            if "code_masks" in params:
                kw["code_masks"] = args.code_masks
            if "pipeline" in params:
                kw["pipeline"] = args.pipeline
            if "faults" in params:
                kw["faults"] = args.faults
            out = mod.run(quick=args.quick, **kw)
            for row in out["rows"]:
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
            sys.stdout.flush()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
