"""End-to-end driver: many-task federated LoRA fine-tuning of a REAL
language model from the assigned zoo (reduced qwen2 family), with MaTU
aggregation over the flat LoRA space — the paper's pipeline applied to
an actual transformer.

Three synthetic "tasks" = three next-token languages (distinct Markov
transition structures over the token space).  Each of 4 clients holds
1-2 tasks; per round every client fine-tunes LoRA per task, unifies,
uploads; the stateless server runs Eq. 3-6 and downlinks modulators.

    PYTHONPATH=src python examples/fed_finetune_lm.py [--rounds 5]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import save
from repro.common.tree import TaskVectorSpace
from repro.configs.base import SHAPES, load_arch
from repro.core.client import ClientUpload
from repro.core.server import MaTUServer, MaTUServerConfig
from repro.core.unify import modulate, unify_with_modulators
from repro.optim import adamw
from repro.train.trainer import make_train_step


def make_task_sampler(task_id: int, vocab: int, seed: int = 0):
    """Markov-chain 'language' over the token space, one per task."""
    rng = np.random.default_rng(seed + 101 * task_id)
    base = rng.dirichlet([0.05] * 64, size=64)  # sparse 64-state chain

    def sample(key, batch, seq):
        k1, k2 = jax.random.split(key)
        toks = np.zeros((batch, seq), np.int32)
        states = rng.integers(0, 64, batch)
        for s in range(seq):
            probs = base[states]
            states = np.array([rng.choice(64, p=p) for p in probs])
            toks[:, s] = states + task_id * 64  # distinct token regions
        t = jnp.asarray(toks % vocab)
        return {"tokens": t, "labels": jnp.concatenate(
            [t[:, 1:], jnp.full((batch, 1), -100, jnp.int32)], axis=1)}

    return sample


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=48)
    args = ap.parse_args()

    cfg = load_arch("qwen2-0.5b").reduced()
    model = cfg.build(SHAPES["train_4k"])
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    lora0 = model.lora_init(jax.random.PRNGKey(1))
    # the flat d-axis is DEFINED by the layout manifest; its fingerprint
    # is what client and server compare before a round
    space = TaskVectorSpace.from_tree(lora0)
    d = space.d
    print(f"model: reduced qwen2 family, LoRA d = {d}, "
          f"layout {space.fingerprint}")

    n_tasks = 3
    client_tasks = [[0], [1], [2], [0, 2]]
    samplers = {t: make_task_sampler(t, cfg.vocab) for t in range(n_tasks)}

    train_step, opt = make_train_step(model, adamw(5e-3))
    server = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
    downlinks = {}

    def local_finetune(tv_flat, task, rng):
        """θ_p ⊕ τ -> E local steps -> new τ (flat).  The flat vector
        crosses the wire edge through the layout manifest: unflatten
        once on entry, flatten once on return."""
        lora = jax.tree_util.tree_map(
            jnp.add, lora0, space.unflatten(tv_flat))
        state = opt.init(lora)
        loss = None
        for s in range(args.local_steps):
            rng, k = jax.random.split(rng)
            batch = samplers[task](k, args.batch, args.seq)
            lora, state, m = train_step(params, lora, state, batch)
            loss = float(m["loss"])
        delta = jax.tree_util.tree_map(jnp.subtract, lora, lora0)
        return space.flatten(delta), loss

    rng = jax.random.PRNGKey(42)
    for r in range(args.rounds):
        uploads, losses = [], []
        for cid, tasks in enumerate(client_tasks):
            tvs = []
            for i, t in enumerate(tasks):
                rng, k = jax.random.split(rng)
                if cid in downlinks:
                    dl = downlinks[cid]
                    tv0 = modulate(dl.unified, dl.masks[i], dl.lams[i])
                else:
                    tv0 = jnp.zeros((d,), jnp.float32)
                tv, loss = local_finetune(tv0, t, k)
                tvs.append(tv)
                losses.append(loss)
            unified, masks, lams = unify_with_modulators(jnp.stack(tvs))
            uploads.append(ClientUpload(
                cid, tasks, unified, masks, lams,
                [args.batch * args.seq] * len(tasks),
                fingerprint=space.fingerprint))
        downlinks.update(server.round(uploads))
        bits = sum(u.uplink_bits() for u in uploads)
        print(f"round {r+1}: mean local loss {np.mean(losses):.4f}  "
              f"uplink {bits/8/2**20:.2f} MiB  "
              f"S(0,2)={float(server.last_similarity[0,2]):.2f}")

    # results/ckpt/ is git-ignored: run artifacts never land in the tree
    save("results/ckpt/fed_lm", {"task_vectors": server.last_task_vectors},
         {"rounds": args.rounds})
    print("saved server task vectors -> results/ckpt/fed_lm.npz")


if __name__ == "__main__":
    main()
