"""Quickstart: MaTU in 80 lines.

1. builds a synthetic 6-task constellation with a known conflict,
2. runs federated LoRA fine-tuning with the MaTU strategy,
3. prints per-round accuracy, the sign-similarity matrix Eq. 5 learned
   by the server, and the communication ledger vs FedAvg.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.data.dirichlet import dirichlet_split
from repro.data.synthetic import make_constellation
from repro.fed.simulator import FedConfig, FedSimulator, individual_baseline
from repro.fed.strategies import FedAvgStrategy, MaTUStrategy
from repro.fed.testbed import MLPBackbone


def main():
    n_tasks = 6
    con = make_constellation(n_tasks=n_tasks, n_groups=3, feat_dim=32,
                             n_classes=8, conflict_pairs=[(0, 1)], seed=0)
    split = dirichlet_split(n_clients=9, n_tasks=n_tasks, n_classes=8,
                            zeta_t=0.5, tasks_per_client=2, seed=0)
    bb = MLPBackbone(32, hidden=64, lora_rank=8)
    cfg = FedConfig(rounds=20, local_steps=25, lr=1e-2, eval_every=5, seed=0)

    print(f"== constellation: {n_tasks} tasks in 3 groups "
          f"(groups 0 and 1 conflict), d = {bb.d} LoRA params ==")

    ind = individual_baseline(cfg, con, bb)
    print(f"individual fine-tuning (upper bound): "
          f"{np.mean(list(ind.values())):.3f}\n")

    results = {}
    for name, cls in [("matu", MaTUStrategy), ("fedavg", FedAvgStrategy)]:
        strat = cls(n_tasks, bb.d)
        sim = FedSimulator(cfg, con, split, bb, strat)
        hist = sim.run(verbose=True)
        results[name] = (hist, strat)
        print()

    h_matu, strat = results["matu"]
    h_avg, _ = results["fedavg"]
    print("== final mean accuracy ==")
    print(f"  MaTU    {h_matu.final_mean_acc:.3f}  "
          f"({h_matu.mean_uplink_bits/8/2**20:.2f} MiB/round uplink)")
    print(f"  FedAvg  {h_avg.final_mean_acc:.3f}  "
          f"({h_avg.mean_uplink_bits/8/2**20:.2f} MiB/round uplink)")

    print("\n== server sign-similarity S(t,t') (Eq. 5) ==")
    s = np.asarray(strat.server.last_similarity)
    groups = [con.group_of(t) for t in range(n_tasks)]
    print("groups:", groups)
    for row in s:
        print("  " + " ".join(f"{v:.2f}" for v in row))
    same = [s[a, b] for a in range(n_tasks) for b in range(a + 1, n_tasks)
            if groups[a] == groups[b]]
    diff = [s[a, b] for a in range(n_tasks) for b in range(a + 1, n_tasks)
            if groups[a] != groups[b]]
    print(f"mean within-group S = {np.mean(same):.3f}, "
          f"cross-group S = {np.mean(diff):.3f}")


if __name__ == "__main__":
    main()
