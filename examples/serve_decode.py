"""Multi-tenant serving demo: ONE backbone + ONE unified task vector +
T cheap modulators, decoding a mixed-task batch through one compiled
program.

An actual federated round feeds serving: per-task clients fine-tune
LoRA on distinct Markov "languages" (same rig as fed_finetune_lm),
the MaTU server aggregates, and ``serving_downlink`` hands the round's
unified vector + packed modulators straight to a ``ModulatorStore``.
Requests then carry task ids as DATA: the routed decode program
compiles once and serves every task mix — dense-routed adapters from
the store's LRU, or the fused path where packed mask bits are
modulated inside the LoRA matmul kernel.

    PYTHONPATH=src python examples/serve_decode.py [--quick]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import TaskVectorSpace
from repro.configs.base import SHAPES, load_arch
from repro.core.client import ClientUpload
from repro.core.server import MaTUServer, MaTUServerConfig
from repro.core.unify import unify_with_modulators
from repro.optim import adamw
from repro.serve import GenerationConfig, ModulatorStore, MultiTenantDecoder
from repro.train.trainer import make_train_step

from fed_finetune_lm import make_task_sampler


def federated_round(model, params, lora0, space, samplers, *,
                    local_steps, batch, seq, vocab):
    """One synchronous round, one single-task client per task, through
    the real local-trainer + MaTU server pipeline."""
    train_step, opt = make_train_step(model, adamw(5e-3))
    uploads = []
    rng = jax.random.PRNGKey(42)
    for t in sorted(samplers):
        lora = lora0
        state = opt.init(lora)
        for _ in range(local_steps):
            rng, k = jax.random.split(rng)
            lora, state, m = train_step(params, lora, state,
                                        samplers[t](k, batch, seq))
        delta = jax.tree_util.tree_map(jnp.subtract, lora, lora0)
        unified, masks, lams = unify_with_modulators(
            space.flatten(delta)[None])
        uploads.append(ClientUpload(
            t, [t], unified, masks, lams, [batch * seq],
            fingerprint=space.fingerprint))
    server = MaTUServer(MaTUServerConfig(n_tasks=len(samplers)))
    server.round(uploads)
    return server


def timed_batches(decoder, prompts, task_ids, *, reps):
    decoder.generate(prompts, task_ids)                 # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = decoder.generate(prompts, task_ids)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    return out, reps * len(task_ids) / dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-smoke sizes (fewer local steps / reps)")
    ap.add_argument("--tasks", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=None)
    args = ap.parse_args()
    local_steps = args.local_steps or (2 if args.quick else 6)
    reps = 2 if args.quick else 8

    cfg = load_arch("qwen2-0.5b").reduced()
    model = cfg.build(SHAPES["decode_32k"])
    params = model.init(jax.random.PRNGKey(0))
    lora0 = model.lora_init(jax.random.PRNGKey(1))
    space = TaskVectorSpace.from_tree(lora0)
    print(f"backbone: reduced qwen2, LoRA d = {space.d}, "
          f"layout {space.fingerprint}")

    samplers = {t: make_task_sampler(t, cfg.vocab)
                for t in range(args.tasks)}
    server = federated_round(model, params, lora0, space, samplers,
                             local_steps=local_steps, batch=4, seq=32,
                             vocab=cfg.vocab)

    # -- the serving handoff: one downlink makes the round resident ----
    store = ModulatorStore(space, lora0, capacity=args.tasks)
    store.ingest(server.serving_downlink(fingerprint=space.fingerprint))
    rep = store.storage_report()
    print(f"store: {rep['tasks']} tasks resident in "
          f"{rep['resident_bytes']/2**20:.2f} MiB vs "
          f"{rep['checkpoint_bytes']/2**20:.2f} MiB of per-task "
          f"checkpoints ({rep['ratio']:.1f}x smaller)")

    # -- mixed-task traffic: task ids are data, one program serves all --
    gen_cfg = GenerationConfig(max_new_tokens=8, temperature=0.0)
    b = args.tasks
    prompts = jax.random.randint(jax.random.PRNGKey(3), (b, 16),
                                 1, cfg.vocab)
    mixes = [list(range(args.tasks)),
             list(range(args.tasks))[::-1],
             [0] * b]
    dense = MultiTenantDecoder(model, params, store, cfg=gen_cfg)
    fused = MultiTenantDecoder(model, params, store, fused=True,
                               cfg=gen_cfg)

    for mix in mixes:
        out = dense.generate(prompts, mix)
        print(f"  mix {mix}: first tokens "
              f"{[int(x) for x in out[:, prompts.shape[1]]]}")
    assert dense.compile_count() == 1, "decode recompiled across mixes"

    mix = mixes[0]
    out_d, rps_d = timed_batches(dense, prompts, mix, reps=reps)
    out_f, rps_f = timed_batches(fused, prompts, mix, reps=reps)
    same = bool(jnp.array_equal(out_d, out_f))
    print(f"dense-routed: {rps_d:.1f} req/s   fused: {rps_f:.1f} req/s   "
          f"tokens identical: {same}")
    print(f"compiled decode programs: dense={dense.compile_count()} "
          f"fused={fused.compile_count()}  "
          f"LRU hits/misses: {store.hits}/{store.misses}")
    assert same, "fused decode diverged from dense-routed"


if __name__ == "__main__":
    main()
