"""Serving example: batched prefill + autoregressive decode with KV /
recurrent-state caches, across three architecture families (dense GQA
with ring-buffer SWA, xLSTM with O(1) state, deepseek-style MLA with
the compressed latent cache).

    PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, load_arch


def serve(arch: str, *, batch=2, prompt_len=24, gen=8):
    cfg = load_arch(arch).reduced()
    model = cfg.build(SHAPES["decode_32k"])
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    lora = model.lora_init(jax.random.PRNGKey(1))
    prompt = jax.random.randint(key, (batch, prompt_len), 1, cfg.vocab)

    prefill = jax.jit(lambda p, l, b, c: model.prefill_step(p, l, b, c))
    decode = jax.jit(lambda p, l, b, c, pos: model.decode_fn(p, l, b, c, pos))

    cache = model.init_cache(batch, prompt_len + gen + 8)
    t0 = time.perf_counter()
    logits, cache = prefill(params, lora, {"tokens": prompt}, cache)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    for i in range(gen - 1):
        logits, cache = decode(params, lora, {"tokens": tok}, cache,
                               jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    toks = jnp.concatenate(out, axis=1)

    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree_util.tree_leaves(cache))
    print(f"{arch:24s} generated {gen} tokens x {batch} seqs in {dt:.2f}s  "
          f"cache={cache_bytes/2**20:.2f} MiB")
    print(f"  sample: {list(map(int, toks[0][:8]))}")


def main():
    for arch in ["qwen2-0.5b", "xlstm-1.3b", "deepseek-v2-236b"]:
        serve(arch)


if __name__ == "__main__":
    main()
