"""Round-resumable checkpointing: pytrees → npz + json manifest.

Arrays are stored flat (leaf path → array) in a single .npz; the
manifest records the tree structure, dtypes, and user metadata
(round number, strategy, config digest) so a federated run or a
trainer can resume exactly.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
SEP = "/"


def _flatten_with_paths(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: PyTree, metadata: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    np.savez(path + ".npz", **flat)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "treedef": str(treedef),
        "keys": sorted(flat.keys()),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "metadata": metadata or {},
    }
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=2)


def load(path: str, like: PyTree) -> Tuple[PyTree, dict]:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    with open(path + ".json") as f:
        manifest = json.load(f)
    data = np.load(path + ".npz")
    flat_like = _flatten_with_paths(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    leaves, treedef = jax.tree_util.tree_flatten(like)
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    out = []
    for (path_keys, leaf) in paths:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_keys)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["metadata"]
