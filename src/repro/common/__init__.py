from repro.common import tree
from repro.common.tree import (
    tree_size,
    tree_flatten_vector,
    tree_unflatten_vector,
    tree_zeros_like,
    tree_add,
    tree_sub,
    tree_scale,
    tree_dot,
    tree_norm,
)

__all__ = [
    "tree",
    "tree_size",
    "tree_flatten_vector",
    "tree_unflatten_vector",
    "tree_zeros_like",
    "tree_add",
    "tree_sub",
    "tree_scale",
    "tree_dot",
    "tree_norm",
]
