"""Pytree utilities shared across the framework.

Task vectors live in (LoRA-)parameter pytrees; the MaTU server math is
defined over the *flattened* d-dimensional vector. These helpers move
between the two representations deterministically (leaves in
``jax.tree_util`` canonical order) so client and server always agree on
the layout of the unified task vector.

:class:`TaskVectorSpace` is the explicit form of that agreement: a
deterministic layout manifest (leaf path, shape, per-leaf dtype, flat
offset) mapping any LoRA-targeted parameter pytree onto the d-axis the
round engine operates on, plus a serializable fingerprint so client and
server can verify they are talking about the same layout *before* a
round aggregates anything.  The legacy ``tree_flatten_vector`` /
``tree_unflatten_vector`` pair stays as the unchecked fast path — a
``TaskVectorSpace`` built from a template produces byte-identical flat
vectors (same canonical leaf order, same raveling).
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_size(tree: PyTree) -> int:
    """Total number of scalar entries across all leaves."""
    return sum(int(np.prod(leaf.shape)) for leaf in jax.tree_util.tree_leaves(tree))


def tree_flatten_vector(tree: PyTree, dtype=jnp.float32) -> jax.Array:
    """Flatten a pytree of arrays into a single 1-D vector.

    Leaf order is jax's canonical tree order, so the inverse
    (:func:`tree_unflatten_vector`) round-trips exactly.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((0,), dtype=dtype)
    return jnp.concatenate([jnp.ravel(leaf).astype(dtype) for leaf in leaves])


def tree_unflatten_vector(vector: jax.Array, like: PyTree) -> PyTree:
    """Inverse of :func:`tree_flatten_vector` given a structural template."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out, offset = [], 0
    for leaf in leaves:
        n = int(np.prod(leaf.shape))
        out.append(jnp.reshape(vector[offset : offset + n], leaf.shape).astype(leaf.dtype))
        offset += n
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    parts = jax.tree_util.tree_map(lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    return sum(jax.tree_util.tree_leaves(parts))


def tree_norm(a: PyTree) -> jax.Array:
    return jnp.sqrt(tree_dot(a, a))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)


# ----------------------------------------------------------------------
# TaskVectorSpace: the explicit flat-layout contract
# ----------------------------------------------------------------------


class TaskVectorLayoutError(ValueError):
    """Client/server disagree on the task-vector layout (manifest
    fingerprint mismatch, or a tree that doesn't fit the manifest).
    Raised *before* any aggregation touches the offending vector."""


def _render_path(key_path) -> str:
    """Stable, human-readable path string for a tree_util key path.

    Dict keys render as their key, sequence entries as their index —
    ``units/blk0/mixer/wq/a``.  The rendering is the manifest identity,
    so it must stay deterministic across processes."""
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:  # pragma: no cover - future key kinds
            parts.append(str(k))
    return "/".join(parts)


@dataclass(frozen=True)
class LeafSpec:
    """One manifest row: where a model-space leaf lives on the d-axis."""

    path: str
    shape: Tuple[int, ...]
    dtype: str
    offset: int

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


class TaskVectorSpace:
    """Deterministic layout manifest mapping a LoRA parameter pytree to
    the flat d-axis.

    Layout contract
    ---------------
    * Leaves are enumerated in jax's canonical tree order (the same
      order :func:`tree_flatten_vector` uses), each raveled C-order and
      placed at a contiguous ``[offset, offset + size)`` slice of the
      flat vector; ``d`` is the total.
    * The flat wire dtype is ``dtype`` (fp32 by default); per-leaf model
      dtypes are recorded in the manifest and restored on
      :meth:`unflatten`.
    * ``fingerprint`` is a content hash of the manifest (paths, shapes,
      dtypes, offsets, d).  Two processes that agree on the fingerprint
      are guaranteed to agree on the meaning of every coordinate of the
      flat vector; disagreement must abort the round — see
      :meth:`require_compatible`.

    A space built with :meth:`from_tree` keeps the template's treedef
    and supports :meth:`flatten`/:meth:`unflatten`; a space rebuilt via
    :meth:`from_json` carries the manifest only (enough to verify
    fingerprints and describe the layout), and rebuilds a nested-dict
    template from the paths for structure-free use.
    """

    def __init__(self, leaves: Tuple[LeafSpec, ...], dtype=jnp.float32,
                 treedef=None):
        self.leaves = tuple(leaves)
        self.dtype = jnp.dtype(dtype)
        self._treedef = treedef
        self.d = int(sum(l.size for l in self.leaves))
        # offsets must tile [0, d) contiguously in order
        off = 0
        for leaf in self.leaves:
            if leaf.offset != off:
                raise TaskVectorLayoutError(
                    f"manifest offset for {leaf.path!r} is {leaf.offset}, "
                    f"expected {off} (manifest rows must tile the d-axis)")
            off += leaf.size

    # -- construction ---------------------------------------------------
    @classmethod
    def from_tree(cls, tree: PyTree, dtype=jnp.float32) -> "TaskVectorSpace":
        """Build the manifest from a template pytree (e.g. the model's
        ``lora_init`` output).  Leaf order is canonical tree order."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        specs, off = [], 0
        for key_path, leaf in flat:
            spec = LeafSpec(path=_render_path(key_path),
                            shape=tuple(int(s) for s in leaf.shape),
                            dtype=str(jnp.dtype(leaf.dtype)),
                            offset=off)
            specs.append(spec)
            off += spec.size
        return cls(tuple(specs), dtype=dtype, treedef=treedef)

    # -- identity -------------------------------------------------------
    def manifest_text(self) -> str:
        """Canonical text form of the manifest (the fingerprint input)."""
        lines = [f"{l.path} shape={l.shape} dtype={l.dtype} offset={l.offset}"
                 for l in self.leaves]
        lines.append(f"d={self.d} wire_dtype={self.dtype.name}")
        return "\n".join(lines)

    @property
    def fingerprint(self) -> str:
        return hashlib.sha256(self.manifest_text().encode()).hexdigest()[:16]

    def require_compatible(self, other, context: str = "") -> None:
        """Abort-before-aggregate check.  ``other`` is a fingerprint
        string or another :class:`TaskVectorSpace`; raises
        :class:`TaskVectorLayoutError` on mismatch."""
        theirs = other.fingerprint if isinstance(other, TaskVectorSpace) else str(other)
        if theirs != self.fingerprint:
            where = f" ({context})" if context else ""
            raise TaskVectorLayoutError(
                f"task-vector layout mismatch{where}: local manifest "
                f"{self.fingerprint} != peer {theirs}; refusing to "
                f"aggregate vectors whose coordinates may not align")

    def by_path(self, path: str) -> LeafSpec:
        """Manifest row for a leaf path (serving router lookup: a
        consumer that slices one leaf's coordinates — or packed mask
        bits — out of the flat d-axis needs the leaf's offset/shape
        without walking the whole manifest)."""
        if not hasattr(self, "_by_path"):
            self._by_path = {l.path: l for l in self.leaves}
        try:
            return self._by_path[path]
        except KeyError:
            raise TaskVectorLayoutError(
                f"no manifest row for leaf path {path!r}") from None

    # -- flat <-> tree --------------------------------------------------
    def template(self) -> PyTree:
        """Zeros pytree in the manifest's model space."""
        leaves = [jnp.zeros(l.shape, dtype=l.dtype) for l in self.leaves]
        if self._treedef is not None:
            return jax.tree_util.tree_unflatten(self._treedef, leaves)
        root: dict = {}
        for spec, leaf in zip(self.leaves, leaves):
            node = root
            parts = spec.path.split("/") if spec.path else [""]
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = leaf
        return root

    def _check_tree(self, tree: PyTree) -> list:
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        if len(flat) != len(self.leaves):
            raise TaskVectorLayoutError(
                f"tree has {len(flat)} leaves, manifest has "
                f"{len(self.leaves)}")
        leaves = []
        for (key_path, leaf), spec in zip(flat, self.leaves):
            path = _render_path(key_path)
            if path != spec.path or tuple(leaf.shape) != spec.shape:
                raise TaskVectorLayoutError(
                    f"leaf {path!r} {tuple(leaf.shape)} does not match "
                    f"manifest row {spec.path!r} {spec.shape}")
            leaves.append(leaf)
        return leaves

    def flatten(self, tree: PyTree) -> jax.Array:
        """Model-space pytree -> flat (d,) wire vector.  Validates the
        tree against the manifest (path + shape per leaf)."""
        leaves = self._check_tree(tree)
        if not leaves:
            return jnp.zeros((0,), dtype=self.dtype)
        return jnp.concatenate([jnp.ravel(x).astype(self.dtype) for x in leaves])

    def unflatten(self, vector: jax.Array) -> PyTree:
        """Flat (>= d,) wire vector -> model-space pytree (extra
        zero-pad coordinates past ``d`` are ignored)."""
        if int(vector.shape[0]) < self.d:
            raise TaskVectorLayoutError(
                f"vector has {int(vector.shape[0])} coords, manifest "
                f"needs d={self.d}")
        pieces = [jnp.reshape(vector[l.offset:l.offset + l.size],
                              l.shape).astype(l.dtype) for l in self.leaves]
        if self._treedef is not None:
            return jax.tree_util.tree_unflatten(self._treedef, pieces)
        out = self.template()
        flat_paths = [l.path for l in self.leaves]
        for path, piece in zip(flat_paths, pieces):
            node = out
            parts = path.split("/") if path else [""]
            for p in parts[:-1]:
                node = node[p]
            node[parts[-1]] = piece
        return out

    # -- serialization --------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "version": 1,
            "wire_dtype": self.dtype.name,
            "d": self.d,
            "fingerprint": self.fingerprint,
            "leaves": [{"path": l.path, "shape": list(l.shape),
                        "dtype": l.dtype, "offset": l.offset}
                       for l in self.leaves],
        }, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "TaskVectorSpace":
        obj = json.loads(text)
        specs = tuple(LeafSpec(path=e["path"], shape=tuple(e["shape"]),
                               dtype=e["dtype"], offset=int(e["offset"]))
                      for e in obj["leaves"])
        space = cls(specs, dtype=jnp.dtype(obj["wire_dtype"]))
        if obj.get("fingerprint") and obj["fingerprint"] != space.fingerprint:
            raise TaskVectorLayoutError(
                f"serialized fingerprint {obj['fingerprint']} does not "
                f"match rebuilt manifest {space.fingerprint}")
        return space

    def __repr__(self) -> str:
        return (f"TaskVectorSpace(d={self.d}, leaves={len(self.leaves)}, "
                f"fingerprint={self.fingerprint})")


def pad_vector(vector: jax.Array, d: int) -> jax.Array:
    """Zero-pad a flat vector up to a common d (the engine's shared slot
    width).  Identity when already that long."""
    n = int(vector.shape[0])
    if n == d:
        return vector
    if n > d:
        raise TaskVectorLayoutError(f"vector ({n}) longer than target d ({d})")
    return jnp.pad(vector, (0, d - n))
