"""Pytree utilities shared across the framework.

Task vectors live in (LoRA-)parameter pytrees; the MaTU server math is
defined over the *flattened* d-dimensional vector. These helpers move
between the two representations deterministically (leaves in
``jax.tree_util`` canonical order) so client and server always agree on
the layout of the unified task vector.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_size(tree: PyTree) -> int:
    """Total number of scalar entries across all leaves."""
    return sum(int(np.prod(leaf.shape)) for leaf in jax.tree_util.tree_leaves(tree))


def tree_flatten_vector(tree: PyTree, dtype=jnp.float32) -> jax.Array:
    """Flatten a pytree of arrays into a single 1-D vector.

    Leaf order is jax's canonical tree order, so the inverse
    (:func:`tree_unflatten_vector`) round-trips exactly.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((0,), dtype=dtype)
    return jnp.concatenate([jnp.ravel(leaf).astype(dtype) for leaf in leaves])


def tree_unflatten_vector(vector: jax.Array, like: PyTree) -> PyTree:
    """Inverse of :func:`tree_flatten_vector` given a structural template."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out, offset = [], 0
    for leaf in leaves:
        n = int(np.prod(leaf.shape))
        out.append(jnp.reshape(vector[offset : offset + n], leaf.shape).astype(leaf.dtype))
        offset += n
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    parts = jax.tree_util.tree_map(lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b)
    return sum(jax.tree_util.tree_leaves(parts))


def tree_norm(a: PyTree) -> jax.Array:
    return jnp.sqrt(tree_dot(a, a))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)
