"""Architecture & input-shape registry.

Each assigned architecture has a module ``repro/configs/<id>.py``
defining ``CONFIG = ArchConfig(...)`` with the exact published
hyper-parameters (source cited in the file).  ``ArchConfig.build``
instantiates the model; ``reduced()`` yields the smoke-test variant
(≤2 layers/units, d_model ≤ 512, ≤ 4 experts) of the same family.

Input shapes are the four assigned global shapes; ``input_specs``
produces ``jax.ShapeDtypeStruct`` stand-ins for every model input of a
given (arch × shape) so the multi-pod dry-run lowers without touching
device memory.
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclass
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    source: str = ""                 # citation
    # dense/attention options
    qkv_bias: bool = False
    rope_base: float = 1_000_000.0
    tie_embeddings: bool = False
    head_dim: Optional[int] = None
    # moe options
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    shared_d_ff: Optional[int] = None
    moe_capacity_factor: float = 1.25
    # mla options (deepseek)
    use_mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # ssm / hybrid options
    ssm_state: int = 16
    mlstm_chunk: int = 256
    hybrid_window: int = 2048        # hymba SWA on the attention branch
    # vlm options
    mrope_sections: Optional[Tuple[int, int, int]] = None
    vision_tokens: int = 1024        # stub patch embeddings per sample
    # audio options
    enc_frames: int = 1500
    # long-context policy
    sliding_window_long: Optional[int] = 4096  # None => skip long_500k
    # PEFT / numerics
    lora_rank: int = 16
    dtype: Any = jnp.bfloat16
    remat: bool = True

    # -- variants ------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family, tiny dims, fp32, CPU-friendly."""
        r = replace(
            self,
            n_layers=2,
            d_model=min(self.d_model, 128),
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            n_shared_experts=min(self.n_shared_experts, 1),
            shared_d_ff=min(self.shared_d_ff, 64) if self.shared_d_ff else None,
            moe_capacity_factor=8.0,  # droplessness for smoke-test equality
            q_lora_rank=32,
            kv_lora_rank=16,
            qk_nope_dim=16,
            qk_rope_dim=8,
            v_head_dim=16,
            head_dim=None,
            ssm_state=8,
            mlstm_chunk=16,
            hybrid_window=16,
            vision_tokens=8,
            enc_frames=16,
            mrope_sections=(4, 6, 6) if self.mrope_sections else None,
            lora_rank=4,
            dtype=jnp.float32,
            remat=False,
        )
        return r

    @property
    def supports_long(self) -> bool:
        if self.family in ("ssm", "hybrid"):
            return True
        if self.family == "audio":
            return False  # see DESIGN.md: 500k decoder context is not meaningful
        return self.sliding_window_long is not None

    def window_for_shape(self, shape: ShapeSpec) -> Optional[int]:
        if shape.name == "long_500k" and self.family not in ("ssm",):
            return self.sliding_window_long
        return None

    # -- model builder --------------------------------------------------------
    def build(self, shape: Optional[ShapeSpec] = None):
        from repro.models.builders import build_model
        return build_model(self, shape)


    # -- LoRA targeting rules -------------------------------------------------
    def lora_targets(self) -> Tuple[str, ...]:
        """Which matmuls get LoRA adapters in this family.

        Returned as module-path patterns matched against the
        ``TaskVectorSpace`` manifest leaf paths (each adapter leaf is
        ``<pattern>/{a,b,alpha}``).  This is the declarative contract
        the testbed verifies against the actual ``lora_init`` tree —
        see :func:`check_lora_targets`."""
        return lora_targets_for(self)

    def check_lora_targets(self, leaf_paths) -> None:
        """Verify a manifest's leaf paths against the family's
        targeting rules: every declared target must appear, and no
        adapter may live outside the declared targets.  Raises
        ``ValueError`` naming the offending target/path."""
        check_lora_targets(self.lora_targets(), leaf_paths,
                           context=f"{self.name} ({self.family})")


# Per-family adapter placements (the reduced zoo variants).  Attention
# q/o projections and the FFN down-projection are the shared baseline;
# MLA swaps wq for the wq_a low-rank factor, MoE adapts only the shared
# (always-on) expert, SSM/hybrid adapt the recurrent in/out projections.
_FAMILY_LORA_TARGETS: Dict[str, Tuple[str, ...]] = {
    "dense":  ("mixer/wq", "mixer/wo", "ffn/down"),
    "vlm":    ("mixer/wq", "mixer/wo", "ffn/down"),
    "ssm":    ("mlstm/up", "mlstm/down", "slstm/wx", "slstm/ffn_down"),
    "hybrid": ("mixer/attn/wq", "mixer/attn/wo",
               "mixer/mamba/in_proj", "mixer/mamba/out_proj", "ffn/down"),
    "audio":  ("encoder/attn/wq", "encoder/attn/wo", "encoder/mlp/down",
               "decoder/self_attn/wq", "decoder/self_attn/wo",
               "decoder/cross_attn/wq", "decoder/cross_attn/wo",
               "decoder/mlp/down"),
    # vit is a bespoke ViTConfig (not ArchConfig) but shares the rule table
    "vit":    ("attn/wq", "attn/wo", "mlp/down"),
}


def lora_targets_for(cfg) -> Tuple[str, ...]:
    """Family targeting rules for an :class:`ArchConfig` (or anything
    with ``family`` and the moe/mla fields)."""
    family = cfg.family
    if family == "moe":
        targets = ["mixer/wq_a" if getattr(cfg, "use_mla", False)
                   else "mixer/wq", "mixer/wo"]
        if getattr(cfg, "n_shared_experts", 0) > 0:
            targets.append("ffn/shared/down")
        return tuple(targets)
    return _FAMILY_LORA_TARGETS[family]


def check_lora_targets(targets: Tuple[str, ...], leaf_paths,
                       context: str = "") -> None:
    """Every target pattern must match ≥1 adapter leaf and every leaf
    must belong to a declared target (leaves are ``.../{a,b,alpha}``)."""
    where = f" [{context}]" if context else ""
    modules = set()
    for path in leaf_paths:
        mod = path.rsplit("/", 1)[0]
        if not any(mod == t or mod.endswith("/" + t) for t in targets):
            raise ValueError(
                f"LoRA adapter at {path!r} is outside the declared "
                f"targets {targets}{where}")
        modules.add(mod)
    for t in targets:
        if not any(m == t or m.endswith("/" + t) for m in modules):
            raise ValueError(
                f"declared LoRA target {t!r} has no adapter in the "
                f"manifest (modules: {sorted(modules)}){where}")


def load_arch(name: str) -> ArchConfig:
    mod = importlib.import_module(
        f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


ARCH_IDS = [
    "xlstm-1.3b",
    "qwen2.5-3b",
    "whisper-large-v3",
    "hymba-1.5b",
    "qwen2-0.5b",
    "deepseek-v2-236b",
    "qwen2.5-32b",
    "qwen2-vl-7b",
    "granite-moe-3b-a800m",
    "codeqwen1.5-7b",
]


# Reduced model zoo for federated rounds: one representative arch per
# family key.  ``fed.testbed.make_zoo_backbones`` builds an
# ``ArchBackbone`` per entry (vit_b32 is a bespoke ViTConfig and is
# special-cased there); a mixed round draws clients across families.
ZOO_FAMILIES: Dict[str, str] = {
    "lm": "qwen2-0.5b",             # dense decoder LM
    "encdec": "whisper-large-v3",   # audio encoder-decoder
    "vit": "vit_b32",               # vision transformer
    "ssm": "xlstm-1.3b",            # recurrent xLSTM stack
    "moe": "granite-moe-3b-a800m",  # sparse mixture-of-experts
}


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeSpec, *, concrete: bool = False,
                batch_override: Optional[int] = None,
                seq_override: Optional[int] = None) -> Dict[str, Any]:
    """Model inputs for a given shape. ``concrete=True`` returns real
    arrays (for smoke tests); default returns ShapeDtypeStructs."""
    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    f32, i32 = cfg.dtype, jnp.int32

    def mk(shp, dt):
        if concrete:
            if dt == i32:
                return jnp.zeros(shp, dt)
            return jnp.ones(shp, dt) * 0.01
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "decode":
        batch = {"tokens": mk((b, 1), i32)}
    elif cfg.family == "audio":
        batch = {
            "audio_embeds": mk((b, cfg.enc_frames, cfg.d_model), f32),
            "tokens": mk((b, s), i32),
            "labels": mk((b, s), i32),
        }
    elif cfg.family == "vlm":
        n_img = min(cfg.vision_tokens, max(s // 4, 1))
        n_txt = s - n_img
        pos = mk((b, s, 3), i32)
        batch = {
            "tokens": mk((b, n_txt), i32),
            "labels": mk((b, n_txt), i32),
            "extra_embeds": mk((b, n_img, cfg.d_model), f32),
            "positions": pos,
        }
    else:
        batch = {"tokens": mk((b, s), i32), "labels": mk((b, s), i32)}

    if shape.kind == "prefill":
        batch.pop("labels", None)
    return batch
