"""deepseek-v2-236b — MLA + MoE [arXiv:2405.04434].

60 layers, d_model=5120, 128 heads with Multi-head Latent Attention
(kv_lora_rank=512, q_lora_rank=1536, qk 128 nope + 64 rope, v 128);
MoE: 2 shared + 160 routed experts (d_ff=1536 each), top-6 routing.
Decode uses the absorbed latent cache (512+64 per token — the MLA
cache saving that motivates the arch).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=1536,
    vocab=102400,
    source="arXiv:2405.04434",
    n_experts=160,
    top_k=6,
    n_shared_experts=2,
    shared_d_ff=1536,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    rope_base=10_000.0,
)
