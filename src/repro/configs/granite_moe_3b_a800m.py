"""granite-moe-3b-a800m — 40 routed experts, top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family].

32 layers, d_model=1536, 24 heads (GQA kv=8), per-expert d_ff=512.
40 experts do not divide the 16-way model axis → token-parallel MoE
fallback (DESIGN.md §5): tokens split over ``model`` along sequence,
experts replicated, all-gather restores the sequence.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_experts=40,
    top_k=8,
    rope_base=10_000.0,
    tie_embeddings=True,
)
