"""hymba-1.5b — parallel attention + mamba heads [arXiv:2411.13676].

32 hybrid layers, d_model=1600, 25 attention heads (GQA kv=5) in
parallel with a Mamba branch (ssm_state=16); SWA (window 2048) on the
attention branch as in the paper; SwiGLU d_ff=5504. Sub-quadratic path
→ runs long_500k.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    source="arXiv:2411.13676",
    ssm_state=16,
    hybrid_window=2048,
    rope_base=10_000.0,
)
