"""qwen2-0.5b — dense GQA with QKV bias, tied embeddings [arXiv:2407.10671]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151936,
    source="arXiv:2407.10671",
    qkv_bias=True,
    rope_base=1_000_000.0,
    tie_embeddings=True,
)
