"""qwen2-vl-7b — M-RoPE, dynamic resolution (vision frontend STUB)
[arXiv:2409.12191].

28 dense layers, d_model=3584, 28 heads (GQA kv=4), d_ff=18944.
M-RoPE splits the 64 rotary frequency slots into (16, 24, 24) for
temporal/height/width coordinates.  input_specs supplies precomputed
patch embeddings (ViT encoder + projector stubbed per the brief);
the language model and the M-RoPE position handling are real.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    source="arXiv:2409.12191",
    qkv_bias=True,
    rope_base=1_000_000.0,
    mrope_sections=(16, 24, 24),
    vision_tokens=1024,
)
