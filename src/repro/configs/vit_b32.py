"""ViT-B/32 — the paper's own model [Dosovitskiy et al., 2021].

Used (with LoRA rank 16, as in the paper) by the federated benchmarks.
224x224 @ 32px patches → 49 patches of dim 3072. The paper-scale
config is exercised by the dry-run; the fed accuracy benchmarks use
``reduced_vit()`` on synthetic tasks (see DESIGN.md §3).
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ViTConfig:
    patch_dim: int = 3072        # 32*32*3
    n_patches: int = 49
    d_model: int = 768
    n_layers: int = 12
    n_heads: int = 12
    d_ff: int = 3072
    lora_rank: int = 16
    family: str = "vit"          # LoRA targeting rules key (configs.base)


CONFIG = ViTConfig()


def reduced_vit() -> ViTConfig:
    return ViTConfig(patch_dim=32, n_patches=8, d_model=64, n_layers=2,
                     n_heads=4, d_ff=128, lora_rank=4)


def build(cfg: ViTConfig = CONFIG, dtype=None):
    import jax.numpy as jnp
    from repro.models.vit import ViT
    return ViT(patch_dim=cfg.patch_dim, n_patches=cfg.n_patches,
               d_model=cfg.d_model, n_layers=cfg.n_layers, n_heads=cfg.n_heads,
               d_ff=cfg.d_ff, dtype=dtype or jnp.float32)
