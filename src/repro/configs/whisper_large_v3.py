"""whisper-large-v3 — encoder-decoder, conv frontend STUB [arXiv:2212.04356].

32 encoder + 32 decoder layers, d_model=1280, 20 MHA heads, GELU MLP
d_ff=5120, vocab 51866.  input_specs supplies precomputed 1500-frame
embeddings (the mel+conv frontend is the brief's allowed stub).
long_500k is SKIPPED for this arch (DESIGN.md §4): pure full-attention
enc-dec and a 500k-token decoder context has no audio interpretation.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    source="arXiv:2212.04356",
    enc_frames=1500,
    sliding_window_long=None,  # long_500k skipped (see DESIGN.md)
)
