"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517].

48 layers at d_model=2048 as 24 alternating (mLSTM, sLSTM) pairs,
4 heads, vocab 50304, no FFN outside the blocks (d_ff=0: the mLSTM
block carries a proj_factor-2 up-projection, the sLSTM block a GeGLU
FFN, per the xLSTM block designs).  Sub-quadratic → runs long_500k
natively on O(1) recurrent state.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    source="arXiv:2405.04517",
    tie_embeddings=True,
    sliding_window_long=None,  # attention-free; long_500k runs natively
)
