"""MaTU core: the paper's contribution as composable JAX functions.

Client math:  unify / modulators / modulate      (repro.core.unify)
Server math:  Eq. 3-6 + matu_round               (repro.core.aggregation)
Orchestration: MaTUClient / MaTUServer           (repro.core.client/.server)
Baseline merges: FedAvg / TIES / MaT-FL grouping (repro.core.baselines)
"""

from repro.core.aggregation import (agreement_mask, cross_task_aggregate,
                                    matu_round, sign_similarity,
                                    task_aggregate, topk_similar,
                                    transfer_weights)
from repro.core.client import ClientDownlink, ClientUpload, MaTUClient
from repro.core.engine import (EngineConfig, EngineOutput, PackedRound,
                               RoundEngine, batched_client_unify,
                               pack_from_slots, pack_uploads)
from repro.core.server import MaTUServer, MaTUServerConfig
from repro.core.unify import (modulate, modulators, task_mask, task_scaler,
                              unify, unify_masked, unify_with_modulators,
                              unify_with_modulators_masked)

__all__ = [
    "agreement_mask", "cross_task_aggregate", "matu_round",
    "sign_similarity", "task_aggregate", "topk_similar",
    "transfer_weights",
    "ClientDownlink", "ClientUpload", "MaTUClient",
    "EngineConfig", "EngineOutput", "PackedRound", "RoundEngine",
    "batched_client_unify", "pack_from_slots", "pack_uploads",
    "MaTUServer", "MaTUServerConfig",
    "modulate", "modulators", "task_mask", "task_scaler",
    "unify", "unify_masked", "unify_with_modulators",
    "unify_with_modulators_masked",
]
