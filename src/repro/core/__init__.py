"""MaTU core: the paper's contribution as composable JAX functions.

Client math:  unify / modulators / modulate      (repro.core.unify)
Server math:  Eq. 3-6 + matu_round               (repro.core.aggregation)
Orchestration: MaTUClient / MaTUServer           (repro.core.client/.server)
Baseline merges: FedAvg / TIES / MaT-FL grouping (repro.core.baselines)
"""

from repro.core.aggregation import (agreement_mask, cross_task_aggregate,
                                    matu_round, sign_similarity,
                                    task_aggregate, topk_similar)
from repro.core.client import ClientDownlink, ClientUpload, MaTUClient
from repro.core.server import MaTUServer, MaTUServerConfig
from repro.core.unify import (modulate, modulators, task_mask, task_scaler,
                              unify, unify_with_modulators)

__all__ = [
    "agreement_mask", "cross_task_aggregate", "matu_round",
    "sign_similarity", "task_aggregate", "topk_similar",
    "ClientDownlink", "ClientUpload", "MaTUClient",
    "MaTUServer", "MaTUServerConfig",
    "modulate", "modulators", "task_mask", "task_scaler",
    "unify", "unify_with_modulators",
]
