"""MaTU server-side aggregation (paper §3.2, Eq. 3–6).

The server is *stateless*: each round it receives, per client n,
  • the unified task vector τ_n (d,),
  • per held task t: a binary mask m_n^t (d,) and a scalar λ_n^t,
  • metadata: the task→client allocation A and dataset sizes |D_n^t|,
and returns, per task, the new aggregated task vector τ^{t,r+1}; the
per-client unified vectors + modulators for the next round are then
re-derived with :func:`repro.core.unify.unify_with_modulators`.

Interpretation note (documented deviation-free reading of Eq. 4): the
server does not possess the raw τ_n^t — clients only upload (τ_n, m_n^t,
λ_n^t).  The reconstruction the paper defines in §3.2 is
τ̇_n^t = λ_n^t · m_n^t ⊙ τ_n, and Eq. 4's ``λ_n^t · m̂^t ⊙ τ_n^t`` is read
as applying λ once to the masked unified vector:
τ̂^t = Σ_n γ_n^t · m̂^t ⊙ (λ_n^t · m_n^t ⊙ τ_n).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

RHO_DEFAULT = 0.4     # Eq. 3 threshold, after Tenison et al. 2023
EPS_DEFAULT = 0.5     # Eq. 6 similarity filter
KAPPA_DEFAULT = 3     # Eq. 6 top-κ


def agreement_mask(masks: jax.Array, unified: jax.Array,
                   member: jax.Array, rho: float = RHO_DEFAULT) -> jax.Array:
    """Eq. 3 — averaged task mask m̂^t for ONE task.

    masks: (N, d) binary masks m_n^t for all clients (zeros for
           non-members); unified: (N, d) unified vectors τ_n;
    member: (N,) bool — A(n, t).
    Returns m̂^t (d,) float: 1 where the agreement score α ≥ ρ, else α.
    """
    w = member.astype(jnp.float32)
    n_t = jnp.maximum(jnp.sum(w), 1.0)
    signs = jnp.sign(jnp.where(masks, unified, 0.0))  # sgn(m_n^t ⊙ τ_n)
    alpha = jnp.abs(jnp.einsum("n,nd->d", w, signs)) / n_t
    return jnp.where(alpha >= rho, 1.0, alpha)


def reconstruct(unified: jax.Array, masks: jax.Array, lams: jax.Array) -> jax.Array:
    """τ̇_n^t = λ_n^t · m_n^t ⊙ τ_n for stacked clients: (N,d)."""
    return lams[:, None] * jnp.where(masks, unified, 0.0)


def task_aggregate(unified: jax.Array, masks: jax.Array, lams: jax.Array,
                   member: jax.Array, data_sizes: jax.Array,
                   rho: float = RHO_DEFAULT):
    """Eq. 3 + Eq. 4 for ONE task.

    unified (N,d); masks (N,d) bool; lams (N,); member (N,) bool;
    data_sizes (N,) float (|D_n^t|; zero for non-members).
    Returns (τ̂^t (d,), m̂^t (d,)).
    """
    m_hat = agreement_mask(masks, unified, member, rho)
    gamma = data_sizes * member.astype(data_sizes.dtype)
    gamma = gamma / jnp.maximum(jnp.sum(gamma), 1e-12)
    recon = reconstruct(unified, masks, lams)          # (N, d)
    tau_hat = jnp.einsum("n,nd->d", gamma, recon) * m_hat
    return tau_hat, m_hat


def sign_similarity(tau_hats: jax.Array) -> jax.Array:
    """Eq. 5 — sign-conflict task similarity matrix S (T, T) ∈ [0, 1].

    S(t,t') = ½ (mean_i sgn(τ̂^t)_i · sgn(τ̂^t')_i + 1).
    Recast as a matmul of sign vectors (the MXU form the Pallas kernel
    implements): S = (sgn(T) sgn(T)^T) / d.
    """
    d = tau_hats.shape[-1]
    signs = jnp.sign(tau_hats)
    return 0.5 * (signs @ signs.T / d + 1.0)


def topk_similar(sim: jax.Array, eps: float = EPS_DEFAULT,
                 kappa: int = KAPPA_DEFAULT) -> jax.Array:
    """Z^t as a weight matrix: (T, T) with S(t,t') kept for the top-κ
    t' ≠ t having S > ε, zero elsewhere."""
    t = sim.shape[0]
    offdiag = sim * (1.0 - jnp.eye(t, dtype=sim.dtype))
    eligible = jnp.where(offdiag > eps, offdiag, 0.0)
    k = min(kappa, t - 1) if t > 1 else 0
    if k == 0:
        return jnp.zeros_like(sim)
    vals, _ = jax.lax.top_k(eligible, k)
    thresh = vals[:, -1:]                      # kth largest per row
    keep = (eligible >= thresh) & (eligible > 0)
    return jnp.where(keep, eligible, 0.0)


def transfer_weights(sim: jax.Array, held: jax.Array, *,
                     eps: float = EPS_DEFAULT, kappa: int = KAPPA_DEFAULT,
                     cross_task: bool = True,
                     uniform_cross: bool = False) -> jax.Array:
    """Eq. 6 neighbourhood weights from the held-masked similarity —
    the one definition of the cross-task/uniform/off ablation switch
    (mirrored for the kernel layer by
    ``repro.kernels.ref.cross_weights_ref``)."""
    heldf = held.astype(sim.dtype)
    if not cross_task:
        return jnp.zeros_like(sim)
    if uniform_cross:
        t = sim.shape[0]
        w = (1.0 - jnp.eye(t, dtype=sim.dtype)) * heldf[None, :] * heldf[:, None]
        return w / jnp.maximum(jnp.sum(w, 1, keepdims=True), 1.0)
    return topk_similar(sim, eps, kappa)


def cross_task_aggregate(tau_hats: jax.Array, m_hats: jax.Array,
                         sim_weights: jax.Array) -> jax.Array:
    """Eq. 6 — τ̃^t = Σ_{t'∈Z^t} S(t,t') · m̂^t ⊙ τ̂^{t'} for all tasks,
    normalised over Z^t (Σ S as the partition) so ‖τ̃‖ ≈ ‖τ̂‖.

    Implementation note (documented deviation): Eq. 6 verbatim sums
    κ terms with weights S ≈ 1, and Eq. 7 adds that onto τ̂ — iterated
    over rounds this grows task-vector norms geometrically (~(1+κ·S̄)ᴿ;
    measured 4×/round on the synthetic testbed).  The paper's §3.2
    overview states the server "by averaging these two … creates the
    updated task vectors", which is only norm-stable if τ̃ itself is an
    average over Z^t.  We therefore normalise by Σ_{t'} S(t,t').

    tau_hats (T,d); m_hats (T,d); sim_weights (T,T) from topk_similar.
    """
    total = jnp.sum(sim_weights, axis=1, keepdims=True)
    norm_w = sim_weights / jnp.maximum(total, 1e-12)
    mixed = jnp.einsum("ts,sd->td", norm_w, tau_hats)
    return m_hats * mixed


def combine_round(tau_hats: jax.Array, tau_tildes: jax.Array,
                  sim_weights: jax.Array) -> jax.Array:
    """Eq. 7 with the overview's "averaging": τ = (τ̂ + τ̃)/2 for tasks
    that have cross-task donors, τ = τ̂ otherwise."""
    has = (jnp.sum(sim_weights, axis=1, keepdims=True) > 0).astype(tau_hats.dtype)
    return (tau_hats + tau_tildes * has) / (1.0 + has)


class RoundOutput(NamedTuple):
    task_vectors: jax.Array   # (T, d) τ^{t,r+1}
    tau_hats: jax.Array       # (T, d) same-task component
    tau_tildes: jax.Array     # (T, d) cross-task component
    m_hats: jax.Array         # (T, d)
    similarity: jax.Array     # (T, T)


def matu_round(unified: jax.Array, masks: jax.Array, lams: jax.Array,
               allocation: jax.Array, data_sizes: jax.Array, *,
               rho: float = RHO_DEFAULT, eps: float = EPS_DEFAULT,
               kappa: int = KAPPA_DEFAULT,
               cross_task: bool = True,
               uniform_cross: bool = False) -> RoundOutput:
    """One stateless MaTU server round over ALL tasks (vmapped Eq. 3–6).

    unified (N,d); masks (N,T,d) bool (m_n^t; False where A(n,t)=0);
    lams (N,T); allocation (N,T) bool; data_sizes (N,T) float.

    Tasks with no member this round (all-False allocation column) are
    masked out of the similarity matrix and the cross-task weights, so
    transfer never mixes in their zero task vectors under partial
    participation.  This is the reference semantics of
    :class:`repro.core.engine.RoundEngine`.

    ``cross_task=False`` and ``uniform_cross=True`` give the two
    ablation variants of Fig. 6b.
    """
    def per_task(mask_t, lam_t, member_t, sizes_t):
        return task_aggregate(unified, mask_t, lam_t, member_t, sizes_t, rho)

    tau_hats, m_hats = jax.vmap(per_task, in_axes=(1, 1, 1, 1))(
        masks, lams, allocation, data_sizes)

    held = jnp.any(allocation, axis=0)
    heldf = held.astype(tau_hats.dtype)
    sim = sign_similarity(tau_hats) * heldf[None, :] * heldf[:, None]
    weights = transfer_weights(sim, held, eps=eps, kappa=kappa,
                               cross_task=cross_task,
                               uniform_cross=uniform_cross)
    tau_tildes = cross_task_aggregate(tau_hats, m_hats, weights)

    return RoundOutput(combine_round(tau_hats, tau_tildes, weights),
                       tau_hats, tau_tildes, m_hats, sim)


def matu_round_packed(unified: jax.Array, mask_words: jax.Array,
                      lams: jax.Array, allocation: jax.Array,
                      data_sizes: jax.Array, d: int, **kw) -> RoundOutput:
    """Wire-format adapter for :func:`matu_round`: accepts the transport
    tensors the engine natively holds — bf16 ``unified`` (N, d) and
    bit-packed ``mask_words`` (N, T, ceil(d/32)) uint32 — unpacks them
    through the single ``ops.unpack_masks`` contract, and runs the dense
    fp32 reference.  This is the oracle the packed engine's parity tests
    compare against: same inputs, reference semantics, dense compute.
    """
    from repro.kernels import ops
    masks = ops.unpack_masks(mask_words, d)
    return matu_round(unified.astype(jnp.float32), masks, lams,
                      allocation, data_sizes, **kw)
