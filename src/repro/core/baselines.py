"""Aggregation math for the paper's baselines (Tables 1–2).

Orchestration (client sampling, local training, personalization
bookkeeping) lives in ``repro.fed.strategies``; this module is the pure
merge math:

* FedAvg / FedProx server merge (identical server op; FedProx differs
  client-side via the proximal term — see ``repro.fed.local``).
* TIES-merging (Yadav et al. 2023): trim → elect sign → disjoint mean.
* MaT-FL dynamic grouping (Cai et al. 2023): cosine-similarity greedy
  clustering; aggregation happens within groups.
* NTK-FedAvg (Muhamed et al. 2024): FedAvg over task adapters of a
  *linearised* model — the linearisation itself is in
  ``repro.fed.local.linearised_loss`` (jvp-based); the server merge is
  plain weighted averaging, as in the paper.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def weighted_average(vectors: jax.Array, weights: jax.Array) -> jax.Array:
    """FedAvg merge: vectors (M, d), weights (M,) ∝ |D|."""
    w = weights / jnp.maximum(jnp.sum(weights), 1e-12)
    return jnp.einsum("m,md->d", w, vectors)


def ties_merge(task_vectors: jax.Array, *, keep_frac: float = 0.2) -> jax.Array:
    """TIES-merging: per-vector magnitude trim to ``keep_frac``, sign
    election by summed magnitude, disjoint mean over aligned entries."""
    k, d = task_vectors.shape
    keep = max(1, int(d * keep_frac))
    # trim: zero all but the top-|keep| magnitude entries of each vector
    mags = jnp.abs(task_vectors)
    thresh = jax.lax.top_k(mags, keep)[0][:, -1:]
    trimmed = jnp.where(mags >= thresh, task_vectors, 0.0)
    # elect: sign of summed magnitudes
    sigma = jnp.sign(jnp.sum(trimmed, axis=0))
    aligned = (trimmed * sigma[None, :]) > 0
    count = jnp.maximum(jnp.sum(aligned, axis=0), 1)
    return jnp.sum(jnp.where(aligned, trimmed, 0.0), axis=0) / count


def cosine_similarity_matrix(vectors: jax.Array, eps: float = 1e-12) -> jax.Array:
    norms = jnp.linalg.norm(vectors, axis=-1, keepdims=True)
    unit = vectors / jnp.maximum(norms, eps)
    return unit @ unit.T


def greedy_group(sim: np.ndarray, threshold: float = 0.0) -> List[List[int]]:
    """MaT-FL grouping: greedily merge clients whose mean cosine
    similarity to an existing group exceeds ``threshold``."""
    n = sim.shape[0]
    groups: List[List[int]] = []
    for i in range(n):
        best, best_s = None, threshold
        for gi, g in enumerate(groups):
            s = float(np.mean([sim[i, j] for j in g]))
            if s > best_s:
                best, best_s = gi, s
        if best is None:
            groups.append([i])
        else:
            groups[best].append(i)
    return groups
