"""MaTU client-side logic (paper §3.2 "Local Training with many-tasks").

A client holds k_n tasks.  Each round it:

1. materialises per-task weights  θ_t = θ_p + λ^t · m^t ⊙ τ_n   from the
   downlinked unified vector + modulators,
2. fine-tunes each task locally (the trainer is injected — the core
   stays model-agnostic over flat vectors),
3. re-unifies the resulting task vectors and derives fresh modulators,
4. uploads ONE unified vector + (mask, scalar) per task.

Communication accounting (bits/round, as in Tables 1–2):
  uplink  = 32·d  +  k·(d + 32)      [fp32 vector + k binary masks + k scalars]
vs an adapter-per-task scheme's 32·k·d.

Mask transport layouts — ``masks`` on an upload/downlink is one of:

* dense bool ``(k, d)`` — the paper's accounting (32d + k(d+32));
* bit-packed uint32 words ``(k, ceil(d/32))`` — the raw packed wire
  (``repro.kernels.bitpack``), measured off buffer sizes;
* an entropy-coded uint8 byte stream (1-D) — the Golomb-Rice wire
  (``repro.fed.compression``), k self-delimiting row records; bits are
  measured off the actual stream length.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.unify import modulate, unify_with_modulators


def paper_link_bits(d: int, k: int, float_bits: int = 32) -> int:
    """The paper's per-client link accounting: one fp32 vector + per
    task a dense-bit mask + a scalar — 32d + k(d + 32).  THE single
    definition of the legacy/bool-layout accounting (mirrors
    ``repro.kernels.bitpack.wire_bits`` for the packed wire)."""
    return float_bits * d + k * (d + float_bits)


def _link_bits(unified: jax.Array, masks: jax.Array, k: int,
               float_bits: int) -> int:
    """Shared up/downlink accounting: measured coded stream bits for an
    entropy-coded uint8 wire, measured packed wire bits when the masks
    travel as uint32 words, the paper formula otherwise."""
    d = int(unified.shape[0])
    if masks.dtype == jnp.uint8:
        # vector buffer + the actual coded byte stream + k scalers
        return (8 * unified.dtype.itemsize * d + 8 * int(masks.size)
                + k * float_bits)
    if masks.dtype == jnp.uint32:
        from repro.kernels.bitpack import wire_bits
        return wire_bits(d, k, vec_bytes_per_elem=unified.dtype.itemsize,
                         float_bits=float_bits)
    return paper_link_bits(d, k, float_bits)


def _masks_dense(unified: jax.Array, masks: jax.Array,
                 k: Optional[int] = None) -> jax.Array:
    """Dense bool (k, d) view of modulator masks, whichever layout they
    travel in (the single ``ops.unpack_masks`` contract; coded streams
    decode host-side first — ``k`` is required for them)."""
    d = int(unified.shape[0])
    if masks.dtype == jnp.uint8:
        from repro.fed.compression import decode_mask_rows
        masks = jnp.asarray(decode_mask_rows(np.asarray(masks), d, k))
    if masks.dtype != jnp.uint32:
        return masks
    from repro.kernels import ops
    return ops.unpack_masks(masks, d)


@dataclass
class ClientUpload:
    client_id: int
    task_ids: List[int]
    unified: jax.Array          # (d,) fp32 | bf16 (wire)
    masks: jax.Array            # (k, d) bool | (k, ceil(d/32)) uint32 | uint8 stream
    lams: jax.Array             # (k,)
    data_sizes: List[int]
    # TaskVectorSpace manifest fingerprint of the layout the vector was
    # flattened through (None for legacy homogeneous rounds); lets the
    # server verify layout agreement before aggregating
    fingerprint: Optional[str] = None
    _dense: Optional[jax.Array] = field(default=None, repr=False,
                                        compare=False)

    @property
    def packed(self) -> bool:
        return self.masks.dtype == jnp.uint32

    @property
    def coded(self) -> bool:
        """True when ``masks`` is the entropy-coded uint8 byte stream."""
        return self.masks.dtype == jnp.uint8

    def masks_dense(self) -> jax.Array:
        if self._dense is None:
            self._dense = _masks_dense(self.unified, self.masks,
                                       len(self.task_ids))
        return self._dense

    def uplink_bits(self, float_bits: int = 32) -> int:
        """Uplink size in bits.  For wire-format uploads this is
        *measured* off the actual buffers (bf16 vector + packed words,
        or the entropy-coded byte stream); for legacy bool uploads it
        is the paper's 32d + k(d+32)."""
        return _link_bits(self.unified, self.masks, len(self.task_ids),
                          float_bits)


@dataclass
class ClientDownlink:
    unified: jax.Array          # (d,) fp32 | bf16 (wire)
    masks: jax.Array            # (k, d) bool | (k, ceil(d/32)) uint32 | uint8 stream
    lams: jax.Array             # (k,)
    # TaskVectorSpace manifest fingerprint of the layout the vector was
    # flattened through (None for legacy rounds) — the serving
    # ModulatorStore refuses to ingest a downlink whose fingerprint
    # does not match its own manifest (same handshake as uploads)
    fingerprint: Optional[str] = None
    _words: Optional[jax.Array] = field(default=None, repr=False,
                                        compare=False)

    @property
    def packed(self) -> bool:
        return self.masks.dtype == jnp.uint32

    @property
    def coded(self) -> bool:
        """True when ``masks`` is the entropy-coded uint8 byte stream."""
        return self.masks.dtype == jnp.uint8

    def _decoded_words(self) -> jax.Array:
        """Coded stream → (k, ceil(d/32)) packed words, decoded once
        and cached — the 32x-smaller layout every consumer accepts."""
        if self._words is None:
            from repro.fed.compression import decode_mask_rows
            self._words = jnp.asarray(decode_mask_rows(
                np.asarray(self.masks), int(self.unified.shape[0]),
                int(self.lams.shape[0])))
        return self._words

    def masks_dense(self) -> jax.Array:
        masks = self._decoded_words() if self.coded else self.masks
        return _masks_dense(self.unified, masks)

    def mask_row(self, i: int) -> jax.Array:
        """Row ``i`` of the modulator masks in a ``modulate``-ready
        layout: the packed word row / bool row directly; the coded wire
        decodes to packed words once (cached), never to dense bools."""
        return (self._decoded_words()[i] if self.coded
                else self.masks[i])

    def downlink_bits(self, float_bits: int = 32) -> int:
        return _link_bits(self.unified, self.masks,
                          int(self.lams.shape[0]), float_bits)


class MaTUClient:
    """One federated client; ``trainer(task_id, tv_init, rng) -> tv_new``
    runs the local fine-tune in flat task-vector space.

    ``space`` (optional): the client backbone's
    :class:`~repro.common.tree.TaskVectorSpace` layout manifest.  When
    given, ``d`` may be omitted (it defaults to ``space.d``) and every
    upload carries ``space.fingerprint`` so the server can verify
    layout agreement before aggregating; :meth:`verify_layout` is the
    client-side half of the same handshake (check the server's
    advertised fingerprint before training against its downlink)."""

    def __init__(self, client_id: int, task_ids: List[int],
                 data_sizes: List[int], d: Optional[int] = None,
                 trainer: Callable[[int, jax.Array, jax.Array], jax.Array] = None,
                 code_masks: bool = False, space=None):
        if d is None:
            if space is None:
                raise ValueError("MaTUClient needs d or a TaskVectorSpace")
            d = space.d
        self.client_id = client_id
        self.task_ids = list(task_ids)
        self.data_sizes = list(data_sizes)
        self.d = d
        self.trainer = trainer
        self.code_masks = code_masks
        self.space = space
        self.state: Optional[ClientDownlink] = None

    @property
    def fingerprint(self) -> Optional[str]:
        return self.space.fingerprint if self.space is not None else None

    def verify_layout(self, server_fingerprint: str) -> None:
        """Abort-before-train check against the server's advertised
        layout fingerprint (raises
        :class:`~repro.common.tree.TaskVectorLayoutError`)."""
        if self.space is not None:
            self.space.require_compatible(server_fingerprint,
                                          context=f"client {self.client_id}")

    def task_vector_init(self, task_index: int) -> jax.Array:
        """Starting τ for a local task from the current downlink."""
        if self.state is None:
            return jnp.zeros((self.d,), jnp.float32)
        return modulate(self.state.unified,
                        self.state.mask_row(task_index),
                        self.state.lams[task_index])

    def run_round(self, rng: jax.Array) -> ClientUpload:
        tvs = []
        for i, t in enumerate(self.task_ids):
            rng, sub = jax.random.split(rng)
            tvs.append(self.trainer(t, self.task_vector_init(i), sub))
        stacked = jnp.stack(tvs)
        unified, masks, lams = unify_with_modulators(stacked)
        if self.code_masks:
            # wire boundary: entropy-code the fresh modulator masks and
            # ship the bf16 vector — the server decodes at pack time
            from repro.fed.compression import encode_mask_rows
            from repro.kernels.bitpack import pack_bits_np
            stream = encode_mask_rows(pack_bits_np(np.asarray(masks)),
                                      self.d)
            return ClientUpload(self.client_id, self.task_ids,
                                unified.astype(jnp.bfloat16),
                                jnp.asarray(stream), lams, self.data_sizes,
                                fingerprint=self.fingerprint)
        return ClientUpload(self.client_id, self.task_ids, unified,
                            masks, lams, self.data_sizes,
                            fingerprint=self.fingerprint)

    def receive(self, downlink: ClientDownlink) -> None:
        self.state = downlink
