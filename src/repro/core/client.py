"""MaTU client-side logic (paper §3.2 "Local Training with many-tasks").

A client holds k_n tasks.  Each round it:

1. materialises per-task weights  θ_t = θ_p + λ^t · m^t ⊙ τ_n   from the
   downlinked unified vector + modulators,
2. fine-tunes each task locally (the trainer is injected — the core
   stays model-agnostic over flat vectors),
3. re-unifies the resulting task vectors and derives fresh modulators,
4. uploads ONE unified vector + (mask, scalar) per task.

Communication accounting (bits/round, as in Tables 1–2):
  uplink  = 32·d  +  k·(d + 32)      [fp32 vector + k binary masks + k scalars]
vs an adapter-per-task scheme's 32·k·d.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.unify import modulate, unify_with_modulators


@dataclass
class ClientUpload:
    client_id: int
    task_ids: List[int]
    unified: jax.Array          # (d,)
    masks: jax.Array            # (k, d) bool
    lams: jax.Array             # (k,)
    data_sizes: List[int]

    def uplink_bits(self, float_bits: int = 32) -> int:
        d = int(self.unified.shape[0])
        k = len(self.task_ids)
        return float_bits * d + k * (d + float_bits)


@dataclass
class ClientDownlink:
    unified: jax.Array          # (d,)
    masks: jax.Array            # (k, d) bool
    lams: jax.Array             # (k,)

    def downlink_bits(self, float_bits: int = 32) -> int:
        d = int(self.unified.shape[0])
        k = int(self.masks.shape[0])
        return float_bits * d + k * (d + float_bits)


class MaTUClient:
    """One federated client; ``trainer(task_id, tv_init, rng) -> tv_new``
    runs the local fine-tune in flat task-vector space."""

    def __init__(self, client_id: int, task_ids: List[int],
                 data_sizes: List[int], d: int,
                 trainer: Callable[[int, jax.Array, jax.Array], jax.Array]):
        self.client_id = client_id
        self.task_ids = list(task_ids)
        self.data_sizes = list(data_sizes)
        self.d = d
        self.trainer = trainer
        self.state: Optional[ClientDownlink] = None

    def task_vector_init(self, task_index: int) -> jax.Array:
        """Starting τ for a local task from the current downlink."""
        if self.state is None:
            return jnp.zeros((self.d,), jnp.float32)
        return modulate(self.state.unified,
                        self.state.masks[task_index],
                        self.state.lams[task_index])

    def run_round(self, rng: jax.Array) -> ClientUpload:
        tvs = []
        for i, t in enumerate(self.task_ids):
            rng, sub = jax.random.split(rng)
            tvs.append(self.trainer(t, self.task_vector_init(i), sub))
        stacked = jnp.stack(tvs)
        unified, masks, lams = unify_with_modulators(stacked)
        return ClientUpload(self.client_id, self.task_ids, unified,
                            masks, lams, self.data_sizes)

    def receive(self, downlink: ClientDownlink) -> None:
        self.state = downlink
