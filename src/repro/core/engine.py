"""Batched, kernel-backed MaTU round engine (paper §3.2, Eq. 3–7).

One jit-compiled pipeline replaces the three divergent server paths the
repo used to carry (the Python-loop ``MaTUServer.round``, the dense
``matu_round`` reference, and the unused Pallas kernels):

  pack  →  Eq. 3+4 batched agreement/merge  →  Eq. 5 sign similarity
        →  Eq. 6+7 cross-task transfer      →  batched downlink
           re-unification (fused unify + mask + λ kernel)

All tensor math dispatches through
:func:`repro.kernels.ops.matu_round_slots_packed` (packed Pallas
kernels on TPU; the two-pass cache-blocked packed streaming round on
CPU/GPU); ``matu_round`` in :mod:`repro.core.aggregation` remains the
dense reference semantics the engine is tested against.

Padding contract
----------------
A round's ragged ``List[ClientUpload]`` is packed into fixed-shape
*slot* tensors so participation sampling keeps a static jit signature:

* client axis: padded to ``n_max`` (next power of two ≥ N by default);
  padding rows have all-invalid slots, so they drop out of every
  reduction.
* slot axis: each client's held tasks occupy the first k_n of
  ``k_max`` slots (next power of two ≥ max k_n); invalid slots carry
  zero masks/λ/sizes and the sentinel task id T.  Per-task reductions
  are segment-sums keyed by slot task id — the sentinel bucket (index
  T of T+1 segments) swallows all padding; downlink gathers clamp the
  sentinel and the slot-valid mask zeroes its output.
* task axis: always the full registry size T.  Tasks with no member
  this round produce τ̂ = 0, m̂ = 0 (``matu_round`` semantics — the
  legacy server reported m̂ = 1 for unheld tasks, which is unobservable
  downstream) and are masked out of the similarity matrix so
  cross-task transfer never mixes in zero vectors.

Wire format
-----------
The slot tensors ARE the uplink/downlink wire format — what the engine
holds in memory is byte-identical to what a client transmits, so
communication accounting is measured off the buffers rather than
simulated:

* **masks** travel bit-packed: ``uint32`` words of shape
  ``(n_max, k_max, ceil(d/32))``, 32 mask bits per word, **LSB-first**
  (element j of a d-length mask is bit ``j % 32`` of word ``j // 32``;
  see ``repro.kernels.bitpack`` for the single definition).  Tail bits
  of the last word — elements ``d .. 32*ceil(d/32)`` — are always
  zero; producers enforce it and popcount consumers rely on it.
* **unified / task vectors** travel bf16 (``jnp.bfloat16`` storage);
  all round *compute* is fp32 — kernels upcast one cache/VMEM tile at
  a time, and every sign-derived quantity (modulator mask bits, m̂,
  similarity) plus λ num/den is computed from fp32 values *before* the
  outgoing bf16 rounding.  Consequently packed↔bool parity is exact
  on identical (already bf16-quantised) inputs: masks, m̂, and
  similarity are bit-identical in every mode (per-coordinate
  decisions, independent of tile/chunk grouping), bf16 vector outputs
  are the bf16 rounding of the fp32 ones, and λs are bit-identical on
  the streaming ref round (same CHUNK_D accumulation grouping as the
  bool round).  On the Pallas paths the packed kernels tile d at 4096
  (128 uint32 lanes) vs the bool kernels' 2048, so the λ num/den
  partial sums group differently across tiles — λ agrees to fp32
  accumulation tolerance (~1e-6 relative) there, not bitwise.
* **m̂** is not part of the wire and is not materialised in fp32:
  the engine carries the Eq. 3 agreement numerator (an exact integer
  ≤ N_t) at one byte per coordinate and re-derives
  m̂ = 1[α ≥ ρ] ∨ α on demand (``EngineOutput.m_hats``).
* λ / sizes stay fp32 scalars (k per client, 32 bits each on the
  paper's accounting).

The bool/fp32 slot layout is retained behind ``pack_uploads(...,
packed=False)`` as the A/B baseline and parity oracle
(``benchmarks/bench_round_engine.py`` measures both).

The slot layout keeps the packed footprint and the round's work at
O(Σ k_n · d) — the same asymptotics as the legacy ragged loop — while
the dense (N, T, ·) tensors the Pallas kernels and ``matu_round``
consume are derived on demand (``PackedRound.dense_tensors`` /
scatter inside the kernel path).

The jit cache is keyed on (shape signature, dispatch mode, d); the
mode is resolved from the environment once per call (see
``ops.resolve_mode``) so ``REPRO_DISABLE_PALLAS`` /
``REPRO_PALLAS_INTERPRET`` A/B checks never collide in the cache.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import EPS_DEFAULT, KAPPA_DEFAULT, RHO_DEFAULT
from repro.core.client import ClientDownlink, ClientUpload
from repro.kernels import bitpack, ops


@dataclass(frozen=True)
class EngineConfig:
    n_tasks: int
    rho: float = RHO_DEFAULT
    eps: float = EPS_DEFAULT
    kappa: int = KAPPA_DEFAULT
    cross_task: bool = True
    uniform_cross: bool = False


@dataclass
class PackedRound:
    """Fixed-shape slot tensors for one round + host-side metadata.

    In the default wire layout ``unified`` is bf16 and ``slot_masks``
    holds bit-packed uint32 words (``packed`` is True); the legacy
    bool/fp32 layout (``pack_uploads(..., packed=False)``) is kept for
    A/B benchmarks and parity tests.
    """
    client_ids: List[int]            # actual clients, row order
    task_ids: List[List[int]]        # per client, slot order
    unified: jax.Array               # (n_max, d) bf16 (wire) | fp32 (bool A/B)
    slot_masks: jax.Array            # (n_max, k_max, ceil(d/32)) uint32 | (…, d) bool
    slot_lams: jax.Array             # (n_max, k_max) fp32
    slot_sizes: jax.Array            # (n_max, k_max) fp32
    slot_tasks: jax.Array            # (n_max, k_max) int32; T = invalid sentinel
    slot_valid: jax.Array            # (n_max, k_max) bool
    n_tasks: int
    d: int                           # unpacked feature count (static)

    @property
    def n_clients(self) -> int:
        return len(self.client_ids)

    @property
    def packed(self) -> bool:
        """True when the slot tensors are in the wire layout."""
        return self.slot_masks.dtype == jnp.uint32

    def wire_bits(self) -> int:
        """Measured uplink size of the real (non-padding) slots: the
        bits actually occupied by this round's wire buffers (bf16
        unified + packed mask words + fp32 λ per slot).  For the bool
        A/B layout this reports the paper's fp32+dense-bit accounting
        (32d + k(d+32)) — the scheme those buffers implement."""
        from repro.core.client import paper_link_bits
        total = 0
        for tasks in self.task_ids:
            k = len(tasks)
            if self.packed:
                total += bitpack.wire_bits(
                    self.d, k,
                    vec_bytes_per_elem=self.unified.dtype.itemsize)
            else:
                total += paper_link_bits(self.d, k)
        return total

    def dense_tensors(self):
        """Scatter to the dense per-task layout ``matu_round`` consumes:
        (masks (N, T, d) bool, lams (N, T), member (N, T), sizes (N, T)).
        Test/diagnostic helper — the hot path never materialises this
        on CPU.  Delegates to the single slot→dense contract in
        :func:`repro.kernels.ops.slots_to_dense` (packed masks go
        through the one sanctioned ``ops.unpack_masks`` route)."""
        masks = (ops.unpack_masks(self.slot_masks, self.d)
                 if self.packed else self.slot_masks)
        return ops.slots_to_dense(masks, self.slot_lams,
                                  self.slot_sizes, self.slot_valid,
                                  self.slot_tasks, self.n_tasks)


class EngineOutput(NamedTuple):
    """Round results.  Neither τ̃ nor m̂ is materialised on the hot
    path: τ̃ is (2·task_vectors − tau_hats) on rows with donors, and m̂
    is re-derived from the exact byte-wide agreement numerator via the
    ``m_hats`` property.  The packed path fills (alpha_num, n_held);
    the bool A/B path fills ``m_hats_dense`` instead."""
    task_vectors: jax.Array          # (T, d) τ^{t,r+1} fp32
    tau_hats: jax.Array              # (T, d) fp32
    similarity: jax.Array            # (T, T), held-masked
    down_unified: jax.Array          # (n_max, d) bf16 (wire) | fp32
    down_masks: jax.Array            # (n_max, k_max, ceil(d/32)) uint32 | (…, d) bool
    down_lams: jax.Array             # (n_max, k_max)
    alpha_num: Optional[jax.Array] = None    # (T, d) uint8 — |Σ sgn(m⊙τ)|
    n_held: Optional[jax.Array] = None       # (T,) fp32 member counts
    rho: float = RHO_DEFAULT
    m_hats_dense: Optional[jax.Array] = None  # (T, d) fp32 (bool path only)

    @property
    def m_hats(self) -> jax.Array:
        """Eq. 3 averaged task masks m̂ (T, d) fp32 — identical (bit for
        bit) to the value the round used internally: the same fp32
        division α = |Σ sgn| / max(N_t, 1) both passes performed."""
        if self.m_hats_dense is not None:
            return self.m_hats_dense
        alpha = (self.alpha_num.astype(jnp.float32)
                 / jnp.maximum(self.n_held, 1.0)[:, None])
        return jnp.where(alpha >= self.rho, 1.0, alpha)


def _round_up_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def pack_uploads(uploads: Sequence[ClientUpload], n_tasks: int, *,
                 n_max: Optional[int] = None,
                 k_max: Optional[int] = None,
                 packed: bool = True) -> PackedRound:
    """Pack a ragged round of uploads into the engine's slot layout.

    Pure data movement (numpy fills + ``np.packbits`` of O(Σ k_n · d)
    *bits* for the masks, one host→device transfer per tensor); all
    math stays inside the jitted round.  ``packed=False`` selects the
    legacy bool/fp32 layout (A/B baseline).  A client's bool masks are
    bit-packed and its unified vector rounded to bf16 here — this IS
    the uplink quantisation, applied once at the wire boundary.
    """
    if not uploads:
        raise ValueError("pack_uploads: empty round (no uploads) — "
                         "sample at least one client or skip the round")
    n = len(uploads)
    d = int(uploads[0].unified.shape[0])
    n_max = n_max or _round_up_pow2(n)
    k_max = k_max or _round_up_pow2(max(len(u.task_ids) for u in uploads))
    if n_max < n:
        raise ValueError(f"n_max={n_max} < round size {n}")

    # np.empty + zero only the padding: the valid region is fully
    # overwritten below, so a full np.zeros would write the big
    # mask/vector buffers twice for nothing
    # host-side bf16 fill for the wire layout (ml_dtypes ships with
    # jax): halves the host→device transfer and skips the device cast
    vec_dtype = np.float32
    if packed:
        import ml_dtypes
        vec_dtype = ml_dtypes.bfloat16
    unified = np.empty((n_max, d), vec_dtype)
    unified[n:] = 0.0
    if packed:
        dw = bitpack.packed_width(d)
        slot_masks = np.zeros((n_max, k_max, dw), np.uint32)
    else:
        slot_masks = np.empty((n_max, k_max, d), bool)
        slot_masks[n:] = False
    slot_lams = np.zeros((n_max, k_max), np.float32)
    slot_sizes = np.zeros((n_max, k_max), np.float32)
    slot_tasks = np.full((n_max, k_max), n_tasks, np.int32)
    slot_valid = np.zeros((n_max, k_max), bool)

    for i, up in enumerate(uploads):
        k = len(up.task_ids)
        unified[i] = np.asarray(up.unified)
        m = np.asarray(up.masks)
        if packed:
            # accept either bool masks (legacy clients — packed here at
            # the wire boundary) or already-packed words
            slot_masks[i, :k] = (m if m.dtype == np.uint32
                                 else bitpack.pack_bits_np(m))
        else:
            slot_masks[i, :k] = (bitpack.unpack_bits_np(m, d)
                                 if m.dtype == np.uint32 else m)
            slot_masks[i, k:] = False
        slot_lams[i, :k] = np.asarray(up.lams, np.float32)
        slot_sizes[i, :k] = np.asarray(up.data_sizes, np.float32)
        slot_tasks[i, :k] = up.task_ids
        slot_valid[i, :k] = True

    uni = jnp.asarray(unified)                    # bf16 wire dtype if packed
    return PackedRound([u.client_id for u in uploads],
                       [list(u.task_ids) for u in uploads],
                       uni, jnp.asarray(slot_masks),
                       jnp.asarray(slot_lams), jnp.asarray(slot_sizes),
                       jnp.asarray(slot_tasks), jnp.asarray(slot_valid),
                       n_tasks, d)


def pack_from_slots(client_ids: List[int], task_ids: List[List[int]],
                    unified: jax.Array, slot_masks: jax.Array,
                    slot_lams: jax.Array, slot_tasks: jax.Array,
                    slot_valid: jax.Array, slot_sizes: jax.Array,
                    n_tasks: int) -> PackedRound:
    """Build a PackedRound from already-batched slot tensors (the
    strategy's pre-packed upload path) — zero copies, the slot layout
    IS the engine's native layout.  ``slot_masks`` may be uint32 wire
    words (``batched_client_unify`` output) or legacy dense bool."""
    d = int(unified.shape[-1])
    return PackedRound(client_ids, task_ids, unified, slot_masks,
                       slot_lams.astype(jnp.float32),
                       slot_sizes.astype(jnp.float32),
                       slot_tasks.astype(jnp.int32), slot_valid,
                       n_tasks, d)


def _round_impl(unified, slot_masks, slot_lams, slot_sizes, slot_valid,
                slot_tasks, *, cfg: EngineConfig, mode: str, d: int):
    """The whole server step, traced once per (shapes, mode, d).  The
    mask dtype selects the wire-format (uint32) or bool A/B path."""
    kw = dict(rho=cfg.rho, eps=cfg.eps, kappa=cfg.kappa,
              cross_task=cfg.cross_task, uniform_cross=cfg.uniform_cross,
              mode=mode)
    if slot_masks.dtype == jnp.uint32:
        return ops.matu_round_slots_packed(
            unified, slot_masks, slot_lams, slot_sizes, slot_valid,
            slot_tasks, cfg.n_tasks, d, **kw)
    return ops.matu_round_slots(
        unified, slot_masks, slot_lams, slot_sizes, slot_valid, slot_tasks,
        cfg.n_tasks, **kw)


class RoundEngine:
    """Stateless per-round executor; owns only jit caches (one per
    (dispatch mode, d) — shapes are handled by jax.jit's own cache)."""

    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        self._impls: Dict[tuple, object] = {}

    def _impl(self, mode: str, d: int):
        fn = self._impls.get((mode, d))
        if fn is None:
            import repro.core.engine as _mod
            fn = jax.jit(functools.partial(_mod._round_impl, cfg=self.cfg,
                                           mode=mode, d=d))
            self._impls[(mode, d)] = fn
        return fn

    def run_packed(self, packed: PackedRound, *,
                   mode: Optional[str] = None) -> EngineOutput:
        mode = mode or ops.resolve_mode()
        out = self._impl(mode, packed.d)(
            packed.unified, packed.slot_masks, packed.slot_lams,
            packed.slot_sizes, packed.slot_valid, packed.slot_tasks)
        if packed.packed:
            (tv, tau, a_num, n_held, sim, du, dm, dl) = out
            return EngineOutput(tv, tau, sim, du, dm, dl,
                                alpha_num=a_num, n_held=n_held,
                                rho=self.cfg.rho)
        (tv, tau, m_hats, sim, du, dm, dl) = out
        return EngineOutput(tv, tau, sim, du, dm, dl,
                            rho=self.cfg.rho, m_hats_dense=m_hats)

    def downlinks(self, packed: PackedRound,
                  out: EngineOutput) -> Dict[int, ClientDownlink]:
        """Slice the batched downlink tensors back to ragged per-client
        ClientDownlinks (views, no compute).  Mask rows stay in the
        packed wire format; clients unpack on use (``modulate``)."""
        result: Dict[int, ClientDownlink] = {}
        for i, cid in enumerate(packed.client_ids):
            k = len(packed.task_ids[i])
            result[cid] = ClientDownlink(out.down_unified[i],
                                         out.down_masks[i, :k],
                                         out.down_lams[i, :k])
        return result

    def round(self, uploads: Sequence[ClientUpload], *,
              mode: Optional[str] = None, packed: bool = True
              ) -> Tuple[Dict[int, ClientDownlink], EngineOutput]:
        """Pack → run → unpack: the drop-in replacement for the legacy
        per-task Python loop in ``MaTUServer.round``.  ``packed=False``
        runs the bool/fp32 A/B layout."""
        batch = pack_uploads(uploads, self.cfg.n_tasks, packed=packed)
        out = self.run_packed(batch, mode=mode)
        return self.downlinks(batch, out), out


# -- batched client-side unification ----------------------------------------

@functools.lru_cache(maxsize=None)
def _client_unify_jit(mode: str, packed: bool):
    fn = ops.fused_unify_packed if packed else ops.fused_unify
    return jax.jit(functools.partial(fn, mode=mode))


def batched_client_unify(task_vectors: jax.Array, valid: jax.Array, *,
                         mode: Optional[str] = None, packed: bool = True):
    """All clients' upload construction in one fused call.

    task_vectors (N, k_max, d) zero-padded stacks; valid (N, k_max).
    By default emits the uplink wire format:
    (unified (N, d) **bf16**, mask_words (N, k_max, ceil(d/32))
    **uint32**, lams (N, k_max) fp32) — row n equals
    ``unify_with_modulators(task_vectors[n, valid[n]])`` with the
    unified vector rounded to bf16 *after* the masks/λ were derived
    from it in fp32.  ``packed=False`` returns the legacy
    (fp32, bool, fp32) triple.
    """
    mode = mode or ops.resolve_mode()
    return _client_unify_jit(mode, packed)(task_vectors, valid)
