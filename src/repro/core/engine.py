"""Batched, kernel-backed MaTU round engine (paper §3.2, Eq. 3–7).

One jit-compiled pipeline replaces the three divergent server paths the
repo used to carry (the Python-loop ``MaTUServer.round``, the dense
``matu_round`` reference, and the unused Pallas kernels):

  pack  →  Eq. 3+4 batched agreement/merge  →  Eq. 5 sign similarity
        →  Eq. 6+7 cross-task transfer      →  batched downlink
           re-unification (fused unify + mask + λ kernel)

All tensor math dispatches through :func:`repro.kernels.ops.matu_round_slots`
(dense Pallas kernels on TPU; the two-pass cache-blocked streaming
round on CPU/GPU); ``matu_round`` in :mod:`repro.core.aggregation`
remains the dense reference semantics the engine is tested against.

Padding contract
----------------
A round's ragged ``List[ClientUpload]`` is packed into fixed-shape
*slot* tensors so participation sampling keeps a static jit signature:

* client axis: padded to ``n_max`` (next power of two ≥ N by default);
  padding rows have all-invalid slots, so they drop out of every
  reduction.
* slot axis: each client's held tasks occupy the first k_n of
  ``k_max`` slots (next power of two ≥ max k_n); invalid slots carry
  zero masks/λ/sizes and the sentinel task id T.  Per-task reductions
  are segment-sums keyed by slot task id — the sentinel bucket (index
  T of T+1 segments) swallows all padding; downlink gathers clamp the
  sentinel and the slot-valid mask zeroes its output.
* task axis: always the full registry size T.  Tasks with no member
  this round produce τ̂ = 0, m̂ = 0 (``matu_round`` semantics — the
  legacy server reported m̂ = 1 for unheld tasks, which is unobservable
  downstream) and are masked out of the similarity matrix so
  cross-task transfer never mixes in zero vectors.

The slot layout keeps the packed footprint and the round's work at
O(Σ k_n · d) — the same asymptotics as the legacy ragged loop — while
the dense (N, T, d) tensors the Pallas kernels and ``matu_round``
consume are derived on demand (``PackedRound.dense_tensors`` /
scatter inside the kernel path).

The jit cache is keyed on (shape signature, dispatch mode); the mode is
resolved from the environment once per call (see ``ops.resolve_mode``)
so ``REPRO_DISABLE_PALLAS`` / ``REPRO_PALLAS_INTERPRET`` A/B checks
never collide in the cache.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import EPS_DEFAULT, KAPPA_DEFAULT, RHO_DEFAULT
from repro.core.client import ClientDownlink, ClientUpload
from repro.kernels import ops


@dataclass(frozen=True)
class EngineConfig:
    n_tasks: int
    rho: float = RHO_DEFAULT
    eps: float = EPS_DEFAULT
    kappa: int = KAPPA_DEFAULT
    cross_task: bool = True
    uniform_cross: bool = False


@dataclass
class PackedRound:
    """Fixed-shape slot tensors for one round + host-side metadata."""
    client_ids: List[int]            # actual clients, row order
    task_ids: List[List[int]]        # per client, slot order
    unified: jax.Array               # (n_max, d) fp32
    slot_masks: jax.Array            # (n_max, k_max, d) bool
    slot_lams: jax.Array             # (n_max, k_max) fp32
    slot_sizes: jax.Array            # (n_max, k_max) fp32
    slot_tasks: jax.Array            # (n_max, k_max) int32; T = invalid sentinel
    slot_valid: jax.Array            # (n_max, k_max) bool
    n_tasks: int

    @property
    def n_clients(self) -> int:
        return len(self.client_ids)

    def dense_tensors(self):
        """Scatter to the dense per-task layout ``matu_round`` consumes:
        (masks (N, T, d), lams (N, T), member (N, T), sizes (N, T)).
        Test/diagnostic helper — the hot path never materialises this
        on CPU.  Delegates to the single slot→dense contract in
        :func:`repro.kernels.ops.slots_to_dense`."""
        return ops.slots_to_dense(self.slot_masks, self.slot_lams,
                                  self.slot_sizes, self.slot_valid,
                                  self.slot_tasks, self.n_tasks)


class EngineOutput(NamedTuple):
    """Round results.  τ̃ is not materialised on the hot path — where
    needed it is (2·task_vectors − tau_hats) on rows with donors."""
    task_vectors: jax.Array          # (T, d) τ^{t,r+1}
    tau_hats: jax.Array              # (T, d)
    m_hats: jax.Array                # (T, d)
    similarity: jax.Array            # (T, T), held-masked
    down_unified: jax.Array          # (n_max, d)
    down_masks: jax.Array            # (n_max, k_max, d) bool
    down_lams: jax.Array             # (n_max, k_max)


def _round_up_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def pack_uploads(uploads: Sequence[ClientUpload], n_tasks: int, *,
                 n_max: Optional[int] = None,
                 k_max: Optional[int] = None) -> PackedRound:
    """Pack a ragged round of uploads into the engine's slot layout.

    Pure data movement (numpy fills of O(Σ k_n · d) bytes, one
    host→device transfer per tensor); all math stays inside the jitted
    round.
    """
    n = len(uploads)
    d = int(uploads[0].unified.shape[0])
    n_max = n_max or _round_up_pow2(n)
    k_max = k_max or _round_up_pow2(max(len(u.task_ids) for u in uploads))
    if n_max < n:
        raise ValueError(f"n_max={n_max} < round size {n}")

    # np.empty + zero only the padding: the valid region is fully
    # overwritten below, so a full np.zeros would write the big
    # (n_max, k_max, d) buffers twice for nothing
    unified = np.empty((n_max, d), np.float32)
    unified[n:] = 0.0
    slot_masks = np.empty((n_max, k_max, d), bool)
    slot_masks[n:] = False
    slot_lams = np.zeros((n_max, k_max), np.float32)
    slot_sizes = np.zeros((n_max, k_max), np.float32)
    slot_tasks = np.full((n_max, k_max), n_tasks, np.int32)
    slot_valid = np.zeros((n_max, k_max), bool)

    for i, up in enumerate(uploads):
        k = len(up.task_ids)
        unified[i] = np.asarray(up.unified, np.float32)
        slot_masks[i, :k] = np.asarray(up.masks, bool)
        slot_masks[i, k:] = False
        slot_lams[i, :k] = np.asarray(up.lams, np.float32)
        slot_sizes[i, :k] = np.asarray(up.data_sizes, np.float32)
        slot_tasks[i, :k] = up.task_ids
        slot_valid[i, :k] = True

    return PackedRound([u.client_id for u in uploads],
                       [list(u.task_ids) for u in uploads],
                       jnp.asarray(unified), jnp.asarray(slot_masks),
                       jnp.asarray(slot_lams), jnp.asarray(slot_sizes),
                       jnp.asarray(slot_tasks), jnp.asarray(slot_valid),
                       n_tasks)


def pack_from_slots(client_ids: List[int], task_ids: List[List[int]],
                    unified: jax.Array, slot_masks: jax.Array,
                    slot_lams: jax.Array, slot_tasks: jax.Array,
                    slot_valid: jax.Array, slot_sizes: jax.Array,
                    n_tasks: int) -> PackedRound:
    """Build a PackedRound from already-batched slot tensors (the
    strategy's pre-packed upload path) — zero copies, the slot layout
    IS the engine's native layout."""
    return PackedRound(client_ids, task_ids, unified, slot_masks,
                       slot_lams.astype(jnp.float32),
                       slot_sizes.astype(jnp.float32),
                       slot_tasks.astype(jnp.int32), slot_valid, n_tasks)


def _round_impl(unified, slot_masks, slot_lams, slot_sizes, slot_valid,
                slot_tasks, *, cfg: EngineConfig, mode: str) -> EngineOutput:
    """The whole server step, traced once per (shapes, mode)."""
    out = ops.matu_round_slots(
        unified, slot_masks, slot_lams, slot_sizes, slot_valid, slot_tasks,
        cfg.n_tasks, rho=cfg.rho, eps=cfg.eps, kappa=cfg.kappa,
        cross_task=cfg.cross_task, uniform_cross=cfg.uniform_cross,
        mode=mode)
    return EngineOutput(*out)


class RoundEngine:
    """Stateless per-round executor; owns only jit caches (one per
    dispatch mode — shapes are handled by jax.jit's own cache)."""

    def __init__(self, cfg: EngineConfig):
        self.cfg = cfg
        self._impls: Dict[str, object] = {}

    def _impl(self, mode: str):
        fn = self._impls.get(mode)
        if fn is None:
            import repro.core.engine as _mod
            fn = jax.jit(functools.partial(_mod._round_impl, cfg=self.cfg,
                                           mode=mode))
            self._impls[mode] = fn
        return fn

    def run_packed(self, packed: PackedRound, *,
                   mode: Optional[str] = None) -> EngineOutput:
        mode = mode or ops.resolve_mode()
        return self._impl(mode)(packed.unified, packed.slot_masks,
                                packed.slot_lams, packed.slot_sizes,
                                packed.slot_valid, packed.slot_tasks)

    def downlinks(self, packed: PackedRound,
                  out: EngineOutput) -> Dict[int, ClientDownlink]:
        """Slice the batched downlink tensors back to ragged per-client
        ClientDownlinks (views, no compute)."""
        result: Dict[int, ClientDownlink] = {}
        for i, cid in enumerate(packed.client_ids):
            k = len(packed.task_ids[i])
            result[cid] = ClientDownlink(out.down_unified[i],
                                         out.down_masks[i, :k],
                                         out.down_lams[i, :k])
        return result

    def round(self, uploads: Sequence[ClientUpload], *,
              mode: Optional[str] = None
              ) -> Tuple[Dict[int, ClientDownlink], EngineOutput]:
        """Pack → run → unpack: the drop-in replacement for the legacy
        per-task Python loop in ``MaTUServer.round``."""
        packed = pack_uploads(uploads, self.cfg.n_tasks)
        out = self.run_packed(packed, mode=mode)
        return self.downlinks(packed, out), out


# -- batched client-side unification ----------------------------------------

@functools.lru_cache(maxsize=None)
def _client_unify_jit(mode: str):
    return jax.jit(functools.partial(ops.fused_unify, mode=mode))


def batched_client_unify(task_vectors: jax.Array, valid: jax.Array, *,
                         mode: Optional[str] = None):
    """All clients' upload construction in one fused call.

    task_vectors (N, k_max, d) zero-padded stacks; valid (N, k_max).
    Returns (unified (N, d), masks (N, k_max, d) bool, lams (N, k_max))
    — row n equals ``unify_with_modulators(task_vectors[n, valid[n]])``.
    """
    mode = mode or ops.resolve_mode()
    return _client_unify_jit(mode)(task_vectors, valid)
