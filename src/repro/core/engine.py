"""Batched, kernel-backed MaTU round engine (paper §3.2, Eq. 3–7).

One jit-compiled pipeline replaces the three divergent server paths the
repo used to carry (the Python-loop ``MaTUServer.round``, the dense
``matu_round`` reference, and the unused Pallas kernels):

  pack  →  Eq. 3+4 batched agreement/merge  →  Eq. 5 sign similarity
        →  Eq. 6+7 cross-task transfer      →  batched downlink
           re-unification (fused unify + mask + λ kernel)

All tensor math dispatches through
:func:`repro.kernels.ops.matu_round_slots_packed` (packed Pallas
kernels on TPU; the two-pass cache-blocked packed streaming round on
CPU/GPU); ``matu_round`` in :mod:`repro.core.aggregation` remains the
dense reference semantics the engine is tested against.

Padding contract
----------------
A round's ragged ``List[ClientUpload]`` is packed into fixed-shape
*slot* tensors so participation sampling keeps a static jit signature:

* client axis: padded to ``n_max`` (next power of two ≥ N by default);
  padding rows have all-invalid slots, so they drop out of every
  reduction.
* slot axis: each client's held tasks occupy the first k_n of
  ``k_max`` slots (next power of two ≥ max k_n); invalid slots carry
  zero masks/λ/sizes and the sentinel task id T.  Per-task reductions
  are segment-sums keyed by slot task id — the sentinel bucket (index
  T of T+1 segments) swallows all padding; downlink gathers clamp the
  sentinel and the slot-valid mask zeroes its output.
* task axis: always the full registry size T.  Tasks with no member
  this round produce τ̂ = 0, m̂ = 0 (``matu_round`` semantics — the
  legacy server reported m̂ = 1 for unheld tasks, which is unobservable
  downstream) and are masked out of the similarity matrix so
  cross-task transfer never mixes in zero vectors.

Wire format
-----------
The slot tensors ARE the uplink/downlink wire format — what the engine
holds in memory is byte-identical to what a client transmits, so
communication accounting is measured off the buffers rather than
simulated:

* **masks** travel bit-packed: ``uint32`` words of shape
  ``(n_max, k_max, ceil(d/32))``, 32 mask bits per word, **LSB-first**
  (element j of a d-length mask is bit ``j % 32`` of word ``j // 32``;
  see ``repro.kernels.bitpack`` for the single definition).  Tail bits
  of the last word — elements ``d .. 32*ceil(d/32)`` — are always
  zero; producers enforce it and popcount consumers rely on it.
* **unified / task vectors** travel bf16 (``jnp.bfloat16`` storage);
  all round *compute* is fp32 — kernels upcast one cache/VMEM tile at
  a time, and every sign-derived quantity (modulator mask bits, m̂,
  similarity) plus λ num/den is computed from fp32 values *before* the
  outgoing bf16 rounding.  Consequently packed↔bool parity is exact
  on identical (already bf16-quantised) inputs: masks, m̂, and
  similarity are bit-identical in every mode (per-coordinate
  decisions, independent of tile/chunk grouping), bf16 vector outputs
  are the bf16 rounding of the fp32 ones, and λs are bit-identical on
  the streaming ref round (same CHUNK_D accumulation grouping as the
  bool round).  On the Pallas paths the packed kernels tile d at 4096
  (128 uint32 lanes) vs the bool kernels' 2048, so the λ num/den
  partial sums group differently across tiles — λ agrees to fp32
  accumulation tolerance (~1e-6 relative) there, not bitwise.
* **m̂** is not part of the wire and is not materialised in fp32:
  the engine carries the Eq. 3 agreement numerator (an exact integer
  ≤ N_t) at one byte per coordinate and re-derives
  m̂ = 1[α ≥ ρ] ∨ α on demand (``EngineOutput.m_hats``).
* λ / sizes stay fp32 scalars (k per client, 32 bits each on the
  paper's accounting).

Task-vector layout contract
---------------------------
The engine never sees a model: the d-axis it merges coordinate-by-
coordinate is DEFINED upstream by each backbone's
:class:`~repro.common.tree.TaskVectorSpace` manifest (LoRA delta
leaves in canonical tree order, each raveled C-order into a contiguous
``[offset, offset + size)`` slice).  That makes layout agreement a
precondition, not a property the engine can check numerically — so it
is enforced at the edges: the manifest ``fingerprint`` rides every
upload, and the strategy layer refuses to aggregate
(``TaskVectorLayoutError``) when a client's fingerprint disagrees with
the server's expectation for any task it holds.  Mixed-architecture
rounds zero-pad every client's vector to a common d that is a multiple
of 256 coordinates (``8 × bitpack.WORD_BITS`` = one ``LAMBDA_BLOCK``),
so shorter manifests end exactly on a packed-word AND λ-block
boundary: pad coordinates are zero in every row, contribute nothing to
any reduction, and the packed/bool parity guarantees above carry over
to padded rounds unchanged.

The bool/fp32 slot layout is retained behind ``pack_uploads(...,
packed=False)`` as the A/B baseline and parity oracle
(``benchmarks/bench_round_engine.py`` measures both).

**Entropy-coded layer (optional, host edge only).**  On top of the
packed words sits an invertible Golomb-Rice coder
(:mod:`repro.fed.compression`): each mask row becomes one
self-describing record — a 5-byte header (polarity bit, raw-escape
bit, 5-bit Rice parameter, uint32 run count) followed by the Rice
payload (unary quotients then fixed-width remainders, LSB-first,
byte-padded), or the raw packed words verbatim when Rice would expand
(so coded ≤ raw + header at any density).  Decode needs only ``d`` and
the bytes.  The coded layer never enters the jitted round:
``pack_uploads`` decodes coded (uint8) uploads into slot words at the
host edge, and ``RoundEngine.downlinks(code_masks=True)`` encodes the
downlink rows back to streams; biased modulator masks (P(1) ≈ 0.75 on
own tasks) go out at ~0.82 bits/coord, measured off the actual byte
streams.  ``code_masks=False`` (default) keeps the raw packed wire as
the A/B toggle.

The slot layout keeps the packed footprint and the round's work at
O(Σ k_n · d) — the same asymptotics as the legacy ragged loop — while
the dense (N, T, ·) tensors the Pallas kernels and ``matu_round``
consume are derived on demand (``PackedRound.dense_tensors`` /
scatter inside the kernel path).

The jit cache is keyed on (shape signature, dispatch mode, d); the
mode is resolved from the environment once per call (see
``ops.resolve_mode``) so ``REPRO_DISABLE_PALLAS`` /
``REPRO_PALLAS_INTERPRET`` A/B checks never collide in the cache.

Host pipeline
-------------
``RoundEngine.round_stream`` runs a sequence of rounds through a
two-deep host/device pipeline: while round r's jitted step executes on
the device (jax dispatch is asynchronous), the host finishes round
r−1 (block → batched downlink encode → yield) and then packs/decodes
round r+1's uploads.  The contract:

* **buffer ownership** — ``pack_uploads`` stages its big host tensors
  (unified, slot_masks) in a :class:`SlotStage`; the pipeline
  alternates TWO stages, so the stage refilled for round r+1 is the
  one round r−1 used — and round r−1 was explicitly blocked
  (``jax.block_until_ready`` on its whole ``EngineOutput``) before
  that refill begins.  A staging buffer is therefore never written
  while a device step that may alias it (CPU ``jnp.asarray`` can be
  zero-copy) is in flight.  Fresh (non-staged) allocations — the small
  per-slot tensors, and everything in the ``pipeline=False`` path —
  need no discipline: they are never reused.
* **block_until_ready** — the ONLY sync points are the per-round drain
  (block on round r−1's outputs before encoding its downlinks) and
  the implicit ``np.asarray`` of downlink tensors inside
  ``downlinks``.  Dispatch order on a single device serialises the
  steps, so draining r−1 after dispatching r leaves the device busy
  throughout.
* **escape hatch** — ``pipeline=False`` runs pack → block → downlink
  strictly sequentially with fresh buffers.  Both paths execute the
  identical numpy/XLA computations in a different order, so pipelined
  rounds are **bit-identical** to sequential ones (the A/B contract
  tests/test_pipeline.py enforces, mirroring the sharded ≡
  single-device contract above).
* **timings** — each yielded round carries a ``phase_us`` dict
  (``pack`` / ``decode`` / ``encode`` / ``device`` microseconds;
  ``device`` is dispatch→ready wall, which under the pipeline
  overlaps the host phases of its neighbours).

``round_stream`` pulls upload round r+1 before yielding round r, so
the input iterable must not depend on the previous round's downlinks —
replay/bench traffic qualifies; the simulator's closed training loop
instead pipelines via the strategy's deferred drain
(``MaTUStrategy(pipeline=True)``), which overlaps the dispatched round
with the simulator's own bookkeeping under the same blocking contract.

Sharding contract
-----------------
With a mesh, one engine call runs distributed over the ``taskvec``
logical axis (``repro.nn.sharding``: d shards over every mesh axis the
rule names — ("pod", "data", "model") on the production pods, all 8
host devices on the CI debug mesh):

* **layout** — every d-axis tensor (``unified``, ``slot_masks``,
  ``down_unified``, ``down_masks``, τ̂/τ/α) splits on its LAST axis
  into ``n_shards`` contiguous slices; per-slot scalars (λ, sizes,
  task ids, validity) are replicated.  ``pack_uploads`` /
  ``pack_from_slots`` / ``batched_client_unify`` place the buffers
  with the matching ``NamedSharding`` at the wire boundary, so the
  round never reshards.
* **padding** — d is zero-padded to ``pad_d_for_shards(d, n_shards)``:
  each shard holds a power-of-two multiple of 256 coords.  256 coords
  = 8 uint32 words (``bitpack.WORD_BITS`` — packed mask words are
  never split mid-word, the wire layout stays the single source of
  truth) and one λ reduction block (``ref.LAMBDA_BLOCK``).  Padded
  coords carry zero masks/vectors and drop out of every reduction;
  outputs are sliced back to d.
* **collectives** — ``_round_impl`` runs ``ops.matu_round_slots`` /
  ``_packed`` under ``shard_map``; per-coordinate math (Eq. 3, 4, 6, 7
  and the downlink re-unification) never crosses shards.  Exactly two
  reductions do: one integer psum of the Eq. 5 (T, T) popcount dots
  (exact under any order), and one psum of the λ numerator/denominator
  block-tree roots (``ref._lam_totals``).  Everything derived from the
  per-client scalars (γ, N_t, held) is computed replicated.  No
  all-gather / all-to-all / reduce-scatter appears in the round HLO.
* **parity** — the λ reductions run on a fixed 256-coord block grid
  combined by a shard-count-invariant binary tree, so the sharded
  round is **bit-identical** to the single-device round in "ref" mode
  for both the packed and bool layouts (power-of-two shard counts).
  On the Pallas paths masks/m̂/similarity stay bit-identical and λ
  agrees to fp32 accumulation tolerance (the PR 2 tile caveat).

Population-scale contract
-------------------------
``RoundEngine.round_chunked`` streams a round of N uploads through a
fixed-shape chunk buffer of C clients, so a round's memory is
**O(chunk + T·d), independent of N** — the client-axis twin of the
d-sharding above.  The Eq. 3/4 agreement numerators (integer sign
votes), Eq. 5 popcount dot partials, per-task size totals, and the λ
num/den block partials are all associative folds, split into four
phases (``repro.kernels.ref``, chunked section):

* **phase A** (scalars): per-task size totals + membership counts fold
  into (T+1,) accumulators — the Eq. 4 γ normaliser needs the *global*
  totals before any merge work, which is why the engine makes two
  passes over the upload stream (``uploads`` may be a zero-arg
  callable returning a fresh iterator — the population simulator
  re-derives sampled clients on demand and never materialises the
  round).
* **phase B** (merge): each chunk packs into the SAME slot layout as
  the monolithic round (one ``SlotStage``, blocked before refill) and
  folds sign votes + γλ-weighted merge partials into carried
  (T+1, dp) accumulators via one jitted chunk step reused across
  chunks (the last chunk is padded — same static signature, padding
  rows carry the sentinel task id so their contributions land in the
  swallowed (T+1)-th segment).
* **finish**: Eq. 3 α/m̂, Eq. 5 dots, Eq. 6 weights, Eq. 7 combine and
  the λ numerator from the accumulators alone — no slot tensor in
  sight.
* **phase C** (downlink): per chunk, re-unification from the finished
  task vectors; each slot row lives in exactly one chunk, so this is
  embarrassingly parallel over rows.  ``sink`` streams each chunk's
  ``ClientDownlink``s out instead of holding N of them.

**Chunk-count invariance** (the bit-identity rule, extending PR 3's
shard-count-invariant λ tree): every fp32 client-axis reduction is ONE
global sequential scatter fold — the carried ``acc.at[ids].add``
applies the same adds in the same global row order as the monolithic
round's whole-round segment-sum, for ANY contiguous chunking; the
integer votes/dots are order-free; and every d-axis reduction keeps
the monolithic grid (``CHUNK_D`` streaming blocks, ``LAMBDA_BLOCK`` λ
tree).  Hence chunked ≡ monolithic **bit for bit** in ref mode for
both layouts — masks, λ, vectors, and the measured wire bits
(tests/test_chunked_engine.py), for chunk sizes 1, non-divisors of N,
and > N alike.

**2-D (slots × taskvec) mesh**: on a ``make_population_mesh`` the
"slots" axis shards the chunk's client/slot rows in phase C (and the
ingest buffers ride along) while the taskvec axes keep sharding d;
phase B never splits the client fold across devices (that would change
the fp32 accumulation order) — each shard folds every row of its
d-slice locally, so the merge step has NO collectives and the whole
round keeps the monolithic collective budget: one integer dots psum +
one λ-num roots psum in the finish, plus one λ-den roots psum per
chunk in phase C.

Async & fault model
-------------------
The engine itself is stateless per round and keyed by ``(n_max, k_max,
d, mode)`` — exactly what a buffered async server needs: the admission
queue (``repro.fed.systems.AdmissionQueue``) drains whatever has
arrived by the current tick into the SAME fixed-shape slot tensors, so
the jit caches reuse across ticks regardless of which clients made it.

* **staleness discount** — a buffered upload dispatched at round q and
  folded at round r carries staleness ``s = r − q``; its slots get the
  weight ``w = δ**s`` (``δ = STALENESS_DISCOUNT``), attached as
  ``PackedRound.slot_weights`` and applied inside the jitted round as
  ``λ·w`` and ``size·w`` before the Eq. 3 masked-agg / λ block
  partials (``ops._apply_slot_weights``).  Discounting the λ shrinks
  the stale slot's reconstructed vector; discounting the size shrinks
  its share of the γ normalization — fresh uploads win both ways.
* **sync ≡ async equivalence** — with an always-available, zero-
  latency, zero-fault trace (``ClientSystems.ideal``) every upload has
  ``s = 0`` so ``w = 1``; the weighted trace multiplies by 1.0 (exact
  under IEEE 754) and the drain order equals the sync selection order,
  so the async round is **bit-identical** to the sync one — unified
  vectors, λ, masks, and the measured History bits
  (tests/test_async_fed.py).  ``slot_weights=None`` (every synchronous
  caller) never traces the multiply at all.
* **fault injection & quarantine** — corrupted coded uploads are the
  wire's problem, not the engine's: the async strategy validates each
  client's stream (CRC frame + entropy decode,
  ``repro.fed.systems.wrap_stream`` / ``CodedStreamError``) BEFORE
  packing and simply leaves quarantined clients out of the batch; the
  engine never sees malformed bytes.  Empty rounds (everyone dropped)
  never reach ``pack_uploads`` — the simulator skips-and-carries.
* **dark tasks** — a task with no admitted member this round produces
  τ̂ = 0 and a zeroed similarity row (the padding contract above);
  the async strategy carries last-seen per-task vectors and decays
  them toward the unified vector instead of evaluating the zeros (see
  ``AsyncMaTUStrategy``).
"""

from __future__ import annotations

import functools
import itertools
import time
from dataclasses import dataclass
from typing import (Callable, Dict, Iterable, List, NamedTuple, Optional,
                    Sequence, Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.aggregation import EPS_DEFAULT, KAPPA_DEFAULT, RHO_DEFAULT
from repro.core.client import ClientDownlink, ClientUpload
from repro.kernels import bitpack, ops
from repro.kernels.ref import CHUNK_D, LAMBDA_BLOCK, _chunked, _next_pow2
from repro.nn.sharding import slot_axes, taskvec_axes, taskvec_sharding

# default async staleness discount δ: a buffered upload folded s rounds
# after dispatch enters Eq. 3 with weight δ**s (see "Async & fault
# model" in the module docstring); δ**0 = 1 keeps fresh uploads exact.
STALENESS_DISCOUNT = 0.5


@dataclass(frozen=True)
class EngineConfig:
    n_tasks: int
    rho: float = RHO_DEFAULT
    eps: float = EPS_DEFAULT
    kappa: int = KAPPA_DEFAULT
    cross_task: bool = True
    uniform_cross: bool = False


@dataclass
class PackedRound:
    """Fixed-shape slot tensors for one round + host-side metadata.

    In the default wire layout ``unified`` is bf16 and ``slot_masks``
    holds bit-packed uint32 words (``packed`` is True); the legacy
    bool/fp32 layout (``pack_uploads(..., packed=False)``) is kept for
    A/B benchmarks and parity tests.
    """
    client_ids: List[int]            # actual clients, row order
    task_ids: List[List[int]]        # per client, slot order
    unified: jax.Array               # (n_max, d) bf16 (wire) | fp32 (bool A/B)
    slot_masks: jax.Array            # (n_max, k_max, ceil(d/32)) uint32 | (…, d) bool
    slot_lams: jax.Array             # (n_max, k_max) fp32
    slot_sizes: jax.Array            # (n_max, k_max) fp32
    slot_tasks: jax.Array            # (n_max, k_max) int32; T = invalid sentinel
    slot_valid: jax.Array            # (n_max, k_max) bool
    n_tasks: int
    d: int                           # unpacked feature count (static)
    # d after the taskvec-shard padding (pad_d_for_shards); equals d
    # when packed without a mesh.  The d-axis tensors above carry THIS
    # width; wire accounting and output slicing use the true ``d``.
    d_pad: Optional[int] = None
    # per-slot staleness-discount weights (n_max, k_max) fp32, or None
    # for the synchronous (all-fresh) round.  Applied inside the jitted
    # round as λ·w and size·w before the Eq. 3 / λ block partials (see
    # ``ops._apply_slot_weights``); w ≡ 1 is bitwise identical to None.
    slot_weights: Optional[jax.Array] = None

    @property
    def n_clients(self) -> int:
        return len(self.client_ids)

    @property
    def padded_d(self) -> int:
        return self.d_pad or self.d

    @property
    def packed(self) -> bool:
        """True when the slot tensors are in the wire layout."""
        return self.slot_masks.dtype == jnp.uint32

    def wire_bits(self) -> int:
        """Measured uplink size of the real (non-padding) slots: the
        bits actually occupied by this round's wire buffers (bf16
        unified + packed mask words + fp32 λ per slot).  For the bool
        A/B layout this reports the paper's fp32+dense-bit accounting
        (32d + k(d+32)) — the scheme those buffers implement."""
        from repro.core.client import paper_link_bits
        total = 0
        for tasks in self.task_ids:
            k = len(tasks)
            if self.packed:
                total += bitpack.wire_bits(
                    self.d, k,
                    vec_bytes_per_elem=self.unified.dtype.itemsize)
            else:
                total += paper_link_bits(self.d, k)
        return total

    def dense_tensors(self):
        """Scatter to the dense per-task layout ``matu_round`` consumes:
        (masks (N, T, d) bool, lams (N, T), member (N, T), sizes (N, T)).
        Test/diagnostic helper — the hot path never materialises this
        on CPU.  Delegates to the single slot→dense contract in
        :func:`repro.kernels.ops.slots_to_dense` (packed masks go
        through the one sanctioned ``ops.unpack_masks`` route)."""
        masks = (ops.unpack_masks(self.slot_masks, self.d)
                 if self.packed else self.slot_masks)
        return ops.slots_to_dense(masks, self.slot_lams,
                                  self.slot_sizes, self.slot_valid,
                                  self.slot_tasks, self.n_tasks)


class EngineOutput(NamedTuple):
    """Round results.  Neither τ̃ nor m̂ is materialised on the hot
    path: τ̃ is (2·task_vectors − tau_hats) on rows with donors, and m̂
    is re-derived from the exact byte-wide agreement numerator via the
    ``m_hats`` property.  The packed path fills (alpha_num, n_held);
    the bool A/B path fills ``m_hats_dense`` instead."""
    task_vectors: jax.Array          # (T, d) τ^{t,r+1} fp32
    tau_hats: jax.Array              # (T, d) fp32
    similarity: jax.Array            # (T, T), held-masked
    down_unified: jax.Array          # (n_max, d) bf16 (wire) | fp32
    down_masks: jax.Array            # (n_max, k_max, ceil(d/32)) uint32 | (…, d) bool
    down_lams: jax.Array             # (n_max, k_max)
    alpha_num: Optional[jax.Array] = None    # (T, d) uint8 — |Σ sgn(m⊙τ)|
    n_held: Optional[jax.Array] = None       # (T,) fp32 member counts
    rho: float = RHO_DEFAULT
    m_hats_dense: Optional[jax.Array] = None  # (T, d) fp32 (bool path only)

    @property
    def m_hats(self) -> jax.Array:
        """Eq. 3 averaged task masks m̂ (T, d) fp32 — identical (bit for
        bit) to the value the round used internally: the same fp32
        division α = |Σ sgn| / max(N_t, 1) both passes performed."""
        if self.m_hats_dense is not None:
            return self.m_hats_dense
        alpha = (self.alpha_num.astype(jnp.float32)
                 / jnp.maximum(self.n_held, 1.0)[:, None])
        return jnp.where(alpha >= self.rho, 1.0, alpha)


def _round_up_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def pad_d_for_shards(d: int, n_shards: int) -> int:
    """Padded feature count for a taskvec-sharded round: each of the
    ``n_shards`` contiguous d-slices is a power-of-two multiple of 256
    coords — word-aligned for the packed wire layout (256 = 8 ×
    ``bitpack.WORD_BITS``) and block-aligned for the shard-invariant λ
    reduction grid (``ref.LAMBDA_BLOCK``), which is what makes the
    sharded λs bit-identical to the single-device round's.  Identity
    when unsharded."""
    if n_shards <= 1:
        return d
    per_shard_blocks = _next_pow2(-(-d // (n_shards * LAMBDA_BLOCK)))
    return n_shards * LAMBDA_BLOCK * per_shard_blocks


def _mesh_layout(mesh: Optional[Mesh]):
    """(axes, sizes, n_shards) of the taskvec rule on this mesh."""
    if mesh is None:
        return (), (), 1
    axes = taskvec_axes(mesh)
    sizes = tuple(int(mesh.shape[a]) for a in axes)
    return axes, sizes, int(np.prod(sizes)) if axes else 1


class SlotStage:
    """Reusable host staging buffers for :func:`pack_uploads`.

    Holds the round's BIG host tensors (unified vectors, slot mask
    words) keyed by name, reallocating only when the shape signature
    changes — so a steady-state round stream refills warm pages instead
    of faulting fresh hundred-MB allocations every round.  Ownership
    contract (see "Host pipeline" in the module docstring): because CPU
    ``jnp.asarray`` may be zero-copy, a stage must not be refilled
    while a device step that consumed its buffers is still in flight —
    ``RoundEngine.round_stream`` alternates two stages and blocks round
    r−1 before round r+1 touches its stage.
    """

    def __init__(self) -> None:
        self._bufs: Dict[str, np.ndarray] = {}

    def alloc(self, name: str, shape: tuple, dtype) -> np.ndarray:
        buf = self._bufs.get(name)
        if (buf is None or buf.shape != tuple(shape)
                or buf.dtype != np.dtype(dtype)):
            buf = np.empty(shape, dtype)
            self._bufs[name] = buf
        return buf


def pack_uploads(uploads: Sequence[ClientUpload], n_tasks: int, *,
                 n_max: Optional[int] = None,
                 k_max: Optional[int] = None,
                 packed: bool = True,
                 mesh: Optional[Mesh] = None,
                 stage: Optional[SlotStage] = None,
                 phase_us: Optional[Dict[str, float]] = None) -> PackedRound:
    """Pack a ragged round of uploads into the engine's slot layout.

    Pure data movement (numpy fills + ``np.packbits`` of O(Σ k_n · d)
    *bits* for the masks, one host→device transfer per tensor); all
    math stays inside the jitted round.  ``packed=False`` selects the
    legacy bool/fp32 layout (A/B baseline).  A client's bool masks are
    bit-packed and its unified vector rounded to bf16 here — this IS
    the uplink quantisation, applied once at the wire boundary.

    Entropy-coded (uint8 stream) uploads are decoded here at the host
    edge in ONE batched ``decode_mask_rows`` call across every coded
    client — records are self-delimiting, so the concatenated streams
    decode to exactly the per-client rows (the jitted round never sees
    the coded layer).

    ``stage`` reuses a :class:`SlotStage`'s big staging buffers
    (pipeline path — see the buffer-ownership contract); ``phase_us``
    accumulates ``pack`` / ``decode`` host microseconds into the given
    dict.

    With ``mesh``, d is zero-padded to ``pad_d_for_shards`` and every
    d-axis tensor is placed with its taskvec ``NamedSharding`` (packed
    mask words split on whole 8-word blocks — never mid-word); scalars
    are replicated onto the mesh.  See the sharding contract above.
    """
    if not uploads:
        raise ValueError("pack_uploads: empty round (no uploads) — "
                         "sample at least one client or skip the round")
    t_pack = time.perf_counter()
    n = len(uploads)
    d = int(uploads[0].unified.shape[0])
    _, _, n_shards = _mesh_layout(mesh)
    d_pad = pad_d_for_shards(d, n_shards)
    n_max = n_max or _round_up_pow2(n)
    k_max = k_max or _round_up_pow2(max(len(u.task_ids) for u in uploads))
    if n_max < n:
        raise ValueError(f"n_max={n_max} < round size {n}")

    # one batched host-edge decode for ALL coded clients: streams
    # concatenate (records self-delimit) and split back by row count
    ks = [len(u.task_ids) for u in uploads]
    masks_np = [np.asarray(u.masks) for u in uploads]
    coded = [i for i, m in enumerate(masks_np) if m.dtype == np.uint8]
    dec_s = 0.0
    if coded:
        from repro.fed.compression import decode_mask_rows
        t0 = time.perf_counter()
        rows = decode_mask_rows(
            masks_np[coded[0]] if len(coded) == 1
            else np.concatenate([masks_np[i] for i in coded]),
            d, sum(ks[i] for i in coded))
        off = 0
        for i in coded:
            masks_np[i] = rows[off:off + ks[i]]
            off += ks[i]
        dec_s = time.perf_counter() - t0

    # np.empty + zero only the padding: the valid region is fully
    # overwritten below, so a full np.zeros would write the big
    # mask/vector buffers twice for nothing.  With a stage the same
    # (possibly dirty) buffers come back each round — the explicit
    # padding writes below are exactly the re-zeroing reuse needs.
    # host-side bf16 fill for the wire layout (ml_dtypes ships with
    # jax): halves the host→device transfer and skips the device cast
    vec_dtype = np.float32
    if packed:
        import ml_dtypes
        vec_dtype = ml_dtypes.bfloat16
    alloc = stage.alloc if stage is not None else (
        lambda _name, shape, dtype: np.empty(shape, dtype))
    unified = alloc("unified", (n_max, d_pad), vec_dtype)
    unified[n:] = 0.0
    unified[:, d:] = 0.0
    if packed:
        dw = bitpack.packed_width(d)
        wpad = bitpack.packed_width(d_pad)
        slot_masks = alloc("slot_masks", (n_max, k_max, wpad), np.uint32)
        slot_masks[n:] = 0
        if wpad > dw:
            slot_masks[:n, :, dw:] = 0
    else:
        slot_masks = alloc("slot_masks", (n_max, k_max, d_pad), bool)
        slot_masks[n:] = False
        slot_masks[:, :, d:] = False
    slot_lams = np.zeros((n_max, k_max), np.float32)
    slot_sizes = np.zeros((n_max, k_max), np.float32)
    slot_tasks = np.full((n_max, k_max), n_tasks, np.int32)
    slot_valid = np.zeros((n_max, k_max), bool)

    for i, up in enumerate(uploads):
        k = ks[i]
        unified[i, :d] = np.asarray(up.unified)
        m = masks_np[i]
        if packed:
            # accept either bool masks (legacy clients — packed here at
            # the wire boundary) or already-packed words
            slot_masks[i, :k, :dw] = (m if m.dtype == np.uint32
                                      else bitpack.pack_bits_np(m))
            slot_masks[i, k:, :dw] = 0
        else:
            slot_masks[i, :k, :d] = (bitpack.unpack_bits_np(m, d)
                                     if m.dtype == np.uint32 else m)
            slot_masks[i, k:] = False
        slot_lams[i, :k] = np.asarray(up.lams, np.float32)
        slot_sizes[i, :k] = np.asarray(up.data_sizes, np.float32)
        slot_tasks[i, :k] = up.task_ids
        slot_valid[i, :k] = True
    if phase_us is not None:
        phase_us["decode"] = phase_us.get("decode", 0.0) + dec_s * 1e6
        phase_us["pack"] = (phase_us.get("pack", 0.0)
                            + (time.perf_counter() - t_pack - dec_s) * 1e6)

    arrays = (unified, slot_masks, slot_lams, slot_sizes, slot_tasks,
              slot_valid)
    if n_shards > 1:
        rep = NamedSharding(mesh, P())
        put = (taskvec_sharding(mesh, 2), taskvec_sharding(mesh, 3),
               rep, rep, rep, rep)
        uni, masks, lams, sizes, tasks, valid = (
            jax.device_put(a, s) for a, s in zip(arrays, put))
    else:
        uni, masks, lams, sizes, tasks, valid = map(jnp.asarray, arrays)
    return PackedRound([u.client_id for u in uploads],
                       [list(u.task_ids) for u in uploads],
                       uni, masks, lams, sizes, tasks, valid,
                       n_tasks, d, d_pad if n_shards > 1 else None)


def pack_from_slots(client_ids: List[int], task_ids: List[List[int]],
                    unified: jax.Array, slot_masks: jax.Array,
                    slot_lams: jax.Array, slot_tasks: jax.Array,
                    slot_valid: jax.Array, slot_sizes: jax.Array,
                    n_tasks: int, *, d: Optional[int] = None,
                    mesh: Optional[Mesh] = None,
                    slot_weights: Optional[jax.Array] = None) -> PackedRound:
    """Build a PackedRound from already-batched slot tensors (the
    strategy's pre-packed upload path) — zero copies, the slot layout
    IS the engine's native layout.  ``slot_masks`` may be uint32 wire
    words (``batched_client_unify`` output) or legacy dense bool.

    ``d`` is the true feature count when the d-axis tensors already
    carry the taskvec-shard padding (``batched_client_unify`` with a
    mesh emits them padded + sharded); with ``mesh`` given and
    *unpadded* tensors, the pad + sharded placement happens here.

    ``slot_weights`` (optional (n, k_max) fp32) attaches the async
    staleness discount to the round (replicated under a mesh)."""
    packed = slot_masks.dtype == jnp.uint32
    width = int(unified.shape[-1])
    d = d or width
    _, _, n_shards = _mesh_layout(mesh)
    d_pad = pad_d_for_shards(d, n_shards)
    if width not in (d, d_pad):
        raise ValueError(f"pack_from_slots: unified width {width} matches "
                         f"neither d={d} nor the shard-padded {d_pad}")
    if n_shards > 1 and width != d_pad:
        unified = jnp.pad(unified, ((0, 0), (0, d_pad - width)))
        w_pad = (d_pad // 32 - slot_masks.shape[-1] if packed
                 else d_pad - slot_masks.shape[-1])
        slot_masks = jnp.pad(slot_masks,
                             ((0, 0), (0, 0), (0, w_pad)))
    if n_shards > 1:
        rep = NamedSharding(mesh, P())
        unified = jax.device_put(unified, taskvec_sharding(mesh, 2))
        slot_masks = jax.device_put(slot_masks, taskvec_sharding(mesh, 3))
        put_rep = lambda x: jax.device_put(x, rep)  # noqa: E731
    else:
        put_rep = lambda x: x  # noqa: E731
    if slot_weights is not None:
        slot_weights = put_rep(jnp.asarray(slot_weights, jnp.float32))
    return PackedRound(client_ids, task_ids, unified, slot_masks,
                       put_rep(slot_lams.astype(jnp.float32)),
                       put_rep(slot_sizes.astype(jnp.float32)),
                       put_rep(slot_tasks.astype(jnp.int32)),
                       put_rep(slot_valid),
                       n_tasks, d, d_pad if n_shards > 1 else None,
                       slot_weights)


def _round_impl(unified, slot_masks, slot_lams, slot_sizes, slot_valid,
                slot_tasks, slot_weights=None, *, cfg: EngineConfig,
                mode: str, d: int,
                mesh: Optional[Mesh] = None,
                axes: Tuple[str, ...] = (),
                axis_sizes: Tuple[int, ...] = ()):
    """The whole server step, traced once per (shapes, mode, d, mesh).
    The mask dtype selects the wire-format (uint32) or bool A/B path;
    with a (mesh, taskvec axes) pair the op runs under ``shard_map``
    per the engine's sharding contract.  ``slot_weights`` (async
    staleness discount, replicated under a mesh) pre-scales λ and sizes
    inside ``ops`` — omitted entirely from the trace when None, so the
    synchronous jit programs are untouched."""
    kw = dict(rho=cfg.rho, eps=cfg.eps, kappa=cfg.kappa,
              cross_task=cfg.cross_task, uniform_cross=cfg.uniform_cross,
              mode=mode)
    packed = slot_masks.dtype == jnp.uint32
    n_shards = int(np.prod(axis_sizes)) if axes else 1
    if mesh is None or n_shards == 1:
        kw["slot_weights"] = slot_weights
        if packed:
            return ops.matu_round_slots_packed(
                unified, slot_masks, slot_lams, slot_sizes, slot_valid,
                slot_tasks, cfg.n_tasks, d, **kw)
        return ops.matu_round_slots(
            unified, slot_masks, slot_lams, slot_sizes, slot_valid,
            slot_tasks, cfg.n_tasks, **kw)

    d_pad = int(unified.shape[-1])
    d_local = d_pad // n_shards
    ax = axes[0] if len(axes) == 1 else axes
    s2, s3, rep = P(None, ax), P(None, None, ax), P()
    kw.update(axis_name=axes, axis_sizes=axis_sizes, d_norm=d)

    if packed:
        def body(u, m, lam, sz, val, tid, *w):
            return ops.matu_round_slots_packed(
                u, m, lam, sz, val, tid, cfg.n_tasks, d_local,
                slot_weights=w[0] if w else None, **kw)
        # (tv, τ̂, α_num, n_held, sim, down_uni, down_words, down_lams)
        out_specs = (s2, s2, s2, rep, rep, s2, s3, rep)
    else:
        def body(u, m, lam, sz, val, tid, *w):
            return ops.matu_round_slots(
                u, m, lam, sz, val, tid, cfg.n_tasks,
                slot_weights=w[0] if w else None, **kw)
        # (tv, τ̂, m̂, sim, down_uni, down_masks, down_lams)
        out_specs = (s2, s2, s2, rep, s2, s3, rep)

    in_specs = (s2, s3, rep, rep, rep, rep)
    operands = (unified, slot_masks, slot_lams, slot_sizes, slot_valid,
                slot_tasks)
    if slot_weights is not None:
        in_specs += (rep,)
        operands += (slot_weights,)
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)(*operands)


def _assemble_downlinks(client_ids: List[int], task_ids: List[List[int]],
                        d: int, down_unified, down_masks, down_lams, *,
                        code_masks: bool = False,
                        phase_us: Optional[Dict[str, float]] = None
                        ) -> Dict[int, ClientDownlink]:
    """Slice batched downlink tensors back to ragged per-client
    ClientDownlinks — the shared back half of ``RoundEngine.downlinks``
    and each ``round_chunked`` phase-C chunk.  With ``code_masks`` the
    mask rows of ALL the given clients are entropy-coded in one batched
    call and split back by per-row record sizes (records self-delimit,
    so each slice is byte-identical to encoding that client alone)."""
    streams: Optional[List[jax.Array]] = None
    if code_masks:
        from repro.fed.compression import encode_mask_rows_with_sizes
        t0 = time.perf_counter()
        dm = np.asarray(down_masks)
        if dm.dtype != np.uint32:     # bool A/B layout
            dm = bitpack.pack_bits_np(dm)
        ks = [len(t) for t in task_ids]
        rows = dm[np.repeat(np.arange(len(ks)), ks),
                  np.concatenate([np.arange(k, dtype=np.int64)
                                  for k in ks])]
        stream, sizes = encode_mask_rows_with_sizes(rows, d)
        ends = np.cumsum(sizes)
        streams, b0, r0 = [], 0, 0
        for k in ks:
            b1 = int(ends[r0 + k - 1]) if k else b0
            streams.append(jnp.asarray(stream[b0:b1]))
            b0, r0 = b1, r0 + k
        if phase_us is not None:
            phase_us["encode"] = (phase_us.get("encode", 0.0)
                                  + (time.perf_counter() - t0) * 1e6)
    result: Dict[int, ClientDownlink] = {}
    for i, cid in enumerate(client_ids):
        k = len(task_ids[i])
        rows_i = streams[i] if code_masks else down_masks[i, :k]
        result[cid] = ClientDownlink(down_unified[i], rows_i,
                                     down_lams[i, :k])
    return result


# -- chunked-round jit bodies (population-scale contract) --------------------
# Module-level (not closures) so tests can monkeypatch them, mirroring
# ``_round_impl``; each is traced once per (shapes, mode, d, mesh).

def _chunk_scalars_impl(slot_sizes, slot_valid, slot_tasks, totals, nt_acc,
                        slot_weights=None, *, mode: str):
    """Phase-A chunk step (replicated scalars — no shard_map needed)."""
    return ops.matu_chunk_scalars(slot_sizes, slot_valid, slot_tasks,
                                  totals, nt_acc,
                                  slot_weights=slot_weights, mode=mode)


def _merge_chunk_impl(unified, slot_masks, slot_lams, slot_sizes, slot_valid,
                      slot_tasks, totals, a_acc, tau_acc, slot_weights=None,
                      *, mode: str, d: int, mesh: Optional[Mesh] = None,
                      axes: Tuple[str, ...] = (),
                      axis_sizes: Tuple[int, ...] = ()):
    """Phase-B chunk step.  Under a mesh each taskvec shard folds EVERY
    chunk row of its local d-slice — the client fold is never split
    across devices (that would change the fp32 accumulation order), so
    the step has no collectives."""
    packed = slot_masks.dtype == jnp.uint32
    n_shards = int(np.prod(axis_sizes)) if axes else 1
    if mesh is None or n_shards == 1:
        if packed:
            return ops.matu_merge_chunk_packed(
                unified, slot_masks, slot_lams, slot_sizes, slot_valid,
                slot_tasks, totals, a_acc, tau_acc, d,
                slot_weights=slot_weights, mode=mode)
        return ops.matu_merge_chunk(
            unified, slot_masks, slot_lams, slot_sizes, slot_valid,
            slot_tasks, totals, a_acc, tau_acc,
            slot_weights=slot_weights, mode=mode)

    d_local = int(unified.shape[-1]) // n_shards
    ax = axes[0] if len(axes) == 1 else axes
    s2, s3, rep = P(None, ax), P(None, None, ax), P()

    def body(u, m, lam, sz, val, tid, tot, a, ta, *w):
        w0 = w[0] if w else None
        if packed:
            return ops.matu_merge_chunk_packed(u, m, lam, sz, val, tid,
                                               tot, a, ta, d_local,
                                               slot_weights=w0, mode=mode)
        return ops.matu_merge_chunk(u, m, lam, sz, val, tid, tot, a, ta,
                                    slot_weights=w0, mode=mode)

    in_specs = (s2, s3, rep, rep, rep, rep, rep, s2, s2)
    operands = (unified, slot_masks, slot_lams, slot_sizes, slot_valid,
                slot_tasks, totals, a_acc, tau_acc)
    if slot_weights is not None:
        in_specs += (rep,)
        operands += (slot_weights,)
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=(s2, s2), check_rep=False)(*operands)


def _finish_impl(a_acc, tau_acc, nt_acc, *, cfg: EngineConfig, mode: str,
                 d: int, packed: bool, n_for_dtype: int,
                 mesh: Optional[Mesh] = None, axes: Tuple[str, ...] = (),
                 axis_sizes: Tuple[int, ...] = ()):
    """Chunked-round finish: Eq. 3 α/m̂ → Eq. 5 dots → Eq. 6/7 → λ
    numerator, from the accumulators alone.  The only collectives of
    the whole merge+finish pipeline live here (integer dots psum + λ
    roots psum), exactly the monolithic round's budget."""
    kw = dict(n_tasks=cfg.n_tasks, rho=cfg.rho, eps=cfg.eps,
              kappa=cfg.kappa, cross_task=cfg.cross_task,
              uniform_cross=cfg.uniform_cross, mode=mode)
    n_shards = int(np.prod(axis_sizes)) if axes else 1
    if mesh is None or n_shards == 1:
        if packed:
            return ops.matu_finish_packed(a_acc, tau_acc, nt_acc,
                                          n_for_dtype, d=d, **kw)
        return ops.matu_finish(a_acc, tau_acc, nt_acc, d=d, **kw)

    d_local = int(a_acc.shape[-1]) // n_shards
    ax = axes[0] if len(axes) == 1 else axes
    s2, rep = P(None, ax), P()
    kw.update(axis_name=axes, axis_sizes=axis_sizes, d_norm=d)

    def body(a, ta, nt):
        if packed:
            return ops.matu_finish_packed(a, ta, nt, n_for_dtype,
                                          d=d_local, **kw)
        return ops.matu_finish(a, ta, nt, d=d_local, **kw)

    # (tv, τ̂, α_num | m̂, n_t, sim, num_t)
    return shard_map(body, mesh=mesh, in_specs=(s2, s2, rep),
                     out_specs=(s2, s2, s2, rep, rep, rep),
                     check_rep=False)(a_acc, tau_acc, nt_acc)


def _downlink_chunk_impl(task_vectors, slot_valid, slot_tasks, num_t, *,
                         cfg: EngineConfig, mode: str, d: int, packed: bool,
                         mesh: Optional[Mesh] = None,
                         axes: Tuple[str, ...] = (),
                         axis_sizes: Tuple[int, ...] = (),
                         row_axes: Tuple[str, ...] = ()):
    """Phase-C chunk step: downlink re-unification of one client chunk.
    This is where the 2-D (slots × taskvec) mesh composes: ``row_axes``
    (the fed_slots rule) shard the chunk's client rows, the taskvec
    axes shard d, and the λ-denominator roots psum over the taskvec
    axes only (rows never mix)."""
    n_shards = int(np.prod(axis_sizes)) if axes else 1
    if mesh is None or (n_shards == 1 and not row_axes):
        if packed:
            return ops.matu_downlink_chunk_packed(task_vectors, slot_tasks,
                                                  num_t, d, mode=mode)
        return ops.matu_downlink_chunk(task_vectors, slot_valid, slot_tasks,
                                       num_t, n_tasks=cfg.n_tasks, mode=mode)

    d_local = (int(task_vectors.shape[-1]) // n_shards
               if n_shards > 1 else d)
    ax = (axes[0] if len(axes) == 1 else axes) if n_shards > 1 else None
    rx = (row_axes[0] if len(row_axes) == 1 else row_axes) \
        if row_axes else None
    rep = P()
    kw: Dict[str, object] = dict(mode=mode)
    if n_shards > 1:
        kw.update(axis_name=axes, axis_sizes=axis_sizes)

    def body(tv, val, tid, nt):
        if packed:
            return ops.matu_downlink_chunk_packed(tv, tid, nt, d_local, **kw)
        return ops.matu_downlink_chunk(tv, val, tid, nt,
                                       n_tasks=cfg.n_tasks, **kw)

    in_specs = (P(None, ax), P(rx, None), P(rx, None), rep)
    out_specs = (P(rx, ax), P(rx, None, ax), P(rx, None))
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)(
                         task_vectors, slot_valid, slot_tasks, num_t)


class RoundEngine:
    """Stateless per-round executor; owns only jit caches (one per
    (dispatch mode, d) — shapes are handled by jax.jit's own cache)
    and, optionally, the mesh the round shards over (see the sharding
    contract in the module docstring)."""

    def __init__(self, cfg: EngineConfig, mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self._impls: Dict[tuple, object] = {}
        self.use_mesh(mesh)

    def use_mesh(self, mesh: Optional[Mesh]) -> None:
        """Install (or clear) the taskvec mesh; resets the jit caches —
        the traced program embeds the shard_map layout."""
        self.mesh = mesh
        self._axes, self._axis_sizes, self.n_shards = _mesh_layout(mesh)
        self._slot_axes = slot_axes(mesh) if mesh is not None else ()
        self.slot_shards = (int(np.prod([mesh.shape[a]
                                         for a in self._slot_axes]))
                            if self._slot_axes else 1)
        self._impls.clear()

    def _impl(self, mode: str, d: int):
        fn = self._impls.get((mode, d))
        if fn is None:
            import repro.core.engine as _mod
            fn = jax.jit(functools.partial(
                _mod._round_impl, cfg=self.cfg, mode=mode, d=d,
                mesh=self.mesh, axes=self._axes,
                axis_sizes=self._axis_sizes))
            self._impls[(mode, d)] = fn
        return fn

    def run_packed(self, packed: PackedRound, *,
                   mode: Optional[str] = None) -> EngineOutput:
        mode = mode or ops.resolve_mode()
        d_pad = pad_d_for_shards(packed.d, self.n_shards)
        if packed.padded_d != d_pad:
            raise ValueError(
                f"run_packed: batch padded to d={packed.padded_d} but the "
                f"engine's mesh shards {self.n_shards} ways (wants {d_pad}) "
                f"— pack with the same mesh the engine holds")
        args = (packed.unified, packed.slot_masks, packed.slot_lams,
                packed.slot_sizes, packed.slot_valid, packed.slot_tasks)
        if packed.slot_weights is not None:
            # the weighted trace is a separate jit entry (extra operand)
            # — the synchronous program is never re-traced or perturbed
            args += (packed.slot_weights,)
        out = self._impl(mode, packed.d)(*args)
        if d_pad != packed.d:
            out = _slice_outputs(out, packed.d, packed.packed)
        if packed.packed:
            (tv, tau, a_num, n_held, sim, du, dm, dl) = out
            return EngineOutput(tv, tau, sim, du, dm, dl,
                                alpha_num=a_num, n_held=n_held,
                                rho=self.cfg.rho)
        (tv, tau, m_hats, sim, du, dm, dl) = out
        return EngineOutput(tv, tau, sim, du, dm, dl,
                            rho=self.cfg.rho, m_hats_dense=m_hats)

    def downlinks(self, packed: PackedRound, out: EngineOutput, *,
                  code_masks: bool = False,
                  phase_us: Optional[Dict[str, float]] = None
                  ) -> Dict[int, ClientDownlink]:
        """Slice the batched downlink tensors back to ragged per-client
        ClientDownlinks (views, no compute).  Mask rows stay in the
        packed wire format; clients unpack on use (``modulate``).

        With ``code_masks`` every client's mask rows are entropy-coded
        at this host edge in ONE batched ``encode_mask_rows_with_sizes``
        call (the Golomb-Rice wire layer, ``repro.fed.compression``) and
        the concatenated stream is split back into per-client streams by
        the per-row record sizes — records self-delimit, so each slice
        is byte-identical to encoding that client alone.  Clients decode
        on use (``ClientDownlink.mask_row``) and downlink bits are
        measured off the actual stream.  ``phase_us`` accumulates the
        ``encode`` host microseconds."""
        return _assemble_downlinks(packed.client_ids, packed.task_ids,
                                   packed.d, out.down_unified,
                                   out.down_masks, out.down_lams,
                                   code_masks=code_masks,
                                   phase_us=phase_us)

    def round(self, uploads: Sequence[ClientUpload], *,
              mode: Optional[str] = None, packed: bool = True,
              code_masks: bool = False,
              staleness: Optional[Sequence[int]] = None,
              staleness_discount: float = STALENESS_DISCOUNT
              ) -> Tuple[Dict[int, ClientDownlink], EngineOutput]:
        """Pack → run → unpack: the drop-in replacement for the legacy
        per-task Python loop in ``MaTUServer.round``.  ``packed=False``
        runs the bool/fp32 A/B layout; ``code_masks=True`` emits
        entropy-coded downlink masks (coded uploads are accepted and
        decoded by ``pack_uploads`` regardless of this flag).

        ``staleness`` (one int per upload, async buffered rounds)
        attaches the per-slot discount ``staleness_discount**s`` to the
        round — see "Async & fault model" in the module docstring."""
        batch = pack_uploads(uploads, self.cfg.n_tasks, packed=packed,
                             mesh=self.mesh)
        if staleness is not None:
            n_max, k_max = batch.slot_valid.shape
            w = np.ones((n_max, k_max), np.float32)
            w[:len(uploads)] = (np.float32(staleness_discount)
                                ** np.asarray(staleness,
                                              np.float32))[:, None]
            if self.n_shards > 1:
                batch.slot_weights = jax.device_put(
                    w, NamedSharding(self.mesh, P()))
            else:
                batch.slot_weights = jnp.asarray(w)
        out = self.run_packed(batch, mode=mode)
        return self.downlinks(batch, out, code_masks=code_masks), out

    def _chunk_impls(self, mode: str, d: int, packed: bool,
                     n_for_dtype: int):
        """Jitted (scalars, merge, finish, downlink) chunk steps, cached
        like ``_impl`` — one static signature reused across every chunk
        of every round with this (mode, layout, d).  The big carried
        accumulators are donated so the fold updates in place."""
        key = ("chunked", mode, d, packed, n_for_dtype)
        fns = self._impls.get(key)
        if fns is None:
            import repro.core.engine as _mod
            common = dict(mesh=self.mesh, axes=self._axes,
                          axis_sizes=self._axis_sizes)
            scal = jax.jit(
                functools.partial(_mod._chunk_scalars_impl, mode=mode),
                donate_argnums=(3, 4))
            merge = jax.jit(
                functools.partial(_mod._merge_chunk_impl, mode=mode, d=d,
                                  **common),
                donate_argnums=(7, 8))
            # finish is NOT donated: its (T, dp) outputs have different
            # shapes/dtypes from the accumulators, so donation would
            # only raise "unusable donated buffer" noise
            finish = jax.jit(
                functools.partial(_mod._finish_impl, cfg=self.cfg,
                                  mode=mode, d=d, packed=packed,
                                  n_for_dtype=n_for_dtype, **common))
            down = jax.jit(
                functools.partial(_mod._downlink_chunk_impl, cfg=self.cfg,
                                  mode=mode, d=d, packed=packed,
                                  row_axes=self._slot_axes, **common))
            fns = (scal, merge, finish, down)
            self._impls[key] = fns
        return fns

    def round_chunked(self, uploads, *, chunk_clients: int,
                      mode: Optional[str] = None, packed: bool = True,
                      code_masks: bool = False,
                      staleness: Optional[Sequence[int]] = None,
                      staleness_discount: float = STALENESS_DISCOUNT,
                      k_max: Optional[int] = None,
                      sink: Optional[Callable[
                          [Dict[int, ClientDownlink]], None]] = None,
                      phase_us: Optional[Dict[str, float]] = None
                      ) -> Tuple[Dict[int, ClientDownlink], EngineOutput,
                                 Dict[str, int]]:
        """Run one round by streaming uploads through a fixed-shape
        chunk buffer of ``chunk_clients`` clients — memory is
        O(chunk + T·d), independent of N, and the result is
        BIT-identical to ``round`` in ref mode (see "Population-scale
        contract" in the module docstring).

        ``uploads`` is a sequence of ClientUploads or a zero-arg
        callable returning a fresh iterator over them — the engine
        makes two passes (the Eq. 4 γ normaliser needs global size
        totals before any merge work), and a callable lets the
        population simulator re-derive sampled clients on demand
        instead of materialising the round.

        ``sink`` (optional) receives each phase-C chunk's
        ``{client_id: ClientDownlink}`` dict as it is produced; with a
        sink the returned downlink dict is empty, so no per-client
        state accumulates.  The returned ``EngineOutput`` carries the
        global results (task_vectors / tau_hats / similarity / m̂) with
        the downlink fields None — per-client downlinks only exist
        chunk-at-a-time.  The stats dict reports the measured
        ``uplink_bits`` / ``downlink_bits`` (identical to the
        monolithic round's accounting), ``n_clients`` and ``n_chunks``.
        """
        mode = mode or ops.resolve_mode()
        C = int(chunk_clients)
        if C < 1:
            raise ValueError(f"round_chunked: chunk_clients={C} < 1")
        make_iter = (uploads if callable(uploads)
                     else (lambda: iter(uploads)))

        # -- pass 0: chunk metadata (client ids / task ids / sizes only —
        # O(N·k) host scalars, no d-axis tensor touched)
        metas: List[tuple] = []
        cur_ids: List[int] = []
        cur_tasks: List[List[int]] = []
        cur_sizes: List[np.ndarray] = []
        cur_stal: List[float] = []
        stal_it = iter(staleness) if staleness is not None else None
        d = None
        k_seen, n_clients = 1, 0

        def _flush():
            metas.append((list(cur_ids), list(cur_tasks), list(cur_sizes),
                          list(cur_stal) if stal_it is not None else None))
            cur_ids.clear(), cur_tasks.clear()
            cur_sizes.clear(), cur_stal.clear()

        for up in make_iter():
            if d is None:
                d = int(up.unified.shape[0])
            tids = list(up.task_ids)
            k_seen = max(k_seen, len(tids))
            cur_ids.append(up.client_id)
            cur_tasks.append(tids)
            cur_sizes.append(np.asarray(up.data_sizes, np.float32))
            if stal_it is not None:
                cur_stal.append(next(stal_it))
            n_clients += 1
            if len(cur_ids) == C:
                _flush()
        if cur_ids:
            _flush()
        if n_clients == 0:
            raise ValueError("round_chunked: empty round (no uploads) — "
                             "sample at least one client or skip the round")
        if k_max is None:
            k_max = _round_up_pow2(k_seen)
        elif k_max < k_seen:
            raise ValueError(f"round_chunked: k_max={k_max} < max client "
                             f"task count {k_seen}")
        # pow2 chunk rows, ≥ the slot-shard count so phase-C row
        # sharding always divides evenly
        c_pad = max(_round_up_pow2(C), self.slot_shards)
        n_seg = self.cfg.n_tasks + 1
        d_pad = pad_d_for_shards(d, self.n_shards)
        # accumulator width: the sharded padding, or the monolithic
        # round's own CHUNK_D streaming-grid padding — identical grids
        # are what make chunked ≡ monolithic bitwise
        dp = d_pad if self.n_shards > 1 else _chunked(d, CHUNK_D)[1]
        # same α-numerator dtype decision as the monolithic round
        # (keyed on its default n_max = next pow2 ≥ N)
        n_for_dtype = _round_up_pow2(n_clients)
        scal, merge, finish, down = self._chunk_impls(
            mode, d, packed, n_for_dtype if packed else 0)

        def _scalar_chunk(ids_, tasks_, sizes_, stal_):
            sz = np.zeros((c_pad, k_max), np.float32)
            tk = np.full((c_pad, k_max), self.cfg.n_tasks, np.int32)
            vd = np.zeros((c_pad, k_max), bool)
            for i, (tl, sl) in enumerate(zip(tasks_, sizes_)):
                k = len(tl)
                sz[i, :k] = sl
                tk[i, :k] = tl
                vd[i, :k] = True
            w = None
            if stal_ is not None:
                w = np.ones((c_pad, k_max), np.float32)
                w[:len(ids_)] = (np.float32(staleness_discount)
                                 ** np.asarray(stal_,
                                               np.float32))[:, None]
                w = jnp.asarray(w)
            return jnp.asarray(sz), jnp.asarray(tk), jnp.asarray(vd), w

        # -- phase A: fold the (T+1,) size totals / membership counts
        totals = jnp.zeros((n_seg,), jnp.float32)
        nt_acc = jnp.zeros((n_seg,), jnp.float32)
        w_chunks: List[Optional[jax.Array]] = []
        for ids_, tasks_, sizes_, stal_ in metas:
            sz, tk, vd, w = _scalar_chunk(ids_, tasks_, sizes_, stal_)
            w_chunks.append(w)
            args = (sz, vd, tk, totals, nt_acc)
            totals, nt_acc = scal(*args, w) if w is not None else scal(*args)

        # -- phase B: second pass over the stream, fold merge partials
        a_acc = jnp.zeros((n_seg, dp),
                          jnp.int32 if packed else jnp.float32)
        tau_acc = jnp.zeros((n_seg, dp), jnp.float32)
        stage = SlotStage()
        stream = make_iter()
        uplink_bits = 0
        for ci, (ids_, tasks_, sizes_, stal_) in enumerate(metas):
            ups = list(itertools.islice(stream, len(ids_)))
            if [u.client_id for u in ups] != ids_:
                raise ValueError(
                    "round_chunked: the upload factory returned a "
                    "different round on the second pass — it must be "
                    "deterministic (same clients, same order)")
            batch = pack_uploads(ups, self.cfg.n_tasks, n_max=c_pad,
                                 k_max=k_max, packed=packed, mesh=self.mesh,
                                 stage=stage, phase_us=phase_us)
            uplink_bits += batch.wire_bits()
            args = (batch.unified, batch.slot_masks, batch.slot_lams,
                    batch.slot_sizes, batch.slot_valid, batch.slot_tasks,
                    totals, a_acc, tau_acc)
            if w_chunks[ci] is not None:
                args += (w_chunks[ci],)
            a_acc, tau_acc = merge(*args)
            # the dispatched step may alias the staged host buffers
            # zero-copy (CPU jnp.asarray) — block before the refill
            jax.block_until_ready(tau_acc)

        # -- finish: Eq. 3/5/6/7 + λ numerator from the accumulators
        tv, tau_hats, third, n_t, sim, num_t = finish(a_acc, tau_acc, nt_acc)
        tv_run = tv                    # keeps the shard padding for phase C
        if self.n_shards > 1 and d_pad != d:
            tv, tau_hats, third = tv[:, :d], tau_hats[:, :d], third[:, :d]
        if packed:
            out = EngineOutput(tv, tau_hats, sim, None, None, None,
                               alpha_num=third, n_held=n_t,
                               rho=self.cfg.rho)
        else:
            out = EngineOutput(tv, tau_hats, sim, None, None, None,
                               rho=self.cfg.rho, m_hats_dense=third)

        # -- phase C: per-chunk downlink re-unification, streamed out
        dw = bitpack.packed_width(d)
        downlinks: Dict[int, ClientDownlink] = {}
        downlink_bits = 0
        for ids_, tasks_, sizes_, stal_ in metas:
            tk = np.full((c_pad, k_max), self.cfg.n_tasks, np.int32)
            vd = np.zeros((c_pad, k_max), bool)
            for i, tl in enumerate(tasks_):
                tk[i, :len(tl)] = tl
                vd[i, :len(tl)] = True
            du, dm, dl = down(tv_run, jnp.asarray(vd), jnp.asarray(tk),
                              num_t)
            if self.n_shards > 1 and d_pad != d:
                du = du[:, :d]
                dm = dm[:, :, :dw] if packed else dm[:, :, :d]
            links = _assemble_downlinks(ids_, tasks_, d, du, dm, dl,
                                        code_masks=code_masks,
                                        phase_us=phase_us)
            downlink_bits += sum(link.downlink_bits()
                                 for link in links.values())
            if sink is not None:
                sink(links)
            else:
                downlinks.update(links)

        stats = {"uplink_bits": uplink_bits,
                 "downlink_bits": downlink_bits,
                 "n_clients": n_clients, "n_chunks": len(metas),
                 "chunk_clients": C}
        return downlinks, out, stats

    def round_stream(self, rounds, *, mode: Optional[str] = None,
                     packed: bool = True, code_masks: bool = False,
                     pipeline: bool = True):
        """Run an iterable of upload rounds through the two-deep host
        pipeline (see "Host pipeline" in the module docstring): while
        the device executes round r, the host drains round r−1 (block
        → batched downlink encode → yield) and packs/decodes round
        r+1's uploads into the alternate :class:`SlotStage`.

        Yields ``(downlinks, out, phase_us)`` per round, in input
        order; ``phase_us`` maps ``pack`` / ``decode`` / ``encode`` /
        ``device`` to host microseconds (``device`` is dispatch→ready
        wall — under the pipeline it overlaps its neighbours' host
        phases).  ``pipeline=False`` is the strictly-sequential escape
        hatch, bit-identical by construction.  Rounds are pulled one
        ahead of yields, so the iterable must not depend on the
        previous round's downlinks (replay/bench traffic)."""
        if not pipeline:
            for ups in rounds:
                phase: Dict[str, float] = {}
                batch = pack_uploads(ups, self.cfg.n_tasks, packed=packed,
                                     mesh=self.mesh, phase_us=phase)
                t0 = time.perf_counter()
                out = self.run_packed(batch, mode=mode)
                jax.block_until_ready(out)
                phase["device"] = (time.perf_counter() - t0) * 1e6
                yield (self.downlinks(batch, out, code_masks=code_masks,
                                      phase_us=phase), out, phase)
            return

        stages = (SlotStage(), SlotStage())
        prev = None
        for r, ups in enumerate(rounds):
            phase: Dict[str, float] = {}
            # host pack/decode of round r overlaps round r−1's device
            # step; stage r%2 was last consumed by round r−2, which was
            # drained (blocked) before this point — never in flight
            batch = pack_uploads(ups, self.cfg.n_tasks, packed=packed,
                                 mesh=self.mesh, stage=stages[r % 2],
                                 phase_us=phase)
            out = self.run_packed(batch, mode=mode)      # async dispatch
            pend = (batch, out, phase, time.perf_counter())
            if prev is not None:
                yield self._drain_round(prev, code_masks)
            prev = pend
        if prev is not None:
            yield self._drain_round(prev, code_masks)

    def _drain_round(self, pend, code_masks: bool):
        """Block on a dispatched round and materialise its downlinks —
        the host-side half the pipeline overlaps with the NEXT round's
        device step."""
        batch, out, phase, t_disp = pend
        jax.block_until_ready(out)
        phase["device"] = (time.perf_counter() - t_disp) * 1e6
        return (self.downlinks(batch, out, code_masks=code_masks,
                               phase_us=phase), out, phase)


def _slice_outputs(out: tuple, d: int, packed: bool) -> tuple:
    """Slice a sharded round's padded d-axis outputs back to the true
    feature count (mask words to ceil(d/32) — padded coords carry zero
    bits, so the wire tail-bit convention holds).  Dispatched outside
    the round jit, on the already-sharded device buffers."""
    dw = bitpack.packed_width(d)
    if packed:
        (tv, tau, a_num, n_held, sim, du, dm, dl) = out
        return (tv[:, :d], tau[:, :d], a_num[:, :d], n_held, sim,
                du[:, :d], dm[:, :, :dw], dl)
    (tv, tau, m_hats, sim, du, dm, dl) = out
    return (tv[:, :d], tau[:, :d], m_hats[:, :d], sim,
            du[:, :d], dm[:, :, :d], dl)


# -- batched client-side unification ----------------------------------------

@functools.lru_cache(maxsize=None)
def _client_unify_jit(mode: str, packed: bool):
    fn = ops.fused_unify_packed if packed else ops.fused_unify
    return jax.jit(functools.partial(fn, mode=mode))


@functools.lru_cache(maxsize=None)
def _client_unify_sharded_jit(mode: str, packed: bool, mesh: Mesh,
                              eps: float = 1e-12):
    """shard_map'd fused unify: per-shard kernels on the local d-slice,
    one psum for the λ num/den partial sums (λ matches the unsharded
    call to fp32 accumulation tolerance; masks / bf16 vectors are
    per-coordinate and bit-identical)."""
    axes, _, _ = _mesh_layout(mesh)
    ax = axes[0] if len(axes) == 1 else axes
    s2, s3, rep = P(None, ax), P(None, None, ax), P()

    def body(tv, valid):
        uni, masks, num, den = ops.fused_unify_raw(tv, valid, packed=packed,
                                                   mode=mode)
        num, den = jax.lax.psum((num, den), axes)
        return uni, masks, num / jnp.maximum(den, eps)

    return jax.jit(shard_map(body, mesh=mesh, in_specs=(s3, rep),
                             out_specs=(s2, s3, rep), check_rep=False))


def batched_client_unify(task_vectors: jax.Array, valid: jax.Array, *,
                         mode: Optional[str] = None, packed: bool = True,
                         mesh: Optional[Mesh] = None):
    """All clients' upload construction in one fused call.

    task_vectors (N, k_max, d) zero-padded stacks; valid (N, k_max).
    By default emits the uplink wire format:
    (unified (N, d) **bf16**, mask_words (N, k_max, ceil(d/32))
    **uint32**, lams (N, k_max) fp32) — row n equals
    ``unify_with_modulators(task_vectors[n, valid[n]])`` with the
    unified vector rounded to bf16 *after* the masks/λ were derived
    from it in fp32.  ``packed=False`` returns the legacy
    (fp32, bool, fp32) triple.

    With ``mesh``, d is zero-padded to ``pad_d_for_shards`` and the
    call runs under ``shard_map``; the returned d-axis tensors keep the
    padded width and the taskvec sharding — exactly what
    ``pack_from_slots(..., d=true_d, mesh=mesh)`` expects.
    """
    mode = mode or ops.resolve_mode()
    _, _, n_shards = _mesh_layout(mesh)
    if n_shards == 1:
        return _client_unify_jit(mode, packed)(task_vectors, valid)
    d = int(task_vectors.shape[-1])
    d_pad = pad_d_for_shards(d, n_shards)
    if d_pad != d:
        task_vectors = jnp.pad(task_vectors,
                               ((0, 0), (0, 0), (0, d_pad - d)))
    task_vectors = jax.device_put(task_vectors, taskvec_sharding(mesh, 3))
    valid = jax.device_put(valid, NamedSharding(mesh, P()))
    return _client_unify_sharded_jit(mode, packed, mesh)(task_vectors, valid)
