"""MaTU stateless server (paper §3.2 "Many-tasks Aggregation").

The server keeps NO client state across rounds — it consumes the
round's uploads, runs Eq. 3–6 per task, and emits per-client downlinks
(unified vector + modulators for that client's tasks).  Task identity
(the |T|-sized registry) is the only global it needs.

This Python-level implementation stacks only the members of each task
(memory-lean for the fed simulator).  The dense, fully-vmapped variant
used for the on-mesh lowering is :func:`repro.core.aggregation.matu_round`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.aggregation import (EPS_DEFAULT, KAPPA_DEFAULT, RHO_DEFAULT,
                                    combine_round, cross_task_aggregate,
                                    sign_similarity, task_aggregate,
                                    topk_similar)
from repro.core.client import ClientDownlink, ClientUpload
from repro.core.unify import unify_with_modulators


@dataclass
class MaTUServerConfig:
    n_tasks: int
    rho: float = RHO_DEFAULT
    eps: float = EPS_DEFAULT
    kappa: int = KAPPA_DEFAULT
    cross_task: bool = True
    uniform_cross: bool = False


class MaTUServer:
    def __init__(self, cfg: MaTUServerConfig):
        self.cfg = cfg
        self.last_similarity: Optional[jax.Array] = None
        self.last_task_vectors: Optional[jax.Array] = None

    def round(self, uploads: List[ClientUpload]) -> Dict[int, ClientDownlink]:
        cfg = self.cfg
        d = int(uploads[0].unified.shape[0])

        # ---- Eq. 3 + 4 per task, stacking only members -------------------
        tau_hats = jnp.zeros((cfg.n_tasks, d), jnp.float32)
        m_hats = jnp.ones((cfg.n_tasks, d), jnp.float32)
        held = [False] * cfg.n_tasks
        for t in range(cfg.n_tasks):
            rows, row_masks, row_lams, row_sizes = [], [], [], []
            for up in uploads:
                if t in up.task_ids:
                    i = up.task_ids.index(t)
                    rows.append(up.unified)
                    row_masks.append(up.masks[i])
                    row_lams.append(up.lams[i])
                    row_sizes.append(float(up.data_sizes[i]))
            if not rows:
                continue
            held[t] = True
            unified = jnp.stack(rows)
            masks = jnp.stack(row_masks)
            lams = jnp.asarray(row_lams, jnp.float32)
            sizes = jnp.asarray(row_sizes, jnp.float32)
            member = jnp.ones((len(rows),), bool)
            th, mh = task_aggregate(unified, masks, lams, member, sizes, cfg.rho)
            tau_hats = tau_hats.at[t].set(th)
            m_hats = m_hats.at[t].set(mh)

        # ---- Eq. 5 + 6 across tasks --------------------------------------
        sim = sign_similarity(tau_hats)
        held_arr = jnp.asarray(held)
        # never transfer from/to tasks nobody held this round
        sim = sim * held_arr[None, :] * held_arr[:, None]
        if not cfg.cross_task:
            weights = jnp.zeros_like(sim)
        elif cfg.uniform_cross:
            t = sim.shape[0]
            weights = ((1.0 - jnp.eye(t)) * held_arr[None, :] * held_arr[:, None])
            weights = weights / jnp.maximum(jnp.sum(weights, 1, keepdims=True), 1.0)
        else:
            weights = topk_similar(sim, cfg.eps, cfg.kappa)
        tau_tildes = cross_task_aggregate(tau_hats, m_hats, weights)
        task_vectors = combine_round(tau_hats, tau_tildes, weights)

        self.last_similarity = sim
        self.last_task_vectors = task_vectors

        # ---- per-client re-unification + downlink ------------------------
        out: Dict[int, ClientDownlink] = {}
        for up in uploads:
            tvs = jnp.stack([task_vectors[t] for t in up.task_ids])
            unified, masks, lams = unify_with_modulators(tvs)
            out[up.client_id] = ClientDownlink(unified, masks, lams)
        return out
