"""MaTU stateless server (paper §3.2 "Many-tasks Aggregation").

The server keeps NO client state across rounds — it consumes the
round's uploads, runs Eq. 3–7, and emits per-client downlinks (unified
vector + modulators for that client's tasks).  Task identity (the
|T|-sized registry) is the only global it needs.

Since the round-engine refactor, ``round`` is a thin wrapper over
:class:`repro.core.engine.RoundEngine`: uploads are packed into padded
batch tensors and the whole Eq. 3–7 pipeline runs as one jitted,
kernel-dispatched call (see engine module docstring for the padding
contract).  The original per-task Python loop is preserved verbatim as
``round_legacy`` — it is the behavioural oracle for the engine's
parity tests and the baseline of ``benchmarks/bench_round_engine.py``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.aggregation import (EPS_DEFAULT, KAPPA_DEFAULT, RHO_DEFAULT,
                                    combine_round, cross_task_aggregate,
                                    sign_similarity, task_aggregate,
                                    topk_similar)
from repro.core.client import ClientDownlink, ClientUpload
from repro.core.engine import EngineConfig, EngineOutput, PackedRound, RoundEngine
from repro.core.unify import unify_with_modulators


@dataclass
class MaTUServerConfig:
    n_tasks: int
    rho: float = RHO_DEFAULT
    eps: float = EPS_DEFAULT
    kappa: int = KAPPA_DEFAULT
    cross_task: bool = True
    uniform_cross: bool = False


class MaTUServer:
    def __init__(self, cfg: MaTUServerConfig, mesh=None):
        """``mesh``: optional jax Mesh — the round then runs sharded
        over the taskvec axis (see the engine's sharding contract);
        None keeps the single-device path byte-for-byte."""
        self.cfg = cfg
        self.engine = RoundEngine(EngineConfig(
            n_tasks=cfg.n_tasks, rho=cfg.rho, eps=cfg.eps, kappa=cfg.kappa,
            cross_task=cfg.cross_task, uniform_cross=cfg.uniform_cross),
            mesh=mesh)
        self.last_similarity: Optional[jax.Array] = None
        self.last_task_vectors: Optional[jax.Array] = None

    def use_mesh(self, mesh) -> None:
        """Install (or clear) the taskvec mesh on the round engine."""
        self.engine.use_mesh(mesh)

    def round(self, uploads: List[ClientUpload], *,
              code_masks: bool = False,
              staleness: Optional[List[int]] = None
              ) -> Dict[int, ClientDownlink]:
        """One server step through the batched round engine.
        ``code_masks`` emits entropy-coded downlink mask streams
        (coded uploads are decoded at pack time either way).

        ``staleness`` (async buffered rounds: one int per upload, the
        rounds elapsed since the upload was dispatched) folds late
        uploads with the staleness-discounted λ — see "Async & fault
        model" in the engine module docstring.  None (every synchronous
        caller) keeps the sync jit programs byte-for-byte."""
        downs, out = self.engine.round(uploads, code_masks=code_masks,
                                       staleness=staleness)
        self._record(out)
        return downs

    def round_chunked(self, uploads, *, chunk_clients: int,
                      code_masks: bool = False,
                      staleness: Optional[List[int]] = None,
                      k_max: Optional[int] = None,
                      sink=None) -> Tuple[Dict[int, ClientDownlink],
                                          Dict[str, int]]:
        """Population-scale server step: stream ``uploads`` (a sequence
        or a zero-arg iterator factory) through the engine's fixed-shape
        chunk buffer — memory O(chunk + T·d) independent of the round's
        client count, bit-identical to :meth:`round` in ref mode (the
        engine's "Population-scale contract").  ``sink``, when given,
        receives each chunk's downlink dict as produced and the
        returned dict stays empty (no per-client state accumulates).
        Returns ``(downlinks, stats)`` with the measured wire-bit
        accounting in ``stats``."""
        downs, out, stats = self.engine.round_chunked(
            uploads, chunk_clients=chunk_clients, code_masks=code_masks,
            staleness=staleness, k_max=k_max, sink=sink)
        self._record(out)
        return downs, stats

    def round_packed(self, packed: PackedRound, *,
                     code_masks: bool = False) -> Dict[int, ClientDownlink]:
        """Server step over an already-packed batch (the strategy's
        pre-packed upload path — skips ``pack_uploads`` entirely)."""
        out = self.start_round(packed)
        return self.finish_round(packed, out, code_masks=code_masks)

    def start_round(self, packed: PackedRound) -> EngineOutput:
        """Dispatch the jitted round WITHOUT materialising downlinks —
        the overlap half of ``round_packed``.  jax dispatch is async,
        so this returns immediately with in-flight arrays; pair with
        :meth:`finish_round` (the pipelined strategy defers that drain
        so the device step overlaps host bookkeeping)."""
        out = self.engine.run_packed(packed)
        self._record(out)
        return out

    def finish_round(self, packed: PackedRound, out: EngineOutput, *,
                     code_masks: bool = False,
                     phase_us: Optional[Dict[str, float]] = None
                     ) -> Dict[int, ClientDownlink]:
        """Materialise per-client downlinks from a dispatched round
        (blocks on the downlink tensors; batched Golomb-Rice encode
        when ``code_masks``)."""
        return self.engine.downlinks(packed, out, code_masks=code_masks,
                                     phase_us=phase_us)

    def _record(self, out: EngineOutput) -> None:
        self.last_similarity = out.similarity
        self.last_task_vectors = out.task_vectors

    def serving_downlink(self, *, packed: bool = True,
                         code_masks: bool = False,
                         fingerprint: Optional[str] = None
                         ) -> ClientDownlink:
        """Serving handoff: re-unify the LAST round's full task-vector
        set into one all-tasks downlink for a
        :class:`repro.serve.store.ModulatorStore` — row ``t`` of the
        modulators is task id ``t`` (the store keys on position).

        ``packed`` ships the wire layout (bf16 unified + bit-packed
        uint32 mask words); ``code_masks`` entropy-codes the rows into
        a Golomb-Rice byte stream instead.  ``fingerprint`` stamps the
        layout manifest the task vectors were flattened through
        (``TaskVectorSpace.fingerprint``) so the store can verify the
        handoff before serving anything.
        """
        if self.last_task_vectors is None:
            raise ValueError("serving_downlink needs a completed round "
                             "(no task vectors recorded yet)")
        tvs = self.last_task_vectors
        unified, masks, lams = unify_with_modulators(tvs)
        if code_masks:
            import numpy as np
            from repro.fed.compression import encode_mask_rows
            from repro.kernels.bitpack import pack_bits_np
            d = int(unified.shape[0])
            stream = encode_mask_rows(pack_bits_np(np.asarray(masks)), d)
            return ClientDownlink(unified.astype(jnp.bfloat16),
                                  jnp.asarray(stream), lams,
                                  fingerprint=fingerprint)
        if packed:
            from repro.kernels.bitpack import pack_bits
            return ClientDownlink(unified.astype(jnp.bfloat16),
                                  pack_bits(masks), lams,
                                  fingerprint=fingerprint)
        return ClientDownlink(unified, masks, lams, fingerprint=fingerprint)

    # ------------------------------------------------------------------
    # Legacy reference path: the original host-bound per-task loop.
    # Kept as the parity oracle for tests/test_round_engine.py and the
    # baseline for benchmarks/bench_round_engine.py.  Matches the
    # engine to fp tolerance (the engine's unheld-task m̂ differs — 0
    # vs 1 here — which is unobservable in task vectors and downlinks).
    # ------------------------------------------------------------------
    def round_legacy(self, uploads: List[ClientUpload]
                     ) -> Dict[int, ClientDownlink]:
        cfg = self.cfg
        d = int(uploads[0].unified.shape[0])

        # ---- Eq. 3 + 4 per task, stacking only members -------------------
        tau_hats = jnp.zeros((cfg.n_tasks, d), jnp.float32)
        m_hats = jnp.ones((cfg.n_tasks, d), jnp.float32)
        held = [False] * cfg.n_tasks
        for t in range(cfg.n_tasks):
            rows, row_masks, row_lams, row_sizes = [], [], [], []
            for up in uploads:
                if t in up.task_ids:
                    i = up.task_ids.index(t)
                    # accept wire-format uploads too: dense the packed
                    # mask words and upcast a bf16 vector so the oracle
                    # computes in fp32 like always
                    rows.append(jnp.asarray(up.unified, jnp.float32))
                    row_masks.append(up.masks_dense()[i])
                    row_lams.append(up.lams[i])
                    row_sizes.append(float(up.data_sizes[i]))
            if not rows:
                continue
            held[t] = True
            unified = jnp.stack(rows)
            masks = jnp.stack(row_masks)
            lams = jnp.asarray(row_lams, jnp.float32)
            sizes = jnp.asarray(row_sizes, jnp.float32)
            member = jnp.ones((len(rows),), bool)
            th, mh = task_aggregate(unified, masks, lams, member, sizes, cfg.rho)
            tau_hats = tau_hats.at[t].set(th)
            m_hats = m_hats.at[t].set(mh)

        # ---- Eq. 5 + 6 across tasks --------------------------------------
        sim = sign_similarity(tau_hats)
        held_arr = jnp.asarray(held)
        # never transfer from/to tasks nobody held this round
        sim = sim * held_arr[None, :] * held_arr[:, None]
        if not cfg.cross_task:
            weights = jnp.zeros_like(sim)
        elif cfg.uniform_cross:
            t = sim.shape[0]
            weights = ((1.0 - jnp.eye(t)) * held_arr[None, :] * held_arr[:, None])
            weights = weights / jnp.maximum(jnp.sum(weights, 1, keepdims=True), 1.0)
        else:
            weights = topk_similar(sim, cfg.eps, cfg.kappa)
        tau_tildes = cross_task_aggregate(tau_hats, m_hats, weights)
        task_vectors = combine_round(tau_hats, tau_tildes, weights)

        self.last_similarity = sim
        self.last_task_vectors = task_vectors

        # ---- per-client re-unification + downlink ------------------------
        out: Dict[int, ClientDownlink] = {}
        for up in uploads:
            tvs = jnp.stack([task_vectors[t] for t in up.task_ids])
            unified, masks, lams = unify_with_modulators(tvs)
            out[up.client_id] = ClientDownlink(unified, masks, lams)
        return out
