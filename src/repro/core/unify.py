"""Task unification and task-specific modulators (paper §3.1–3.2).

All functions operate on *flat* task vectors — pytrees are flattened
with :func:`repro.common.tree_flatten_vector` so the client and server
agree on the layout of the d-dimensional space.  Everything is
jit-able and shards elementwise over the ``taskvec`` logical axis.

Kernel-accelerated versions (Pallas) live in ``repro.kernels``; these
jnp implementations are the reference semantics and the CPU path.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def unify(task_vectors: jax.Array) -> jax.Array:
    """"Task unification" (Eq. 2, after Huang et al. 2024 EMR-merging).

    task_vectors: (K, d) stacked task vectors.
    Returns the unified vector tau = sigma ⊙ mu where
    sigma = sgn(Σ_k τ_k) and mu_j = max_k |τ_kj| over sign-aligned k.
    """
    sigma = jnp.sign(jnp.sum(task_vectors, axis=0))
    aligned = (task_vectors * sigma[None, :]) > 0
    mu = jnp.max(jnp.abs(task_vectors) * aligned, axis=0)
    return sigma * mu


def task_mask(task_vector: jax.Array, unified: jax.Array) -> jax.Array:
    """Binary modulator mask m^t = (τ^t ⊙ τ > 0) — bool (d,) or (K, d)."""
    return (task_vector * unified) > 0


def task_scaler(task_vector: jax.Array, mask: jax.Array,
                unified: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Rescaler λ^t = Σ|τ^t| / Σ|m^t ⊙ τ| (scalar, or (K,) if batched)."""
    num = jnp.sum(jnp.abs(task_vector), axis=-1)
    den = jnp.sum(jnp.abs(jnp.where(mask, unified, 0.0)), axis=-1)
    return num / jnp.maximum(den, eps)


def modulators(task_vectors: jax.Array, unified: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Masks (K, d) bool and scalers (K,) for stacked task vectors."""
    masks = task_mask(task_vectors, unified[None, :])
    lams = task_scaler(task_vectors, masks, unified[None, :])
    return masks, lams


def modulate(unified: jax.Array, mask: jax.Array, lam: jax.Array) -> jax.Array:
    """Reconstruct a task vector: τ̇^t = λ^t · m^t ⊙ τ (paper §3.2).

    ``mask`` may be dense bool or the bit-packed uint32 wire rows
    (``ceil(d/32)`` words, LSB-first) a :class:`ClientDownlink` now
    carries — packed rows are unpacked here, at the point of use, so
    the downlink itself never holds an 8x-inflated bool tensor.  A bf16
    wire ``unified`` is upcast so the reconstruction runs in fp32.
    """
    if mask.dtype == jnp.uint32:
        from repro.kernels import bitpack
        mask = bitpack.unpack_bits(mask, unified.shape[-1])
    unified = unified.astype(jnp.float32)
    return lam[..., None] * jnp.where(mask, unified, 0.0) if jnp.ndim(lam) \
        else lam * jnp.where(mask, unified, 0.0)


def unify_with_modulators(task_vectors: jax.Array
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Client-side upload construction: (τ_n, masks, λs) from (K, d)."""
    tau = unify(task_vectors)
    masks, lams = modulators(task_vectors, tau)
    return tau, masks, lams


def unify_masked(task_vectors: jax.Array, valid: jax.Array) -> jax.Array:
    """Padding-aware unification: Eq. 2 over the rows where ``valid``.

    task_vectors (K, d); valid (K,) bool.  Invalid rows are zeroed
    before the sign election, which is exactly equivalent to dropping
    them (zeros change neither the sign sum nor the aligned max), so
    ``unify_masked(x, v) == unify(x[v])``.  This is the reference
    semantics of the fused batched kernel
    (:func:`repro.kernels.ops.fused_unify`).
    """
    x = task_vectors * valid.astype(task_vectors.dtype)[:, None]
    sigma = jnp.sign(jnp.sum(x, axis=0))
    aligned = (x * sigma[None, :]) > 0
    mu = jnp.max(jnp.abs(x) * aligned, axis=0)
    return sigma * mu


def unify_with_modulators_masked(task_vectors: jax.Array, valid: jax.Array
                                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Padding-aware ``unify_with_modulators`` for one slot-packed
    client: invalid slots yield all-False mask rows and λ = 0."""
    tau = unify_masked(task_vectors, valid)
    masks = task_mask(task_vectors, tau[None, :]) & valid[:, None]
    num = jnp.sum(jnp.abs(task_vectors * valid.astype(task_vectors.dtype)[:, None]),
                  axis=-1)
    den = jnp.sum(jnp.abs(jnp.where(masks, tau[None, :], 0.0)), axis=-1)
    lams = num / jnp.maximum(den, 1e-12)
    return tau, masks, lams
