"""Dirichlet task/class allocation for federated simulations.

Mirrors the paper's FL settings (§4): task concentration ζ_t and class
concentration ζ_c, both via Dir(α) following Li et al. 2021.  Lower α
→ more heterogeneous clients.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np


@dataclass
class FedSplit:
    # tasks[c] = list of task ids held by client c
    tasks: List[List[int]]
    # class_probs[(c, t)] = per-class sampling distribution for client c, task t
    class_probs: Dict[tuple, np.ndarray]
    # data_sizes[(c, t)] = |D_c^t|
    data_sizes: Dict[tuple, int]


def dirichlet_split(
    *,
    n_clients: int,
    n_tasks: int,
    n_classes: int,
    tasks_per_client: Optional[int] = None,
    zeta_t: float = 0.5,
    zeta_c: float = 0.1,
    base_samples: int = 256,
    seed: int = 0,
) -> FedSplit:
    """Allocate tasks and class distributions to clients.

    ``zeta_t == 0`` reproduces the paper's *single-task, no-overlap*
    setting (each client gets exactly one task, round-robin).  Otherwise
    each client draws ``tasks_per_client`` tasks (default: sampled 1–5)
    from a Dir(ζ_t)-skewed task popularity distribution.
    """
    rng = np.random.default_rng(seed)
    tasks: List[List[int]] = []
    if zeta_t == 0.0:
        for c in range(n_clients):
            tasks.append([c % n_tasks])
    else:
        popularity = rng.dirichlet([zeta_t] * n_tasks)
        for c in range(n_clients):
            k = tasks_per_client or int(rng.integers(1, min(n_tasks, 5) + 1))
            k = min(k, n_tasks)
            chosen = rng.choice(n_tasks, size=k, replace=False,
                                p=popularity / popularity.sum())
            tasks.append(sorted(int(t) for t in chosen))
        # coverage: every task must have at least one holder (as in the
        # paper's benchmarks, where every dataset is evaluated)
        held = {t for ts in tasks for t in ts}
        for t in range(n_tasks):
            if t not in held:
                c = int(rng.integers(0, n_clients))
                tasks[c] = sorted(set(tasks[c]) | {t})

    class_probs, data_sizes = {}, {}
    for c in range(n_clients):
        for t in tasks[c]:
            p = rng.dirichlet([max(zeta_c, 1e-3)] * n_classes)
            class_probs[(c, t)] = p.astype(np.float64) / p.sum()
            data_sizes[(c, t)] = int(base_samples * (0.5 + rng.random()))
    return FedSplit(tasks, class_probs, data_sizes)


# stream tags keeping the lazy population draws independent: every
# derived rng seeds a fresh SeedSequence from (seed, TAG, ...), so the
# per-client assignment, per-(client, task) local stats, and per-round
# sampling streams never interleave — asking for client c's tasks can
# never perturb client c+1's, no matter the order (or how often) the
# questions are asked.
_POP_CLIENT, _POP_LOCAL, _POP_ROUND = 0x11, 0x22, 0x33


@dataclass
class PopulationSplit:
    """Lazy Dirichlet task assignment over an arbitrarily large client
    population (the 10^5–10^6 scale-out setting).

    Holds O(T) state only: the Dir(ζ_t) task-popularity vector, drawn
    once from ``seed``.  Everything per-client is DERIVED on demand
    from an order-invariant rng seeded by ``(seed, tag, client_id)``,
    so a population of N clients costs nothing until a client is
    actually sampled, and the same client id always resolves to the
    same tasks/sizes regardless of when or how often it is asked for
    (the round engine's two-pass streaming contract relies on exactly
    this).  Distributions match :func:`dirichlet_split` — minus the
    coverage fix-up, which is both O(N) and unnecessary at population
    scale, where every task is held w.h.p.
    """
    n_clients: int
    n_tasks: int
    n_classes: int = 10
    tasks_per_client: Optional[int] = None
    zeta_t: float = 0.5
    zeta_c: float = 0.1
    base_samples: int = 256
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.popularity: Optional[np.ndarray] = (
            rng.dirichlet([self.zeta_t] * self.n_tasks)
            if self.zeta_t > 0.0 else None)

    def tasks_for(self, client_id: int) -> List[int]:
        """Client ``client_id``'s task ids (sorted), derived lazily.
        ``zeta_t == 0`` reproduces the single-task round-robin
        setting, like :func:`dirichlet_split`."""
        if self.popularity is None:
            return [int(client_id) % self.n_tasks]
        rng = np.random.default_rng((self.seed, _POP_CLIENT, int(client_id)))
        k = self.tasks_per_client or int(
            rng.integers(1, min(self.n_tasks, 5) + 1))
        k = min(k, self.n_tasks)
        chosen = rng.choice(self.n_tasks, size=k, replace=False,
                            p=self.popularity / self.popularity.sum())
        return sorted(int(t) for t in chosen)

    def local_stats(self, client_id: int, task_id: int
                    ) -> tuple:
        """(class_probs, data_size) for one (client, task) pair —
        same Dir(ζ_c) class skew and size law as the eager split."""
        rng = np.random.default_rng(
            (self.seed, _POP_LOCAL, int(client_id), int(task_id)))
        p = rng.dirichlet([max(self.zeta_c, 1e-3)] * self.n_classes)
        size = int(self.base_samples * (0.5 + rng.random()))
        return p.astype(np.float64) / p.sum(), size

    def data_sizes_for(self, client_id: int) -> List[int]:
        """Data sizes aligned with ``tasks_for(client_id)``."""
        return [self.local_stats(client_id, t)[1]
                for t in self.tasks_for(client_id)]

    def sample_round(self, round_idx: int, n_sampled: int) -> np.ndarray:
        """Deterministic without-replacement client sample for a round
        — O(n_sampled) rejection draws when the sample is a small
        fraction of the population, O(N) permutation otherwise (never
        hit at population scale)."""
        rng = np.random.default_rng((self.seed, _POP_ROUND, int(round_idx)))
        n, k = self.n_clients, min(int(n_sampled), self.n_clients)
        if k * 8 >= n:
            return rng.permutation(n)[:k].astype(np.int64)
        seen: set = set()
        out: List[int] = []
        while len(out) < k:
            for c in rng.integers(0, n, size=k - len(out)):
                c = int(c)
                if c not in seen:
                    seen.add(c)
                    out.append(c)
        return np.asarray(out, np.int64)


def assign_fixed_groups(n_clients: int, task_groups: List[List[int]]) -> FedSplit:
    """Fixed task-group assignment (Fig. 6a conflict experiments):
    client c gets task_groups[c % len(task_groups)] with uniform classes."""
    tasks = [list(task_groups[c % len(task_groups)]) for c in range(n_clients)]
    class_probs, data_sizes = {}, {}
    for c in range(n_clients):
        for t in tasks[c]:
            class_probs[(c, t)] = None  # uniform
            data_sizes[(c, t)] = 256
    return FedSplit(tasks, class_probs, data_sizes)
