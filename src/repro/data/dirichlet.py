"""Dirichlet task/class allocation for federated simulations.

Mirrors the paper's FL settings (§4): task concentration ζ_t and class
concentration ζ_c, both via Dir(α) following Li et al. 2021.  Lower α
→ more heterogeneous clients.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np


@dataclass
class FedSplit:
    # tasks[c] = list of task ids held by client c
    tasks: List[List[int]]
    # class_probs[(c, t)] = per-class sampling distribution for client c, task t
    class_probs: Dict[tuple, np.ndarray]
    # data_sizes[(c, t)] = |D_c^t|
    data_sizes: Dict[tuple, int]


def dirichlet_split(
    *,
    n_clients: int,
    n_tasks: int,
    n_classes: int,
    tasks_per_client: Optional[int] = None,
    zeta_t: float = 0.5,
    zeta_c: float = 0.1,
    base_samples: int = 256,
    seed: int = 0,
) -> FedSplit:
    """Allocate tasks and class distributions to clients.

    ``zeta_t == 0`` reproduces the paper's *single-task, no-overlap*
    setting (each client gets exactly one task, round-robin).  Otherwise
    each client draws ``tasks_per_client`` tasks (default: sampled 1–5)
    from a Dir(ζ_t)-skewed task popularity distribution.
    """
    rng = np.random.default_rng(seed)
    tasks: List[List[int]] = []
    if zeta_t == 0.0:
        for c in range(n_clients):
            tasks.append([c % n_tasks])
    else:
        popularity = rng.dirichlet([zeta_t] * n_tasks)
        for c in range(n_clients):
            k = tasks_per_client or int(rng.integers(1, min(n_tasks, 5) + 1))
            k = min(k, n_tasks)
            chosen = rng.choice(n_tasks, size=k, replace=False,
                                p=popularity / popularity.sum())
            tasks.append(sorted(int(t) for t in chosen))
        # coverage: every task must have at least one holder (as in the
        # paper's benchmarks, where every dataset is evaluated)
        held = {t for ts in tasks for t in ts}
        for t in range(n_tasks):
            if t not in held:
                c = int(rng.integers(0, n_clients))
                tasks[c] = sorted(set(tasks[c]) | {t})

    class_probs, data_sizes = {}, {}
    for c in range(n_clients):
        for t in tasks[c]:
            p = rng.dirichlet([max(zeta_c, 1e-3)] * n_classes)
            class_probs[(c, t)] = p.astype(np.float64) / p.sum()
            data_sizes[(c, t)] = int(base_samples * (0.5 + rng.random()))
    return FedSplit(tasks, class_probs, data_sizes)


def assign_fixed_groups(n_clients: int, task_groups: List[List[int]]) -> FedSplit:
    """Fixed task-group assignment (Fig. 6a conflict experiments):
    client c gets task_groups[c % len(task_groups)] with uniform classes."""
    tasks = [list(task_groups[c % len(task_groups)]) for c in range(n_clients)]
    class_probs, data_sizes = {}, {}
    for c in range(n_clients):
        for t in tasks[c]:
            class_probs[(c, t)] = None  # uniform
            data_sizes[(c, t)] = 256
    return FedSplit(tasks, class_probs, data_sizes)
