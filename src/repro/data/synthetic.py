"""Synthetic many-task constellations with controllable relatedness.

The paper's accuracy experiments use 8/30 vision datasets that cluster
into related groups (Fig. 2).  Offline we build a *synthetic*
constellation with the same structure, designed so that the frozen
backbone + per-task head CANNOT solve a task without LoRA adaptation:

  latent  z ~ N(0, I_F);   label  y = argmax(W_g z)
  input   x = R_t z + ε

Each task t applies its own input rotation R_t; the backbone must learn
(in LoRA space) to undo R_t before the head can read out W_g.  Group
structure:

* tasks within a group share R_g (± small rotation) → their LoRA task
  vectors point the same way in weight space (high sign agreement,
  positive transfer),
* *conflicting* group pairs use R_b = −R_a → sign-flipped first-layer
  adaptations (systematic weight-space sign conflicts, negative
  transfer),

giving a known ground truth for every ordinal claim of the paper
(MaTU > grouping > FedAvg; ≈ individual; conflict robustness;
sign-similarity ≈ oracle relatedness).  See DESIGN.md §3.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class TaskSpec:
    task_id: int
    group: int
    r: np.ndarray               # (F, F) task input rotation
    w: np.ndarray               # (C, F) latent class map (group-level)
    noise: float = 0.05


@dataclass
class Constellation:
    tasks: List[TaskSpec]
    feat_dim: int
    n_classes: int

    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    def group_of(self, t: int) -> int:
        return self.tasks[t].group

    def oracle_similarity(self) -> np.ndarray:
        """Ground-truth task relatedness: cosine similarity of the input
        transforms the backbone must learn to undo."""
        flats = np.stack([t.r.reshape(-1) for t in self.tasks])
        flats = flats / (np.linalg.norm(flats, axis=1, keepdims=True) + 1e-12)
        return flats @ flats.T


def _small_rotation(rng, f: int, angle: float) -> np.ndarray:
    a = rng.standard_normal((f, f))
    skew = (a - a.T) / 2
    # first-order rotation exp(angle*skew) ≈ I + angle*skew (renormalised)
    m = np.eye(f) + angle * skew
    q, _ = np.linalg.qr(m)
    return q


def make_constellation(
    *,
    n_tasks: int,
    n_groups: int,
    feat_dim: int = 32,
    n_classes: int = 8,
    within_group_angle: float = 0.05,
    conflict_pairs: Optional[List[Tuple[int, int]]] = None,
    noise: float = 0.05,
    seed: int = 0,
) -> Constellation:
    """Build ``n_tasks`` tasks in ``n_groups`` groups (round-robin).

    ``conflict_pairs`` lists (a, b) group pairs with R_b = −R_a
    (maximal weight-space sign conflict); unlisted pairs get
    independent random rotations (neutral relatedness).
    """
    rng = np.random.default_rng(seed)

    group_r, group_w = [], []
    for _g in range(n_groups):
        q, _ = np.linalg.qr(rng.standard_normal((feat_dim, feat_dim)))
        group_r.append(q)
        group_w.append(rng.standard_normal((n_classes, feat_dim)))
    if conflict_pairs:
        for (a, b) in conflict_pairs:
            group_r[b] = -group_r[a]  # sign-flipped input transform

    tasks = []
    for t in range(n_tasks):
        g = t % n_groups
        r = group_r[g] @ _small_rotation(rng, feat_dim, within_group_angle)
        w = group_w[g] + 0.1 * rng.standard_normal((n_classes, feat_dim))
        tasks.append(TaskSpec(t, g, r.astype(np.float32), w.astype(np.float32), noise))
    return Constellation(tasks, feat_dim, n_classes)


def sample_task_batch(task: TaskSpec, key: jax.Array, n: int,
                      class_probs: Optional[np.ndarray] = None):
    """Draw n (x, y): z latent-normal (optionally class-skewed via
    rejection-free prototype shifting), y = argmax(W z), x = R z + ε."""
    k1, k2, k3 = jax.random.split(key, 3)
    f = task.r.shape[0]
    z = jax.random.normal(k1, (n, f))
    if class_probs is not None:
        # non-IID classes: shift latents toward sampled class prototypes
        w = jnp.asarray(task.w)
        cls = jax.random.choice(k2, task.w.shape[0], (n,), p=jnp.asarray(class_probs))
        protos = w[cls] / (jnp.linalg.norm(w[cls], axis=-1, keepdims=True) + 1e-9)
        z = z + 1.5 * protos
    y = jnp.argmax(z @ jnp.asarray(task.w.T), axis=-1)
    x = z @ jnp.asarray(task.r.T) + task.noise * jax.random.normal(k3, (n, f))
    return x.astype(jnp.float32), y


def eval_batch(task: TaskSpec, seed: int = 1234, n: int = 512):
    """Deterministic held-out test set for a task (IID classes)."""
    return sample_task_batch(task, jax.random.PRNGKey(seed + 7919 * task.task_id), n)
