"""Entropy-coded mask transport for MaTU (EXPERIMENTS.md §Perf-comm).

The paper transmits, per client per round, one unified vector + per
task a dense binary mask + a scalar (Sec. 5.3).  Since the wire-format
engine refactor every MaTU round actually ships bf16 unified vectors
and bit-packed uint32 mask words (1 bit/coord; see the
``repro.core.engine`` wire-format contract) — this module is the layer
BELOW that: an actual, invertible entropy coder over the packed words,
so the biased modulator masks (P(1) ≈ 0.75 on a client's own tasks —
the regime DeltaMask, Tsouvalas et al. 2023, targets) go out well
under 1 bit/coord.

Coder: vectorized Golomb-Rice over the gaps between the rarer symbol's
positions, with a self-describing 5-byte header, so decode needs only
``d`` and the byte stream.  Stream layout (everything little-endian,
bit streams LSB-first — the same bit convention as
``repro.kernels.bitpack``):

  byte 0    bit 0: polarity  (1 → coded positions are the SET bits,
                              0 → coded positions are the CLEAR bits)
            bit 1: raw escape (1 → payload is the packed words
                              verbatim, 4·ceil(d/32) bytes; the coder
                              only emits this when the Rice payload
                              would be larger, so coded ≤ raw + header
                              at ANY density)
            bits 3-7: Rice parameter k ∈ [0, 31]
  bytes 1-4 uint32 run count n (number of coded positions)
  payload   unary section: for each of the n gaps, ``gap >> k`` zero
            bits then a one bit; THEN the remainder section: n·k bits,
            the low k bits of each gap, LSB-first per symbol.  Padded
            with zero bits to a byte boundary.

Splitting unary and remainder bits into two sections (rather than
interleaving per symbol) keeps decode fully vectorized: the first n
one-bits of the payload are exactly the n unary terminators, so one
``np.flatnonzero`` recovers every quotient and one reshape every
remainder — no sequential bit walk.  The split is size-neutral.

Round-trip is bit-exact for any density — all-zero and all-one masks
are 5-byte streams (n = 0), single-bit masks cost one gap — and is
enforced by property tests over adversarial densities
(tests/test_compression.py).

Batched stream layout (the hot path)
------------------------------------
:func:`encode_mask_rows` / :func:`decode_mask_rows` process ALL
(client × slot) mask rows in one vectorized numpy pass — the stream
they produce/consume is **byte-identical** to concatenating the scalar
:func:`rice_encode_words` records row by row (the scalar coder is
retained as the parity oracle; see ``*_reference``).  How:

* one ``unpack_bits`` of the whole row stack + one ``flatnonzero``
  gives every row's coded positions; gaps fall out of a single
  shifted-difference (rows are delimited by a row-id change, so no
  per-row loop);
* the Rice parameter search is vectorized over rows
  (:func:`_rice_k_rows`): the 7-candidate window around
  ``floor(log2(mean gap))`` is evaluated with segment-sums
  (``np.add.reduceat``) and an ``argmin`` whose first-minimum
  tie-breaking matches the scalar coder's ascending-k scan exactly;
* every record's byte extent is known once (q, k) are — a prefix sum
  over record sizes places each row's header/payload, and ALL rows'
  unary terminators + remainder bits are written into one
  preallocated bit-space with a single scatter + ``np.packbits``
  (``bitpack.scatter_bits_np``); headers and raw-escape payloads are
  byte-aligned fancy-index writes into the same buffer.
* decode mirrors it: one ``unpackbits`` + one ``flatnonzero`` over the
  whole stream; a light O(rows) boundary walk (each record's length
  needs its unary span — one ``searchsorted`` into the global one-bit
  positions) collects record metadata, then gaps/remainders/positions
  for every Rice record reconstruct in one vectorized pass.

Both directions stream in bounded chunks (``_ENC_CHUNK_BITS``,
``_DEC_WINDOW_BYTES`` / ``_DEC_DENSE_BITS``): record extents are
global, only the bit scatter/gather is windowed, so chunking is
byte-invisible (tests monkeypatch tiny chunks to prove it) while
numpy temps stay small enough to recycle warm allocator pages
instead of round-tripping through mmap.  Byte-invisibility also makes
the chunks INDEPENDENT, so on a multi-core host both directions fan
them out over a shared thread pool (``_coder_pool``; order-preserving
``executor.map``, so the pool cannot change a single output byte —
1-core hosts keep the sequential loop).

Records are self-delimiting, so streams CONCATENATE: the batched
decoder walks k records out of several clients' concatenated uploads
in one call — ``pack_uploads`` and the engine's downlink encode both
batch across the whole round, not per client.

The exact-mean Rice-parameter estimate (``floor(log2(sum // n))``,
integer arithmetic) replaced the float ``log2(mean)`` of the first
coder revision so the scalar and batched selectors cannot diverge on
float rounding edges; it computes the same floor for every input.

Accounting is *measured*, not bounded: :func:`coded_mask_bits` /
:func:`golomb_encode_bits` return 8× the actual stream length the
decoder consumes (header included).  :func:`mask_entropy_bits` keeps
the Shannon bound for comparison — the coder lands within a few
percent of it away from p = 0.5 and escapes to raw near it.

The bf16 unified-vector transport (32d → 16d bits, measured cosine
> 0.999) is the other wire term; :func:`compressed_uplink_bits`
combines both: 16d + Σ_k (coded mask stream + 32-bit scaler).
"""

from __future__ import annotations

import math
import os
from concurrent.futures import ThreadPoolExecutor
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitpack import (packed_width, pack_bits_np,
                                   scatter_bits_np, unpack_bits_np)

HEADER_BYTES = 5
_POLARITY_BIT = 0x01
_RAW_BIT = 0x02
_K_SHIFT = 3


class CodedStreamError(ValueError):
    """A coded byte stream failed validation during decode.

    Raised (instead of a mis-decode, IndexError, or silent garbage) for
    every malformed-input class an adversarial or fault-injected wire
    can produce: truncated header, truncated raw-escape payload,
    truncated unary/remainder sections, a run count whose positions
    point past ``d``, and trailing bytes after the expected row count.
    Subclasses ``ValueError`` so pre-existing callers that caught that
    still work; the async round server catches THIS type to quarantine
    the upload (see ``repro.fed.systems``)."""

# Streaming bounds for the batched coder.  Chunks keep every numpy
# intermediate a few MB — far below glibc's mmap threshold — so the
# allocator hands back the SAME warm pages chunk after chunk instead of
# mmap/munmap-ing a fresh couple-hundred-MB temporary per vector op
# (each of which costs a full page-fault sweep on first touch; on the
# 1-core host that made a monolithic pass ~30x slower than the same
# FLOPs on warm buffers).  Records self-delimit and concatenate, so
# chunking cannot change a single output byte.
_ENC_CHUNK_BITS = 1 << 21   # mask bits (rows × d) encoded per chunk
_DEC_WINDOW_BYTES = 1 << 17  # stream bytes unpacked per decode chunk
_DEC_DENSE_BITS = 1 << 22   # dense (rows × d) reconstructed per chunk

# Because chunking is byte-invisible (records self-delimit and every
# chunk's extent is known before any chunk runs), the chunks are
# INDEPENDENT — so on a multi-core host both directions fan them out
# over a shared thread pool (numpy releases the GIL for the big
# unpack/scatter/packbits passes).  ``executor.map`` preserves chunk
# order, so the concatenated stream / row writes are byte-for-byte the
# sequential ones no matter how the pool schedules — enforced by the
# monkeypatched-tiny-chunk parity test in tests/test_compression.py.
# REPRO_CODER_WORKERS overrides the worker count (1 → sequential).
_pool: Optional[ThreadPoolExecutor] = None
_pool_workers = 0


def _coder_workers() -> int:
    env = os.environ.get("REPRO_CODER_WORKERS")
    return int(env) if env else (os.cpu_count() or 1)


def _coder_pool() -> Optional[ThreadPoolExecutor]:
    """The shared coder pool, or None on a 1-worker host (sequential
    fallback — identical bytes either way)."""
    global _pool, _pool_workers
    n = _coder_workers()
    if n <= 1:
        return None
    if _pool is None or _pool_workers != n:
        _pool = ThreadPoolExecutor(max_workers=n,
                                   thread_name_prefix="rice-coder")
        _pool_workers = n
    return _pool

# (256, 8) lookup: _NTH_ONE[v, i] = LSB-first bit index of the
# (i+1)-th set bit of byte value v (8 where v has fewer ones).  With
# the cumulative byte popcount this turns "position of the n-th
# one-bit" into one searchsorted + one table load — the decoder's
# boundary walk never unpacks bits it will not decode.
_NTH_ONE = np.full((256, 8), 8, np.int8)
for _v in range(256):
    _idx = np.flatnonzero(
        np.unpackbits(np.array([_v], np.uint8), bitorder="little"))
    _NTH_ONE[_v, :_idx.size] = _idx
del _v, _idx
# plain-Python twin for the decoder's boundary walk (no numpy-scalar
# boxing in the per-record hot loop)
_NTH_ONE_L = _NTH_ONE.tolist()


def mask_entropy_bits(mask: np.ndarray) -> float:
    """Shannon bound for transmitting a binary mask of this density."""
    p = float(np.clip(np.mean(mask), 1e-6, 1 - 1e-6))
    h = -(p * math.log2(p) + (1 - p) * math.log2(1 - p))
    return h * mask.size


def _best_rice_k(gaps: np.ndarray) -> int:
    """Rice parameter minimizing the exact payload bits, searched in a
    window around the ``floor(log2(mean gap))`` estimate (the optimum
    for the geometric gap distribution of a Bernoulli mask lives
    there).  Exact integer arithmetic — ``floor(log2(sum // n)) ==
    floor(log2(sum / n))`` for any integers, so the vectorized
    :func:`_rice_k_rows` selector reproduces this bit for bit (ties go
    to the smaller k in both)."""
    n = gaps.size
    q = int(np.sum(gaps)) // n
    k0 = q.bit_length() - 1 if q >= 1 else 0
    best_k, best_bits = 0, None
    for k in range(max(0, k0 - 3), min(31, k0 + 3) + 1):
        bits = int(np.sum(gaps >> k)) + n * (k + 1)
        if best_bits is None or bits < best_bits:
            best_k, best_bits = k, bits
    return best_k


def _rice_k_rows(gaps: np.ndarray, starts: np.ndarray, counts: np.ndarray
                 ) -> np.ndarray:
    """Vectorized :func:`_best_rice_k` over row segments of one flat
    ``gaps`` array (``starts``/``counts`` delimit non-empty segments).
    Candidate window, exact bit counts (``np.add.reduceat`` on int64),
    and first-minimum tie-breaking all match the scalar scan — the
    clipped candidates are non-decreasing in window position, so
    ``argmin`` picking the first minimum IS the ascending-k scan."""
    sums = np.add.reduceat(gaps, starts)
    q = (sums // counts).astype(np.float64)     # exact: q < 2**53
    k0 = np.maximum(np.frexp(q)[1] - 1, 0)      # floor(log2(q)); 0 at q=0
    rows_ix = np.arange(counts.size)
    cand_k = np.empty((7, counts.size), np.int64)
    cand_bits = np.empty((7, counts.size), np.int64)
    lo = int(np.clip(k0 - 3, 0, 31).min())
    hi = int(np.clip(k0 + 3, 0, 31).max())
    if hi - lo <= 12:
        # near-uniform densities (every real round): evaluate the union
        # of the rows' candidate windows with SCALAR shifts — one
        # in-place ``>>= 1`` + one segment-sum per global candidate, no
        # per-gap gather — then assemble each row's own 7 candidates
        # from the table.  Identical bit counts, identical argmin.
        table = np.empty((hi - lo + 1, counts.size), np.int64)
        sh = np.right_shift(gaps, lo)
        table[0] = np.add.reduceat(sh, starts)
        for b in range(1, hi - lo + 1):
            sh >>= 1
            table[b] = np.add.reduceat(sh, starts)
        for j in range(7):
            kc = np.clip(k0 - 3 + j, 0, 31).astype(np.int64)
            cand_k[j] = kc
            cand_bits[j] = table[kc - lo, rows_ix] + counts * (kc + 1)
    else:                       # wildly mixed densities: per-gap shifts
        seg = np.repeat(np.arange(counts.size), counts)
        for j in range(7):
            kc = np.clip(k0 - 3 + j, 0, 31).astype(np.int64)
            cand_k[j] = kc
            cand_bits[j] = (np.add.reduceat(gaps >> kc[seg], starts)
                            + counts * (kc + 1))
    return cand_k[np.argmin(cand_bits, axis=0), rows_ix]


def rice_encode_words(words: np.ndarray, d: int) -> np.ndarray:
    """Encode ONE packed mask row (``ceil(d/32)`` uint32 words, the
    :mod:`repro.kernels.bitpack` layout) into a self-describing uint8
    stream.  Exactly invertible by :func:`rice_decode_words` given only
    ``d``; never more than ``HEADER_BYTES`` over the raw packed words
    (the raw-escape mode)."""
    words = np.ascontiguousarray(np.asarray(words, np.uint32).ravel())
    if words.size != packed_width(d):
        raise ValueError(f"rice_encode_words: {words.size} words != "
                         f"packed_width({d}) = {packed_width(d)}")
    bits = unpack_bits_np(words, d)
    n_set = int(bits.sum())
    polarity = 1 if 2 * n_set <= d else 0
    positions = np.flatnonzero(bits if polarity else ~bits)
    n = positions.size

    raw_payload = words.astype("<u4").view(np.uint8)
    if n == 0:
        header = np.zeros(HEADER_BYTES, np.uint8)
        header[0] = polarity
        return header

    gaps = np.diff(positions.astype(np.int64), prepend=-1) - 1
    k = _best_rice_k(gaps)
    qs = gaps >> k
    unary_len = int(qs.sum()) + n
    total_bits = unary_len + n * k
    if -(-total_bits // 8) >= raw_payload.size:      # raw escape
        header = np.zeros(HEADER_BYTES, np.uint8)
        header[0] = polarity | _RAW_BIT
        return np.concatenate([header, raw_payload])

    stream_bits = np.zeros(total_bits, np.uint8)
    stream_bits[np.cumsum(qs + 1) - 1] = 1           # unary terminators
    if k:
        rem = ((gaps[:, None] >> np.arange(k, dtype=np.int64)) & 1)
        stream_bits[unary_len:] = rem.astype(np.uint8).ravel()
    header = np.zeros(HEADER_BYTES, np.uint8)
    header[0] = polarity | (k << _K_SHIFT)
    header[1:5] = np.array([n], "<u4").view(np.uint8)
    return np.concatenate([header,
                           np.packbits(stream_bits, bitorder="little")])


def rice_decode_words(stream: np.ndarray, d: int
                      ) -> Tuple[np.ndarray, int]:
    """Inverse of :func:`rice_encode_words`: ``(words, consumed_bytes)``
    from a stream that may carry further rows after this one.  Needs
    only ``d`` — polarity, Rice parameter, and run count come from the
    header."""
    stream = np.asarray(stream, np.uint8).ravel()
    if stream.size < HEADER_BYTES:
        raise CodedStreamError("rice_decode_words: truncated header")
    flags = int(stream[0])
    polarity = flags & _POLARITY_BIT
    w = packed_width(d)
    # any single record is ≤ header + raw payload (the escape rule), so
    # later rows in a multi-row stream never need to be unpacked here —
    # keeps decode_mask_rows linear in the total stream length
    stream = stream[:HEADER_BYTES + 4 * w]
    if flags & _RAW_BIT:
        end = HEADER_BYTES + 4 * w
        words = stream[HEADER_BYTES:end].view("<u4").astype(np.uint32)
        if words.size != w:
            raise CodedStreamError("rice_decode_words: truncated raw payload")
        return words, end
    k = flags >> _K_SHIFT
    n = int(stream[1:5].view("<u4")[0])
    if n == 0:
        bits = np.zeros(d, bool) if polarity else np.ones(d, bool)
        return pack_bits_np(bits), HEADER_BYTES

    payload_bits = np.unpackbits(stream[HEADER_BYTES:], bitorder="little")
    ones = np.flatnonzero(payload_bits)
    if ones.size < n:
        raise CodedStreamError("rice_decode_words: truncated unary section")
    ends = ones[:n]                                  # unary terminators
    qs = np.diff(ends, prepend=-1) - 1
    unary_len = int(ends[-1]) + 1
    gaps = qs.astype(np.int64) << k
    if k:
        rem = payload_bits[unary_len:unary_len + n * k]
        if rem.size < n * k:
            raise CodedStreamError("rice_decode_words: truncated remainders")
        gaps += rem.reshape(n, k) @ (1 << np.arange(k, dtype=np.int64))
    positions = np.cumsum(gaps + 1) - 1
    if positions[-1] >= d:
        raise CodedStreamError("rice_decode_words: position beyond d")
    bits = np.zeros(d, bool) if polarity else np.ones(d, bool)
    bits[positions] = bool(polarity)
    consumed = HEADER_BYTES + -(-(unary_len + n * k) // 8)
    return pack_bits_np(bits), consumed


def encode_mask_rows_reference(words: np.ndarray, d: int) -> np.ndarray:
    """Scalar row-by-row encoder (the retained reference): one
    :func:`rice_encode_words` record per row, concatenated.  The
    batched :func:`encode_mask_rows` is byte-identical to this — the
    parity is enforced on the adversarial-density grid in
    tests/test_compression.py."""
    words = np.asarray(words, np.uint32)
    if words.ndim == 1:
        words = words[None]
    parts = [rice_encode_words(row, d) for row in words]
    return (np.concatenate(parts) if parts else np.zeros(0, np.uint8))


def decode_mask_rows_reference(stream: np.ndarray, d: int, k: int
                               ) -> np.ndarray:
    """Scalar row-by-row decoder (the retained reference for the
    batched :func:`decode_mask_rows`)."""
    stream = np.asarray(stream, np.uint8).ravel()
    out = np.empty((k, packed_width(d)), np.uint32)
    off = 0
    for i in range(k):
        row, used = rice_decode_words(stream[off:], d)
        out[i] = row
        off += used
    if off != stream.size:
        raise CodedStreamError(f"decode_mask_rows: {stream.size - off} trailing "
                         f"bytes after {k} rows")
    return out


def _encode_rows_chunk(words: np.ndarray, d: int
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """One bounded chunk of the batched encoder (all intermediates are
    a few MB and int32 — the chunk invariant ``rows × d ≤
    _ENC_CHUNK_BITS`` keeps every bit offset and position below 2³¹)."""
    r = words.shape[0]
    w = packed_width(d)

    # polarity from word popcounts (O(w), no dense sum), then flip the
    # minority-symbol selection on the WORDS — one conditional xor per
    # row plus a tail-word fix keeps the dense layer to a single unpack
    n_set = np.bitwise_count(words).sum(axis=1, dtype=np.int64)
    polarity = 2 * n_set <= d                              # (r,) bool
    n = np.where(polarity, n_set, d - n_set)               # coded positions
    coded_words = np.where(polarity[:, None], words,
                           words ^ np.uint32(0xFFFFFFFF))
    if d % 32:                                             # tail bits stay 0
        coded_words[:, -1] &= np.uint32((1 << (d % 32)) - 1)
    flat = np.flatnonzero(unpack_bits_np(coded_words, d))  # row-major

    active = n > 0
    n_act = n[active]
    a = n_act.size
    sizes = np.full(r, HEADER_BYTES, np.int64)
    flags = polarity.astype(np.uint8)
    if a == 0:                                             # headers only
        out = np.zeros(int(sizes.sum()), np.uint8)
        out[np.concatenate(([0], np.cumsum(sizes)[:-1]))] = flags
        return out, sizes

    starts = np.concatenate(([0], np.cumsum(n_act)[:-1]))

    # shared gap extraction: consecutive differences of the row-major
    # flat positions are the in-row gaps everywhere except each row's
    # first position, whose gap is its offset from the row origin —
    # one diff + a scatter fix-up at the row starts, no per-gap
    # row-id/previous-position arrays
    gaps = np.empty(flat.size, np.int32)
    if flat.size:
        gaps[0] = 1                                        # overwritten below
        np.subtract(flat[1:], flat[:-1], out=gaps[1:], casting="unsafe")
        gaps -= 1
        act_rows = np.flatnonzero(active)
        gaps[starts] = flat[starts] - act_rows * np.int64(d)

    seg = np.repeat(np.arange(a, dtype=np.int32), n_act)
    k_act = _rice_k_rows(gaps, starts, n_act)
    k_uni = int(k_act[0]) if k_act.min() == k_act.max() else None
    if k_uni is not None:                      # one k for every row —
        qs = gaps >> np.int32(k_uni)           # scalar shifts, no gather
        k_seg = None
    else:
        k_seg = k_act.astype(np.int32)[seg]
        qs = gaps >> k_seg
    unary_len = np.add.reduceat(qs, starts).astype(np.int64) + n_act
    total_bits = unary_len + n_act * k_act
    rice_bytes = -(-total_bits // 8)
    raw = rice_bytes >= 4 * w                              # raw escape
    sizes[active] = HEADER_BYTES + np.where(raw, 4 * w, rice_bytes)
    flags[active] |= np.where(raw, _RAW_BIT,
                              k_act << _K_SHIFT).astype(np.uint8)

    offsets = np.concatenate(([0], np.cumsum(sizes)[:-1]))
    total = int(sizes.sum())
    off_act = offsets[active]

    rice = ~raw
    if rice.any():
        # prefix-sum bit-scatter: every Rice row's unary terminators and
        # remainder bits land in ONE bit-space covering the whole stream
        # (header / raw-payload byte regions stay zero there and are
        # written byte-aligned below — the regions are disjoint)
        all_rice = not raw.any()
        bit_base = (8 * (off_act + HEADER_BYTES)).astype(np.int32)
        cum = np.cumsum(qs + np.int32(1), dtype=np.int32)
        before = np.concatenate(
            ([0], cum[starts[1:] - 1])).astype(np.int32)
        row_term = bit_base - before                       # per-row offset
        row_term -= 1
        term = row_term[seg]
        term += cum
        if all_rice:
            positions = [term]
        else:
            rice_gap = rice[seg]
            positions = [term[rice_gap]]
        kmax = int(k_act[rice].max(initial=0))
        if kmax:
            rem_row = bit_base + unary_len.astype(np.int32)
            if k_uni is not None:                  # fused arange stride
                rem_row -= np.int32(k_uni) * starts.astype(np.int32)
                rem_at = rem_row[seg]
                rem_at += np.arange(0, k_uni * gaps.size, k_uni,
                                    dtype=np.int32)
            else:
                rem_at = rem_row[seg]
                j_local = np.arange(gaps.size, dtype=np.int32)
                j_local -= starts.astype(np.int32)[seg]
                rem_at += j_local * k_seg
            for b in range(kmax):
                hit = (gaps & np.int32(1 << b)).astype(bool)
                if k_uni is None:
                    hit &= k_seg > b
                if not all_rice:
                    hit &= rice_gap
                positions.append(rem_at[hit] + b)
        out = scatter_bits_np(np.concatenate(positions), total)
    else:
        out = np.zeros(total, np.uint8)

    out[offsets] = flags
    off_rice = off_act[rice]
    if off_rice.size:                                      # uint32 run count
        out[off_rice[:, None] + np.arange(1, 5)] = (
            n_act[rice].astype("<u4").view(np.uint8).reshape(-1, 4))
    if raw.any():                                          # raw payloads
        raw_rows = np.flatnonzero(active)[raw]
        payload = (np.ascontiguousarray(words[raw_rows]).astype("<u4")
                   .view(np.uint8).reshape(raw_rows.size, 4 * w))
        out[off_act[raw][:, None] + HEADER_BYTES + np.arange(4 * w)] = payload
    return out, sizes


def encode_mask_rows_with_sizes(words: np.ndarray, d: int
                                ) -> Tuple[np.ndarray, np.ndarray]:
    """Batched encoder core: vectorized over ALL rows →
    ``(stream, per_row_record_bytes)``.  The sizes array lets callers
    split the one concatenated stream back into per-client slices
    (``np.add.reduceat`` / cumsum over the client's row counts) without
    re-encoding — the engine's downlink path and the strategy's uplink
    path both encode the whole round in one call.

    Rows are processed in ``_ENC_CHUNK_BITS``-bounded chunks (records
    concatenate, so the output is byte-for-byte independent of the
    chunking) to keep the working set in warm allocator pages."""
    words = np.asarray(words, np.uint32)
    if words.ndim == 1:
        words = words[None]
    r = words.shape[0]
    w = packed_width(d)
    if r == 0:
        return np.zeros(0, np.uint8), np.zeros(0, np.int64)
    if words.shape[-1] != w:
        raise ValueError(f"encode_mask_rows: {words.shape[-1]} words/row "
                         f"!= packed_width({d}) = {w}")
    rows_per = max(1, _ENC_CHUNK_BITS // (32 * w))
    if r <= rows_per:
        return _encode_rows_chunk(words, d)
    chunk_starts = range(0, r, rows_per)
    pool = _coder_pool()
    if pool is None:
        parts = [_encode_rows_chunk(words[i:i + rows_per], d)
                 for i in chunk_starts]
    else:
        # independent chunks on the pool; map preserves chunk order, so
        # the concatenation is byte-identical to the sequential loop
        parts = list(pool.map(
            lambda i: _encode_rows_chunk(words[i:i + rows_per], d),
            chunk_starts))
    streams, sizes = zip(*parts)
    return np.concatenate(streams), np.concatenate(sizes)


def encode_mask_rows(words: np.ndarray, d: int) -> np.ndarray:
    """Encode a ``(k, ceil(d/32))`` stack of packed mask rows (or one
    1-D row) into one concatenated uint8 stream — each row's record is
    self-delimiting, so :func:`decode_mask_rows` walks it with only
    ``d`` and the row count.  Batched: all rows encode in one
    vectorized numpy pass, byte-identical to the scalar
    :func:`encode_mask_rows_reference`."""
    return encode_mask_rows_with_sizes(words, d)[0]


def _decode_rice_chunk(stream: np.ndarray, out: np.ndarray, d: int,
                       lo: int, hi: int, rows: np.ndarray, kk: np.ndarray,
                       n: np.ndarray, pb: np.ndarray, unary: np.ndarray,
                       pol: np.ndarray) -> None:
    """Vectorized reconstruction of one bounded group of Rice records
    (stream bytes ``[lo, hi)``; ``rows × d ≤ _DEC_DENSE_BITS`` keeps
    the dense scratch and every int32 index in range).  Writes the
    decoded packed words into ``out[rows]``."""
    win = np.unpackbits(stream[lo:hi], bitorder="little")
    ones = np.flatnonzero(win).astype(np.int32)
    pb_rel = (pb - 8 * lo).astype(np.int32)
    j0 = np.searchsorted(ones, pb_rel).astype(np.int32)
    nr = rows.size
    g = int(n.sum())
    starts = np.concatenate(([0], np.cumsum(n)[:-1]))
    starts32 = starts.astype(np.int32)
    seg = np.repeat(np.arange(nr, dtype=np.int32), n)
    idx = (j0 - starts32)[seg]
    idx += np.arange(g, dtype=np.int32)
    ends = ones[idx]
    # consecutive terminator differences are quotients + 1 in-row; the
    # row starts take the distance from the record's payload base —
    # one diff + a scatter fix-up, mirroring the encoder's gap pass
    q = np.empty(g, np.int32)
    q[0] = 1                                   # overwritten by fix-up
    np.subtract(ends[1:], ends[:-1], out=q[1:])
    q -= 1
    q[starts] = ends[starts] - pb_rel
    kmax = int(kk.max())
    k_uni = int(kk[0]) if int(kk.min()) == kmax else None
    # a corrupt stream can carry quotients/k that overflow 32 bits
    # before the position validation below fires — the scalar
    # reference raises there, so widen whenever quotient<<k could
    # exceed int32 even on garbage input (quotient < window bits)
    wide = kmax + (8 * (hi - lo)).bit_length() > 31
    gaps = q.astype(np.int64) if wide else q
    k_seg = None
    if k_uni is not None:
        if k_uni:
            gaps <<= k_uni
    else:
        k_seg = kk.astype(np.int32)[seg]
        gaps = gaps << k_seg
    if kmax:
        dt = np.int64 if wide else np.int32
        rem_row = pb_rel + unary.astype(np.int32)
        if k_uni is not None:
            rem_row -= np.int32(k_uni) * starts32
            rem_at = rem_row[seg]
            rem_at += np.arange(0, k_uni * g, k_uni, dtype=np.int32)
            for b in range(kmax):
                gaps += win[rem_at + b].astype(dt) << b
        else:
            rem_at = rem_row[seg]
            wk = np.arange(g, dtype=np.int32)
            wk -= starts32[seg]
            rem_at += wk * k_seg
            for b in range(kmax):
                sel = k_seg > b
                gaps[sel] += win[rem_at[sel] + b].astype(dt) << b
    cum = np.cumsum(gaps + 1, dtype=np.int64)
    before = np.concatenate(([0], cum[starts[1:] - 1]))
    positions = cum
    positions -= before[seg]
    positions -= 1
    if int(positions[np.cumsum(n) - 1].max()) >= d:
        raise CodedStreamError("rice_decode_words: position beyond d")
    # scatter the coded symbol's positions, pack, then flip rows whose
    # polarity coded the CLEAR bits at the word level (tail bits reset)
    dense = np.zeros((nr, d), bool)
    scat = seg * np.int64(d)
    scat += positions
    dense.reshape(-1)[scat] = True
    wout = pack_bits_np(dense)
    flip = ~pol
    if flip.any():
        wout[flip] ^= np.uint32(0xFFFFFFFF)
        if d % 32:
            wout[flip, -1] &= np.uint32((1 << (d % 32)) - 1)
    out[rows] = wout


def decode_mask_rows(stream: np.ndarray, d: int, k: int) -> np.ndarray:
    """Inverse of :func:`encode_mask_rows` → ``(k, ceil(d/32))`` uint32
    words, bit-identical to what was encoded.  Batched in two phases:
    a light O(k) boundary walk (records self-delimit, so each record's
    extent needs only its unary span — one ``searchsorted`` into the
    stream's cumulative byte popcount plus an n-th-set-bit table load,
    no bit unpacking), then windowed vectorized reconstruction of the
    Rice records' gaps, remainders, and positions in
    ``_DEC_WINDOW_BYTES``/``_DEC_DENSE_BITS``-bounded chunks.  Because
    records self-delimit, ``stream`` may be several clients' uploads
    concatenated — ``k`` is the total row count across them."""
    stream = np.asarray(stream, np.uint8).ravel()
    w = packed_width(d)
    out = np.empty((k, w), np.uint32)
    if k == 0:
        if stream.size:
            raise CodedStreamError(f"decode_mask_rows: {stream.size} trailing "
                             "bytes after 0 rows")
        return out

    # cpc[j] = one-bits in stream[:j] — the walk's only global scan
    cpc = np.zeros(stream.size + 1, np.int64)
    np.cumsum(np.bitwise_count(stream), dtype=np.int64, out=cpc[1:])

    # phase 1: boundary walk.  The chain is inherently serial (each
    # record's extent gates the next record's offset) and a global bit
    # unpack would break the bounded-memory contract, so instead of
    # vectorising across records the walk batches each step down to
    # pure-Python byte reads (memoryview + int.from_bytes — no numpy
    # slice/view per record) plus ONE C binary search confined to the
    # record's own ≤ 4w-byte window of the popcount prefix (the raw
    # escape bounds every record, so the window always brackets the
    # terminator) — identical offsets and errors to the original
    # full-array walk, at a fraction of the per-record overhead.
    mv = stream.data
    size = stream.size
    cpc_at = cpc.item              # unboxed scalar reads in the loop
    nth_l = _NTH_ONE_L             # (cpc stays numpy: tolist() would
    search = cpc.searchsorted      # cost O(stream) Python ints)
    empty_rows, empty_pol = [], []
    raw_rows, raw_offs = [], []
    rice = dict(row=[], kk=[], n=[], pb=[], unary=[], pol=[], end=[])
    (r_row, r_kk, r_n, r_pb, r_unary, r_pol, r_end) = (
        rice["row"].append, rice["kk"].append, rice["n"].append,
        rice["pb"].append, rice["unary"].append, rice["pol"].append,
        rice["end"].append)
    raw_len = HEADER_BYTES + 4 * w
    off = 0
    for i in range(k):
        if off + HEADER_BYTES > size:
            raise CodedStreamError("rice_decode_words: truncated header")
        flags = mv[off]
        pol = flags & _POLARITY_BIT
        if flags & _RAW_BIT:
            if off + raw_len > size:
                raise CodedStreamError("rice_decode_words: truncated raw payload")
            raw_rows.append(i)
            raw_offs.append(off + HEADER_BYTES)
            off += raw_len
            continue
        n = int.from_bytes(mv[off + 1:off + 5], "little")
        if n == 0:
            empty_rows.append(i)
            empty_pol.append(pol)
            off += HEADER_BYTES
            continue
        pb_byte = off + HEADER_BYTES
        lim_byte = pb_byte + 4 * w
        if lim_byte > size:
            lim_byte = size
        target = cpc_at(pb_byte) + n
        if target > cpc_at(lim_byte):
            raise CodedStreamError("rice_decode_words: truncated unary section")
        # byte holding the n-th one-bit after pb, then the bit within
        # it; cpc[pb] < target ≤ cpc[lim] brackets the terminator, so
        # the global search equals a window search and stays O(log S)
        jbyte = int(search(target, side="left")) - 1
        bit = nth_l[mv[jbyte]][target - cpc_at(jbyte) - 1]
        kk = flags >> _K_SHIFT
        unary = 8 * (jbyte - pb_byte) + bit + 1
        if unary + n * kk > 8 * (lim_byte - pb_byte):
            raise CodedStreamError("rice_decode_words: truncated remainders")
        r_row(i)
        r_kk(kk)
        r_n(n)
        r_pb(8 * pb_byte)
        r_unary(unary)
        r_pol(pol)
        off += HEADER_BYTES + -(-(unary + n * kk) // 8)
        r_end(off)
    if off != size:
        raise CodedStreamError(f"decode_mask_rows: {size - off} trailing "
                         f"bytes after {k} rows")

    # phase 2: vectorized reconstruction
    if empty_rows:
        pol = np.asarray(empty_pol, bool)
        fill = np.where(pol[:, None], np.zeros(w, np.uint32),
                        pack_bits_np(np.ones(d, bool))[None])
        out[np.asarray(empty_rows)] = fill
    if raw_rows:
        if len(raw_rows) * 4 * w <= 1 << 21:
            idx = np.asarray(raw_offs)[:, None] + np.arange(4 * w)
            out[np.asarray(raw_rows)] = (np.ascontiguousarray(stream[idx])
                                         .view("<u4").astype(np.uint32))
        else:                       # big rows: per-row views, no index grid
            for i, o in zip(raw_rows, raw_offs):
                out[i] = stream[o:o + 4 * w].view("<u4").astype(np.uint32)
    if rice["row"]:
        rows = np.asarray(rice["row"])
        kk = np.asarray(rice["kk"], np.int64)
        n = np.asarray(rice["n"], np.int64)
        pb = np.asarray(rice["pb"], np.int64)
        unary = np.asarray(rice["unary"], np.int64)
        pol = np.asarray(rice["pol"], bool)
        end = np.asarray(rice["end"], np.int64)
        lo = pb // 8
        nr = rows.size
        spans = []
        i0 = 0
        while i0 < nr:               # bounded windows over the records
            i1 = i0 + 1
            while (i1 < nr and end[i1] - lo[i0] <= _DEC_WINDOW_BYTES
                   and (i1 + 1 - i0) * d <= _DEC_DENSE_BITS):
                i1 += 1
            spans.append((i0, i1))
            i0 = i1

        def _one(span):
            a, b = span
            sl = slice(a, b)
            _decode_rice_chunk(stream, out, d, int(lo[a]), int(end[b - 1]),
                               rows[sl], kk[sl], n[sl], pb[sl], unary[sl],
                               pol[sl])

        pool = _coder_pool()
        if pool is None or len(spans) == 1:
            for span in spans:
                _one(span)
        else:
            # windows write DISJOINT out[rows] regions, so pooled
            # execution is race-free and bit-identical; map raises the
            # first window's CodedStreamError like the loop would
            list(pool.map(_one, spans))
    return out


def coded_mask_bits(masks, d: int) -> int:
    """Measured coded size (bits) of a mask stack in any layout the
    stack travels in — packed uint32 words, dense bool rows, or an
    already-coded uint8 stream (returned as-is)."""
    m = np.asarray(masks)
    if m.dtype == np.uint8:
        return 8 * m.size
    if m.dtype != np.uint32:
        m = pack_bits_np(m.astype(bool))
    return 8 * int(encode_mask_rows(m, d).size)


def golomb_encode_bits(mask: np.ndarray) -> int:
    """Measured bit count of the shipped Golomb-Rice stream for one
    dense mask — 8× the byte length of :func:`rice_encode_words` on its
    packed words, header (polarity + Rice parameter + run count)
    included, so this is exactly what a decoder consumes.

    (The pre-coder version of this function under-counted: it derived
    the Golomb parameter from the data without transmitting it and
    charged an all-ones mask 1 bit — undecodable accounting.  Kept
    under its old name; it now delegates to the real coder.)"""
    flat = np.asarray(mask, bool).ravel()
    return 8 * int(rice_encode_words(pack_bits_np(flat), flat.size).size)


def quantize_bf16_transport(v: jax.Array) -> jax.Array:
    """The bf16 wire transport itself (batch-shape agnostic, no host
    sync) — the single definition of what 'compressed unified vector'
    means; the batched strategy path calls this directly."""
    return v.astype(jnp.bfloat16).astype(jnp.float32)


def quantize_bf16(v: jax.Array) -> Tuple[jax.Array, float]:
    """bf16 transport of ONE unified vector; returns (vector, cosine)."""
    q = quantize_bf16_transport(v)
    denom = jnp.linalg.norm(v) * jnp.linalg.norm(q) + 1e-12
    return q, float(jnp.dot(v, q) / denom)


def compressed_uplink_bits(unified: jax.Array, masks: jax.Array,
                           *, use_entropy_bound: bool = False,
                           n_rows: Optional[int] = None) -> int:
    """Total uplink bits for one client under the coded scheme:
    16d (measured bf16 vector; a legacy fp32 vector is accounted at
    the bf16 transport it would use) + per mask row the MEASURED coded
    stream + a 32-bit scaler.  ``masks`` may be dense bool rows, the
    bit-packed uint32 wire words, or an already-coded uint8 stream
    (then its measured length is used directly, and ``n_rows`` must
    say how many scalers ride along — matching
    ``ClientUpload.uplink_bits`` on the same buffers).  With
    ``use_entropy_bound`` the mask term is the Shannon bound instead —
    the comparison axis, not a transmittable size."""
    d = int(unified.shape[0])
    total = 16 * d
    m = np.asarray(masks)
    if m.dtype == np.uint8:
        if n_rows is None:
            raise ValueError("compressed_uplink_bits: an already-coded "
                             "uint8 stream needs n_rows for the scaler "
                             "accounting (or use ClientUpload.uplink_bits)")
        if not use_entropy_bound:
            return total + 8 * m.size + 32 * n_rows
        # bound comparison asked for: decode back to rows and fall
        # through to the Shannon term
        m = decode_mask_rows(m, d, n_rows)
    if m.ndim == 1:
        m = m[None]
    k = m.shape[0]
    if use_entropy_bound:
        rows = unpack_bits_np(m, d) if m.dtype == np.uint32 else m
        p = np.clip(rows.mean(axis=1), 1e-6, 1 - 1e-6)
        h = -(p * np.log2(p) + (1 - p) * np.log2(1 - p)) * d
        return total + int(np.ceil(h).sum()) + 32 * k
    # measured: ONE batched encode of all rows — the concatenated
    # stream's length is exactly the sum of the per-row records
    words = m if m.dtype == np.uint32 else pack_bits_np(m.astype(bool))
    return total + 8 * int(encode_mask_rows(words, d).size) + 32 * k


# Raw (uncoded) wire accounting lives in repro.kernels.bitpack.wire_bits
# — the single definition ClientUpload.uplink_bits / ClientDownlink
# .downlink_bits / PackedRound.wire_bits delegate to for the raw packed
# layout; coded uploads/downlinks are accounted off their actual byte
# streams (coded_mask_bits).
