"""Entropy-coded mask transport for MaTU (EXPERIMENTS.md §Perf-comm).

The paper transmits, per client per round, one unified vector + per
task a dense binary mask + a scalar (Sec. 5.3).  Since the wire-format
engine refactor every MaTU round actually ships bf16 unified vectors
and bit-packed uint32 mask words (1 bit/coord; see the
``repro.core.engine`` wire-format contract) — this module is the layer
BELOW that: an actual, invertible entropy coder over the packed words,
so the biased modulator masks (P(1) ≈ 0.75 on a client's own tasks —
the regime DeltaMask, Tsouvalas et al. 2023, targets) go out well
under 1 bit/coord.

Coder: vectorized Golomb-Rice over the gaps between the rarer symbol's
positions, with a self-describing 5-byte header, so decode needs only
``d`` and the byte stream.  Stream layout (everything little-endian,
bit streams LSB-first — the same bit convention as
``repro.kernels.bitpack``):

  byte 0    bit 0: polarity  (1 → coded positions are the SET bits,
                              0 → coded positions are the CLEAR bits)
            bit 1: raw escape (1 → payload is the packed words
                              verbatim, 4·ceil(d/32) bytes; the coder
                              only emits this when the Rice payload
                              would be larger, so coded ≤ raw + header
                              at ANY density)
            bits 3-7: Rice parameter k ∈ [0, 31]
  bytes 1-4 uint32 run count n (number of coded positions)
  payload   unary section: for each of the n gaps, ``gap >> k`` zero
            bits then a one bit; THEN the remainder section: n·k bits,
            the low k bits of each gap, LSB-first per symbol.  Padded
            with zero bits to a byte boundary.

Splitting unary and remainder bits into two sections (rather than
interleaving per symbol) keeps decode fully vectorized: the first n
one-bits of the payload are exactly the n unary terminators, so one
``np.flatnonzero`` recovers every quotient and one reshape every
remainder — no sequential bit walk.  The split is size-neutral.

Round-trip is bit-exact for any density — all-zero and all-one masks
are 5-byte streams (n = 0), single-bit masks cost one gap — and is
enforced by property tests over adversarial densities
(tests/test_compression.py).

Accounting is *measured*, not bounded: :func:`coded_mask_bits` /
:func:`golomb_encode_bits` return 8× the actual stream length the
decoder consumes (header included).  :func:`mask_entropy_bits` keeps
the Shannon bound for comparison — the coder lands within a few
percent of it away from p = 0.5 and escapes to raw near it.

The bf16 unified-vector transport (32d → 16d bits, measured cosine
> 0.999) is the other wire term; :func:`compressed_uplink_bits`
combines both: 16d + Σ_k (coded mask stream + 32-bit scaler).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bitpack import packed_width, pack_bits_np, unpack_bits_np

HEADER_BYTES = 5
_POLARITY_BIT = 0x01
_RAW_BIT = 0x02
_K_SHIFT = 3


def mask_entropy_bits(mask: np.ndarray) -> float:
    """Shannon bound for transmitting a binary mask of this density."""
    p = float(np.clip(np.mean(mask), 1e-6, 1 - 1e-6))
    h = -(p * math.log2(p) + (1 - p) * math.log2(1 - p))
    return h * mask.size


def _best_rice_k(gaps: np.ndarray) -> int:
    """Rice parameter minimizing the exact payload bits, searched in a
    window around the log2(mean gap) estimate (the optimum for the
    geometric gap distribution of a Bernoulli mask lives there)."""
    mean = float(gaps.mean())
    k0 = max(0, int(math.log2(mean)) if mean >= 1.0 else 0)
    best_k, best_bits = 0, None
    for k in range(max(0, k0 - 3), min(31, k0 + 3) + 1):
        bits = int(np.sum(gaps >> k)) + gaps.size * (k + 1)
        if best_bits is None or bits < best_bits:
            best_k, best_bits = k, bits
    return best_k


def rice_encode_words(words: np.ndarray, d: int) -> np.ndarray:
    """Encode ONE packed mask row (``ceil(d/32)`` uint32 words, the
    :mod:`repro.kernels.bitpack` layout) into a self-describing uint8
    stream.  Exactly invertible by :func:`rice_decode_words` given only
    ``d``; never more than ``HEADER_BYTES`` over the raw packed words
    (the raw-escape mode)."""
    words = np.ascontiguousarray(np.asarray(words, np.uint32).ravel())
    if words.size != packed_width(d):
        raise ValueError(f"rice_encode_words: {words.size} words != "
                         f"packed_width({d}) = {packed_width(d)}")
    bits = unpack_bits_np(words, d)
    n_set = int(bits.sum())
    polarity = 1 if 2 * n_set <= d else 0
    positions = np.flatnonzero(bits if polarity else ~bits)
    n = positions.size

    raw_payload = words.astype("<u4").view(np.uint8)
    if n == 0:
        header = np.zeros(HEADER_BYTES, np.uint8)
        header[0] = polarity
        return header

    gaps = np.diff(positions.astype(np.int64), prepend=-1) - 1
    k = _best_rice_k(gaps)
    qs = gaps >> k
    unary_len = int(qs.sum()) + n
    total_bits = unary_len + n * k
    if -(-total_bits // 8) >= raw_payload.size:      # raw escape
        header = np.zeros(HEADER_BYTES, np.uint8)
        header[0] = polarity | _RAW_BIT
        return np.concatenate([header, raw_payload])

    stream_bits = np.zeros(total_bits, np.uint8)
    stream_bits[np.cumsum(qs + 1) - 1] = 1           # unary terminators
    if k:
        rem = ((gaps[:, None] >> np.arange(k, dtype=np.int64)) & 1)
        stream_bits[unary_len:] = rem.astype(np.uint8).ravel()
    header = np.zeros(HEADER_BYTES, np.uint8)
    header[0] = polarity | (k << _K_SHIFT)
    header[1:5] = np.array([n], "<u4").view(np.uint8)
    return np.concatenate([header,
                           np.packbits(stream_bits, bitorder="little")])


def rice_decode_words(stream: np.ndarray, d: int
                      ) -> Tuple[np.ndarray, int]:
    """Inverse of :func:`rice_encode_words`: ``(words, consumed_bytes)``
    from a stream that may carry further rows after this one.  Needs
    only ``d`` — polarity, Rice parameter, and run count come from the
    header."""
    stream = np.asarray(stream, np.uint8).ravel()
    if stream.size < HEADER_BYTES:
        raise ValueError("rice_decode_words: truncated header")
    flags = int(stream[0])
    polarity = flags & _POLARITY_BIT
    w = packed_width(d)
    # any single record is ≤ header + raw payload (the escape rule), so
    # later rows in a multi-row stream never need to be unpacked here —
    # keeps decode_mask_rows linear in the total stream length
    stream = stream[:HEADER_BYTES + 4 * w]
    if flags & _RAW_BIT:
        end = HEADER_BYTES + 4 * w
        words = stream[HEADER_BYTES:end].view("<u4").astype(np.uint32)
        if words.size != w:
            raise ValueError("rice_decode_words: truncated raw payload")
        return words, end
    k = flags >> _K_SHIFT
    n = int(stream[1:5].view("<u4")[0])
    if n == 0:
        bits = np.zeros(d, bool) if polarity else np.ones(d, bool)
        return pack_bits_np(bits), HEADER_BYTES

    payload_bits = np.unpackbits(stream[HEADER_BYTES:], bitorder="little")
    ones = np.flatnonzero(payload_bits)
    if ones.size < n:
        raise ValueError("rice_decode_words: truncated unary section")
    ends = ones[:n]                                  # unary terminators
    qs = np.diff(ends, prepend=-1) - 1
    unary_len = int(ends[-1]) + 1
    gaps = qs.astype(np.int64) << k
    if k:
        rem = payload_bits[unary_len:unary_len + n * k]
        if rem.size < n * k:
            raise ValueError("rice_decode_words: truncated remainders")
        gaps += rem.reshape(n, k) @ (1 << np.arange(k, dtype=np.int64))
    positions = np.cumsum(gaps + 1) - 1
    if positions[-1] >= d:
        raise ValueError("rice_decode_words: position beyond d")
    bits = np.zeros(d, bool) if polarity else np.ones(d, bool)
    bits[positions] = bool(polarity)
    consumed = HEADER_BYTES + -(-(unary_len + n * k) // 8)
    return pack_bits_np(bits), consumed


def encode_mask_rows(words: np.ndarray, d: int) -> np.ndarray:
    """Encode a ``(k, ceil(d/32))`` stack of packed mask rows (or one
    1-D row) into one concatenated uint8 stream — each row's record is
    self-delimiting, so :func:`decode_mask_rows` walks it with only
    ``d`` and the row count."""
    words = np.asarray(words, np.uint32)
    if words.ndim == 1:
        words = words[None]
    parts = [rice_encode_words(row, d) for row in words]
    return (np.concatenate(parts) if parts else np.zeros(0, np.uint8))


def decode_mask_rows(stream: np.ndarray, d: int, k: int) -> np.ndarray:
    """Inverse of :func:`encode_mask_rows` → ``(k, ceil(d/32))`` uint32
    words, bit-identical to what was encoded."""
    stream = np.asarray(stream, np.uint8).ravel()
    out = np.empty((k, packed_width(d)), np.uint32)
    off = 0
    for i in range(k):
        row, used = rice_decode_words(stream[off:], d)
        out[i] = row
        off += used
    if off != stream.size:
        raise ValueError(f"decode_mask_rows: {stream.size - off} trailing "
                         f"bytes after {k} rows")
    return out


def coded_mask_bits(masks, d: int) -> int:
    """Measured coded size (bits) of a mask stack in any layout the
    stack travels in — packed uint32 words, dense bool rows, or an
    already-coded uint8 stream (returned as-is)."""
    m = np.asarray(masks)
    if m.dtype == np.uint8:
        return 8 * m.size
    if m.dtype != np.uint32:
        m = pack_bits_np(m.astype(bool))
    return 8 * int(encode_mask_rows(m, d).size)


def golomb_encode_bits(mask: np.ndarray) -> int:
    """Measured bit count of the shipped Golomb-Rice stream for one
    dense mask — 8× the byte length of :func:`rice_encode_words` on its
    packed words, header (polarity + Rice parameter + run count)
    included, so this is exactly what a decoder consumes.

    (The pre-coder version of this function under-counted: it derived
    the Golomb parameter from the data without transmitting it and
    charged an all-ones mask 1 bit — undecodable accounting.  Kept
    under its old name; it now delegates to the real coder.)"""
    flat = np.asarray(mask, bool).ravel()
    return 8 * int(rice_encode_words(pack_bits_np(flat), flat.size).size)


def quantize_bf16_transport(v: jax.Array) -> jax.Array:
    """The bf16 wire transport itself (batch-shape agnostic, no host
    sync) — the single definition of what 'compressed unified vector'
    means; the batched strategy path calls this directly."""
    return v.astype(jnp.bfloat16).astype(jnp.float32)


def quantize_bf16(v: jax.Array) -> Tuple[jax.Array, float]:
    """bf16 transport of ONE unified vector; returns (vector, cosine)."""
    q = quantize_bf16_transport(v)
    denom = jnp.linalg.norm(v) * jnp.linalg.norm(q) + 1e-12
    return q, float(jnp.dot(v, q) / denom)


def compressed_uplink_bits(unified: jax.Array, masks: jax.Array,
                           *, use_entropy_bound: bool = False,
                           n_rows: Optional[int] = None) -> int:
    """Total uplink bits for one client under the coded scheme:
    16d (measured bf16 vector; a legacy fp32 vector is accounted at
    the bf16 transport it would use) + per mask row the MEASURED coded
    stream + a 32-bit scaler.  ``masks`` may be dense bool rows, the
    bit-packed uint32 wire words, or an already-coded uint8 stream
    (then its measured length is used directly, and ``n_rows`` must
    say how many scalers ride along — matching
    ``ClientUpload.uplink_bits`` on the same buffers).  With
    ``use_entropy_bound`` the mask term is the Shannon bound instead —
    the comparison axis, not a transmittable size."""
    d = int(unified.shape[0])
    total = 16 * d
    m = np.asarray(masks)
    if m.dtype == np.uint8:
        if n_rows is None:
            raise ValueError("compressed_uplink_bits: an already-coded "
                             "uint8 stream needs n_rows for the scaler "
                             "accounting (or use ClientUpload.uplink_bits)")
        if not use_entropy_bound:
            return total + 8 * m.size + 32 * n_rows
        # bound comparison asked for: decode back to rows and fall
        # through to the Shannon term
        m = decode_mask_rows(m, d, n_rows)
    if m.dtype == np.uint32:
        m = unpack_bits_np(m, d)
    if m.ndim == 1:
        m = m[None]
    for row in m:
        bits = (mask_entropy_bits(row) if use_entropy_bound
                else golomb_encode_bits(row))
        total += int(math.ceil(bits)) + 32         # + fp32 scaler
    return total


# Raw (uncoded) wire accounting lives in repro.kernels.bitpack.wire_bits
# — the single definition ClientUpload.uplink_bits / ClientDownlink
# .downlink_bits / PackedRound.wire_bits delegate to for the raw packed
# layout; coded uploads/downlinks are accounted off their actual byte
# streams (coded_mask_bits).
