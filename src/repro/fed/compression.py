"""Beyond-paper: uplink compression for MaTU (EXPERIMENTS.md §Perf-comm).

The paper transmits, per client per round, one fp32 unified vector +
per task a dense binary mask + a scalar: 32d + k(d + 32) bits.  Two
orthogonal, lossless-or-bounded reductions (both techniques the paper
itself cites as related work — DeltaMask, Tsouvalas et al. 2023):

1. **Entropy-coded masks.**  The modulator masks are heavily biased:
   m^t_j = (τ^t_j · τ_j > 0) holds for ~half the entries only when
   tasks conflict; for a client's own tasks the empirical P(1) ≈ 0.75+.
   An arithmetic coder reaches the entropy bound H(p)·d bits; we
   account (and test) that bound and ship a simple, exactly invertible
   run-length/Golomb fallback.

2. **bf16 unified vector.**  Task vectors tolerate bf16 transport (the
   server math is fp32 on arrival); 32d → 16d bits with measured
   cosine > 0.999 to the fp32 vector on the testbed.

Combined uplink: 16d + k(H(p)·d + 32) bits — another ~2.3× under the
paper's own scheme at k = 2 (see bench_table2 detail + tests).

Since the wire-format engine refactor the bf16 vector and the 1-bit
mask transport are not simulated — every MaTU round actually ships
bf16 unified vectors and bit-packed uint32 mask words (see the
``repro.core.engine`` wire-format contract), so the raw accounting
(``repro.kernels.bitpack.wire_bits``, via ``ClientUpload.uplink_bits``)
is measured off buffer sizes and the functions here quantify the
*additional* entropy-coding headroom.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def mask_entropy_bits(mask: np.ndarray) -> float:
    """Shannon bound for transmitting a binary mask of this density."""
    p = float(np.clip(np.mean(mask), 1e-6, 1 - 1e-6))
    h = -(p * math.log2(p) + (1 - p) * math.log2(1 - p))
    return h * mask.size


def golomb_encode_bits(mask: np.ndarray) -> int:
    """Exact bit count of a Golomb-Rice run-length code of the sparser
    symbol (invertible; a practical stand-in for arithmetic coding)."""
    flat = np.asarray(mask, bool).ravel()
    p1 = flat.mean()
    target = ~flat if p1 > 0.5 else flat          # encode the rarer symbol
    p = max(float(target.mean()), 1e-9)
    m = max(1, int(round(-1.0 / math.log2(max(1 - p, 1e-9)))))
    k = max(0, int(math.ceil(math.log2(m))))
    idx = np.flatnonzero(target)
    gaps = np.diff(idx, prepend=-1) - 1
    # each gap: unary quotient (gap//m + 1 bits) + k-bit remainder
    bits = int(np.sum(gaps // m + 1 + k)) + 1     # +1 polarity bit
    return bits


def quantize_bf16_transport(v: jax.Array) -> jax.Array:
    """The bf16 wire transport itself (batch-shape agnostic, no host
    sync) — the single definition of what 'compressed unified vector'
    means; the batched strategy path calls this directly."""
    return v.astype(jnp.bfloat16).astype(jnp.float32)


def quantize_bf16(v: jax.Array) -> Tuple[jax.Array, float]:
    """bf16 transport of ONE unified vector; returns (vector, cosine)."""
    q = quantize_bf16_transport(v)
    denom = jnp.linalg.norm(v) * jnp.linalg.norm(q) + 1e-12
    return q, float(jnp.dot(v, q) / denom)


def compressed_uplink_bits(unified: jax.Array, masks: jax.Array,
                           *, use_entropy_bound: bool = False) -> int:
    """Total uplink bits for one client under the compressed scheme.

    Since the wire-format refactor the vector term is *measured* from
    the actual transport buffer (bf16 → 16d bits; a legacy fp32 vector
    is still accounted at the 16d bf16 transport it would use), and
    ``masks`` may arrive either as dense bool rows or as the bit-packed
    uint32 wire words the engine natively ships (unpacked here only to
    evaluate the entropy coder, via the repo-wide bit convention).
    """
    d = int(unified.shape[0])
    # 16d either way: measured for a bf16 wire upload, the simulated
    # bf16 transport bound for a legacy fp32 vector
    total = 16 * d
    m = np.asarray(masks)
    if m.dtype == np.uint32:
        from repro.kernels.bitpack import unpack_bits_np
        m = unpack_bits_np(m, d)
    if m.ndim == 1:
        m = m[None]
    for row in m:
        bits = (mask_entropy_bits(row) if use_entropy_bound
                else golomb_encode_bits(row))
        total += int(math.ceil(bits)) + 32         # + fp32 scaler
    return total


# Raw (uncoded) wire accounting lives in repro.kernels.bitpack.wire_bits
# — the single definition ClientUpload.uplink_bits / ClientDownlink
# .downlink_bits / PackedRound.wire_bits all delegate to.  This module
# only quantifies the entropy-coding headroom on top of it.
