"""Client-side local fine-tuning.

One jitted trainer handles all strategies:

* vanilla (FedAvg / MaTU / MaT-FL / FedPer / TIES):   CE loss
* FedProx:  + (μ/2)·||τ − τ_anchor||²   (Li et al. 2020)
* NTK-FedAvg:  trains the *linearised* model f(0) + J(0)·τ
  (Muhamed et al. 2024) — implemented with jax.jvp, not an
  approximation of the baseline but the actual mechanism.

Backbones exposing a :class:`~repro.common.tree.TaskVectorSpace`
(``space``) and a tree-level feature path (``features_tree``) train
PYTREE-AWARE: the optimizer runs over the model-space LoRA delta pytree
(so AdamW moments live in model space, shaped like the adapters they
update) and the flat d-vector exists only at the wire edge — unflatten
the downlinked τ once on entry, flatten the trained delta once on
return.  The wire contract is unchanged either way:
``train(tv0, head0, X, Y, rng) -> (tv, head, final_loss)`` over flat
vectors of length ``backbone.d``.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.tree import tree_dot, tree_sub, tree_zeros_like
from repro.optim import adamw


def make_local_trainer(backbone, *, steps: int, batch_size: int, lr: float,
                       prox_mu: float = 0.0, linearize: bool = False):
    """Returns train(tv0, head0, X, Y, rng) -> (tv, head, final_loss)."""
    space = getattr(backbone, "space", None)
    if space is not None and hasattr(backbone, "features_tree"):
        return _make_tree_trainer(backbone, space, steps=steps,
                                  batch_size=batch_size, lr=lr,
                                  prox_mu=prox_mu, linearize=linearize)
    return _make_flat_trainer(backbone, steps=steps, batch_size=batch_size,
                              lr=lr, prox_mu=prox_mu, linearize=linearize)


def _make_flat_trainer(backbone, *, steps: int, batch_size: int, lr: float,
                       prox_mu: float, linearize: bool):
    """Legacy path: optimizer state in flat task-vector space."""
    feats = backbone.lin_features if linearize else backbone.features
    opt = adamw(lr)

    def loss_fn(tv, head, xb, yb, anchor):
        f = feats(tv, xb)
        logits = f @ head
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yb[:, None], axis=-1)[:, 0]
        ce = jnp.mean(lse - gold)
        if prox_mu > 0.0:
            ce = ce + 0.5 * prox_mu * jnp.sum(jnp.square(tv - anchor))
        return ce

    @jax.jit
    def train(tv0, head0, x, y, rng):
        anchor = tv0
        params = (tv0, head0)
        state = opt.init(params)

        def body(carry, key):
            params, state = carry
            idx = jax.random.randint(key, (batch_size,), 0, x.shape[0])
            xb, yb = x[idx], y[idx]
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p[0], p[1], xb, yb, anchor))(params)
            params, state = opt.update(grads, state, params)
            return (params, state), loss

        keys = jax.random.split(rng, steps)
        (params, _), losses = jax.lax.scan(body, (params, state), keys)
        return params[0], params[1], losses[-1]

    return train


def _make_tree_trainer(backbone, space, *, steps: int, batch_size: int,
                       lr: float, prox_mu: float, linearize: bool):
    """Pytree-aware path: AdamW over the model-space LoRA delta pytree
    (+ head); the flat vector appears only at the wire edge."""
    opt = adamw(lr)

    def feats(delta, xb):
        if linearize:
            zero = tree_zeros_like(delta)
            f0, jvp_out = jax.jvp(
                lambda dt: backbone.features_tree(dt, xb), (zero,), (delta,))
            return f0 + jvp_out
        return backbone.features_tree(delta, xb)

    def loss_fn(delta, head, xb, yb, anchor):
        f = feats(delta, xb)
        logits = f @ head
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yb[:, None], axis=-1)[:, 0]
        ce = jnp.mean(lse - gold)
        if prox_mu > 0.0:
            diff = tree_sub(delta, anchor)
            ce = ce + 0.5 * prox_mu * tree_dot(diff, diff)
        return ce

    @jax.jit
    def train(tv0, head0, x, y, rng):
        # wire edge: flat -> model space, once
        delta0 = space.unflatten(tv0)
        anchor = delta0
        params = (delta0, head0)
        state = opt.init(params)       # AdamW moments live in model space

        def body(carry, key):
            params, state = carry
            idx = jax.random.randint(key, (batch_size,), 0, x.shape[0])
            xb, yb = x[idx], y[idx]
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p[0], p[1], xb, yb, anchor))(params)
            params, state = opt.update(grads, state, params)
            return (params, state), loss

        keys = jax.random.split(rng, steps)
        (params, _), losses = jax.lax.scan(body, (params, state), keys)
        # wire edge: model space -> flat, once
        return space.flatten(params[0]), params[1], losses[-1]

    return train


def make_head(key, feat_out: int, n_classes: int) -> jax.Array:
    return jax.random.normal(key, (feat_out, n_classes)) * 0.01
