"""Federated simulation harness (replaces the paper's Flower setup).

Runs R rounds of: client sampling (ξ) → per-(client, task) local
fine-tuning in flat task-vector space → strategy aggregation → global
per-task head averaging → periodic evaluation.  Produces the metrics
the paper reports: per-task accuracy, averages, and bits/round.

Async & fault model
-------------------
Passing ``systems=ClientSystems(...)`` switches the loop to the
event-clock mode: each round is a tick, sampled clients still train,
but their uploads land in an :class:`~repro.fed.systems.AdmissionQueue`
with an arrival tick of ``dispatch + systems.delay(c, r)`` and the
server drains whatever has ARRIVED by the current tick.  Crashed
clients are never sampled, dropouts train but never upload, and
uploads older than ``FedConfig.max_staleness`` rounds are discarded as
stale.  Drained uploads are folded with the staleness-discounted λ
(``w = STALENESS_DISCOUNT**s``; see the engine docstring) when the
strategy supports ``aggregate_admitted``; a round that drains nothing
calls ``strategy.skip_round()`` and records a 0-bit History row
instead of crashing.  Per-round fault/staleness/quarantine counters
land in ``History.fault_counts`` (same keys in sync mode, where every
round reports ``sampled == admitted`` and zeros elsewhere).

Equivalence anchor: under ``ClientSystems.ideal(n)`` (always
available, zero latency, zero faults) every upload arrives within its
dispatch tick in selection order, staleness is uniformly 0 (w = 1
exactly, and the slot-weight multiply is never traced), so the async
run is **bit-identical** to the sync run — unified vectors, λ,
downlinks, and measured wire bits.

RNG keys are failure-invariant by construction: selection draws from
``fold_in(fold_in(base, 0), round)`` and client c's training keys from
``fold_in``-chains over (base, 1, c, round, task) — never from a
sequentially split stream — so injecting a fault for one client cannot
perturb any other client's draws (the satellite regression in
tests/test_systems.py).

Population mode
---------------
:class:`PopulationSimulator` is the client-axis scale-out harness: a
lazy :class:`~repro.data.dirichlet.PopulationSplit` over 10^5–10^6
clients, per-round sampling, and the chunked server round
(``MaTUServer.round_chunked``) so a round's memory is O(chunk + T·d)
regardless of how many clients report.  Nothing per-client is ever
materialised for the non-sampled population: a sampled client's upload
is derived on demand from ``(seed, round, client_id)`` plus the
current global task vectors, regenerated identically on the engine's
second streaming pass, and its downlink is handed to a sink instead of
cached — so neither the simulator nor the strategy layer grows state
with the population.  ``History`` rows stay the aggregate per-round
scalars they are in the sync loop (measured wire bits, fault
counters); ``FedConfig.eval_every`` gates evaluation exactly as in
:meth:`FedSimulator.run`.  Local "training" is the synthetic drift
model ``τ ← τ + step·(g_t − τ) + noise`` toward fixed hidden per-task
targets g_t, so convergence (cosine alignment to g_t, reported through
``History.task_acc``) is meaningful without per-client model state.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import TaskVectorLayoutError, pad_vector
from repro.core.client import ClientUpload
from repro.core.server import MaTUServer, MaTUServerConfig
from repro.core.unify import unify_with_modulators
from repro.data.dirichlet import FedSplit, PopulationSplit
from repro.data.synthetic import Constellation, eval_batch, sample_task_batch
from repro.fed.local import make_head, make_local_trainer
from repro.fed.strategies import RoundBatch, Strategy, Upload
from repro.fed.systems import (AdmissionQueue, ClientSystems,
                               blank_fault_counters)
from repro.fed.testbed import round_up_d


@dataclass
class FedConfig:
    rounds: int = 20
    participation: float = 1.0       # ξ
    local_steps: int = 10            # E (steps per task per round)
    batch_size: int = 32
    local_data: int = 256            # samples per (client, task)
    lr: float = 5e-3
    prox_mu: float = 0.1
    eval_every: int = 5
    seed: int = 0
    # defer the strategy's server-round drain so the dispatched round
    # overlaps the simulator's host bookkeeping (MaTU; no-op for
    # per-client strategies).  Bit-identical to False — same ops,
    # different order (tests/test_pipeline.py).
    pipeline: bool = False
    # async mode only: buffered uploads older than this many rounds are
    # discarded as stale instead of admitted (counted in
    # History.fault_counts["stale"])
    max_staleness: int = 4


@dataclass
class History:
    rounds: List[int] = field(default_factory=list)
    task_acc: List[Dict[int, float]] = field(default_factory=list)
    mean_acc: List[float] = field(default_factory=list)
    uplink_bits_per_round: List[int] = field(default_factory=list)
    # measured off the actual downlink wire buffers (bf16 vectors +
    # bit-packed mask words, or the Golomb-Rice coded byte streams
    # under MaTUStrategy(code_masks=True)) where the strategy has
    # them; 0 otherwise.  Uplink bits follow the same rule — with the
    # coded wire both columns are real coded stream lengths.
    downlink_bits_per_round: List[int] = field(default_factory=list)
    # per-phase host/device µs of each round's server step, as reported
    # by the strategy ({"pack"/"decode"/"encode"/"device"} where the
    # strategy measures them, {} otherwise).  Under pipeline=True a
    # round's phases complete at its drain, so entry r holds the most
    # recently COMPLETED round at the time round r was recorded — one
    # behind the in-flight round.
    phase_us: List[Dict[str, float]] = field(default_factory=list)
    # one dict per ROUND (every round, not just eval rounds) with the
    # repro.fed.systems.FAULT_KEYS counters: clients sampled / dropped
    # / crashed (unavailable) / straggling, uploads discarded stale,
    # uploads quarantined by the validating decode, uploads still
    # buffered after the drain, uploads admitted to the server step,
    # and skipped (1 when the round admitted nothing).  Sync rounds
    # report sampled == admitted and zeros elsewhere.
    fault_counts: List[Dict[str, int]] = field(default_factory=list)

    @property
    def total_fault_counts(self) -> Dict[str, int]:
        """Sum of the per-round fault counters over the whole run."""
        out = blank_fault_counters()
        for c in self.fault_counts:
            for k, v in c.items():
                out[k] = out.get(k, 0) + int(v)
        return out

    @property
    def final_task_acc(self) -> Dict[int, float]:
        return self.task_acc[-1] if self.task_acc else {}

    @property
    def final_mean_acc(self) -> float:
        return self.mean_acc[-1] if self.mean_acc else 0.0

    @property
    def mean_uplink_bits(self) -> float:
        b = self.uplink_bits_per_round
        return float(np.mean(b)) if b else 0.0

    @property
    def mean_downlink_bits(self) -> float:
        """Mean measured downlink wire bits per recorded round (0.0
        before any round was recorded — mirrors mean_uplink_bits)."""
        b = self.downlink_bits_per_round
        return float(np.mean(b)) if b else 0.0

    @property
    def mean_phase_us(self) -> Dict[str, float]:
        """Per-phase mean µs over the rounds that reported that phase
        ({} when the strategy measures nothing)."""
        out: Dict[str, List[float]] = {}
        for ph in self.phase_us:
            for key, us in (ph or {}).items():
                out.setdefault(key, []).append(us)
        return {k: float(np.mean(v)) for k, v in out.items()}


class FedSimulator:
    def __init__(self, cfg: FedConfig, constellation: Constellation,
                 split: FedSplit, backbone, strategy: Strategy,
                 mesh=None, systems: Optional[ClientSystems] = None):
        """``mesh``: optional jax Mesh threaded to the strategy — MaTU
        then runs its server round sharded over the taskvec axis (the
        engine's sharding contract); the simulation loop itself is
        unchanged, so the same script runs on 1 device and on N.

        ``systems``: optional :class:`~repro.fed.systems.ClientSystems`
        event-clock trace — switches ``run`` to the async buffered mode
        (see "Async & fault model" in the module docstring).  Under
        ``ClientSystems.ideal`` the async run is bit-identical to
        ``systems=None``.

        ``backbone``: one backbone shared by every client (the
        homogeneous path, unchanged), or a per-client mapping — a dict
        ``{client_id: backbone}`` or list — so one round mixes
        architectures.  Each client's delta flattens through its own
        ``TaskVectorSpace`` manifest and is zero-padded to the round's
        common d (the max over clients, rounded up to the 256-coord
        word boundary); holders of the same task must share a manifest
        fingerprint (checked here, and again by the strategy before
        every aggregation) because their rows merge coordinate-wise."""
        self.cfg = cfg
        self.con = constellation
        self.split = split
        self.strategy = strategy
        self.mesh = mesh
        self.systems = systems
        if mesh is not None:
            strategy.use_mesh(mesh)
        strategy.use_pipeline(cfg.pipeline)
        self.rng = jax.random.PRNGKey(cfg.seed)
        self.n_clients = len(split.tasks)
        if systems is not None and systems.n_clients != self.n_clients:
            raise ValueError(f"systems models {systems.n_clients} clients, "
                             f"split has {self.n_clients}")

        # -- backbone resolution: homogeneous object vs per-client map ----
        if isinstance(backbone, (list, tuple)):
            backbone = dict(enumerate(backbone))
        if isinstance(backbone, dict):
            missing = set(range(self.n_clients)) - set(backbone)
            if missing:
                raise ValueError(f"per-client backbones missing clients "
                                 f"{sorted(missing)}")
            self.backbones: Optional[Dict[int, object]] = {
                int(c): b for c, b in backbone.items()}
            self.backbone = None
            self.d = round_up_d(max(b.d for b in self.backbones.values()))
        else:
            self.backbones = None
            self.backbone = backbone
            self.d = backbone.d

        # per-task layout agreement + the backbone evaluation uses: all
        # holders of a task must flatten through the SAME manifest
        self._task_backbone: Dict[int, object] = {}
        if self.backbones is not None:
            task_fps: Dict[int, str] = {}
            for t in range(self.con.n_tasks):
                holders = [c for c in range(self.n_clients)
                           if t in split.tasks[c]]
                if not holders:
                    continue
                bbs = [self.backbones[c] for c in holders]
                fps = {b.fingerprint for b in bbs}
                if len(fps) > 1:
                    raise TaskVectorLayoutError(
                        f"task {t} is held by clients with different "
                        f"task-vector layouts {sorted(fps)}; holders of "
                        f"one task must share a manifest")
                if len({b.feat_out for b in bbs}) > 1:
                    raise ValueError(
                        f"task {t} holders disagree on feat_out; the "
                        f"shared head needs one feature width")
                self._task_backbone[t] = bbs[0]
                task_fps[t] = bbs[0].fingerprint
            strategy.use_layouts(task_fps)

        # one jitted trainer per distinct backbone object
        self._trainers: Dict[int, object] = {}
        for bb in ([self.backbone] if self.backbones is None
                   else self.backbones.values()):
            if id(bb) not in self._trainers:
                self._trainers[id(bb)] = make_local_trainer(
                    bb, steps=cfg.local_steps, batch_size=cfg.batch_size,
                    lr=cfg.lr,
                    prox_mu=cfg.prox_mu if strategy.needs_prox else 0.0,
                    linearize=strategy.needs_linearize)
        self.trainer = (self._trainers[id(self.backbone)]
                        if self.backbones is None else None)

        # pre-sample local datasets (fixed size -> single jit signature)
        self.local_data: Dict[tuple, tuple] = {}
        for c in range(self.n_clients):
            for t in split.tasks[c]:
                self.rng, k = jax.random.split(self.rng)
                probs = split.class_probs.get((c, t))
                self.local_data[(c, t)] = sample_task_batch(
                    self.con.tasks[t], k, cfg.local_data, probs)

        # global per-task heads (averaged among holders every round);
        # sized for the task's holder backbone in mixed rounds
        self.rng, hk = jax.random.split(self.rng)
        self.heads: Dict[int, jax.Array] = {
            t: make_head(jax.random.fold_in(hk, t),
                         self._backbone_for_task(t).feat_out,
                         self.con.n_classes)
            for t in range(self.con.n_tasks)
        }
        self._eval_sets = {t: eval_batch(self.con.tasks[t])
                           for t in range(self.con.n_tasks)}

    def _backbone_for_task(self, task_id: int):
        if self.backbones is None:
            return self.backbone
        if task_id in self._task_backbone:
            return self._task_backbone[task_id]
        return next(iter(self.backbones.values()))

    def _backbone_for_client(self, c: int):
        return self.backbone if self.backbones is None else self.backbones[c]

    # -- evaluation ---------------------------------------------------------
    def task_accuracy(self, task_id: int, tv: jax.Array) -> float:
        x, y = self._eval_sets[task_id]
        bb = self._backbone_for_task(task_id)
        logits = bb.features(tv[:bb.d], x) @ self.heads[task_id]
        return float(jnp.mean(jnp.argmax(logits, -1) == y))

    def evaluate(self) -> Dict[int, float]:
        out = {}
        for t in range(self.con.n_tasks):
            vecs = self.strategy.eval_vectors(t)
            out[t] = float(np.mean([self.task_accuracy(t, v) for v in vecs]))
        return out

    # -- local training -----------------------------------------------------
    def _train_client(self, c: int, r: int, train_base: jax.Array
                      ) -> Tuple[Upload, List[tuple]]:
        """Run client ``c``'s per-task local fine-tuning for round
        ``r``.  Training keys derive from fold_in chains over (c, r, t)
        only — failure-invariant: another client's faults can never
        shift them (see module docstring)."""
        ck = jax.random.fold_in(jax.random.fold_in(train_base, c), r)
        bb = self._backbone_for_client(c)
        trainer = self._trainers[id(bb)]
        tvs, sizes, head_pairs = [], [], []
        for t in self.split.tasks[c]:
            tk = jax.random.fold_in(ck, t)
            x, y = self.local_data[(c, t)]
            # wire edge: the strategy hands out the round's common-d
            # vector; this client's manifest covers the [0, bb.d) prefix
            tv0 = self.strategy.task_init(c, t)[:bb.d]
            tv, head, _loss = trainer(tv0, self.heads[t], x, y, tk)
            tvs.append(pad_vector(tv, self.d))
            sizes.append(self.split.data_sizes[(c, t)])
            head_pairs.append((t, head, sizes[-1]))
        fp = getattr(bb, "fingerprint", None) if self.backbones is not None \
            else None
        return (Upload(c, list(self.split.tasks[c]), jnp.stack(tvs), sizes,
                       fingerprint=fp),
                head_pairs)

    # -- main loop ------------------------------------------------------------
    def run(self, verbose: bool = False) -> History:
        cfg = self.cfg
        hist = History()
        n_sel = max(1, int(round(cfg.participation * self.n_clients)))
        # failure-invariant key schedule: selection draws and per-client
        # training keys come from fold_in chains over disjoint
        # sub-bases, never from one sequentially split stream
        sel_base = jax.random.fold_in(self.rng, 0)
        train_base = jax.random.fold_in(self.rng, 1)
        sysm = self.systems
        queue = AdmissionQueue() if sysm is not None else None

        for r in range(cfg.rounds):
            counters = blank_fault_counters()
            sk = jax.random.fold_in(sel_base, r)
            if sysm is None:
                selected = np.asarray(jax.random.choice(
                    sk, self.n_clients, (n_sel,), replace=False))
            else:
                avail = [c for c in range(self.n_clients)
                         if sysm.available(c, r)]
                counters["crashed"] = self.n_clients - len(avail)
                if len(avail) == self.n_clients:
                    # IDENTICAL draw to the sync branch — the
                    # ideal-trace bit-parity anchor
                    selected = np.asarray(jax.random.choice(
                        sk, self.n_clients, (n_sel,), replace=False))
                elif avail:
                    k = min(n_sel, len(avail))
                    idx = np.asarray(jax.random.choice(
                        sk, len(avail), (k,), replace=False))
                    selected = np.asarray(avail, np.int64)[idx]
                else:
                    selected = np.asarray([], np.int64)
            counters["sampled"] = int(len(selected))

            # train sampled clients; sync admits in place, async pushes
            # into the admission queue with the trace's arrival tick
            admitted: List[Upload] = []
            head_lists: List[list] = []
            staleness: List[int] = []
            dispatch_rounds: List[int] = []
            for c in selected:
                c = int(c)
                if sysm is not None and sysm.dropout(c, r):
                    counters["dropped"] += 1
                    continue
                upload, head_pairs = self._train_client(c, r, train_base)
                if sysm is None:
                    admitted.append(upload)
                    head_lists.append(head_pairs)
                    staleness.append(0)
                else:
                    delay = sysm.delay(c, r)
                    if delay > 0:
                        counters["stragglers"] += 1
                    queue.push(r + delay, r, (upload, head_pairs))
            if sysm is not None:
                for item in queue.pop_ready(r):
                    upload, head_pairs = item.payload
                    s = r - item.dispatch
                    if s > cfg.max_staleness:
                        counters["stale"] += 1
                        continue
                    admitted.append(upload)
                    head_lists.append(head_pairs)
                    staleness.append(s)
                    dispatch_rounds.append(item.dispatch)
                counters["buffered"] = len(queue)
            counters["admitted"] = len(admitted)

            if not admitted:
                # nothing reached the server this tick: skip-and-carry
                # (History still gets a full 0-bit row for the round)
                counters["skipped"] = 1
                self.strategy.skip_round()
            elif hasattr(self.strategy, "aggregate_admitted"):
                self.strategy.aggregate_admitted(
                    RoundBatch.from_uploads(admitted, self.con.n_tasks),
                    staleness, sysm,
                    dispatch_rounds if sysm is not None else None)
            else:
                # hand the strategy ONE pre-packed batch: batched
                # strategies (MaTU's round engine) consume the padded
                # tensors directly, per-client strategies unwrap the
                # ragged uploads list
                self.strategy.aggregate_batch(RoundBatch.from_uploads(
                    admitted, self.con.n_tasks))
            quarantined = getattr(self.strategy, "last_quarantined",
                                  frozenset())
            counters["quarantined"] = len(quarantined)
            hist.fault_counts.append(counters)
            # under pipeline=True the dispatched round is still in
            # flight here: this snapshot is the most recently completed
            # round's phases (see History.phase_us)
            hist.phase_us.append(dict(self.strategy.last_phase_us or {}))

            # head averaging over the round's ADMITTED, non-quarantined
            # uploads (drain order == selection order in sync/ideal)
            new_heads: Dict[int, list] = {}
            for upload, pairs in zip(admitted, head_lists):
                if upload.client_id in quarantined:
                    continue
                for t, head, size in pairs:
                    new_heads.setdefault(t, []).append((head, size))
            for t, pairs in new_heads.items():
                w = jnp.asarray([p[1] for p in pairs], jnp.float32)
                w = w / jnp.sum(w)
                self.heads[t] = sum(wi * h for (h, _), wi in zip(pairs, w))

            bits = self.strategy.uplink_bits(admitted)
            if (r + 1) % cfg.eval_every == 0 or r == cfg.rounds - 1:
                acc = self.evaluate()
                hist.rounds.append(r + 1)
                hist.task_acc.append(acc)
                hist.mean_acc.append(float(np.mean(list(acc.values()))))
                hist.uplink_bits_per_round.append(bits)
                hist.downlink_bits_per_round.append(
                    self.strategy.downlink_bits())
                if verbose:
                    print(f"[{self.strategy.name}] round {r+1:3d} "
                          f"mean_acc={hist.mean_acc[-1]:.3f} bits={bits:,}")
        return hist


# population-mode rng stream tags — disjoint from PopulationSplit's
# (0x11/0x22/0x33) so simulator draws never collide with split draws
# under the same base seed
_POP_TARGET, _POP_UPDATE, _POP_DROP = 0x44, 0x55, 0x66


class PopulationSimulator:
    """Client-axis scale-out harness over a lazy population (see
    "Population mode" in the module docstring).

    ``clients_per_round`` defaults to ``participation · n_clients`` —
    set it (or a small ``FedConfig.participation``) explicitly for
    populations where training the whole cohort is not the point.
    ``mesh``: optional jax Mesh — the chunked round then runs sharded
    (taskvec d-axis, plus slot rows on a ``make_population_mesh``).
    ``sink``: optional per-chunk downlink consumer; the default
    discards them so no per-client state accumulates anywhere.
    """

    def __init__(self, cfg: FedConfig, split: PopulationSplit,
                 server_cfg: Optional[MaTUServerConfig] = None, *,
                 d: int = 4096, clients_per_round: Optional[int] = None,
                 chunk_clients: int = 64, step: float = 0.3,
                 noise: float = 1e-2, dropout_prob: float = 0.0,
                 code_masks: bool = False, mesh=None, sink=None):
        self.cfg = cfg
        self.split = split
        self.d = int(d)
        self.n_tasks = split.n_tasks
        self.chunk_clients = int(chunk_clients)
        self.step = float(step)
        self.noise = float(noise)
        self.dropout_prob = float(dropout_prob)
        self.code_masks = code_masks
        self.sink = sink if sink is not None else (lambda links: None)
        self.clients_per_round = int(
            clients_per_round if clients_per_round is not None
            else max(1, round(cfg.participation * split.n_clients)))
        self.server = MaTUServer(
            server_cfg or MaTUServerConfig(n_tasks=split.n_tasks), mesh=mesh)
        # hidden per-task targets the synthetic local updates drift
        # toward — O(T·d), the same footprint class as the round itself
        trg = np.random.default_rng((cfg.seed, _POP_TARGET)).standard_normal(
            (self.n_tasks, self.d)).astype(np.float32)
        self._targets = trg / np.linalg.norm(trg, axis=1, keepdims=True)
        self._tv_host = np.zeros((self.n_tasks, self.d), np.float32)

    # -- lazy client derivation --------------------------------------------
    def _dropout(self, c: int, r: int) -> bool:
        return bool(self.dropout_prob > 0.0 and np.random.default_rng(
            (self.cfg.seed, _POP_DROP, int(r), int(c))).random()
            < self.dropout_prob)

    def _make_upload(self, c: int, r: int, tv: np.ndarray) -> ClientUpload:
        """Derive client ``c``'s round-``r`` upload from scratch:
        tasks/sizes from the lazy split, update noise from the
        order-invariant (seed, round, client) stream, drift from the
        CURRENT global task vectors ``tv`` (frozen for the round, so
        the engine's two streaming passes see identical uploads)."""
        ts = self.split.tasks_for(c)
        rng = np.random.default_rng((self.cfg.seed, _POP_UPDATE,
                                     int(r), int(c)))
        rows = np.empty((len(ts), self.d), np.float32)
        sizes = []
        for i, t in enumerate(ts):
            z = rng.standard_normal(self.d).astype(np.float32)
            rows[i] = tv[t] + self.step * (self._targets[t] - tv[t]) \
                + self.noise * z
            sizes.append(self.split.local_stats(c, t)[1])
        unified, masks, lams = unify_with_modulators(jnp.asarray(rows))
        return ClientUpload(int(c), ts, unified, masks, lams, sizes)

    def _upload_factory(self, ids: List[int], r: int):
        tv = self._tv_host  # frozen snapshot for both engine passes

        def gen():
            for c in ids:
                yield self._make_upload(c, r, tv)

        return gen

    # -- evaluation ---------------------------------------------------------
    def evaluate(self) -> Dict[int, float]:
        """Per-task alignment of the server's task vector with its
        hidden target, mapped to [0, 1] (cosine → (1+cos)/2)."""
        out = {}
        for t in range(self.n_tasks):
            v, g = self._tv_host[t], self._targets[t]
            den = float(np.linalg.norm(v) * np.linalg.norm(g))
            out[t] = 0.5 * (1.0 + float(v @ g) / den) if den > 0 else 0.0
        return out

    # -- main loop ----------------------------------------------------------
    def run(self, verbose: bool = False) -> History:
        cfg = self.cfg
        hist = History()
        for r in range(cfg.rounds):
            counters = blank_fault_counters()
            ids = self.split.sample_round(r, self.clients_per_round)
            counters["sampled"] = int(len(ids))
            if self.dropout_prob > 0.0:
                keep = np.asarray([not self._dropout(int(c), r)
                                   for c in ids], bool)
                counters["dropped"] = int(len(ids) - keep.sum())
                ids = ids[keep]
            stats = {"uplink_bits": 0, "downlink_bits": 0}
            if len(ids):
                _, stats = self.server.round_chunked(
                    self._upload_factory([int(c) for c in ids], r),
                    chunk_clients=self.chunk_clients,
                    code_masks=self.code_masks, sink=self.sink)
                self._tv_host = np.asarray(self.server.last_task_vectors)
            else:
                counters["skipped"] = 1
            counters["admitted"] = int(len(ids))
            hist.fault_counts.append(counters)
            hist.phase_us.append({})
            if (r + 1) % cfg.eval_every == 0 or r == cfg.rounds - 1:
                acc = self.evaluate()
                hist.rounds.append(r + 1)
                hist.task_acc.append(acc)
                hist.mean_acc.append(float(np.mean(list(acc.values()))))
                hist.uplink_bits_per_round.append(stats["uplink_bits"])
                hist.downlink_bits_per_round.append(stats["downlink_bits"])
                if verbose:
                    print(f"[population] round {r+1:3d} "
                          f"align={hist.mean_acc[-1]:.3f} "
                          f"bits={stats['uplink_bits']:,}")
        return hist


def individual_baseline(cfg: FedConfig, constellation: Constellation,
                        backbone, *, steps_multiplier: int = 10,
                        seed: int = 0) -> Dict[int, float]:
    """Per-task centralized fine-tuning (the paper's upper bound)."""
    trainer = make_local_trainer(backbone, steps=cfg.local_steps * steps_multiplier,
                                 batch_size=cfg.batch_size, lr=cfg.lr)
    rng = jax.random.PRNGKey(seed)
    out = {}
    for t in range(constellation.n_tasks):
        rng, k1, k2, k3 = jax.random.split(rng, 4)
        x, y = sample_task_batch(constellation.tasks[t], k1, cfg.local_data * 4)
        tv0 = jnp.zeros((backbone.d,), jnp.float32)
        head0 = make_head(k2, backbone.feat_out, constellation.n_classes)
        tv, head, _ = trainer(tv0, head0, x, y, k3)
        xe, ye = eval_batch(constellation.tasks[t])
        logits = backbone.features(tv, xe) @ head
        out[t] = float(jnp.mean(jnp.argmax(logits, -1) == ye))
    return out
