"""Federated simulation harness (replaces the paper's Flower setup).

Runs R rounds of: client sampling (ξ) → per-(client, task) local
fine-tuning in flat task-vector space → strategy aggregation → global
per-task head averaging → periodic evaluation.  Produces the metrics
the paper reports: per-task accuracy, averages, and bits/round.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.dirichlet import FedSplit
from repro.data.synthetic import Constellation, eval_batch, sample_task_batch
from repro.fed.local import make_head, make_local_trainer
from repro.fed.strategies import RoundBatch, Strategy, Upload


@dataclass
class FedConfig:
    rounds: int = 20
    participation: float = 1.0       # ξ
    local_steps: int = 10            # E (steps per task per round)
    batch_size: int = 32
    local_data: int = 256            # samples per (client, task)
    lr: float = 5e-3
    prox_mu: float = 0.1
    eval_every: int = 5
    seed: int = 0
    # defer the strategy's server-round drain so the dispatched round
    # overlaps the simulator's host bookkeeping (MaTU; no-op for
    # per-client strategies).  Bit-identical to False — same ops,
    # different order (tests/test_pipeline.py).
    pipeline: bool = False


@dataclass
class History:
    rounds: List[int] = field(default_factory=list)
    task_acc: List[Dict[int, float]] = field(default_factory=list)
    mean_acc: List[float] = field(default_factory=list)
    uplink_bits_per_round: List[int] = field(default_factory=list)
    # measured off the actual downlink wire buffers (bf16 vectors +
    # bit-packed mask words, or the Golomb-Rice coded byte streams
    # under MaTUStrategy(code_masks=True)) where the strategy has
    # them; 0 otherwise.  Uplink bits follow the same rule — with the
    # coded wire both columns are real coded stream lengths.
    downlink_bits_per_round: List[int] = field(default_factory=list)
    # per-phase host/device µs of each round's server step, as reported
    # by the strategy ({"pack"/"decode"/"encode"/"device"} where the
    # strategy measures them, {} otherwise).  Under pipeline=True a
    # round's phases complete at its drain, so entry r holds the most
    # recently COMPLETED round at the time round r was recorded — one
    # behind the in-flight round.
    phase_us: List[Dict[str, float]] = field(default_factory=list)

    @property
    def final_task_acc(self) -> Dict[int, float]:
        return self.task_acc[-1] if self.task_acc else {}

    @property
    def final_mean_acc(self) -> float:
        return self.mean_acc[-1] if self.mean_acc else 0.0

    @property
    def mean_uplink_bits(self) -> float:
        b = self.uplink_bits_per_round
        return float(np.mean(b)) if b else 0.0

    @property
    def mean_downlink_bits(self) -> float:
        """Mean measured downlink wire bits per recorded round (0.0
        before any round was recorded — mirrors mean_uplink_bits)."""
        b = self.downlink_bits_per_round
        return float(np.mean(b)) if b else 0.0

    @property
    def mean_phase_us(self) -> Dict[str, float]:
        """Per-phase mean µs over the rounds that reported that phase
        ({} when the strategy measures nothing)."""
        out: Dict[str, List[float]] = {}
        for ph in self.phase_us:
            for key, us in (ph or {}).items():
                out.setdefault(key, []).append(us)
        return {k: float(np.mean(v)) for k, v in out.items()}


class FedSimulator:
    def __init__(self, cfg: FedConfig, constellation: Constellation,
                 split: FedSplit, backbone, strategy: Strategy,
                 mesh=None):
        """``mesh``: optional jax Mesh threaded to the strategy — MaTU
        then runs its server round sharded over the taskvec axis (the
        engine's sharding contract); the simulation loop itself is
        unchanged, so the same script runs on 1 device and on N."""
        self.cfg = cfg
        self.con = constellation
        self.split = split
        self.backbone = backbone
        self.strategy = strategy
        self.mesh = mesh
        if mesh is not None:
            strategy.use_mesh(mesh)
        strategy.use_pipeline(cfg.pipeline)
        self.rng = jax.random.PRNGKey(cfg.seed)
        self.n_clients = len(split.tasks)

        self.trainer = make_local_trainer(
            backbone, steps=cfg.local_steps, batch_size=cfg.batch_size,
            lr=cfg.lr,
            prox_mu=cfg.prox_mu if strategy.needs_prox else 0.0,
            linearize=strategy.needs_linearize)

        # pre-sample local datasets (fixed size -> single jit signature)
        self.local_data: Dict[tuple, tuple] = {}
        for c in range(self.n_clients):
            for t in split.tasks[c]:
                self.rng, k = jax.random.split(self.rng)
                probs = split.class_probs.get((c, t))
                self.local_data[(c, t)] = sample_task_batch(
                    self.con.tasks[t], k, cfg.local_data, probs)

        # global per-task heads (averaged among holders every round)
        self.rng, hk = jax.random.split(self.rng)
        self.heads: Dict[int, jax.Array] = {
            t: make_head(jax.random.fold_in(hk, t), backbone.feat_out,
                         self.con.n_classes)
            for t in range(self.con.n_tasks)
        }
        self._eval_sets = {t: eval_batch(self.con.tasks[t])
                           for t in range(self.con.n_tasks)}

    # -- evaluation ---------------------------------------------------------
    def task_accuracy(self, task_id: int, tv: jax.Array) -> float:
        x, y = self._eval_sets[task_id]
        logits = self.backbone.features(tv, x) @ self.heads[task_id]
        return float(jnp.mean(jnp.argmax(logits, -1) == y))

    def evaluate(self) -> Dict[int, float]:
        out = {}
        for t in range(self.con.n_tasks):
            vecs = self.strategy.eval_vectors(t)
            out[t] = float(np.mean([self.task_accuracy(t, v) for v in vecs]))
        return out

    # -- main loop ------------------------------------------------------------
    def run(self, verbose: bool = False) -> History:
        cfg = self.cfg
        hist = History()
        n_sel = max(1, int(round(cfg.participation * self.n_clients)))

        for r in range(cfg.rounds):
            self.rng, sk = jax.random.split(self.rng)
            selected = np.asarray(
                jax.random.choice(sk, self.n_clients, (n_sel,), replace=False))

            uploads: List[Upload] = []
            new_heads: Dict[int, list] = {}
            for c in selected:
                c = int(c)
                tvs, sizes = [], []
                for t in self.split.tasks[c]:
                    self.rng, tk = jax.random.split(self.rng)
                    x, y = self.local_data[(c, t)]
                    tv0 = self.strategy.task_init(c, t)
                    tv, head, _loss = self.trainer(tv0, self.heads[t], x, y, tk)
                    tvs.append(tv)
                    sizes.append(self.split.data_sizes[(c, t)])
                    new_heads.setdefault(t, []).append((head, sizes[-1]))
                uploads.append(Upload(c, list(self.split.tasks[c]),
                                      jnp.stack(tvs), sizes))

            # hand the strategy ONE pre-packed batch: batched strategies
            # (MaTU's round engine) consume the padded tensors directly,
            # per-client strategies unwrap the ragged uploads list
            self.strategy.aggregate_batch(RoundBatch.from_uploads(
                uploads, self.con.n_tasks))
            # under pipeline=True the dispatched round is still in
            # flight here: this snapshot is the most recently completed
            # round's phases (see History.phase_us)
            hist.phase_us.append(dict(self.strategy.last_phase_us or {}))
            for t, pairs in new_heads.items():
                w = jnp.asarray([p[1] for p in pairs], jnp.float32)
                w = w / jnp.sum(w)
                self.heads[t] = sum(wi * h for (h, _), wi in zip(pairs, w))

            bits = self.strategy.uplink_bits(uploads)
            if (r + 1) % cfg.eval_every == 0 or r == cfg.rounds - 1:
                acc = self.evaluate()
                hist.rounds.append(r + 1)
                hist.task_acc.append(acc)
                hist.mean_acc.append(float(np.mean(list(acc.values()))))
                hist.uplink_bits_per_round.append(bits)
                hist.downlink_bits_per_round.append(
                    self.strategy.downlink_bits())
                if verbose:
                    print(f"[{self.strategy.name}] round {r+1:3d} "
                          f"mean_acc={hist.mean_acc[-1]:.3f} bits={bits:,}")
        return hist


def individual_baseline(cfg: FedConfig, constellation: Constellation,
                        backbone, *, steps_multiplier: int = 10,
                        seed: int = 0) -> Dict[int, float]:
    """Per-task centralized fine-tuning (the paper's upper bound)."""
    trainer = make_local_trainer(backbone, steps=cfg.local_steps * steps_multiplier,
                                 batch_size=cfg.batch_size, lr=cfg.lr)
    rng = jax.random.PRNGKey(seed)
    out = {}
    for t in range(constellation.n_tasks):
        rng, k1, k2, k3 = jax.random.split(rng, 4)
        x, y = sample_task_batch(constellation.tasks[t], k1, cfg.local_data * 4)
        tv0 = jnp.zeros((backbone.d,), jnp.float32)
        head0 = make_head(k2, backbone.feat_out, constellation.n_classes)
        tv, head, _ = trainer(tv0, head0, x, y, k3)
        xe, ye = eval_batch(constellation.tasks[t])
        logits = backbone.features(tv, xe) @ head
        out[t] = float(jnp.mean(jnp.argmax(logits, -1) == ye))
    return out
