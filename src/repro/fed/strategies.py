"""Federated aggregation strategies: MaTU + all paper baselines.

The simulator calls, per round:
  ``task_init(client, task_index)``    → τ to start local training from
  ``aggregate(uploads)``               → server step (strategy state)
  ``eval_vectors(task_id)``            → list of τ to evaluate for a task
  ``uplink_bits(uploads)``             → communicated bits this round

``uploads`` is a list of :class:`Upload` (one per client) carrying the
per-task fine-tuned vectors.  Each strategy decides what is *actually*
transmitted (MaTU: unified vector + modulators; others: per-task
adapters) — uplink accounting reflects that, reproducing the bpt
columns of Tables 1–2.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import (cosine_similarity_matrix, greedy_group,
                                  ties_merge, weighted_average)
from repro.core.client import ClientDownlink, ClientUpload
from repro.core.engine import (STALENESS_DISCOUNT, batched_client_unify,
                               pack_from_slots, _round_up_pow2)
from repro.kernels import bitpack
from repro.core.server import MaTUServer, MaTUServerConfig
from repro.core.unify import modulate, unify_with_modulators

FLOAT_BITS = 32


@dataclass
class Upload:
    client_id: int
    task_ids: List[int]
    task_vectors: jax.Array     # (k, d) fine-tuned vectors, one per task
    data_sizes: List[int]
    # TaskVectorSpace manifest fingerprint of the client's backbone —
    # the layout the rows were flattened through (zero-padded past the
    # manifest's own d up to the round's common d).  None for legacy
    # homogeneous rounds; when the strategy has expected layouts
    # installed (``use_layouts``) a mismatch aborts BEFORE aggregation.
    fingerprint: Optional[str] = None


@dataclass
class RoundBatch:
    """One round's uploads, with fixed-shape slot-packed batch tensors
    built lazily on first access.

    The simulator hands this to every strategy once per round;
    strategies that batch their server step (MaTU's round engine) touch
    the padded tensors and pay the O(N·k_max·d) pack exactly once,
    while per-client strategies only ever read the ragged ``uploads``
    list and never trigger it.  Slot axis is padded to a power of two
    so ragged k_n keeps a static jit signature across rounds.
    """
    uploads: List[Upload]
    n_tasks: int
    k_max: int
    _packed: Optional[tuple] = None

    @classmethod
    def from_uploads(cls, uploads: List["Upload"], n_tasks: int,
                     k_max: Optional[int] = None) -> "RoundBatch":
        k_max = k_max or _round_up_pow2(max(len(u.task_ids) for u in uploads))
        return cls(list(uploads), n_tasks, k_max)

    def _pack(self) -> tuple:
        if self._packed is None:
            n = len(self.uploads)
            d = int(self.uploads[0].task_vectors.shape[-1])
            tvs = np.zeros((n, self.k_max, d), np.float32)
            valid = np.zeros((n, self.k_max), bool)
            slot_tasks = np.full((n, self.k_max), self.n_tasks, np.int32)
            slot_sizes = np.zeros((n, self.k_max), np.float32)
            for i, u in enumerate(self.uploads):
                k = len(u.task_ids)
                tvs[i, :k] = np.asarray(u.task_vectors, np.float32)
                valid[i, :k] = True
                slot_tasks[i, :k] = u.task_ids
                slot_sizes[i, :k] = u.data_sizes
            self._packed = (jnp.asarray(tvs), jnp.asarray(valid),
                            jnp.asarray(slot_tasks), jnp.asarray(slot_sizes))
        return self._packed

    @property
    def task_vectors(self) -> jax.Array:   # (N, k_max, d) zero-padded stacks
        return self._pack()[0]

    @property
    def valid(self) -> jax.Array:          # (N, k_max) bool
        return self._pack()[1]

    @property
    def slot_tasks(self) -> jax.Array:     # (N, k_max) int32; n_tasks sentinel
        return self._pack()[2]

    @property
    def slot_sizes(self) -> jax.Array:     # (N, k_max) fp32
        return self._pack()[3]

    @property
    def client_ids(self) -> List[int]:
        return [u.client_id for u in self.uploads]

    @property
    def task_ids(self) -> List[List[int]]:
        return [list(u.task_ids) for u in self.uploads]


class Strategy:
    name = "base"
    needs_prox = False
    needs_linearize = False
    # per-phase host/device µs of the most recently COMPLETED server
    # round ({"pack"/"decode"/"encode"/"device"} where applicable);
    # None for strategies that don't measure
    last_phase_us: Optional[Dict[str, float]] = None

    def __init__(self, n_tasks: int, d: int):
        self.n_tasks, self.d = n_tasks, d
        # task id -> expected TaskVectorSpace fingerprint (use_layouts)
        self.expected_layouts: Optional[Dict[int, str]] = None

    def task_init(self, client_id: int, task_id: int) -> jax.Array:
        raise NotImplementedError

    def use_layouts(self, task_fingerprints: Dict[int, str]) -> None:
        """Install the server's expected per-task layout fingerprints.
        Every subsequent round verifies each upload's manifest
        fingerprint against the tasks it holds BEFORE aggregation —
        see :meth:`verify_layouts`."""
        self.expected_layouts = dict(task_fingerprints)

    def verify_layouts(self, uploads: List[Upload]) -> None:
        """Client/server layout agreement check (the abort-before-
        aggregate half of the task-vector layout contract): raises
        :class:`~repro.common.tree.TaskVectorLayoutError` when an
        upload's manifest fingerprint disagrees with the server's
        expectation for any task it holds.  No-op until
        :meth:`use_layouts` installs expectations; uploads without a
        fingerprint (legacy homogeneous rounds) pass."""
        exp = self.expected_layouts
        if not exp:
            return
        from repro.common.tree import TaskVectorLayoutError
        for u in uploads:
            fp = getattr(u, "fingerprint", None)
            if fp is None:
                continue
            for t in u.task_ids:
                want = exp.get(t)
                if want is not None and want != fp:
                    raise TaskVectorLayoutError(
                        f"client {u.client_id} uploads task {t} flattened "
                        f"through manifest {fp}, server expects {want}; "
                        f"refusing to aggregate")

    def aggregate(self, uploads: List[Upload]) -> None:
        raise NotImplementedError

    def aggregate_batch(self, batch: RoundBatch) -> None:
        """Server step from a pre-packed batch; the default unwraps to
        the ragged per-client path.  Batched strategies override."""
        self.verify_layouts(batch.uploads)
        self.aggregate(batch.uploads)

    def use_mesh(self, mesh) -> None:
        """Install a device mesh for strategies whose server step can
        run sharded (MaTU's taskvec-sharded round engine); the default
        is a no-op so per-client strategies ignore it."""

    def use_pipeline(self, on: bool) -> None:
        """Enable the deferred-drain server pipeline for strategies
        that support it (MaTU overlaps the dispatched round with host
        bookkeeping); the default is a no-op so per-client strategies
        ignore it."""

    def skip_round(self) -> None:
        """Called INSTEAD of ``aggregate_batch`` when a round admits no
        uploads (every sampled client dropped out, crashed, or went
        stale): carry all server state unchanged so the simulator can
        record a 0-bit History row and keep going.  The default is a
        no-op — stateless-per-round strategies already carry."""

    def eval_vectors(self, task_id: int) -> List[jax.Array]:
        raise NotImplementedError

    def uplink_bits(self, uploads: List[Upload]) -> int:
        # default: one adapter per task per client (fp32)
        return sum(FLOAT_BITS * self.d * len(u.task_ids) for u in uploads)

    def downlink_bits(self) -> int:
        """Measured downlink wire bits of the last round (0 where the
        strategy has no explicit downlink tensors)."""
        return 0


# ---------------------------------------------------------------------------
class MaTUStrategy(Strategy):
    name = "matu"

    def __init__(self, n_tasks: int, d: int, *, rho: float = 0.4,
                 eps: float = 0.5, kappa: int = 3, cross_task: bool = True,
                 uniform_cross: bool = False, compress: bool = False,
                 code_masks: bool = False, pipeline: bool = False,
                 chunk_clients: Optional[int] = None, mesh=None):
        super().__init__(n_tasks, d)
        self.mesh = mesh
        self.server = MaTUServer(MaTUServerConfig(
            n_tasks=n_tasks, rho=rho, eps=eps, kappa=kappa,
            cross_task=cross_task, uniform_cross=uniform_cross), mesh=mesh)
        self.downlinks: Dict[int, ClientDownlink] = {}
        self.client_tasks: Dict[int, List[int]] = {}
        # ``code_masks``: ship the Golomb-Rice entropy-coded mask wire
        # both ways (repro.fed.compression) — uplink streams are built
        # from the same packed words the engine computes on, downlink
        # streams decoded by clients on use; up/downlink bits are then
        # measured off the actual coded byte streams.  ``compress``
        # (legacy accounting flag) swaps the UPLINK accounting for the
        # coder's measured size without shipping the streams.
        self.code_masks = code_masks
        self.compress = compress
        # ``pipeline``: defer the round's drain (block + downlink
        # encode) until its results are first NEEDED (next task_init /
        # downlink_bits), so the async-dispatched jitted round overlaps
        # the simulator's host bookkeeping between rounds.  Same ops in
        # a different order — bit-identical to pipeline=False (the
        # tests/test_pipeline.py contract).
        self.pipeline = pipeline
        # ``chunk_clients``: route the server step through the engine's
        # chunked-slot fold (``MaTUServer.round_chunked``) so its slot
        # tensors stay O(chunk_clients) instead of O(N) — the
        # population-scale engine path under the regular simulator.
        # Bit-identical to the batched path in ref mode; synchronous
        # (downlinks stream out of phase C chunk by chunk, so there is
        # no deferred drain to pipeline).  With ``code_masks`` the
        # DOWNLINK still ships coded; the uplink stays raw packed words
        # (per-chunk uplink coding is the async/population wire's job).
        self.chunk_clients = chunk_clients
        self._pending = None     # (packed, out, phase_us, t_dispatch)
        self._last_uploads: List[ClientUpload] = []

    def use_mesh(self, mesh) -> None:
        """Shard the server round over the taskvec axis of ``mesh``
        (None restores the single-device path)."""
        self._drain()
        self.mesh = mesh
        self.server.use_mesh(mesh)

    def use_pipeline(self, on: bool) -> None:
        """Toggle the deferred-drain pipeline (drains any in-flight
        round first so toggling mid-run is safe)."""
        self._drain()
        self.pipeline = on

    def _drain(self) -> None:
        """Finish the in-flight round, if any: block on the device
        step, batch-encode + install its downlinks, record timings."""
        if self._pending is None:
            return
        packed, out, phase, t_disp = self._pending
        self._pending = None
        jax.block_until_ready(out)
        phase["device"] = (time.perf_counter() - t_disp) * 1e6
        self.downlinks.update(self.server.finish_round(
            packed, out, code_masks=self.code_masks, phase_us=phase))
        self.last_phase_us = phase

    def task_init(self, client_id: int, task_id: int) -> jax.Array:
        self._drain()
        dl = self.downlinks.get(client_id)
        if dl is None:
            return jnp.zeros((self.d,), jnp.float32)
        i = self.client_tasks[client_id].index(task_id)
        return modulate(dl.unified, dl.mask_row(i), dl.lams[i])

    def aggregate(self, uploads: List[Upload]) -> None:
        self.aggregate_batch(RoundBatch.from_uploads(uploads, self.n_tasks))

    def aggregate_batch(self, batch: RoundBatch) -> None:
        """Fully batched round: ONE fused kernel call unifies every
        client's upload straight into the wire format (bf16 unified
        vectors + bit-packed uint32 mask words), one scatter packs the
        round, and the engine runs Eq. 3–7 + downlink re-unification in
        a single jitted step over the packed tensors — the uplink is
        byte-identical to what the engine computes on, so communication
        accounting is measured off these buffers, not simulated.  With
        a mesh installed both steps run sharded over the taskvec axis
        (the wire tensors are born with the d-axis NamedSharding and
        never reshard between unify and round).  With ``pipeline`` the
        round is left dispatched-but-undrained on return (downlinks
        materialise at first use); either way at most one round is ever
        in flight."""
        self.verify_layouts(batch.uploads)
        if self.chunk_clients:
            self._aggregate_chunked(batch)
            return
        self._drain()
        phase: Dict[str, float] = {}
        t0 = time.perf_counter()
        unified, mask_words, lams = batched_client_unify(
            batch.task_vectors, batch.valid, mesh=self.mesh)
        packed = pack_from_slots(batch.client_ids, batch.task_ids, unified,
                                 mask_words, lams, batch.slot_tasks,
                                 batch.valid, batch.slot_sizes, self.n_tasks,
                                 d=self.d, mesh=self.mesh)
        out = self.server.start_round(packed)     # async dispatch
        t_disp = time.perf_counter()
        phase["pack"] = (t_disp - t0) * 1e6
        dw = bitpack.packed_width(self.d)
        ks = [len(u.task_ids) for u in batch.uploads]
        if self.code_masks:
            # the coded uplink: every client's packed word rows — the
            # exact bytes the engine computes on — entropy-coded in ONE
            # batched call (np.asarray blocks only on the unify kernel,
            # not the in-flight round) and split back per client by the
            # self-delimiting record sizes
            from repro.fed.compression import encode_mask_rows_with_sizes
            t1 = time.perf_counter()
            words_np = np.asarray(mask_words)
            rows = words_np[np.repeat(np.arange(len(ks)), ks),
                            np.concatenate([np.arange(k, dtype=np.int64)
                                            for k in ks])][:, :dw]
            stream, sizes = encode_mask_rows_with_sizes(rows, self.d)
            ends = np.cumsum(sizes)
            up_masks, b0, r0 = [], 0, 0
            for k in ks:
                b1 = int(ends[r0 + k - 1]) if k else b0
                up_masks.append(jnp.asarray(stream[b0:b1]))
                b0, r0 = b1, r0 + k
            phase["encode"] = (time.perf_counter() - t1) * 1e6
        else:
            up_masks = [mask_words[i, :k, :dw]
                        for i, k in enumerate(ks)]
        self._last_uploads = [
            ClientUpload(u.client_id, list(u.task_ids),
                         unified[i, :self.d], up_masks[i],
                         lams[i, :len(u.task_ids)], list(u.data_sizes))
            for i, u in enumerate(batch.uploads)
        ]
        for u in batch.uploads:
            self.client_tasks[u.client_id] = list(u.task_ids)
        self._pending = (packed, out, phase, t_disp)
        if not self.pipeline:
            self._drain()

    def _aggregate_chunked(self, batch: RoundBatch) -> None:
        """Chunked server step: the SAME wire buffers as the batched
        path (one fused unify — bit-parity with ``aggregate_batch``
        requires the identical bf16/packed-word rounding), streamed
        through ``MaTUServer.round_chunked`` so the engine never
        materialises the O(N·k_max·d/32) slot tensors."""
        self._drain()
        phase: Dict[str, float] = {}
        t0 = time.perf_counter()
        unified, mask_words, lams = batched_client_unify(
            batch.task_vectors, batch.valid, mesh=self.mesh)
        dw = bitpack.packed_width(self.d)
        ups = []
        for i, u in enumerate(batch.uploads):
            k = len(u.task_ids)
            ups.append(ClientUpload(u.client_id, list(u.task_ids),
                                    unified[i, :self.d],
                                    mask_words[i, :k, :dw], lams[i, :k],
                                    list(u.data_sizes)))
            self.client_tasks[u.client_id] = list(u.task_ids)
        phase["pack"] = (time.perf_counter() - t0) * 1e6
        t1 = time.perf_counter()
        downs, _ = self.server.round_chunked(
            ups, chunk_clients=self.chunk_clients,
            code_masks=self.code_masks)
        phase["device"] = (time.perf_counter() - t1) * 1e6
        self.downlinks.update(downs)
        self._last_uploads = ups
        self.last_phase_us = phase

    def skip_round(self) -> None:
        """Empty round: drain any in-flight round, then clear the
        per-round wire accounting so ``uplink_bits`` / ``downlink_bits``
        report 0 for the skipped round.  The unified per-task vectors,
        similarity, and every client's cached downlink stay exactly as
        the last aggregated round left them (skip-and-carry)."""
        self._drain()
        self._last_uploads = []
        self.last_phase_us = {}

    def eval_vectors(self, task_id: int) -> List[jax.Array]:
        return [self.server.last_task_vectors[task_id]]

    def uplink_bits(self, uploads: List[Upload]) -> int:
        if self._last_uploads:
            if self.compress and not self.code_masks:
                # accounting-only: the coder's measured size for masks
                # that actually travelled as raw packed words
                from repro.fed.compression import compressed_uplink_bits
                return sum(compressed_uplink_bits(u.unified, u.masks)
                           for u in self._last_uploads)
            # measured: the bits of the actual wire buffers (bf16
            # vector + packed words or coded streams + fp32 scalers)
            return sum(u.uplink_bits() for u in self._last_uploads)
        # paper accounting fallback (no wire buffers built yet):
        # ONE unified fp32 vector + per task (binary mask + scalar)
        from repro.core.client import paper_link_bits
        return sum(paper_link_bits(self.d, len(u.task_ids), FLOAT_BITS)
                   for u in uploads)

    def downlink_bits(self) -> int:
        """Measured downlink wire bits of the LAST round only: the
        ``downlinks`` dict is the persistent per-client state cache
        (``task_init`` needs every client ever served), so sum just the
        clients actually served this round."""
        self._drain()
        return sum(self.downlinks[u.client_id].downlink_bits()
                   for u in self._last_uploads
                   if u.client_id in self.downlinks)


# ---------------------------------------------------------------------------
class AsyncMaTUStrategy(MaTUStrategy):
    """Buffered, staleness-aware, fault-tolerant MaTU server step for
    the async simulator mode (``FedSimulator(..., systems=...)``).

    Extends :class:`MaTUStrategy` with the four async concerns:

    * **staleness-discounted λ** — an admitted upload dispatched at
      round q and folded at round r carries staleness ``s = r − q``;
      its slots enter Eq. 3 with weight ``w = staleness_discount**s``
      (``PackedRound.slot_weights``, applied inside the jitted round as
      λ·w and size·w).  ``s = 0`` gives w = 1 exactly, which together
      with the sync-identical drain order makes the ideal-trace async
      round bit-identical to the sync path.
    * **validating decode + quarantine** — when the trace can corrupt
      (``systems.injects_corruption``), each client's coded stream is
      CRC-framed (``repro.fed.systems.wrap_stream``), tampered per the
      fault model, then validated (frame check + full entropy decode);
      uploads raising :class:`~repro.fed.systems.WireFrameError` or
      :class:`~repro.fed.compression.CodedStreamError` are quarantined:
      left out of the packed round entirely (their client ids are in
      ``last_quarantined``; their bytes still count as uplink traffic).
    * **dark-task carry + decay** — per-task last-seen vectors: a task
      aggregated this round refreshes bitwise (age 0); a dark task ages
      and decays toward the unified vector of the seen tasks,
      ``τ_t ← (1 − β)·τ_t + β·unify(seen τ)`` (``β = dark_decay``), so
      ``eval_vectors`` and the carried ``similarity`` stay well-posed
      through long dark spells instead of collapsing to the engine's
      zero rows.
    * **skip-and-carry** — an all-quarantined or empty round advances
      the ages and carries every other state unchanged.
    """
    name = "matu-async"

    def __init__(self, n_tasks: int, d: int, *,
                 staleness_discount: float = STALENESS_DISCOUNT,
                 dark_decay: float = 0.25, **kw):
        super().__init__(n_tasks, d, **kw)
        self.staleness_discount = float(staleness_discount)
        self.dark_decay = float(dark_decay)
        # rounds since each task was last aggregated (0 = this round)
        self.task_age = np.zeros(n_tasks, np.int64)
        self._task_seen = np.zeros(n_tasks, bool)
        self._task_vecs = jnp.zeros((n_tasks, d), jnp.float32)
        self.last_quarantined: frozenset = frozenset()

    # -- carried per-task state ---------------------------------------------
    def _age_and_decay(self, held, decay: bool = True) -> None:
        """Refresh ages for ``held`` tasks; age every dark task and pull
        the ever-seen dark ones toward the unified vector of the seen
        task stack (the decay target the engine docstring documents).
        ``decay=False`` (skipped / all-quarantined rounds, where no
        engine round ran) only advances the ages — pure carry."""
        dark = np.ones(self.n_tasks, bool)
        if held:
            held_idx = np.asarray(sorted(held), np.int64)
            dark[held_idx] = False
            self.task_age[held_idx] = 0
            self._task_seen[held_idx] = True
        self.task_age[dark] += 1
        decay_idx = np.flatnonzero(dark & self._task_seen) if decay \
            else np.empty(0, np.int64)
        if decay_idx.size:
            seen_rows = jnp.asarray(np.flatnonzero(self._task_seen))
            u = unify_with_modulators(self._task_vecs[seen_rows])[0]
            beta = self.dark_decay
            rows = jnp.asarray(decay_idx)
            self._task_vecs = self._task_vecs.at[rows].set(
                (1.0 - beta) * self._task_vecs[rows] + beta * u[None, :])

    @property
    def similarity(self) -> np.ndarray:
        """Carried Eq. 5 sign-similarity over the last-seen task
        vectors — rows for dark tasks decay toward the unified vector's
        row (never NaN, never the engine's hard zeros).  Computed
        lazily on the host so reading it is the only sync point."""
        v = np.asarray(self._task_vecs)
        s = np.sign(v)
        sim = 0.5 * ((s @ s.T) / max(v.shape[1], 1) + 1.0)
        seen = self._task_seen.astype(np.float32)
        return (sim * seen[None, :] * seen[:, None]).astype(np.float32)

    def eval_vectors(self, task_id: int) -> List[jax.Array]:
        return [self._task_vecs[task_id]]

    def skip_round(self) -> None:
        super().skip_round()
        self.last_quarantined = frozenset()
        self._age_and_decay(set(), decay=False)

    def aggregate_batch(self, batch: RoundBatch) -> None:
        self.aggregate_admitted(batch, [0] * len(batch.uploads))

    def aggregate_admitted(self, batch: RoundBatch, staleness: List[int],
                           systems=None,
                           dispatch_rounds: Optional[List[int]] = None
                           ) -> int:
        """Server step over the admission queue's drain: validate (and
        possibly quarantine) each upload, then run the engine round
        over the survivors with the staleness-discounted slot weights.
        Returns the number of uploads actually aggregated (0 when every
        admitted upload was quarantined — the caller should treat that
        like a skipped round for head updates)."""
        self.verify_layouts(batch.uploads)
        self._drain()
        inject = (systems is not None and systems.injects_corruption
                  and dispatch_rounds is not None)
        if inject and not self.code_masks:
            raise ValueError("wire fault injection (corrupt_prob > 0) "
                             "tampers the CODED mask streams — construct "
                             "AsyncMaTUStrategy(code_masks=True)")
        phase: Dict[str, float] = {}
        t0 = time.perf_counter()
        unified, mask_words, lams = batched_client_unify(
            batch.task_vectors, batch.valid, mesh=self.mesh)
        ks = [len(u.task_ids) for u in batch.uploads]
        dw = bitpack.packed_width(self.d)
        quarantined: List[int] = []
        if self.code_masks:
            from repro.fed.compression import (CodedStreamError,
                                               decode_mask_rows,
                                               encode_mask_rows_with_sizes)
            t1 = time.perf_counter()
            words_np = np.asarray(mask_words)
            rows = words_np[np.repeat(np.arange(len(ks)), ks),
                            np.concatenate([np.arange(k, dtype=np.int64)
                                            for k in ks])][:, :dw]
            stream, sizes = encode_mask_rows_with_sizes(rows, self.d)
            ends = np.cumsum(sizes)
            streams, b0, r0 = [], 0, 0
            for k in ks:
                b1 = int(ends[r0 + k - 1]) if k else b0
                streams.append(stream[b0:b1])
                b0, r0 = b1, r0 + k
            phase["encode"] = (time.perf_counter() - t1) * 1e6
            if inject:
                from repro.fed.systems import (WireFrameError, unwrap_stream,
                                               wrap_stream)
                framed = [wrap_stream(s) for s in streams]
                for i, u in enumerate(batch.uploads):
                    if systems.corrupt(u.client_id, dispatch_rounds[i]):
                        framed[i] = systems.tamper(framed[i], u.client_id,
                                                   dispatch_rounds[i])
                # validating decode: CRC frame first, then the full
                # entropy decode — malformed uploads never reach the
                # slot tensors
                for i, k in enumerate(ks):
                    try:
                        decode_mask_rows(unwrap_stream(framed[i]),
                                         self.d, k)
                    except (WireFrameError, CodedStreamError):
                        quarantined.append(i)
                streams = framed
            up_masks = [jnp.asarray(s) for s in streams]
        else:
            up_masks = [mask_words[i, :k, :dw] for i, k in enumerate(ks)]

        # wire accounting covers every admitted upload — including the
        # quarantined ones (their bytes travelled), framed when fault
        # injection is active
        self._last_uploads = [
            ClientUpload(u.client_id, list(u.task_ids),
                         unified[i, :self.d], up_masks[i],
                         lams[i, :len(u.task_ids)], list(u.data_sizes))
            for i, u in enumerate(batch.uploads)
        ]
        self.last_quarantined = frozenset(
            batch.uploads[i].client_id for i in quarantined)

        keep = [i for i in range(len(ks)) if i not in set(quarantined)]
        if not keep:
            # everything admitted this round was malformed: no engine
            # round runs; carry state like a skipped round
            self.last_phase_us = phase
            self._age_and_decay(set(), decay=False)
            return 0

        cids = [batch.client_ids[i] for i in keep]
        tids = [batch.task_ids[i] for i in keep]
        stale = [int(staleness[i]) for i in keep]
        if quarantined:
            sel = jnp.asarray(np.asarray(keep, np.int64))
            unified_k, words_k, lams_k = (unified[sel], mask_words[sel],
                                          lams[sel])
            tasks_k, valid_k, sizes_k = (batch.slot_tasks[sel],
                                         batch.valid[sel],
                                         batch.slot_sizes[sel])
        else:
            unified_k, words_k, lams_k = unified, mask_words, lams
            tasks_k, valid_k, sizes_k = (batch.slot_tasks, batch.valid,
                                         batch.slot_sizes)
        slot_weights = None
        if any(stale):
            w = (np.float32(self.staleness_discount)
                 ** np.asarray(stale, np.float32))
            slot_weights = jnp.asarray(np.ascontiguousarray(
                np.broadcast_to(w[:, None], (len(keep), batch.k_max))))
        packed = pack_from_slots(cids, tids, unified_k, words_k, lams_k,
                                 tasks_k, valid_k, sizes_k, self.n_tasks,
                                 d=self.d, mesh=self.mesh,
                                 slot_weights=slot_weights)
        out = self.server.start_round(packed)     # async dispatch
        t_disp = time.perf_counter()
        phase["pack"] = (t_disp - t0) * 1e6 - phase.get("encode", 0.0)
        for i in keep:
            u = batch.uploads[i]
            self.client_tasks[u.client_id] = list(u.task_ids)
        self._pending = (packed, out, phase, t_disp)

        # carried per-task state: held tasks refresh bitwise from the
        # round output; dark tasks age and decay toward the unified
        held = {t for i in keep for t in batch.task_ids[i]}
        rows = jnp.asarray(sorted(held))
        self._task_vecs = self._task_vecs.at[rows].set(
            out.task_vectors[rows])
        self._age_and_decay(held)
        if not self.pipeline:
            self._drain()
        return len(keep)


# ---------------------------------------------------------------------------
class FedAvgStrategy(Strategy):
    name = "fedavg"

    def __init__(self, n_tasks: int, d: int):
        super().__init__(n_tasks, d)
        self.global_v = jnp.zeros((d,), jnp.float32)

    def task_init(self, client_id: int, task_id: int) -> jax.Array:
        return self.global_v

    def aggregate(self, uploads: List[Upload]) -> None:
        vecs, weights = [], []
        for u in uploads:
            for i, _t in enumerate(u.task_ids):
                vecs.append(u.task_vectors[i])
                weights.append(float(u.data_sizes[i]))
        self.global_v = weighted_average(jnp.stack(vecs), jnp.asarray(weights))

    def eval_vectors(self, task_id: int) -> List[jax.Array]:
        return [self.global_v]


class FedProxStrategy(FedAvgStrategy):
    name = "fedprox"
    needs_prox = True


class NTKFedAvgStrategy(FedAvgStrategy):
    """NTK-FedAvg: same server merge, but clients train the linearised
    model (jvp at the pretrained point) — see repro.fed.local."""
    name = "ntk-fedavg"
    needs_linearize = True


class TIESStrategy(Strategy):
    name = "ties"

    def __init__(self, n_tasks: int, d: int, keep_frac: float = 0.2):
        super().__init__(n_tasks, d)
        self.keep_frac = keep_frac
        self.global_v = jnp.zeros((d,), jnp.float32)

    def task_init(self, client_id: int, task_id: int) -> jax.Array:
        return self.global_v

    def aggregate(self, uploads: List[Upload]) -> None:
        vecs = [u.task_vectors[i] for u in uploads for i in range(len(u.task_ids))]
        self.global_v = ties_merge(jnp.stack(vecs), keep_frac=self.keep_frac)

    def eval_vectors(self, task_id: int) -> List[jax.Array]:
        return [self.global_v]


# ---------------------------------------------------------------------------
class FedPerStrategy(Strategy):
    """FedPer: shared slice averaged globally; personal slice (later
    layers) kept per-client.  Heads are always personal in our harness."""
    name = "fedper"

    def __init__(self, n_tasks: int, d: int, split_point: int):
        super().__init__(n_tasks, d)
        self.split = split_point
        self.shared = jnp.zeros((split_point,), jnp.float32)
        self.personal: Dict[int, jax.Array] = {}
        self.holders: Dict[int, List[int]] = {t: [] for t in range(n_tasks)}

    def task_init(self, client_id: int, task_id: int) -> jax.Array:
        pers = self.personal.get(client_id, jnp.zeros((self.d - self.split,), jnp.float32))
        return jnp.concatenate([self.shared, pers])

    def aggregate(self, uploads: List[Upload]) -> None:
        shared_vecs, weights = [], []
        for u in uploads:
            mean_tv = jnp.mean(u.task_vectors, axis=0)
            shared_vecs.append(mean_tv[: self.split])
            weights.append(float(sum(u.data_sizes)))
            self.personal[u.client_id] = mean_tv[self.split:]
            for t in u.task_ids:
                if u.client_id not in self.holders[t]:
                    self.holders[t].append(u.client_id)
        self.shared = weighted_average(jnp.stack(shared_vecs), jnp.asarray(weights))

    def eval_vectors(self, task_id: int) -> List[jax.Array]:
        out = []
        for c in self.holders[task_id]:
            pers = self.personal.get(c)
            if pers is not None:
                out.append(jnp.concatenate([self.shared, pers]))
        return out or [jnp.concatenate([self.shared,
                                        jnp.zeros((self.d - self.split,), jnp.float32)])]

    def uplink_bits(self, uploads: List[Upload]) -> int:
        # clients transmit only the shared slice (per task)
        return sum(FLOAT_BITS * self.split * len(u.task_ids) for u in uploads)


# ---------------------------------------------------------------------------
class MaTFLStrategy(Strategy):
    """MaT-FL (Cai et al. 2023): dynamic grouping by cosine similarity of
    client updates; aggregation within groups only."""
    name = "mat-fl"

    def __init__(self, n_tasks: int, d: int, threshold: float = 0.0):
        super().__init__(n_tasks, d)
        self.threshold = threshold
        self.client_v: Dict[int, jax.Array] = {}
        self.holders: Dict[int, List[int]] = {t: [] for t in range(n_tasks)}

    def task_init(self, client_id: int, task_id: int) -> jax.Array:
        return self.client_v.get(client_id, jnp.zeros((self.d,), jnp.float32))

    def aggregate(self, uploads: List[Upload]) -> None:
        ids = [u.client_id for u in uploads]
        means = jnp.stack([jnp.mean(u.task_vectors, axis=0) for u in uploads])
        sim = np.asarray(cosine_similarity_matrix(means))
        groups = greedy_group(sim, self.threshold)
        for g in groups:
            gv = jnp.mean(means[jnp.asarray(g)], axis=0)
            for i in g:
                self.client_v[ids[i]] = gv
        for u in uploads:
            for t in u.task_ids:
                if u.client_id not in self.holders[t]:
                    self.holders[t].append(u.client_id)

    def eval_vectors(self, task_id: int) -> List[jax.Array]:
        out = [self.client_v[c] for c in self.holders[task_id] if c in self.client_v]
        return out or [jnp.zeros((self.d,), jnp.float32)]


STRATEGIES = {
    "matu": MaTUStrategy,
    "matu-async": AsyncMaTUStrategy,
    "fedavg": FedAvgStrategy,
    "fedprox": FedProxStrategy,
    "ntk-fedavg": NTKFedAvgStrategy,
    "ties": TIESStrategy,
    "fedper": FedPerStrategy,
    "mat-fl": MaTFLStrategy,
}
