"""Event-clock client-system layer for fault-tolerant async rounds.

The paper's deployment story is millions of intermittently-available
devices; :class:`repro.fed.simulator.FedSimulator` was a synchronous
barrier where every sampled client always answers.  This module is the
systems half of the async mode: a deterministic, *stateless* event
clock over (client, round) that decides availability, latency, and
fault injection — plus the admission queue the server drains every
tick and the CRC frame that makes wire corruption detectable.

Determinism contract
--------------------
Every draw is keyed by ``(seed, channel, client, round)`` through
``np.random.SeedSequence`` — no mutable RNG state anywhere.  Two
consequences the tests rely on:

* **replayable**: ``available(c, r)`` / ``dropout(c, r)`` /
  ``delay(c, r)`` / ``corrupt(c, r)`` return the same answer no matter
  when or how often they are called;
* **failure-invariant**: injecting a fault for client A cannot perturb
  any draw for client B (each (client, round) cell owns its own
  generator), which composes with the simulator's ``fold_in``-derived
  training keys into the end-to-end guarantee that survivors' local
  trajectories are bit-identical with and without the fault.

Fault model
-----------
:class:`FaultModel` covers the four failure classes of the async round
server (all probabilities per (client, round), all off by default so
``ClientSystems.ideal`` is the zero-fault trace):

* **dropout** — the sampled client trains but never uploads;
* **stragglers** — the upload lands ``straggler_delay`` rounds late
  (``straggler_delay=1`` models the "2x-latency" device that takes two
  round periods per round), on top of the per-client ``base_delay``
  heterogeneity vector;
* **crash-and-rejoin** — a crash at round q makes the client
  unavailable (never sampled) for rounds q .. q+crash_rounds−1, after
  which it rejoins with its last-served state;
* **corruption** — the client's *coded* upload stream is tampered on
  the wire: truncated at a random byte, or 1–8 distinct bit flips.

Wire framing
------------
Golomb-Rice streams are near-bijective — most bit flips decode to a
*different valid mask* — so corruption detection cannot live in the
entropy coder.  :func:`wrap_stream` adds a 9-byte frame (magic, uint32
payload length, CRC-32) and :func:`unwrap_stream` raises
:class:`WireFrameError` on any mismatch; together with the coder's own
:class:`~repro.fed.compression.CodedStreamError` validation this gives
the async strategy a validating decode that quarantines 100% of
injected truncations and bit flips.  Framing is only applied when the
fault model can corrupt (``corrupt_prob > 0``), so the zero-fault wire
— and therefore the measured bits in ``History`` — stays byte-identical
to the sync path (the sync ≡ async bit-parity anchor).
"""

from __future__ import annotations

import heapq
import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

# the per-round fault/staleness/quarantine counters recorded in
# History.fault_counts — one dict per round, same keys in sync and
# async modes (sync rounds report sampled == admitted and zeros
# elsewhere)
FAULT_KEYS = ("sampled", "dropped", "crashed", "stragglers", "stale",
              "quarantined", "buffered", "admitted", "skipped")

FRAME_MAGIC = 0xA5
FRAME_BYTES = 9                     # magic(1) + length(4) + crc32(4)


def blank_fault_counters() -> Dict[str, int]:
    return {k: 0 for k in FAULT_KEYS}


class WireFrameError(ValueError):
    """A framed byte stream failed its length/CRC validation."""


def wrap_stream(stream: np.ndarray) -> np.ndarray:
    """Frame a uint8 stream: ``magic | uint32 length | uint32 crc32 |
    payload`` (little-endian).  The CRC covers the payload bytes; the
    explicit length makes truncation detection deterministic even when
    the cut lands on a self-delimiting record boundary."""
    payload = np.ascontiguousarray(np.asarray(stream, np.uint8).ravel())
    head = np.empty(FRAME_BYTES, np.uint8)
    head[0] = FRAME_MAGIC
    head[1:5] = np.array([payload.size], "<u4").view(np.uint8)
    head[5:9] = np.array([zlib.crc32(payload.tobytes())],
                         "<u4").view(np.uint8)
    return np.concatenate([head, payload])


def unwrap_stream(framed: np.ndarray) -> np.ndarray:
    """Validate and strip a :func:`wrap_stream` frame.  Raises
    :class:`WireFrameError` on a short/absent header, magic mismatch,
    length mismatch (truncated or trailing bytes), or CRC mismatch."""
    buf = np.ascontiguousarray(np.asarray(framed, np.uint8).ravel())
    if buf.size < FRAME_BYTES:
        raise WireFrameError(f"frame: {buf.size} bytes < {FRAME_BYTES}-byte "
                             "header")
    if int(buf[0]) != FRAME_MAGIC:
        raise WireFrameError(f"frame: bad magic {int(buf[0]):#x}")
    length = int(buf[1:5].view("<u4")[0])
    if buf.size - FRAME_BYTES != length:
        raise WireFrameError(f"frame: payload {buf.size - FRAME_BYTES} bytes"
                             f" != declared {length}")
    payload = buf[FRAME_BYTES:]
    crc = int(buf[5:9].view("<u4")[0])
    if zlib.crc32(payload.tobytes()) != crc:
        raise WireFrameError("frame: CRC mismatch")
    return payload


@dataclass(frozen=True)
class FaultModel:
    """Per-(client, round) fault probabilities (see module docstring).
    The default instance is the zero-fault model."""
    dropout: float = 0.0            # P(sampled client never uploads)
    straggler_frac: float = 0.0     # P(upload delayed straggler_delay)
    straggler_delay: int = 1        # extra rounds a straggler's upload takes
    crash_prob: float = 0.0         # P(crash at round r)
    crash_rounds: int = 2           # rounds unavailable after a crash
    corrupt_prob: float = 0.0       # P(coded upload tampered on the wire)
    truncate_frac: float = 0.5      # of corruptions: truncation vs bit flips
    seed: int = 0


# draw channels — one independent generator per (channel, client, round)
_CH_CRASH, _CH_DROP, _CH_DELAY, _CH_CORRUPT, _CH_TAMPER = range(5)


class ClientSystems:
    """Deterministic event-clock system model for ``n_clients`` devices.

    ``base_delay`` is the per-client latency heterogeneity vector (extra
    rounds every upload takes, before straggling); ``forced_dropouts``
    is a set of (client, round) pairs dropped with probability 1 —
    the regression-test hook for targeted fault injection."""

    def __init__(self, n_clients: int, faults: FaultModel = FaultModel(),
                 base_delay: Optional[Sequence[int]] = None,
                 forced_dropouts: Optional[set] = None):
        self.n_clients = int(n_clients)
        self.faults = faults
        self.base_delay = (np.zeros(self.n_clients, np.int64)
                           if base_delay is None
                           else np.asarray(base_delay, np.int64))
        if self.base_delay.shape != (self.n_clients,):
            raise ValueError("base_delay must have one entry per client")
        self.forced_dropouts = frozenset(forced_dropouts or ())

    @classmethod
    def ideal(cls, n_clients: int) -> "ClientSystems":
        """Always-available / zero-latency / zero-fault trace — the
        configuration under which async ≡ sync, bit for bit."""
        return cls(n_clients)

    # -- stateless draws ----------------------------------------------------
    def _rng(self, channel: int, client: int, rnd: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence((self.faults.seed, channel, client, rnd)))

    def _crashed_at(self, client: int, rnd: int) -> bool:
        if self.faults.crash_prob <= 0.0 or rnd < 0:
            return False
        return (self._rng(_CH_CRASH, client, rnd).random()
                < self.faults.crash_prob)

    def available(self, client: int, rnd: int) -> bool:
        """False while the client is crashed: a crash at round q covers
        rounds q .. q + crash_rounds − 1 (rejoin after)."""
        lo = max(0, rnd - self.faults.crash_rounds + 1)
        return not any(self._crashed_at(client, q)
                       for q in range(lo, rnd + 1))

    def dropout(self, client: int, rnd: int) -> bool:
        if (client, rnd) in self.forced_dropouts:
            return True
        if self.faults.dropout <= 0.0:
            return False
        return self._rng(_CH_DROP, client, rnd).random() < self.faults.dropout

    def is_straggler(self, client: int, rnd: int) -> bool:
        if self.faults.straggler_frac <= 0.0:
            return False
        return (self._rng(_CH_DELAY, client, rnd).random()
                < self.faults.straggler_frac)

    def delay(self, client: int, rnd: int) -> int:
        """Rounds until this round's upload reaches the server (0 =
        arrives within the dispatch round, the sync ideal)."""
        extra = (self.faults.straggler_delay
                 if self.is_straggler(client, rnd) else 0)
        return int(self.base_delay[client]) + extra

    def corrupt(self, client: int, rnd: int) -> bool:
        if self.faults.corrupt_prob <= 0.0:
            return False
        return (self._rng(_CH_CORRUPT, client, rnd).random()
                < self.faults.corrupt_prob)

    @property
    def injects_corruption(self) -> bool:
        """True when uploads must travel CRC-framed (corrupt_prob > 0);
        the zero-fault wire stays frameless for sync bit-parity."""
        return self.faults.corrupt_prob > 0.0

    def tamper(self, stream: np.ndarray, client: int, rnd: int) -> np.ndarray:
        """Deterministically corrupt a byte stream: truncate at a random
        byte (with prob ``truncate_frac``) or flip 1–8 DISTINCT bits
        (distinct so flips can never cancel back to the original)."""
        g = self._rng(_CH_TAMPER, client, rnd)
        s = np.array(stream, np.uint8, copy=True)
        if s.size == 0:
            return s
        if g.random() < self.faults.truncate_frac:
            return s[:int(g.integers(0, s.size))]
        n_flips = int(g.integers(1, 9))
        pos = g.choice(s.size * 8, size=min(n_flips, s.size * 8),
                       replace=False)
        np.bitwise_xor.at(s, pos // 8, (1 << (pos % 8)).astype(np.uint8))
        return s


@dataclass(order=True)
class _QueueItem:
    arrival: int
    dispatch: int
    seq: int
    payload: object = None


class AdmissionQueue:
    """Buffered upload admission: uploads land with their arrival tick,
    the server drains everything that has arrived by the current tick.

    Drain order is (arrival, dispatch round, push order) — so with an
    ideal trace (every arrival == dispatch == now, pushes in selection
    order) the drained order IS the sync round's upload order, which is
    what makes the async slot packing byte-identical to sync."""

    def __init__(self) -> None:
        self._heap: List[_QueueItem] = []
        self._seq = 0

    def push(self, arrival: int, dispatch: int, payload) -> None:
        heapq.heappush(self._heap,
                       _QueueItem(int(arrival), int(dispatch), self._seq,
                                  payload))
        self._seq += 1

    def pop_ready(self, now: int) -> List[_QueueItem]:
        out = []
        while self._heap and self._heap[0].arrival <= now:
            out.append(heapq.heappop(self._heap))
        return out

    def __len__(self) -> int:
        return len(self._heap)
