"""Backbones for federated experiments, exposing a flat LoRA task-vector
space (the d-dimensional space MaTU operates in).

Implementations:

* :class:`ArchBackbone` — the general form: wraps ANY reduced config-zoo
  model (``ArchConfig.reduced().build()`` — lm / encdec / ssm / moe /
  vlm / hybrid — or the bespoke vit_b32 ``ViTConfig``) behind the flat
  task-vector interface.  Features come from the model's real forward
  pass; ``lin_features`` linearises that same forward with ``jax.jvp``.
* :class:`MLPBackbone` — fast CPU testbed used by the paper-claim
  benchmarks (frozen 2-layer MLP + LoRA on both layers).
* :class:`ViTBackbone` — ``ArchBackbone("vit_b32")`` with the historical
  constructor, kept for the integration test and the quickstart.

Every backbone exposes:
  d                     — task-vector dimension
  space                 — the :class:`~repro.common.tree.TaskVectorSpace`
                          layout manifest for d
  fingerprint           — the manifest fingerprint (layout agreement)
  features(tv, x)       — (B, feat_out) features under flat LoRA vector tv
  features_tree(dt, x)  — same features from the model-space delta pytree
                          (the pytree-aware trainer's path)
  lin_features(tv, x)   — NTK-linearised features at the pretrained
                          point (jax.jvp), for the NTK-FedAvg baseline
  split_point           — index splitting "shared" vs "personal" slices
                          of the flat vector (FedPer)

Task-vector layout contract
---------------------------
The flat d-axis every backbone exposes is DEFINED by its
:class:`~repro.common.tree.TaskVectorSpace` manifest: LoRA adapter
leaves (delta over the standard A-gaussian/B-zero init, so τ = 0 is
exactly the pretrained point) in canonical tree order, each raveled
C-order into a contiguous ``[offset, offset + size)`` slice.  The
manifest's ``fingerprint`` is the layout identity: holders of the same
task must agree on it before a round (the simulator/strategy refuse to
aggregate otherwise — ``TaskVectorLayoutError``), because the engine
merges task vectors coordinate by coordinate.  Which matmuls carry
adapters is declared per family in ``configs.base`` (``lora_targets``)
and verified against the manifest at backbone construction.  Mixed
rounds flatten each client's delta through its own manifest and
zero-pad to the round's common d — a multiple of 256 coords
(``8 × bitpack.WORD_BITS`` = one ``ref.LAMBDA_BLOCK``, the PR 3
word-boundary rule), so the packed uint32 wire words and the λ
reduction blocks of every backbone's prefix stay aligned and the
packed/bool layouts remain bit-identical.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.tree import (TaskVectorSpace, tree_add, tree_dot,
                               tree_flatten_vector, tree_unflatten_vector)
from repro.configs.base import (ZOO_FAMILIES, check_lora_targets, load_arch,
                                lora_targets_for)

# the word-boundary rule: common-d padding quantum for mixed rounds
# (8 × bitpack.WORD_BITS == ref.LAMBDA_BLOCK)
D_BOUNDARY = 256


def round_up_d(d: int, boundary: int = D_BOUNDARY) -> int:
    """Round a task-vector dimension up to the wire word boundary."""
    return -(-int(d) // boundary) * boundary


class MLPBackbone:
    def __init__(self, feat_dim: int, hidden: int = 64, lora_rank: int = 4,
                 seed: int = 0):
        k = jax.random.PRNGKey(seed)
        k1, k2, k3, k4 = jax.random.split(k, 4)
        self.w1 = jax.random.normal(k1, (feat_dim, hidden)) / math.sqrt(feat_dim)
        self.w2 = jax.random.normal(k2, (hidden, hidden)) / math.sqrt(hidden)
        self.rank = lora_rank
        # The task vector is a DELTA over the standard LoRA init
        # (A gaussian, B zero): τ = 0 is exactly the pretrained point,
        # and gradients flow (A=B=0 would be a saddle).
        self.lora0 = {
            "l1": {"a": jax.random.normal(k3, (feat_dim, lora_rank)) / math.sqrt(feat_dim),
                   "b": jnp.zeros((lora_rank, hidden))},
            "l2": {"a": jax.random.normal(k4, (hidden, lora_rank)) / math.sqrt(hidden),
                   "b": jnp.zeros((lora_rank, hidden))},
        }
        self.space = TaskVectorSpace.from_tree(self.lora0)
        self.template = self.space.template()
        self.d = self.space.d
        self.fingerprint = self.space.fingerprint
        self.feat_out = hidden
        # FedPer split: layer-1 LoRA shared, layer-2 LoRA personal
        self.split_point = int(self.template["l1"]["a"].size + self.template["l1"]["b"].size)

    def _unflatten(self, tv: jax.Array):
        delta = tree_unflatten_vector(tv, self.template)
        return tree_add(self.lora0, delta)

    def features_tree(self, delta, x: jax.Array) -> jax.Array:
        l = tree_add(self.lora0, delta)
        h = x @ (self.w1 + l["l1"]["a"] @ l["l1"]["b"])
        h = jax.nn.gelu(h)
        h = h @ (self.w2 + l["l2"]["a"] @ l["l2"]["b"])
        return jax.nn.gelu(h)

    def features(self, tv: jax.Array, x: jax.Array) -> jax.Array:
        return self.features_tree(tree_unflatten_vector(tv, self.template), x)

    def lin_features(self, tv: jax.Array, x: jax.Array) -> jax.Array:
        zero = jnp.zeros_like(tv)
        f0, jvp_out = jax.jvp(lambda v: self.features(v, x), (zero,), (tv,))
        return f0 + jvp_out


class ArchBackbone:
    """Flat LoRA task-vector interface over any reduced zoo model.

    ``arch`` is a config-zoo id (``qwen2-0.5b``, ``whisper-large-v3``,
    ``xlstm-1.3b``, ``granite-moe-3b-a800m``, …) or ``vit_b32``.  The
    pretrained point is the model's random init; the task vector is the
    flat delta over the standard LoRA init (A gaussian, B zero), laid
    out by ``self.space`` (see the module's layout contract).

    Features are the model's REAL forward pass:

    * vit — patches through the ViT trunk, CLS features;
    * lm-kind (dense/moe/ssm/hybrid/vlm) — the synthetic feature vector
      enters as ``ctx_len`` projected ``extra_embeds`` positions ahead
      of one query token; features are the final hidden state at the
      query position (so they depend on every block's adapters);
    * encdec (audio) — the feature vector enters as projected encoder
      frames; features are the decoder's final hidden state (through
      cross-attention, so encoder AND decoder adapters matter).

    The input projection is a fixed random matrix — part of the frozen
    backbone, never trained.
    """

    def __init__(self, arch: str, feat_dim: Optional[int] = None, *,
                 seed: int = 0, ctx_len: int = 4, reduced: bool = True):
        self.arch = arch
        self.kind: str
        k = jax.random.PRNGKey(seed)
        if arch in ("vit", "vit_b32"):
            from repro.configs.vit_b32 import CONFIG, build, reduced_vit
            cfg = reduced_vit() if reduced else CONFIG
            self.cfg = cfg
            self.model = build(cfg)
            self.kind = "vit"
            self.params = self.model.init(k)
            self.lora0 = self.model.lora_init(jax.random.PRNGKey(seed + 1),
                                              cfg.lora_rank)
            self.feat_out = cfg.d_model
            self.feat_dim = cfg.patch_dim * cfg.n_patches
        else:
            cfg = load_arch(arch)
            self.cfg = cfg = cfg.reduced() if reduced else cfg
            am = cfg.build()
            self.model = am.model
            self.kind = am.kind          # "lm" | "encdec"
            self.params = am.init(k)
            self.lora0 = am.lora_init(jax.random.PRNGKey(seed + 1))
            self.feat_out = cfg.d_model
            if feat_dim is None:
                raise ValueError(f"{arch}: feat_dim is required for "
                                 "lm/encdec backbones")
            self.feat_dim = int(feat_dim)
            self.ctx_len = int(ctx_len)
            # fixed random input projection: synthetic features ->
            # ctx_len pseudo-token embeddings (frozen, untrained)
            pk = jax.random.fold_in(k, 0xF0)
            self.in_proj = (jax.random.normal(
                pk, (self.feat_dim, self.ctx_len * cfg.d_model))
                / math.sqrt(self.feat_dim)).astype(jnp.float32)

        self.space = TaskVectorSpace.from_tree(self.lora0)
        self.template = self.space.template()
        self.d = self.space.d
        self.fingerprint = self.space.fingerprint
        # declared targeting rules vs the actual manifest — fail loudly
        # at construction, not mid-round
        check_lora_targets(lora_targets_for(self.cfg),
                           [l.path for l in self.space.leaves],
                           context=f"{arch}")
        # FedPer split at the leaf boundary nearest d/2
        half = self.d // 2
        self.split_point = min((l.offset for l in self.space.leaves
                                if l.offset >= half), default=half)

    # -- feature paths ------------------------------------------------------
    def _embed_ctx(self, x: jax.Array) -> jax.Array:
        b = x.shape[0]
        return (x @ self.in_proj).reshape(b, self.ctx_len,
                                          self.cfg.d_model)

    def features_tree(self, delta, x: jax.Array) -> jax.Array:
        """(B, feat_out) features from the model-space delta pytree."""
        lora = tree_add(self.lora0, delta)
        if self.kind == "vit":
            # x arrives either flat (B, n_patches*patch_dim) or
            # patch-sized (B, patch_dim) — the latter is tiled across
            # patches, which keeps synthetic rotation tasks undoable by
            # patch-level LoRA.
            cfg = self.cfg
            if x.shape[-1] == cfg.patch_dim:
                patches = jnp.broadcast_to(
                    x[:, None, :], (x.shape[0], cfg.n_patches, cfg.patch_dim))
            else:
                patches = x.reshape(x.shape[0], cfg.n_patches, cfg.patch_dim)
            return self.model.features(self.params, patches, lora=lora)
        b = x.shape[0]
        tokens = jnp.zeros((b, 1), jnp.int32)
        ctx = self._embed_ctx(x)
        if self.kind == "encdec":
            hidden = self.model.forward(self.params, tokens, ctx, lora=lora,
                                        return_hidden=True)
            return hidden[:, -1]
        hidden, _aux = self.model.forward(self.params, tokens, lora=lora,
                                          extra_embeds=ctx,
                                          return_hidden=True)
        return hidden[:, -1]

    def features(self, tv: jax.Array, x: jax.Array) -> jax.Array:
        return self.features_tree(self.space.unflatten(tv), x)

    def lin_features(self, tv: jax.Array, x: jax.Array) -> jax.Array:
        zero = jnp.zeros_like(tv)
        f0, jvp_out = jax.jvp(lambda v: self.features(v, x), (zero,), (tv,))
        return f0 + jvp_out


class ViTBackbone(ArchBackbone):
    """The paper's model family (ViT + LoRA) behind the historical
    constructor; now just :class:`ArchBackbone` on vit_b32."""

    def __init__(self, seed: int = 0, reduced: bool = True):
        super().__init__("vit_b32", seed=seed, reduced=reduced)


def make_zoo_backbones(feat_dim: int, families=None, *, seed: int = 0,
                       ctx_len: int = 4) -> Dict[str, ArchBackbone]:
    """One :class:`ArchBackbone` per zoo family (``ZOO_FAMILIES``).

    ``feat_dim`` must equal the reduced vit patch_dim (32) when the vit
    family is included — the synthetic constellation feeds every
    backbone the same (B, feat_dim) batches."""
    out: Dict[str, ArchBackbone] = {}
    for fam in (families or list(ZOO_FAMILIES)):
        arch = ZOO_FAMILIES[fam]
        bb = ArchBackbone(arch, feat_dim=None if fam == "vit" else feat_dim,
                          seed=seed, ctx_len=ctx_len)
        if fam == "vit" and bb.cfg.patch_dim != feat_dim:
            raise ValueError(
                f"vit patch_dim {bb.cfg.patch_dim} != feat_dim {feat_dim}: "
                "the constellation must feed patch-sized features")
        out[fam] = bb
    return out
