"""Backbones for federated experiments, exposing a flat LoRA task-vector
space (the d-dimensional space MaTU operates in).

Two implementations:

* :class:`MLPBackbone` — fast CPU testbed used by the paper-claim
  benchmarks (frozen 2-layer MLP + LoRA on both layers).
* :class:`ViTBackbone` — the paper's actual model family (ViT + LoRA
  rank 16 on attention/MLP), used in the integration test and the
  quickstart; slower but exercises the real model zoo.

Both expose:
  d                     — task-vector dimension
  features(tv, x)       — (B, feat_out) features under LoRA vector tv
  lin_features(tv, x)   — NTK-linearised features at the pretrained
                          point (jax.jvp), for the NTK-FedAvg baseline
  split_point           — index splitting "shared" vs "personal" slices
                          of the flat vector (FedPer)
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.tree import tree_flatten_vector, tree_unflatten_vector


class MLPBackbone:
    def __init__(self, feat_dim: int, hidden: int = 64, lora_rank: int = 4,
                 seed: int = 0):
        k = jax.random.PRNGKey(seed)
        k1, k2, k3, k4 = jax.random.split(k, 4)
        self.w1 = jax.random.normal(k1, (feat_dim, hidden)) / math.sqrt(feat_dim)
        self.w2 = jax.random.normal(k2, (hidden, hidden)) / math.sqrt(hidden)
        self.rank = lora_rank
        # The task vector is a DELTA over the standard LoRA init
        # (A gaussian, B zero): τ = 0 is exactly the pretrained point,
        # and gradients flow (A=B=0 would be a saddle).
        self.lora0 = {
            "l1": {"a": jax.random.normal(k3, (feat_dim, lora_rank)) / math.sqrt(feat_dim),
                   "b": jnp.zeros((lora_rank, hidden))},
            "l2": {"a": jax.random.normal(k4, (hidden, lora_rank)) / math.sqrt(hidden),
                   "b": jnp.zeros((lora_rank, hidden))},
        }
        self.template = jax.tree_util.tree_map(jnp.zeros_like, self.lora0)
        self.d = int(sum(x.size for x in jax.tree_util.tree_leaves(self.template)))
        self.feat_out = hidden
        # FedPer split: layer-1 LoRA shared, layer-2 LoRA personal
        self.split_point = int(self.template["l1"]["a"].size + self.template["l1"]["b"].size)

    def _unflatten(self, tv: jax.Array):
        delta = tree_unflatten_vector(tv, self.template)
        return jax.tree_util.tree_map(jnp.add, self.lora0, delta)

    def features(self, tv: jax.Array, x: jax.Array) -> jax.Array:
        l = self._unflatten(tv)
        h = x @ (self.w1 + l["l1"]["a"] @ l["l1"]["b"])
        h = jax.nn.gelu(h)
        h = h @ (self.w2 + l["l2"]["a"] @ l["l2"]["b"])
        return jax.nn.gelu(h)

    def lin_features(self, tv: jax.Array, x: jax.Array) -> jax.Array:
        zero = jnp.zeros_like(tv)
        f0, jvp_out = jax.jvp(lambda v: self.features(v, x), (zero,), (tv,))
        return f0 + jvp_out


class ViTBackbone:
    def __init__(self, seed: int = 0, reduced: bool = True):
        from repro.configs.vit_b32 import CONFIG, build, reduced_vit
        cfg = reduced_vit() if reduced else CONFIG
        self.cfg = cfg
        self.vit = build(cfg)
        k = jax.random.PRNGKey(seed)
        self.params = self.vit.init(k)
        # task vector = delta over the standard LoRA init (A≠0, B=0)
        self.lora0 = self.vit.lora_init(jax.random.PRNGKey(seed + 1), cfg.lora_rank)
        self.template = jax.tree_util.tree_map(jnp.zeros_like, self.lora0)
        self.d = int(sum(x.size for x in jax.tree_util.tree_leaves(self.template)))
        self.feat_out = cfg.d_model
        self.split_point = self.d // 2  # FedPer: later layers personal
        self.feat_dim = cfg.patch_dim * cfg.n_patches

    def _unflatten(self, tv: jax.Array):
        delta = tree_unflatten_vector(tv, self.template)
        return jax.tree_util.tree_map(jnp.add, self.lora0, delta)

    def features(self, tv: jax.Array, x: jax.Array) -> jax.Array:
        # x arrives either flat (B, n_patches*patch_dim) or patch-sized
        # (B, patch_dim) — the latter is tiled across patches, which
        # keeps synthetic rotation tasks undoable by patch-level LoRA.
        if x.shape[-1] == self.cfg.patch_dim:
            patches = jnp.broadcast_to(x[:, None, :],
                                       (x.shape[0], self.cfg.n_patches,
                                        self.cfg.patch_dim))
        else:
            patches = x.reshape(x.shape[0], self.cfg.n_patches, self.cfg.patch_dim)
        return self.vit.features(self.params, patches, lora=self._unflatten(tv))

    def lin_features(self, tv: jax.Array, x: jax.Array) -> jax.Array:
        zero = jnp.zeros_like(tv)
        f0, jvp_out = jax.jvp(lambda v: self.features(v, x), (zero,), (tv,))
        return f0 + jvp_out
