"""Bit-packed mask wire format: the single definition of the layout.

Binary modulator masks are the round's largest tensors — at
``(n_max, k_max, d)`` a bool layout spends 8 bits per mask bit and is
the reason the CPU round is memory-bound.  The wire format packs every
32 mask bits into one ``uint32`` word:

* element ``j`` of a d-length mask lives in word ``j // 32``,
  bit ``j % 32``, **LSB-first** (``(word >> (j % 32)) & 1``);
* a d-length mask occupies ``packed_width(d) = ceil(d / 32)`` words;
* tail bits of the last word (elements ``d .. 32*ceil(d/32)``) are
  always zero — packing enforces it, consumers may rely on it (popcount
  over whole words needs no tail correction).

The same convention is produced by the host-side numpy packer
(``pack_bits_np``: ``np.packbits(bitorder="little")`` + little-endian
``uint32`` view), the jnp packer used inside jitted rounds, and the
in-kernel Pallas packers — so packed tensors are byte-identical across
the client → uplink → engine → downlink path.

Sign bit-planes: a ternary sign vector ``sgn(x) ∈ {-1, 0, +1}`` packs
into two planes, ``pos = pack(x > 0)`` and ``nz = pos | pack(x < 0)``.
The Eq. 5 sign dot becomes pure popcount algebra (see
``packed_sign_dots``), and Eq. 3 sign election becomes bitwise ANDs
against the mask words.

This word layout is also the substrate of the optional entropy-coded
wire layer: :mod:`repro.fed.compression` Golomb-Rice codes whole rows
of these words into self-describing byte streams (and decodes them
back bit-identically) at the host edge — ``wire_bits`` here stays the
single RAW packed accounting; coded streams are accounted off their
measured byte length.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32

# (1, 32) uint32 bit-index row, broadcast against (..., n_words, 1)
_BITS = np.arange(WORD_BITS, dtype=np.uint32)


def packed_width(d: int) -> int:
    """Words per d-length mask: ceil(d / 32)."""
    return -(-d // WORD_BITS)


def wire_bits(d: int, k: int, *, vec_bytes_per_elem: int = 2,
              float_bits: int = 32) -> int:
    """Measured wire size of one client's packed upload/downlink: the
    vector buffer (bf16 by default) + ``k`` packed mask rows + one
    scaler per row.  THE single accounting for the packed wire format —
    client/engine/compression all delegate here."""
    return (8 * vec_bytes_per_elem * d
            + k * (8 * 4 * packed_width(d) + float_bits))


def pack_bits(mask: jax.Array) -> jax.Array:
    """(..., d) bool/{0,1} -> (..., ceil(d/32)) uint32, LSB-first.

    Tail bits beyond d are zero.  Pure jnp — used inside jitted rounds
    and as the "ref" dispatch of ``ops.pack_masks``.
    """
    d = mask.shape[-1]
    pad = (-d) % WORD_BITS
    bits = mask.astype(jnp.uint32)
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    bits = bits.reshape(bits.shape[:-1] + (-1, WORD_BITS))
    return jnp.sum(bits << jnp.asarray(_BITS), axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, d: int, dtype=jnp.bool_) -> jax.Array:
    """(..., w) uint32 -> (..., d) of ``dtype`` (bool by default).

    ``d`` may be any length ≤ 32*w; trailing packed bits are dropped.
    """
    bits = (words[..., None] >> jnp.asarray(_BITS)) & jnp.uint32(1)
    flat = bits.reshape(bits.shape[:-2] + (-1,))
    return flat[..., :d].astype(dtype)


def pack_bits_np(mask: np.ndarray) -> np.ndarray:
    """Host-side packer (same layout as :func:`pack_bits`), via the C
    fast path ``np.packbits(bitorder='little')`` + a little-endian
    uint32 view."""
    mask = np.asarray(mask, bool)
    d = mask.shape[-1]
    pad = (-d) % WORD_BITS
    if pad:
        mask = np.concatenate(
            [mask, np.zeros(mask.shape[:-1] + (pad,), bool)], axis=-1)
    packed_u8 = np.packbits(mask, axis=-1, bitorder="little")
    words = np.ascontiguousarray(packed_u8).view(np.dtype("<u4"))
    if sys.byteorder != "little":          # normalise storage on BE hosts
        words = words.astype(np.uint32)
    return words


def unpack_bits_np(words: np.ndarray, d: int) -> np.ndarray:
    """Host-side inverse of :func:`pack_bits_np` -> (..., d) bool."""
    words = np.asarray(words).astype("<u4", copy=False)
    u8 = words.view(np.uint8)
    bits = np.unpackbits(u8, axis=-1, bitorder="little")
    return bits[..., :d].astype(bool)


def unpack_tile(words: jax.Array, dtype=jnp.float32) -> jax.Array:
    """(R, W) uint32 -> (R, W*32) tile unpack for Pallas kernel bodies:
    uses ``broadcasted_iota`` (TPU needs ≥2-D iota) and no tail slicing
    — kernel tiles are always word-aligned."""
    r, w = words.shape
    iota = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, WORD_BITS), 2)
    bits = (words[:, :, None] >> iota) & jnp.uint32(1)
    return bits.reshape(r, w * WORD_BITS).astype(dtype)


def pack_tile(bits: jax.Array) -> jax.Array:
    """(R, D) bool/{0,1} -> (R, D/32) uint32 tile pack for Pallas kernel
    bodies (D must be a multiple of 32)."""
    r, dd = bits.shape
    iota = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, WORD_BITS), 2)
    b = bits.reshape(r, dd // WORD_BITS, WORD_BITS).astype(jnp.uint32)
    return jnp.sum(b << iota, axis=-1, dtype=jnp.uint32)


def scatter_bits_np(positions: np.ndarray, n_bytes: int) -> np.ndarray:
    """Set the given bit positions (LSB-first within each byte — the
    module's one bit convention) in a zeroed ``n_bytes``-byte buffer.

    The substrate of the batched Golomb-Rice encoder's prefix-sum
    bit-scatter (:mod:`repro.fed.compression`): every row's unary
    terminators and remainder bits land in one preallocated bit-space
    with a single fancy-index write + one ``np.packbits`` — no
    per-row/per-symbol Python loop, and no read-modify-write hazard
    (duplicate byte indices are fine because the OR happens in
    bit-space, where positions are unique)."""
    bit_space = np.zeros(8 * n_bytes, np.uint8)
    if positions.size:
        bit_space[positions] = 1
    return np.packbits(bit_space, bitorder="little")


def slice_bits(words: jax.Array, start: int, length: int) -> jax.Array:
    """Re-aligned bit-range extract: bits ``[start, start + length)`` of
    a packed row, returned as ``ceil(length/32)`` words whose bit 0 is
    the bit at ``start`` (same LSB-first convention, zero tail bits).

    This is how a consumer slices one manifest leaf's mask bits out of
    a whole-d packed row WITHOUT unpacking to bool: each output word is
    the OR of two shifted neighbour words.  ``words`` may carry leading
    batch axes (the slice applies to the last axis); ``start``/``length``
    are static ints.  Bit j of the result == bit ``start + j`` of the
    input row, verified against the unpack→slice→pack oracle in
    tests/test_serve_multitenant.py.
    """
    if length < 0 or start < 0:
        raise ValueError(f"slice_bits needs start/length >= 0, got "
                         f"({start}, {length})")
    n_out = packed_width(length)
    w0, sh = start // WORD_BITS, start % WORD_BITS
    need = n_out + (1 if sh else 0)
    avail = words.shape[-1] - w0
    if avail < need:   # zero-pad so the shifted neighbour read is safe
        pad = [(0, 0)] * (words.ndim - 1) + [(0, need - avail)]
        words = jnp.pad(words, pad)
    lo = words[..., w0:w0 + n_out]
    if sh:
        hi = words[..., w0 + 1:w0 + 1 + n_out]
        out = (lo >> jnp.uint32(sh)) | (hi << jnp.uint32(WORD_BITS - sh))
    else:
        out = lo
    # zero the tail bits past `length` of the last word (layout contract)
    tail = length % WORD_BITS
    if tail:
        keep = jnp.uint32((1 << tail) - 1)
        last = out[..., -1:] & keep
        out = jnp.concatenate([out[..., :-1], last], axis=-1)
    return out


def sign_planes(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Pack ``sgn(x)`` over the last axis into (pos, nz) bit-planes:
    ``pos`` has bit j set iff x_j > 0, ``nz`` iff x_j != 0."""
    pos = pack_bits(x > 0)
    neg = pack_bits(x < 0)
    return pos, pos | neg


def packed_sign_dots(pos: jax.Array, nz: jax.Array) -> jax.Array:
    """Pairwise sign dots Σ_j sgn(x_t)_j · sgn(x_t')_j from (T, w)
    bit-planes, as popcount algebra — exactly the integer the fp32
    ``sgn(X) @ sgn(X).T`` matmul produces (both are exact for d < 2²⁴):

        both  = nz_t & nz_t'                  (coords where neither is 0)
        agree = both & ~(pos_t ^ pos_t')      (equal sign bits)
        dot   = popcnt(agree) - popcnt(both & (pos ^ pos'))
              = popcnt(both) - 2·popcnt(both & (pos ^ pos'))

    Returns (T, T) int32.
    """
    both = nz[:, None, :] & nz[None, :, :]
    diff = both & (pos[:, None, :] ^ pos[None, :, :])
    n_both = jnp.sum(jax.lax.population_count(both), axis=-1,
                     dtype=jnp.int32)
    n_diff = jnp.sum(jax.lax.population_count(diff), axis=-1,
                     dtype=jnp.int32)
    return n_both - 2 * n_diff
