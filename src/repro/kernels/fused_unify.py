"""Pallas TPU kernel: fused unify + task-mask + λ-scaler (Eq. 2 + §3.2
modulators), batched over clients.

Downlink construction re-unifies every client's task vectors each
round.  Composed from the three reference ops this reads the (K, d)
stack three times (unify, mask, scaler) and materialises the unified
vector plus the mask stack in HBM between passes; per round that is
O(N·K·d) extra traffic on the server's hottest loop.  This kernel
streams each client's (K, BD) tile through VMEM once and emits the
unified block, the mask block, and the partial λ numerator/denominator
sums in a single pass.

Layout: grid (B, d/BD), d innermost so the per-(client, slot) scalar
accumulators (num, den) are revisited across the d sweep (zeroed on the
first step, accumulated after — same pattern as the sign_sim kernel).
Slot validity handles ragged k_n: invalid slots are zeroed before the
sign election and excluded from masks, so outputs match per-client
``unify_with_modulators`` on the valid rows exactly.

Masks are emitted as fp32 {0, 1} (bool outputs hit int8 tiling
constraints for small K); the dispatch layer casts back to bool.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import bitpack

BLOCK_D = 2048
BLOCK_D_PACKED = 4096       # 128 uint32 words per tile (one lane tile)


def _fused_unify_kernel(tv_ref, valid_ref, uni_ref, mask_ref, num_ref, den_ref):
    x = tv_ref[0].astype(jnp.float32)               # (K, BD)
    v = valid_ref[0].astype(jnp.float32)            # (K,)
    xm = x * v[:, None]
    sigma = jnp.sign(jnp.sum(xm, axis=0))
    aligned = (xm * sigma[None, :]) > 0.0
    mu = jnp.max(jnp.where(aligned, jnp.abs(xm), 0.0), axis=0)
    tau = sigma * mu
    uni_ref[0] = tau.astype(uni_ref.dtype)
    mask = ((x * tau[None, :]) > 0.0).astype(jnp.float32) * v[:, None]
    mask_ref[0] = mask.astype(mask_ref.dtype)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)

    num_ref[0] += jnp.sum(jnp.abs(xm), axis=1)
    den_ref[0] += jnp.sum(mask * jnp.abs(tau)[None, :], axis=1)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_unify_pallas(task_vectors: jax.Array, valid: jax.Array, *,
                       block_d: int = BLOCK_D, interpret: bool = True):
    """task_vectors (B, K, d); valid (B, K) bool/{0,1}.

    Returns (unified (B, d), masks (B, K, d) fp32 {0,1}, num (B, K),
    den (B, K)); λ = num / max(den, eps) is computed by the caller so
    eps policy stays in one place (invalid slots: num = den = 0).
    Zero-padding d is safe: padded lanes contribute nothing to num/den
    and are sliced off the streamed outputs.
    """
    b, k, d = task_vectors.shape
    pad = (-d) % block_d
    if pad:
        task_vectors = jnp.pad(task_vectors, ((0, 0), (0, 0), (0, pad)))
    dp = d + pad
    unified, masks, num, den = pl.pallas_call(
        _fused_unify_kernel,
        grid=(b, dp // block_d),
        in_specs=[
            pl.BlockSpec((1, k, block_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((1, k, block_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, dp), jnp.float32),
            jax.ShapeDtypeStruct((b, k, dp), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
        ],
        interpret=interpret,
    )(task_vectors, valid.astype(jnp.float32))
    return unified[:, :d], masks[:, :, :d], num, den


def _fused_unify_packed_kernel(tv_ref, valid_ref, uni_ref, mask_ref,
                               num_ref, den_ref):
    x = tv_ref[0].astype(jnp.float32)               # (K, BD)
    v = valid_ref[0].astype(jnp.float32)            # (K,)
    xm = x * v[:, None]
    sigma = jnp.sign(jnp.sum(xm, axis=0))
    aligned = (xm * sigma[None, :]) > 0.0
    mu = jnp.max(jnp.where(aligned, jnp.abs(xm), 0.0), axis=0)
    tau = sigma * mu
    # mask bits decided on the fp32 tau BEFORE the bf16 rounding of the
    # emitted unified vector — bit-identical to the bool/fp32 kernel
    uni_ref[0] = tau.astype(uni_ref.dtype)
    mask = ((x * tau[None, :]) > 0.0).astype(jnp.float32) * v[:, None]
    mask_ref[0] = bitpack.pack_tile(mask)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)

    num_ref[0] += jnp.sum(jnp.abs(xm), axis=1)
    den_ref[0] += jnp.sum(mask * jnp.abs(tau)[None, :], axis=1)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def fused_unify_packed_pallas(task_vectors: jax.Array, valid: jax.Array, *,
                              block_d: int = BLOCK_D_PACKED,
                              interpret: bool = True):
    """Wire-format variant of :func:`fused_unify_pallas`: consumes bf16
    (or fp32) slot stacks and emits the wire tensors directly — bf16
    unified vectors and bit-packed uint32 mask words, packed 32 lanes
    per word inside the kernel so the (B, K, d) mask never exists in
    HBM at more than 1 bit per element.

    Returns (unified (B, d) bf16, mask_words (B, K, ceil(d/32)) uint32,
    num (B, K), den (B, K)); λ = num / max(den, eps) is left to the
    caller.  Compute is fp32 per tile; mask bits and num/den are derived
    from the fp32 values before the bf16 rounding — masks are
    bit-identical to the bool kernel's, while num/den accumulate over
    4096-wide tiles (vs the bool kernel's 2048) so they match to fp32
    accumulation tolerance, not bitwise, for d > 2048.
    """
    b, k, d = task_vectors.shape
    pad = (-d) % block_d
    if pad:
        task_vectors = jnp.pad(task_vectors, ((0, 0), (0, 0), (0, pad)))
    dp = d + pad
    bw = block_d // 32
    unified, mask_words, num, den = pl.pallas_call(
        _fused_unify_packed_kernel,
        grid=(b, dp // block_d),
        in_specs=[
            pl.BlockSpec((1, k, block_d), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((1, k, bw), lambda i, j: (i, 0, j)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, dp), jnp.bfloat16),
            jax.ShapeDtypeStruct((b, k, dp // 32), jnp.uint32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.float32),
        ],
        interpret=interpret,
    )(task_vectors, valid.astype(jnp.float32))
    return (unified[:, :d], mask_words[:, :, :bitpack.packed_width(d)],
            num, den)

