"""Pallas TPU kernel: fused Eq. 3 + Eq. 4 (agreement mask + task merge).

Per task t the server computes, over the N_t member clients,
  α_j  = |Σ_n sgn(m_n ⊙ τ_n)_j| / N_t
  m̂_j  = 1 if α_j ≥ ρ else α_j
  τ̂_j  = m̂_j · Σ_n γ_n λ_n (m_n ⊙ τ_n)_j

A naive composition reads the (N, d) stack three times (sign-sum,
agreement compare, weighted sum) and materialises two (N, d)
intermediates in HBM.  The kernel streams each (N, BD) block through
VMEM once, producing both outputs — HBM traffic drops from ~5·N·d to
(N+2)·d words.

The per-client scalars (λ, γ) are small (N ≤ 64) and ride fully
resident; ρ is compile-time static.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import bitpack

BLOCK_D = 2048
# packed kernels tile 32 mask bits per word: 4096 elements = 128 words,
# exactly one uint32 lane tile
BLOCK_D_PACKED = 4096


def _masked_agg_kernel(u_ref, m_ref, lam_ref, gam_ref, tau_ref, mhat_ref, *, rho):
    u = u_ref[...].astype(jnp.float32)            # (N, BD)
    m = m_ref[...].astype(jnp.float32)            # (N, BD)
    lam = lam_ref[...].astype(jnp.float32)        # (N,)
    gam = gam_ref[...].astype(jnp.float32)        # (N,)
    member = (gam > 0).astype(jnp.float32)
    n_t = jnp.maximum(jnp.sum(member), 1.0)
    masked = u * m
    signs = jnp.sign(masked)
    alpha = jnp.abs(jnp.sum(member[:, None] * signs, axis=0)) / n_t
    m_hat = jnp.where(alpha >= rho, 1.0, alpha)
    weighted = jnp.sum((gam * lam)[:, None] * masked, axis=0)
    tau_ref[...] = (weighted * m_hat).astype(tau_ref.dtype)
    mhat_ref[...] = m_hat.astype(mhat_ref.dtype)


def _masked_agg_batched_kernel(u_ref, m_ref, lam_ref, gam_ref, mem_ref,
                               tau_ref, mhat_ref, *, rho):
    u = u_ref[...].astype(jnp.float32)            # (N, BD)
    m = m_ref[:, 0, :].astype(jnp.float32)        # (N, BD)
    lam = lam_ref[:, 0].astype(jnp.float32)       # (N,)
    gam = gam_ref[:, 0].astype(jnp.float32)       # (N,)
    mem = mem_ref[:, 0].astype(jnp.float32)       # (N,)
    n_t = jnp.maximum(jnp.sum(mem), 1.0)
    masked = u * m
    alpha = jnp.abs(jnp.sum(mem[:, None] * jnp.sign(masked), axis=0)) / n_t
    m_hat = jnp.where(alpha >= rho, 1.0, alpha)
    weighted = jnp.sum((gam * lam)[:, None] * masked, axis=0)
    tau_ref[0, :] = (weighted * m_hat).astype(tau_ref.dtype)
    mhat_ref[0, :] = m_hat.astype(mhat_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rho", "block_d", "interpret"))
def masked_agg_batched_pallas(unified: jax.Array, masks: jax.Array,
                              lams: jax.Array, gammas: jax.Array,
                              members: jax.Array, *, rho: float = 0.4,
                              block_d: int = BLOCK_D, interpret: bool = True):
    """Whole-round Eq. 3 + Eq. 4: every task in one launch.

    unified (N, d); masks (N, T, d) {0,1} (zero rows off-membership);
    lams/gammas/members (N, T).  ``members`` is the explicit A(n, t)
    allocation (the agreement denominator N_t counts members even when
    their data weight is zero, matching ``matu_round``).

    Grid is (T, d/BD): each program streams one (N, BD) lane block of
    one task through VMEM, so the (N, T, d) mask tensor is read exactly
    once and no (T, d) intermediate ever round-trips to HBM.
    Returns (tau_hats (T, d), m_hats (T, d)) in fp32.
    """
    n, d = unified.shape
    t = masks.shape[1]
    pad = (-d) % block_d
    if pad:
        unified = jnp.pad(unified, ((0, 0), (0, pad)))
        masks = jnp.pad(masks, ((0, 0), (0, 0), (0, pad)))
    dp = d + pad
    kernel = functools.partial(_masked_agg_batched_kernel, rho=rho)
    tau, m_hat = pl.pallas_call(
        kernel,
        grid=(t, dp // block_d),
        in_specs=[
            pl.BlockSpec((n, block_d), lambda i, j: (0, j)),
            pl.BlockSpec((n, 1, block_d), lambda i, j: (0, i, j)),
            pl.BlockSpec((n, 1), lambda i, j: (0, i)),
            pl.BlockSpec((n, 1), lambda i, j: (0, i)),
            pl.BlockSpec((n, 1), lambda i, j: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_d), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, dp), jnp.float32),
            jax.ShapeDtypeStruct((t, dp), jnp.float32),
        ],
        interpret=interpret,
    )(unified, masks.astype(unified.dtype), lams.astype(jnp.float32),
      gammas.astype(jnp.float32), members.astype(jnp.float32))
    return tau[:, :d], m_hat[:, :d]


def _masked_agg_batched_packed_kernel(u_ref, pos_ref, neg_ref, mw_ref,
                                      lam_ref, gam_ref, mem_ref,
                                      tau_ref, anum_ref, *, rho):
    u = u_ref[...].astype(jnp.float32)              # (N, BD)
    w = mw_ref[:, 0, :]                             # (N, BW) uint32
    lam = lam_ref[:, 0].astype(jnp.float32)         # (N,)
    gam = gam_ref[:, 0].astype(jnp.float32)
    mem = mem_ref[:, 0].astype(jnp.float32)
    n_t = jnp.maximum(jnp.sum(mem), 1.0)
    # sgn(m ⊙ τ_n) via word-wide ANDs against τ_n's sign bit-planes
    # (packed ONCE per d-block outside the kernel — every task row of
    # the grid reuses them): bit(m & pos) − bit(m & neg); the merge
    # reuses the same planes — m ⊙ τ = τ·(bit(m&pos) + bit(m&neg))
    # exactly (τ = 0 contributes 0)
    sp = bitpack.unpack_tile(w & pos_ref[...])      # (N, BD) f32 {0,1}
    sn = bitpack.unpack_tile(w & neg_ref[...])
    a_num = jnp.abs(jnp.sum(mem[:, None] * (sp - sn), axis=0))
    m_hat = jnp.where(a_num / n_t >= rho, 1.0, a_num / n_t)
    weighted = jnp.sum((gam * lam)[:, None] * (u * (sp + sn)), axis=0)
    tau_ref[0, :] = (weighted * m_hat).astype(tau_ref.dtype)
    anum_ref[0, :] = a_num.astype(anum_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rho", "block_d", "interpret"))
def masked_agg_batched_packed_pallas(unified: jax.Array, mask_words: jax.Array,
                                     lams: jax.Array, gammas: jax.Array,
                                     members: jax.Array, *, rho: float = 0.4,
                                     block_d: int = BLOCK_D_PACKED,
                                     interpret: bool = True):
    """Wire-format twin of :func:`masked_agg_batched_pallas`: the
    (N, T, d) mask tensor arrives as bit-packed uint32 words
    (N, T, ceil(d/32)) and is expanded 32-bits-per-word inside VMEM —
    HBM mask traffic drops 8x vs the bool layout and 32x vs fp32.
    ``unified`` may be bf16 (the uplink wire dtype); each tile is upcast
    to fp32 in VMEM.

    Instead of m̂ this kernel emits the Eq. 3 agreement *numerator*
    |Σ_n sgn(m_n ⊙ τ_n)| — an exact small integer (≤ N) from which the
    caller re-derives m̂ = 1[α ≥ ρ] ∨ α with the identical fp32 division
    (and can store it at one byte per coordinate).
    Returns (tau_hats (T, d) fp32, alpha_num (T, d) fp32).
    """
    n, d = unified.shape
    t = mask_words.shape[1]
    pad = (-d) % block_d
    dp = d + pad
    dwp = dp // 32
    if pad:
        unified = jnp.pad(unified, ((0, 0), (0, pad)))
    if mask_words.shape[2] != dwp:
        mask_words = jnp.pad(
            mask_words, ((0, 0), (0, 0), (0, dwp - mask_words.shape[2])))
    bw = block_d // 32
    # τ_n's sign bit-planes are task-independent: pack them once here
    # (tiny (N, dwp) words) instead of once per task row in-kernel.
    # The comparisons run on the wire dtype directly — bf16 > 0 decides
    # exactly like its fp32 upcast, so no dense fp32 copy is made.
    pos_w = bitpack.pack_bits(unified > 0.0)
    neg_w = bitpack.pack_bits(unified < 0.0)
    kernel = functools.partial(_masked_agg_batched_packed_kernel, rho=rho)
    tau, anum = pl.pallas_call(
        kernel,
        grid=(t, dp // block_d),
        in_specs=[
            pl.BlockSpec((n, block_d), lambda i, j: (0, j)),
            pl.BlockSpec((n, bw), lambda i, j: (0, j)),
            pl.BlockSpec((n, bw), lambda i, j: (0, j)),
            pl.BlockSpec((n, 1, bw), lambda i, j: (0, i, j)),
            pl.BlockSpec((n, 1), lambda i, j: (0, i)),
            pl.BlockSpec((n, 1), lambda i, j: (0, i)),
            pl.BlockSpec((n, 1), lambda i, j: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_d), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_d), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, dp), jnp.float32),
            jax.ShapeDtypeStruct((t, dp), jnp.float32),
        ],
        interpret=interpret,
    )(unified, pos_w, neg_w, mask_words, lams.astype(jnp.float32),
      gammas.astype(jnp.float32), members.astype(jnp.float32))
    return tau[:, :d], anum[:, :d]


@functools.partial(jax.jit, static_argnames=("rho", "block_d", "interpret"))
def masked_agg_pallas(unified: jax.Array, masks: jax.Array, lams: jax.Array,
                      gammas: jax.Array, *, rho: float = 0.4,
                      block_d: int = BLOCK_D, interpret: bool = True):
    """unified (N,d); masks (N,d) {0,1}; lams/gammas (N,).

    gammas must be the normalised membership weights (0 for
    non-members); N_t is inferred as the count of positive gammas.
    Returns (tau_hat (d,), m_hat (d,)) in fp32.
    """
    n, d = unified.shape
    pad = (-d) % block_d
    if pad:
        unified = jnp.pad(unified, ((0, 0), (0, pad)))
        masks = jnp.pad(masks, ((0, 0), (0, pad)))
    dp = d + pad
    kernel = functools.partial(_masked_agg_kernel, rho=rho)
    tau, m_hat = pl.pallas_call(
        kernel,
        grid=(dp // block_d,),
        in_specs=[
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_d,), lambda i: (i,)),
            pl.BlockSpec((block_d,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((dp,), jnp.float32),
            jax.ShapeDtypeStruct((dp,), jnp.float32),
        ],
        interpret=interpret,
    )(unified, masks.astype(unified.dtype), lams, gammas)
    return tau[:d], m_hat[:d]
