"""Pallas TPU kernel: chunkwise-parallel mLSTM (beyond-paper extension).

The xLSTM architecture's hot loop is the stabilised chunkwise mLSTM
(repro.nn.ssm.mlstm_chunkwise).  The jnp version materialises the
(B, H, L, L) decay matrix and five intermediate (B, H, L, ·) tensors in
HBM per chunk; this kernel keeps the whole per-(batch, head) chunk
working set — q/k/v tiles, the L×L decay mask, and the recurrent
(C, n, m) state — resident in VMEM, streaming each input tile exactly
once.

Grid: (B·H, n_chunks) with the chunk dimension sequential ("arbitrary")
so the (C, n, m) state persists in VMEM scratch across chunks of the
same (batch, head) program.  MXU work: the three L×Dk / L×L / L×Dv
matmuls per chunk.  For TPU lowering, L and the head dims should be
lane-aligned (multiples of 8×128 tiles); the ops-level wrapper pads.
Validated in interpret mode against the jnp oracle and the step
recurrence (tests/test_kernels_mlstm.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept
# either spelling (same version-tolerance pattern as launch/mesh._make_mesh).
_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")


def _mlstm_chunk_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, h_ref,
                        c_scr, n_scr, m_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, -1e30)

    q = q_ref[0].astype(jnp.float32)        # (L, Dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)        # (L, Dv)
    ic = i_ref[0].astype(jnp.float32)       # (L,)
    fc = f_ref[0].astype(jnp.float32)

    C, n, m = c_scr[...], n_scr[...], m_scr[0]

    log_f = -jax.nn.softplus(-fc)
    bcum = jnp.cumsum(log_f)
    c = ic - bcum
    cmax = jax.lax.cummax(c, axis=0)
    m_t = bcum + jnp.maximum(m, cmax)                       # (L,)

    scale_inter = jnp.exp(bcum + m - m_t)                   # (L,)
    h_inter = (q @ C) * scale_inter[:, None]                # (L, Dv)
    qn_inter = (q @ n[:, None])[:, 0] * scale_inter         # (L,)

    pos = jax.lax.iota(jnp.int32, chunk)
    causal = pos[:, None] >= pos[None, :]
    d_log = bcum[:, None] - bcum[None, :] + ic[None, :]
    d_mat = jnp.where(causal, jnp.exp(d_log - m_t[:, None]), 0.0)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    w = d_mat * scores
    h_intra = jnp.dot(w, v, preferred_element_type=jnp.float32)
    qn_intra = jnp.sum(w, axis=-1)

    qn = qn_inter + qn_intra
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))[:, None]
    h_ref[0] = ((h_inter + h_intra) / denom).astype(h_ref.dtype)

    total = bcum[-1]
    m_next = jnp.maximum(m + total, total + jnp.max(c))
    wgt = jnp.exp(total - bcum + ic - m_next)               # (L,)
    c_scr[...] = (jnp.exp(m + total - m_next) * C
                  + jnp.dot(k.T * wgt[None, :], v,
                            preferred_element_type=jnp.float32))
    n_scr[...] = jnp.exp(m + total - m_next) * n + (k.T * wgt[None, :]).sum(1)
    m_scr[0] = m_next


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunkwise_pallas(q, k, v, i_pre, f_pre, *, chunk: int = 64,
                           interpret: bool = True):
    """q,k (BH, S, Dk); v (BH, S, Dv); i_pre/f_pre (BH, S) -> h (BH, S, Dv).

    Zero initial state (block-local form used inside the LM); S padded
    to a chunk multiple with i=-inf / f=+40 identity steps.
    """
    bh, s, dk = q.shape
    dv = v.shape[-1]
    pad = (-s) % chunk
    if pad:
        z = ((0, 0), (0, pad), (0, 0))
        q, k, v = jnp.pad(q, z), jnp.pad(k, z), jnp.pad(v, z)
        i_pre = jnp.pad(i_pre, ((0, 0), (0, pad)), constant_values=-1e30)
        f_pre = jnp.pad(f_pre, ((0, 0), (0, pad)), constant_values=40.0)
    sp = s + pad
    nc = sp // chunk

    kernel = functools.partial(_mlstm_chunk_kernel, chunk=chunk)
    h = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sp, dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),   # C carry
            pltpu.VMEM((dk,), jnp.float32),      # n carry
            pltpu.VMEM((1,), jnp.float32),       # m carry
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, i_pre, f_pre)
    return h[:, :s]
