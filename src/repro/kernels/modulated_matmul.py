"""Pallas TPU kernel: per-request modulated LoRA matmul for serving.

The multi-tenant decode path applies each request's task modulator to
the shared LoRA leaf at matmul time:

    y_b = x_b @ (base + lam_b * m_b * tau)

The reference route first materialises every request's effective
weight in HBM (unpack the mask words to fp32, three elementwise passes
over (B, K, N)) and only then runs the batched matmul.  This kernel
streams one request per grid step: the packed uint32 words expand to
{0, 1} lanes in VMEM (``bitpack.unpack_tile``), the λ-scale and the
add onto the base leaf fuse into the same tile, and the MXU consumes
the effective weight without it ever existing in HBM — applying a
modulator costs no extra HBM pass beyond reading base/tau once per
request.

Layout: grid (B,); whole (S, K) / (K, N) blocks per step (LoRA leaves
are small — K or N is the rank r, so a full leaf fits VMEM easily).
Bit order: ``words[b]`` is the row-major (K, N) mask of request b in
the repo's LSB-first uint32 layout (``repro.kernels.bitpack``);
``K * N`` must be word-aligned (% 32 == 0) — the router only routes
leaf pairs that qualify and falls back to the dense path otherwise.

Bit-parity: ``(lam * bits) * tau`` with bits ∈ {0, 1} is IEEE-exact
``lam * where(m, tau, 0)``, so the fused product matches the
unpack-then-matmul oracle (``ref.modulated_matmul_ref``) bitwise; the
dot contraction is the same shape in both (tested in
tests/test_serve_multitenant.py, ref + pallas_interpret).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import bitpack


def _modulated_matmul_kernel(x_ref, base_ref, tau_ref, words_ref, lam_ref,
                             out_ref):
    k, n = base_ref.shape
    bits = bitpack.unpack_tile(words_ref[...], jnp.float32)  # (1, W*32)
    m = bits.reshape(k, n)
    w_eff = (base_ref[...].astype(jnp.float32)
             + lam_ref[0, 0] * m * tau_ref[...].astype(jnp.float32))
    x = x_ref[0].astype(jnp.float32)                          # (S, K)
    out_ref[0] = jnp.dot(x, w_eff, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def modulated_matmul_pallas(x: jax.Array, base: jax.Array, tau: jax.Array,
                            words: jax.Array, lam: jax.Array, *,
                            interpret: bool = True) -> jax.Array:
    """x (B, S, K); base/tau (K, N); words (B, ceil(K*N/32)) uint32;
    lam (B,).  Returns (B, S, N) fp32 = x_b @ (base + lam_b·m_b·tau).

    ``K * N`` must be a multiple of 32 (word-aligned leaf); the
    dispatch layer enforces it.
    """
    b, s, k = x.shape
    k2, n = base.shape
    assert k == k2, (x.shape, base.shape)
    out = pl.pallas_call(
        _modulated_matmul_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, s, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((1, words.shape[-1]), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, n), jnp.float32),
        interpret=interpret,
    )(x, base, tau, words, lam.astype(jnp.float32).reshape(b, 1))
    return out
