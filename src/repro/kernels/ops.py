"""Dispatch layer over the Pallas kernels — the ONLY entry point the
round engine (repro.core.engine) uses for Eq. 2–7 math.

Three dispatch modes:

  "pallas"            natively-compiled kernels (TPU backend)
  "pallas_interpret"  kernel bodies executed by the Pallas interpreter
                      (bit-identical to the TPU lowering; validation
                      path, far too slow for the CPU hot loop)
  "ref"               pure-jnp oracles (repro.kernels.ref) — the fast
                      XLA path on CPU/GPU

Resolution (``resolve_mode``): ``REPRO_DISABLE_PALLAS=1`` forces "ref"
everywhere; on TPU the default is "pallas"; elsewhere the default is
"ref" unless ``REPRO_PALLAS_INTERPRET=1`` opts into interpreter-mode
validation.  Every op also takes an explicit ``mode=`` so jitted
callers (the round engine) can resolve once per call and key their jit
cache on it instead of re-reading the environment at trace time.

The small (T, T)-sized Eq. 6–7 ops (top-κ filter, cross-task combine)
have no Pallas kernel — a (T, T) top-k plus a (T, T)·(T, d) MXU matmul
is already optimal under XLA — but are still routed through here so no
jnp-only server path remains outside this module.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import bitpack, ref
from repro.kernels.fused_unify import (fused_unify_packed_pallas,
                                       fused_unify_pallas)
from repro.kernels.masked_agg import (masked_agg_batched_packed_pallas,
                                      masked_agg_batched_pallas,
                                      masked_agg_pallas)
from repro.kernels.modulated_matmul import modulated_matmul_pallas
from repro.kernels.sign_sim import sign_sim_packed_pallas, sign_sim_pallas
from repro.kernels.unify import unify_pallas

MODES = ("pallas", "pallas_interpret", "ref")


def resolve_mode() -> str:
    """Pick the dispatch mode for the current process/backend."""
    if os.environ.get("REPRO_DISABLE_PALLAS", "0") == "1":
        return "ref"
    if jax.default_backend() == "tpu":
        return "pallas"
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return "pallas_interpret"
    return "ref"


def _norm(mode: Optional[str]) -> str:
    mode = mode or resolve_mode()
    if mode not in MODES:
        raise ValueError(f"unknown kernel dispatch mode {mode!r}; "
                         f"expected one of {MODES}")
    return mode


def unify(task_vectors: jax.Array, *, mode: Optional[str] = None) -> jax.Array:
    """(K, d) -> (d,) task unification (Eq. 2)."""
    mode = _norm(mode)
    if mode == "ref":
        return ref.unify_ref(task_vectors)
    return unify_pallas(task_vectors, interpret=(mode == "pallas_interpret"))


def masked_agg(unified, masks, lams, gammas, *, rho: float = 0.4,
               mode: Optional[str] = None):
    """Single-task Eq. 3 + Eq. 4 (membership inferred from gammas>0)."""
    mode = _norm(mode)
    if mode == "ref":
        return ref.masked_agg_ref(unified, masks, lams, gammas, rho)
    return masked_agg_pallas(unified, masks, lams, gammas, rho=rho,
                             interpret=(mode == "pallas_interpret"))


def masked_agg_batched(unified, masks, lams, gammas, members, *,
                       rho: float = 0.4, mode: Optional[str] = None):
    """Whole-round Eq. 3 + Eq. 4 over packed (N, T, d) tensors."""
    mode = _norm(mode)
    if mode == "ref":
        return ref.masked_agg_batched_ref(unified, masks, lams, gammas,
                                          members, rho)
    return masked_agg_batched_pallas(unified, masks, lams, gammas, members,
                                     rho=rho,
                                     interpret=(mode == "pallas_interpret"))


def sign_sim(tau_hats: jax.Array, *, mode: Optional[str] = None) -> jax.Array:
    """Eq. 5 sign-conflict similarity (T, d) -> (T, T)."""
    mode = _norm(mode)
    if mode == "ref":
        return ref.sign_sim_ref(tau_hats)
    return sign_sim_pallas(tau_hats, interpret=(mode == "pallas_interpret"))


def fused_unify_raw(task_vectors: jax.Array, valid: jax.Array, *,
                    packed: bool = True, mode: Optional[str] = None):
    """Division-free core of :func:`fused_unify` /
    :func:`fused_unify_packed`: returns (unified, masks-or-words,
    num, den) with the λ division left to the caller — the hook the
    sharded engine needs to ``psum`` the per-shard λ partial sums
    before dividing."""
    mode = _norm(mode)
    if packed:
        if mode == "ref":
            return ref.fused_unify_packed_ref(task_vectors, valid)
        return fused_unify_packed_pallas(
            task_vectors, valid, interpret=(mode == "pallas_interpret"))
    if mode == "ref":
        return ref.fused_unify_ref(task_vectors, valid)
    unified, masks, num, den = fused_unify_pallas(
        task_vectors, valid, interpret=(mode == "pallas_interpret"))
    return unified, masks > 0.5, num, den


def fused_unify(task_vectors: jax.Array, valid: jax.Array, *,
                eps: float = 1e-12, mode: Optional[str] = None):
    """Batched unify + task-mask + λ-scaler over slot-packed clients.

    task_vectors (B, K, d); valid (B, K) bool.  Returns
    (unified (B, d), masks (B, K, d) bool, lams (B, K)) — row b equals
    ``unify_with_modulators(task_vectors[b, valid[b]])`` on the valid
    slots; invalid slots give zero mask rows and λ = 0.
    """
    unified, masks, num, den = fused_unify_raw(task_vectors, valid,
                                               packed=False, mode=mode)
    lams = num / jnp.maximum(den, eps)
    return unified, masks, lams


def pack_masks(masks: jax.Array, *, mode: Optional[str] = None) -> jax.Array:
    """(..., d) bool -> (..., ceil(d/32)) uint32, LSB-first — THE wire
    layout (see ``repro.kernels.bitpack`` for the bit convention).
    Identical in every dispatch mode: packing is pure elementwise bit
    algebra, already optimal under XLA."""
    _norm(mode)
    return bitpack.pack_bits(masks)


def unpack_masks(words: jax.Array, d: int, *,
                 mode: Optional[str] = None) -> jax.Array:
    """Inverse of :func:`pack_masks` — the ONLY sanctioned route back to
    dense bool masks.  Test/diagnostic helper: the round path computes
    on packed words directly and never calls this."""
    _norm(mode)
    return bitpack.unpack_bits(words, d)


def fused_unify_packed(task_vectors: jax.Array, valid: jax.Array, *,
                       eps: float = 1e-12, mode: Optional[str] = None):
    """Wire-format :func:`fused_unify`: same math, but emits the uplink
    tensors — bf16 unified vectors and bit-packed mask words.

    task_vectors (B, K, d) fp32/bf16; valid (B, K) bool.  Returns
    (unified (B, d) bf16, mask_words (B, K, ceil(d/32)) uint32,
    lams (B, K) fp32).  Mask bits and λ are decided on fp32 values
    before the bf16 rounding; masks are bit-identical to
    :func:`fused_unify` on the same inputs in every mode, λ is
    bit-identical on the "ref" path (same chunking) and matches to
    fp32 accumulation tolerance on the Pallas paths (different tile
    width).
    """
    uni, words, num, den = fused_unify_raw(task_vectors, valid,
                                           packed=True, mode=mode)
    lams = num / jnp.maximum(den, eps)
    return uni, words, lams


def masked_agg_batched_packed(unified, mask_words, lams, gammas, members,
                              d: int, *, rho: float = 0.4,
                              mode: Optional[str] = None):
    """Whole-round Eq. 3 + Eq. 4 over packed (N, T, ceil(d/32)) mask
    words (+ bf16-capable unified).  Returns (tau_hats, alpha_num) —
    m̂ is derivable as ``where(alpha_num/max(N_t,1) >= rho, 1, ·)``.
    The "ref" dispatch unpacks and delegates to the bool oracle
    (validation path); the Pallas modes expand words in VMEM only."""
    mode = _norm(mode)
    if mode == "ref":
        masks = bitpack.unpack_bits(mask_words, d, jnp.float32)
        tau, m_hat = ref.masked_agg_batched_ref(
            unified.astype(jnp.float32), masks, lams, gammas, members, rho)
        memf = members.astype(jnp.float32)
        sign_u = jnp.sign(unified.astype(jnp.float32))
        a_num = jnp.abs(jnp.einsum("nt,ntd->td", memf,
                                   masks * sign_u[:, None, :]))
        return tau, a_num
    return masked_agg_batched_packed_pallas(
        unified, mask_words, lams, gammas, members, rho=rho,
        interpret=(mode == "pallas_interpret"))


def sign_sim_packed(pos: jax.Array, nz: jax.Array, d: int, *,
                    mode: Optional[str] = None) -> jax.Array:
    """Eq. 5 similarity from packed sign bit-planes (popcount form);
    ``d`` is the unpacked feature count for the 1/d normalisation."""
    mode = _norm(mode)
    if mode == "ref":
        dots = bitpack.packed_sign_dots(pos, nz).astype(jnp.float32)
    else:
        dots = sign_sim_packed_pallas(
            pos, nz, interpret=(mode == "pallas_interpret"))
    return 0.5 * (dots / d + 1.0)


def topk_weights(sim: jax.Array, *, eps: float = 0.5, kappa: int = 3,
                 mode: Optional[str] = None) -> jax.Array:
    """Eq. 6 top-κ neighbourhood weights (XLA-optimal at (T, T) scale)."""
    _norm(mode)
    return ref.topk_weights_ref(sim, eps, kappa)


def cross_task_combine(tau_hats: jax.Array, m_hats: jax.Array,
                       sim_weights: jax.Array, *, mode: Optional[str] = None):
    """Eq. 6 + Eq. 7: returns (task_vectors, tau_tildes)."""
    _norm(mode)
    return ref.cross_task_combine_ref(tau_hats, m_hats, sim_weights)


def modulated_matmul(x: jax.Array, base: jax.Array, tau: jax.Array,
                     words: jax.Array, lam: jax.Array, *,
                     mode: Optional[str] = None) -> jax.Array:
    """Serving: per-request modulated LoRA matmul,
    ``y_b = x_b @ (base + lam_b · m_b ⊙ tau)`` with the modulator mask
    kept bit-packed until VMEM (fused word-unpack + λ-scale + matmul —
    no per-request effective weight in HBM).

    x (B, S, K); base/tau (K, N) fp32; words (B, ceil(K·N/32)) uint32
    row-major (K, N) mask bits in the LSB-first wire layout; lam (B,)
    fp32.  Returns (B, S, N) fp32.  ``K · N`` must be word-aligned
    (% 32 == 0) — the serve router only routes qualifying leaves here.
    The "ref" dispatch is the unpack-then-matmul oracle; all modes are
    bit-identical (see tests/test_serve_multitenant.py).
    """
    mode = _norm(mode)
    k, n = base.shape
    if (k * n) % 32:
        raise ValueError(f"modulated_matmul needs a word-aligned leaf "
                         f"(K*N % 32 == 0), got {(k, n)}")
    if mode == "ref":
        return ref.modulated_matmul_ref(x, base, tau, words, lam)
    return modulated_matmul_pallas(x, base, tau, words, lam,
                                   interpret=(mode == "pallas_interpret"))


def _slot_scalars_to_dense(slot_lams, slot_sizes, slot_valid, slot_tasks,
                           n_tasks: int):
    """Scatter the per-slot scalars to the dense (N, T) layout (shared
    by the bool and packed slot→dense contracts)."""
    n = slot_lams.shape[0]
    rows = jnp.arange(n)[:, None]
    lams_d = jnp.zeros((n, n_tasks), jnp.float32).at[rows, slot_tasks].set(
        jnp.where(slot_valid, slot_lams, 0.0), mode="drop")
    member_d = jnp.zeros((n, n_tasks), bool).at[rows, slot_tasks].set(
        slot_valid, mode="drop")
    sizes_d = jnp.zeros((n, n_tasks), jnp.float32).at[rows, slot_tasks].set(
        jnp.where(slot_valid, slot_sizes, 0.0), mode="drop")
    return lams_d, member_d, sizes_d


def slots_to_dense(slot_masks, slot_lams, slot_sizes, slot_valid, slot_tasks,
                   n_tasks: int):
    """Scatter slot-packed round tensors to the dense per-task layout
    ((N, T, d) masks, (N, T) lams/member/sizes).  Sentinel task ids
    (== n_tasks) are scatter-dropped.  The single definition of the
    slot→dense contract — used by the kernel round path and by
    ``PackedRound.dense_tensors``."""
    n, k, d = slot_masks.shape
    rows = jnp.arange(n)[:, None]
    masks_d = jnp.zeros((n, n_tasks, d), bool).at[rows, slot_tasks].set(
        jnp.where(slot_valid[:, :, None], slot_masks, False), mode="drop")
    lams_d, member_d, sizes_d = _slot_scalars_to_dense(
        slot_lams, slot_sizes, slot_valid, slot_tasks, n_tasks)
    return masks_d, lams_d, member_d, sizes_d


def slots_to_dense_packed(slot_mask_words, slot_lams, slot_sizes, slot_valid,
                          slot_tasks, n_tasks: int):
    """Packed twin of :func:`slots_to_dense`: the mask scatter moves
    uint32 words, 8x less data than the bool layout."""
    n, k, dw = slot_mask_words.shape
    rows = jnp.arange(n)[:, None]
    words_d = jnp.zeros((n, n_tasks, dw), jnp.uint32).at[
        rows, slot_tasks].set(
        jnp.where(slot_valid[:, :, None], slot_mask_words, jnp.uint32(0)),
        mode="drop")
    lams_d, member_d, sizes_d = _slot_scalars_to_dense(
        slot_lams, slot_sizes, slot_valid, slot_tasks, n_tasks)
    return words_d, lams_d, member_d, sizes_d


def _round_slots_dense(unified, slot_masks, slot_lams, slot_sizes, slot_valid,
                       slot_tasks, n_tasks, *, rho, eps, kappa, cross_task,
                       uniform_cross, mode, axis_name=None, d_norm=0):
    """Kernel-path round: scatter the slot tensors to the dense
    (N, T, d) layout the Pallas kernels consume, then compose the
    batched masked-agg, sign-sim, and fused-unify kernels.  On TPU the
    dense read is a single HBM stream per kernel; on CPU this path is
    validation-only (interpret mode).

    With ``axis_name`` set the function is a ``shard_map`` body on the
    local d-slice: the Eq. 5 dots go through the popcount kernel (raw
    integers — the fused normalised kernel cannot be un-normalised
    exactly) plus one psum, and the λ num/den partial sums one more —
    λ agrees with the single-device kernels to fp32 accumulation
    tolerance (tile grouping differs), the PR 2 Pallas caveat."""
    masks_d, lams_d, member_d, sizes_d = slots_to_dense(
        slot_masks, slot_lams, slot_sizes, slot_valid, slot_tasks, n_tasks)

    memf = member_d.astype(jnp.float32)
    gam = sizes_d * memf
    gam = gam / jnp.maximum(jnp.sum(gam, axis=0, keepdims=True), 1e-12)
    tau_hats, m_hats = masked_agg_batched(unified, masks_d, lams_d, gam,
                                          member_d, rho=rho, mode=mode)
    held = jnp.any(member_d, axis=0)
    heldf = held.astype(jnp.float32)
    if axis_name is None:
        sim = sign_sim(tau_hats, mode=mode) * heldf[None, :] * heldf[:, None]
    else:
        pos, nz = bitpack.sign_planes(tau_hats)
        dots = sign_sim_packed_pallas(
            pos, nz, interpret=(mode == "pallas_interpret"))
        dots = jax.lax.psum(dots, axis_name)
        sim = (0.5 * (dots.astype(jnp.float32) / d_norm + 1.0)
               * heldf[None, :] * heldf[:, None])
    weights = ref.cross_weights_ref(sim, held, eps=eps, kappa=kappa,
                                    cross_task=cross_task,
                                    uniform_cross=uniform_cross)
    task_vectors, _tau_tildes = ref.cross_task_combine_ref(tau_hats, m_hats,
                                                           weights)
    # sentinel slot ids are clamped; the valid mask zeroes their output
    tvs_slots = jnp.take(task_vectors, slot_tasks, axis=0, mode="clip")
    uni, dmasks, num, den = fused_unify_pallas(
        tvs_slots, slot_valid, interpret=(mode == "pallas_interpret"))
    if axis_name is not None:
        num, den = jax.lax.psum((num, den), axis_name)
    return (task_vectors, tau_hats, m_hats, sim,
            uni, dmasks > 0.5, num, den)


def _apply_slot_weights(slot_lams, slot_sizes, slot_weights):
    """Staleness-discount pre-scaling (async rounds): per-slot weights
    w ∈ (0, 1] scale both the modulator λ (the slot's reconstructed
    vector shrinks toward zero) and the γ size weight (the slot loses
    share in the Eq. 3 normalization) BEFORE the weighted values enter
    the masked-agg / λ block-partial kernels — so no kernel needs a new
    operand.  ``w = 1`` is bitwise exact (IEEE multiply by 1.0), which
    is what keeps the zero-staleness async round bit-identical to the
    sync one."""
    if slot_weights is None:
        return slot_lams, slot_sizes
    w = slot_weights.astype(jnp.float32)
    lams = None if slot_lams is None else slot_lams * w
    return lams, slot_sizes * w


def matu_round_slots(unified, slot_masks, slot_lams, slot_sizes, slot_valid,
                     slot_tasks, n_tasks: int, *, rho: float = 0.4,
                     eps: float = 0.5, kappa: int = 3,
                     cross_task: bool = True, uniform_cross: bool = False,
                     lam_eps: float = 1e-12, mode: Optional[str] = None,
                     slot_weights=None,
                     axis_name=None, axis_sizes=(), d_norm: int = 0):
    """The full MaTU server round over slot-packed uploads — the single
    entry point of :class:`repro.core.engine.RoundEngine`.

    "ref" runs the two-pass cache-blocked streaming round
    (O(Σk_n · d) work, d-chunked so accumulators stay cache-resident);
    the Pallas modes scatter to the dense layout and compose the
    batched kernels.  Returns (task_vectors, tau_hats, m_hats,
    similarity, down_unified, down_masks, down_lams).  τ̃ is not
    materialised (derivable as (2τ − τ̂) on rows with donors).

    ``axis_name`` / ``axis_sizes`` / ``d_norm`` make the op a
    ``shard_map`` body over the taskvec axis (see the engine's sharding
    contract): inputs are the local d-slice, ``d_norm`` is the global
    feature count, and the Eq. 5 dots + λ num/den totals are the only
    cross-shard collectives.

    ``slot_weights`` (optional, (N, K) fp32) is the async staleness
    discount — see :func:`_apply_slot_weights`.
    """
    mode = _norm(mode)
    slot_lams, slot_sizes = _apply_slot_weights(slot_lams, slot_sizes,
                                                slot_weights)
    kw = dict(rho=rho, eps=eps, kappa=kappa, cross_task=cross_task,
              uniform_cross=uniform_cross)
    if mode == "ref":
        out = ref.matu_round_slots_ref(unified, slot_masks, slot_lams,
                                       slot_sizes, slot_valid, slot_tasks,
                                       n_tasks, axis_name=axis_name,
                                       axis_sizes=axis_sizes, d_norm=d_norm,
                                       **kw)
    else:
        out = _round_slots_dense(unified, slot_masks, slot_lams, slot_sizes,
                                 slot_valid, slot_tasks, n_tasks,
                                 mode=mode, axis_name=axis_name,
                                 d_norm=d_norm, **kw)
    (task_vectors, tau_hats, m_hats, sim,
     down_unified, down_masks, num, den) = out
    down_lams = num / jnp.maximum(den, lam_eps)
    return (task_vectors, tau_hats, m_hats, sim,
            down_unified, down_masks, down_lams)


def _round_slots_dense_packed(unified, slot_mask_words, slot_lams, slot_sizes,
                              slot_valid, slot_tasks, n_tasks, d, *, rho, eps,
                              kappa, cross_task, uniform_cross, mode,
                              axis_name=None, d_norm=0):
    """Packed kernel-path round: scatter the uint32 mask words to the
    dense (N, T, d/32) layout, then compose the packed batched
    masked-agg, popcount sign-sim, and packed fused-unify kernels.  The
    mask tensor stays 1 bit/element in HBM end to end; words are
    expanded to lanes only inside VMEM tiles.  With ``axis_name`` set
    this is a ``shard_map`` body on the local d-slice: the popcount
    dots (exact integers) and the λ num/den partial sums are psum'd."""
    words_d, lams_d, member_d, sizes_d = slots_to_dense_packed(
        slot_mask_words, slot_lams, slot_sizes, slot_valid, slot_tasks,
        n_tasks)

    memf = member_d.astype(jnp.float32)
    gam = sizes_d * memf
    gam = gam / jnp.maximum(jnp.sum(gam, axis=0, keepdims=True), 1e-12)
    interp = (mode == "pallas_interpret")
    tau_hats, a_num = masked_agg_batched_packed_pallas(
        unified, words_d, lams_d, gam, member_d, rho=rho, interpret=interp)
    n_t = jnp.sum(memf, axis=0)
    held = n_t > 0
    heldf = held.astype(jnp.float32)
    alpha = a_num / jnp.maximum(n_t, 1.0)[:, None]
    m_hats = jnp.where(alpha >= rho, 1.0, alpha)

    pos, nz = bitpack.sign_planes(tau_hats)
    dots = sign_sim_packed_pallas(pos, nz, interpret=interp)
    if axis_name is not None:
        dots = jax.lax.psum(dots, axis_name)
    sim = (0.5 * (dots / (d_norm or d) + 1.0)
           * heldf[None, :] * heldf[:, None])
    weights = ref.cross_weights_ref(sim, held, eps=eps, kappa=kappa,
                                    cross_task=cross_task,
                                    uniform_cross=uniform_cross)
    task_vectors, _tau_tildes = ref.cross_task_combine_ref(tau_hats, m_hats,
                                                           weights)
    # sentinel slot ids are clamped; the valid mask zeroes their output
    tvs_slots = jnp.take(task_vectors, slot_tasks, axis=0, mode="clip")
    uni, dwords, num, den = fused_unify_packed_pallas(
        tvs_slots, slot_valid, interpret=interp)
    if axis_name is not None:
        num, den = jax.lax.psum((num, den), axis_name)
    a_u8 = a_num.astype(ref.alpha_dtype(slot_valid.shape[0]))
    return (task_vectors, tau_hats, a_u8, n_t, sim, uni, dwords, num, den)


def matu_round_slots_packed(unified, slot_mask_words, slot_lams, slot_sizes,
                            slot_valid, slot_tasks, n_tasks: int, d: int, *,
                            rho: float = 0.4, eps: float = 0.5,
                            kappa: int = 3, cross_task: bool = True,
                            uniform_cross: bool = False,
                            lam_eps: float = 1e-12,
                            mode: Optional[str] = None,
                            slot_weights=None,
                            axis_name=None, axis_sizes=(), d_norm: int = 0):
    """The full MaTU server round over wire-format slot uploads — the
    default entry point of :class:`repro.core.engine.RoundEngine`.

    Layout: ``unified`` (N, d) bf16 (fp32 tolerated), ``slot_mask_words``
    (N, K, ceil(d/32)) uint32 bit-packed masks (LSB-first, zero tail
    bits — see ``repro.kernels.bitpack``); scalars as in
    :func:`matu_round_slots`.  ``d`` is static (the word axis cannot
    express it).

    "ref" runs the two-pass cache-blocked packed streaming round; the
    Pallas modes scatter words to the dense packed layout and compose
    the packed kernels.  Returns (task_vectors fp32, tau_hats fp32,
    alpha_num uint8, n_held, similarity, down_unified bf16,
    down_mask_words uint32, down_lams) — m̂ is re-derivable from
    (alpha_num, n_held, ρ) and never materialised in fp32 on the hot
    path; τ̃ as before is (2τ − τ̂) on rows with donors.

    ``axis_name`` / ``axis_sizes`` / ``d_norm`` make the op a
    ``shard_map`` body over the taskvec axis — ``d`` is then the LOCAL
    unpacked count of this shard's slice (a multiple of 32; see the
    engine's sharding contract) and ``d_norm`` the global one.

    ``slot_weights`` (optional, (N, K) fp32) is the async staleness
    discount — see :func:`_apply_slot_weights`.
    """
    mode = _norm(mode)
    slot_lams, slot_sizes = _apply_slot_weights(slot_lams, slot_sizes,
                                                slot_weights)
    kw = dict(rho=rho, eps=eps, kappa=kappa, cross_task=cross_task,
              uniform_cross=uniform_cross)
    if mode == "ref":
        out = ref.matu_round_slots_packed_ref(
            unified, slot_mask_words, slot_lams, slot_sizes, slot_valid,
            slot_tasks, n_tasks, d, axis_name=axis_name,
            axis_sizes=axis_sizes, d_norm=d_norm, **kw)
    else:
        out = _round_slots_dense_packed(
            unified, slot_mask_words, slot_lams, slot_sizes, slot_valid,
            slot_tasks, n_tasks, d, mode=mode, axis_name=axis_name,
            d_norm=d_norm, **kw)
    (task_vectors, tau_hats, alpha_num, n_held, sim,
     down_unified, down_mask_words, num, den) = out
    down_lams = num / jnp.maximum(den, lam_eps)
    return (task_vectors, tau_hats, alpha_num, n_held, sim,
            down_unified, down_mask_words, down_lams)


# ---------------------------------------------------------------------------
# Chunked-slot hierarchical aggregation (client-axis streaming round).
#
# Every dispatch mode routes to the streaming jnp implementation in
# ``repro.kernels.ref`` — the chunk folds are scatter-adds and
# cache-blocked elementwise sweeps that XLA already emits optimally,
# and the chunk-count-invariance contract (chunked ≡ monolithic
# bitwise in ref mode) is defined against that implementation.  The
# Pallas kernels remain the monolithic round's accelerated path.
# ---------------------------------------------------------------------------


def matu_chunk_scalars(slot_sizes, slot_valid, slot_tasks, totals_acc,
                       nt_acc, *, slot_weights=None,
                       mode: Optional[str] = None):
    """Phase A of the chunked round: fold one chunk's per-task size
    totals (γ normaliser) and membership counts (Eq. 3 N_t) into the
    carried (T+1,) accumulators.  ``slot_weights`` applies the async
    staleness discount to the sizes exactly as the monolithic round
    does (:func:`_apply_slot_weights`)."""
    _norm(mode)
    _, slot_sizes = _apply_slot_weights(None, slot_sizes, slot_weights)
    return ref.matu_chunk_scalars_ref(slot_sizes, slot_valid, slot_tasks,
                                      totals_acc, nt_acc)


def matu_merge_chunk(unified, slot_masks, slot_lams, slot_sizes, slot_valid,
                     slot_tasks, totals, a_acc, tau_acc, *,
                     slot_weights=None, mode: Optional[str] = None):
    """Phase B of the chunked round, bool/fp32 layout: fold one client
    chunk's Eq. 3 sign votes and Eq. 4 merge partials into the carried
    (T+1, dp) fp32 accumulators (``totals`` from phase A)."""
    _norm(mode)
    slot_lams, slot_sizes = _apply_slot_weights(slot_lams, slot_sizes,
                                                slot_weights)
    return ref.matu_merge_chunk_ref(unified, slot_masks, slot_lams,
                                    slot_sizes, slot_valid, slot_tasks,
                                    totals, a_acc, tau_acc)


def matu_merge_chunk_packed(unified, slot_mask_words, slot_lams, slot_sizes,
                            slot_valid, slot_tasks, totals, a_acc, tau_acc,
                            d: int, *, slot_weights=None,
                            mode: Optional[str] = None):
    """Phase B, wire layout: ``a_acc`` is (T+1, dp) int32 (exact sign
    votes), ``tau_acc`` (T+1, dp) fp32; ``d`` is static (local count
    under ``shard_map``)."""
    _norm(mode)
    slot_lams, slot_sizes = _apply_slot_weights(slot_lams, slot_sizes,
                                                slot_weights)
    return ref.matu_merge_chunk_packed_ref(unified, slot_mask_words,
                                           slot_lams, slot_sizes, slot_valid,
                                           slot_tasks, totals, a_acc,
                                           tau_acc, d=d)


def matu_finish(a_acc, tau_acc, nt_acc, *, n_tasks: int, d: int,
                rho: float = 0.4, eps: float = 0.5, kappa: int = 3,
                cross_task: bool = True, uniform_cross: bool = False,
                mode: Optional[str] = None,
                axis_name=None, axis_sizes=(), d_norm: int = 0):
    """Finish the chunked bool-layout round from the accumulators:
    returns (task_vectors, tau_hats, m_hats, n_t, similarity, num_t)."""
    _norm(mode)
    return ref.matu_finish_ref(a_acc, tau_acc, nt_acc, n_tasks=n_tasks, d=d,
                               rho=rho, eps=eps, kappa=kappa,
                               cross_task=cross_task,
                               uniform_cross=uniform_cross,
                               axis_name=axis_name, axis_sizes=axis_sizes,
                               d_norm=d_norm)


def matu_finish_packed(a_acc, tau_acc, nt_acc, n_clients: int, *,
                       n_tasks: int, d: int, rho: float = 0.4,
                       eps: float = 0.5, kappa: int = 3,
                       cross_task: bool = True, uniform_cross: bool = False,
                       mode: Optional[str] = None,
                       axis_name=None, axis_sizes=(), d_norm: int = 0):
    """Finish the chunked packed round: returns (task_vectors, tau_hats,
    alpha_num, n_t, similarity, num_t).  ``n_clients`` is the round's
    total client count (it picks the monolithic ``alpha_dtype``)."""
    _norm(mode)
    return ref.matu_finish_packed_ref(a_acc, tau_acc, nt_acc, n_clients,
                                      n_tasks=n_tasks, d=d, rho=rho, eps=eps,
                                      kappa=kappa, cross_task=cross_task,
                                      uniform_cross=uniform_cross,
                                      axis_name=axis_name,
                                      axis_sizes=axis_sizes, d_norm=d_norm)


def matu_downlink_chunk(task_vectors, slot_valid, slot_tasks, num_t, *,
                        n_tasks: int, lam_eps: float = 1e-12,
                        mode: Optional[str] = None,
                        axis_name=None, axis_sizes=()):
    """Phase C, bool layout: downlink re-unification of one client chunk
    from the finished task vectors.  Returns (down_unified (C, d) fp32,
    down_masks (C, K, d) bool, down_lams (C, K)) — the λ division is
    the monolithic round's ``num / max(den, lam_eps)``."""
    _norm(mode)
    uni, dmasks, num, den = ref.matu_downlink_chunk_ref(
        task_vectors, slot_valid, slot_tasks, num_t, n_tasks=n_tasks,
        axis_name=axis_name, axis_sizes=axis_sizes)
    down_lams = num / jnp.maximum(den, lam_eps)
    return uni, dmasks, down_lams


def matu_downlink_chunk_packed(task_vectors, slot_tasks, num_t, d: int, *,
                               lam_eps: float = 1e-12,
                               mode: Optional[str] = None,
                               axis_name=None, axis_sizes=()):
    """Phase C, wire layout: returns (down_unified (C, d) bf16,
    down_mask_words (C, K, ceil(d/32)) uint32, down_lams (C, K))."""
    _norm(mode)
    uni, dwords, num, den = ref.matu_downlink_chunk_packed_ref(
        task_vectors, slot_tasks, num_t, d=d,
        axis_name=axis_name, axis_sizes=axis_sizes)
    down_lams = num / jnp.maximum(den, lam_eps)
    return uni, dwords, down_lams
