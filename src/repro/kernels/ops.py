"""Dispatch layer over the Pallas kernels — the ONLY entry point the
round engine (repro.core.engine) uses for Eq. 2–7 math.

Three dispatch modes:

  "pallas"            natively-compiled kernels (TPU backend)
  "pallas_interpret"  kernel bodies executed by the Pallas interpreter
                      (bit-identical to the TPU lowering; validation
                      path, far too slow for the CPU hot loop)
  "ref"               pure-jnp oracles (repro.kernels.ref) — the fast
                      XLA path on CPU/GPU

Resolution (``resolve_mode``): ``REPRO_DISABLE_PALLAS=1`` forces "ref"
everywhere; on TPU the default is "pallas"; elsewhere the default is
"ref" unless ``REPRO_PALLAS_INTERPRET=1`` opts into interpreter-mode
validation.  Every op also takes an explicit ``mode=`` so jitted
callers (the round engine) can resolve once per call and key their jit
cache on it instead of re-reading the environment at trace time.

The small (T, T)-sized Eq. 6–7 ops (top-κ filter, cross-task combine)
have no Pallas kernel — a (T, T) top-k plus a (T, T)·(T, d) MXU matmul
is already optimal under XLA — but are still routed through here so no
jnp-only server path remains outside this module.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fused_unify import fused_unify_pallas
from repro.kernels.masked_agg import masked_agg_batched_pallas, masked_agg_pallas
from repro.kernels.sign_sim import sign_sim_pallas
from repro.kernels.unify import unify_pallas

MODES = ("pallas", "pallas_interpret", "ref")


def resolve_mode() -> str:
    """Pick the dispatch mode for the current process/backend."""
    if os.environ.get("REPRO_DISABLE_PALLAS", "0") == "1":
        return "ref"
    if jax.default_backend() == "tpu":
        return "pallas"
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return "pallas_interpret"
    return "ref"


def _norm(mode: Optional[str]) -> str:
    mode = mode or resolve_mode()
    if mode not in MODES:
        raise ValueError(f"unknown kernel dispatch mode {mode!r}; "
                         f"expected one of {MODES}")
    return mode


def unify(task_vectors: jax.Array, *, mode: Optional[str] = None) -> jax.Array:
    """(K, d) -> (d,) task unification (Eq. 2)."""
    mode = _norm(mode)
    if mode == "ref":
        return ref.unify_ref(task_vectors)
    return unify_pallas(task_vectors, interpret=(mode == "pallas_interpret"))


def masked_agg(unified, masks, lams, gammas, *, rho: float = 0.4,
               mode: Optional[str] = None):
    """Single-task Eq. 3 + Eq. 4 (membership inferred from gammas>0)."""
    mode = _norm(mode)
    if mode == "ref":
        return ref.masked_agg_ref(unified, masks, lams, gammas, rho)
    return masked_agg_pallas(unified, masks, lams, gammas, rho=rho,
                             interpret=(mode == "pallas_interpret"))


def masked_agg_batched(unified, masks, lams, gammas, members, *,
                       rho: float = 0.4, mode: Optional[str] = None):
    """Whole-round Eq. 3 + Eq. 4 over packed (N, T, d) tensors."""
    mode = _norm(mode)
    if mode == "ref":
        return ref.masked_agg_batched_ref(unified, masks, lams, gammas,
                                          members, rho)
    return masked_agg_batched_pallas(unified, masks, lams, gammas, members,
                                     rho=rho,
                                     interpret=(mode == "pallas_interpret"))


def sign_sim(tau_hats: jax.Array, *, mode: Optional[str] = None) -> jax.Array:
    """Eq. 5 sign-conflict similarity (T, d) -> (T, T)."""
    mode = _norm(mode)
    if mode == "ref":
        return ref.sign_sim_ref(tau_hats)
    return sign_sim_pallas(tau_hats, interpret=(mode == "pallas_interpret"))


def fused_unify(task_vectors: jax.Array, valid: jax.Array, *,
                eps: float = 1e-12, mode: Optional[str] = None):
    """Batched unify + task-mask + λ-scaler over slot-packed clients.

    task_vectors (B, K, d); valid (B, K) bool.  Returns
    (unified (B, d), masks (B, K, d) bool, lams (B, K)) — row b equals
    ``unify_with_modulators(task_vectors[b, valid[b]])`` on the valid
    slots; invalid slots give zero mask rows and λ = 0.
    """
    mode = _norm(mode)
    if mode == "ref":
        unified, masks, num, den = ref.fused_unify_ref(task_vectors, valid)
    else:
        unified, masks, num, den = fused_unify_pallas(
            task_vectors, valid, interpret=(mode == "pallas_interpret"))
        masks = masks > 0.5
    lams = num / jnp.maximum(den, eps)
    return unified, masks, lams


def topk_weights(sim: jax.Array, *, eps: float = 0.5, kappa: int = 3,
                 mode: Optional[str] = None) -> jax.Array:
    """Eq. 6 top-κ neighbourhood weights (XLA-optimal at (T, T) scale)."""
    _norm(mode)
    return ref.topk_weights_ref(sim, eps, kappa)


def cross_task_combine(tau_hats: jax.Array, m_hats: jax.Array,
                       sim_weights: jax.Array, *, mode: Optional[str] = None):
    """Eq. 6 + Eq. 7: returns (task_vectors, tau_tildes)."""
    _norm(mode)
    return ref.cross_task_combine_ref(tau_hats, m_hats, sim_weights)


def slots_to_dense(slot_masks, slot_lams, slot_sizes, slot_valid, slot_tasks,
                   n_tasks: int):
    """Scatter slot-packed round tensors to the dense per-task layout
    ((N, T, d) masks, (N, T) lams/member/sizes).  Sentinel task ids
    (== n_tasks) are scatter-dropped.  The single definition of the
    slot→dense contract — used by the kernel round path and by
    ``PackedRound.dense_tensors``."""
    n, k, d = slot_masks.shape
    rows = jnp.arange(n)[:, None]
    masks_d = jnp.zeros((n, n_tasks, d), bool).at[rows, slot_tasks].set(
        jnp.where(slot_valid[:, :, None], slot_masks, False), mode="drop")
    lams_d = jnp.zeros((n, n_tasks), jnp.float32).at[rows, slot_tasks].set(
        jnp.where(slot_valid, slot_lams, 0.0), mode="drop")
    member_d = jnp.zeros((n, n_tasks), bool).at[rows, slot_tasks].set(
        slot_valid, mode="drop")
    sizes_d = jnp.zeros((n, n_tasks), jnp.float32).at[rows, slot_tasks].set(
        jnp.where(slot_valid, slot_sizes, 0.0), mode="drop")
    return masks_d, lams_d, member_d, sizes_d


def _round_slots_dense(unified, slot_masks, slot_lams, slot_sizes, slot_valid,
                       slot_tasks, n_tasks, *, rho, eps, kappa, cross_task,
                       uniform_cross, mode):
    """Kernel-path round: scatter the slot tensors to the dense
    (N, T, d) layout the Pallas kernels consume, then compose the
    batched masked-agg, sign-sim, and fused-unify kernels.  On TPU the
    dense read is a single HBM stream per kernel; on CPU this path is
    validation-only (interpret mode)."""
    masks_d, lams_d, member_d, sizes_d = slots_to_dense(
        slot_masks, slot_lams, slot_sizes, slot_valid, slot_tasks, n_tasks)

    memf = member_d.astype(jnp.float32)
    gam = sizes_d * memf
    gam = gam / jnp.maximum(jnp.sum(gam, axis=0, keepdims=True), 1e-12)
    tau_hats, m_hats = masked_agg_batched(unified, masks_d, lams_d, gam,
                                          member_d, rho=rho, mode=mode)
    held = jnp.any(member_d, axis=0)
    heldf = held.astype(jnp.float32)
    sim = sign_sim(tau_hats, mode=mode) * heldf[None, :] * heldf[:, None]
    weights = ref.cross_weights_ref(sim, held, eps=eps, kappa=kappa,
                                    cross_task=cross_task,
                                    uniform_cross=uniform_cross)
    task_vectors, _tau_tildes = ref.cross_task_combine_ref(tau_hats, m_hats,
                                                           weights)
    # sentinel slot ids are clamped; the valid mask zeroes their output
    tvs_slots = jnp.take(task_vectors, slot_tasks, axis=0, mode="clip")
    uni, dmasks, num, den = fused_unify_pallas(
        tvs_slots, slot_valid, interpret=(mode == "pallas_interpret"))
    return (task_vectors, tau_hats, m_hats, sim,
            uni, dmasks > 0.5, num, den)


def matu_round_slots(unified, slot_masks, slot_lams, slot_sizes, slot_valid,
                     slot_tasks, n_tasks: int, *, rho: float = 0.4,
                     eps: float = 0.5, kappa: int = 3,
                     cross_task: bool = True, uniform_cross: bool = False,
                     lam_eps: float = 1e-12, mode: Optional[str] = None):
    """The full MaTU server round over slot-packed uploads — the single
    entry point of :class:`repro.core.engine.RoundEngine`.

    "ref" runs the two-pass cache-blocked streaming round
    (O(Σk_n · d) work, d-chunked so accumulators stay cache-resident);
    the Pallas modes scatter to the dense layout and compose the
    batched kernels.  Returns (task_vectors, tau_hats, m_hats,
    similarity, down_unified, down_masks, down_lams).  τ̃ is not
    materialised (derivable as (2τ − τ̂) on rows with donors).
    """
    mode = _norm(mode)
    kw = dict(rho=rho, eps=eps, kappa=kappa, cross_task=cross_task,
              uniform_cross=uniform_cross)
    if mode == "ref":
        out = ref.matu_round_slots_ref(unified, slot_masks, slot_lams,
                                       slot_sizes, slot_valid, slot_tasks,
                                       n_tasks, **kw)
    else:
        out = _round_slots_dense(unified, slot_masks, slot_lams, slot_sizes,
                                 slot_valid, slot_tasks, n_tasks,
                                 mode=mode, **kw)
    (task_vectors, tau_hats, m_hats, sim,
     down_unified, down_masks, num, den) = out
    down_lams = num / jnp.maximum(den, lam_eps)
    return (task_vectors, tau_hats, m_hats, sim,
            down_unified, down_masks, down_lams)
