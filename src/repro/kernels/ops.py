"""Jit'd dispatch layer over the Pallas kernels.

On TPU the kernels compile natively; everywhere else (this CPU
container, unit tests) they run in ``interpret=True`` mode, which
executes the kernel body in Python — bit-identical semantics, so the
allclose sweeps in tests/test_kernels.py validate the TPU code path.

Set ``REPRO_DISABLE_PALLAS=1`` to force the pure-jnp reference
implementations (used by A/B numerics checks).
"""

from __future__ import annotations

import os

import jax

from repro.kernels import ref
from repro.kernels.masked_agg import masked_agg_pallas
from repro.kernels.sign_sim import sign_sim_pallas
from repro.kernels.unify import unify_pallas


def _use_pallas() -> bool:
    return os.environ.get("REPRO_DISABLE_PALLAS", "0") != "1"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def unify(task_vectors: jax.Array) -> jax.Array:
    if _use_pallas():
        return unify_pallas(task_vectors, interpret=_interpret())
    return ref.unify_ref(task_vectors)


def masked_agg(unified, masks, lams, gammas, *, rho: float = 0.4):
    if _use_pallas():
        return masked_agg_pallas(unified, masks, lams, gammas, rho=rho,
                                 interpret=_interpret())
    return ref.masked_agg_ref(unified, masks, lams, gammas, rho)


def sign_sim(tau_hats: jax.Array) -> jax.Array:
    if _use_pallas():
        return sign_sim_pallas(tau_hats, interpret=_interpret())
    return ref.sign_sim_ref(tau_hats)
