"""Pure-jnp oracles for the Pallas kernels (the reference semantics).

These mirror ``repro.core`` math exactly; kernel tests sweep shapes and
dtypes asserting allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def unify_ref(task_vectors: jax.Array) -> jax.Array:
    """(K, d) -> (d,): sign election + max-|.| magnitude (Eq. 2)."""
    x = task_vectors.astype(jnp.float32)
    sigma = jnp.sign(jnp.sum(x, axis=0))
    aligned = (x * sigma[None, :]) > 0
    mu = jnp.max(jnp.abs(x) * aligned, axis=0)
    return sigma * mu


def masked_agg_ref(unified: jax.Array, masks: jax.Array, lams: jax.Array,
                   gammas: jax.Array, rho: float):
    """Eq. 3 + Eq. 4 fused for one task.

    unified (N, d); masks (N, d) {0,1}; lams (N,); gammas (N,) already
    normalised membership·|D| weights (zero rows = non-members).
    Returns (tau_hat (d,), m_hat (d,)).
    """
    u = unified.astype(jnp.float32)
    m = masks.astype(jnp.float32)
    member = (gammas > 0).astype(jnp.float32)
    n_t = jnp.maximum(jnp.sum(member), 1.0)
    signs = jnp.sign(u * m)
    alpha = jnp.abs(jnp.einsum("n,nd->d", member, signs)) / n_t
    m_hat = jnp.where(alpha >= rho, 1.0, alpha)
    recon = lams[:, None].astype(jnp.float32) * (u * m)
    tau_hat = jnp.einsum("n,nd->d", gammas.astype(jnp.float32), recon) * m_hat
    return tau_hat, m_hat


def sign_sim_ref(tau_hats: jax.Array) -> jax.Array:
    """Eq. 5: S = ½(sgn(T)·sgn(T)ᵀ/d + 1) over (T, d) -> (T, T)."""
    x = tau_hats.astype(jnp.float32)
    d = x.shape[-1]
    s = jnp.sign(x)
    return 0.5 * (s @ s.T / d + 1.0)
