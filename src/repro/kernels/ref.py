"""Pure-jnp oracles for the Pallas kernels (the reference semantics).

These mirror ``repro.core`` math exactly; kernel tests sweep shapes and
dtypes asserting allclose against these.

The two streaming round functions (``matu_round_slots_ref`` /
``matu_round_slots_packed_ref``) are also the bodies the sharded engine
runs per shard under ``shard_map``: with ``axis_name`` set they receive
the local d-slice of every d-axis tensor and reconstruct the few
genuinely global quantities with explicit collectives — the Eq. 5
(T, T) sign dots by one ``psum`` (integer-exact under any reduction
order) and the λ numerator/denominator totals by the shard-invariant
block-tree reduction below (bit-identical to the single-device round).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import bitpack


def unify_ref(task_vectors: jax.Array) -> jax.Array:
    """(K, d) -> (d,): sign election + max-|.| magnitude (Eq. 2)."""
    x = task_vectors.astype(jnp.float32)
    sigma = jnp.sign(jnp.sum(x, axis=0))
    aligned = (x * sigma[None, :]) > 0
    mu = jnp.max(jnp.abs(x) * aligned, axis=0)
    return sigma * mu


def masked_agg_ref(unified: jax.Array, masks: jax.Array, lams: jax.Array,
                   gammas: jax.Array, rho: float):
    """Eq. 3 + Eq. 4 fused for one task.

    unified (N, d); masks (N, d) {0,1}; lams (N,); gammas (N,) already
    normalised membership·|D| weights (zero rows = non-members).
    Returns (tau_hat (d,), m_hat (d,)).
    """
    u = unified.astype(jnp.float32)
    m = masks.astype(jnp.float32)
    member = (gammas > 0).astype(jnp.float32)
    n_t = jnp.maximum(jnp.sum(member), 1.0)
    signs = jnp.sign(u * m)
    alpha = jnp.abs(jnp.einsum("n,nd->d", member, signs)) / n_t
    m_hat = jnp.where(alpha >= rho, 1.0, alpha)
    recon = lams[:, None].astype(jnp.float32) * (u * m)
    tau_hat = jnp.einsum("n,nd->d", gammas.astype(jnp.float32), recon) * m_hat
    return tau_hat, m_hat


def sign_sim_ref(tau_hats: jax.Array) -> jax.Array:
    """Eq. 5: S = ½(sgn(T)·sgn(T)ᵀ/d + 1) over (T, d) -> (T, T)."""
    x = tau_hats.astype(jnp.float32)
    d = x.shape[-1]
    s = jnp.sign(x)
    return 0.5 * (s @ s.T / d + 1.0)


def masked_agg_batched_ref(unified: jax.Array, masks: jax.Array,
                           lams: jax.Array, gammas: jax.Array,
                           members: jax.Array, rho: float):
    """Eq. 3 + Eq. 4 fused over ALL tasks of a packed round.

    unified (N, d); masks (N, T, d) {0,1} (zero rows for non-members);
    lams/gammas/members (N, T).  ``members`` is the explicit A(n, t)
    allocation so a member with zero data weight still counts toward
    the agreement denominator N_t (matching ``matu_round``).

    Implemented as a sequential ``lax.map`` over the task axis so peak
    memory stays at O(N·d) regardless of T — the packed (N, T, d) mask
    tensor is only ever sliced, never materialised in fp32.
    Returns (tau_hats (T, d), m_hats (T, d)).
    """
    u = unified.astype(jnp.float32)
    sign_u = jnp.sign(u)

    def one_task(t):
        m = masks[:, t, :].astype(jnp.float32)         # (N, d)
        mem = members[:, t].astype(jnp.float32)        # (N,)
        gl = (gammas[:, t] * lams[:, t]).astype(jnp.float32)
        n_t = jnp.maximum(jnp.sum(mem), 1.0)
        alpha = jnp.abs(jnp.einsum("n,nd->d", mem, m * sign_u)) / n_t
        m_hat = jnp.where(alpha >= rho, 1.0, alpha)
        tau_hat = jnp.einsum("n,nd->d", gl, m * u) * m_hat
        return tau_hat, m_hat

    return jax.lax.map(one_task, jnp.arange(masks.shape[1]))


# d-axis streaming chunk for the CPU reference path: the per-chunk
# working set ((N, K, dc) fp32 products, (T, dc) accumulators) stays
# cache-resident, mirroring the Pallas kernels' VMEM grid over d.
CHUNK_D = 1 << 14

# Fixed block grid for the λ numerator/denominator reductions over d:
# partial sums are taken per LAMBDA_BLOCK consecutive coords and the
# totals combined by a power-of-two-aligned binary tree over block
# index (``_tree_total``).  Because the grid and tree depend only on
# the block index — never on chunk width or shard count — the λ totals
# of the sharded round are bit-identical to the single-device round's,
# provided shard boundaries land on block boundaries (the engine pads d
# so every shard holds a power-of-two number of whole blocks).  One
# block is 8 uint32 mask words, so block alignment subsumes the wire
# format's 32-bit word-boundary rule (``bitpack.WORD_BITS``).
LAMBDA_BLOCK = 256
assert LAMBDA_BLOCK % bitpack.WORD_BITS == 0


def _chunked(d: int, chunk: int):
    """Pick an effective chunk (≤ requested, covering small d in one
    step) and the padded length.  Chunks are always power-of-two
    multiples of LAMBDA_BLOCK, so the λ block grid tiles every chunk."""
    c = min(chunk, max(LAMBDA_BLOCK, 1 << (d - 1).bit_length()))
    pad = (-d) % c
    return c, d + pad


def _next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (n - 1).bit_length()


def _block_partials(x: jax.Array) -> jax.Array:
    """(..., c) -> (..., c // LAMBDA_BLOCK) per-block partial sums over
    the fixed λ block grid (c is a multiple of LAMBDA_BLOCK)."""
    s = x.shape
    return jnp.sum(x.reshape(s[:-1] + (s[-1] // LAMBDA_BLOCK, LAMBDA_BLOCK)),
                   axis=-1)


def _tree_total(p: jax.Array) -> jax.Array:
    """(..., L) -> (...,): canonical binary-tree sum, pairing elements
    (2i, 2i+1) at every level after zero-padding L to a power of two.

    The grouping depends only on the index grid, so any zero-padded
    extension of the same nonneg partials gives the bit-identical total
    (x + 0.0 is exact) — the property the shard-parity contract rests
    on."""
    L = p.shape[-1]
    Lp = _next_pow2(L)
    if Lp != L:
        p = jnp.pad(p, [(0, 0)] * (p.ndim - 1) + [(0, Lp - L)])
    while p.shape[-1] > 1:
        p = p[..., 0::2] + p[..., 1::2]
    return p[..., 0]


def _shard_offset(axis_name, axis_sizes) -> jax.Array:
    """Flat taskvec shard index of the executing device, major→minor in
    spec order — matches the d-axis layout of a dim sharded over the
    same mesh-axis tuple."""
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    idx = jnp.int32(0)
    for a, s in zip(names, axis_sizes):
        idx = idx * s + lax.axis_index(a)
    return idx


def _lam_totals(parts, axis_name=None, axis_sizes=()):
    """Finish the λ reductions from per-block partial buffers.

    Each ``parts`` entry is (..., n_blk_local) nonneg fp32 partials on
    the fixed LAMBDA_BLOCK grid.  Local blocks reduce by the canonical
    tree; under ``shard_map`` (axis_name set) the per-shard roots are
    scattered into a (n_shards,)-slot vector — exact, single contributor
    per slot — combined by ONE ``psum`` covering every λ array, and the
    tree finishes over the shard axis.  With power-of-two shard counts
    and whole power-of-two block counts per shard this is the exact
    canonical tree over the global block grid: bit-identical to the
    single-device reduction."""
    loc = tuple(_tree_total(p) for p in parts)
    if axis_name is None:
        return loc
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    n_sh = int(np.prod(axis_sizes))
    off = _shard_offset(names, axis_sizes)
    # every λ root rides ONE all-reduce: flatten + concat the roots,
    # scatter into this shard's slot column, psum, tree over shards
    flat = jnp.concatenate([x.reshape(-1) for x in loc])
    scat = lax.dynamic_update_slice_in_dim(
        jnp.zeros((flat.shape[0], n_sh), flat.dtype), flat[:, None], off,
        axis=1)
    total = _tree_total(lax.psum(scat, names))
    out, at = [], 0
    for x in loc:
        out.append(total[at:at + x.size].reshape(x.shape))
        at += x.size
    return tuple(out)


def _unify_block(x, vf):
    """Eq. 2 + modulators on one (…, K, dc) block; vf (…, K) float."""
    xm = x * vf[..., None]
    sigma = jnp.sign(jnp.sum(xm, axis=-2))
    aligned = (xm * sigma[..., None, :]) > 0
    mu = jnp.max(jnp.where(aligned, jnp.abs(xm), 0.0), axis=-2)
    tau = sigma * mu
    mask = ((x * tau[..., None, :]) > 0) & (vf[..., None] > 0)
    maskf = mask.astype(jnp.float32)
    num = jnp.sum(jnp.abs(xm), axis=-1)
    den = jnp.sum(maskf * jnp.abs(tau)[..., None, :], axis=-1)
    return tau, mask, num, den


def fused_unify_ref(task_vectors: jax.Array, valid: jax.Array, *,
                    chunk: int = CHUNK_D):
    """Fused unify + task-mask + λ-scaler, batched over clients.

    task_vectors (B, K, d) slot-packed per-task vectors (garbage/zero in
    invalid slots); valid (B, K) bool.  Invalid slots are zeroed before
    the sign election, so the result equals per-client
    ``unify_with_modulators(task_vectors[b, valid[b]])`` row-for-row.

    Streams the d axis in cache-sized chunks (one fori_loop writing
    into pre-allocated buffers in place), so every input byte is read
    once and every output byte written once.  Returns
    (unified (B, d), masks (B, K, d) bool, num (B, K), den (B, K))
    with λ = num / max(den, eps) left to the caller (invalid slots
    give num = den = 0 → λ = 0).
    """
    b, k, d = task_vectors.shape
    chunk, dp = _chunked(d, chunk)
    x_p = task_vectors.astype(jnp.float32)
    if dp != d:                      # aligned d never pays the pad copy
        x_p = jnp.pad(x_p, ((0, 0), (0, 0), (0, dp - d)))
    vf = valid.astype(jnp.float32)

    def step(c, carry):
        uni, msk, num, den = carry
        off = c * chunk
        x = jax.lax.dynamic_slice_in_dim(x_p, off, chunk, axis=2)
        tau, mask, num_c, den_c = _unify_block(x, vf)
        uni = jax.lax.dynamic_update_slice_in_dim(uni, tau, off, axis=1)
        msk = jax.lax.dynamic_update_slice_in_dim(msk, mask, off, axis=2)
        return uni, msk, num + num_c, den + den_c

    uni, msk, num, den = jax.lax.fori_loop(
        0, dp // chunk, step,
        (jnp.zeros((b, dp), jnp.float32), jnp.zeros((b, k, dp), bool),
         jnp.zeros((b, k), jnp.float32), jnp.zeros((b, k), jnp.float32)))
    return uni[:, :d], msk[:, :, :d], num, den


def fused_unify_packed_ref(task_vectors: jax.Array, valid: jax.Array, *,
                           chunk: int = CHUNK_D):
    """Wire-format variant of :func:`fused_unify_ref`: consumes bf16 (or
    fp32) slot-packed task vectors and emits the uplink wire tensors —
    bf16 unified vectors and bit-packed uint32 mask words.

    task_vectors (B, K, d) bf16/fp32; valid (B, K) bool.  All compute is
    fp32 per cache-sized d-chunk (inputs are upcast tile-by-tile, never
    as a whole), mask bits are decided on the fp32 values BEFORE the
    unified vector is rounded to bf16, and λ num/den stay fp32 — so the
    modulators are bit-identical to the bool/fp32 path on the same
    inputs.  Returns (unified (B, d) bf16, mask_words (B, K, ceil(d/32))
    uint32, num (B, K), den (B, K)).
    """
    b, k, d = task_vectors.shape
    chunk, dp = _chunked(d, chunk)
    dwc, dwp = chunk // 32, dp // 32
    x_p = task_vectors
    if dp != d:
        x_p = jnp.pad(x_p, ((0, 0), (0, 0), (0, dp - d)))
    vf = valid.astype(jnp.float32)

    # the unified carry stays fp32 inside the loop — a bf16 carry
    # defeats XLA's in-place buffer aliasing on CPU (each iteration
    # copies the whole buffer); the wire rounding is one streaming
    # cast after the loop
    def step(c, carry):
        uni, msk, num, den = carry
        off = c * chunk
        x = jax.lax.dynamic_slice_in_dim(x_p, off, chunk, axis=2)
        tau, mask, num_c, den_c = _unify_block(x.astype(jnp.float32), vf)
        words = bitpack.pack_bits(mask)
        uni = jax.lax.dynamic_update_slice_in_dim(uni, tau, off, axis=1)
        msk = jax.lax.dynamic_update_slice_in_dim(msk, words, c * dwc, axis=2)
        return uni, msk, num + num_c, den + den_c

    uni, msk, num, den = jax.lax.fori_loop(
        0, dp // chunk, step,
        (jnp.zeros((b, dp), jnp.float32),
         jnp.zeros((b, k, dwp), jnp.uint32),
         jnp.zeros((b, k), jnp.float32), jnp.zeros((b, k), jnp.float32)))
    return (uni[:, :d].astype(jnp.bfloat16),
            msk[:, :, :bitpack.packed_width(d)], num, den)


def alpha_dtype(n: int):
    """Narrowest dtype holding the Eq. 3 agreement numerator
    |Σ_n sgn(m ⊙ τ_n)| ≤ N_t ≤ n (an exact small integer)."""
    return jnp.uint8 if n <= 255 else jnp.int32


def matu_round_slots_packed_ref(unified: jax.Array, slot_mask_words: jax.Array,
                                slot_lams: jax.Array, slot_sizes: jax.Array,
                                slot_valid: jax.Array, slot_tasks: jax.Array,
                                n_tasks: int, d: int, *, rho: float,
                                eps: float, kappa: int,
                                cross_task: bool = True,
                                uniform_cross: bool = False,
                                chunk: int = CHUNK_D,
                                axis_name=None, axis_sizes=(),
                                d_norm: int = 0):
    """Wire-format twin of :func:`matu_round_slots_ref`: the same
    two-pass cache-blocked streaming round, but every big tensor stays
    in its transport layout end to end —

    * ``unified`` (N, d) arrives bf16 and is upcast fp32 one chunk at a
      time (never materialised dense);
    * ``slot_mask_words`` (N, K, ceil(d/32)) uint32 packed masks; the
      Eq. 3 sign election runs on bitwise ANDs of mask words against the
      sign bit-planes of τ_n, and only the two AND products are expanded
      to fp32 (the mask itself is never unpacked separately: the merge
      selector m·[τ≠0] is their sum, exact because τ=0 contributes 0);
    * Eq. 5 sign dots accumulate by popcount over the packed sign
      planes of τ̂ (exact integers — identical to the fp32 matmul);
    * m̂ is never materialised: pass 1 stores the agreement numerator
      |Σ sgn| as one byte per coordinate (exact; see ``alpha_dtype``)
      and pass 2 re-derives m̂ = 1[α ≥ ρ] ∨ α with the identical fp32
      division, so both passes see bit-identical values;
    * the downlink re-unification emits bf16 unified vectors and packed
      mask words — the downlink wire format — with mask bits and λ
      num/den decided on fp32 values before the bf16 rounding.

    Apart from transport rounding of the *inputs/outputs*, every fp32
    op runs in the same order as the bool/fp32 round, so on identical
    (already-quantised) inputs the masks and λs match bit for bit.

    Under ``shard_map`` (``axis_name`` set, with the mesh axis sizes in
    ``axis_sizes``) every d-axis tensor is the executing shard's slice,
    ``d`` is the LOCAL unpacked count, and ``d_norm`` carries the global
    feature count for the Eq. 5 1/d normalisation.  The Eq. 5 popcount
    dots cross shards through one integer ``psum`` (exact under any
    reduction order) and the λ num/den totals through the single
    ``_lam_totals`` psum — per-coordinate math never communicates.

    Returns (task_vectors (T, d) fp32, tau_hats (T, d) fp32,
    alpha_num (T, d) uint8, n_t (T,) fp32, similarity (T, T),
    down_unified (N, d) bf16, down_mask_words (N, K, ceil(d/32)),
    down_num (N, K), down_den (N, K)).
    """
    n, k, dw_in = slot_mask_words.shape
    m_rows = n * k
    chunk, dp = _chunked(d, chunk)
    dwc, dwp = chunk // 32, dp // 32
    n_blk, blkc = dp // LAMBDA_BLOCK, chunk // LAMBDA_BLOCK
    n_seg = n_tasks + 1
    a_dt = alpha_dtype(n)
    d_norm = d_norm or d

    ids = slot_tasks.reshape(m_rows)
    vf = slot_valid.reshape(m_rows).astype(jnp.float32)
    sizes = slot_sizes.reshape(m_rows).astype(jnp.float32) * vf
    totals = jax.ops.segment_sum(sizes, ids, num_segments=n_seg)
    gam = sizes / jnp.maximum(totals[ids], 1e-12)
    glv = gam * slot_lams.reshape(m_rows).astype(jnp.float32) * vf
    n_t = jax.ops.segment_sum(vf, ids, num_segments=n_seg)[:n_tasks]
    held = n_t > 0

    u_p = unified                       # stays bf16; upcast per chunk
    m_w = slot_mask_words
    if dp != d:
        u_p = jnp.pad(u_p, ((0, 0), (0, dp - d)))
    if dwp != dw_in:
        m_w = jnp.pad(m_w, ((0, 0), (0, 0), (0, dwp - dw_in)))

    glv_nk = glv.reshape(n, k)
    n_t_max = jnp.maximum(n_t, 1.0)

    # ---- pass 1: Eq. 3 + 4 per chunk, Eq. 5 popcount dots ----------------
    # one unpack per chunk (to int8 — the sign election is pure small-
    # integer algebra: int8 bits × int8 signs, exact) feeds both the
    # Eq. 3 election and the Eq. 4 merge; the packed words never exist
    # in fp32 outside this cache-resident block.  The fp32 merge keeps
    # the single whole-round segment-sum so its accumulation order is
    # identical to the bool layout's (bit-parity); the sign sum is
    # integer-exact under any order.
    def pass1(c, carry):
        tau_buf, anum_buf, dots = carry
        off = c * chunk
        uc = jax.lax.dynamic_slice_in_dim(u_p, off, chunk,
                                          axis=1).astype(jnp.float32)
        mw = jax.lax.dynamic_slice_in_dim(m_w, c * dwc, dwc, axis=2)
        mi8 = bitpack.unpack_bits(mw, chunk, jnp.int8)         # (N, K, dc)
        signs = (mi8 * jnp.sign(uc).astype(jnp.int8)[:, None, :])
        a_num = jax.ops.segment_sum(
            signs.reshape(m_rows, chunk).astype(jnp.int32), ids,
            num_segments=n_seg)[:n_tasks].astype(jnp.float32)
        recon = mi8.astype(jnp.float32) * (glv_nk[:, :, None]
                                           * uc[:, None, :])
        tau_pre = jax.ops.segment_sum(recon.reshape(m_rows, chunk), ids,
                                      num_segments=n_seg)[:n_tasks]
        a_abs = jnp.abs(a_num)
        alpha = a_abs / n_t_max[:, None]
        m_hat = jnp.where(alpha >= rho, 1.0, alpha)
        tau = tau_pre * m_hat
        pos_t, nz_t = bitpack.sign_planes(tau)
        dots = dots + bitpack.packed_sign_dots(pos_t, nz_t)
        tau_buf = jax.lax.dynamic_update_slice_in_dim(tau_buf, tau, off,
                                                      axis=1)
        anum_buf = jax.lax.dynamic_update_slice_in_dim(
            anum_buf, a_abs.astype(a_dt), off, axis=1)
        return tau_buf, anum_buf, dots

    tau_hats, anum_buf, dots = jax.lax.fori_loop(
        0, dp // chunk, pass1,
        (jnp.zeros((n_tasks, dp), jnp.float32),
         jnp.zeros((n_tasks, dp), a_dt),
         jnp.zeros((n_tasks, n_tasks), jnp.int32)))

    if axis_name is not None:
        # the one tensor collective of the sharded round: the (T, T)
        # popcount dots are exact integers, so the psum is bit-identical
        # to the single-device accumulation under any reduction order
        dots = lax.psum(dots, axis_name)

    heldf = held.astype(jnp.float32)
    sim = 0.5 * (dots.astype(jnp.float32) / d_norm + 1.0) \
        * heldf[None, :] * heldf[:, None]
    weights = cross_weights_ref(sim, held, eps=eps, kappa=kappa,
                                cross_task=cross_task,
                                uniform_cross=uniform_cross)
    total_w = jnp.sum(weights, axis=1, keepdims=True)
    norm_w = weights / jnp.maximum(total_w, 1e-12)
    has = (total_w > 0).astype(jnp.float32)

    c1 = (1.0 / (1.0 + has))
    c2 = (has / (1.0 + has))

    # ---- pass 2: Eq. 6 + 7 per chunk, downlink re-unify while hot --------
    # m̂ is re-derived from the byte-wide agreement numerator with the
    # same fp32 division pass 1 used — bit-identical, 4x less traffic.
    # Invalid slots gather the appended all-zero sentinel row (ids ==
    # n_tasks), which zeroes them exactly as the bool path's validity
    # multiplies did — no per-element vf masking anywhere in the block.
    def pass2(c, carry):
        tv_buf, uni_buf, dmask_buf, num_p, den_p = carry
        off = c * chunk
        tau = jax.lax.dynamic_slice_in_dim(tau_hats, off, chunk, axis=1)
        anum = jax.lax.dynamic_slice_in_dim(anum_buf, off, chunk, axis=1)
        alpha = anum.astype(jnp.float32) / n_t_max[:, None]
        m_hat = jnp.where(alpha >= rho, 1.0, alpha)
        tv = c1 * tau + c2 * (m_hat * (norm_w @ tau))
        num_p = jax.lax.dynamic_update_slice_in_dim(
            num_p, _block_partials(jnp.abs(tv)), c * blkc, axis=1)
        tv_ext = jnp.concatenate([tv, jnp.zeros((1, chunk), jnp.float32)], 0)
        # one (N, K, dc) gather feeds the σ election and the per-slot
        # sweep (the sweep slices it — no re-gather per slot).  Sign
        # agreement is decided by sign algebra, not fp products —
        # aligned ⟺ x·σ > 0 exactly, and relu(x·σ) = |x| on aligned
        # coords exactly (σ = ±1) — so per-slot work stays in L2-sized
        # (N, dc) tiles.  x·τ_n > 0 ⟺ aligned ∧ μ > 0 (exact up to
        # fp32 underflow of the x·τ product, where the algebraic sign
        # is used); on the mask |τ_n| = |σ|·μ = μ exactly, so the λ
        # denominator sums μ directly.
        x = jnp.take(tv_ext, ids, axis=0).reshape(n, k, chunk)
        sigma = jnp.sign(jnp.sum(x, axis=1))                   # (N, dc)
        posm = sigma > 0
        negm = sigma < 0
        als = []
        mu = jnp.zeros((n, chunk), jnp.float32)
        for kk in range(k):
            x_k = x[:, kk, :]                                  # (N, dc)
            al_k = ((x_k > 0) & posm) | ((x_k < 0) & negm)
            mu = jnp.maximum(mu, jnp.where(al_k, jnp.abs(x_k), 0.0))
            als.append(al_k)
        tau_n = sigma * mu
        mupos = mu[:, None, :] > 0
        dmask = jnp.stack(als, axis=1) & mupos     # zero slots: never set
        den_c = _block_partials(jnp.where(dmask, mu[:, None, :], 0.0))
        tv_buf = jax.lax.dynamic_update_slice_in_dim(tv_buf, tv, off, axis=1)
        # fp32 carry (see fused_unify_packed_ref): the bf16 wire
        # rounding happens in one streaming cast after the loop
        uni_buf = jax.lax.dynamic_update_slice_in_dim(uni_buf, tau_n, off,
                                                      axis=1)
        dmask_buf = jax.lax.dynamic_update_slice_in_dim(
            dmask_buf, bitpack.pack_bits(dmask), c * dwc, axis=2)
        den_p = jax.lax.dynamic_update_slice_in_dim(den_p, den_c, c * blkc,
                                                    axis=2)
        return tv_buf, uni_buf, dmask_buf, num_p, den_p

    tv_buf, uni_buf, dmask_buf, num_p, den_p = jax.lax.fori_loop(
        0, dp // chunk, pass2,
        (jnp.zeros((n_tasks, dp), jnp.float32),
         jnp.zeros((n, dp), jnp.float32),
         jnp.zeros((n, k, dwp), jnp.uint32),
         jnp.zeros((n_tasks, n_blk), jnp.float32),
         jnp.zeros((n, k, n_blk), jnp.float32)))
    # λ totals on the shard-invariant block grid (one psum when sharded)
    num_t, den = _lam_totals((num_p, den_p), axis_name, axis_sizes)
    num = jnp.concatenate([num_t, jnp.zeros((1,),
                                            jnp.float32)])[ids].reshape(n, k)

    dw = bitpack.packed_width(d)
    return (tv_buf[:, :d], tau_hats[:, :d], anum_buf[:, :d], n_t, sim,
            uni_buf[:, :d].astype(jnp.bfloat16), dmask_buf[:, :, :dw],
            num, den)


def cross_weights_ref(sim: jax.Array, held: jax.Array, *, eps: float,
                      kappa: int, cross_task: bool,
                      uniform_cross: bool) -> jax.Array:
    """Eq. 6 neighbourhood weights from the held-masked similarity —
    the shared (T, T)-sized logic of every round path (server, dense
    reference, chunked slot round)."""
    heldf = held.astype(sim.dtype)
    if not cross_task:
        return jnp.zeros_like(sim)
    if uniform_cross:
        t = sim.shape[0]
        w = (1.0 - jnp.eye(t, dtype=sim.dtype)) * heldf[None, :] * heldf[:, None]
        return w / jnp.maximum(jnp.sum(w, 1, keepdims=True), 1.0)
    return topk_weights_ref(sim, eps, kappa)


def matu_round_slots_ref(unified: jax.Array, slot_masks: jax.Array,
                         slot_lams: jax.Array, slot_sizes: jax.Array,
                         slot_valid: jax.Array, slot_tasks: jax.Array,
                         n_tasks: int, *, rho: float, eps: float, kappa: int,
                         cross_task: bool = True, uniform_cross: bool = False,
                         chunk: int = CHUNK_D,
                         axis_name=None, axis_sizes=(), d_norm: int = 0):
    """The full MaTU server round (Eq. 3–7 + downlink re-unification)
    over slot-packed uploads, streamed in two cache-blocked passes.

    Layout: unified (N, d); slot_masks (N, K, d) bool; slot_lams /
    slot_sizes / slot_valid (N, K); slot_tasks (N, K) int32 with the
    sentinel ``n_tasks`` in invalid slots.  Work is O(Σ_n k_n · d) —
    the same asymptotics as the legacy ragged loop, NOT the dense
    O(N·T·d) — because per-task reductions are segment-sums over slot
    rows rather than masked sums over all clients.

    Pass 1 streams each d-chunk once: Eq. 3 agreement + Eq. 4 merge via
    segment-sum into a cache-resident (T+1, dc) accumulator (sentinel
    bucket swallows invalid slots), Eq. 5 sign-dot accumulated on the
    fly.  The (T, T) weight logic runs between passes.  Pass 2 streams
    chunks again: Eq. 6 mix + Eq. 7 combine, then gathers each chunk's
    fresh task vectors straight into the fused downlink re-unification
    while they are still cache-hot.

    Returns (task_vectors, tau_hats, m_hats, similarity, down_unified,
    down_masks, down_num, down_den).  τ̃ is not materialised on the hot
    path — consumers can derive it as (2τ − τ̂) on rows with donors.

    ``axis_name`` / ``axis_sizes`` / ``d_norm``: per-shard execution
    under ``shard_map`` — see :func:`matu_round_slots_packed_ref` (here
    the Eq. 5 dots are integer-valued fp32, still exact under any psum
    order for d < 2²⁴).
    """
    n, k, d = slot_masks.shape
    m_rows = n * k
    chunk, dp = _chunked(d, chunk)
    n_blk, blkc = dp // LAMBDA_BLOCK, chunk // LAMBDA_BLOCK
    n_seg = n_tasks + 1
    d_norm = d_norm or d

    ids = slot_tasks.reshape(m_rows)
    vf = slot_valid.reshape(m_rows).astype(jnp.float32)
    sizes = slot_sizes.reshape(m_rows).astype(jnp.float32) * vf
    totals = jax.ops.segment_sum(sizes, ids, num_segments=n_seg)
    gam = sizes / jnp.maximum(totals[ids], 1e-12)
    glv = gam * slot_lams.reshape(m_rows).astype(jnp.float32) * vf
    n_t = jax.ops.segment_sum(vf, ids, num_segments=n_seg)[:n_tasks]
    held = n_t > 0

    u_p = unified.astype(jnp.float32)
    m_p = slot_masks
    if dp != d:                      # aligned d never pays the pad copies
        u_p = jnp.pad(u_p, ((0, 0), (0, dp - d)))
        m_p = jnp.pad(m_p, ((0, 0), (0, 0), (0, dp - d)))

    glv_nk = glv.reshape(n, k)

    # ---- pass 1: Eq. 3 + 4 per chunk, Eq. 5 dots accumulated -------------
    # sgn(m ⊙ τ_n) is factored as m ⊙ sgn(τ_n) (m binary), so the sign
    # is taken once per client row, not once per slot.
    def pass1(c, carry):
        tau_buf, mhat_buf, dots = carry
        off = c * chunk
        uc = jax.lax.dynamic_slice_in_dim(u_p, off, chunk, axis=1)
        mc = jax.lax.dynamic_slice_in_dim(m_p, off, chunk, axis=2)
        signs = jnp.where(mc, jnp.sign(uc)[:, None, :], 0.0)
        a_num = jax.ops.segment_sum(signs.reshape(m_rows, chunk), ids,
                                    num_segments=n_seg)[:n_tasks]
        recon = jnp.where(mc, (glv_nk[:, :, None] * uc[:, None, :]), 0.0)
        tau_pre = jax.ops.segment_sum(recon.reshape(m_rows, chunk), ids,
                                      num_segments=n_seg)[:n_tasks]
        alpha = jnp.abs(a_num) / jnp.maximum(n_t, 1.0)[:, None]
        m_hat = jnp.where(alpha >= rho, 1.0, alpha)
        tau = tau_pre * m_hat
        s = jnp.sign(tau)
        dots = dots + s @ s.T
        tau_buf = jax.lax.dynamic_update_slice_in_dim(tau_buf, tau, off, axis=1)
        mhat_buf = jax.lax.dynamic_update_slice_in_dim(mhat_buf, m_hat, off,
                                                       axis=1)
        return tau_buf, mhat_buf, dots

    tau_hats, m_hats, dots = jax.lax.fori_loop(
        0, dp // chunk, pass1,
        (jnp.zeros((n_tasks, dp), jnp.float32),
         jnp.zeros((n_tasks, dp), jnp.float32),
         jnp.zeros((n_tasks, n_tasks), jnp.float32)))

    if axis_name is not None:
        dots = lax.psum(dots, axis_name)     # integer-valued: exact

    heldf = held.astype(jnp.float32)
    sim = 0.5 * (dots / d_norm + 1.0) * heldf[None, :] * heldf[:, None]
    weights = cross_weights_ref(sim, held, eps=eps, kappa=kappa,
                                cross_task=cross_task,
                                uniform_cross=uniform_cross)
    total_w = jnp.sum(weights, axis=1, keepdims=True)
    norm_w = weights / jnp.maximum(total_w, 1e-12)
    has = (total_w > 0).astype(jnp.float32)

    ids_c = jnp.minimum(ids, n_tasks - 1)       # clamp sentinel for gather
    vf_nk = vf.reshape(n, k)
    # Eq. 7 as two precomputed row scales: τ = c1·τ̂ + c2·(m̂ ⊙ mixed)
    c1 = (1.0 / (1.0 + has))
    c2 = (has / (1.0 + has))

    # ---- pass 2: Eq. 6 + 7 per chunk, downlink re-unify while hot --------
    # The λ numerator Σ|τ^t| is shared by every client holding task t,
    # so it is accumulated once per task ((T, dc) work) and gathered per
    # slot after the loop — not recomputed per (client, slot).
    def pass2(c, carry):
        tv_buf, uni_buf, dmask_buf, num_p, den_p = carry
        off = c * chunk
        tau = jax.lax.dynamic_slice_in_dim(tau_hats, off, chunk, axis=1)
        m_hat = jax.lax.dynamic_slice_in_dim(m_hats, off, chunk, axis=1)
        tv = c1 * tau + c2 * (m_hat * (norm_w @ tau))
        num_p = jax.lax.dynamic_update_slice_in_dim(
            num_p, _block_partials(jnp.abs(tv)), c * blkc, axis=1)
        x = jnp.take(tv, ids_c, axis=0).reshape(n, k, chunk)
        xm = x * vf_nk[:, :, None]
        sigma = jnp.sign(jnp.sum(xm, axis=1))                  # (N, dc)
        # aligned max via relu(xm·σ): σ ∈ {-1,0,1} ⇒ relu(xm·σ) equals
        # |xm| exactly on sign-aligned entries and 0 elsewhere
        mu = jnp.max(jax.nn.relu(xm * sigma[:, None, :]), axis=1)
        tau_n = sigma * mu
        dmask = (x * tau_n[:, None, :] > 0) & (vf_nk[:, :, None] > 0)
        den_c = _block_partials(
            jnp.where(dmask, jnp.abs(tau_n)[:, None, :], 0.0))
        tv_buf = jax.lax.dynamic_update_slice_in_dim(tv_buf, tv, off, axis=1)
        uni_buf = jax.lax.dynamic_update_slice_in_dim(uni_buf, tau_n, off,
                                                      axis=1)
        dmask_buf = jax.lax.dynamic_update_slice_in_dim(dmask_buf, dmask, off,
                                                        axis=2)
        den_p = jax.lax.dynamic_update_slice_in_dim(den_p, den_c, c * blkc,
                                                    axis=2)
        return tv_buf, uni_buf, dmask_buf, num_p, den_p

    tv_buf, uni_buf, dmask_buf, num_p, den_p = jax.lax.fori_loop(
        0, dp // chunk, pass2,
        (jnp.zeros((n_tasks, dp), jnp.float32),
         jnp.zeros((n, dp), jnp.float32),
         jnp.zeros((n, k, dp), bool),
         jnp.zeros((n_tasks, n_blk), jnp.float32),
         jnp.zeros((n, k, n_blk), jnp.float32)))
    num_t, den = _lam_totals((num_p, den_p), axis_name, axis_sizes)
    num = num_t[ids_c].reshape(n, k) * vf_nk

    return (tv_buf[:, :d], tau_hats[:, :d], m_hats[:, :d],
            sim, uni_buf[:, :d], dmask_buf[:, :, :d], num, den)


def topk_weights_ref(sim: jax.Array, eps: float, kappa: int) -> jax.Array:
    """Eq. 6 neighbourhood Z^t as a (T, T) weight matrix (mirror of
    ``repro.core.aggregation.topk_similar``)."""
    t = sim.shape[0]
    offdiag = sim * (1.0 - jnp.eye(t, dtype=sim.dtype))
    eligible = jnp.where(offdiag > eps, offdiag, 0.0)
    k = min(kappa, t - 1) if t > 1 else 0
    if k == 0:
        return jnp.zeros_like(sim)
    vals, _ = jax.lax.top_k(eligible, k)
    thresh = vals[:, -1:]
    keep = (eligible >= thresh) & (eligible > 0)
    return jnp.where(keep, eligible, 0.0)


def cross_task_combine_ref(tau_hats: jax.Array, m_hats: jax.Array,
                           sim_weights: jax.Array):
    """Eq. 6 + Eq. 7 (mirror of ``cross_task_aggregate`` +
    ``combine_round``): normalised cross-task mix, then the overview's
    averaging.  Returns (task_vectors (T, d), tau_tildes (T, d))."""
    total = jnp.sum(sim_weights, axis=1, keepdims=True)
    norm_w = sim_weights / jnp.maximum(total, 1e-12)
    tau_tildes = m_hats * jnp.einsum("ts,sd->td", norm_w, tau_hats)
    has = (total > 0).astype(tau_hats.dtype)
    task_vectors = (tau_hats + tau_tildes * has) / (1.0 + has)
    return task_vectors, tau_tildes


# ---------------------------------------------------------------------------
# Chunked-slot hierarchical aggregation: the client-axis streaming round.
#
# The monolithic rounds above materialise every slot tensor for the
# whole round — O(N·K·d) — which caps the client axis.  The four
# functions per layout below split the identical math into per-chunk
# folds over carried accumulators so a round's memory is O(chunk + T·d)
# regardless of N:
#
#   phase A  ``matu_chunk_scalars_ref``    per chunk: fold sizes / valid
#            counts into (T+1,) totals (the Eq. 4 γ normaliser needs
#            global per-task size totals before any merge work).
#   phase B  ``matu_merge_chunk[_packed]_ref``  per chunk: fold the
#            Eq. 3 sign votes and Eq. 4 merge partials into carried
#            (T+1, dp) accumulators.
#   finish   ``matu_finish[_packed]_ref``  once: Eq. 3 α/m̂, Eq. 5 sign
#            dots, Eq. 6 weights, Eq. 7 combine and the λ numerator
#            from the accumulators — no slot tensors involved.
#   phase C  ``matu_downlink_chunk[_packed]_ref``  per chunk: downlink
#            re-unification of one client chunk from the finished task
#            vectors (each slot row lives in exactly one chunk, so this
#            phase is embarrassingly parallel over rows).
#
# Chunk-count invariance (the bit-identity contract): every fp32
# client-axis reduction is ONE global sequential scatter fold —
# ``acc.at[ids].add(x_chunk)`` carried across chunks applies the same
# adds in the same global row order as the monolithic round's
# whole-round ``segment_sum`` (XLA applies scatter updates in row
# order on CPU), so the accumulated totals are bitwise equal for ANY
# contiguous chunking, including a jitted fixed-shape chunk step with
# sentinel-padded tail rows (padding rows carry the sentinel task id,
# so their zeros land in the swallowed (T+1)-th bucket, never in a task
# row).  The Eq. 3 votes and Eq. 5 dots are exact integers (order
# free), and every d-axis reduction (λ block partials, CHUNK_D
# streaming grid) keeps the monolithic grid — identical op shapes,
# identical lowering, bitwise-identical results.  Under ``shard_map``
# the merge fold never splits the client axis across devices (each
# shard folds every row of its d-slice locally — no collectives);
# the finish crosses shards exactly like the monolithic round (integer
# dots psum + the shard-invariant ``_lam_totals`` tree) and phase C
# adds one λ-denominator psum per chunk.
# ---------------------------------------------------------------------------


def matu_chunk_scalars_ref(slot_sizes: jax.Array, slot_valid: jax.Array,
                           slot_tasks: jax.Array, totals_acc: jax.Array,
                           nt_acc: jax.Array):
    """Phase-A chunk step: fold one chunk's data sizes and validity
    counts into the carried (T+1,) fp32 accumulators.

    slot_sizes/slot_valid/slot_tasks (C, K); ``totals_acc`` accumulates
    Σ size·valid per task (the γ normaliser), ``nt_acc`` the Eq. 3
    membership count N_t.  Returns the updated (totals_acc, nt_acc).
    """
    m_rows = slot_sizes.shape[0] * slot_sizes.shape[1]
    ids = slot_tasks.reshape(m_rows)
    vf = slot_valid.reshape(m_rows).astype(jnp.float32)
    sizes = slot_sizes.reshape(m_rows).astype(jnp.float32) * vf
    return totals_acc.at[ids].add(sizes), nt_acc.at[ids].add(vf)


def matu_merge_chunk_packed_ref(unified: jax.Array, slot_mask_words: jax.Array,
                                slot_lams: jax.Array, slot_sizes: jax.Array,
                                slot_valid: jax.Array, slot_tasks: jax.Array,
                                totals: jax.Array, a_acc: jax.Array,
                                tau_acc: jax.Array, *, d: int,
                                chunk: int = CHUNK_D):
    """Phase-B chunk step, wire layout: fold one client chunk's Eq. 3
    sign votes (int32, exact) and Eq. 4 merge partials (fp32, global
    row order) into the carried (T+1, dp) accumulators.

    ``totals`` is the phase-A global size total (T+1,) — the γ weights
    need it before any merge work, which is why the chunked round makes
    two passes over the upload stream.  ``a_acc`` (T+1, dp) int32 and
    ``tau_acc`` (T+1, dp) fp32 are carried across chunks; the d-axis
    streaming grid is the monolithic round's (``_chunked``).  Under
    ``shard_map`` every d-axis tensor is the local slice and ``d`` the
    local count — the fold has no collectives.
    """
    n, k, dw_in = slot_mask_words.shape
    m_rows = n * k
    chunk, dp = _chunked(d, chunk)
    dwc, dwp = chunk // 32, dp // 32

    ids = slot_tasks.reshape(m_rows)
    vf = slot_valid.reshape(m_rows).astype(jnp.float32)
    sizes = slot_sizes.reshape(m_rows).astype(jnp.float32) * vf
    gam = sizes / jnp.maximum(totals[ids], 1e-12)
    glv = gam * slot_lams.reshape(m_rows).astype(jnp.float32) * vf
    glv_nk = glv.reshape(n, k)

    u_p = unified                       # stays bf16; upcast per chunk
    m_w = slot_mask_words
    if dp != d:
        u_p = jnp.pad(u_p, ((0, 0), (0, dp - d)))
    if dwp != dw_in:
        m_w = jnp.pad(m_w, ((0, 0), (0, 0), (0, dwp - dw_in)))

    def fold(c, carry):
        a_acc, tau_acc = carry
        off = c * chunk
        uc = lax.dynamic_slice_in_dim(u_p, off, chunk,
                                      axis=1).astype(jnp.float32)
        mw = lax.dynamic_slice_in_dim(m_w, c * dwc, dwc, axis=2)
        mi8 = bitpack.unpack_bits(mw, chunk, jnp.int8)         # (C, K, dc)
        signs = (mi8 * jnp.sign(uc).astype(jnp.int8)[:, None, :])
        a_blk = lax.dynamic_slice_in_dim(a_acc, off, chunk, axis=1)
        a_blk = a_blk.at[ids].add(
            signs.reshape(m_rows, chunk).astype(jnp.int32))
        a_acc = lax.dynamic_update_slice_in_dim(a_acc, a_blk, off, axis=1)
        recon = mi8.astype(jnp.float32) * (glv_nk[:, :, None]
                                           * uc[:, None, :])
        t_blk = lax.dynamic_slice_in_dim(tau_acc, off, chunk, axis=1)
        t_blk = t_blk.at[ids].add(recon.reshape(m_rows, chunk))
        tau_acc = lax.dynamic_update_slice_in_dim(tau_acc, t_blk, off, axis=1)
        return a_acc, tau_acc

    return lax.fori_loop(0, dp // chunk, fold, (a_acc, tau_acc))


def matu_merge_chunk_ref(unified: jax.Array, slot_masks: jax.Array,
                         slot_lams: jax.Array, slot_sizes: jax.Array,
                         slot_valid: jax.Array, slot_tasks: jax.Array,
                         totals: jax.Array, a_acc: jax.Array,
                         tau_acc: jax.Array, *, chunk: int = CHUNK_D):
    """Phase-B chunk step, bool/fp32 layout twin of
    :func:`matu_merge_chunk_packed_ref` (here both accumulators are
    fp32 — the sign votes are small exact integers in fp32, matching
    the monolithic bool round's accumulation dtype)."""
    n, k, d = slot_masks.shape
    m_rows = n * k
    chunk, dp = _chunked(d, chunk)

    ids = slot_tasks.reshape(m_rows)
    vf = slot_valid.reshape(m_rows).astype(jnp.float32)
    sizes = slot_sizes.reshape(m_rows).astype(jnp.float32) * vf
    gam = sizes / jnp.maximum(totals[ids], 1e-12)
    glv = gam * slot_lams.reshape(m_rows).astype(jnp.float32) * vf
    glv_nk = glv.reshape(n, k)

    u_p = unified.astype(jnp.float32)
    m_p = slot_masks
    if dp != d:
        u_p = jnp.pad(u_p, ((0, 0), (0, dp - d)))
        m_p = jnp.pad(m_p, ((0, 0), (0, 0), (0, dp - d)))

    def fold(c, carry):
        a_acc, tau_acc = carry
        off = c * chunk
        uc = lax.dynamic_slice_in_dim(u_p, off, chunk, axis=1)
        mc = lax.dynamic_slice_in_dim(m_p, off, chunk, axis=2)
        signs = jnp.where(mc, jnp.sign(uc)[:, None, :], 0.0)
        a_blk = lax.dynamic_slice_in_dim(a_acc, off, chunk, axis=1)
        a_blk = a_blk.at[ids].add(signs.reshape(m_rows, chunk))
        a_acc = lax.dynamic_update_slice_in_dim(a_acc, a_blk, off, axis=1)
        recon = jnp.where(mc, (glv_nk[:, :, None] * uc[:, None, :]), 0.0)
        t_blk = lax.dynamic_slice_in_dim(tau_acc, off, chunk, axis=1)
        t_blk = t_blk.at[ids].add(recon.reshape(m_rows, chunk))
        tau_acc = lax.dynamic_update_slice_in_dim(tau_acc, t_blk, off, axis=1)
        return a_acc, tau_acc

    return lax.fori_loop(0, dp // chunk, fold, (a_acc, tau_acc))


def matu_finish_packed_ref(a_acc: jax.Array, tau_acc: jax.Array,
                           nt_acc: jax.Array, n_clients: int, *, n_tasks: int,
                           d: int, rho: float, eps: float, kappa: int,
                           cross_task: bool = True,
                           uniform_cross: bool = False,
                           chunk: int = CHUNK_D,
                           axis_name=None, axis_sizes=(), d_norm: int = 0):
    """Finish the chunked packed round from the accumulated partials:
    Eq. 3 α/m̂ from the integer vote accumulator (same fp32 division as
    the monolithic round), Eq. 5 popcount dots, Eq. 6 weights, Eq. 7
    combine, and the λ numerator totals on the shard-invariant block
    grid.  ``n_clients`` is the whole round's client count — it picks
    the same ``alpha_dtype`` the monolithic round would.

    Returns (task_vectors (T, d), tau_hats (T, d), alpha_num (T, d),
    n_t (T,), similarity (T, T), num_t (T,) λ numerator totals).
    """
    chunk, dp = _chunked(d, chunk)
    n_blk, blkc = dp // LAMBDA_BLOCK, chunk // LAMBDA_BLOCK
    a_dt = alpha_dtype(n_clients)
    d_norm = d_norm or d
    n_t = nt_acc[:n_tasks]
    held = n_t > 0
    n_t_max = jnp.maximum(n_t, 1.0)

    def pass1(c, carry):
        tau_buf, anum_buf, dots = carry
        off = c * chunk
        a_num = lax.dynamic_slice_in_dim(
            a_acc, off, chunk, axis=1)[:n_tasks].astype(jnp.float32)
        tau_pre = lax.dynamic_slice_in_dim(tau_acc, off, chunk,
                                           axis=1)[:n_tasks]
        a_abs = jnp.abs(a_num)
        alpha = a_abs / n_t_max[:, None]
        m_hat = jnp.where(alpha >= rho, 1.0, alpha)
        tau = tau_pre * m_hat
        pos_t, nz_t = bitpack.sign_planes(tau)
        dots = dots + bitpack.packed_sign_dots(pos_t, nz_t)
        tau_buf = jax.lax.dynamic_update_slice_in_dim(tau_buf, tau, off,
                                                      axis=1)
        anum_buf = jax.lax.dynamic_update_slice_in_dim(
            anum_buf, a_abs.astype(a_dt), off, axis=1)
        return tau_buf, anum_buf, dots

    tau_hats, anum_buf, dots = jax.lax.fori_loop(
        0, dp // chunk, pass1,
        (jnp.zeros((n_tasks, dp), jnp.float32),
         jnp.zeros((n_tasks, dp), a_dt),
         jnp.zeros((n_tasks, n_tasks), jnp.int32)))

    if axis_name is not None:
        dots = lax.psum(dots, axis_name)

    heldf = held.astype(jnp.float32)
    sim = 0.5 * (dots.astype(jnp.float32) / d_norm + 1.0) \
        * heldf[None, :] * heldf[:, None]
    weights = cross_weights_ref(sim, held, eps=eps, kappa=kappa,
                                cross_task=cross_task,
                                uniform_cross=uniform_cross)
    total_w = jnp.sum(weights, axis=1, keepdims=True)
    norm_w = weights / jnp.maximum(total_w, 1e-12)
    has = (total_w > 0).astype(jnp.float32)
    c1 = (1.0 / (1.0 + has))
    c2 = (has / (1.0 + has))

    def pass2(c, carry):
        tv_buf, num_p = carry
        off = c * chunk
        tau = jax.lax.dynamic_slice_in_dim(tau_hats, off, chunk, axis=1)
        anum = jax.lax.dynamic_slice_in_dim(anum_buf, off, chunk, axis=1)
        alpha = anum.astype(jnp.float32) / n_t_max[:, None]
        m_hat = jnp.where(alpha >= rho, 1.0, alpha)
        tv = c1 * tau + c2 * (m_hat * (norm_w @ tau))
        num_p = jax.lax.dynamic_update_slice_in_dim(
            num_p, _block_partials(jnp.abs(tv)), c * blkc, axis=1)
        tv_buf = jax.lax.dynamic_update_slice_in_dim(tv_buf, tv, off, axis=1)
        return tv_buf, num_p

    tv_buf, num_p = jax.lax.fori_loop(
        0, dp // chunk, pass2,
        (jnp.zeros((n_tasks, dp), jnp.float32),
         jnp.zeros((n_tasks, n_blk), jnp.float32)))
    num_t, = _lam_totals((num_p,), axis_name, axis_sizes)
    return (tv_buf[:, :d], tau_hats[:, :d], anum_buf[:, :d], n_t, sim, num_t)


def matu_finish_ref(a_acc: jax.Array, tau_acc: jax.Array, nt_acc: jax.Array,
                    *, n_tasks: int, d: int, rho: float, eps: float,
                    kappa: int, cross_task: bool = True,
                    uniform_cross: bool = False, chunk: int = CHUNK_D,
                    axis_name=None, axis_sizes=(), d_norm: int = 0):
    """Bool/fp32-layout finish of the chunked round — same structure as
    :func:`matu_finish_packed_ref` but m̂ is buffered dense and the
    Eq. 5 dots use the fp32 sign matmul, matching the monolithic bool
    round op for op.  Returns (task_vectors, tau_hats, m_hats (T, d),
    n_t, similarity, num_t)."""
    chunk, dp = _chunked(d, chunk)
    n_blk, blkc = dp // LAMBDA_BLOCK, chunk // LAMBDA_BLOCK
    d_norm = d_norm or d
    n_t = nt_acc[:n_tasks]
    held = n_t > 0

    def pass1(c, carry):
        tau_buf, mhat_buf, dots = carry
        off = c * chunk
        a_num = lax.dynamic_slice_in_dim(a_acc, off, chunk, axis=1)[:n_tasks]
        tau_pre = lax.dynamic_slice_in_dim(tau_acc, off, chunk,
                                           axis=1)[:n_tasks]
        alpha = jnp.abs(a_num) / jnp.maximum(n_t, 1.0)[:, None]
        m_hat = jnp.where(alpha >= rho, 1.0, alpha)
        tau = tau_pre * m_hat
        s = jnp.sign(tau)
        dots = dots + s @ s.T
        tau_buf = jax.lax.dynamic_update_slice_in_dim(tau_buf, tau, off,
                                                      axis=1)
        mhat_buf = jax.lax.dynamic_update_slice_in_dim(mhat_buf, m_hat, off,
                                                       axis=1)
        return tau_buf, mhat_buf, dots

    tau_hats, m_hats, dots = jax.lax.fori_loop(
        0, dp // chunk, pass1,
        (jnp.zeros((n_tasks, dp), jnp.float32),
         jnp.zeros((n_tasks, dp), jnp.float32),
         jnp.zeros((n_tasks, n_tasks), jnp.float32)))

    if axis_name is not None:
        dots = lax.psum(dots, axis_name)     # integer-valued: exact

    heldf = held.astype(jnp.float32)
    sim = 0.5 * (dots / d_norm + 1.0) * heldf[None, :] * heldf[:, None]
    weights = cross_weights_ref(sim, held, eps=eps, kappa=kappa,
                                cross_task=cross_task,
                                uniform_cross=uniform_cross)
    total_w = jnp.sum(weights, axis=1, keepdims=True)
    norm_w = weights / jnp.maximum(total_w, 1e-12)
    has = (total_w > 0).astype(jnp.float32)
    c1 = (1.0 / (1.0 + has))
    c2 = (has / (1.0 + has))

    def pass2(c, carry):
        tv_buf, num_p = carry
        off = c * chunk
        tau = jax.lax.dynamic_slice_in_dim(tau_hats, off, chunk, axis=1)
        m_hat = jax.lax.dynamic_slice_in_dim(m_hats, off, chunk, axis=1)
        tv = c1 * tau + c2 * (m_hat * (norm_w @ tau))
        num_p = jax.lax.dynamic_update_slice_in_dim(
            num_p, _block_partials(jnp.abs(tv)), c * blkc, axis=1)
        tv_buf = jax.lax.dynamic_update_slice_in_dim(tv_buf, tv, off, axis=1)
        return tv_buf, num_p

    tv_buf, num_p = jax.lax.fori_loop(
        0, dp // chunk, pass2,
        (jnp.zeros((n_tasks, dp), jnp.float32),
         jnp.zeros((n_tasks, n_blk), jnp.float32)))
    num_t, = _lam_totals((num_p,), axis_name, axis_sizes)
    return (tv_buf[:, :d], tau_hats[:, :d], m_hats[:, :d], n_t, sim, num_t)


def matu_downlink_chunk_packed_ref(task_vectors: jax.Array,
                                   slot_tasks: jax.Array, num_t: jax.Array,
                                   *, d: int, chunk: int = CHUNK_D,
                                   axis_name=None, axis_sizes=()):
    """Phase-C chunk step, wire layout: downlink re-unification of one
    client chunk from the finished task vectors — the monolithic packed
    pass 2's per-slot sweep, restricted to this chunk's rows (each slot
    row lives in exactly one chunk, so per-row results are trivially
    chunk-invariant; the λ denominator rides the same shard-invariant
    block tree, one psum per chunk when sharded).  Invalid slots gather
    the appended all-zero sentinel row exactly as the monolithic round
    does.  Returns (down_unified (C, d) bf16, down_mask_words
    (C, K, ceil(d/32)), down_num (C, K), down_den (C, K))."""
    n, k = slot_tasks.shape
    m_rows = n * k
    chunk, dp = _chunked(d, chunk)
    dwc, dwp = chunk // 32, dp // 32
    n_blk, blkc = dp // LAMBDA_BLOCK, chunk // LAMBDA_BLOCK
    ids = slot_tasks.reshape(m_rows)
    tv_p = task_vectors
    if dp != d:
        tv_p = jnp.pad(tv_p, ((0, 0), (0, dp - d)))

    def step(c, carry):
        uni_buf, dmask_buf, den_p = carry
        off = c * chunk
        tv = lax.dynamic_slice_in_dim(tv_p, off, chunk, axis=1)
        tv_ext = jnp.concatenate([tv, jnp.zeros((1, chunk), jnp.float32)], 0)
        x = jnp.take(tv_ext, ids, axis=0).reshape(n, k, chunk)
        sigma = jnp.sign(jnp.sum(x, axis=1))                   # (C, dc)
        posm = sigma > 0
        negm = sigma < 0
        als = []
        mu = jnp.zeros((n, chunk), jnp.float32)
        for kk in range(k):
            x_k = x[:, kk, :]                                  # (C, dc)
            al_k = ((x_k > 0) & posm) | ((x_k < 0) & negm)
            mu = jnp.maximum(mu, jnp.where(al_k, jnp.abs(x_k), 0.0))
            als.append(al_k)
        tau_n = sigma * mu
        mupos = mu[:, None, :] > 0
        dmask = jnp.stack(als, axis=1) & mupos     # zero slots: never set
        den_c = _block_partials(jnp.where(dmask, mu[:, None, :], 0.0))
        uni_buf = jax.lax.dynamic_update_slice_in_dim(uni_buf, tau_n, off,
                                                      axis=1)
        dmask_buf = jax.lax.dynamic_update_slice_in_dim(
            dmask_buf, bitpack.pack_bits(dmask), c * dwc, axis=2)
        den_p = jax.lax.dynamic_update_slice_in_dim(den_p, den_c, c * blkc,
                                                    axis=2)
        return uni_buf, dmask_buf, den_p

    uni_buf, dmask_buf, den_p = jax.lax.fori_loop(
        0, dp // chunk, step,
        (jnp.zeros((n, dp), jnp.float32),
         jnp.zeros((n, k, dwp), jnp.uint32),
         jnp.zeros((n, k, n_blk), jnp.float32)))
    den, = _lam_totals((den_p,), axis_name, axis_sizes)
    num = jnp.concatenate([num_t, jnp.zeros((1,),
                                            jnp.float32)])[ids].reshape(n, k)
    dw = bitpack.packed_width(d)
    return (uni_buf[:, :d].astype(jnp.bfloat16), dmask_buf[:, :, :dw],
            num, den)


def matu_downlink_chunk_ref(task_vectors: jax.Array, slot_valid: jax.Array,
                            slot_tasks: jax.Array, num_t: jax.Array, *,
                            n_tasks: int, chunk: int = CHUNK_D,
                            axis_name=None, axis_sizes=()):
    """Phase-C chunk step, bool/fp32 layout twin of
    :func:`matu_downlink_chunk_packed_ref` (sentinel ids clamped for
    the gather, validity handled by explicit vf multiplies — the
    monolithic bool pass 2's conventions).  Returns (down_unified
    (C, d) fp32, down_masks (C, K, d) bool, down_num, down_den)."""
    n, k = slot_tasks.shape
    m_rows = n * k
    d = task_vectors.shape[-1]
    chunk, dp = _chunked(d, chunk)
    n_blk, blkc = dp // LAMBDA_BLOCK, chunk // LAMBDA_BLOCK
    ids = slot_tasks.reshape(m_rows)
    ids_c = jnp.minimum(ids, n_tasks - 1)       # clamp sentinel for gather
    vf_nk = slot_valid.reshape(m_rows).astype(jnp.float32).reshape(n, k)
    tv_p = task_vectors
    if dp != d:
        tv_p = jnp.pad(tv_p, ((0, 0), (0, dp - d)))

    def step(c, carry):
        uni_buf, dmask_buf, den_p = carry
        off = c * chunk
        tv = lax.dynamic_slice_in_dim(tv_p, off, chunk, axis=1)
        x = jnp.take(tv, ids_c, axis=0).reshape(n, k, chunk)
        xm = x * vf_nk[:, :, None]
        sigma = jnp.sign(jnp.sum(xm, axis=1))                  # (C, dc)
        mu = jnp.max(jax.nn.relu(xm * sigma[:, None, :]), axis=1)
        tau_n = sigma * mu
        dmask = (x * tau_n[:, None, :] > 0) & (vf_nk[:, :, None] > 0)
        den_c = _block_partials(
            jnp.where(dmask, jnp.abs(tau_n)[:, None, :], 0.0))
        uni_buf = jax.lax.dynamic_update_slice_in_dim(uni_buf, tau_n, off,
                                                      axis=1)
        dmask_buf = jax.lax.dynamic_update_slice_in_dim(dmask_buf, dmask, off,
                                                        axis=2)
        den_p = jax.lax.dynamic_update_slice_in_dim(den_p, den_c, c * blkc,
                                                    axis=2)
        return uni_buf, dmask_buf, den_p

    uni_buf, dmask_buf, den_p = jax.lax.fori_loop(
        0, dp // chunk, step,
        (jnp.zeros((n, dp), jnp.float32),
         jnp.zeros((n, k, dp), bool),
         jnp.zeros((n, k, n_blk), jnp.float32)))
    den, = _lam_totals((den_p,), axis_name, axis_sizes)
    num = num_t[ids_c].reshape(n, k) * vf_nk
    return (uni_buf[:, :d], dmask_buf[:, :, :d], num, den)


# ---------------------------------------------------------------------------
# Serving: modulated LoRA matmul (reference semantics of the fused
# repro.kernels.modulated_matmul Pallas kernel).
# ---------------------------------------------------------------------------


def modulated_matmul_ref(x: jax.Array, base: jax.Array, tau: jax.Array,
                         words: jax.Array, lam: jax.Array) -> jax.Array:
    """Per-request modulated LoRA matmul, the unpack-then-matmul oracle.

    x (B, ..., K); base/tau (K, N) fp32 (the base adapter leaf and the
    unified-vector slice reshaped to the leaf); words (B, W) uint32
    bit-packed modulator bits of the leaf, row-major over (K, N); lam
    (B,) fp32 per-request scalers.  Returns (B, ..., N):

        y_b = x_b @ (base + lam_b * m_b * tau)

    The effective weight is materialised per request here (the extra
    HBM pass the fused kernel removes); elementwise order matches
    ``tree_add(lora0, unflatten(modulate(...)))`` exactly —
    ``(lam * bits) * tau`` is bitwise ``lam * where(m, tau, 0)`` for
    bits in {0, 1} — so serving paths built from either are
    bit-identical.
    """
    b = x.shape[0]
    k, n = base.shape
    bits = bitpack.unpack_bits(words, k * n, jnp.float32).reshape(b, k, n)
    w_eff = base[None] + lam[:, None, None] * bits * tau[None]
    return jnp.einsum("b...k,bkn->b...n", x.astype(jnp.float32), w_eff)
