"""Pallas TPU kernel: sign-conflict task similarity (Eq. 5) as an MXU matmul.

The jnp form is an elementwise sign + (T, d) @ (d, T) in fp32.  At
full-fine-tune scale d ~ 10⁸ and T ~ 30, so the op is a skinny
memory-bound matmul.  The kernel tiles d, signs each (T, BD) tile in
VMEM, and accumulates the (T, T) partial product across the grid —
the sign tile never round-trips to HBM (the XLA version materialises
the full sgn(T) matrix first: 2× traffic).

Grid iterates over d; the (T, T) output block is revisited every step
(accumulation pattern: zero on first step, add afterwards).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 2048
BLOCK_W = 512           # uint32 words per grid step of the packed kernel


def _sign_sim_kernel(x_ref, acc_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)          # (T, BD)
    s = jnp.sign(x)
    acc_ref[...] += jnp.dot(s, s.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def sign_sim_pallas(tau_hats: jax.Array, *, block_d: int = BLOCK_D,
                    interpret: bool = True) -> jax.Array:
    """(T, d) -> (T, T) similarity in [0, 1]. Zero-padding d is safe:
    sgn(0)·sgn(0) = 0 contributes nothing."""
    t, d = tau_hats.shape
    pad = (-d) % block_d
    if pad:
        tau_hats = jnp.pad(tau_hats, ((0, 0), (0, pad)))
    dp = d + pad
    dots = pl.pallas_call(
        _sign_sim_kernel,
        grid=(dp // block_d,),
        in_specs=[pl.BlockSpec((t, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((t, t), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, t), jnp.float32),
        interpret=interpret,
    )(tau_hats)
    return 0.5 * (dots / d + 1.0)


def _sign_sim_packed_kernel(pos_ref, nz_ref, acc_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # the popcount identity lives in ONE place (bitpack) — the kernel
    # tile is exactly the (T, BW) shape the helper operates on
    from repro.kernels import bitpack
    dots = bitpack.packed_sign_dots(pos_ref[...], nz_ref[...])
    acc_ref[...] += dots.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def sign_sim_packed_pallas(pos: jax.Array, nz: jax.Array, *,
                           block_w: int = BLOCK_W,
                           interpret: bool = True) -> jax.Array:
    """Eq. 5 sign dots from packed sign bit-planes (the wire-format
    form of :func:`sign_sim_pallas`): ``pos``/``nz`` are (T, w) uint32
    planes with bit j set iff τ̂_j > 0 / τ̂_j ≠ 0 (see
    ``repro.kernels.bitpack.sign_planes``).

    Per word the dot contribution is pure popcount algebra —
    popcnt(both) − 2·popcnt(both & (pos ⊕ pos')) — an exact integer
    identical to the fp32 sgn·sgnᵀ matmul, at 1/32 the element count.
    Zero padding of the planes contributes nothing.  Returns the raw
    (T, T) dots in fp32; the caller normalises by the *unpacked* d:
    S = ½(dots/d + 1).
    """
    t, w = pos.shape
    pad = (-w) % block_w
    if pad:
        pos = jnp.pad(pos, ((0, 0), (0, pad)))
        nz = jnp.pad(nz, ((0, 0), (0, pad)))
    wp = w + pad
    return pl.pallas_call(
        _sign_sim_packed_kernel,
        grid=(wp // block_w,),
        in_specs=[pl.BlockSpec((t, block_w), lambda i: (0, i)),
                  pl.BlockSpec((t, block_w), lambda i: (0, i))],
        out_specs=pl.BlockSpec((t, t), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((t, t), jnp.float32),
        interpret=interpret,
    )(pos, nz)
