"""Pallas TPU kernel: task unification (Eq. 2).

The server re-unifies per-client task vectors every round; at
full-fine-tune scale d is the model size, so this is a pure
memory-bound streaming op.  The jnp reference reads the (K, d) stack
~5× (sum, sign, abs, compare, max); this kernel streams each (K, BD)
block through VMEM once and fuses sign-election + aligned max-|.| into
a single pass — the arithmetic intensity is fixed, the win is HBM
traffic.

Blocking: grid over d in BD=2048 lanes (16 × 128, aligned to the VPU
8×128 vregs); K rides along entirely in VMEM (K ≤ 64 in practice:
VMEM use = K·BD·4B ≤ 512 KiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 2048


def _unify_kernel(tv_ref, out_ref):
    x = tv_ref[...].astype(jnp.float32)          # (K, BD)
    total = jnp.sum(x, axis=0)
    sigma = jnp.sign(total)
    aligned = (x * sigma[None, :]) > 0.0
    mu = jnp.max(jnp.where(aligned, jnp.abs(x), 0.0), axis=0)
    out_ref[...] = (sigma * mu).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def unify_pallas(task_vectors: jax.Array, *, block_d: int = BLOCK_D,
                 interpret: bool = True) -> jax.Array:
    """(K, d) -> (d,). Pads d to a lane multiple internally."""
    k, d = task_vectors.shape
    pad = (-d) % block_d
    if pad:
        task_vectors = jnp.pad(task_vectors, ((0, 0), (0, pad)))
    dp = d + pad
    out = pl.pallas_call(
        _unify_kernel,
        grid=(dp // block_d,),
        in_specs=[pl.BlockSpec((k, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=interpret,
    )(task_vectors)
    return out[:d]
