import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes, without allocating any real buffers.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results/]

For each combo this:
  1. builds the model at full config (bf16),
  2. eval_shape's params / LoRA / optimizer state / caches,
  3. maps every tensor's logical axes to NamedShardings on the mesh,
  4. jit-lowers the step (train: loss+LoRA-grads+AdamW; prefill; decode),
  5. compiles, and records memory_analysis / cost_analysis / per-kind
     collective bytes parsed from the compiled HLO into a JSON artifact
     consumed by benchmarks/bench_roofline.py (§Roofline).

NOTE: the XLA_FLAGS line above MUST run before any other import — jax
locks the device count on first init.  (The first import of jax happens
transitively below.)
"""

import argparse
import json
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ARCH_IDS, SHAPES, input_specs, load_arch
from repro.launch.mesh import arch_rules, make_production_mesh
from repro.nn.sharding import logical_to_sharding, mesh_context
from repro.optim import adamw
from repro.train.trainer import make_train_step

PyTree = Any


# ---------------------------------------------------------------------------
# collective-byte accounting (parsed from compiled HLO)
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"%?(\w[\w.\-]*)\s*=\s*((?:[a-z0-9_]+\[[^\]]*\](?:\{[^}]*\})?|\([^)]*\)))"
    r"\s*%?(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across jax versions.

    Older jax returns one dict; newer jax returns a list with one entry
    per module (possibly empty).  Always hand callers a plain dict so
    ``cost.get("flops")`` works everywhere."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op, per kind.

    Shapes in the compiled module are per-device (post-SPMD), so the
    returned numbers are bytes per device per step."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        sig, kind = m.group(2), m.group(3)
        if "-start" in line and "-done" in line:
            continue
        out[kind] = out.get(kind, 0) + _shape_bytes(sig)
    return out


# ---------------------------------------------------------------------------
# sharding assembly
# ---------------------------------------------------------------------------

def batch_shardings(batch_struct, mesh):
    def one(s):
        if s.shape and s.shape[0] > 1:
            spec_axes = ("batch",) + (None,) * (len(s.shape) - 1)
        else:
            spec_axes = (None,) * len(s.shape)
        from repro.nn.sharding import resolve_spec
        return NamedSharding(mesh, resolve_spec(spec_axes, s.shape, mesh=mesh))
    return jax.tree_util.tree_map(one, batch_struct)


def opt_state_shardings(opt_state_struct, lora_sh, mesh):
    """mu/nu mirror the LoRA tree; scalars replicated."""
    def one(path, s):
        return NamedSharding(mesh, P()) if s.ndim == 0 else None
    # structure: {"step": scalar, "mu": lora-tree, "nu": lora-tree}
    return {
        "step": NamedSharding(mesh, P()),
        "mu": lora_sh,
        "nu": lora_sh,
    }


# ---------------------------------------------------------------------------
# per-combo dry run
# ---------------------------------------------------------------------------

def run_combo(arch: str, shape_name: str, mesh, *, verbose: bool = True,
              seq_override: Optional[int] = None,
              batch_override: Optional[int] = None) -> Dict[str, Any]:
    cfg = load_arch(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.supports_long:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "full-attention enc-dec; see DESIGN.md §4"}

    rules = arch_rules(cfg, mesh)
    t0 = time.time()
    with mesh_context(mesh, rules):
        model = cfg.build(shape)
        params_struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        lora_struct = jax.eval_shape(lambda: model.lora_init(jax.random.PRNGKey(1)))
        params_sh = logical_to_sharding(model.axes(), params_struct, mesh=mesh, rules=None)
        lora_sh = logical_to_sharding(model.lora_axes(), lora_struct, mesh=mesh, rules=None)
        batch_struct = input_specs(cfg, shape, batch_override=batch_override,
                                   seq_override=seq_override)
        batch_sh = batch_shardings(batch_struct, mesh)

        if shape.kind == "train":
            train_step, opt = make_train_step(model, adamw(1e-4))
            opt_struct = jax.eval_shape(opt.init, lora_struct)
            opt_sh = opt_state_shardings(opt_struct, lora_sh, mesh)
            fn = jax.jit(train_step,
                         in_shardings=(params_sh, lora_sh, opt_sh, batch_sh),
                         donate_argnums=(1, 2))
            args = (params_struct, lora_struct, opt_struct, batch_struct)
        else:
            b = batch_override or shape.global_batch
            s = seq_override or shape.seq_len
            cache_struct = jax.eval_shape(
                lambda: model.init_cache(b, s))
            cache_sh = logical_to_sharding(model.cache_axes(), cache_struct,
                                           mesh=mesh, rules=None)
            if shape.kind == "prefill":
                def prefill_step(params, lora, batch, cache):
                    return model.prefill_step(params, lora, batch, cache)
                fn = jax.jit(prefill_step,
                             in_shardings=(params_sh, lora_sh, batch_sh, cache_sh),
                             donate_argnums=(3,))
                args = (params_struct, lora_struct, batch_struct, cache_struct)
            else:
                def decode_step(params, lora, batch, cache, pos):
                    return model.decode_fn(params, lora, batch, cache, pos)
                pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
                fn = jax.jit(decode_step,
                             in_shardings=(params_sh, lora_sh, batch_sh, cache_sh,
                                           NamedSharding(mesh, P())),
                             donate_argnums=(3,))
                args = (params_struct, lora_struct, batch_struct, cache_struct,
                        pos_struct)

        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        coll = collective_bytes(compiled.as_text())

    # memory_analysis numbers are PER DEVICE (verified empirically);
    # cost_analysis flops/bytes are whole-program sums.
    n_dev = mesh.size
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": dict(mesh.shape),
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_per_device": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "temp_size_in_bytes", 0) or 0)
                          + (getattr(mem, "argument_size_in_bytes", 0) or 0),
        },
        "cost": {
            "flops": cost.get("flops") if cost else None,
            "bytes_accessed": cost.get("bytes accessed") if cost else None,
        },
        "collective_bytes_per_device": coll,
        "devices": n_dev,
    }
    if verbose:
        mb = result["memory_per_device"]
        print(f"[{arch} × {shape_name} × {tuple(mesh.shape.values())}] "
              f"compile={t_compile:.0f}s  "
              f"args/dev={(mb['argument_bytes'] or 0)/2**30:.2f}GiB  "
              f"temp/dev={(mb['temp_bytes'] or 0)/2**30:.2f}GiB  "
              f"peak/dev={(mb['peak_bytes'] or 0)/2**30:.2f}GiB  "
              f"flops={result['cost']['flops'] or 0:.3e}  "
              f"coll={ {k: f'{v/2**20:.0f}MiB' for k, v in coll.items()} }")
    return result


def run_matu_round(mesh, *, n_clients: int = 30, n_tasks: int = 30,
                   d: int = 1 << 27, verbose: bool = True):
    """Lower the paper's server aggregation (Eq. 3-6, matu_round) on the
    production mesh: the d dimension shards over ALL mesh axes
    ('taskvec' rule); Eq. 5's sign-dot reduction over d becomes the only
    cross-shard collective.  d defaults to 2^27 (a 7B-class LoRA space /
    a 134M-param full-fine-tune task vector)."""
    from repro.core.aggregation import matu_round
    from repro.nn.sharding import mesh_context, resolve_spec

    t0 = time.time()
    with mesh_context(mesh):
        dv = NamedSharding(mesh, resolve_spec(("taskvec",), (d,), mesh=mesh))
        ndv = NamedSharding(mesh, resolve_spec((None, "taskvec"), (n_clients, d), mesh=mesh))
        ntdv = NamedSharding(mesh, resolve_spec((None, None, "taskvec"),
                                                (n_clients, n_tasks, d), mesh=mesh))
        rep = NamedSharding(mesh, P())
        unified = jax.ShapeDtypeStruct((n_clients, d), jnp.float32)
        masks = jax.ShapeDtypeStruct((n_clients, n_tasks, d), jnp.bool_)
        lams = jax.ShapeDtypeStruct((n_clients, n_tasks), jnp.float32)
        alloc = jax.ShapeDtypeStruct((n_clients, n_tasks), jnp.bool_)
        sizes = jax.ShapeDtypeStruct((n_clients, n_tasks), jnp.float32)

        fn = jax.jit(lambda u, m, l, a, s: matu_round(u, m, l, a, s).task_vectors,
                     in_shardings=(ndv, ntdv, rep, rep, rep))
        with mesh:
            lowered = fn.lower(unified, masks, lams, alloc, sizes)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        coll = collective_bytes(compiled.as_text())
    res = {
        "arch": "matu-round", "shape": f"N{n_clients}_T{n_tasks}_d{d}",
        "mesh": dict(mesh.shape), "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "memory_per_device": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.temp_size_in_bytes + mem.argument_size_in_bytes,
        },
        "cost": {"flops": cost.get("flops"), "bytes_accessed": cost.get("bytes accessed")},
        "collective_bytes_per_device": coll,
        "devices": mesh.size,
    }
    if verbose:
        m = res["memory_per_device"]
        print(f"[matu-round N={n_clients} T={n_tasks} d=2^{d.bit_length()-1} x {tuple(mesh.shape.values())}] "
              f"args/dev={m['argument_bytes']/2**30:.2f}GiB temp/dev={m['temp_bytes']/2**30:.2f}GiB "
              f"flops={res['cost']['flops'] or 0:.3e} coll={{{', '.join(f'{k}:{v/2**20:.0f}MiB' for k,v in coll.items())}}}")
    return res


def run_round_engine(mesh, *, n_clients: int = 32, n_tasks: int = 30,
                     d: int = 1 << 27, k_max: int = 4,
                     verbose: bool = True):
    """Lower + compile the taskvec-sharded round ENGINE (shard_map over
    ``ops.matu_round_slots_packed``) on the production mesh with no real
    buffers: ShapeDtypeStructs carry the d-axis NamedShardings the
    engine's pack path would install.  Reports the per-shard slot-buffer
    bytes (the wire tensors each chip actually holds) next to the
    compiled memory/cost/collective numbers the model dry-runs emit —
    the d axis shards over every mesh axis, so the only collectives are
    the two all-reduces of the sharding contract (the (T, T) similarity
    dots + the λ block-tree roots)."""
    from repro.core.engine import (EngineConfig, RoundEngine,
                                   _round_up_pow2, pad_d_for_shards)
    from repro.kernels import bitpack
    from repro.nn.sharding import taskvec_sharding

    t0 = time.time()
    eng = RoundEngine(EngineConfig(n_tasks=n_tasks), mesh=mesh)
    n_max = _round_up_pow2(n_clients)
    k_pad = _round_up_pow2(k_max)
    d_pad = pad_d_for_shards(d, eng.n_shards)
    dw = bitpack.packed_width(d_pad)
    rep = NamedSharding(mesh, P())
    args = (
        jax.ShapeDtypeStruct((n_max, d_pad), jnp.bfloat16,
                             sharding=taskvec_sharding(mesh, 2)),
        jax.ShapeDtypeStruct((n_max, k_pad, dw), jnp.uint32,
                             sharding=taskvec_sharding(mesh, 3)),
        jax.ShapeDtypeStruct((n_max, k_pad), jnp.float32, sharding=rep),
        jax.ShapeDtypeStruct((n_max, k_pad), jnp.float32, sharding=rep),
        jax.ShapeDtypeStruct((n_max, k_pad), jnp.bool_, sharding=rep),
        jax.ShapeDtypeStruct((n_max, k_pad), jnp.int32, sharding=rep),
    )
    with mesh:
        lowered = eng._impl("ref", d).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())

    # the wire slot buffers each shard holds (uplink; the downlink
    # mirrors them) — d-axis tensors split n_shards ways, per-slot
    # scalars replicated
    sharded = 2 * n_max * d_pad + 4 * n_max * k_pad * dw
    replicated = (4 + 4 + 1 + 4) * n_max * k_pad
    per_shard = sharded // eng.n_shards + replicated
    res = {
        "arch": "matu-round-engine",
        "shape": f"N{n_clients}_T{n_tasks}_d{d}_k{k_max}",
        "mesh": dict(mesh.shape), "status": "ok",
        "taskvec_shards": eng.n_shards,
        "d_pad": d_pad,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "slot_buffer_bytes_per_shard": per_shard,
        "slot_buffer_bytes_total": sharded + replicated * eng.n_shards,
        "memory_per_device": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.temp_size_in_bytes + mem.argument_size_in_bytes,
        },
        "cost": {"flops": cost.get("flops") if cost else None,
                 "bytes_accessed": cost.get("bytes accessed") if cost else None},
        "collective_bytes_per_device": coll,
        "devices": mesh.size,
    }
    if verbose:
        m = res["memory_per_device"]
        print(f"[matu-round-engine N={n_clients} T={n_tasks} "
              f"d=2^{d.bit_length()-1} x {tuple(mesh.shape.values())}] "
              f"shards={eng.n_shards} "
              f"slot-buf/shard={per_shard/2**20:.1f}MiB "
              f"args/dev={m['argument_bytes']/2**20:.1f}MiB "
              f"temp/dev={m['temp_bytes']/2**20:.1f}MiB "
              f"coll={{{', '.join(f'{k}:{v/2**10:.1f}KiB' for k, v in coll.items())}}}")
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--matu-round", action="store_true",
                    help="lower the MaTU server aggregation itself")
    ap.add_argument("--engine-round", action="store_true",
                    help="lower the taskvec-sharded round ENGINE "
                         "(shard_map + wire-format slot tensors)")
    ap.add_argument("--matu-d", type=int, default=1 << 27)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    tag = "multipod" if args.multi_pod else "singlepod"
    os.makedirs(args.out, exist_ok=True)

    if args.matu_round:
        r = run_matu_round(mesh, d=args.matu_d)
        with open(os.path.join(args.out, f"matu_round__{tag}.json"), "w") as f:
            json.dump(r, f, indent=2)
        return

    if args.engine_round:
        r = run_round_engine(mesh, d=args.matu_d)
        with open(os.path.join(args.out, f"engine_round__{tag}.json"),
                  "w") as f:
            json.dump(r, f, indent=2)
        return

    combos = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                combos.append((arch, shape))
    else:
        combos.append((args.arch, args.shape))

    results = []
    for arch, shape in combos:
        try:
            r = run_combo(arch, shape, mesh, seq_override=args.seq,
                          batch_override=args.batch)
        except Exception as e:  # noqa: BLE001 — record the failure
            r = {"arch": arch, "shape": shape, "status": "error",
                 "error": f"{type(e).__name__}: {e}"}
            print(f"[{arch} × {shape}] FAILED: {r['error'][:300]}")
        results.append(r)
        path = os.path.join(args.out, f"{arch}__{shape}__{tag}.json")
        with open(path, "w") as f:
            json.dump(r, f, indent=2)

    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skipped")
    err = sum(1 for r in results if r["status"] == "error")
    print(f"\n== dry-run [{tag}]: {ok} ok, {sk} skipped, {err} failed "
          f"of {len(results)} ==")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
