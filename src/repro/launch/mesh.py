"""Production mesh definitions (TPU v5e pods).

Single-pod: 256 chips as (16, 16) → ("data", "model").
Multi-pod:  2 × 256 chips as (2, 16, 16) → ("pod", "data", "model").

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state — the dry-run
sets XLA_FLAGS for 512 host devices before any jax import; smoke tests
and benches see the single real CPU device.
"""

from __future__ import annotations

from typing import Mapping, Optional

import jax

# TPU v5e hardware constants (per chip) — used by the roofline report.
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link


def _make_mesh(shape, axes, devices=None):
    """jax.make_mesh across jax versions: newer jaxes take axis_types
    (pass Auto so GSPMD stays in charge); 0.4.x has neither the kwarg
    nor the enum and defaults to the same behaviour."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale sharding tests (8 host devices)."""
    return _make_mesh(shape, axes)


def make_round_mesh(n_devices: Optional[int] = None):
    """1-D ("data",) mesh over the first ``n_devices`` local devices for
    the taskvec-sharded round engine (benches / single-host serving).
    The "taskvec" rule maps onto ("pod", "data", "model"), so on this
    mesh the d axis splits ``n_devices`` ways; on the production pod
    meshes the same rule spans all 256/512 chips."""
    devs = jax.devices()
    n = n_devices or len(devs)
    if n > len(devs):
        raise ValueError(f"make_round_mesh: {n} devices requested, "
                         f"{len(devs)} available")
    return _make_mesh((n,), ("data",), devices=devs[:n])


def make_population_mesh(slots: int = 2, n_devices: Optional[int] = None):
    """2-D ("slots", "data") mesh for the chunked population round: the
    "slots" axis shards a chunk's client/slot rows (ingest + phase-C
    downlink re-unification, see the engine's population-scale
    contract) and "data" carries the taskvec d-sharding — composing
    into the ROADMAP's (slots × taskvec) layout.  ``slots`` must divide
    the device count; power-of-two counts keep the chunk row padding
    aligned."""
    devs = jax.devices()
    n = n_devices or len(devs)
    if n > len(devs):
        raise ValueError(f"make_population_mesh: {n} devices requested, "
                         f"{len(devs)} available")
    if slots < 1 or n % slots != 0:
        raise ValueError(f"make_population_mesh: slots={slots} must divide "
                         f"the device count {n}")
    return _make_mesh((slots, n // slots), ("slots", "data"),
                      devices=devs[:n])


def arch_rules(cfg, mesh) -> Mapping[str, object]:
    """Per-arch logical-axis rule overrides (DESIGN.md §5).

    kv_heads shard over ``model`` only when the head count divides the
    axis (codeqwen MHA); otherwise KV stays replicated (standard GQA
    tensor parallelism).
    """
    n_model = mesh.shape.get("model", 1)
    rules = {}
    if cfg.n_kv_heads and cfg.n_kv_heads % n_model == 0 and not cfg.use_mla:
        rules["kv_heads"] = "model"
    return rules
