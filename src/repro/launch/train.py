"""Training launcher.

Two modes:

* ``fed`` (default) — the paper's pipeline: many-task federated LoRA
  fine-tuning with a selectable aggregation strategy on the synthetic
  constellation, with checkpointing and the communication ledger.

    PYTHONPATH=src python -m repro.launch.train fed --strategy matu \
        --tasks 8 --clients 16 --rounds 40

* ``lm`` — supervised LoRA fine-tuning steps of one assigned
  architecture (reduced variant on CPU; the full configs are exercised
  by the dry-run / on real TPU metal by the same code path).

    PYTHONPATH=src python -m repro.launch.train lm --arch qwen2-0.5b --steps 50
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def run_fed(args) -> None:
    from repro.ckpt.checkpoint import save
    from repro.data.dirichlet import dirichlet_split
    from repro.data.synthetic import make_constellation
    from repro.fed.simulator import FedConfig, FedSimulator, individual_baseline
    from repro.fed.strategies import STRATEGIES
    from repro.fed.testbed import MLPBackbone, ViTBackbone

    con = make_constellation(n_tasks=args.tasks, n_groups=3, feat_dim=32,
                             n_classes=8, conflict_pairs=[(0, 1)],
                             seed=args.seed)
    split = dirichlet_split(n_clients=args.clients, n_tasks=args.tasks,
                            n_classes=8, zeta_t=args.zeta_t,
                            tasks_per_client=args.tasks_per_client or None,
                            seed=args.seed)
    bb = (ViTBackbone(seed=args.seed) if args.backbone == "vit"
          else MLPBackbone(32, hidden=64, lora_rank=8, seed=args.seed))
    cfg = FedConfig(rounds=args.rounds, local_steps=args.local_steps,
                    lr=args.lr, participation=args.participation,
                    eval_every=max(args.rounds // 4, 1), seed=args.seed)

    cls = STRATEGIES[args.strategy]
    kw = {"split_point": bb.split_point} if args.strategy == "fedper" else {}
    strat = cls(args.tasks, bb.d, **kw)
    sim = FedSimulator(cfg, con, split, bb, strat)
    hist = sim.run(verbose=True)

    print(f"\nfinal mean acc: {hist.final_mean_acc:.3f}  "
          f"uplink/round: {hist.mean_uplink_bits/8/2**20:.2f} MiB")
    if args.compare_individual:
        ind = individual_baseline(cfg, con, bb)
        print(f"individual upper bound: {np.mean(list(ind.values())):.3f}")
    if args.ckpt and strat.name == "matu":
        save(args.ckpt, {"task_vectors": strat.server.last_task_vectors},
             {"rounds": args.rounds, "strategy": strat.name})
        print(f"saved server task vectors -> {args.ckpt}.npz")


def run_lm(args) -> None:
    from repro.configs.base import SHAPES, input_specs, load_arch
    from repro.optim import adamw, linear_warmup_cosine
    from repro.train.trainer import make_train_step

    cfg = load_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = cfg.build(SHAPES["train_4k"])
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    lora = model.lora_init(jax.random.PRNGKey(args.seed + 1))
    step, opt = make_train_step(
        model, adamw(linear_warmup_cosine(args.lr, 10, args.steps)))
    state = opt.init(lora)
    step = jax.jit(step)

    rng = jax.random.PRNGKey(7)
    for i in range(args.steps):
        rng, k = jax.random.split(rng)
        batch = input_specs(cfg, SHAPES["train_4k"], concrete=True,
                            batch_override=args.batch, seq_override=args.seq)
        batch["tokens"] = jax.random.randint(k, batch["tokens"].shape, 0, cfg.vocab)
        batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
        t0 = time.perf_counter()
        lora, state, m = step(params, lora, state, batch)
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            jax.block_until_ready(m["loss"])
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"{(time.perf_counter()-t0)*1e3:.0f} ms")


def main() -> None:
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode")

    f = sub.add_parser("fed")
    f.add_argument("--strategy", default="matu")
    f.add_argument("--tasks", type=int, default=8)
    f.add_argument("--clients", type=int, default=16)
    f.add_argument("--rounds", type=int, default=40)
    f.add_argument("--local-steps", type=int, default=30)
    f.add_argument("--lr", type=float, default=1e-2)
    f.add_argument("--zeta-t", type=float, default=0.0)
    f.add_argument("--tasks-per-client", type=int, default=0)
    f.add_argument("--participation", type=float, default=1.0)
    f.add_argument("--backbone", choices=["mlp", "vit"], default="mlp")
    f.add_argument("--compare-individual", action="store_true")
    f.add_argument("--ckpt", default="")
    f.add_argument("--seed", type=int, default=0)

    l = sub.add_parser("lm")
    l.add_argument("--arch", default="qwen2-0.5b")
    l.add_argument("--steps", type=int, default=50)
    l.add_argument("--batch", type=int, default=4)
    l.add_argument("--seq", type=int, default=64)
    l.add_argument("--lr", type=float, default=5e-3)
    l.add_argument("--reduced", action="store_true", default=True)
    l.add_argument("--seed", type=int, default=0)

    args = ap.parse_args()
    if args.mode == "lm":
        run_lm(args)
    else:
        run_fed(args)


if __name__ == "__main__":
    main()
