"""Composable transformer blocks with a uniform (train/prefill/decode) API.

Every block type exposes:

* ``__call__(params, x, *, positions, lora, impl) -> (x, aux)``
* ``prefill(params, x, cache, *, positions, lora, impl) -> (x, cache, aux)``
* ``decode_step(params, x, cache, pos, *, lora) -> (x, cache)``
* ``init_cache(batch, max_len, dtype)`` / ``cache_axes()``

so the LM can ``lax.scan`` over stacked per-layer parameters regardless
of the mixer family.  SSM blocks (MLSTMBlock/SLSTMBlock/Mamba) manage
their own norms/residuals; this module adapts them to the same API.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.nn.attention import Attention
from repro.nn.mla import MLAttention
from repro.nn.mlp import GeluMLP, SwiGLU
from repro.nn.module import LayerNorm, Module, RMSNorm
from repro.nn.moe import MoE
from repro.nn.ssm import Mamba, MLSTMBlock, SLSTMBlock

PyTree = Any


class Block(Module):
    """Pre-norm residual block: mixer (attention/MLA/hybrid) + optional FFN."""

    def __init__(self, d_model: int, mixer: Module, ffn: Optional[Module], *,
                 norm_cls=RMSNorm, dtype=jnp.float32):
        self.d_model, self.mixer, self.ffn = d_model, mixer, ffn
        self.norm1 = norm_cls(d_model, dtype=dtype)
        self.norm2 = norm_cls(d_model, dtype=dtype) if ffn is not None else None
        self.dtype = dtype

    def init(self, key):
        k1, k2 = jax.random.split(key)
        p = {"norm1": self.norm1.init(None), "mixer": self.mixer.init(k1)}
        if self.ffn is not None:
            p["norm2"] = self.norm2.init(None)
            p["ffn"] = self.ffn.init(k2)
        return p

    def axes(self):
        a = {"norm1": self.norm1.axes(), "mixer": self.mixer.axes()}
        if self.ffn is not None:
            a["norm2"] = self.norm2.axes()
            a["ffn"] = self.ffn.axes()
        return a

    def lora_init(self, key, rank: int):
        k1, k2 = jax.random.split(key)
        out = {"mixer": self.mixer.lora_init(k1, rank)}
        if self.ffn is not None and hasattr(self.ffn, "lora_init"):
            out["ffn"] = self.ffn.lora_init(k2, rank)
        return out

    def lora_axes(self):
        out = {"mixer": self.mixer.lora_axes()}
        if self.ffn is not None and hasattr(self.ffn, "lora_axes"):
            out["ffn"] = self.ffn.lora_axes()
        return out

    def _ffn_apply(self, params, x, lora):
        lora = lora or {}
        y = self.ffn(params["ffn"], self.norm2(params["norm2"], x), lora.get("ffn"))
        aux = getattr(self.ffn, "last_aux", jnp.zeros((), jnp.float32))
        return x + y, aux

    def __call__(self, params, x, *, positions=None, lora=None, impl="full"):
        lora = lora or {}
        h = self.mixer(params["mixer"], self.norm1(params["norm1"], x),
                       positions=positions, lora=lora.get("mixer"), impl=impl)
        x = x + h
        if self.ffn is None:
            return x, jnp.zeros((), jnp.float32)
        return self._ffn_apply(params, x, lora)

    # -- serving -----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=None):
        return self.mixer.init_cache(batch, max_len, dtype)

    def cache_axes(self):
        return self.mixer.cache_axes()

    def prefill(self, params, x, cache, *, positions=None, lora=None, impl="chunked"):
        lora = lora or {}
        h, cache = self.mixer.prefill(params["mixer"], self.norm1(params["norm1"], x), cache,
                                      positions=positions, lora=lora.get("mixer"), impl=impl)
        x = x + h
        if self.ffn is None:
            return x, cache, jnp.zeros((), jnp.float32)
        x, aux = self._ffn_apply(params, x, lora)
        return x, cache, aux

    def decode_step(self, params, x, cache, pos, *, lora=None):
        lora = lora or {}
        h, cache = self.mixer.decode_step(params["mixer"], self.norm1(params["norm1"], x),
                                          cache, pos, lora=lora.get("mixer"))
        x = x + h
        if self.ffn is not None:
            x, _ = self._ffn_apply(params, x, lora)
        return x, cache


class SSMBlockAdapter(Module):
    """Adapts MLSTMBlock / SLSTMBlock / Mamba-with-own-residual to Block API."""

    def __init__(self, inner: Module):
        self.inner = inner

    def init(self, key):
        return self.inner.init(key)

    def axes(self):
        return self.inner.axes()

    def lora_init(self, key, rank):
        return self.inner.lora_init(key, rank)

    def lora_axes(self):
        return self.inner.lora_axes()

    def init_cache(self, batch, max_len, dtype=None):
        return self.inner.init_cache(batch, max_len, dtype)

    def cache_axes(self):
        return self.inner.cache_axes()

    def __call__(self, params, x, *, positions=None, lora=None, impl="full"):
        del positions, impl
        y, _ = self.inner.forward(params, x, lora=lora)
        return y, jnp.zeros((), jnp.float32)

    def prefill(self, params, x, cache, *, positions=None, lora=None, impl="chunked"):
        del positions, impl
        y, cache = self.inner.forward(params, x, lora=lora, state=cache)
        return y, cache, jnp.zeros((), jnp.float32)

    def decode_step(self, params, x, cache, pos, *, lora=None):
        y, cache = self.inner.decode_step(params, x, cache, pos, lora=lora)
        return y, cache


class HybridMixer(Module):
    """Hymba-style parallel attention ‖ mamba heads on the same input.

    Branch outputs are individually RMS-normalised and fused with a
    learnable per-branch scale (β), then mean-combined — matching the
    hymba fusion (arXiv:2411.13676 §2)."""

    def __init__(self, d_model: int, attn: Attention, mamba: Mamba, *, dtype=jnp.float32):
        self.d_model, self.attn, self.mamba, self.dtype = d_model, attn, mamba, dtype
        self.norm_a = RMSNorm(d_model, dtype=dtype)
        self.norm_m = RMSNorm(d_model, dtype=dtype)

    def init(self, key):
        ka, km = jax.random.split(key)
        return {"attn": self.attn.init(ka), "mamba": self.mamba.init(km),
                "norm_a": self.norm_a.init(None), "norm_m": self.norm_m.init(None),
                "beta": jnp.ones((2,), self.dtype)}

    def axes(self):
        return {"attn": self.attn.axes(), "mamba": self.mamba.axes(),
                "norm_a": self.norm_a.axes(), "norm_m": self.norm_m.axes(),
                "beta": (None,)}

    def lora_init(self, key, rank):
        ka, km = jax.random.split(key)
        return {"attn": self.attn.lora_init(ka, rank), "mamba": self.mamba.lora_init(km, rank)}

    def lora_axes(self):
        return {"attn": self.attn.lora_axes(), "mamba": self.mamba.lora_axes()}

    def _fuse(self, params, ya, ym):
        ya = self.norm_a(params["norm_a"], ya)
        ym = self.norm_m(params["norm_m"], ym)
        return 0.5 * (params["beta"][0] * ya + params["beta"][1] * ym)

    def __call__(self, params, x, *, positions=None, lora=None, impl="full"):
        lora = lora or {}
        ya = self.attn(params["attn"], x, positions=positions, lora=lora.get("attn"), impl=impl)
        ym = self.mamba(params["mamba"], x, lora=lora.get("mamba"))
        return self._fuse(params, ya, ym)

    def init_cache(self, batch, max_len, dtype=None):
        return {"attn": self.attn.init_cache(batch, max_len, dtype),
                "mamba": self.mamba.init_cache(batch, max_len, dtype)}

    def cache_axes(self):
        return {"attn": self.attn.cache_axes(), "mamba": self.mamba.cache_axes()}

    def prefill(self, params, x, cache, *, positions=None, lora=None, impl="chunked"):
        lora = lora or {}
        ya, ca = self.attn.prefill(params["attn"], x, cache["attn"],
                                   positions=positions, lora=lora.get("attn"), impl=impl)
        ym, cm = self.mamba.forward(params["mamba"], x, lora=lora.get("mamba"),
                                    state=cache["mamba"])
        return self._fuse(params, ya, ym), {"attn": ca, "mamba": cm}

    def decode_step(self, params, x, cache, pos, *, lora=None):
        lora = lora or {}
        ya, ca = self.attn.decode_step(params["attn"], x, cache["attn"], pos,
                                       lora=lora.get("attn"))
        ym, cm = self.mamba.decode_step(params["mamba"], x, cache["mamba"],
                                        lora=lora.get("mamba"))
        return self._fuse(params, ya, ym), {"attn": ca, "mamba": cm}
