"""Assemble models from ArchConfig (one builder per family).

``build_model`` returns an object with the uniform interface the
launcher/trainer/tests rely on:

* ``init(key)`` / ``lora_init(key)`` / ``axes()`` / ``lora_axes()``
* ``loss(params, lora, batch)``
* ``prefill_step(params, lora, batch, cache)``
* ``decode_fn(params, lora, batch, cache, pos)``
* ``init_cache(batch, max_len)`` / ``cache_axes()``
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.blocks import Block, HybridMixer, SSMBlockAdapter
from repro.models.encdec import EncDecLM
from repro.models.lm import LM
from repro.nn.attention import Attention
from repro.nn.mla import MLAttention
from repro.nn.mlp import SwiGLU
from repro.nn.moe import MoE
from repro.nn.ssm import Mamba, MLSTMBlock, SLSTMBlock

PyTree = Any


class ArchModel:
    """Uniform facade over LM / EncDecLM for one (config, shape) pair."""

    def __init__(self, cfg: ArchConfig, model, kind: str):
        self.cfg = cfg
        self.model = model
        self.kind = kind  # "lm" | "encdec"

    # -- params ---------------------------------------------------------
    def init(self, key):
        return self.model.init(key)

    def lora_init(self, key):
        return self.model.lora_init(key, self.cfg.lora_rank)

    def axes(self):
        return self.model.axes()

    def lora_axes(self):
        return self.model.lora_axes()

    # -- steps ----------------------------------------------------------
    def loss(self, params, lora, batch):
        return self.model.loss(params, lora, batch)

    def init_cache(self, batch: int, max_len: int, dtype=None):
        return self.model.init_cache(batch, max_len, dtype)

    def cache_axes(self):
        return self.model.cache_axes()

    def prefill_step(self, params, lora, batch, cache, impl="chunked"):
        return self.model.prefill(params, lora, batch, cache, impl=impl)

    def decode_fn(self, params, lora, batch, cache, pos):
        return self.model.decode_step(params, lora, batch["tokens"], cache, pos)


def _attention(cfg: ArchConfig, *, window: Optional[int]) -> Attention:
    return Attention(
        cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
        head_dim=cfg.head_dim,
        qkv_bias=cfg.qkv_bias,
        rope=True, rope_base=cfg.rope_base,
        mrope_sections=cfg.mrope_sections,
        window=window,
        dtype=cfg.dtype,
    )


def build_model(cfg: ArchConfig, shape: Optional[ShapeSpec] = None) -> ArchModel:
    window = cfg.window_for_shape(shape) if shape is not None else None
    dt = cfg.dtype

    if cfg.family in ("dense", "vlm"):
        mixer = _attention(cfg, window=window)
        block = Block(cfg.d_model, mixer, SwiGLU(cfg.d_model, cfg.d_ff, dtype=dt), dtype=dt)
        lm = LM(vocab=cfg.vocab, d_model=cfg.d_model, n_units=cfg.n_layers,
                unit_blocks=[("blk", block)], tie_embeddings=cfg.tie_embeddings,
                mrope=cfg.mrope_sections is not None, remat=cfg.remat, dtype=dt)
        return ArchModel(cfg, lm, "lm")

    if cfg.family == "moe":
        if cfg.use_mla:
            mixer = MLAttention(
                cfg.d_model, cfg.n_heads,
                q_lora_rank=cfg.q_lora_rank, kv_lora_rank=cfg.kv_lora_rank,
                qk_nope_dim=cfg.qk_nope_dim, qk_rope_dim=cfg.qk_rope_dim,
                v_head_dim=cfg.v_head_dim, rope_base=cfg.rope_base,
                window=window, dtype=dt)
        else:
            mixer = _attention(cfg, window=window)
        ffn = MoE(cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.top_k,
                  n_shared=cfg.n_shared_experts, shared_d_ff=cfg.shared_d_ff,
                  capacity_factor=cfg.moe_capacity_factor, dtype=dt)
        block = Block(cfg.d_model, mixer, ffn, dtype=dt)
        lm = LM(vocab=cfg.vocab, d_model=cfg.d_model, n_units=cfg.n_layers,
                unit_blocks=[("blk", block)], tie_embeddings=cfg.tie_embeddings,
                remat=cfg.remat, dtype=dt)
        return ArchModel(cfg, lm, "lm")

    if cfg.family == "ssm":  # xLSTM: alternating mLSTM/sLSTM pairs
        assert cfg.n_layers % 2 == 0
        mlstm = SSMBlockAdapter(MLSTMBlock(cfg.d_model, cfg.n_heads,
                                           chunk=cfg.mlstm_chunk, dtype=dt))
        slstm = SSMBlockAdapter(SLSTMBlock(cfg.d_model, cfg.n_heads, dtype=dt))
        lm = LM(vocab=cfg.vocab, d_model=cfg.d_model, n_units=cfg.n_layers // 2,
                unit_blocks=[("mlstm", mlstm), ("slstm", slstm)],
                tie_embeddings=cfg.tie_embeddings, remat=cfg.remat, dtype=dt)
        return ArchModel(cfg, lm, "lm")

    if cfg.family == "hybrid":  # hymba: parallel attention ‖ mamba heads
        attn = _attention(cfg, window=window if window is not None else cfg.hybrid_window)
        mamba = Mamba(cfg.d_model, d_state=cfg.ssm_state, dtype=dt)
        mixer = HybridMixer(cfg.d_model, attn, mamba, dtype=dt)
        block = Block(cfg.d_model, mixer, SwiGLU(cfg.d_model, cfg.d_ff, dtype=dt), dtype=dt)
        lm = LM(vocab=cfg.vocab, d_model=cfg.d_model, n_units=cfg.n_layers,
                unit_blocks=[("blk", block)], tie_embeddings=cfg.tie_embeddings,
                remat=cfg.remat, dtype=dt)
        return ArchModel(cfg, lm, "lm")

    if cfg.family == "audio":  # whisper: enc-dec
        max_dec = max(448, shape.seq_len if shape is not None else 448)
        model = EncDecLM(vocab=cfg.vocab, d_model=cfg.d_model,
                         n_enc_layers=cfg.n_layers, n_dec_layers=cfg.n_layers,
                         n_heads=cfg.n_heads, d_ff=cfg.d_ff,
                         max_dec_len=max_dec, enc_frames=cfg.enc_frames,
                         remat=cfg.remat, dtype=dt)
        return ArchModel(cfg, model, "encdec")

    raise ValueError(f"unknown family {cfg.family!r}")
