"""Encoder-decoder LM (whisper-large-v3 backbone).

The mel-spectrogram + conv feature extractor is STUBBED per the brief:
``input_specs()`` supplies precomputed frame embeddings (B, T_enc, d) —
we implement the transformer encoder over those frames and the full
autoregressive decoder (self-attn + cross-attn), including serving with
a cross-KV cache computed once at prefill.
"""

from __future__ import annotations

import math
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

from repro.models.lm import (chunked_cross_entropy, cross_entropy,
                             grad_safe_barrier)
from repro.nn.attention import Attention
from repro.nn.mlp import GeluMLP
from repro.nn.module import Dense, Embedding, LayerNorm, Module
from repro.nn.sharding import constrain

PyTree = Any


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim))
    pe = jnp.zeros((length, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


class EncoderBlock(Module):
    def __init__(self, d_model, n_heads, d_ff, dtype=jnp.float32):
        self.attn = Attention(d_model, n_heads, n_heads, qkv_bias=True, out_bias=True,
                              rope=False, causal=False, dtype=dtype)
        self.mlp = GeluMLP(d_model, d_ff, dtype=dtype)
        self.ln1 = LayerNorm(d_model, dtype=dtype)
        self.ln2 = LayerNorm(d_model, dtype=dtype)

    def init(self, key):
        ka, km = jax.random.split(key)
        return {"ln1": self.ln1.init(None), "attn": self.attn.init(ka),
                "ln2": self.ln2.init(None), "mlp": self.mlp.init(km)}

    def axes(self):
        return {"ln1": self.ln1.axes(), "attn": self.attn.axes(),
                "ln2": self.ln2.axes(), "mlp": self.mlp.axes()}

    def lora_init(self, key, rank):
        ka, km = jax.random.split(key)
        return {"attn": self.attn.lora_init(ka, rank), "mlp": self.mlp.lora_init(km, rank)}

    def lora_axes(self):
        return {"attn": self.attn.lora_axes(), "mlp": self.mlp.lora_axes()}

    def __call__(self, params, x, *, lora=None, impl="auto"):
        lora = lora or {}
        x = x + self.attn(params["attn"], self.ln1(params["ln1"], x),
                          lora=lora.get("attn"), impl=impl)
        x = x + self.mlp(params["mlp"], self.ln2(params["ln2"], x), lora.get("mlp"))
        return x


class DecoderBlock(Module):
    def __init__(self, d_model, n_heads, d_ff, dtype=jnp.float32):
        self.self_attn = Attention(d_model, n_heads, n_heads, qkv_bias=True, out_bias=True,
                                   rope=False, causal=True, dtype=dtype)
        self.cross_attn = Attention(d_model, n_heads, n_heads, qkv_bias=True, out_bias=True,
                                    rope=False, causal=False, cross=True, dtype=dtype)
        self.mlp = GeluMLP(d_model, d_ff, dtype=dtype)
        self.ln1 = LayerNorm(d_model, dtype=dtype)
        self.ln2 = LayerNorm(d_model, dtype=dtype)
        self.ln3 = LayerNorm(d_model, dtype=dtype)

    def init(self, key):
        ks, kc, km = jax.random.split(key, 3)
        return {"ln1": self.ln1.init(None), "self_attn": self.self_attn.init(ks),
                "ln2": self.ln2.init(None), "cross_attn": self.cross_attn.init(kc),
                "ln3": self.ln3.init(None), "mlp": self.mlp.init(km)}

    def axes(self):
        return {"ln1": self.ln1.axes(), "self_attn": self.self_attn.axes(),
                "ln2": self.ln2.axes(), "cross_attn": self.cross_attn.axes(),
                "ln3": self.ln3.axes(), "mlp": self.mlp.axes()}

    def lora_init(self, key, rank):
        ks, kc, km = jax.random.split(key, 3)
        return {"self_attn": self.self_attn.lora_init(ks, rank),
                "cross_attn": self.cross_attn.lora_init(kc, rank),
                "mlp": self.mlp.lora_init(km, rank)}

    def lora_axes(self):
        return {"self_attn": self.self_attn.lora_axes(),
                "cross_attn": self.cross_attn.lora_axes(),
                "mlp": self.mlp.lora_axes()}

    def __call__(self, params, x, enc_out, *, lora=None, impl="auto"):
        lora = lora or {}
        x = x + self.self_attn(params["self_attn"], self.ln1(params["ln1"], x),
                               lora=lora.get("self_attn"), impl=impl)
        x = x + self.cross_attn(params["cross_attn"], self.ln2(params["ln2"], x),
                                kv_input=enc_out, lora=lora.get("cross_attn"))
        x = x + self.mlp(params["mlp"], self.ln3(params["ln3"], x), lora.get("mlp"))
        return x

    # -- serving ------------------------------------------------------------
    def init_cache(self, batch, max_len, dtype=None):
        return {"self": self.self_attn.init_cache(batch, max_len, dtype)}

    def cache_axes(self):
        return {"self": self.self_attn.cache_axes(),
                "cross": {"k": ("batch", None, "kv_heads", "head_dim"),
                          "v": ("batch", None, "kv_heads", "head_dim")}}

    def build_cross_cache(self, params, enc_out):
        return self.cross_attn.init_cross_cache(params["cross_attn"], enc_out)

    def prefill(self, params, x, enc_out, cache, *, lora=None, impl="chunked"):
        lora = lora or {}
        h, self_c = self.self_attn.prefill(params["self_attn"],
                                           self.ln1(params["ln1"], x), cache["self"],
                                           lora=lora.get("self_attn"), impl=impl)
        x = x + h
        x = x + self.cross_attn(params["cross_attn"], self.ln2(params["ln2"], x),
                                kv_input=enc_out, lora=lora.get("cross_attn"))
        x = x + self.mlp(params["mlp"], self.ln3(params["ln3"], x), lora.get("mlp"))
        return x, {"self": self_c}

    def decode_step(self, params, x, cache, cross_cache, pos, *, lora=None):
        lora = lora or {}
        h, self_c = self.self_attn.decode_step(params["self_attn"],
                                               self.ln1(params["ln1"], x), cache["self"],
                                               pos, lora=lora.get("self_attn"))
        x = x + h
        x = x + self.cross_attn.cross_decode_step(params["cross_attn"],
                                                  self.ln2(params["ln2"], x), cross_cache,
                                                  lora=lora.get("cross_attn"))
        x = x + self.mlp(params["mlp"], self.ln3(params["ln3"], x), lora.get("mlp"))
        return x, {"self": self_c}


class EncDecLM(Module):
    """Whisper-style encoder-decoder with scanned layer stacks."""

    def __init__(self, *, vocab: int, d_model: int, n_enc_layers: int,
                 n_dec_layers: int, n_heads: int, d_ff: int,
                 max_dec_len: int = 448, enc_frames: int = 1500,
                 remat: bool = True, dtype=jnp.float32):
        self.vocab, self.d_model = vocab, d_model
        self.n_enc, self.n_dec = n_enc_layers, n_dec_layers
        self.max_dec_len, self.enc_frames = max_dec_len, enc_frames
        self.remat = remat
        self.dtype = dtype
        self.enc_block = EncoderBlock(d_model, n_heads, d_ff, dtype=dtype)
        self.dec_block = DecoderBlock(d_model, n_heads, d_ff, dtype=dtype)
        self.embed = Embedding(vocab, d_model, dtype=dtype)
        self.enc_ln = LayerNorm(d_model, dtype=dtype)
        self.dec_ln = LayerNorm(d_model, dtype=dtype)

    def init(self, key):
        ke, kd, kt, kp = jax.random.split(key, 4)
        return {
            "encoder": self.enc_block.init_stacked(ke, self.n_enc),
            "decoder": self.dec_block.init_stacked(kd, self.n_dec),
            "embed": self.embed.init(kt),
            "pos_embed": {"table": (jax.random.normal(kp, (self.max_dec_len, self.d_model)) * 0.01).astype(self.dtype)},
            "enc_ln": self.enc_ln.init(None),
            "dec_ln": self.dec_ln.init(None),
        }

    def axes(self):
        return {
            "encoder": self.enc_block.stacked_axes(),
            "decoder": self.dec_block.stacked_axes(),
            "embed": self.embed.axes(),
            "pos_embed": {"table": (None, "embed")},
            "enc_ln": self.enc_ln.axes(),
            "dec_ln": self.dec_ln.axes(),
        }

    def lora_init(self, key, rank: int):
        ke, kd = jax.random.split(key)
        enc = jax.vmap(lambda k: self.enc_block.lora_init(k, rank))(jax.random.split(ke, self.n_enc))
        dec = jax.vmap(lambda k: self.dec_block.lora_init(k, rank))(jax.random.split(kd, self.n_dec))
        return {"encoder": enc, "decoder": dec}

    def lora_axes(self):
        def stack(ax):
            return jax.tree_util.tree_map(
                lambda a: ("layers",) + tuple(a or ()), ax,
                is_leaf=lambda x: x is None or isinstance(x, tuple))
        return {"encoder": stack(self.enc_block.lora_axes()),
                "decoder": stack(self.dec_block.lora_axes())}

    # -- encoder -------------------------------------------------------------
    def encode(self, params, audio_embeds, *, lora=None):
        x = audio_embeds.astype(self.dtype)
        x = x + sinusoidal_positions(x.shape[1], self.d_model).astype(self.dtype)[None]
        x = constrain(x, ("batch", None, "embed"))

        def body(x, xs):
            if lora is not None:
                p, l = xs
            else:
                (p,) = xs
                l = None
            return self.enc_block(p, x, lora=l), None

        if self.remat:
            body = jax.checkpoint(body)
        xs = (params["encoder"],) if lora is None else (params["encoder"], lora["encoder"])
        x, _ = jax.lax.scan(body, x, xs)
        return self.enc_ln(params["enc_ln"], x)

    def _dec_embed(self, params, tokens, offset=0):
        x = self.embed(params["embed"], tokens).astype(self.dtype)
        s = tokens.shape[1]
        pos_table = params["pos_embed"]["table"]
        pos = jax.lax.dynamic_slice_in_dim(pos_table, offset, s, 0) if isinstance(offset, int) \
            else jax.lax.dynamic_slice_in_dim(pos_table, offset, s, 0)
        return constrain(x + pos[None], ("batch", None, "embed"))

    # -- training ----------------------------------------------------------------
    def forward(self, params, tokens, audio_embeds, *, lora=None, impl="auto",
                return_hidden=False):
        enc_out = self.encode(params, audio_embeds, lora=lora)
        x = self._dec_embed(params, tokens)

        def body(x, xs):
            if lora is not None:
                p, l = xs
            else:
                (p,) = xs
                l = None
            x = grad_safe_barrier(x)
            return self.dec_block(p, x, enc_out, lora=l, impl=impl), None

        if self.remat:
            body = jax.checkpoint(body)
        xs = (params["decoder"],) if lora is None else (params["decoder"], lora["decoder"])
        x, _ = jax.lax.scan(body, x, xs)
        if return_hidden:
            return x
        x = self.dec_ln(params["dec_ln"], x)
        logits = self.embed.attend(params["embed"], x)  # tied head
        return constrain(logits, ("batch", None, "vocab"))

    def loss(self, params, lora, batch):
        hidden = self.forward(params, batch["tokens"], batch["audio_embeds"],
                              lora=lora, return_hidden=True)

        def head_fn(xc):
            xc = self.dec_ln(params["dec_ln"], xc)
            return constrain(self.embed.attend(params["embed"], xc),
                             ("batch", None, "vocab"))

        return chunked_cross_entropy(hidden, head_fn, batch["labels"])

    # -- serving -----------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=None) -> PyTree:
        dtype = dtype or self.dtype
        one = self.dec_block.self_attn.init_cache(batch, max_len, dtype)
        self_c = jax.tree_util.tree_map(
            lambda leaf: jnp.broadcast_to(leaf, (self.n_dec,) + leaf.shape).copy(), one)
        hd = self.dec_block.cross_attn.head_dim
        nk = self.dec_block.cross_attn.n_kv
        cross = {"k": jnp.zeros((self.n_dec, batch, self.enc_frames, nk, hd), dtype),
                 "v": jnp.zeros((self.n_dec, batch, self.enc_frames, nk, hd), dtype)}
        return {"self": self_c, "cross": cross}

    def cache_axes(self):
        ax = self.dec_block.cache_axes()
        stack = lambda t: jax.tree_util.tree_map(
            lambda a: ("layers",) + tuple(a or ()), t,
            is_leaf=lambda x: x is None or isinstance(x, tuple))
        return {"self": stack({"self": ax["self"]})["self"], "cross": stack(ax["cross"])}

    def prefill(self, params, lora, batch, cache, *, impl="chunked"):
        enc_out = self.encode(params, batch["audio_embeds"], lora=lora)
        x = self._dec_embed(params, batch["tokens"])

        def body(carry, xs):
            x = carry
            if lora is not None:
                p, l, c = xs
            else:
                p, c = xs
                l = None
            x, new_c = self.dec_block.prefill(p, x, enc_out, {"self": c}, lora=l, impl=impl)
            cross = self.dec_block.build_cross_cache(p, enc_out)
            return x, (new_c["self"], cross)

        xs = ((params["decoder"], cache["self"]) if lora is None
              else (params["decoder"], lora["decoder"], cache["self"]))
        x, (self_c, cross_c) = jax.lax.scan(body, x, xs)
        x = self.dec_ln(params["dec_ln"], x[:, -1:, :])
        logits = self.embed.attend(params["embed"], x)[:, 0]
        return logits, {"self": self_c, "cross": cross_c}

    def decode_step(self, params, lora, tokens, cache, pos):
        x = self._dec_embed(params, tokens, offset=pos)

        def body(carry, xs):
            x = carry
            if lora is not None:
                p, l, c, cc = xs
            else:
                p, c, cc = xs
                l = None
            x, new_c = self.dec_block.decode_step(p, x, {"self": c}, cc, pos, lora=l)
            return x, new_c["self"]

        xs = ((params["decoder"], cache["self"], cache["cross"]) if lora is None
              else (params["decoder"], lora["decoder"], cache["self"], cache["cross"]))
        x, self_c = jax.lax.scan(body, x, xs)
        x = self.dec_ln(params["dec_ln"], x)
        logits = self.embed.attend(params["embed"], x)[:, 0]
        return logits, {"self": self_c, "cross": cache["cross"]}
