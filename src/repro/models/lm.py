"""Generic decoder-only language model over scanned block units.

A "unit" is an ordered list of named blocks applied sequentially; the
model stacks ``n_units`` copies and runs them with ONE ``lax.scan``
(one traced unit → fast lowering even for 64-layer configs).
Heterogeneous per-layer patterns (xlstm's alternating mLSTM/sLSTM) are
expressed as a multi-block unit, so interleaving is preserved.

Entry points (all pure):

* ``loss(params, lora, batch)``            next-token CE (train_step body)
* ``forward(params, tokens, ...)``         full-seq logits
* ``prefill(params, lora, batch, cache)``  fills caches, last-token logits
* ``decode_step(params, lora, tokens, cache, pos)``  one token w/ cache
"""

from __future__ import annotations

from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import Dense, Embedding, Module, RMSNorm
from repro.nn.sharding import constrain

PyTree = Any


@jax.custom_jvp
def grad_safe_barrier(x: jax.Array) -> jax.Array:
    """``lax.optimization_barrier`` that survives differentiation.

    The raw primitive has no differentiation rule (NotImplementedError
    under ``jax.grad`` as of jax 0.4.37), which broke every LM train
    step that scanned over a barrier'd loop body.  The barrier is an
    identity, so the custom_jvp keeps the scheduling fence in the
    primal while tangents pass straight through — the fence exists to
    stop XLA hoisting weight-stack converts out of the scan, a concern
    the (already fp32) tangents don't share.
    """
    return jax.lax.optimization_barrier(x)


@grad_safe_barrier.defjvp
def _grad_safe_barrier_jvp(primals, tangents):
    return grad_safe_barrier(primals[0]), tangents[0]


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_index: int = -100) -> jax.Array:
    """Mean next-token CE in fp32; labels==ignore_index are masked."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = (labels != ignore_index).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_cross_entropy(x: jax.Array, head_fn, labels: jax.Array,
                          *, chunk: int = 512,
                          ignore_index: int = -100) -> jax.Array:
    """Fused head+CE over sequence chunks.

    Never materialises the full (B, S, V) logits: each scan step
    projects one (B, chunk, d) slice and reduces it to (nll_sum,
    count); the chunk body is rematerialised so the backward also
    holds only one chunk of logits.  This is the memory-dominant
    term of large-vocab LoRA training (measured 10+ fp32 copies of
    the full logits in the unfused HLO).
    """
    b, s, d = x.shape
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=ignore_index)
    xs = x.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, inp):
        nll_sum, count = carry
        xc, lc = inp
        logits = head_fn(xc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        mask = (lc != ignore_index).astype(jnp.float32)
        return (nll_sum + jnp.sum((lse - gold) * mask), count + jnp.sum(mask)), None

    (nll_sum, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (xs, ls))
    return nll_sum / jnp.maximum(count, 1.0)


class LM(Module):
    def __init__(
        self,
        *,
        vocab: int,
        d_model: int,
        n_units: int,
        unit_blocks: List[Tuple[str, Module]],
        norm_cls=RMSNorm,
        tie_embeddings: bool = False,
        mrope: bool = False,
        remat: bool = True,
        train_impl: str = "auto",
        aux_loss_coef: float = 0.01,
        dtype=jnp.float32,
    ):
        self.vocab, self.d_model, self.n_units = vocab, d_model, n_units
        self.unit_blocks = unit_blocks
        self.tie = tie_embeddings
        self.mrope = mrope
        self.remat = remat
        self.train_impl = train_impl
        self.aux_loss_coef = aux_loss_coef
        self.dtype = dtype
        self.embed = Embedding(vocab, d_model, dtype=dtype)
        self.final_norm = norm_cls(d_model, dtype=dtype)
        if not tie_embeddings:
            self.lm_head = Dense(d_model, vocab, axes=("embed", "vocab"), dtype=dtype)

    # -- params ------------------------------------------------------------
    def init(self, key):
        keys = jax.random.split(key, 2 + len(self.unit_blocks))
        units = {}
        for i, (name, blk) in enumerate(self.unit_blocks):
            units[name] = blk.init_stacked(keys[2 + i], self.n_units)
        p = {"embed": self.embed.init(keys[0]), "units": units,
             "final_norm": self.final_norm.init(None)}
        if not self.tie:
            p["lm_head"] = self.lm_head.init(keys[1])
        return p

    def axes(self):
        units = {name: blk.stacked_axes() for name, blk in self.unit_blocks}
        a = {"embed": self.embed.axes(), "units": units,
             "final_norm": self.final_norm.axes()}
        if not self.tie:
            a["lm_head"] = self.lm_head.axes()
        return a

    def lora_init(self, key, rank: int):
        keys = jax.random.split(key, len(self.unit_blocks))
        units = {}
        for i, (name, blk) in enumerate(self.unit_blocks):
            ks = jax.random.split(keys[i], self.n_units)
            units[name] = jax.vmap(lambda k, b=blk: b.lora_init(k, rank))(ks)
        return {"units": units}

    def lora_axes(self):
        def stack(ax):
            return jax.tree_util.tree_map(
                lambda a: ("layers",) + tuple(a or ()), ax,
                is_leaf=lambda x: x is None or isinstance(x, tuple))
        return {"units": {name: stack(blk.lora_axes()) for name, blk in self.unit_blocks}}

    # -- shared pieces -------------------------------------------------------
    def _embed_in(self, params, tokens, extra_embeds=None):
        x = self.embed(params["embed"], tokens).astype(self.dtype)
        if extra_embeds is not None:
            # VLM path: prepend modality embeddings (already d_model-dim)
            x = jnp.concatenate([extra_embeds.astype(self.dtype), x], axis=1)
        return constrain(x, ("batch", None, "embed"))

    def _head(self, params, x):
        x = self.final_norm(params["final_norm"], x)
        if self.tie:
            logits = self.embed.attend(params["embed"], x)
        else:
            logits = self.lm_head(params["lm_head"], x)
        return constrain(logits, ("batch", None, "vocab"))

    def _default_positions(self, b, s, offset=0):
        pos = jnp.arange(offset, offset + s)[None].repeat(b, axis=0)
        if self.mrope:
            return jnp.stack([pos, pos, pos], axis=-1)
        return pos

    def _unit_lora(self, lora):
        return None if lora is None else lora["units"]

    # -- full-sequence forward -------------------------------------------------
    def forward(self, params, tokens, *, lora=None, positions=None,
                extra_embeds=None, impl="full", return_hidden=False):
        b = tokens.shape[0]
        x = self._embed_in(params, tokens, extra_embeds)
        s = x.shape[1]
        if positions is None:
            positions = self._default_positions(b, s)
        unit_l = self._unit_lora(lora)

        def body(x, xs):
            ps = xs[0]
            ls = xs[1] if unit_l is not None else None
            # barrier: blocks XLA from hoisting bf16->f32 converts of the
            # loop-invariant weight stacks out of the scan (measured to
            # double the weight-stack footprint otherwise)
            x = grad_safe_barrier(x)
            x = constrain(x, ("batch", "act_seq", "embed"))
            aux = jnp.zeros((), jnp.float32)
            for name, blk in self.unit_blocks:
                l = None if ls is None else ls.get(name)
                x, a = blk(ps[name], x, positions=positions, lora=l, impl=impl)
                aux = aux + a
            x = constrain(x, ("batch", "act_seq", "embed"))
            return x, aux

        if self.remat:
            body = jax.checkpoint(body)
        xs = (params["units"],) if unit_l is None else (params["units"], unit_l)
        x, auxs = jax.lax.scan(body, x, xs)
        if return_hidden:
            return x, jnp.sum(auxs)
        logits = self._head(params, x)
        return logits, jnp.sum(auxs)

    def loss(self, params, lora, batch):
        hidden, aux = self.forward(
            params, batch["tokens"], lora=lora,
            positions=batch.get("positions"),
            extra_embeds=batch.get("extra_embeds"),
            impl=self.train_impl, return_hidden=True)
        labels = batch["labels"]
        if hidden.shape[1] != labels.shape[1]:  # VLM: loss only on text tail
            hidden = hidden[:, -labels.shape[1]:]

        def head_fn(xc):
            return self._head(params, xc)

        return (chunked_cross_entropy(hidden, head_fn, labels)
                + self.aux_loss_coef * aux)

    # -- serving -----------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=None) -> PyTree:
        dtype = dtype or self.dtype

        def per_unit(blk):
            one = blk.init_cache(batch, max_len, dtype)
            return jax.tree_util.tree_map(
                lambda leaf: jnp.broadcast_to(leaf, (self.n_units,) + leaf.shape).copy(), one)

        return {name: per_unit(blk) for name, blk in self.unit_blocks}

    def cache_axes(self):
        return {
            name: jax.tree_util.tree_map(
                lambda a: ("layers",) + tuple(a or ()),
                blk.cache_axes(),
                is_leaf=lambda x: x is None or isinstance(x, tuple))
            for name, blk in self.unit_blocks
        }

    def prefill(self, params, lora, batch, cache, *, impl="chunked"):
        tokens = batch["tokens"]
        b = tokens.shape[0]
        x = self._embed_in(params, tokens, batch.get("extra_embeds"))
        s = x.shape[1]
        positions = batch.get("positions")
        if positions is None:
            positions = self._default_positions(b, s)
        unit_l = self._unit_lora(lora)

        def body(x, xs):
            if unit_l is not None:
                ps, ls, cs = xs
            else:
                ps, cs = xs
                ls = None
            new_c = {}
            for name, blk in self.unit_blocks:
                l = None if ls is None else ls.get(name)
                x, c, _aux = blk.prefill(ps[name], x, cs[name],
                                         positions=positions, lora=l, impl=impl)
                new_c[name] = c
            return x, new_c

        xs = ((params["units"], cache) if unit_l is None
              else (params["units"], unit_l, cache))
        x, new_cache = jax.lax.scan(body, x, xs)
        logits = self._head(params, x[:, -1:, :])[:, 0]
        return logits, new_cache

    def decode_step(self, params, lora, tokens, cache, pos):
        """tokens (B,1) -> (logits (B,V), cache)."""
        x = self._embed_in(params, tokens)
        unit_l = self._unit_lora(lora)

        def body(x, xs):
            if unit_l is not None:
                ps, ls, cs = xs
            else:
                ps, cs = xs
                ls = None
            new_c = {}
            for name, blk in self.unit_blocks:
                l = None if ls is None else ls.get(name)
                x, c = blk.decode_step(ps[name], x, cs[name], pos, lora=l)
                new_c[name] = c
            return x, new_c

        xs = ((params["units"], cache) if unit_l is None
              else (params["units"], unit_l, cache))
        x, new_cache = jax.lax.scan(body, x, xs)
        logits = self._head(params, x)[:, 0]
        return logits, new_cache
