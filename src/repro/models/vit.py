"""Vision Transformer backbone (the paper's own model family, ViT-B/32).

Patchification is external: the model consumes pre-extracted patch
vectors (B, n_patches, patch_dim) — for the paper-scale experiments we
use synthetic tasks, for which patch vectors are generated directly.
Per-task classifier heads live in the federated layer (repro.fed), so
MaTU task vectors cover exactly the shared LoRA parameters, as in the
paper.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.encdec import EncoderBlock
from repro.nn.module import Dense, LayerNorm, Module
from repro.nn.sharding import constrain

PyTree = Any


class ViT(Module):
    def __init__(self, *, patch_dim: int, n_patches: int, d_model: int,
                 n_layers: int, n_heads: int, d_ff: int, remat: bool = False,
                 dtype=jnp.float32):
        self.patch_dim, self.n_patches = patch_dim, n_patches
        self.d_model, self.n_layers = d_model, n_layers
        self.remat = remat
        self.dtype = dtype
        self.patch_embed = Dense(patch_dim, d_model, bias=True, axes=(None, "embed"), dtype=dtype)
        self.block = EncoderBlock(d_model, n_heads, d_ff, dtype=dtype)
        self.final_ln = LayerNorm(d_model, dtype=dtype)

    def init(self, key):
        kp, kb, kc, kpos = jax.random.split(key, 4)
        return {
            "patch_embed": self.patch_embed.init(kp),
            "cls": (jax.random.normal(kc, (1, 1, self.d_model)) * 0.02).astype(self.dtype),
            "pos": (jax.random.normal(kpos, (1, self.n_patches + 1, self.d_model)) * 0.02).astype(self.dtype),
            "blocks": self.block.init_stacked(kb, self.n_layers),
            "final_ln": self.final_ln.init(None),
        }

    def axes(self):
        return {
            "patch_embed": self.patch_embed.axes(),
            "cls": (None, None, "embed"),
            "pos": (None, None, "embed"),
            "blocks": self.block.stacked_axes(),
            "final_ln": self.final_ln.axes(),
        }

    def lora_init(self, key, rank: int):
        ks = jax.random.split(key, self.n_layers)
        return {"blocks": jax.vmap(lambda k: self.block.lora_init(k, rank))(ks)}

    def lora_axes(self):
        return {"blocks": jax.tree_util.tree_map(
            lambda a: ("layers",) + tuple(a or ()), self.block.lora_axes(),
            is_leaf=lambda x: x is None or isinstance(x, tuple))}

    def features(self, params, patches, *, lora=None):
        """patches (B, P, patch_dim) -> CLS features (B, d_model)."""
        b = patches.shape[0]
        x = self.patch_embed(params["patch_embed"], patches.astype(self.dtype))
        cls = jnp.broadcast_to(params["cls"], (b, 1, self.d_model))
        x = jnp.concatenate([cls, x], axis=1) + params["pos"]
        x = constrain(x, ("batch", None, "embed"))

        def body(x, xs):
            if lora is not None:
                p, l = xs
            else:
                (p,) = xs
                l = None
            return self.block(p, x, lora=l), None

        if self.remat:
            body = jax.checkpoint(body)
        xs = (params["blocks"],) if lora is None else (params["blocks"], lora["blocks"])
        x, _ = jax.lax.scan(body, x, xs)
        x = self.final_ln(params["final_ln"], x)
        return x[:, 0]

    def __call__(self, params, patches, *, lora=None):
        return self.features(params, patches, lora=lora)
