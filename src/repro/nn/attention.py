"""Grouped-query attention with RoPE / M-RoPE, sliding windows and caches.

One module serves the dense (qwen2/2.5, codeqwen), VLM (qwen2-vl),
encoder-decoder (whisper) and hybrid (hymba attention branch) families.

Three execution paths:

* ``__call__``      full-sequence (training / short prefill); `impl` picks
                    between materialised scores ("full") and a
                    lax.scan over query chunks with bounded memory
                    ("chunked") — the 32k prefill path.
* ``prefill``       full-sequence + writes the KV cache.
* ``decode_step``   single-token with KV cache; ring buffer when a
                    sliding window is configured (long_500k path).

All softmax math is fp32 regardless of activation dtype.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import Dense, Module
from repro.nn.rope import apply_rope
from repro.nn.sharding import constrain, current_mesh

PyTree = Any
NEG_INF = -1e30


def _split_heads(x, n_heads, head_dim):
    return x.reshape(x.shape[:-1] + (n_heads, head_dim))


class Attention(Module):
    def __init__(
        self,
        d_model: int,
        n_heads: int,
        n_kv_heads: int,
        *,
        head_dim: Optional[int] = None,
        qkv_bias: bool = False,
        out_bias: bool = False,
        rope: bool = True,
        rope_base: float = 10000.0,
        mrope_sections: Optional[Tuple[int, ...]] = None,
        window: Optional[int] = None,
        causal: bool = True,
        cross: bool = False,
        q_chunk: int = 512,
        dtype=jnp.float32,
    ):
        assert n_heads % n_kv_heads == 0
        self.d_model = d_model
        self.n_heads = n_heads
        self.n_kv = n_kv_heads
        self.head_dim = head_dim or d_model // n_heads
        self.group = n_heads // n_kv_heads
        self.rope = rope and not cross
        self.rope_base = rope_base
        self.mrope_sections = mrope_sections
        self.window = window
        self.causal = causal and not cross
        self.cross = cross
        self.q_chunk = q_chunk
        self.dtype = dtype
        hd = self.head_dim
        self.wq = Dense(d_model, n_heads * hd, bias=qkv_bias, axes=("embed", "heads"), dtype=dtype)
        self.wk = Dense(d_model, n_kv_heads * hd, bias=qkv_bias, axes=("embed", "kv_heads"), dtype=dtype)
        self.wv = Dense(d_model, n_kv_heads * hd, bias=qkv_bias, axes=("embed", "kv_heads"), dtype=dtype)
        self.wo = Dense(n_heads * hd, d_model, bias=out_bias, axes=("heads", "embed"), dtype=dtype,
                        scale=1.0 / math.sqrt(n_heads * hd))

    # -- params ----------------------------------------------------------
    def init(self, key):
        kq, kk, kv, ko = jax.random.split(key, 4)
        return {"wq": self.wq.init(kq), "wk": self.wk.init(kk),
                "wv": self.wv.init(kv), "wo": self.wo.init(ko)}

    def axes(self):
        return {"wq": self.wq.axes(), "wk": self.wk.axes(),
                "wv": self.wv.axes(), "wo": self.wo.axes()}

    def lora_init(self, key, rank: int):
        kq, ko = jax.random.split(key, 2)
        return {"wq": self.wq.lora_init(kq, rank), "wo": self.wo.lora_init(ko, rank)}

    def lora_axes(self):
        return {"wq": self.wq.lora_axes(), "wo": self.wo.lora_axes()}

    # -- projections -----------------------------------------------------
    def _qkv(self, params, x, kv_input, positions, lora):
        lora = lora or {}
        q = _split_heads(self.wq(params["wq"], x, lora.get("wq")), self.n_heads, self.head_dim)
        k = _split_heads(self.wk(params["wk"], kv_input), self.n_kv, self.head_dim)
        v = _split_heads(self.wv(params["wv"], kv_input), self.n_kv, self.head_dim)
        q = constrain(q, ("batch", None, "heads", None))
        if self.rope and positions is not None:
            q = apply_rope(q, positions, base=self.rope_base, mrope_sections=self.mrope_sections)
            k = apply_rope(k, positions, base=self.rope_base, mrope_sections=self.mrope_sections)
        return q, k, v

    def _out(self, params, ctx, lora):
        lora = lora or {}
        b, s = ctx.shape[0], ctx.shape[1]
        y = self.wo(params["wo"], ctx.reshape(b, s, self.n_heads * self.head_dim), lora.get("wo"))
        # reduce-scatter into the sequence-parallel residual layout
        # instead of a full all-reduce (PERF-1, EXPERIMENTS.md §Perf)
        return constrain(y, ("batch", "act_seq", "embed"))

    # -- mask ------------------------------------------------------------
    def _mask(self, q_pos, k_pos):
        """q_pos (Q,), k_pos (K,) -> bool (Q, K); True = attend."""
        ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
        if self.causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        if self.window is not None:
            ok &= (q_pos[:, None] - k_pos[None, :]) < self.window
        return ok

    def _sdpa(self, q, k, v, mask):
        """q (B,Q,H,D), k/v (B,S,K,D), mask (Q,S) or (B,1,1,Q,S)."""
        b, qlen = q.shape[0], q.shape[1]
        qg = q.reshape(b, qlen, self.n_kv, self.group, self.head_dim)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
        scores *= 1.0 / math.sqrt(self.head_dim)
        if mask is not None:
            if mask.ndim == 2:
                mask = mask[None, None, None]
            scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        ctx = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
        return ctx.reshape(b, qlen, self.n_heads, self.head_dim)

    # -- full-sequence ---------------------------------------------------
    def __call__(self, params, x, *, positions=None, lora=None,
                 kv_input=None, impl: str = "full",
                 q_chunk: Optional[int] = None) -> jax.Array:
        """x (B,S,d). For cross-attn pass kv_input (B,S_kv,d).

        impl: "full" (materialised scores), "chunked" (scan over query
        chunks), or "auto" (full only when S fits one chunk)."""
        q_chunk = q_chunk or self.q_chunk
        kv_input = x if kv_input is None else kv_input
        q, k, v = self._qkv(params, x, kv_input, positions, lora)
        s_q, s_k = q.shape[1], k.shape[1]
        rope_pos = positions if positions is not None and positions.ndim == 2 else None
        q_pos = rope_pos[0] if rope_pos is not None else jnp.arange(s_q)
        k_pos = q_pos if kv_input is x else jnp.arange(s_k)
        use_full = (impl == "full") or s_q <= q_chunk
        if impl == "auto" and s_q > q_chunk:
            use_full = False
        if use_full:
            mask = self._mask(q_pos, k_pos) if (self.causal or self.window) else None
            ctx = self._sdpa(q, k, v, mask)
        else:
            ctx = self._chunked(q, k, v, q_pos, k_pos, q_chunk)
        return self._out(params, ctx, lora)

    def _seq_parallel(self) -> bool:
        """When the head count does not divide the model axis, shard the
        query-chunk (sequence) dim over `model` instead — otherwise XLA
        replicates heads and score blocks blow up 16x (DESIGN.md §5)."""
        mesh = current_mesh()
        if mesh is None or "model" not in mesh.shape:
            return False
        return self.n_heads % mesh.shape["model"] != 0

    def _chunked(self, q, k, v, q_pos, k_pos, q_chunk):
        """lax.scan over query chunks; O(chunk * S) score memory.
        The chunk body is rematerialised (probs are recomputed in the
        backward pass instead of being saved per chunk)."""
        b, s_q = q.shape[0], q.shape[1]
        n_chunks = -(-s_q // q_chunk)
        pad = n_chunks * q_chunk - s_q
        if pad:
            q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
            q_pos = jnp.pad(q_pos, (0, pad), constant_values=-1)
        qs = q.reshape(b, n_chunks, q_chunk, self.n_heads, self.head_dim).transpose(1, 0, 2, 3, 4)
        seq_par = self._seq_parallel()
        # PERF-4: pin the stacked chunk layout — without this XLA keeps
        # flip-flopping between seq- and (partial) head-sharding across
        # the scan boundary, causing involuntary full rematerializations
        # (observed on the 20/25/40-head archs).
        if seq_par:
            qs = constrain(qs, (None, "batch", "act_seq", None, None))
        else:
            qs = constrain(qs, (None, "batch", None, "heads", None))
        qps = q_pos.reshape(n_chunks, q_chunk)

        @jax.checkpoint
        def body(carry, inp):
            qc, qp = inp
            if seq_par:
                qc = constrain(qc, ("batch", "act_seq", None, None))
            mask = self._mask(qp, k_pos) & (qp >= 0)[:, None]
            out = self._sdpa(qc, k, v, mask)
            if seq_par:
                out = constrain(out, ("batch", "act_seq", None, None))
            return carry, out

        _, ctx = jax.lax.scan(body, None, (qs, qps))
        ctx = ctx.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * q_chunk, self.n_heads, self.head_dim)
        return ctx[:, :s_q]

    # -- serving ---------------------------------------------------------
    def cache_len(self, max_len: int) -> int:
        return min(max_len, self.window) if self.window is not None else max_len

    def init_cache(self, batch: int, max_len: int, dtype=None) -> PyTree:
        dtype = dtype or self.dtype
        s = self.cache_len(max_len)
        z = jnp.zeros((batch, s, self.n_kv, self.head_dim), dtype)
        return {"k": z, "v": z, "kpos": jnp.full((s,), -1, jnp.int32)}

    def prefill(self, params, x, cache, *, positions=None, lora=None,
                impl: str = "chunked", q_chunk: Optional[int] = None):
        """Run full-seq attention AND populate the cache (suffix for SWA)."""
        y = self(params, x, positions=positions, lora=lora, impl=impl, q_chunk=q_chunk)
        _, k, v = self._qkv(params, x, x, positions, lora)
        s_cache = cache["k"].shape[1]
        s = k.shape[1]
        if s >= s_cache:
            # keep the trailing window, slot = pos % window
            start = s - s_cache
            kpos = jnp.arange(start, s)
            slots = kpos % s_cache
            cache = {"k": cache["k"].at[:, slots].set(k[:, start:]),
                     "v": cache["v"].at[:, slots].set(v[:, start:]),
                     "kpos": cache["kpos"].at[slots].set(kpos)}
        else:
            cache = {"k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1),
                     "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1),
                     "kpos": cache["kpos"].at[:s].set(jnp.arange(s))}
        return y, cache

    def decode_step(self, params, x, cache, pos, *, lora=None):
        """x (B,1,d); pos scalar int32 = position of this token."""
        b = x.shape[0]
        if self.mrope_sections is not None:
            positions = jnp.broadcast_to(pos, (b, 1, 3)).astype(jnp.int32)
        else:
            positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
        q, k, v = self._qkv(params, x, x, positions, lora)
        s_cache = cache["k"].shape[1]
        slot = (pos % s_cache).astype(jnp.int32)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 1)
        kpos = jax.lax.dynamic_update_slice_in_dim(cache["kpos"], jnp.broadcast_to(pos, (1,)).astype(jnp.int32), slot, 0)
        valid = (kpos >= 0) & (kpos <= pos)
        if self.window is not None:
            valid &= (pos - kpos) < self.window
        ctx = self._sdpa(q, ck, cv, valid[None, :].astype(bool))
        y = self._out(params, ctx, lora)
        return y, {"k": ck, "v": cv, "kpos": kpos}

    # -- cross-attention serving (whisper) --------------------------------
    def init_cross_cache(self, params, enc_out, *, lora=None):
        """Project encoder output to K/V once; reused every decode step."""
        k = _split_heads(self.wk(params["wk"], enc_out), self.n_kv, self.head_dim)
        v = _split_heads(self.wv(params["wv"], enc_out), self.n_kv, self.head_dim)
        return {"k": k, "v": v}

    def cross_decode_step(self, params, x, cross_cache, *, lora=None):
        lora = lora or {}
        q = _split_heads(self.wq(params["wq"], x, lora.get("wq")), self.n_heads, self.head_dim)
        ctx = self._sdpa(q, cross_cache["k"], cross_cache["v"], None)
        return self._out(params, ctx, lora)

    def cache_axes(self):
        return {"k": ("batch", "cache_seq", "kv_heads", "head_dim"),
                "v": ("batch", "cache_seq", "kv_heads", "head_dim"),
                "kpos": ("cache_seq",)}
