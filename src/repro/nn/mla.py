"""Multi-head Latent Attention (DeepSeek-V2), TPU-adapted.

Two execution regimes:

* train / prefill — the "naive" expansion: decompress the latent to
  per-head K/V and run standard attention (chunked over query blocks
  for 32k prefill).
* decode — the *absorbed* form that is MLA's whole point: the KV cache
  stores only the 512-dim compressed latent + the shared 64-dim RoPE
  key per position; query/nope projections are absorbed through
  ``wkv_b`` so scores are taken directly against the latent.  Cache
  bytes per token: (kv_lora + rope) vs H*(nope+v) for vanilla GQA —
  a 64x reduction at deepseek-v2 scale.

Sliding-window (ring-buffer latent cache) supports the long_500k
decode shape.  Softmax math in fp32.
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.nn.module import Dense, Module, RMSNorm
from repro.nn.rope import apply_rope
from repro.nn.sharding import constrain, current_mesh

PyTree = Any
NEG_INF = -1e30


class MLAttention(Module):
    def __init__(
        self,
        d_model: int,
        n_heads: int,
        *,
        q_lora_rank: int = 1536,
        kv_lora_rank: int = 512,
        qk_nope_dim: int = 128,
        qk_rope_dim: int = 64,
        v_head_dim: int = 128,
        rope_base: float = 10000.0,
        window: Optional[int] = None,
        q_chunk: int = 512,
        dtype=jnp.float32,
    ):
        self.d_model, self.n_heads = d_model, n_heads
        self.q_lora_rank, self.kv_lora_rank = q_lora_rank, kv_lora_rank
        self.nope, self.rope_dim, self.v_dim = qk_nope_dim, qk_rope_dim, v_head_dim
        self.qk_dim = qk_nope_dim + qk_rope_dim
        self.rope_base = rope_base
        self.window = window
        self.q_chunk = q_chunk
        self.dtype = dtype
        self.scale = 1.0 / math.sqrt(self.qk_dim)

        self.wq_a = Dense(d_model, q_lora_rank, axes=("embed", None), dtype=dtype)
        self.q_norm = RMSNorm(q_lora_rank, dtype=dtype)
        self.wq_b = Dense(q_lora_rank, n_heads * self.qk_dim, axes=(None, "heads"), dtype=dtype)
        self.wkv_a = Dense(d_model, kv_lora_rank + qk_rope_dim, axes=("embed", None), dtype=dtype)
        self.kv_norm = RMSNorm(kv_lora_rank, dtype=dtype)
        self.wkv_b = Dense(kv_lora_rank, n_heads * (qk_nope_dim + v_head_dim),
                           axes=(None, "heads"), dtype=dtype)
        self.wo = Dense(n_heads * v_head_dim, d_model, axes=("heads", "embed"), dtype=dtype,
                        scale=1.0 / math.sqrt(n_heads * v_head_dim))

    def init(self, key):
        ks = jax.random.split(key, 5)
        return {
            "wq_a": self.wq_a.init(ks[0]), "q_norm": self.q_norm.init(None),
            "wq_b": self.wq_b.init(ks[1]),
            "wkv_a": self.wkv_a.init(ks[2]), "kv_norm": self.kv_norm.init(None),
            "wkv_b": self.wkv_b.init(ks[3]),
            "wo": self.wo.init(ks[4]),
        }

    def axes(self):
        return {
            "wq_a": self.wq_a.axes(), "q_norm": self.q_norm.axes(),
            "wq_b": self.wq_b.axes(),
            "wkv_a": self.wkv_a.axes(), "kv_norm": self.kv_norm.axes(),
            "wkv_b": self.wkv_b.axes(),
            "wo": self.wo.axes(),
        }

    def lora_init(self, key, rank: int):
        ka, ko = jax.random.split(key, 2)
        return {"wq_a": self.wq_a.lora_init(ka, rank), "wo": self.wo.lora_init(ko, rank)}

    def lora_axes(self):
        return {"wq_a": self.wq_a.lora_axes(), "wo": self.wo.lora_axes()}

    # -- shared projections ------------------------------------------------
    def _q(self, params, x, positions, lora):
        lora = lora or {}
        b, s = x.shape[0], x.shape[1]
        q = self.wq_b(params["wq_b"], self.q_norm(params["q_norm"],
                      self.wq_a(params["wq_a"], x, lora.get("wq_a"))))
        q = q.reshape(b, s, self.n_heads, self.qk_dim)
        q = constrain(q, ("batch", None, "heads", None))
        q_nope, q_rope = q[..., : self.nope], q[..., self.nope :]
        if positions is not None:
            q_rope = apply_rope(q_rope, positions, base=self.rope_base)
        return q_nope, q_rope

    def _latent(self, params, x, positions):
        """-> (c_kv normed (B,S,Lk), k_rope (B,S,R) rope'd)."""
        kv_a = self.wkv_a(params["wkv_a"], x)
        c_kv = self.kv_norm(params["kv_norm"], kv_a[..., : self.kv_lora_rank])
        k_rope = kv_a[..., self.kv_lora_rank :][:, :, None, :]  # (B,S,1,R)
        if positions is not None:
            k_rope = apply_rope(k_rope, positions, base=self.rope_base)
        return c_kv, k_rope[:, :, 0, :]

    def _wkv_b_split(self, params):
        w = params["wkv_b"]["w"].reshape(self.kv_lora_rank, self.n_heads, self.nope + self.v_dim)
        return w[..., : self.nope], w[..., self.nope :]  # (Lk,H,nope), (Lk,H,v)

    # -- full-sequence (train / prefill math) --------------------------------
    def __call__(self, params, x, *, positions=None, lora=None,
                 impl: str = "full", q_chunk: Optional[int] = None):
        q_chunk = q_chunk or self.q_chunk
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        q_nope, q_rope = self._q(params, x, positions, lora)
        c_kv, k_rope = self._latent(params, x, positions)
        wk, wv = self._wkv_b_split(params)
        k_nope = jnp.einsum("bsc,chd->bshd", c_kv, wk)
        v = jnp.einsum("bsc,chd->bshd", c_kv, wv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, self.n_heads, self.rope_dim))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        pos = positions[0]

        use_full = (impl == "full") or s <= q_chunk
        if impl == "auto" and s > q_chunk:
            use_full = False
        if use_full:
            ctx = self._sdpa(q, k, v, pos, pos)
        else:
            ctx = self._chunked(q, k, v, pos, q_chunk)
        return self._out(params, ctx, lora)

    def _mask(self, q_pos, k_pos):
        ok = k_pos[None, :] <= q_pos[:, None]
        if self.window is not None:
            ok &= (q_pos[:, None] - k_pos[None, :]) < self.window
        return ok

    def _sdpa(self, q, k, v, q_pos, k_pos):
        scores = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * self.scale
        mask = self._mask(q_pos, k_pos)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqs,bshd->bqhd", probs, v)

    def _chunked(self, q, k, v, pos, q_chunk):
        b, s = q.shape[0], q.shape[1]
        n_chunks = -(-s // q_chunk)
        pad = n_chunks * q_chunk - s
        if pad:
            q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
            pos_p = jnp.pad(pos, (0, pad), constant_values=-1)
        else:
            pos_p = pos
        qs = q.reshape(b, n_chunks, q_chunk, self.n_heads, self.qk_dim).transpose(1, 0, 2, 3, 4)
        # PERF-2: the reshape/transpose into chunks loses the head
        # sharding of q — without this constraint XLA replicates all
        # heads per device for the scan input stack.
        qs = constrain(qs, (None, "batch", None, "heads", None))
        qps = pos_p.reshape(n_chunks, q_chunk)

        @jax.checkpoint
        def body(carry, inp):
            qc, qp = inp
            scores = jnp.einsum("bqhd,bshd->bhqs", qc, k).astype(jnp.float32) * self.scale
            mask = self._mask(qp, pos) & (qp >= 0)[:, None]
            scores = jnp.where(mask[None, None], scores, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
            return carry, jnp.einsum("bhqs,bshd->bqhd", probs, v)

        _, ctx = jax.lax.scan(body, None, (qs, qps))
        ctx = ctx.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * q_chunk, self.n_heads, self.v_dim)
        return ctx[:, :s]

    def _out(self, params, ctx, lora):
        lora = lora or {}
        b, s = ctx.shape[0], ctx.shape[1]
        y = self.wo(params["wo"], ctx.reshape(b, s, self.n_heads * self.v_dim), lora.get("wo"))
        # reduce-scatter into the sequence-parallel residual (PERF-1)
        return constrain(y, ("batch", "act_seq", "embed"))

    # -- serving: compressed-latent cache ------------------------------------
    def cache_len(self, max_len: int) -> int:
        return min(max_len, self.window) if self.window is not None else max_len

    def init_cache(self, batch: int, max_len: int, dtype=None) -> PyTree:
        dtype = dtype or self.dtype
        s = self.cache_len(max_len)
        return {
            "c_kv": jnp.zeros((batch, s, self.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, s, self.rope_dim), dtype),
            "kpos": jnp.full((s,), -1, jnp.int32),
        }

    def cache_axes(self):
        return {"c_kv": ("batch", "cache_seq", None),
                "k_rope": ("batch", "cache_seq", None),
                "kpos": ("cache_seq",)}

    def prefill(self, params, x, cache, *, positions=None, lora=None,
                impl: str = "chunked", q_chunk: Optional[int] = None):
        q_chunk = q_chunk or self.q_chunk
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        y = self(params, x, positions=positions, lora=lora, impl=impl, q_chunk=q_chunk)
        c_kv, k_rope = self._latent(params, x, positions)
        s_cache = cache["c_kv"].shape[1]
        if s >= s_cache:
            start = s - s_cache
            kpos = jnp.arange(start, s)
            slots = kpos % s_cache
            cache = {"c_kv": cache["c_kv"].at[:, slots].set(c_kv[:, start:]),
                     "k_rope": cache["k_rope"].at[:, slots].set(k_rope[:, start:]),
                     "kpos": cache["kpos"].at[slots].set(kpos)}
        else:
            cache = {"c_kv": jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, 0, 1),
                     "k_rope": jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope, 0, 1),
                     "kpos": cache["kpos"].at[:s].set(jnp.arange(s))}
        return y, cache

    def decode_step(self, params, x, cache, pos, *, lora=None):
        """Absorbed MLA decode: scores against the latent cache directly."""
        b = x.shape[0]
        positions = jnp.broadcast_to(pos, (b, 1)).astype(jnp.int32)
        q_nope, q_rope = self._q(params, x, positions, lora)  # (B,1,H,*)
        c_kv, k_rope = self._latent(params, x, positions)     # (B,1,Lk),(B,1,R)

        s_cache = cache["c_kv"].shape[1]
        slot = (pos % s_cache).astype(jnp.int32)
        cc = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, slot, 1)
        cr = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope, slot, 1)
        kpos = jax.lax.dynamic_update_slice_in_dim(
            cache["kpos"], jnp.broadcast_to(pos, (1,)).astype(jnp.int32), slot, 0)

        wk, wv = self._wkv_b_split(params)
        q_c = jnp.einsum("bqhd,chd->bqhc", q_nope, wk)  # absorb into latent space
        scores = (jnp.einsum("bqhc,bsc->bhqs", q_c, cc)
                  + jnp.einsum("bqhr,bsr->bhqs", q_rope, cr)).astype(jnp.float32) * self.scale
        valid = (kpos >= 0) & (kpos <= pos)
        if self.window is not None:
            valid &= (pos - kpos) < self.window
        scores = jnp.where(valid[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(cc.dtype)
        ctx_c = jnp.einsum("bhqs,bsc->bqhc", probs, cc)
        ctx = jnp.einsum("bqhc,chd->bqhd", ctx_c, wv)  # absorb value up-projection
        y = self._out(params, ctx, lora)
        return y, {"c_kv": cc, "k_rope": cr, "kpos": kpos}
