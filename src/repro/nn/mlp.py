"""Feed-forward blocks: SwiGLU (llama/qwen family) and GELU (whisper/ViT)."""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.nn.module import Dense, Module
from repro.nn.sharding import constrain

PyTree = Any


class SwiGLU(Module):
    def __init__(self, d_model: int, d_ff: int, *, dtype=jnp.float32):
        self.d_model, self.d_ff, self.dtype = d_model, d_ff, dtype
        self.gate = Dense(d_model, d_ff, axes=("embed", "mlp"), dtype=dtype)
        self.up = Dense(d_model, d_ff, axes=("embed", "mlp"), dtype=dtype)
        self.down = Dense(d_ff, d_model, axes=("mlp", "embed"), dtype=dtype)

    def init(self, key):
        kg, ku, kd = jax.random.split(key, 3)
        return {"gate": self.gate.init(kg), "up": self.up.init(ku), "down": self.down.init(kd)}

    def axes(self):
        return {"gate": self.gate.axes(), "up": self.up.axes(), "down": self.down.axes()}

    def lora_init(self, key, rank: int):
        kd, = jax.random.split(key, 1)
        return {"down": self.down.lora_init(kd, rank)}

    def lora_axes(self):
        return {"down": self.down.lora_axes()}

    def __call__(self, params, x, lora: Optional[PyTree] = None):
        lora = lora or {}
        h = jax.nn.silu(self.gate(params["gate"], x)) * self.up(params["up"], x)
        h = constrain(h, ("batch", None, "mlp"))
        # reduce-scatter into the sequence-parallel residual (PERF-1)
        return constrain(self.down(params["down"], h, lora.get("down")),
                         ("batch", "act_seq", "embed"))


class GeluMLP(Module):
    def __init__(self, d_model: int, d_ff: int, *, bias: bool = True, dtype=jnp.float32):
        self.d_model, self.d_ff, self.dtype = d_model, d_ff, dtype
        self.up = Dense(d_model, d_ff, bias=bias, axes=("embed", "mlp"), dtype=dtype)
        self.down = Dense(d_ff, d_model, bias=bias, axes=("mlp", "embed"), dtype=dtype)

    def init(self, key):
        ku, kd = jax.random.split(key, 2)
        return {"up": self.up.init(ku), "down": self.down.init(kd)}

    def axes(self):
        return {"up": self.up.axes(), "down": self.down.axes()}

    def lora_init(self, key, rank: int):
        kd, = jax.random.split(key, 1)
        return {"down": self.down.lora_init(kd, rank)}

    def lora_axes(self):
        return {"down": self.down.lora_axes()}

    def __call__(self, params, x, lora: Optional[PyTree] = None):
        lora = lora or {}
        h = jax.nn.gelu(self.up(params["up"], x), approximate=True)
        h = constrain(h, ("batch", None, "mlp"))
        # reduce-scatter into the sequence-parallel residual (PERF-1)
        return constrain(self.down(params["down"], h, lora.get("down")),
                         ("batch", "act_seq", "embed"))
