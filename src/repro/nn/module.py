"""Minimal functional module system (pure JAX, no flax).

A ``Module`` is a config-carrying object with three methods:

* ``init(key) -> params``      nested dict of jnp arrays
* ``axes() -> axes``           same structure, leaves = logical-axis tuples
* ``__call__(params, ...)``    pure function of (params, inputs)

Parameters are plain pytrees, so optimizers, task vectors, LoRA and
checkpointing all operate with ``jax.tree_util`` directly.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def _split(key, n):
    return jax.random.split(key, n)


class Module:
    """Base class; subclasses define init/axes/__call__."""

    name: str = ""

    def init(self, key) -> PyTree:  # pragma: no cover - abstract
        raise NotImplementedError

    def axes(self) -> PyTree:  # pragma: no cover - abstract
        raise NotImplementedError

    # -- helpers ---------------------------------------------------------
    def init_stacked(self, key, n: int) -> PyTree:
        """Stack ``n`` independent inits along a leading ``layers`` axis."""
        keys = _split(key, n)
        return jax.vmap(self.init)(keys)

    def stacked_axes(self) -> PyTree:
        ax = self.axes()
        return jax.tree_util.tree_map(
            lambda a: ("layers",) + tuple(a or ()),
            ax,
            is_leaf=lambda x: x is None or isinstance(x, tuple),
        )


def dense_init(key, in_dim: int, out_dim: int, *, dtype=jnp.float32,
               scale: Optional[float] = None) -> jax.Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


class Dense(Module):
    """y = x @ W (+ b). LoRA-aware: pass a mirrored ``lora`` subtree."""

    def __init__(self, in_dim: int, out_dim: int, *, bias: bool = False,
                 axes: Tuple[Optional[str], Optional[str]] = (None, None),
                 dtype=jnp.float32, scale: Optional[float] = None):
        self.in_dim, self.out_dim, self.bias = in_dim, out_dim, bias
        self._axes, self.dtype, self.scale = axes, dtype, scale

    def init(self, key):
        p = {"w": dense_init(key, self.in_dim, self.out_dim, dtype=self.dtype, scale=self.scale)}
        if self.bias:
            p["b"] = jnp.zeros((self.out_dim,), self.dtype)
        return p

    def axes(self):
        a = {"w": self._axes}
        if self.bias:
            a["b"] = (self._axes[1],)
        return a

    def __call__(self, params, x, lora: Optional[PyTree] = None):
        w = params["w"]
        y = jnp.einsum("...i,io->...o", x, w)
        if lora is not None and "a" in lora:
            a = lora["a"]
            if isinstance(a, dict):
                # fused multi-tenant form (repro.serve.router): each
                # leaf is {"base", "tau", "words"} + per-request
                # "lam"/"alpha" — the modulated weight is built in
                # VMEM by the fused kernel, never materialised here
                y = y + self._lora_routed_fused(x, lora)
            elif a.ndim == 3:
                # dense-routed multi-tenant form: leaves carry a
                # leading per-request axis (B, in, r)/(B, r, out)/(B,)
                r = a.shape[-1]
                scaling = lora["alpha"].astype(x.dtype) / r
                h = jnp.einsum("b...i,bir->b...r", x, a)
                yl = jnp.einsum("b...r,bro->b...o", h, lora["b"])
                y = y + yl * scaling.reshape((-1,) + (1,) * (yl.ndim - 1))
            else:
                # LoRA: y += (x @ A) @ B * (alpha / r); A:(in,r) B:(r,out)
                r = a.shape[-1]
                scaling = lora.get("alpha", jnp.asarray(float(r), x.dtype)) / r
                y = y + jnp.einsum("...r,ro->...o", jnp.einsum("...i,ir->...r", x, a), lora["b"]) * scaling
        if self.bias:
            y = y + params["b"]
        return y

    @staticmethod
    def _lora_routed_fused(x, lora):
        """Fused serving branch: both LoRA matmuls run through
        ``ops.modulated_matmul`` so each request's modulator is applied
        in VMEM (word-unpack + λ-scale fused into the dot).  ``x`` is
        (B, in) or (B, S, in); per-request ``lam``/``alpha`` are (B,).
        Elementwise ``base + lam·m⊙tau`` is bitwise the dense path's
        ``lora0 + unflatten(modulate(...))`` leaf, so this branch is
        bit-identical to the dense-routed one under jit."""
        from repro.kernels import ops as _kops  # local: keep nn dep-free
        af, bf, lam = lora["a"], lora["b"], lora["lam"]
        r = af["base"].shape[-1]
        squeeze = x.ndim == 2
        x3 = x[:, None, :] if squeeze else x
        h = _kops.modulated_matmul(x3.astype(jnp.float32), af["base"],
                                   af["tau"], af["words"], lam)
        yl = _kops.modulated_matmul(h, bf["base"], bf["tau"], bf["words"],
                                    lam)
        scaling = (lora["alpha"].astype(jnp.float32) / r)
        yl = yl * scaling[:, None, None]
        yl = yl[:, 0] if squeeze else yl
        return yl.astype(x.dtype)

    # LoRA factory -------------------------------------------------------
    def lora_init(self, key, rank: int, *, alpha: Optional[float] = None, dtype=None):
        dtype = dtype or self.dtype
        ka, _ = _split(key, 2)
        return {
            "a": (jax.random.normal(ka, (self.in_dim, rank)) / math.sqrt(self.in_dim)).astype(dtype),
            "b": jnp.zeros((rank, self.out_dim), dtype),
            "alpha": jnp.asarray(float(alpha if alpha is not None else rank), dtype),
        }

    def lora_axes(self):
        return {"a": (self._axes[0], "lora"), "b": ("lora", self._axes[1]), "alpha": None}


class Embedding(Module):
    def __init__(self, vocab: int, dim: int, *, dtype=jnp.float32,
                 axes: Tuple[str, str] = ("vocab", "embed")):
        self.vocab, self.dim, self.dtype, self._axes = vocab, dim, dtype, axes

    def init(self, key):
        return {"table": (jax.random.normal(key, (self.vocab, self.dim)) * 0.02).astype(self.dtype)}

    def axes(self):
        return {"table": self._axes}

    def __call__(self, params, ids):
        return jnp.take(params["table"], ids, axis=0)

    def attend(self, params, x):
        """Tied readout: logits = x @ table^T."""
        return jnp.einsum("...d,vd->...v", x, params["table"])


class RMSNorm(Module):
    def __init__(self, dim: int, *, eps: float = 1e-6, dtype=jnp.float32):
        self.dim, self.eps, self.dtype = dim, eps, dtype

    def init(self, key):
        del key
        return {"scale": jnp.ones((self.dim,), self.dtype)}

    def axes(self):
        return {"scale": ("embed",)}

    def __call__(self, params, x):
        dt = x.dtype
        x32 = x.astype(jnp.float32)
        y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + self.eps)
        return (y * params["scale"].astype(jnp.float32)).astype(dt)


class LayerNorm(Module):
    def __init__(self, dim: int, *, eps: float = 1e-5, dtype=jnp.float32):
        self.dim, self.eps, self.dtype = dim, eps, dtype

    def init(self, key):
        del key
        return {"scale": jnp.ones((self.dim,), self.dtype),
                "bias": jnp.zeros((self.dim,), self.dtype)}

    def axes(self):
        return {"scale": ("embed",), "bias": ("embed",)}

    def __call__(self, params, x):
        dt = x.dtype
        x32 = x.astype(jnp.float32)
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + self.eps)
        return (y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)).astype(dt)
