"""Mixture-of-Experts FFN with TPU-native expert parallelism.

Used by deepseek-v2 (2 shared + 160 routed, top-6, MLA attention) and
granite-3b-moe (40 routed, top-8).

Design (DESIGN.md §5): under tensor parallelism the token activations
are already replicated across the ``model`` mesh axis.  We exploit that
replication instead of an all-to-all: inside a ``shard_map`` over the
mesh, every model-shard selects — from its *replicated* local tokens —
the rows routed to *its* slice of the experts (local scatter into an
(E_local, C, d) capacity buffer), runs its experts, scatters results
back to token order, and a single ``psum`` over ``model`` combines the
partial outputs.  That psum replaces BOTH the EP combine all-to-all and
the usual TP FFN all-reduce, so MoE costs the same collective as a
dense TP FFN.

When the expert count does not divide the model axis (granite: 40 on a
16-way axis), we fall back to *token-parallel* MoE: tokens are split
over ``model`` along the sequence axis, every shard runs all (small)
experts on its token slice, and an ``all_gather`` over ``model``
restores the sequence.  Decode steps (S=1) run fully replicated — the
work is negligible.

Routed experts are frozen under PEFT (LoRA attaches to attention +
shared experts), keeping MaTU task vectors dense — see DESIGN.md §4.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn.module import Module, dense_init
from repro.nn.mlp import SwiGLU
from repro.nn.sharding import current_mesh

PyTree = Any

try:  # jax >= 0.6 exposes shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map

# the "don't verify replication" kwarg was renamed check_rep -> check_vma
import inspect as _inspect

_SM_NOCHECK = ({"check_vma": False}
               if "check_vma" in _inspect.signature(shard_map).parameters
               else {"check_rep": False})


def _round8(x: int) -> int:
    return max(8, ((x + 7) // 8) * 8)


class MoE(Module):
    def __init__(
        self,
        d_model: int,
        d_ff: int,
        n_experts: int,
        top_k: int,
        *,
        n_shared: int = 0,
        shared_d_ff: Optional[int] = None,
        capacity_factor: float = 1.25,
        dtype=jnp.float32,
    ):
        self.d_model, self.d_ff = d_model, d_ff
        self.n_experts, self.top_k = n_experts, top_k
        self.n_shared = n_shared
        self.capacity_factor = capacity_factor
        self.dtype = dtype
        self.shared = (
            SwiGLU(d_model, (shared_d_ff or d_ff) * n_shared, dtype=dtype) if n_shared else None
        )

    # -- params (experts stacked on a leading E axis) ---------------------
    def init(self, key):
        kr, kg, ku, kd, ks = jax.random.split(key, 5)
        e, d, f = self.n_experts, self.d_model, self.d_ff
        p = {
            "router": {"w": dense_init(kr, d, e, dtype=self.dtype)},
            "experts": {
                "gate": jax.vmap(lambda k: dense_init(k, d, f, dtype=self.dtype))(jax.random.split(kg, e)),
                "up": jax.vmap(lambda k: dense_init(k, d, f, dtype=self.dtype))(jax.random.split(ku, e)),
                "down": jax.vmap(lambda k: dense_init(k, f, d, dtype=self.dtype))(jax.random.split(kd, e)),
            },
        }
        if self.shared is not None:
            p["shared"] = self.shared.init(ks)
        return p

    def axes(self):
        ep = self._expert_parallel()
        # Expert-parallel: experts over `model`; additionally the embed
        # dim is sharded over `data` at REST (ZeRO-3 style — the
        # shard_map boundary all-gathers one layer's slice per scan
        # step).  Without EP (granite): per-expert ffn dim over `model`.
        e_ax = "experts" if ep else None
        emb_ax = "expert_embed" if ep else "embed"
        f_ax = None if ep else "moe_mlp"
        a = {
            "router": {"w": ("embed", None)},
            "experts": {
                "gate": (e_ax, emb_ax, f_ax),
                "up": (e_ax, emb_ax, f_ax),
                "down": (e_ax, f_ax, emb_ax),
            },
        }
        if self.shared is not None:
            a["shared"] = self.shared.axes()
        return a

    def lora_init(self, key, rank: int):
        return {"shared": self.shared.lora_init(key, rank)} if self.shared is not None else {}

    def lora_axes(self):
        return {"shared": self.shared.lora_axes()} if self.shared is not None else {}

    # -- mesh helpers ------------------------------------------------------
    def _mesh_info(self):
        mesh = current_mesh()
        if mesh is None or "model" not in mesh.shape:
            return None
        return mesh

    def _expert_parallel(self, mesh=None) -> bool:
        mesh = mesh or self._mesh_info()
        if mesh is None:
            return False
        return self.n_experts % mesh.shape["model"] == 0

    # -- local (per-shard) MoE compute ------------------------------------
    def _local_moe(self, router_w, experts, xt, e0: int, n_local: int, cap: int):
        """xt (T, d) local tokens; experts hold slices [e0, e0+n_local).

        Returns (out (T, d), aux_loss scalar). Scatter-based dispatch:
        loops over the k choices (unrolled, k<=8) so peak extra memory
        is one (T, d) buffer instead of (T*k, d).
        """
        t, d = xt.shape
        logits = jnp.einsum("td,de->te", xt, router_w).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, self.top_k)  # (T, k)
        gate_vals = (gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)).astype(xt.dtype)

        # position of each (token, choice) within its local expert's capacity
        flat_e = gate_idx.reshape(-1)  # (T*k,) global expert ids, row-major (token-major)
        local = (flat_e >= e0) & (flat_e < e0 + n_local)
        le = jnp.where(local, flat_e - e0, n_local)  # dummy bin for foreign rows
        onehot = jax.nn.one_hot(le, n_local + 1, dtype=jnp.int32)
        pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=-1)  # (T*k,)
        keep = local & (pos < cap)
        le_c = jnp.where(keep, le, n_local)       # scatter drops land in row n_local
        pos_c = jnp.where(keep, pos, 0)

        le_k = le_c.reshape(t, self.top_k)
        pos_k = pos_c.reshape(t, self.top_k)
        keep_k = keep.reshape(t, self.top_k)

        buf = jnp.zeros((n_local + 1, cap, d), xt.dtype)
        for j in range(self.top_k):
            buf = buf.at[le_k[:, j], pos_k[:, j]].add(xt * keep_k[:, j, None].astype(xt.dtype))
        buf = buf[:n_local]  # (E_local, C, d)

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, experts["gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", buf, experts["up"])
        eout = jnp.einsum("ecf,efd->ecd", h, experts["down"])
        eout = jnp.concatenate([eout, jnp.zeros((1, cap, d), eout.dtype)], axis=0)

        out = jnp.zeros((t, d), xt.dtype)
        for j in range(self.top_k):
            rows = eout[le_k[:, j], pos_k[:, j]]  # (T, d); dummy row = 0
            out = out + rows * (gate_vals[:, j] * keep_k[:, j].astype(xt.dtype))[:, None]

        # Switch-style load-balance aux (over local view of the router)
        me = jnp.mean(jax.nn.one_hot(gate_idx[:, 0], self.n_experts, dtype=jnp.float32), axis=0)
        ce = jnp.mean(probs, axis=0)
        aux = self.n_experts * jnp.sum(me * ce)
        return out, aux

    def capacity(self, n_tokens: int) -> int:
        return _round8(int(self.capacity_factor * n_tokens * self.top_k / self.n_experts))

    def _chunked_local_moe(self, router_w, experts, xt, e0, n_local,
                           token_chunk: int = 8192):
        """PERF-3: scan over token chunks so the dispatch buffers
        ((E_local, C, d) + the k unrolled (T, d) scatter/gather rows)
        scale with the chunk, not the full local token count — measured
        ~2x peak-memory reduction on deepseek-v2 train_4k.  Capacity is
        enforced per chunk (slightly stricter than global capacity;
        standard practice)."""
        t, d = xt.shape
        if t <= token_chunk or t % token_chunk != 0:
            cap = self.capacity(t)
            return self._local_moe(router_w, experts, xt, e0, n_local, cap)
        n_chunks = t // token_chunk
        cap = self.capacity(token_chunk)

        @jax.checkpoint
        def body(carry, xc):
            out, aux = self._local_moe(router_w, experts, xc, e0, n_local, cap)
            return carry, (out, aux)

        _, (outs, auxs) = jax.lax.scan(
            body, None, xt.reshape(n_chunks, token_chunk, d))
        return outs.reshape(t, d), jnp.mean(auxs)

    # -- public call -------------------------------------------------------
    def __call__(self, params, x, lora: Optional[PyTree] = None):
        """x (B, S, d) -> (B, S, d). Sets ``self.last_aux``."""
        lora = lora or {}
        b, s, d = x.shape
        mesh = self._mesh_info()

        if mesh is None:
            xt = x.reshape(b * s, d)
            out, aux = self._local_moe(
                params["router"]["w"], params["experts"], xt, 0, self.n_experts,
                self.capacity(b * s))
            y = out.reshape(b, s, d)
        else:
            y, aux = self._sharded_moe(params, x, mesh)

        if self.shared is not None:
            y = y + self.shared(params["shared"], x, lora.get("shared"))
        self.last_aux = aux
        return y

    def _sharded_moe(self, params, x, mesh):
        b, s, d = x.shape
        n_model = mesh.shape["model"]
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        n_data = 1
        for a in batch_axes:
            n_data *= mesh.shape[a]
        batch_spec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
        b_shard = batch_spec if b % max(n_data, 1) == 0 and n_data > 1 else None
        b_loc = b // n_data if b_shard is not None else b

        ep = self._expert_parallel(mesh)
        token_parallel = (not ep) and (s % n_model == 0) and s > 1

        all_axes = tuple(mesh.axis_names)

        if ep:
            n_local = self.n_experts // n_model
            x_spec = P(b_shard, None, None)
            e_spec = {"gate": P("model", None, None), "up": P("model", None, None),
                      "down": P("model", None, None)}

            def fn(router_w, experts, xs):
                idx = jax.lax.axis_index("model")
                xt = xs.reshape(-1, d)
                out, aux = self._chunked_local_moe(router_w, experts, xt,
                                                   idx * n_local, n_local)
                out = jax.lax.psum(out, "model")
                return out.reshape(xs.shape), jax.lax.pmean(aux, all_axes)
        elif token_parallel:
            cap = self.capacity(b_loc * (s // n_model))
            x_spec = P(b_shard, "model", None)
            e_spec = {"gate": P(None, None, None), "up": P(None, None, None),
                      "down": P(None, None, None)}

            def fn(router_w, experts, xs):
                xt = xs.reshape(-1, d)
                out, aux = self._local_moe(router_w, experts, xt, 0, self.n_experts, cap)
                return out.reshape(xs.shape), jax.lax.pmean(aux, all_axes)
        else:
            # replicated over model (decode steps / tiny S): every shard
            # computes all experts on its batch slice.
            cap = self.capacity(b_loc * s)
            x_spec = P(b_shard, None, None)
            e_spec = {"gate": P(None, None, None), "up": P(None, None, None),
                      "down": P(None, None, None)}

            def fn(router_w, experts, xs):
                xt = xs.reshape(-1, d)
                out, aux = self._local_moe(router_w, experts, xt, 0, self.n_experts, cap)
                return out.reshape(xs.shape), jax.lax.pmean(aux, all_axes)

        y, aux = shard_map(
            fn,
            mesh=mesh,
            in_specs=(P(), e_spec, x_spec),
            out_specs=(x_spec, P()),
            **_SM_NOCHECK,
        )(params["router"]["w"], params["experts"], x)
        return y, aux
