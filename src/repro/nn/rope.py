"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp


def rope_angles(positions: jax.Array, head_dim: int, base: float) -> jax.Array:
    """positions (..., S) -> angles (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x: jax.Array, positions: jax.Array, *, base: float = 10000.0,
               mrope_sections: Optional[Sequence[int]] = None) -> jax.Array:
    """Apply rotary embedding.

    x: (B, S, H, D) with D even, rotate-half (llama) convention.
    positions: (B, S) int32, or (B, S, 3) for M-RoPE (t/h/w coords).

    M-RoPE (Qwen2-VL): the D/2 frequency slots are partitioned into
    sections; each section takes its phase from the corresponding
    position coordinate (temporal / height / width).
    """
    d = x.shape[-1]
    half = d // 2
    if mrope_sections is None:
        ang = rope_angles(positions, d, base)  # (B,S,half)
    else:
        assert positions.ndim == 3 and positions.shape[-1] == len(mrope_sections)
        assert sum(mrope_sections) == half, (mrope_sections, half)
        per = []
        offset = 0
        freqs = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
        for i, sec in enumerate(mrope_sections):
            f = freqs[offset : offset + sec]
            per.append(positions[..., i].astype(jnp.float32)[..., None] * f)
            offset += sec
        ang = jnp.concatenate(per, axis=-1)  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]  # (B,S,1,half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


def text_mrope_positions(positions: jax.Array) -> jax.Array:
    """Lift (B,S) text positions to (B,S,3) M-RoPE coords (all equal)."""
    return jnp.stack([positions, positions, positions], axis=-1)
