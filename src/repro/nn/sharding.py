"""Logical-axis sharding (MaxText-style).

Every parameter in the model zoo is annotated with a tuple of *logical*
axis names (e.g. ``("embed", "mlp")``).  A set of *rules* maps each
logical axis to zero-or-one mesh axes; :func:`logical_to_sharding`
turns an axes-pytree into a NamedSharding pytree for pjit
in_shardings/out_shardings, and :func:`constrain` applies
``with_sharding_constraint`` to activations inside the traced function.

Outside a mesh context (CPU unit tests, smoke tests) every call is a
no-op, so model code can be written once.
"""

from __future__ import annotations

import threading
from typing import Any, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any
LogicalAxes = Optional[Tuple[Optional[str], ...]]

# Default rules used by the production launcher.  ``None`` = replicate.
# "batch"-like axes shard over the data axes; tensor axes over "model".
DEFAULT_RULES: Tuple[Tuple[str, Any], ...] = (
    ("batch", ("pod", "data")),
    ("fed_clients", ("pod", "data")),
    # chunked-round client/slot rows: present only on population meshes
    # (make_population_mesh) — elsewhere the rule maps to no mesh axis
    # and the slot rows stay replicated.
    ("fed_slots", ("slots",)),
    ("act_seq", "model"),      # sequence-parallel residual stream
    # KV caches shard their sequence dim over whatever axes the batch
    # dim left unused — distributed flash-decode (softmax partials are
    # psum-combined by GSPMD).  For batch-sharded decode that is
    # `model`; for batch-1 long-context it is both axes.
    ("cache_seq", ("data", "model")),
    ("embed", None),
    ("heads", "model"),
    ("kv_heads", None),
    ("head_dim", None),
    ("mlp", "model"),
    ("moe_mlp", "model"),
    ("experts", "model"),
    ("expert_embed", "data"),   # ZeRO-3 rest sharding for expert weights
    ("vocab", "model"),
    ("state", None),
    ("conv", None),
    ("lora", None),
    ("layers", None),
    ("taskvec", ("pod", "data", "model")),  # flattened-d MaTU server math
    ("tasks", None),
)


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Mapping[str, Any] = dict(DEFAULT_RULES)


_CTX = _Ctx()


class mesh_context:
    """Context manager installing (mesh, rules) for logical sharding."""

    def __init__(self, mesh: Mesh, rules: Optional[Mapping[str, Any]] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)
        self._prev = None

    def __enter__(self):
        self._prev = (_CTX.mesh, _CTX.rules)
        _CTX.mesh, _CTX.rules = self.mesh, self.rules
        return self

    def __exit__(self, *exc):
        _CTX.mesh, _CTX.rules = self._prev
        return False


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _axis_size(mesh: Mesh, mesh_axes) -> int:
    if mesh_axes is None:
        return 1
    if isinstance(mesh_axes, str):
        return mesh.shape[mesh_axes]
    return int(np.prod([mesh.shape[a] for a in mesh_axes]))


def resolve_spec(
    logical: LogicalAxes,
    shape: Optional[Sequence[int]] = None,
    *,
    mesh: Optional[Mesh] = None,
    rules: Optional[Mapping[str, Any]] = None,
) -> P:
    """Map logical axis names to a PartitionSpec under the active rules.

    When ``shape`` is given, a mesh mapping that does not divide the
    dimension evenly is dropped (replicated) — we prefer replication
    over GSPMD padding for parameters, and record the decision at the
    call site that cares (the dry-run prints effective specs).
    """
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if logical is None or mesh is None:
        return P()
    spec, used = [], set()
    for i, name in enumerate(logical):
        mesh_axes = rules.get(name) if name is not None else None
        if mesh_axes is None:
            spec.append(None)
            continue
        # a mesh axis may be consumed by only one tensor dim
        if isinstance(mesh_axes, str):
            candidates = (mesh_axes,)
        else:
            candidates = tuple(a for a in mesh_axes)
        candidates = tuple(a for a in candidates if a in mesh.shape and a not in used)
        if not candidates:
            spec.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in candidates]))
        if shape is not None and shape[i] % size != 0:
            # try a prefix of the candidate axes that divides
            ok = None
            for j in range(len(candidates) - 1, 0, -1):
                sub = candidates[:j]
                s = int(np.prod([mesh.shape[a] for a in sub]))
                if shape[i] % s == 0:
                    ok = sub
                    break
            if ok is None:
                spec.append(None)
                continue
            candidates = ok
        used.update(candidates)
        spec.append(candidates[0] if len(candidates) == 1 else tuple(candidates))
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def logical_to_sharding(axes_tree: PyTree, shapes_tree: Optional[PyTree] = None,
                        *, mesh: Optional[Mesh] = None,
                        rules: Optional[Mapping[str, Any]] = None) -> PyTree:
    """Build a NamedSharding pytree from a logical-axes pytree."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        raise ValueError("logical_to_sharding requires an active mesh_context or explicit mesh")

    def one(axes, shape=None):
        return NamedSharding(mesh, resolve_spec(axes, shape, mesh=mesh, rules=rules))

    if shapes_tree is None:
        return jax.tree_util.tree_map(one, axes_tree, is_leaf=lambda x: x is None or isinstance(x, tuple))
    return jax.tree_util.tree_map(
        lambda a, s: one(a, s.shape if hasattr(s, "shape") else s),
        axes_tree, shapes_tree,
        is_leaf=lambda x: x is None or isinstance(x, tuple),
    )


# -- taskvec axis (MaTU sharded round engine) -------------------------------
# The flattened-d server math shards over every mesh axis the "taskvec"
# rule names; these helpers are the single place the engine asks "how
# is the d axis laid out on this mesh".

def taskvec_axes(mesh: Optional[Mesh] = None, *,
                 rules: Optional[Mapping[str, Any]] = None
                 ) -> Tuple[str, ...]:
    """Mesh axes the ``taskvec`` logical axis shards over, major→minor
    (only axes present in the mesh).  Empty tuple = replicated."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return ()
    rules = rules or _CTX.rules
    mapped = rules.get("taskvec")
    if mapped is None:
        return ()
    if isinstance(mapped, str):
        mapped = (mapped,)
    return tuple(a for a in mapped if a in mesh.shape)


def taskvec_shards(mesh: Optional[Mesh] = None, *,
                   rules: Optional[Mapping[str, Any]] = None) -> int:
    """Number of d-axis shards the taskvec rule yields on this mesh."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return 1
    axes = taskvec_axes(mesh, rules=rules)
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def slot_axes(mesh: Optional[Mesh] = None, *,
              rules: Optional[Mapping[str, Any]] = None) -> Tuple[str, ...]:
    """Mesh axes the ``fed_slots`` logical axis (the chunked round's
    client/slot rows) shards over — empty on every mesh without a
    "slots" axis, so the chunked round degrades to row-replicated."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return ()
    rules = rules or _CTX.rules
    mapped = rules.get("fed_slots")
    if mapped is None:
        return ()
    if isinstance(mapped, str):
        mapped = (mapped,)
    return tuple(a for a in mapped if a in mesh.shape)


def slot_shards(mesh: Optional[Mesh] = None, *,
                rules: Optional[Mapping[str, Any]] = None) -> int:
    """Number of client/slot-row shards the fed_slots rule yields."""
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return 1
    axes = slot_axes(mesh, rules=rules)
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def taskvec_sharding(mesh: Mesh, ndim: int, *,
                     rules: Optional[Mapping[str, Any]] = None
                     ) -> NamedSharding:
    """NamedSharding placing an ndim-rank tensor with its LAST axis
    split over the taskvec mesh axes (all other axes replicated) — the
    layout of every d-axis slot tensor in the sharded round engine."""
    axes = taskvec_axes(mesh, rules=rules)
    last: Any = None
    if len(axes) == 1:
        last = axes[0]
    elif axes:
        last = axes
    return NamedSharding(mesh, P(*([None] * (ndim - 1) + [last])))


def constrain(x: jax.Array, logical: LogicalAxes) -> jax.Array:
    """with_sharding_constraint under the active mesh; no-op otherwise."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = resolve_spec(logical, x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
