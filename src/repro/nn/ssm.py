"""Recurrent sequence mixers: xLSTM (mLSTM + sLSTM) and Mamba.

TPU adaptation notes (DESIGN.md §3):

* mLSTM runs in the *chunkwise-parallel* form — within a chunk the
  update is expressed as masked matmuls (MXU-shaped), across chunks a
  ``lax.scan`` carries the (C, n, m) matrix-memory state.  A pure
  per-step recurrence (``mlstm_recurrent``) is kept as the numerical
  oracle and as the decode step.  Both are fully stabilised in log
  space (running max ``m``).
* sLSTM has a true hidden-to-gate recurrence, so it is inherently
  sequential: ``lax.scan`` over time.
* Mamba (hymba's SSM branch) uses a diagonal selective state-space
  recurrence, scanned over time for training and a single fused update
  for decoding.

All recurrences are O(S) in sequence length — these are the
architectures that run the ``long_500k`` shape natively.
"""

from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import Dense, Module, RMSNorm
from repro.nn.sharding import constrain

PyTree = Any


def _logsigmoid(x):
    return -jax.nn.softplus(-x)


def _headwise_rmsnorm(x, scale, eps=1e-6):
    """x (..., H, D) normalised per head (GroupNorm as in xLSTM)."""
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# mLSTM core math
# ---------------------------------------------------------------------------

def mlstm_recurrent_step(state, q, k, v, i_pre, f_pre):
    """One stabilised mLSTM step.

    state: C (B,H,Dk,Dv), n (B,H,Dk), m (B,H)
    q,k (B,H,Dk), v (B,H,Dv), i_pre/f_pre (B,H) pre-activations.
    """
    C, n, m = state
    log_f = _logsigmoid(f_pre.astype(jnp.float32))
    i32 = i_pre.astype(jnp.float32)
    m_new = jnp.maximum(log_f + m, i32)
    fp = jnp.exp(log_f + m - m_new)
    ip = jnp.exp(i32 - m_new)
    C = fp[..., None, None] * C + ip[..., None, None] * (k[..., :, None] * v[..., None, :])
    n = fp[..., None] * n + ip[..., None] * k
    qn = jnp.einsum("bhd,bhd->bh", q, n)
    num = jnp.einsum("bhd,bhdv->bhv", q, C)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))[..., None]
    h = num / denom
    return (C, n, m_new), h.astype(v.dtype)


def mlstm_chunkwise(q, k, v, i_pre, f_pre, state, *, chunk: int = 256):
    """Chunkwise-parallel stabilised mLSTM.

    q,k (B,H,S,Dk) — q pre-scaled by Dk**-0.5; v (B,H,S,Dv);
    i_pre,f_pre (B,H,S). Returns (h (B,H,S,Dv), final_state).
    """
    b, hh, s, dk = q.shape
    dv = v.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        zq = ((0, 0), (0, 0), (0, pad), (0, 0))
        q, k, v = jnp.pad(q, zq), jnp.pad(k, zq), jnp.pad(v, zq)
        # padded steps: forget-gate pre = +inf would keep state; use
        # f_pre=+40 (keep) and i_pre=-inf (inject nothing).
        i_pre = jnp.pad(i_pre, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
        f_pre = jnp.pad(f_pre, ((0, 0), (0, 0), (0, pad)), constant_values=40.0)

    def to_chunks(x):
        return x.reshape(x.shape[:2] + (nc, chunk) + x.shape[3:]).transpose(2, 0, 1, 3, *range(4, x.ndim + 1))

    qs, ks, vs = to_chunks(q), to_chunks(k), to_chunks(v)
    is_, fs = to_chunks(i_pre), to_chunks(f_pre)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, inp):
        C, n, m = carry
        qc, kc, vc, ic, fc = inp  # (B,H,L,*), (B,H,L)
        ic = ic.astype(jnp.float32)
        log_f = _logsigmoid(fc.astype(jnp.float32))
        bcum = jnp.cumsum(log_f, axis=-1)                       # (B,H,L)
        c = ic - bcum
        cmax = jax.lax.cummax(c, axis=2)
        m_t = bcum + jnp.maximum(m[..., None], cmax)            # (B,H,L)

        scale_inter = jnp.exp(bcum + m[..., None] - m_t)        # (B,H,L)
        h_inter = jnp.einsum("bhld,bhdv->bhlv", qc, C) * scale_inter[..., None]
        qn_inter = jnp.einsum("bhld,bhd->bhl", qc, n) * scale_inter

        d_log = bcum[..., :, None] - bcum[..., None, :] + ic[..., None, :]
        d_mat = jnp.where(causal, jnp.exp(d_log - m_t[..., None]), 0.0)  # (B,H,L,L)
        scores = jnp.einsum("bhld,bhsd->bhls", qc, kc).astype(jnp.float32)
        w = d_mat * scores
        h_intra = jnp.einsum("bhls,bhsv->bhlv", w.astype(vc.dtype), vc)
        qn_intra = jnp.sum(w, axis=-1)

        qn = qn_inter + qn_intra
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))[..., None]
        h = (h_inter.astype(jnp.float32) + h_intra.astype(jnp.float32)) / denom

        total = bcum[..., -1]
        m_next = jnp.maximum(m + total, total + jnp.max(c, axis=-1))
        wgt = jnp.exp(total[..., None] - bcum + ic - m_next[..., None])  # (B,H,L)
        C = (jnp.exp(m + total - m_next)[..., None, None] * C
             + jnp.einsum("bhs,bhsd,bhsv->bhdv", wgt, kc, vc))
        n = (jnp.exp(m + total - m_next)[..., None] * n
             + jnp.einsum("bhs,bhsd->bhd", wgt, kc))
        return (C, n, m_next), h.astype(v.dtype)

    final, hs = jax.lax.scan(body, state, (qs, ks, vs, is_, fs))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(b, hh, nc * chunk, dv)
    return h[:, :, :s], final


# ---------------------------------------------------------------------------
# Depthwise causal conv (shared by mLSTM / mamba branches)
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, *, state=None):
    """x (B,S,D), w (K,D) depthwise. Returns (y, new_state (B,K-1,D))."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = _depthwise(xp, w)
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return y, new_state


def _depthwise(xp, w):
    """Simple unrolled depthwise causal conv: xp (B, S+K-1, D), w (K, D)."""
    k = w.shape[0]
    s_out = xp.shape[1] - (k - 1)
    y = jnp.zeros((xp.shape[0], s_out, xp.shape[2]), xp.dtype)
    for j in range(k):
        y = y + xp[:, j : j + s_out] * w[j]
    return y


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------

class MLSTMBlock(Module):
    """Pre-LN mLSTM block: up-proj (u, z gate) -> conv -> q,k,v -> cell ->
    headwise norm -> silu(z) gate -> down-proj. proj_factor=2."""

    def __init__(self, d_model: int, n_heads: int, *, proj_factor: int = 2,
                 qk_factor: int = 4, conv_kernel: int = 4, chunk: int = 256,
                 dtype=jnp.float32):
        self.d_model, self.n_heads = d_model, n_heads
        self.d_inner = d_model * proj_factor
        self.qk_dim = self.d_inner // qk_factor
        self.dk = self.qk_dim // n_heads
        self.dv = self.d_inner // n_heads
        self.conv_kernel = conv_kernel
        self.chunk = chunk
        self.dtype = dtype
        self.norm = RMSNorm(d_model, dtype=dtype)
        self.up = Dense(d_model, 2 * self.d_inner, axes=("embed", "mlp"), dtype=dtype)
        self.wq = Dense(self.d_inner, self.qk_dim, axes=("mlp", "heads"), dtype=dtype)
        self.wk = Dense(self.d_inner, self.qk_dim, axes=("mlp", "heads"), dtype=dtype)
        self.wif = Dense(self.d_inner, 2 * n_heads, axes=("mlp", None), dtype=dtype)
        self.down = Dense(self.d_inner, d_model, axes=("mlp", "embed"), dtype=dtype,
                          scale=1.0 / math.sqrt(self.d_inner))

    def init(self, key):
        ks = jax.random.split(key, 6)
        return {
            "norm": self.norm.init(None),
            "up": self.up.init(ks[0]),
            "conv": {"w": (jax.random.normal(ks[1], (self.conv_kernel, self.d_inner)) * 0.1).astype(self.dtype)},
            "wq": self.wq.init(ks[2]), "wk": self.wk.init(ks[3]),
            "wif": self.wif.init(ks[4]),
            "hnorm": {"scale": jnp.ones((self.n_heads, self.dv), self.dtype)},
            "down": self.down.init(ks[5]),
        }

    def axes(self):
        return {
            "norm": self.norm.axes(),
            "up": self.up.axes(),
            "conv": {"w": ("conv", "mlp")},
            "wq": self.wq.axes(), "wk": self.wk.axes(),
            "wif": self.wif.axes(),
            "hnorm": {"scale": (None, None)},
            "down": self.down.axes(),
        }

    def lora_init(self, key, rank: int):
        ku, kd = jax.random.split(key, 2)
        return {"up": self.up.lora_init(ku, rank), "down": self.down.lora_init(kd, rank)}

    def lora_axes(self):
        return {"up": self.up.lora_axes(), "down": self.down.lora_axes()}

    def _project(self, params, x, lora, conv_state):
        lora = lora or {}
        b, s, _ = x.shape
        xn = self.norm(params["norm"], x)
        uz = self.up(params["up"], xn, lora.get("up"))
        u, z = jnp.split(uz, 2, axis=-1)
        u = constrain(u, ("batch", None, "mlp"))
        uc, conv_state = causal_conv1d(u, params["conv"]["w"], state=conv_state)
        uc = jax.nn.silu(uc)
        q = self.wq(params["wq"], uc).reshape(b, s, self.n_heads, self.dk)
        k = self.wk(params["wk"], uc).reshape(b, s, self.n_heads, self.dk)
        v = uc.reshape(b, s, self.n_heads, self.dv)
        gates = self.wif(params["wif"], uc).reshape(b, s, self.n_heads, 2)
        q = q * (self.dk ** -0.5)
        k = k * (self.dk ** -0.5)
        return q, k, v, gates[..., 0], gates[..., 1], z, conv_state

    def init_cache(self, batch: int, max_len: int = 0, dtype=None) -> PyTree:
        dtype = dtype or self.dtype
        return {
            "C": jnp.zeros((batch, self.n_heads, self.dk, self.dv), jnp.float32),
            "n": jnp.zeros((batch, self.n_heads, self.dk), jnp.float32),
            "m": jnp.full((batch, self.n_heads), -1e30, jnp.float32),
            "conv": jnp.zeros((batch, self.conv_kernel - 1, self.d_inner), dtype),
        }

    def cache_axes(self):
        return {"C": ("batch", None, None, "state"), "n": ("batch", None, "state"),
                "m": ("batch", None), "conv": ("batch", None, "mlp")}

    def _finish(self, params, h, z, lora):
        lora = lora or {}
        b, s = h.shape[0], h.shape[2]
        h = _headwise_rmsnorm(h.transpose(0, 2, 1, 3), params["hnorm"]["scale"])  # (B,S,H,Dv)
        h = h.reshape(b, s, self.d_inner) * jax.nn.silu(z)
        return self.down(params["down"], h, lora.get("down"))

    def __call__(self, params, x, *, lora=None, state=None, positions=None):
        y, _ = self.forward(params, x, lora=lora, state=state)
        return y

    def forward(self, params, x, *, lora=None, state=None):
        b = x.shape[0]
        state = state or self.init_cache(b, dtype=x.dtype)
        q, k, v, i_pre, f_pre, z, conv_state = self._project(params, x, lora, state["conv"])
        st = (state["C"], state["n"], state["m"])
        h, (C, n, m) = mlstm_chunkwise(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            i_pre.transpose(0, 2, 1), f_pre.transpose(0, 2, 1), st, chunk=self.chunk)
        y = self._finish(params, h, z, lora)
        return x + y.astype(x.dtype), {"C": C, "n": n, "m": m, "conv": conv_state}

    prefill = forward

    def decode_step(self, params, x, cache, pos=None, *, lora=None):
        del pos
        q, k, v, i_pre, f_pre, z, conv_state = self._project(params, x, lora, cache["conv"])
        st = (cache["C"], cache["n"], cache["m"])
        (C, n, m), h = mlstm_recurrent_step(
            st, q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
            v[:, 0].astype(jnp.float32), i_pre[:, 0], f_pre[:, 0])
        # h (B,H,Dv) -> (B,H,1,Dv) for the shared output path
        y = self._finish(params, h[:, :, None, :], z, lora)
        return x + y.astype(x.dtype), {"C": C, "n": n, "m": m, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM)
# ---------------------------------------------------------------------------

class SLSTMBlock(Module):
    """Scalar-memory LSTM with hidden-to-gate recurrence + GeGLU FFN."""

    def __init__(self, d_model: int, n_heads: int, *, ffn_factor: float = 4 / 3,
                 dtype=jnp.float32):
        assert d_model % n_heads == 0
        self.d_model, self.n_heads = d_model, n_heads
        self.dh = d_model // n_heads
        self.d_ffn = int(d_model * ffn_factor)
        self.dtype = dtype
        self.norm = RMSNorm(d_model, dtype=dtype)
        self.wx = Dense(d_model, 4 * d_model, axes=("embed", "mlp"), dtype=dtype)
        self.norm2 = RMSNorm(d_model, dtype=dtype)
        self.ffn_up = Dense(d_model, 2 * self.d_ffn, axes=("embed", "mlp"), dtype=dtype)
        self.ffn_down = Dense(self.d_ffn, d_model, axes=("mlp", "embed"), dtype=dtype)

    def init(self, key):
        ks = jax.random.split(key, 4)
        # per-head recurrent weights R: (H, 4, dh, dh)
        r = (jax.random.normal(ks[1], (self.n_heads, 4, self.dh, self.dh))
             / math.sqrt(self.dh)).astype(self.dtype)
        return {
            "norm": self.norm.init(None),
            "wx": self.wx.init(ks[0]),
            "r": {"w": r},
            "hnorm": {"scale": jnp.ones((self.n_heads, self.dh), self.dtype)},
            "norm2": self.norm2.init(None),
            "ffn_up": self.ffn_up.init(ks[2]),
            "ffn_down": self.ffn_down.init(ks[3]),
        }

    def axes(self):
        return {
            "norm": self.norm.axes(),
            "wx": self.wx.axes(),
            "r": {"w": (None, None, "head_dim", None)},
            "hnorm": {"scale": (None, None)},
            "norm2": self.norm2.axes(),
            "ffn_up": self.ffn_up.axes(),
            "ffn_down": self.ffn_down.axes(),
        }

    def lora_init(self, key, rank: int):
        kx, kd = jax.random.split(key, 2)
        return {"wx": self.wx.lora_init(kx, rank), "ffn_down": self.ffn_down.lora_init(kd, rank)}

    def lora_axes(self):
        return {"wx": self.wx.lora_axes(), "ffn_down": self.ffn_down.lora_axes()}

    def init_cache(self, batch: int, max_len: int = 0, dtype=None) -> PyTree:
        z = jnp.zeros((batch, self.n_heads, self.dh), jnp.float32)
        return {"c": z, "n": z + 0.0, "h": z + 0.0,
                "m": jnp.full((batch, self.n_heads, self.dh), -1e30, jnp.float32)}

    def cache_axes(self):
        return {"c": ("batch", None, "head_dim"), "n": ("batch", None, "head_dim"),
                "h": ("batch", None, "head_dim"), "m": ("batch", None, "head_dim")}

    def _step(self, params, carry, gx):
        """carry: dict of (B,H,dh); gx (B,H,4,dh) input-gate preacts."""
        c, n, h, m = carry["c"], carry["n"], carry["h"], carry["m"]
        rec = jnp.einsum("bhd,hgde->bhge", h.astype(self.dtype), params["r"]["w"])
        g = gx + rec.astype(jnp.float32)
        i_pre, f_pre, z_pre, o_pre = g[:, :, 0], g[:, :, 1], g[:, :, 2], g[:, :, 3]
        log_f = _logsigmoid(f_pre)
        m_new = jnp.maximum(log_f + m, i_pre)
        fp = jnp.exp(log_f + m - m_new)
        ip = jnp.exp(i_pre - m_new)
        c = fp * c + ip * jnp.tanh(z_pre)
        n = fp * n + ip
        h_new = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1e-6)
        return {"c": c, "n": n, "h": h_new, "m": m_new}

    def _cell(self, params, x, lora, carry):
        lora = lora or {}
        b, s, _ = x.shape
        xn = self.norm(params["norm"], x)
        gx = self.wx(params["wx"], xn, lora.get("wx"))
        gx = gx.reshape(b, s, 4, self.n_heads, self.dh).astype(jnp.float32)

        def body(cy, g_t):
            cy = self._step(params, cy, g_t.transpose(0, 2, 1, 3))  # (B,4,H,dh)->(B,H,4,dh)
            return cy, cy["h"]

        carry, hs = jax.lax.scan(body, carry, gx.transpose(1, 0, 2, 3, 4))
        hs = _headwise_rmsnorm(hs.transpose(1, 0, 2, 3), params["hnorm"]["scale"])  # (B,S,H,dh)
        return hs.reshape(b, s, self.d_model).astype(x.dtype), carry

    def _ffn(self, params, x, lora):
        lora = lora or {}
        xn = self.norm2(params["norm2"], x)
        u, g = jnp.split(self.ffn_up(params["ffn_up"], xn), 2, axis=-1)
        return self.ffn_down(params["ffn_down"], u * jax.nn.gelu(g, approximate=True),
                             lora.get("ffn_down"))

    def __call__(self, params, x, *, lora=None, state=None, positions=None):
        y, _ = self.forward(params, x, lora=lora, state=state)
        return y

    def forward(self, params, x, *, lora=None, state=None):
        carry = state or self.init_cache(x.shape[0])
        h, carry = self._cell(params, x, lora, carry)
        x = x + h
        x = x + self._ffn(params, x, lora)
        return x, carry

    prefill = forward

    def decode_step(self, params, x, cache, pos=None, *, lora=None):
        del pos
        y, cache = self.forward(params, x, lora=lora, state=cache)
        return y, cache


# ---------------------------------------------------------------------------
# Mamba (selective SSM) — hymba's parallel branch
# ---------------------------------------------------------------------------

class Mamba(Module):
    def __init__(self, d_model: int, *, d_state: int = 16, expand: int = 2,
                 conv_kernel: int = 4, dt_rank: Optional[int] = None,
                 dtype=jnp.float32):
        self.d_model = d_model
        self.d_state = d_state
        self.d_inner = expand * d_model
        self.conv_kernel = conv_kernel
        self.dt_rank = dt_rank or max(16, d_model // 16)
        self.dtype = dtype
        self.in_proj = Dense(d_model, 2 * self.d_inner, axes=("embed", "mlp"), dtype=dtype)
        self.x_proj = Dense(self.d_inner, self.dt_rank + 2 * d_state, axes=("mlp", None), dtype=dtype)
        self.dt_proj = Dense(self.dt_rank, self.d_inner, bias=True, axes=(None, "mlp"), dtype=dtype)
        self.out_proj = Dense(self.d_inner, d_model, axes=("mlp", "embed"), dtype=dtype,
                              scale=1.0 / math.sqrt(self.d_inner))

    def init(self, key):
        ks = jax.random.split(key, 5)
        a = jnp.broadcast_to(jnp.arange(1, self.d_state + 1, dtype=jnp.float32),
                             (self.d_inner, self.d_state))
        return {
            "in_proj": self.in_proj.init(ks[0]),
            "conv": {"w": (jax.random.normal(ks[1], (self.conv_kernel, self.d_inner)) * 0.1).astype(self.dtype)},
            "x_proj": self.x_proj.init(ks[2]),
            "dt_proj": self.dt_proj.init(ks[3]),
            "a_log": jnp.log(a),
            "d": jnp.ones((self.d_inner,), jnp.float32),
            "out_proj": self.out_proj.init(ks[4]),
        }

    def axes(self):
        return {
            "in_proj": self.in_proj.axes(),
            "conv": {"w": ("conv", "mlp")},
            "x_proj": self.x_proj.axes(),
            "dt_proj": self.dt_proj.axes(),
            "a_log": ("mlp", "state"),
            "d": ("mlp",),
            "out_proj": self.out_proj.axes(),
        }

    def lora_init(self, key, rank: int):
        ki, ko = jax.random.split(key, 2)
        return {"in_proj": self.in_proj.lora_init(ki, rank),
                "out_proj": self.out_proj.lora_init(ko, rank)}

    def lora_axes(self):
        return {"in_proj": self.in_proj.lora_axes(), "out_proj": self.out_proj.lora_axes()}

    def init_cache(self, batch: int, max_len: int = 0, dtype=None) -> PyTree:
        dtype = dtype or self.dtype
        return {
            "ssm": jnp.zeros((batch, self.d_inner, self.d_state), jnp.float32),
            "conv": jnp.zeros((batch, self.conv_kernel - 1, self.d_inner), dtype),
        }

    def cache_axes(self):
        return {"ssm": ("batch", "mlp", "state"), "conv": ("batch", None, "mlp")}

    def _inputs(self, params, x, lora, conv_state):
        lora = lora or {}
        xz = self.in_proj(params["in_proj"], x, lora.get("in_proj"))
        xi, z = jnp.split(xz, 2, axis=-1)
        xi = constrain(xi, ("batch", None, "mlp"))
        xc, conv_state = causal_conv1d(xi, params["conv"]["w"], state=conv_state)
        xc = jax.nn.silu(xc)
        proj = self.x_proj(params["x_proj"], xc)
        dt_low = proj[..., : self.dt_rank]
        bmat = proj[..., self.dt_rank : self.dt_rank + self.d_state]
        cmat = proj[..., self.dt_rank + self.d_state :]
        dt = jax.nn.softplus(self.dt_proj(params["dt_proj"], dt_low)).astype(jnp.float32)
        return xc, z, dt, bmat.astype(jnp.float32), cmat.astype(jnp.float32), conv_state

    def forward(self, params, x, *, lora=None, state=None):
        b, s, _ = x.shape
        state = state or self.init_cache(b, dtype=x.dtype)
        xc, z, dt, bmat, cmat, conv_state = self._inputs(params, x, lora, state["conv"])
        a = -jnp.exp(params["a_log"])  # (Din, N)

        def body(h, inp):
            xt, dt_t, b_t, c_t = inp  # (B,Din),(B,Din),(B,N),(B,N)
            da = jnp.exp(dt_t[..., None] * a)                       # (B,Din,N)
            h = da * h + (dt_t * xt.astype(jnp.float32))[..., None] * b_t[:, None, :]
            y = jnp.einsum("bdn,bn->bd", h, c_t)
            return h, y

        xs = (xc.transpose(1, 0, 2), dt.transpose(1, 0, 2),
              bmat.transpose(1, 0, 2), cmat.transpose(1, 0, 2))
        h, ys = jax.lax.scan(body, state["ssm"], xs)
        y = ys.transpose(1, 0, 2).astype(x.dtype)  # (B,S,Din)
        y = y + xc * params["d"].astype(x.dtype)
        y = y * jax.nn.silu(z)
        out = self.out_proj(params["out_proj"], y, (lora or {}).get("out_proj"))
        return out, {"ssm": h, "conv": conv_state}

    def __call__(self, params, x, *, lora=None, state=None, positions=None):
        y, _ = self.forward(params, x, lora=lora, state=state)
        return y

    prefill = forward

    def decode_step(self, params, x, cache, pos=None, *, lora=None):
        del pos
        y, cache = self.forward(params, x, lora=lora, state=cache)
        return y, cache
