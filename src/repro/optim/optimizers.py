"""Pure-JAX optimizers (no optax): SGD(+momentum), AdamW, gradient
clipping, and transformation chaining.

An :class:`Optimizer` is an (init, update) pair over arbitrary pytrees;
``update`` returns (new_params, new_state).  Learning rates may be
floats or step-indexed schedules (callables).
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def _lr_at(lr: Schedule, step: jax.Array) -> jax.Array:
    return lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple]  # (grads, state, params) -> (params, state)


def sgd(lr: Schedule, momentum: float = 0.0) -> Optimizer:
    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mom"] = jax.tree_util.tree_map(jnp.zeros_like, params)
        return state

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            mom = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, state["mom"], grads)
            params = jax.tree_util.tree_map(lambda p, m: p - lr_t * m, params, mom)
            return params, {"step": step, "mom": mom}
        params = jax.tree_util.tree_map(lambda p, g: p - lr_t * g, params, grads)
        return params, {"step": step}

    return Optimizer(init, update)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"step": jnp.zeros((), jnp.int32), "mu": z,
                "nu": jax.tree_util.tree_map(jnp.zeros_like, z)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state["mu"], grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            step_size = lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                step_size = step_size + lr_t * weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step_size).astype(p.dtype)

        params = jax.tree_util.tree_map(upd, params, mu, nu)
        return params, {"step": step, "mu": mu, "nu": nu}

    return Optimizer(init, update)


def clip_by_global_norm(max_norm: float) -> Callable[[PyTree], PyTree]:
    def clip(grads):
        leaves = jax.tree_util.tree_leaves(grads)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
        return jax.tree_util.tree_map(lambda g: g * scale, grads)
    return clip


def chain(clip: Optional[Callable[[PyTree], PyTree]], opt: Optimizer) -> Optimizer:
    if clip is None:
        return opt

    def update(grads, state, params):
        return opt.update(clip(grads), state, params)

    return Optimizer(opt.init, update)
