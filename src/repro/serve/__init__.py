from repro.serve.generate import generate, GenerationConfig

__all__ = ["generate", "GenerationConfig"]
