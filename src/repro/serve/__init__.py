"""Multi-tenant MaTU serving: one backbone, one unified vector, T
cheap modulators.

Serving contract
----------------
1. **Store handoff.**  After a federated round,
   ``MaTUServer.serving_downlink(fingerprint=space.fingerprint)``
   re-unifies the full task-vector set into one all-tasks
   :class:`~repro.core.client.ClientDownlink` (row t ↔ task id t) and
   :meth:`ModulatorStore.ingest` makes it resident: the unified vector
   ONCE in its wire dtype, per task a bit-packed uint32 mask row + an
   fp32 λ.  Masks stay packed until point of use — bool rows are
   packed on ingest, entropy-coded streams decode straight to words.
   The store verifies the downlink's ``TaskVectorSpace`` fingerprint
   against its own manifest before serving anything and refuses an
   unstamped downlink unless explicitly overridden — the same
   abort-before-use handshake the aggregation path runs.

2. **Routing.**  Task ids are DATA, not trace constants.
   :func:`~repro.serve.router.route_batch` resolves a batch's per-
   request task ids eagerly (outside jit) into a routed LoRA pytree:
   dense-routed (per-request adapters from the store's LRU, stacked on
   axis 1 behind the layers axis) or fused (packed per-leaf mask bits
   re-aligned with ``bitpack.slice_bits`` + per-request λ; the
   modulated weight ``base + λ·m⊙τ`` is built in VMEM by the
   ``ops.modulated_matmul`` kernel, fused into the LoRA matmul).
   Dense-routed is bit-identical to single-tenant decode with the
   dense unpacked modulator; fused matches unpack-then-matmul
   bitwise within one compiled program and token-for-token end to
   end (see ``router`` docstring for the fma rounding caveat).

3. **Cache keying.**  The jitted decode program is keyed ONLY on
   shapes — batch size, prompt length, generation config — never on
   task ids or the task mix.  A :class:`~repro.serve.router.
   MultiTenantDecoder` therefore compiles once per (B, S) and reuses
   that one program across every mix (``compile_count()`` asserts it).
   Materialised adapters live in the store's bounded LRU; evictions
   rebuild from packed state on the next request, cheap and off the
   decode hot path.

``generate`` is the sampling loop itself (single jitted ``lax.scan``),
shared by single-task and multi-tenant callers.
"""

from repro.serve.generate import GenerationConfig, generate
from repro.serve.router import MultiTenantDecoder, route_batch
from repro.serve.store import ModulatorStore

__all__ = ["GenerationConfig", "generate", "ModulatorStore",
           "MultiTenantDecoder", "route_batch"]
