"""Batched autoregressive generation over any ArchModel.

Single jitted ``lax.scan`` over decode steps (one compiled program for
the whole generation, cache donated between steps), with greedy /
temperature / top-k sampling.  Works across cache kinds: KV, sliding-
window ring buffers, MLA latents, and recurrent SSM states.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 => greedy
    top_k: int = 0                    # 0 => full distribution
    eos_id: Optional[int] = None


def _sample(logits: jax.Array, cfg: GenerationConfig, key: jax.Array) -> jax.Array:
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def generate(model, params, lora, prompt: jax.Array,
             cfg: GenerationConfig = GenerationConfig(),
             *, rng: Optional[jax.Array] = None,
             max_len: Optional[int] = None) -> jax.Array:
    """prompt (B, S) int32 -> (B, S + max_new_tokens)."""
    b, s = prompt.shape
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    max_len = max_len or (s + cfg.max_new_tokens + 8)

    cache = model.init_cache(b, max_len)
    logits, cache = model.prefill_step(params, lora, {"tokens": prompt}, cache)
    # split BEFORE first use: the prefill sample and the scan carry must
    # consume independent streams (reusing `rng` for both correlated the
    # first token with step 0 at temperature > 0)
    rng, first_key = jax.random.split(rng)
    first = _sample(logits, cfg, first_key)

    def step(carry, inp):
        tok, cache, key, done = carry
        pos, = inp
        key, sub = jax.random.split(key)
        logits, cache = model.decode_fn(params, lora, {"tokens": tok[:, None]},
                                        cache, pos)
        nxt = _sample(logits, cfg, sub)
        if cfg.eos_id is not None:
            nxt = jnp.where(done, cfg.eos_id, nxt)
            done = done | (nxt == cfg.eos_id)
        return (nxt, cache, key, done), nxt

    done0 = jnp.zeros((b,), bool)
    if cfg.eos_id is not None:
        done0 = done0 | (first == cfg.eos_id)
    positions = jnp.arange(s, s + cfg.max_new_tokens - 1, dtype=jnp.int32)
    (_, _, _, _), rest = jax.lax.scan(
        step, (first, cache, rng, done0), (positions,))
    out = jnp.concatenate([first[:, None], rest.T], axis=1)
    return jnp.concatenate([prompt, out], axis=1)
