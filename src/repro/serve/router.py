"""Per-request task routing: one decode batch, many tasks.

``route_batch`` turns a per-request task-id list into the *routed*
LoRA pytree the model consumes, in one of two forms (both detected by
``repro.nn.module.Dense.__call__`` — task ids are DATA resolved here,
eagerly, outside jit, so the compiled decode program depends only on
the batch size, never on which tasks are in the mix):

dense-routed (``fused=False``)
    Each request's materialised adapter (``store.adapter`` — LRU-cached
    ``lora0 + unflatten(λ·m⊙τ)``) is gathered and stacked along a new
    per-request axis at position 1: leaves go ``(L, ...) -> (L, B, ...)``
    so the model's layers-scan still slices axis 0 and every Dense sees
    per-request ``(B, in, r)`` factors.

fused (``fused=True``)
    No adapter is materialised at all.  For every Dense LoRA site the
    routed tree carries ``{"base", "tau", "words"}`` per factor — the
    shared base leaf, the unified vector's model-space slice, and each
    request's *packed* mask bits for that leaf, re-aligned out of the
    whole-d wire row with ``bitpack.slice_bits`` (never unpacked on the
    host) — plus per-request ``lam`` and the densely reconstructed
    per-request ``alpha``.  The modulated weight
    ``base + λ·m⊙τ`` is then built in VMEM by the
    ``ops.modulated_matmul`` kernel, fused into the LoRA matmul.
    Sites whose per-layer factor size is not word-aligned (% 32 != 0)
    fall back to dense-routed leaves for that site only.

Dense-routed is bit-identical to single-tenant decode with the dense
unpacked modulator: ``(λ·m)⊙τ`` is IEEE-exact ``λ·where(m, τ, 0)``
for mask bits in {0, 1}, and the per-request batched einsum contracts
identically to the broadcast one.  The fused form is bit-identical to
unpack-then-matmul *within the same compiled program* and token-
identical end to end; its effective weights can sit one rounding of
the modulated delta off the dense path's because XLA contracts the
in-jit ``base + λ·m⊙τ`` build into an fma (the product feeds the add
unrounded) while a materialised adapter rounds it first —
tests/test_serve_multitenant.py pins down all three contracts.

``MultiTenantDecoder`` is the serving front end: it routes a batch,
runs :func:`repro.serve.generate.generate` through ONE jitted program
reused across task mixes, and exposes the compile count so the
one-program contract is testable.
"""

from __future__ import annotations

import functools
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bitpack
from repro.serve.generate import GenerationConfig, generate
from repro.serve.store import ModulatorStore

PyTree = Any


def _lora_sites(node, prefix: str = ""):
    """Yield ``(path_prefix, site_dict)`` for every Dense LoRA site —
    a dict node carrying array leaves ``a``/``b`` (+ ``alpha``) — in a
    nested lora pytree.  Paths match the TaskVectorSpace rendering."""
    if not isinstance(node, dict):
        return
    if "a" in node and "b" in node and not isinstance(node["a"], dict):
        yield prefix, node
        return
    for key in node:
        sub = f"{prefix}/{key}" if prefix else str(key)
        yield from _lora_sites(node[key], sub)


def _stack_requests(adapters: Sequence[PyTree]) -> PyTree:
    """Stack per-request adapter pytrees along a new axis 1 — after the
    leading layers axis, so the model's unit scan still slices layers
    and each slice carries the per-request axis first."""
    return jax.tree_util.tree_map(
        lambda *leaves: jnp.stack(leaves, axis=1), *adapters)


def _layer_words(rows: jax.Array, offset: int, per_layer: int,
                 n_layers: int) -> jax.Array:
    """(B, W_wire) whole-d packed rows -> (L, B, ceil(per_layer/32))
    re-aligned per-layer mask words of one manifest leaf (the leaf's
    flat block is C-order over (L, ...) — layer l owns bits
    ``[offset + l·per_layer, offset + (l+1)·per_layer)``)."""
    return jnp.stack([bitpack.slice_bits(rows, offset + l * per_layer,
                                         per_layer)
                      for l in range(n_layers)], axis=0)


def _site_dense_routed(site0, tau_site, rows, lam, space, prefix):
    """Dense-routed fallback for one site: reconstruct each request's
    leaves ``leaf0 + λ·m⊙τ`` densely and lay them out (L, B, ...)."""
    out = {}
    for key, leaf0 in site0.items():
        spec = space.by_path(f"{prefix}/{key}")
        bits = bitpack.unpack_bits(
            bitpack.slice_bits(rows, spec.offset, spec.size),
            spec.size, jnp.float32).reshape((rows.shape[0],) + spec.shape)
        lam_b = lam.reshape((-1,) + (1,) * len(spec.shape))
        val = (leaf0.astype(jnp.float32)[None]
               + lam_b * bits * tau_site[key][None])
        out[key] = jnp.moveaxis(val, 0, 1)  # (B, L, ...) -> (L, B, ...)
    return out


def route_batch(store: ModulatorStore, task_ids: Sequence[int], *,
                fused: bool = False) -> PyTree:
    """Routed LoRA pytree for one batch of per-request task ids (see
    module docstring for the two forms).  Runs eagerly: task ids are
    resolved to arrays here so the jitted decode program never traces
    on them."""
    ids = [int(t) for t in task_ids]
    if not ids:
        raise ValueError("route_batch needs at least one request")
    if not fused:
        return _stack_requests([store.adapter(t) for t in ids])

    space = store.space
    tau_tree = store.tau_tree()
    rows = jnp.stack([store.mask_words(t) for t in ids])      # (B, W)
    lam = jnp.stack([store.lam(t) for t in ids])              # (B,)

    def build(node0, tau_node, prefix=""):
        if (isinstance(node0, dict) and "a" in node0 and "b" in node0
                and not isinstance(node0["a"], dict)):
            return build_site(node0, tau_node, prefix)
        return {k: build(node0[k], tau_node[k],
                         f"{prefix}/{k}" if prefix else str(k))
                for k in node0}

    def build_site(site0, tau_site, prefix):
        a_spec = space.by_path(f"{prefix}/a")
        b_spec = space.by_path(f"{prefix}/b")
        n_layers = a_spec.shape[0]
        a_sz = int(np.prod(a_spec.shape[1:]))
        b_sz = int(np.prod(b_spec.shape[1:]))
        if a_sz % 32 or b_sz % 32:
            return _site_dense_routed(site0, tau_site, rows, lam, space,
                                      prefix)
        fusedsite = {
            "a": {"base": site0["a"].astype(jnp.float32),
                  "tau": tau_site["a"],
                  "words": _layer_words(rows, a_spec.offset, a_sz, n_layers)},
            "b": {"base": site0["b"].astype(jnp.float32),
                  "tau": tau_site["b"],
                  "words": _layer_words(rows, b_spec.offset, b_sz, n_layers)},
            "lam": jnp.broadcast_to(lam[None, :], (n_layers, len(ids))),
        }
        if "alpha" in site0:
            al_spec = space.by_path(f"{prefix}/alpha")
            bits = bitpack.unpack_bits(
                bitpack.slice_bits(rows, al_spec.offset, al_spec.size),
                al_spec.size, jnp.float32)                    # (B, L)
            alpha_eff = (site0["alpha"].astype(jnp.float32)[None, :]
                         + lam[:, None] * bits * tau_site["alpha"][None, :])
            fusedsite["alpha"] = alpha_eff.T                  # (L, B)
        return fusedsite

    return build(store.lora0, tau_tree)


class MultiTenantDecoder:
    """Batched multi-tenant decode front end over one backbone.

    One instance = one compiled decode program per (batch, prompt)
    shape, reused across every task mix: routing happens eagerly in
    :func:`route_batch`, so the jitted generation only ever sees
    fixed-shape routed-lora pytrees.  ``compile_count()`` exposes the
    jit cache size — the one-program contract is asserted in tests.
    """

    def __init__(self, model, params, store: ModulatorStore, *,
                 fused: bool = False,
                 cfg: GenerationConfig = GenerationConfig()):
        self.model = model
        self.params = params
        self.store = store
        self.fused = fused
        self.cfg = cfg
        self._gen = jax.jit(functools.partial(generate, model),
                            static_argnames=("cfg", "max_len"))

    def generate(self, prompts: jax.Array, task_ids: Sequence[int], *,
                 rng: Optional[jax.Array] = None,
                 max_len: Optional[int] = None) -> jax.Array:
        """prompts (B, S) int32 + per-request task ids (len B) ->
        (B, S + max_new_tokens) through the routed decode program."""
        b = int(prompts.shape[0])
        if len(task_ids) != b:
            raise ValueError(f"{len(task_ids)} task ids for batch {b}")
        lora = route_batch(self.store, task_ids, fused=self.fused)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        max_len = max_len or (int(prompts.shape[1])
                              + self.cfg.max_new_tokens + 8)
        return self._gen(self.params, lora, prompts, self.cfg, rng=rng,
                         max_len=max_len)

    def compile_count(self) -> int:
        """Number of compiled decode programs behind this decoder."""
        return int(self._gen._cache_size())
