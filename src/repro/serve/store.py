"""ModulatorStore: the multi-tenant serving state — one unified vector,
T cheap modulators, zero per-task checkpoints.

The paper's deployment story (§3.2): after federation the server ships
ONE unified task vector τ plus per-task lightweight modulators
(binary mask m^t, scaler λ^t); a task's adapter is reconstructed as
``lora0 + unflatten(λ^t · m^t ⊙ τ)``.  The store is that story made
resident:

* the unified vector is held ONCE, in its wire dtype (bf16 off a
  packed downlink) — upcast to fp32 only at materialisation, exactly
  like :func:`repro.core.unify.modulate`;
* per task id it holds a bit-packed uint32 mask row (LSB-first wire
  words — bool downlink rows are packed on ingest, entropy-coded
  streams decode straight to words, dense bools never become resident)
  and one fp32 λ;
* materialised task adapters (model-space LoRA pytrees) live in a
  bounded LRU — the working set of hot tasks — and are rebuilt on
  demand from the packed state on a miss.

Ingest is the handoff from a :class:`repro.core.server.MaTUServer`
round (``serving_downlink``): a :class:`ClientDownlink` whose rows are
task ids.  The store refuses a downlink whose ``TaskVectorSpace``
fingerprint does not match its own manifest (same abort-before-use
handshake the round path runs), and refuses an *unstamped* downlink
unless the caller passes ``unchecked=True`` explicitly.

``storage_report`` measures the MaTU win: resident bytes
(base adapter + unified vector + T packed modulators) vs what
per-task-checkpoint serving would hold resident (T full fp32 adapter
pytrees) — the ≥5x headline at T=30 in
``results/bench/serving.json``.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import (TaskVectorLayoutError, TaskVectorSpace,
                               tree_add)
from repro.core.client import ClientDownlink
from repro.core.unify import modulate
from repro.kernels import bitpack

PyTree = Any


class ModulatorStore:
    """Task-id-keyed modulator cache backing the multi-tenant decoder.

    ``space`` is the serving model's layout manifest
    (:class:`TaskVectorSpace` over the LoRA template); ``lora0`` the
    base adapter pytree the deltas apply to (the standard A-gaussian /
    B-zero init — τ = 0 reconstructs the pretrained point).
    ``capacity`` bounds the LRU of materialised task pytrees.
    """

    def __init__(self, space: TaskVectorSpace, lora0: PyTree, *,
                 capacity: int = 8):
        if capacity < 1:
            raise ValueError(f"LRU capacity must be >= 1, got {capacity}")
        self.space = space
        self.lora0 = lora0
        self.capacity = capacity
        self.unified: Optional[jax.Array] = None       # (d,) wire dtype
        self._words: Dict[int, jax.Array] = {}         # t -> (W,) uint32
        self._lams: Dict[int, jax.Array] = {}          # t -> fp32 scalar
        self._lru: "OrderedDict[int, PyTree]" = OrderedDict()
        self._tau_tree: Optional[PyTree] = None        # fp32 unflatten cache
        self.hits = 0
        self.misses = 0
        self.materializations = 0

    # -- ingest ---------------------------------------------------------
    def ingest(self, downlink: ClientDownlink,
               task_ids: Optional[Iterable[int]] = None, *,
               unchecked: bool = False) -> List[int]:
        """Install a round's unified vector + modulators.

        ``downlink`` rows map to ``task_ids`` (row i ↔ task_ids[i];
        default ``0..k-1``, the ``serving_downlink`` convention).  The
        downlink's layout fingerprint must match this store's manifest;
        a downlink with no fingerprint is refused unless
        ``unchecked=True``.  Masks become resident as packed uint32
        words whatever layout they arrive in; stale LRU entries for the
        refreshed tasks are dropped.  Returns the installed task ids.
        """
        if downlink.fingerprint is None:
            if not unchecked:
                raise TaskVectorLayoutError(
                    "refusing to serve an unstamped downlink (no layout "
                    "fingerprint); pass unchecked=True to override")
        else:
            self.space.require_compatible(downlink.fingerprint,
                                          context="serving store ingest")
        d = int(downlink.unified.shape[-1])
        if d < self.space.d:
            raise TaskVectorLayoutError(
                f"downlink vector has {d} coords, serving manifest needs "
                f"d={self.space.d}")
        k = int(downlink.lams.shape[0])
        ids = list(range(k)) if task_ids is None else [int(t) for t in task_ids]
        if len(ids) != k:
            raise ValueError(f"{len(ids)} task ids for {k} modulator rows")
        if downlink.coded:
            words = downlink.mask_row(slice(0, k))  # decoded words, cached
        elif downlink.packed:
            words = downlink.masks
        else:
            words = bitpack.pack_bits(downlink.masks)
        self.unified = downlink.unified
        self._tau_tree = None
        for i, t in enumerate(ids):
            self._words[t] = words[i]
            self._lams[t] = jnp.asarray(downlink.lams[i], jnp.float32)
            self._lru.pop(t, None)          # stale materialisation out
        return ids

    # -- lookup ---------------------------------------------------------
    @property
    def task_ids(self) -> List[int]:
        return sorted(self._words)

    def __contains__(self, task_id: int) -> bool:
        return int(task_id) in self._words

    def _require(self, task_id: int) -> int:
        t = int(task_id)
        if t not in self._words:
            raise KeyError(f"task {t} has no resident modulator "
                           f"(known: {self.task_ids})")
        return t

    def mask_words(self, task_id: int) -> jax.Array:
        """Packed (ceil(d/32),) uint32 modulator row — stays packed."""
        return self._words[self._require(task_id)]

    def lam(self, task_id: int) -> jax.Array:
        return self._lams[self._require(task_id)]

    def delta(self, task_id: int) -> jax.Array:
        """Flat fp32 modulated delta λ^t · m^t ⊙ τ (the packed row is
        unpacked here, at point of use)."""
        t = self._require(task_id)
        return modulate(self.unified, self._words[t], self._lams[t])

    def tau_tree(self) -> PyTree:
        """The unified vector as a model-space fp32 pytree (the fused
        router's per-leaf τ operand), unflattened once per ingest."""
        if self.unified is None:
            raise ValueError("store has no unified vector (ingest first)")
        if self._tau_tree is None:
            self._tau_tree = self.space.unflatten(
                self.unified.astype(jnp.float32))
        return self._tau_tree

    def adapter(self, task_id: int) -> PyTree:
        """Materialised task adapter ``lora0 + unflatten(delta)``, via
        the LRU (hit: no recompute; miss: rebuild from packed state and
        possibly evict the least-recently-used task)."""
        t = self._require(task_id)
        if t in self._lru:
            self.hits += 1
            self._lru.move_to_end(t)
            return self._lru[t]
        self.misses += 1
        self.materializations += 1
        adapter = tree_add(self.lora0, self.space.unflatten(self.delta(t)))
        self._lru[t] = adapter
        while len(self._lru) > self.capacity:
            self._lru.popitem(last=False)
        return adapter

    def cached_task_ids(self) -> List[int]:
        """LRU contents, least- to most-recently used (test hook)."""
        return list(self._lru)

    # -- storage accounting ---------------------------------------------
    def resident_bytes(self) -> int:
        """Bytes the store keeps resident: base adapter + the unified
        vector (wire dtype) + per task one packed mask row + one fp32 λ.
        LRU materialisations are a bounded working-set cache, not part
        of the serving state, and are excluded (set ``capacity=1`` to
        make them negligible)."""
        base = sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
                   for l in jax.tree_util.tree_leaves(self.lora0))
        uni = int(self.unified.size) * self.unified.dtype.itemsize \
            if self.unified is not None else 0
        mods = sum(int(w.size) * 4 + 4 for w in self._words.values())
        return base + uni + mods

    def checkpoint_bytes(self) -> int:
        """What per-task-checkpoint serving holds resident instead: one
        full fp32 adapter pytree per task (each is lora0 + delta — same
        shape as lora0, 4 bytes per coordinate)."""
        per_task = 4 * self.space.d
        return len(self._words) * per_task

    def storage_report(self) -> Dict[str, float]:
        resident = self.resident_bytes()
        ckpt = self.checkpoint_bytes()
        return {
            "tasks": len(self._words),
            "d": self.space.d,
            "resident_bytes": resident,
            "checkpoint_bytes": ckpt,
            "ratio": (ckpt / resident) if resident else float("inf"),
            "lru_capacity": self.capacity,
            "lru_hits": self.hits,
            "lru_misses": self.misses,
            "materializations": self.materializations,
        }
