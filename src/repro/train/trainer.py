"""Train/serve step factories for the big-model path (pjit-ready).

``make_train_step`` builds the canonical LoRA fine-tune step used by
the launcher, the dry-run, and the LM examples: loss → LoRA grads →
AdamW update.  Base parameters stay frozen (no optimizer state).
``make_full_train_step`` is the full-fine-tune variant (baseline for
ablations).  Serve steps wrap prefill/decode with cache donation.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim import Optimizer, adamw, chain, clip_by_global_norm

PyTree = Any


def make_train_step(model, opt: Optional[Optimizer] = None,
                    grad_clip: Optional[float] = 1.0):
    """Returns train_step(params, lora, opt_state, batch) ->
    (lora, opt_state, metrics). Differentiates LoRA only."""
    opt = opt or adamw(1e-4, weight_decay=0.0)
    opt = chain(clip_by_global_norm(grad_clip) if grad_clip else None, opt)

    def train_step(params, lora, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda l: model.loss(params, l, batch))(lora)
        lora, opt_state = opt.update(grads, opt_state, lora)
        return lora, opt_state, {"loss": loss}

    return train_step, opt


def make_full_train_step(model, opt: Optional[Optimizer] = None,
                         grad_clip: Optional[float] = 1.0):
    """Full fine-tune variant: differentiates base params (lora=None)."""
    opt = opt or adamw(1e-4, weight_decay=0.0)
    opt = chain(clip_by_global_norm(grad_clip) if grad_clip else None, opt)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, None, batch))(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss}

    return train_step, opt


def make_prefill_step(model, impl: str = "chunked"):
    def prefill_step(params, lora, batch, cache):
        return model.prefill_step(params, lora, batch, cache, impl=impl)
    return prefill_step


def make_decode_step(model):
    def decode_step(params, lora, batch, cache, pos):
        return model.decode_fn(params, lora, batch, cache, pos)
    return decode_step
