"""Server aggregation tests: Eq. 3–6 and the full stateless round."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (agreement_mask, combine_round,
                                    cross_task_aggregate, matu_round,
                                    sign_similarity, task_aggregate,
                                    topk_similar)
from repro.core.client import ClientUpload
from repro.core.server import MaTUServer, MaTUServerConfig
from repro.core.unify import unify_with_modulators

jax.config.update("jax_platform_name", "cpu")


def test_agreement_mask_unanimous():
    """All members agree on sign -> alpha=1 -> m_hat=1 on support."""
    unified = jnp.array([[1.0, -1.0, 2.0], [2.0, -3.0, 1.0]])
    masks = jnp.ones((2, 3), bool)
    member = jnp.array([True, True])
    m_hat = agreement_mask(masks, unified, member, rho=0.4)
    np.testing.assert_allclose(m_hat, [1.0, 1.0, 1.0])


def test_agreement_mask_conflict():
    """Perfect sign conflict -> alpha=0 -> m_hat=0 (soft suppression)."""
    unified = jnp.array([[1.0, -1.0], [-1.0, 1.0]])
    masks = jnp.ones((2, 2), bool)
    member = jnp.array([True, True])
    m_hat = agreement_mask(masks, unified, member, rho=0.4)
    np.testing.assert_allclose(m_hat, [0.0, 0.0])


def test_agreement_mask_threshold():
    """alpha below rho passes through as the soft value."""
    unified = jnp.array([[1.0], [1.0], [-1.0]])
    masks = jnp.ones((3, 1), bool)
    member = jnp.array([True, True, True])
    m_hat = agreement_mask(masks, unified, member, rho=0.4)
    np.testing.assert_allclose(m_hat, [1.0 / 3.0], rtol=1e-6)  # 1/3 < 0.4


def test_task_aggregate_single_client_identity_mask():
    """One member, full mask: tau_hat = lambda * unified (gamma=1)."""
    unified = jnp.array([[2.0, -4.0, 1.0], [9.0, 9.0, 9.0]])
    masks = jnp.array([[1, 1, 1], [0, 0, 0]], bool)
    lams = jnp.array([0.5, 7.0])
    member = jnp.array([True, False])
    sizes = jnp.array([10.0, 0.0])
    tau_hat, m_hat = task_aggregate(unified, masks, lams, member, sizes)
    np.testing.assert_allclose(tau_hat, [1.0, -2.0, 0.5])
    np.testing.assert_allclose(m_hat, [1.0, 1.0, 1.0])


def test_sign_similarity_bounds_and_diag():
    x = jnp.asarray(np.random.default_rng(0).standard_normal((5, 200)), jnp.float32)
    s = sign_similarity(x)
    assert np.all(np.asarray(s) >= 0) and np.all(np.asarray(s) <= 1)
    np.testing.assert_allclose(np.diag(np.asarray(s)), 1.0, atol=1e-6)
    np.testing.assert_allclose(s, s.T, rtol=1e-6)


def test_sign_similarity_opposites():
    a = jnp.ones((1, 64))
    s = sign_similarity(jnp.concatenate([a, -a]))
    np.testing.assert_allclose(s, [[1.0, 0.0], [0.0, 1.0]], atol=1e-6)


def test_topk_excludes_self_and_low_sim():
    sim = jnp.array([
        [1.0, 0.9, 0.3],
        [0.9, 1.0, 0.6],
        [0.3, 0.6, 1.0],
    ])
    w = np.asarray(topk_similar(sim, eps=0.5, kappa=2))
    assert w[0, 0] == 0 and w[1, 1] == 0 and w[2, 2] == 0  # no self
    assert w[0, 2] == 0                                     # below eps
    assert w[0, 1] > 0 and w[1, 2] > 0


def test_combine_round_norm_stability():
    """tau^{r+1} stays on the scale of tau_hat (no geometric growth)."""
    rng = np.random.default_rng(0)
    tau_hats = jnp.asarray(rng.standard_normal((4, 500)), jnp.float32)
    m_hats = jnp.ones((4, 500))
    sim = sign_similarity(tau_hats)
    w = topk_similar(sim, eps=0.0, kappa=3)
    tildes = cross_task_aggregate(tau_hats, m_hats, w)
    out = combine_round(tau_hats, tildes, w)
    for t in range(4):
        assert (jnp.linalg.norm(out[t])
                <= 1.5 * jnp.linalg.norm(tau_hats[t]) + 1e-3)


def test_matu_round_shapes_and_ablations():
    rng = np.random.default_rng(0)
    n, t, d = 6, 4, 300
    unified = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    masks = jnp.asarray(rng.random((n, t, d)) > 0.5)
    lams = jnp.asarray(rng.random((n, t)) + 0.5, jnp.float32)
    alloc = jnp.asarray(rng.random((n, t)) > 0.4)
    sizes = jnp.where(alloc, 100.0, 0.0)

    out = matu_round(unified, masks, lams, alloc, sizes)
    assert out.task_vectors.shape == (t, d)
    assert out.similarity.shape == (t, t)

    no_cross = matu_round(unified, masks, lams, alloc, sizes, cross_task=False)
    np.testing.assert_allclose(no_cross.task_vectors, no_cross.tau_hats)

    uni = matu_round(unified, masks, lams, alloc, sizes, uniform_cross=True)
    assert not np.allclose(uni.task_vectors, out.task_vectors)


def test_server_round_stateless_and_complete():
    """Full client->server->client round: downlinks cover each client's
    tasks; the server keeps no per-client state."""
    rng = np.random.default_rng(0)
    d, n_tasks = 128, 5
    ups = []
    for cid, tasks in enumerate([[0, 1], [1, 2], [3], [0, 4]]):
        tvs = jnp.asarray(rng.standard_normal((len(tasks), d)), jnp.float32)
        unified, masks, lams = unify_with_modulators(tvs)
        ups.append(ClientUpload(cid, tasks, unified, masks, lams,
                                [100] * len(tasks)))
    server = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
    down = server.round(ups)
    assert set(down) == {0, 1, 2, 3}
    for up in ups:
        dl = down[up.client_id]
        assert dl.unified.shape == (d,)
        # the downlink travels in the wire format: packed mask words
        assert dl.masks.shape == (len(up.task_ids), -(-d // 32))
        assert dl.masks.dtype == jnp.uint32
        assert dl.masks_dense().shape == (len(up.task_ids), d)
        assert dl.lams.shape == (len(up.task_ids),)
    # stateless: a second identical round gives identical output
    server2 = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
    down2 = server2.round(ups)
    np.testing.assert_allclose(np.asarray(down[0].unified, np.float32),
                               np.asarray(down2[0].unified, np.float32))


def test_uplink_bits_scale_with_one_vector():
    """MaTU uplink = 32d + k(d+32) — one fp32 vector regardless of k."""
    d = 1000
    up1 = ClientUpload(0, [0], jnp.zeros(d), jnp.zeros((1, d), bool),
                       jnp.zeros(1), [1])
    up5 = ClientUpload(0, [0, 1, 2, 3, 4], jnp.zeros(d),
                       jnp.zeros((5, d), bool), jnp.zeros(5), [1] * 5)
    assert up1.uplink_bits() == 32 * d + 1 * (d + 32)
    assert up5.uplink_bits() == 32 * d + 5 * (d + 32)
    # adapter-per-task baseline for 5 tasks costs 5*32*d
    assert up5.uplink_bits() < 5 * 32 * d
