"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated as a REDUCED variant of the
same family (≤2 layers/units, d_model ≤ 128, ≤4 experts) and runs one
forward + one LoRA train step on CPU, asserting output shapes and the
absence of NaNs.  The FULL configs are exercised via the dry-run only.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, input_specs, load_arch
from repro.optim import adamw
from repro.train.trainer import make_train_step

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = load_arch(arch).reduced()
    shape = SHAPES["train_4k"]
    model = cfg.build(shape)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    lora = model.lora_init(jax.random.PRNGKey(1))

    batch = input_specs(cfg, shape, concrete=True, batch_override=2,
                        seq_override=32)
    batch["tokens"] = jax.random.randint(key, batch["tokens"].shape, 0, cfg.vocab)
    batch["labels"] = jax.random.randint(key, batch["labels"].shape, 0, cfg.vocab)

    loss = model.loss(params, lora, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"

    train_step, opt = make_train_step(model, adamw(1e-3))
    opt_state = opt.init(lora)
    lora2, opt_state, metrics = train_step(params, lora, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    # the step must actually move the LoRA parameters
    moved = sum(
        float(jnp.sum(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(lora2),
                        jax.tree_util.tree_leaves(lora)))
    assert moved > 0, f"{arch}: train step was a no-op"
    for leaf in jax.tree_util.tree_leaves(lora2):
        assert jnp.all(jnp.isfinite(leaf)), f"{arch}: NaN in updated LoRA"


def test_scan_barrier_takes_grad():
    """Regression: ``lax.optimization_barrier`` has no differentiation
    rule (NotImplementedError under grad on jax ≤ 0.4.37), which failed
    every train-step case above at seed.  ``grad_safe_barrier`` must be
    an exact identity in both primal and gradient, under the same
    remat + scan structure the LM uses."""
    from repro.models.lm import grad_safe_barrier

    x = jnp.arange(6.0).reshape(2, 3)
    np.testing.assert_array_equal(np.asarray(grad_safe_barrier(x)),
                                  np.asarray(x))
    g = jax.grad(lambda v: jnp.sum(grad_safe_barrier(v) ** 2))(x)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(2 * x))

    def scanned(v):
        @jax.checkpoint
        def body(c, _):
            return jnp.sin(grad_safe_barrier(c)), None
        out, _ = jax.lax.scan(body, v, None, length=3)
        return jnp.sum(out)

    def scanned_ref(v):
        def body(c, _):
            return jnp.sin(c), None
        out, _ = jax.lax.scan(body, v, None, length=3)
        return jnp.sum(out)

    np.testing.assert_allclose(np.asarray(jax.grad(scanned)(x)),
                               np.asarray(jax.grad(scanned_ref)(x)),
                               rtol=1e-6)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_logits_shape(arch):
    cfg = load_arch(arch).reduced()
    model = cfg.build(SHAPES["train_4k"])
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.family == "audio":
        ae = jnp.ones((B, cfg.enc_frames, cfg.d_model)) * 0.01
        logits = model.model.forward(params, toks, ae)
    else:
        logits, _aux = model.model.forward(params, toks)
    assert logits.shape == (B, S, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits)), f"{arch}: NaN logits"


def test_full_configs_match_assignment():
    """The exact published hyper-parameters from the assignment block."""
    expect = {
        "xlstm-1.3b": dict(n_layers=48, d_model=2048, n_heads=4, vocab=50304),
        "qwen2.5-3b": dict(n_layers=36, d_model=2048, n_heads=16,
                           n_kv_heads=2, d_ff=11008, vocab=151936),
        "whisper-large-v3": dict(n_layers=32, d_model=1280, n_heads=20,
                                 d_ff=5120, vocab=51866),
        "hymba-1.5b": dict(n_layers=32, d_model=1600, n_heads=25,
                           n_kv_heads=5, d_ff=5504, vocab=32001, ssm_state=16),
        "qwen2-0.5b": dict(n_layers=24, d_model=896, n_heads=14,
                           n_kv_heads=2, d_ff=4864, vocab=151936),
        "deepseek-v2-236b": dict(n_layers=60, d_model=5120, n_heads=128,
                                 d_ff=1536, vocab=102400, n_experts=160,
                                 top_k=6, kv_lora_rank=512),
        "qwen2.5-32b": dict(n_layers=64, d_model=5120, n_heads=40,
                            n_kv_heads=8, d_ff=27648, vocab=152064),
        "qwen2-vl-7b": dict(n_layers=28, d_model=3584, n_heads=28,
                            n_kv_heads=4, d_ff=18944, vocab=152064),
        "granite-moe-3b-a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                     n_kv_heads=8, d_ff=512, vocab=49155,
                                     n_experts=40, top_k=8),
        "codeqwen1.5-7b": dict(n_layers=32, d_model=4096, n_heads=32,
                               n_kv_heads=32, d_ff=13440, vocab=92416),
    }
    for arch, fields in expect.items():
        cfg = load_arch(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"


def test_long_500k_policy():
    """SSM/hybrid run natively; dense/moe/vlm via SWA; whisper skipped."""
    for arch in ARCH_IDS:
        cfg = load_arch(arch)
        if arch == "whisper-large-v3":
            assert not cfg.supports_long
        else:
            assert cfg.supports_long, arch
        if cfg.family in ("dense", "moe", "vlm"):
            assert cfg.window_for_shape(SHAPES["long_500k"]) == 4096
        if cfg.family == "ssm":
            assert cfg.window_for_shape(SHAPES["long_500k"]) is None
