"""Fault-tolerant async MaTU rounds (simulator systems mode +
AsyncMaTUStrategy).

The scientific anchor: under ``ClientSystems.ideal`` the async buffered
path is BIT-identical to the sync ``FedSimulator.run`` — unified
vectors, λ, measured wire bits, History — for both packed layouts (raw
words and coded streams).  Plus the fault suite from the issue: 30%
dropout + stragglers with staleness cap 4 completes every round and
stays within 2 accuracy points of fault-free; injected corrupted
streams are 100% quarantined and counted; empty rounds skip-and-carry
with a 0-bit History row; staleness-discounted λ actually changes the
merge; fault counters and phase timings are recorded sync and async.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fed.simulator import FedConfig, FedSimulator
from repro.fed.strategies import (AsyncMaTUStrategy, MaTUStrategy,
                                  RoundBatch, STRATEGIES, Upload)
from repro.fed.systems import ClientSystems, FaultModel

jax.config.update("jax_platform_name", "cpu")

N_TASKS = 5


def _setting():
    from repro.data.dirichlet import dirichlet_split
    from repro.data.synthetic import make_constellation
    from repro.fed.testbed import MLPBackbone
    con = make_constellation(n_tasks=N_TASKS, n_groups=2, feat_dim=16,
                             n_classes=4, seed=0)
    split = dirichlet_split(n_clients=5, n_tasks=N_TASKS, n_classes=4,
                            zeta_t=0.5, tasks_per_client=2, seed=0)
    bb = MLPBackbone(16, hidden=24, lora_rank=4)
    return con, split, bb


def _cfg(**kw):
    base = dict(rounds=4, participation=1.0, local_steps=2, batch_size=16,
                local_data=64, eval_every=2)
    base.update(kw)
    return FedConfig(**base)


# -- the equivalence anchor ---------------------------------------------------

@pytest.mark.parametrize("mode_env", ["ref", "pallas_interpret"])
@pytest.mark.parametrize("code_masks", [False, True])
def test_async_ideal_trace_bit_parity(code_masks, mode_env, monkeypatch):
    """Async with the always-available / zero-latency / zero-fault
    trace ≡ sync, bit for bit: accuracies, unified per-task vectors,
    per-client wire buffers (unified bf16 + masks + λ), and the
    measured up/downlink bits — for both packed layouts, under both
    dispatch modes."""
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    if mode_env == "pallas_interpret":
        monkeypatch.delenv("REPRO_DISABLE_PALLAS", raising=False)
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    con, split, bb = _setting()
    cfg = _cfg()

    s_sync = MaTUStrategy(con.n_tasks, bb.d, code_masks=code_masks)
    h_sync = FedSimulator(cfg, con, split, bb, s_sync).run()

    s_async = AsyncMaTUStrategy(con.n_tasks, bb.d, code_masks=code_masks)
    h_async = FedSimulator(cfg, con, split, bb, s_async,
                           systems=ClientSystems.ideal(5)).run()

    assert h_sync.mean_acc == h_async.mean_acc
    assert h_sync.task_acc == h_async.task_acc
    assert h_sync.uplink_bits_per_round == h_async.uplink_bits_per_round
    assert h_sync.downlink_bits_per_round == h_async.downlink_bits_per_round
    np.testing.assert_array_equal(
        np.asarray(s_sync.server.last_task_vectors),
        np.asarray(s_async.server.last_task_vectors))
    ups_s = {u.client_id: u for u in s_sync._last_uploads}
    ups_a = {u.client_id: u for u in s_async._last_uploads}
    assert set(ups_s) == set(ups_a)
    for c in ups_s:
        np.testing.assert_array_equal(np.asarray(ups_s[c].unified),
                                      np.asarray(ups_a[c].unified))
        np.testing.assert_array_equal(np.asarray(ups_s[c].masks),
                                      np.asarray(ups_a[c].masks))
        np.testing.assert_array_equal(np.asarray(ups_s[c].lams),
                                      np.asarray(ups_a[c].lams))
    # the ideal trace reports clean counters every round
    for row in h_async.fault_counts:
        assert row["sampled"] == row["admitted"] > 0
        assert row["dropped"] == row["stale"] == row["quarantined"] == 0


# -- fault suite --------------------------------------------------------------

def test_fault_suite_dropout_stragglers(monkeypatch):
    """30% dropout + 2x-latency stragglers, staleness cap 4: every
    round completes with a History row, admitted staleness never
    exceeds the cap, and final mean accuracy lands within 2 points of
    the fault-free async run."""
    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    con, split, bb = _setting()
    cfg = _cfg(rounds=12, eval_every=6, max_staleness=4)

    s0 = AsyncMaTUStrategy(con.n_tasks, bb.d)
    h0 = FedSimulator(cfg, con, split, bb, s0,
                      systems=ClientSystems.ideal(5)).run()

    fm = FaultModel(dropout=0.3, straggler_frac=0.5, straggler_delay=1,
                    seed=3)
    s1 = AsyncMaTUStrategy(con.n_tasks, bb.d)
    h1 = FedSimulator(cfg, con, split, bb, s1,
                      systems=ClientSystems(5, fm)).run()

    assert len(h1.fault_counts) == cfg.rounds        # all rounds complete
    tot = h1.total_fault_counts
    assert tot["dropped"] > 0 and tot["stragglers"] > 0
    assert tot["admitted"] > 0
    # the trace's max delay (1) never busts the staleness cap (4)
    assert tot["stale"] == 0
    assert len(h1.mean_acc) == len(h0.mean_acc)
    assert abs(h1.final_mean_acc - h0.final_mean_acc) <= 0.02


def test_corruption_quarantined_and_counted(monkeypatch):
    """Wire corruption under the validating decode: the exact set of
    tampered uploads is quarantined (100% detection, no false
    positives), counted in History, kept out of the merge, and still
    billed on the uplink."""
    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    con, split, bb = _setting()
    cfg = _cfg(rounds=6, eval_every=3)
    fm = FaultModel(corrupt_prob=0.4, seed=5)
    systems = ClientSystems(5, fm)
    strat = AsyncMaTUStrategy(con.n_tasks, bb.d, code_masks=True)
    hist = FedSimulator(cfg, con, split, bb, strat, systems=systems).run()

    # zero latency: every upload is dispatched the round it is admitted,
    # so the corrupt draws are replayable straight from the trace
    injected = [sum(1 for c in range(5) if systems.corrupt(c, r))
                for r in range(cfg.rounds)]
    assert sum(injected) > 0
    for r, row in enumerate(hist.fault_counts):
        assert row["quarantined"] == injected[r]
    assert hist.total_fault_counts["quarantined"] == sum(injected)
    # quarantined uploads still billed: coded streams travel framed
    assert all(b > 0 for b in hist.uplink_bits_per_round)


def test_corruption_requires_coded_wire(monkeypatch):
    """Raw packed words carry no redundancy — injecting wire corruption
    without code_masks=True is a configuration error, not silence."""
    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    con, split, bb = _setting()
    strat = AsyncMaTUStrategy(con.n_tasks, bb.d, code_masks=False)
    sim = FedSimulator(_cfg(rounds=1), con, split, bb, strat,
                       systems=ClientSystems(5, FaultModel(corrupt_prob=1.0)))
    with pytest.raises(ValueError, match="code_masks"):
        sim.run()


def test_empty_round_skip_and_carry(monkeypatch):
    """A round in which every sampled client drops out reaches the
    History as a 0-bit skipped row instead of a pack_uploads crash,
    and the server state carries: the next round still works and the
    carried eval vectors are unchanged through the gap."""
    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    con, split, bb = _setting()
    cfg = _cfg(rounds=3, eval_every=1)
    forced = {(c, 1) for c in range(5)}               # round 1: nobody uploads
    strat = AsyncMaTUStrategy(con.n_tasks, bb.d)
    sim = FedSimulator(cfg, con, split, bb, strat,
                       systems=ClientSystems(5, forced_dropouts=forced))
    hist = sim.run()
    assert [row["skipped"] for row in hist.fault_counts] == [0, 1, 0]
    assert hist.fault_counts[1]["admitted"] == 0
    assert hist.fault_counts[1]["dropped"] == 5
    assert len(hist.mean_acc) == 3                    # eval every round
    assert hist.uplink_bits_per_round[1] == 0         # the 0-bit row
    assert hist.uplink_bits_per_round[0] > 0
    assert hist.uplink_bits_per_round[2] > 0
    # skip-and-carry: the skipped round evaluates the carried vectors
    assert hist.task_acc[1] == hist.task_acc[0]


def test_sync_strategy_skip_round_carries(monkeypatch):
    """The plain sync MaTUStrategy also supports skip-and-carry (the
    satellite: no more pack_uploads ValueError on empty rounds)."""
    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    con, split, bb = _setting()
    strat = MaTUStrategy(con.n_tasks, bb.d)
    strat.aggregate([Upload(0, [0, 1],
                            jnp.ones((2, bb.d), jnp.float32), [4, 4])])
    before = np.asarray(strat.server.last_task_vectors)
    bits_before = strat.uplink_bits([])
    assert bits_before > 0
    strat.skip_round()
    np.testing.assert_array_equal(
        before, np.asarray(strat.server.last_task_vectors))
    assert strat.uplink_bits([]) == 0
    assert strat.downlink_bits() == 0


# -- staleness discount -------------------------------------------------------

def _toy_batch(d=64):
    rng = np.random.default_rng(0)
    ups = [Upload(c, [c % N_TASKS, (c + 1) % N_TASKS],
                  jnp.asarray(rng.normal(size=(2, d)), jnp.float32),
                  [4, 6]) for c in range(3)]
    return RoundBatch.from_uploads(ups, N_TASKS)


def test_staleness_discount_zero_is_exact_and_nonzero_bites(monkeypatch):
    """All-zero staleness reproduces the plain batch path bitwise (the
    w = 1 IEEE-exact multiply is never even traced); nonzero staleness
    down-weights the stale client and changes the merge."""
    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    d = 64
    outs = {}
    for tag, stale in (("batch", None), ("zeros", [0, 0, 0]),
                       ("stale", [0, 3, 1])):
        strat = AsyncMaTUStrategy(N_TASKS, d)
        if stale is None:
            strat.aggregate_batch(_toy_batch(d))
        else:
            strat.aggregate_admitted(_toy_batch(d), stale)
        outs[tag] = np.asarray(strat.server.last_task_vectors)
    np.testing.assert_array_equal(outs["batch"], outs["zeros"])
    assert (outs["zeros"] != outs["stale"]).any()


# -- dark-task carry ----------------------------------------------------------

def test_dark_task_age_and_decay(monkeypatch):
    """Tasks absent from a round age; ever-seen dark tasks decay toward
    the unified vector of the seen stack; reappearing resets the age
    and refreshes the carried vector bitwise from the round output."""
    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    d = 64
    rng = np.random.default_rng(7)
    strat = AsyncMaTUStrategy(N_TASKS, d, dark_decay=0.25)

    def batch(task_ids):
        up = Upload(0, list(task_ids),
                    jnp.asarray(rng.normal(size=(len(task_ids), d)),
                                jnp.float32),
                    [4] * len(task_ids))
        return RoundBatch.from_uploads([up], N_TASKS)

    strat.aggregate_batch(batch([0, 1]))
    assert strat.task_age[0] == strat.task_age[1] == 0
    assert strat.task_age[2] == 1                     # never seen: just ages
    v1_after_r1 = np.asarray(strat._task_vecs[1])

    strat.aggregate_batch(batch([0]))                 # task 1 goes dark
    assert strat.task_age[0] == 0 and strat.task_age[1] == 1
    v1_after_r2 = np.asarray(strat._task_vecs[1])
    assert (v1_after_r2 != v1_after_r1).any()         # decayed, not frozen
    # never-seen dark tasks do NOT decay (they have no carried signal)
    np.testing.assert_array_equal(np.asarray(strat._task_vecs[3]),
                                  np.zeros(d, np.float32))

    strat.skip_round()                                # empty round still ages
    assert strat.task_age[0] == 1 and strat.task_age[1] == 2

    strat.aggregate_batch(batch([1]))                 # task 1 reappears
    assert strat.task_age[1] == 0
    np.testing.assert_array_equal(
        np.asarray(strat._task_vecs[1]),
        np.asarray(strat.server.last_task_vectors[1]))
    # carried similarity is masked to ever-seen tasks and finite
    sim = strat.similarity
    assert sim.shape == (N_TASKS, N_TASKS) and np.isfinite(sim).all()
    assert sim[3].sum() == 0.0                        # never-seen row dark


# -- counters & phases (satellite: History coverage) --------------------------

def test_sync_mode_records_fault_counters(monkeypatch):
    """Sync runs carry the same counter schema: one row per round with
    sampled == admitted and zeros elsewhere, for ANY strategy."""
    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    con, split, bb = _setting()
    cfg = _cfg(rounds=3, participation=0.6)
    strat = STRATEGIES["fedavg"](con.n_tasks, bb.d)
    hist = FedSimulator(cfg, con, split, bb, strat).run()
    assert len(hist.fault_counts) == 3
    n_sel = max(1, int(round(0.6 * 5)))
    for row in hist.fault_counts:
        assert row["sampled"] == row["admitted"] == n_sel
        assert row["dropped"] == row["crashed"] == row["stale"] == 0
        assert row["quarantined"] == row["skipped"] == 0
    assert hist.total_fault_counts["admitted"] == 3 * n_sel


def test_mean_phase_us_one_behind_under_pipeline(monkeypatch):
    """Under pipeline=True the round's phases complete at its drain, so
    phase_us[0] is empty and mean_phase_us averages only the reported
    rounds — sync and async agree on the schema."""
    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    con, split, bb = _setting()
    cfg = _cfg(rounds=3, pipeline=True)
    for systems in (None, ClientSystems.ideal(5)):
        strat = (MaTUStrategy if systems is None
                 else AsyncMaTUStrategy)(con.n_tasks, bb.d)
        hist = FedSimulator(cfg, con, split, bb, strat,
                            systems=systems).run()
        assert hist.phase_us[0] == {}                 # one behind
        assert all("pack" in ph and "device" in ph
                   for ph in hist.phase_us[1:])
        mean = hist.mean_phase_us
        assert set(mean) >= {"pack", "device"}
        assert all(v > 0 for v in mean.values())
