"""Chunked-slot round tests (the engine's population-scale contract).

Contract under test (see "Population-scale contract" in
``repro.core.engine``):

* **bit parity** — in "ref" mode ``round_chunked`` is bit-identical to
  the monolithic ``round`` for every chunk size (1, a non-divisor of
  N, > N), BOTH slot layouts (packed wire + bool A/B), with and
  without staleness discounts — the carried scatter-folds replay the
  exact segment sums the monolithic round computes, and the λ combine
  tree is chunk-count-invariant.
* **streaming semantics** — uploads may be a zero-arg iterator
  factory (two engine passes, validated identical); a ``sink``
  receives per-chunk downlink dicts whose union equals the monolithic
  round's, and the returned dict stays empty (no per-client growth).
* **accounting** — uplink/downlink wire bits are invariant in the
  chunk size and equal the monolithic round's accounting.
* **slot sharding** — on 8 host devices the chunked round on the
  (4, 2) debug mesh and on the ("slots", "data") population mesh is
  bit-identical to the single-device monolithic round (subprocess,
  like tests/test_sharded_engine.py).
* **lazy population** — ``PopulationSplit`` derivations are
  order-invariant and seed-stable; ``PopulationSimulator`` honours
  ``FedConfig.eval_every`` (present since the seed, default 5) and is
  run-to-run deterministic.
* **coder pool** — the Golomb-Rice worker pool is byte-invisible:
  pooled encode/decode output is byte-identical to sequential, under
  tiny monkeypatched chunk sizes that force many independent chunks.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FIELDS = ("task_vectors", "tau_hats", "similarity", "m_hats")


def _run_sub(script: str, timeout: int = 600) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def _uploads(rng, n, n_tasks, d, k_max):
    import jax.numpy as jnp
    from repro.core.client import ClientUpload
    from repro.core.unify import unify_with_modulators
    from repro.fed.compression import quantize_bf16_transport

    ups = []
    for cid in range(n):
        k = int(rng.integers(1, k_max + 1))
        tasks = sorted(rng.choice(n_tasks, size=k, replace=False).tolist())
        tvs = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
        uni, masks, lams = unify_with_modulators(tvs)
        ups.append(ClientUpload(cid, tasks, quantize_bf16_transport(uni),
                                masks, lams,
                                rng.integers(10, 200, size=k).tolist()))
    return ups


def _assert_outputs_equal(out_a, out_b, ctx):
    for f in FIELDS:
        a, b = np.asarray(getattr(out_a, f)), np.asarray(getattr(out_b, f))
        assert a.shape == b.shape and np.array_equal(a, b), f"{ctx}: {f}"


def _assert_downlinks_equal(downs_a, downs_b, ctx):
    assert set(downs_a) == set(downs_b), ctx
    for cid, da in downs_a.items():
        db = downs_b[cid]
        for f in ("unified", "masks", "lams"):
            a, b = np.asarray(getattr(da, f)), np.asarray(getattr(db, f))
            assert a.dtype == b.dtype and np.array_equal(a, b), \
                f"{ctx}: client {cid} {f}"


@pytest.mark.parametrize("packed", [True, False], ids=["packed", "bool"])
@pytest.mark.parametrize("chunk", [1, 3, 64])
def test_chunked_bit_identical_ragged(packed, chunk):
    """C ∈ {1, non-divisor, > N} on an N=11 ragged round at d=1000
    (not a CHUNK_D multiple): outputs, downlinks, and wire accounting
    all match the monolithic round bit for bit."""
    from repro.core.engine import EngineConfig, RoundEngine, pack_uploads

    n, T, d = 11, 6, 1000
    ups = _uploads(np.random.default_rng(7), n, T, d, k_max=3)
    eng = RoundEngine(EngineConfig(n_tasks=T))
    downs_m, out_m = eng.round(ups, mode="ref", packed=packed)
    downs_c, out_c, stats = eng.round_chunked(
        ups, chunk_clients=chunk, mode="ref", packed=packed)

    ctx = f"C={chunk}/{'packed' if packed else 'bool'}"
    _assert_outputs_equal(out_m, out_c, ctx)
    _assert_downlinks_equal(downs_m, downs_c, ctx)
    assert stats["n_clients"] == n
    assert stats["n_chunks"] == -(-n // chunk)
    assert stats["uplink_bits"] == pack_uploads(
        ups, T, packed=packed).wire_bits(), ctx
    assert stats["downlink_bits"] == sum(
        dl.downlink_bits() for dl in downs_m.values()), ctx


@pytest.mark.parametrize("packed", [True, False], ids=["packed", "bool"])
def test_chunked_staleness_bit_identical(packed):
    """Per-upload staleness discounts (the async slot weights) survive
    chunking bit for bit — the discount weights are folded per chunk
    into the same carried accumulators."""
    from repro.core.engine import EngineConfig, RoundEngine

    n, T, d = 9, 5, 640
    ups = _uploads(np.random.default_rng(3), n, T, d, k_max=2)
    stal = [int(s) for s in np.random.default_rng(4).integers(0, 4, n)]
    eng = RoundEngine(EngineConfig(n_tasks=T))
    downs_m, out_m = eng.round(ups, mode="ref", packed=packed,
                               staleness=stal)
    downs_c, out_c, _ = eng.round_chunked(
        ups, chunk_clients=4, mode="ref", packed=packed, staleness=stal)
    _assert_outputs_equal(out_m, out_c, "staleness")
    _assert_downlinks_equal(downs_m, downs_c, "staleness")


def test_chunked_factory_and_sink_stream():
    """A zero-arg iterator factory is drawn exactly twice (metadata +
    merge passes); a sink receives per-chunk downlink dicts whose
    union matches the monolithic round, and the returned dict is empty
    — the no-per-client-growth contract the population path relies
    on."""
    from repro.core.engine import EngineConfig, RoundEngine

    n, T, d = 10, 4, 512
    ups = _uploads(np.random.default_rng(11), n, T, d, k_max=2)
    eng = RoundEngine(EngineConfig(n_tasks=T))
    downs_m, out_m = eng.round(ups, mode="ref")

    calls = {"n": 0}

    def factory():
        calls["n"] += 1
        return iter(ups)

    chunks = []
    downs_c, out_c, stats = eng.round_chunked(
        factory, chunk_clients=4, mode="ref", sink=chunks.append)
    assert calls["n"] == 2
    assert downs_c == {}
    assert len(chunks) == stats["n_chunks"] == 3
    union = {}
    for links in chunks:
        assert not (set(links) & set(union))
        union.update(links)
    _assert_outputs_equal(out_m, out_c, "sink")
    _assert_downlinks_equal(downs_m, union, "sink")
    # chunked EngineOutput carries no batched downlink planes
    assert out_c.down_unified is None and out_c.down_masks is None


def test_chunked_rejects_bad_streams():
    """chunk_clients < 1, an empty round, and a factory that returns a
    different round on the second pass are all hard errors — silent
    divergence between the two passes would corrupt the fold."""
    from repro.core.engine import EngineConfig, RoundEngine

    T, d = 4, 256
    ups = _uploads(np.random.default_rng(0), 6, T, d, k_max=2)
    eng = RoundEngine(EngineConfig(n_tasks=T))
    with pytest.raises(ValueError, match="chunk_clients"):
        eng.round_chunked(ups, chunk_clients=0, mode="ref")
    with pytest.raises(ValueError, match="empty round"):
        eng.round_chunked([], chunk_clients=4, mode="ref")

    flips = {"n": 0}

    def unstable():
        flips["n"] += 1
        order = ups if flips["n"] == 1 else list(reversed(ups))
        return iter(order)

    with pytest.raises(ValueError, match="different round"):
        eng.round_chunked(unstable, chunk_clients=4, mode="ref")


_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["REPRO_DISABLE_PALLAS"] = "1"
    import json
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.client import ClientUpload
    from repro.core.engine import EngineConfig, RoundEngine
    from repro.core.unify import unify_with_modulators
    from repro.fed.compression import quantize_bf16_transport
    from repro.launch.mesh import make_debug_mesh, make_population_mesh

    def uploads(rng, n, n_tasks, d, k_max):
        ups = []
        for cid in range(n):
            k = int(rng.integers(1, k_max + 1))
            tasks = sorted(rng.choice(n_tasks, size=k,
                                      replace=False).tolist())
            tvs = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
            uni, masks, lams = unify_with_modulators(tvs)
            ups.append(ClientUpload(cid, tasks, quantize_bf16_transport(uni),
                                    masks, lams,
                                    rng.integers(10, 200, size=k).tolist()))
        return ups

    FIELDS = ("task_vectors", "tau_hats", "similarity", "m_hats")
    meshes = {"debug4x2": make_debug_mesh((4, 2)),
              "pop_s2": make_population_mesh(slots=2)}
    report = {"devices": len(jax.devices())}
    # ragged N, d not divisible by devices*32, chunk a non-divisor
    n, T, d, chunk = 11, 6, 1000, 3
    ups = uploads(np.random.default_rng(5), n, T, d, 3)
    single = RoundEngine(EngineConfig(n_tasks=T))
    for mesh_name, mesh in meshes.items():
        shard = RoundEngine(EngineConfig(n_tasks=T), mesh=mesh)
        for packed in (True, False):
            downs_m, out_m = single.round(ups, packed=packed)
            downs_c, out_c, stats = shard.round_chunked(
                ups, chunk_clients=chunk, packed=packed)
            lay = "packed" if packed else "bool"
            for f in FIELDS:
                a = np.asarray(getattr(out_m, f))
                b = np.asarray(getattr(out_c, f))
                report[f"{mesh_name}/{lay}/{f}"] = bool(
                    a.shape == b.shape and np.array_equal(a, b))
            ok = set(downs_m) == set(downs_c)
            for cid in downs_m:
                for f in ("unified", "masks", "lams"):
                    a = np.asarray(getattr(downs_m[cid], f))
                    b = np.asarray(getattr(downs_c[cid], f))
                    ok = ok and a.dtype == b.dtype and np.array_equal(a, b)
            report[f"{mesh_name}/{lay}/downlinks"] = bool(ok)
            report[f"{mesh_name}/{lay}/bits"] = bool(
                stats["downlink_bits"] == sum(
                    dl.downlink_bits() for dl in downs_m.values()))
    print(json.dumps(report))
""")


def test_chunked_sharded_bit_identical_ref():
    """8-device chunked rounds — (4, 2) debug mesh and the
    ("slots", "data") population mesh — are bit-identical to the
    single-device monolithic round, packed and bool layouts."""
    report = _run_sub(_SHARDED)
    assert report.pop("devices") == 8
    bad = [k for k, v in report.items() if v is not True]
    assert not bad, f"sharded chunked round diverged on: {bad}"


def test_matu_strategy_chunked_bit_identical(monkeypatch):
    """``MaTUStrategy(chunk_clients=…)`` routes the server step through
    the chunked fold and stays bit-identical to the batched path in
    ref mode — same wire buffers, same results, same bit accounting."""
    import jax.numpy as jnp
    from repro.fed.strategies import MaTUStrategy, RoundBatch, Upload

    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)

    rng = np.random.default_rng(13)
    n, T, d = 7, 5, 384
    uploads = []
    for cid in range(n):
        k = int(rng.integers(1, 3))
        tasks = sorted(rng.choice(T, size=k, replace=False).tolist())
        uploads.append(Upload(cid, tasks,
                              jnp.asarray(rng.standard_normal((k, d)),
                                          jnp.float32),
                              rng.integers(10, 100, size=k).tolist()))

    mono = MaTUStrategy(T, d)
    chun = MaTUStrategy(T, d, chunk_clients=3)
    mono.aggregate_batch(RoundBatch.from_uploads(uploads, T))
    chun.aggregate_batch(RoundBatch.from_uploads(uploads, T))

    for t in range(T):
        a = np.asarray(mono.eval_vectors(t)[0])
        b = np.asarray(chun.eval_vectors(t)[0])
        assert np.array_equal(a, b), f"task {t}"
    mono._drain()
    _assert_downlinks_equal(mono.downlinks, chun.downlinks, "strategy")
    assert mono.uplink_bits(uploads) == chun.uplink_bits(uploads)


# -- lazy population ---------------------------------------------------------

def test_population_split_deterministic_and_lazy():
    """Per-client derivations are pure functions of (seed, id): query
    order never matters, same seed reproduces, different seeds differ,
    and round sampling is valid + round-varying without ever
    materialising the population."""
    from repro.data.dirichlet import PopulationSplit

    n = 100_000
    a = PopulationSplit(n_clients=n, n_tasks=8, seed=0)
    b = PopulationSplit(n_clients=n, n_tasks=8, seed=0)
    c = PopulationSplit(n_clients=n, n_tasks=8, seed=1)

    probe = [0, 1, 99_999, 12_345, 7]
    for cid in probe:                       # a queried in probe order
        assert a.tasks_for(cid) == b.tasks_for(cid)
        assert a.data_sizes_for(cid) == b.data_sizes_for(cid)
    for cid in reversed(probe):             # b re-queried reversed
        assert a.tasks_for(cid) == b.tasks_for(cid)
        ts = a.tasks_for(cid)
        assert ts == sorted(set(ts)) and all(0 <= t < 8 for t in ts)
    assert any(a.tasks_for(cid) != c.tasks_for(cid) for cid in probe)

    s0 = a.sample_round(0, 512)
    assert np.array_equal(s0, b.sample_round(0, 512))
    assert len(np.unique(s0)) == 512
    assert s0.min() >= 0 and s0.max() < n
    assert not np.array_equal(s0, a.sample_round(1, 512))
    # k·8 ≥ n exercises the permutation fallback
    tiny = PopulationSplit(n_clients=64, n_tasks=4, seed=0)
    full = tiny.sample_round(0, 64)
    assert sorted(full.tolist()) == list(range(64))


def test_population_fixed_tasks_per_client():
    from repro.data.dirichlet import PopulationSplit

    sp = PopulationSplit(n_clients=1000, n_tasks=8, tasks_per_client=2,
                         seed=3)
    for cid in (0, 17, 999):
        assert len(sp.tasks_for(cid)) == 2
        assert len(sp.data_sizes_for(cid)) == 2


def test_population_simulator_eval_every_and_determinism():
    """The population path honours ``FedConfig.eval_every`` (default 5,
    unchanged since the seed): rounds=6 evals at [5, 6]; fault/phase
    records land every round; two identical runs are bit-identical."""
    from repro.data.dirichlet import PopulationSplit
    from repro.fed.simulator import FedConfig, PopulationSimulator

    cfg = FedConfig(rounds=6, seed=0)       # eval_every default = 5
    split = PopulationSplit(n_clients=64, n_tasks=4, tasks_per_client=2,
                            seed=0)

    def run():
        sim = PopulationSimulator(cfg, split, d=256, clients_per_round=8,
                                  chunk_clients=4)
        return sim, sim.run()

    sim1, h1 = run()
    sim2, h2 = run()
    assert h1.rounds == [5, 6]
    assert len(h1.mean_acc) == 2
    assert len(h1.fault_counts) == len(h1.phase_us) == 6
    assert all(fc["sampled"] == 8 for fc in h1.fault_counts)
    assert h1.mean_acc == h2.mean_acc
    assert np.array_equal(sim1._tv_host, sim2._tv_host)
    # the synthetic drift is actually learning: alignment moves off
    # the 0.5 random-direction baseline
    assert h1.mean_acc[-1] > 0.55


# -- bench results handling --------------------------------------------------

def test_save_detail_merges_per_leg(monkeypatch, tmp_path):
    """Bench legs re-run separately must not clobber each other's rows:
    top-level keys merge, and shared grid keys merge per SUB-key (the
    dropped engine_sharded / pipelined-rows regression)."""
    import benchmarks.common as common

    monkeypatch.setattr(common, "RESULTS_DIR", str(tmp_path))
    common.save_detail("t", {"host_cores": 1,
                             "N32": {"us_packed": 1.0, "speedup": 2.0}})
    common.save_detail("t", {"N32": {"us_sharded": 3.0},
                             "N16": {"us_packed": 4.0}})
    with open(tmp_path / "t.json") as f:
        got = json.load(f)
    assert got == {"host_cores": 1,
                   "N32": {"us_packed": 1.0, "speedup": 2.0,
                           "us_sharded": 3.0},
                   "N16": {"us_packed": 4.0}}
    # corrupt file: start fresh instead of crashing
    (tmp_path / "t.json").write_text("{not json")
    common.save_detail("t", {"a": 1})
    with open(tmp_path / "t.json") as f:
        assert json.load(f) == {"a": 1}


# -- coder pool --------------------------------------------------------------

def test_coder_pool_byte_identical(monkeypatch):
    """The worker pool must be byte-invisible: with tiny chunk sizes
    forcing many independent encode chunks / decode windows, pooled
    output is byte-identical to the sequential fallback and the
    roundtrip is exact."""
    import repro.fed.compression as comp

    rng = np.random.default_rng(42)
    d = 4096
    w = -(-d // 32)
    rows = []
    for density in (0.01, 0.2, 0.7, 0.97):
        dense = rng.random((4, d)) < density
        rows.append(np.packbits(dense, axis=1, bitorder="little")
                    .view(np.uint32)[:, :w])
    words = np.ascontiguousarray(np.concatenate(rows))

    def roundtrip():
        comp._pool, comp._pool_workers = None, 0   # force pool rebuild
        stream, sizes = comp.encode_mask_rows_with_sizes(words, d)
        dec = comp.decode_mask_rows(stream, d, words.shape[0])
        return stream, sizes, dec

    monkeypatch.setattr(comp, "_ENC_CHUNK_BITS", 1 << 12)
    monkeypatch.setattr(comp, "_DEC_WINDOW_BYTES", 1 << 9)

    monkeypatch.setenv("REPRO_CODER_WORKERS", "1")
    s_seq, z_seq, d_seq = roundtrip()
    assert comp._coder_pool() is None               # sequential fallback

    monkeypatch.setenv("REPRO_CODER_WORKERS", "4")
    s_par, z_par, d_par = roundtrip()
    assert comp._coder_pool() is not None

    comp._pool, comp._pool_workers = None, 0        # drop the tiny pool
    assert np.array_equal(s_seq, s_par)
    assert np.array_equal(z_seq, z_par)
    assert np.array_equal(d_seq, d_par)
    assert np.array_equal(d_par, words)
