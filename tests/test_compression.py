"""Entropy-coded mask transport: the Golomb-Rice wire layer.

Round-trip bit-exactness over adversarial densities (all-zero,
all-one, single-bit, balanced, the benchmark's ~0.75 regime) and
d not divisible by 32; self-describing decode (only ``d`` + the byte
stream); measured-size guarantees (coded ≤ raw + header everywhere,
coded < raw on biased masks); and the coded layer threaded through
ClientUpload / pack_uploads / RoundEngine / MaTUStrategy.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.client import ClientUpload
from repro.core.engine import EngineConfig, RoundEngine, pack_uploads
from repro.core.unify import unify_with_modulators
import repro.fed.compression as compression
from repro.fed.compression import (HEADER_BYTES, coded_mask_bits,
                                   decode_mask_rows,
                                   decode_mask_rows_reference,
                                   encode_mask_rows,
                                   encode_mask_rows_reference,
                                   encode_mask_rows_with_sizes,
                                   golomb_encode_bits, mask_entropy_bits,
                                   rice_decode_words, rice_encode_words)
from repro.kernels import bitpack

jax.config.update("jax_platform_name", "cpu")


def _mask(rng, d, p):
    return rng.random(d) < p


# -- coder round-trip ---------------------------------------------------------

@pytest.mark.parametrize("d", [1, 31, 33, 100, 4097, 70001])
@pytest.mark.parametrize("p", [0.0, 1.0, "one_bit", 0.5, 0.75])
def test_roundtrip_adversarial_grid(d, p):
    """Bit-exact round-trip on the adversarial density grid; d is
    never a multiple of 32, so tail-word bits are always in play."""
    rng = np.random.default_rng(d)
    if p == "one_bit":
        mask = np.zeros(d, bool)
        mask[int(rng.integers(d))] = True
    else:
        mask = _mask(rng, d, p)
    words = bitpack.pack_bits_np(mask)
    stream = rice_encode_words(words, d)
    decoded, consumed = rice_decode_words(stream, d)
    assert consumed == stream.size          # self-delimiting record
    np.testing.assert_array_equal(decoded, words)
    # the stream never exceeds the raw packed words by more than the
    # self-describing header (the raw-escape guarantee)
    assert 8 * stream.size <= 8 * 4 * bitpack.packed_width(d) + 8 * HEADER_BYTES


def test_decode_needs_only_d_and_bytes():
    """The stream is self-describing: a decoder built from nothing but
    the raw bytes and d reproduces the words (no side channel for
    polarity / Rice parameter / count)."""
    rng = np.random.default_rng(0)
    d = 5000
    words = bitpack.pack_bits_np(_mask(rng, d, 0.8))
    raw_bytes = bytes(rice_encode_words(words, d))     # "the wire"
    decoded, _ = rice_decode_words(np.frombuffer(raw_bytes, np.uint8), d)
    np.testing.assert_array_equal(decoded, words)


def test_coded_beats_raw_on_biased_masks():
    """The whole point: biased modulator masks (the p≈0.75 own-task
    regime) go below 1 bit/coord, within ~5% of the entropy bound."""
    rng = np.random.default_rng(1)
    d = 1 << 18
    mask = _mask(rng, d, 0.75)
    bits = golomb_encode_bits(mask)
    assert bits < 8 * 4 * bitpack.packed_width(d)      # < raw packed
    assert bits < 1.05 * mask_entropy_bits(mask)       # near the bound


def test_balanced_mask_escapes_to_raw():
    """p = 0.5 is incompressible — the coder must escape to the raw
    payload rather than expand."""
    rng = np.random.default_rng(2)
    d = 1 << 16
    words = bitpack.pack_bits_np(_mask(rng, d, 0.5))
    stream = rice_encode_words(words, d)
    assert stream.size == HEADER_BYTES + 4 * bitpack.packed_width(d)
    decoded, _ = rice_decode_words(stream, d)
    np.testing.assert_array_equal(decoded, words)


def test_header_accounting_regression():
    """Regression for the pre-coder accounting bugs: the Golomb
    parameter is transmitted (header), so an all-ones mask costs a full
    decodable header — the old accountant charged it 1 bit."""
    bits = golomb_encode_bits(np.ones(64, bool))
    assert bits == 8 * HEADER_BYTES                    # not 1
    # and the delegation: golomb_encode_bits IS the measured stream
    rng = np.random.default_rng(3)
    mask = _mask(rng, 9999, 0.3)
    stream = rice_encode_words(bitpack.pack_bits_np(mask), mask.size)
    assert golomb_encode_bits(mask) == 8 * stream.size


def test_multirow_stream_roundtrip():
    """k self-delimiting row records walk back out with only (d, k)."""
    rng = np.random.default_rng(4)
    d, k = 777, 5
    rows = bitpack.pack_bits_np(
        np.stack([_mask(rng, d, p) for p in (0.0, 1.0, 0.2, 0.5, 0.9)]))
    stream = encode_mask_rows(rows, d)
    np.testing.assert_array_equal(decode_mask_rows(stream, d, k), rows)
    assert coded_mask_bits(rows, d) == 8 * stream.size


# -- batched coder ≡ scalar reference ----------------------------------------

def _adversarial_stack(rng, d):
    """A row stack hitting every coder regime at once: all-zero /
    all-one / single-bit / balanced escape / the biased benchmark
    densities / near-degenerate p — mixed densities also force the
    per-row (non-uniform) Rice-k path of the batched encoder."""
    rows = [np.zeros(d, bool), np.ones(d, bool)]
    one = np.zeros(d, bool)
    one[int(rng.integers(d))] = True
    rows.append(one)
    for p in (0.5, 0.75, 0.25, 0.03, 0.97, 0.0001, 0.9999):
        rows.append(_mask(rng, d, p))
    return bitpack.pack_bits_np(np.stack(rows))


@pytest.mark.parametrize("d", [1, 31, 33, 100, 4097, 70001])
def test_batched_byte_identical_to_scalar(d):
    """The tentpole contract: the batched encoder emits the EXACT bytes
    of the row-by-row scalar coder (so every PR 4 round-trip guarantee
    carries over), and the batched decoder inverts both."""
    rng = np.random.default_rng(d)
    words = _adversarial_stack(rng, d)
    stream = encode_mask_rows(words, d)
    ref = encode_mask_rows_reference(words, d)
    assert stream.tobytes() == ref.tobytes()
    k = words.shape[0]
    np.testing.assert_array_equal(decode_mask_rows(stream, d, k), words)
    np.testing.assert_array_equal(
        decode_mask_rows_reference(stream, d, k), words)
    # per-row sizes partition the stream exactly (the batched split
    # the engine's downlink / strategy's uplink paths rely on)
    s2, sizes = encode_mask_rows_with_sizes(words, d)
    assert s2.tobytes() == ref.tobytes()
    assert sizes.sum() == stream.size
    off = 0
    for i, z in enumerate(sizes):
        np.testing.assert_array_equal(
            decode_mask_rows(stream[off:off + int(z)], d, 1)[0], words[i])
        off += int(z)


def test_batched_chunking_is_invisible(monkeypatch):
    """Tiny chunk bounds force the encoder's multi-chunk loop and the
    decoder's windowed walk at test scale — records self-delimit and
    concatenate, so the bytes cannot change."""
    rng = np.random.default_rng(11)
    d = 257
    words = bitpack.pack_bits_np(
        np.stack([_mask(rng, d, rng.random()) for _ in range(50)]))
    ref = encode_mask_rows_reference(words, d)
    monkeypatch.setattr(compression, "_ENC_CHUNK_BITS", 512)
    monkeypatch.setattr(compression, "_DEC_WINDOW_BYTES", 64)
    monkeypatch.setattr(compression, "_DEC_DENSE_BITS", 1024)
    stream = encode_mask_rows(words, d)
    assert stream.tobytes() == ref.tobytes()
    np.testing.assert_array_equal(decode_mask_rows(stream, d, 50), words)


def test_batched_ragged_d_tail_words():
    """d just under / at / over word boundaries (ragged tails) through
    the batched path — tail bits of the last word stay zero on decode."""
    rng = np.random.default_rng(12)
    for d in (31, 32, 33, 63, 64, 65, 95):
        words = bitpack.pack_bits_np(
            np.stack([_mask(rng, d, p) for p in (0.1, 0.5, 0.9)]))
        stream = encode_mask_rows(words, d)
        assert stream.tobytes() == encode_mask_rows_reference(
            words, d).tobytes()
        np.testing.assert_array_equal(decode_mask_rows(stream, d, 3), words)


def test_batched_decode_rejects_corrupt_streams():
    """The batched decoder raises (never returns garbage) on the same
    corrupt inputs the scalar decoder rejects."""
    rng = np.random.default_rng(13)
    d = 1000
    words = bitpack.pack_bits_np(np.stack([_mask(rng, d, 0.75)
                                           for _ in range(3)]))
    stream = encode_mask_rows(words, d)
    with pytest.raises(ValueError):
        decode_mask_rows(stream[:-1], d, 3)          # truncated
    with pytest.raises(ValueError):
        decode_mask_rows(stream, d, 2)               # trailing bytes
    bad = stream.copy()
    bad[1:5] = np.array([255, 255, 255, 127], np.uint8)  # absurd run count
    with pytest.raises(ValueError):
        decode_mask_rows(bad, d, 3)


def test_coded_stream_error_typed():
    """Decode-side validation raises the typed CodedStreamError (the
    ValueError subclass the async server quarantines on) for the three
    adversarial classes: truncated header, run count pointing past the
    stream, and trailing garbage."""
    from repro.fed.compression import CodedStreamError
    assert issubclass(CodedStreamError, ValueError)
    rng = np.random.default_rng(5)
    d = 700
    words = bitpack.pack_bits_np(np.stack([_mask(rng, d, 0.8)
                                           for _ in range(2)]))
    stream = encode_mask_rows(words, d)
    with pytest.raises(CodedStreamError):
        decode_mask_rows(stream[:HEADER_BYTES - 2], d, 2)  # truncated header
    bad = stream.copy()
    bad[1:5] = np.array([255, 255, 255, 127], np.uint8)
    with pytest.raises(CodedStreamError):
        decode_mask_rows(bad, d, 2)                 # run count past stream
    garbage = np.concatenate([stream, np.array([7, 7, 7], np.uint8)])
    with pytest.raises(CodedStreamError):
        decode_mask_rows(garbage, d, 2)             # trailing garbage


def test_decode_fuzz_truncate_and_flip_round_trips_or_typed():
    """Round-trip fuzz: randomly truncating or bit-flipping a valid
    coded stream, decode either raises CodedStreamError or returns a
    (possibly different) valid mask — bit flips can alias, which is
    exactly why the async wire adds a CRC frame (repro.fed.systems) —
    but it must NEVER die with an untyped exception.  Unmodified
    streams keep round-tripping bit-exactly."""
    from repro.fed.compression import CodedStreamError
    rng = np.random.default_rng(99)
    d = 513
    typed = 0
    for _ in range(60):
        k = int(rng.integers(1, 4))
        words = bitpack.pack_bits_np(
            np.stack([_mask(rng, d, float(rng.choice([0.05, 0.5, 0.9])))
                      for _ in range(k)]))
        stream = encode_mask_rows(words, d)
        np.testing.assert_array_equal(decode_mask_rows(stream, d, k), words)
        bad = stream.copy()
        if rng.random() < 0.5 and stream.size > 1:
            bad = bad[:int(rng.integers(0, stream.size))]
        else:
            pos = int(rng.integers(stream.size * 8))
            bad[pos // 8] ^= np.uint8(1 << (pos % 8))
        try:
            decode_mask_rows(bad, d, k)
        except CodedStreamError:
            typed += 1
    assert typed > 0        # the typed rejection path was exercised


try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @hypothesis.given(st.integers(1, 3000), st.floats(0.0, 1.0),
                      st.integers(0, 2**31 - 1))
    @hypothesis.settings(max_examples=60, deadline=None)
    def test_roundtrip_property(d, p, seed):
        mask = np.random.default_rng(seed).random(d) < p
        words = bitpack.pack_bits_np(mask)
        stream = rice_encode_words(words, d)
        decoded, consumed = rice_decode_words(stream, d)
        assert consumed == stream.size
        np.testing.assert_array_equal(decoded, words)

    @hypothesis.given(st.integers(1, 2000), st.integers(1, 8),
                      st.integers(0, 2**31 - 1))
    @hypothesis.settings(max_examples=40, deadline=None)
    def test_batched_parity_property(d, k, seed):
        """Batched coder ≡ scalar reference on random (k, d) stacks of
        per-row random density."""
        rng = np.random.default_rng(seed)
        words = bitpack.pack_bits_np(
            np.stack([rng.random(d) < rng.random() for _ in range(k)]))
        stream = encode_mask_rows(words, d)
        assert stream.tobytes() == encode_mask_rows_reference(
            words, d).tobytes()
        np.testing.assert_array_equal(decode_mask_rows(stream, d, k), words)


# -- the coded layer through the stack ---------------------------------------

def _wire_round(rng, n_clients=4, n_tasks=5, d=1000):
    raw, coded = [], []
    for cid in range(n_clients):
        k = int(rng.integers(1, 4))
        tasks = sorted(rng.choice(n_tasks, size=k, replace=False).tolist())
        tvs = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
        unified, masks, lams = unify_with_modulators(tvs)
        words = bitpack.pack_bits_np(np.asarray(masks))
        sizes = [100] * k
        vec = unified.astype(jnp.bfloat16)
        raw.append(ClientUpload(cid, tasks, vec, jnp.asarray(words),
                                lams, sizes))
        coded.append(ClientUpload(cid, tasks, vec,
                                  jnp.asarray(encode_mask_rows(words, d)),
                                  lams, sizes))
    return raw, coded


def test_client_upload_coded_accounting_and_dense():
    rng = np.random.default_rng(5)
    raw, coded = _wire_round(rng)
    for u_raw, u_coded in zip(raw, coded):
        assert u_coded.coded and not u_coded.packed
        # measured off the actual stream: vector + stream + scalers
        k = len(u_coded.task_ids)
        d = int(u_coded.unified.shape[0])
        expect = 16 * d + 8 * int(u_coded.masks.size) + 32 * k
        assert u_coded.uplink_bits() == expect
        assert u_coded.uplink_bits() <= u_raw.uplink_bits() + 8 * HEADER_BYTES * k
        np.testing.assert_array_equal(np.asarray(u_coded.masks_dense()),
                                      np.asarray(u_raw.masks_dense()))


def test_pack_uploads_decodes_coded_at_host_edge():
    """Coded uploads pack into slot tensors byte-identical to their
    raw packed twins — the jitted round is untouched by the coder."""
    rng = np.random.default_rng(6)
    raw, coded = _wire_round(rng)
    b_raw = pack_uploads(raw, 5)
    b_coded = pack_uploads(coded, 5)
    np.testing.assert_array_equal(np.asarray(b_raw.slot_masks),
                                  np.asarray(b_coded.slot_masks))
    np.testing.assert_array_equal(np.asarray(b_raw.unified),
                                  np.asarray(b_coded.unified))


def test_engine_round_coded_downlink_parity():
    """code_masks=True ships uint8 downlink streams whose decoded rows
    match the raw packed downlink bit for bit, with measured bits no
    larger than raw + per-row headers."""
    rng = np.random.default_rng(7)
    raw, coded = _wire_round(rng)
    eng = RoundEngine(EngineConfig(n_tasks=5))
    downs_raw, out_raw = eng.round(raw)
    downs_coded, out_coded = eng.round(coded, code_masks=True)
    np.testing.assert_array_equal(np.asarray(out_raw.task_vectors),
                                  np.asarray(out_coded.task_vectors))
    for cid, dl_raw in downs_raw.items():
        dl = downs_coded[cid]
        assert dl.coded
        k = int(dl.lams.shape[0])
        np.testing.assert_array_equal(np.asarray(dl.masks_dense()),
                                      np.asarray(dl_raw.masks_dense()))
        # per-row access (what task_init consumes) matches too — coded
        # rows decode to the packed word layout, never dense bools
        np.testing.assert_array_equal(np.asarray(dl.mask_row(k - 1)),
                                      np.asarray(dl_raw.masks[k - 1]))
        assert dl.downlink_bits() <= (dl_raw.downlink_bits()
                                      + 8 * HEADER_BYTES * k)


def test_matu_strategy_coded_wire_parity_and_savings():
    """MaTUStrategy(code_masks=True): identical server results (the
    coded wire decodes to the same bytes the engine computes on), coded
    uplink measured ≤ raw packed uplink, coded downlink measured."""
    from repro.fed.strategies import MaTUStrategy, RoundBatch, Upload

    rng = np.random.default_rng(8)
    n_tasks, d = 5, 2048
    uploads = []
    for cid in range(6):
        k = int(rng.integers(2, 4))
        tasks = sorted(rng.choice(n_tasks, size=k, replace=False).tolist())
        tvs = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
        uploads.append(Upload(cid, tasks, tvs, [100] * k))

    res = {}
    for cm in (False, True):
        strat = MaTUStrategy(n_tasks, d, code_masks=cm)
        strat.aggregate_batch(RoundBatch.from_uploads(list(uploads), n_tasks))
        res[cm] = strat
    for t in range(n_tasks):
        np.testing.assert_array_equal(
            np.asarray(res[False].eval_vectors(t)[0]),
            np.asarray(res[True].eval_vectors(t)[0]))
    # same post-round client state through the coded downlink
    for u in uploads:
        np.testing.assert_array_equal(
            np.asarray(res[False].task_init(u.client_id, u.task_ids[0])),
            np.asarray(res[True].task_init(u.client_id, u.task_ids[0])))
    raw_up = res[False].uplink_bits(uploads)
    coded_up = res[True].uplink_bits(uploads)
    assert all(u.coded for u in res[True]._last_uploads)
    assert coded_up <= raw_up                     # measured savings
    assert 0 < res[True].downlink_bits() <= res[False].downlink_bits()
