"""Unit + property tests for task unification and modulators (Eq. 2, §3.2).

Hypothesis is optional: the property-based tests are skipped (not
errored at collection) in environments without it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.unify import (modulate, modulators, task_mask, task_scaler,
                              unify, unify_masked, unify_with_modulators,
                              unify_with_modulators_masked)

jax.config.update("jax_platform_name", "cpu")


def test_unify_hand_case():
    tvs = jnp.array([[1.0, -2.0, 0.5], [3.0, 1.0, -1.0]])
    np.testing.assert_allclose(unify(tvs), [3.0, -2.0, -1.0])


def test_unify_single_vector_is_identity():
    tv = jnp.array([[0.3, -0.7, 0.0, 2.0]])
    np.testing.assert_allclose(unify(tv), tv[0])


def test_modulators_hand_case():
    tvs = jnp.array([[1.0, -2.0, 0.5], [3.0, 1.0, -1.0]])
    tau, masks, lams = unify_with_modulators(tvs)
    np.testing.assert_array_equal(masks, [[True, True, False], [True, False, True]])
    np.testing.assert_allclose(lams, [3.5 / 5.0, 5.0 / 4.0])


if HAVE_HYPOTHESIS:
    @st.composite
    def tv_stack(draw):
        k = draw(st.integers(1, 6))
        d = draw(st.integers(1, 64))
        arr = draw(hnp.arrays(np.float32, (k, d),
                              elements=st.floats(-10, 10, width=32)))
        return jnp.asarray(arr)

    @hypothesis.given(tv_stack())
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_unify_sign_matches_sum(tvs):
        """σ = sgn(Σ τ): the unified vector never opposes the summed direction."""
        u = np.asarray(unify(tvs))
        total = np.asarray(jnp.sum(tvs, axis=0))
        assert np.all(u * total >= 0)

    @hypothesis.given(tv_stack())
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_unify_magnitude_bounded_by_max(tvs):
        """|τ_j| ≤ max_k |τ_kj| — election never amplifies."""
        u = np.abs(np.asarray(unify(tvs)))
        mx = np.max(np.abs(np.asarray(tvs)), axis=0)
        assert np.all(u <= mx + 1e-6)

    @hypothesis.given(tv_stack())
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_scalers_nonnegative(tvs):
        tau, masks, lams = unify_with_modulators(tvs)
        assert np.all(np.asarray(lams) >= 0)

    @hypothesis.given(tv_stack())
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_mask_alignment(tvs):
        """Masked unified entries always share the task vector's sign."""
        tau, masks, lams = unify_with_modulators(tvs)
        recon_signs = np.sign(np.asarray(tau))[None] * np.asarray(masks)
        tv_signs = np.sign(np.asarray(tvs))
        agree = (recon_signs == 0) | (recon_signs == tv_signs)
        assert np.all(agree)


def test_unify_masked_equals_subset():
    """unify_masked(x, v) == unify(x[v]) — padding rows are inert."""
    rng = np.random.default_rng(7)
    tvs = jnp.asarray(rng.standard_normal((5, 96)), jnp.float32)
    valid = jnp.asarray([True, False, True, True, False])
    np.testing.assert_allclose(unify_masked(tvs, valid),
                               unify(tvs[np.asarray(valid)]),
                               rtol=1e-6, atol=1e-7)


def test_unify_with_modulators_masked_matches_ragged():
    """The padding-aware variant matches the ragged reference row-for-row
    and zeroes the modulators of invalid slots."""
    rng = np.random.default_rng(8)
    tvs = jnp.asarray(rng.standard_normal((4, 128)), jnp.float32)
    valid = jnp.asarray([True, True, False, True])
    sel = np.asarray(valid)
    tau_m, masks_m, lams_m = unify_with_modulators_masked(tvs, valid)
    tau_r, masks_r, lams_r = unify_with_modulators(tvs[sel])
    np.testing.assert_allclose(tau_m, tau_r, rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(masks_m)[sel], np.asarray(masks_r))
    np.testing.assert_allclose(np.asarray(lams_m)[sel], lams_r, rtol=1e-5)
    assert not np.any(np.asarray(masks_m)[~sel])
    np.testing.assert_allclose(np.asarray(lams_m)[~sel], 0.0)


def test_identical_tasks_reconstruct_exactly():
    """K copies of the same vector: unify + modulate is lossless."""
    tv = jnp.asarray(np.random.default_rng(0).standard_normal(128), jnp.float32)
    stack = jnp.stack([tv, tv, tv])
    tau, masks, lams = unify_with_modulators(stack)
    recon = modulate(tau, masks[0], lams[0])
    np.testing.assert_allclose(recon, tv, rtol=1e-5, atol=1e-6)


def test_modulate_scaling_preserves_l1():
    """λ restores the task vector's L1 mass on the masked support."""
    rng = np.random.default_rng(1)
    tvs = jnp.asarray(rng.standard_normal((3, 256)), jnp.float32)
    tau, masks, lams = unify_with_modulators(tvs)
    for i in range(3):
        recon = modulate(tau, masks[i], lams[i])
        np.testing.assert_allclose(jnp.sum(jnp.abs(recon)),
                                   jnp.sum(jnp.abs(tvs[i])), rtol=1e-4)
