"""Tests for the beyond-paper extensions: uplink compression and the
generation utility."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, load_arch
from repro.fed.compression import (compressed_uplink_bits, golomb_encode_bits,
                                   mask_entropy_bits, quantize_bf16)
from repro.serve.generate import GenerationConfig, generate

jax.config.update("jax_platform_name", "cpu")


# -- compression ---------------------------------------------------------------

def test_entropy_bound_below_dense():
    rng = np.random.default_rng(0)
    for p in (0.1, 0.25, 0.75, 0.9):
        mask = rng.random(10_000) < p
        assert mask_entropy_bits(mask) < mask.size  # beats 1 bit/entry


def test_golomb_bits_near_entropy_for_sparse():
    rng = np.random.default_rng(1)
    mask = rng.random(50_000) < 0.1
    golomb = golomb_encode_bits(mask)
    bound = mask_entropy_bits(mask)
    assert golomb < mask.size            # beats dense
    assert golomb < 1.6 * bound          # within ~60% of the bound


def test_golomb_handles_dense_by_polarity_flip():
    rng = np.random.default_rng(2)
    mask = rng.random(20_000) < 0.92     # dense ones
    assert golomb_encode_bits(mask) < mask.size


def test_bf16_transport_preserves_direction():
    v = jax.random.normal(jax.random.PRNGKey(0), (20_000,))
    q, cos = quantize_bf16(v)
    assert cos > 0.999
    # signs are what MaTU's aggregation consumes — must be preserved
    # wherever the magnitude is representable
    big = jnp.abs(v) > 1e-3
    assert bool(jnp.all(jnp.sign(q)[big] == jnp.sign(v)[big]))


def test_compressed_uplink_beats_paper_scheme():
    """The paper's uplink is 32d + k(d+32); compression must beat it for
    biased masks."""
    rng = np.random.default_rng(3)
    d, k = 8_192, 4
    unified = jnp.asarray(rng.standard_normal(d), jnp.float32)
    masks = jnp.asarray(rng.random((k, d)) < 0.78)  # typical own-task density
    paper_bits = 32 * d + k * (d + 32)
    comp_bits = compressed_uplink_bits(unified, masks)
    assert comp_bits < paper_bits
    assert comp_bits < 0.75 * paper_bits  # ≥25% saving


# -- generation ------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen2-0.5b", "xlstm-1.3b"])
def test_generate_greedy_matches_manual(arch):
    cfg = load_arch(arch).reduced()
    model = cfg.build(SHAPES["decode_32k"])
    params = model.init(jax.random.PRNGKey(0))
    lora = model.lora_init(jax.random.PRNGKey(1))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 1, cfg.vocab)

    out = generate(model, params, lora, prompt,
                   GenerationConfig(max_new_tokens=4, temperature=0.0))
    assert out.shape == (2, 10)

    # manual greedy reference via full forward
    ref = list(np.asarray(prompt[0]))
    for _ in range(4):
        full, _ = model.model.forward(params, jnp.asarray([ref], jnp.int32),
                                      lora=lora)
        ref.append(int(jnp.argmax(full[0, -1])))
    assert list(np.asarray(out[0])) == ref


def test_generate_sampling_respects_top_k():
    cfg = load_arch("qwen2-0.5b").reduced()
    model = cfg.build(SHAPES["decode_32k"])
    params = model.init(jax.random.PRNGKey(0))
    lora = model.lora_init(jax.random.PRNGKey(1))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 5), 1, cfg.vocab)
    out1 = generate(model, params, lora, prompt,
                    GenerationConfig(max_new_tokens=6, temperature=1.0, top_k=5),
                    rng=jax.random.PRNGKey(3))
    out2 = generate(model, params, lora, prompt,
                    GenerationConfig(max_new_tokens=6, temperature=1.0, top_k=5),
                    rng=jax.random.PRNGKey(3))
    np.testing.assert_array_equal(out1, out2)  # deterministic given rng
    assert out1.shape == (1, 11)
    assert int(out1.min()) >= 0 and int(out1.max()) < cfg.vocab


def test_compressed_matu_strategy_accuracy_parity():
    """Since the wire-format refactor every MaTU round ships bf16
    vectors + bit-packed masks; ``compress=True`` only swaps the mask
    accounting for the entropy-coded bound.  Accuracy must be identical
    (same wire either way), the measured wire must beat the paper's
    fp32+dense-bit scheme by ≥1.5x, and the entropy-coded accounting
    can only improve on the raw packed wire."""
    from repro.data.dirichlet import dirichlet_split
    from repro.data.synthetic import make_constellation
    from repro.fed.simulator import FedConfig, FedSimulator
    from repro.fed.strategies import FLOAT_BITS, MaTUStrategy
    from repro.fed.testbed import MLPBackbone

    con = make_constellation(n_tasks=4, n_groups=2, feat_dim=24, n_classes=6,
                             seed=0)
    split = dirichlet_split(n_clients=6, n_tasks=4, n_classes=6, zeta_t=0.5,
                            tasks_per_client=2, seed=0)
    bb = MLPBackbone(24, hidden=48, lora_rank=6)
    cfg = FedConfig(rounds=6, local_steps=15, lr=1e-2, eval_every=6, seed=0)
    res = {}
    for comp in (False, True):
        strat = MaTUStrategy(4, bb.d, compress=comp)
        h = FedSimulator(cfg, con, split, bb, strat).run()
        res[comp] = (h.final_mean_acc, h.mean_uplink_bits,
                     h.downlink_bits_per_round[-1])
    assert res[True][0] == res[False][0]               # identical wire
    # paper scheme for the same round shape: 32d + k(d+32) per client
    paper = (FLOAT_BITS * bb.d + 2 * (bb.d + FLOAT_BITS)) * 6
    assert res[False][1] < paper / 1.5                 # measured wire wins
    assert res[True][1] <= res[False][1]               # entropy ≤ raw packed
    assert res[False][2] > 0                           # measured downlink
