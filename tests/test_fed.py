"""Federated integration tests: the paper's ordinal claims on the
synthetic constellation (DESIGN.md §3, EXPERIMENTS.md §Claims).

These are the behaviour-level guarantees of the reproduction:
  * MaTU trains (improves over round 0) and beats FedAvg under task
    heterogeneity with conflicts,
  * the sign-conflict similarity (Eq. 5) recovers the ground-truth
    group structure (Fig. 2–3 claim),
  * MaTU's uplink is O(1) adapters per client vs O(k) for baselines
    (Fig. 5a claim).
"""

import jax
import numpy as np
import pytest

from repro.data.dirichlet import dirichlet_split
from repro.data.synthetic import make_constellation
from repro.fed.simulator import FedConfig, FedSimulator
from repro.fed.strategies import (FedAvgStrategy, MaTUStrategy,
                                  NTKFedAvgStrategy)
from repro.fed.testbed import MLPBackbone

jax.config.update("jax_platform_name", "cpu")

N_TASKS = 6


@pytest.fixture(scope="module")
def setting():
    con = make_constellation(n_tasks=N_TASKS, n_groups=3, feat_dim=24,
                             n_classes=6, conflict_pairs=[(0, 1)], seed=0)
    split = dirichlet_split(n_clients=9, n_tasks=N_TASKS, n_classes=6,
                            zeta_t=0.0, seed=0)
    bb = MLPBackbone(24, hidden=48, lora_rank=6)
    cfg = FedConfig(rounds=12, local_steps=25, lr=1e-2, eval_every=6, seed=0)
    return con, split, bb, cfg


def _run(setting, strategy_cls, **kw):
    con, split, bb, cfg = setting
    strat = strategy_cls(N_TASKS, bb.d, **kw)
    sim = FedSimulator(cfg, con, split, bb, strat)
    return sim.run(), strat


def test_matu_learns_and_beats_fedavg(setting):
    h_matu, strat = _run(setting, MaTUStrategy)
    h_avg, _ = _run(setting, FedAvgStrategy)
    assert h_matu.final_mean_acc > 1.5 / N_TASKS  # far above chance
    assert h_matu.mean_acc[-1] >= h_matu.mean_acc[0] - 0.05  # no collapse
    assert h_matu.final_mean_acc > h_avg.final_mean_acc - 0.02


def test_sign_similarity_recovers_groups(setting):
    con, split, bb, cfg = setting
    _h, strat = _run(setting, MaTUStrategy)
    sim = np.asarray(strat.server.last_similarity)
    same, diff = [], []
    for a in range(N_TASKS):
        for b in range(a + 1, N_TASKS):
            (same if con.group_of(a) == con.group_of(b) else diff).append(sim[a, b])
    assert np.mean(same) > np.mean(diff), (np.mean(same), np.mean(diff))


def test_sign_similarity_correlates_with_oracle(setting):
    """Pearson correlation between Eq. 5 similarity and the ground-truth
    relatedness matrix (the Fig. 3 claim, ordinal form).  The full
    benchmark (30 rounds, benchmarks/bench_similarity) measures
    r = 0.88; at this CI scale (12 rounds) we require positive
    correlation with margin."""
    # the 6-task fixture is too small for a stable Pearson estimate
    # (15 pairs); use the benchmark's 8-task setting at reduced rounds
    # (measured r = 0.86-0.93 for rounds 15-30).
    del setting
    from repro.data.dirichlet import dirichlet_split
    from repro.data.synthetic import make_constellation
    from repro.fed.simulator import FedConfig, FedSimulator
    from repro.fed.testbed import MLPBackbone
    n = 8
    con = make_constellation(n_tasks=n, n_groups=3, feat_dim=32, n_classes=8,
                             conflict_pairs=[(0, 1)], seed=0)
    split = dirichlet_split(n_clients=16, n_tasks=n, n_classes=8,
                            zeta_t=0.0, seed=0)
    bb = MLPBackbone(32, hidden=64, lora_rank=8)
    cfg = FedConfig(rounds=15, local_steps=30, lr=1e-2, eval_every=15, seed=0)
    strat = MaTUStrategy(n, bb.d)
    FedSimulator(cfg, con, split, bb, strat).run()
    sim = np.asarray(strat.server.last_similarity)
    oracle = con.oracle_similarity()
    iu = np.triu_indices(n, k=1)
    r = np.corrcoef(sim[iu], oracle[iu])[0, 1]
    assert r > 0.5, f"sign-sim/oracle correlation too weak: {r:.3f}"


def test_comm_o1_vs_ok(setting):
    """MaTU uplink stays ~flat as tasks/client grows; FedAvg grows ~k."""
    con, _split, bb, cfg = setting
    from repro.data.dirichlet import dirichlet_split as ds
    bits = {}
    for k in (1, 3):
        split = ds(n_clients=6, n_tasks=N_TASKS, n_classes=6, zeta_t=0.5,
                   tasks_per_client=k, seed=1)
        for cls in (MaTUStrategy, FedAvgStrategy):
            strat = cls(N_TASKS, bb.d)
            sim = FedSimulator(FedConfig(rounds=2, local_steps=2, eval_every=2),
                               con, split, bb, strat)
            h = sim.run()
            bits[(cls.name, k)] = h.mean_uplink_bits
    growth_matu = bits[("matu", 3)] / bits[("matu", 1)]
    growth_avg = bits[("fedavg", 3)] / bits[("fedavg", 1)]
    assert growth_matu < growth_avg
    assert bits[("matu", 3)] < bits[("fedavg", 3)]


def test_history_mean_downlink_bits(setting):
    """History.mean_downlink_bits mirrors mean_uplink_bits: 0.0 on an
    empty history, the mean of the measured per-round downlink wire
    bits once MaTU has run (its downlink tensors are measured, so the
    mean must be positive and match the raw column)."""
    from repro.fed.simulator import History

    assert History().mean_downlink_bits == 0.0
    con, _split, bb, cfg = setting
    from repro.data.dirichlet import dirichlet_split as ds
    split = ds(n_clients=5, n_tasks=N_TASKS, n_classes=6, zeta_t=0.5,
               tasks_per_client=2, seed=2)
    sim = FedSimulator(FedConfig(rounds=2, local_steps=2, eval_every=1),
                       con, split, bb, MaTUStrategy(N_TASKS, bb.d))
    h = sim.run()
    assert h.downlink_bits_per_round and all(
        b > 0 for b in h.downlink_bits_per_round)
    assert h.mean_downlink_bits == pytest.approx(
        float(np.mean(h.downlink_bits_per_round)))


def test_ntk_linearized_trainer_runs(setting):
    h, _ = _run(setting, NTKFedAvgStrategy)
    assert h.final_mean_acc > 1.0 / N_TASKS  # learns something
