"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
plus hypothesis property checks.  Kernels run in interpret mode on CPU —
bit-identical semantics to the TPU lowering path."""

import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.masked_agg import masked_agg_pallas
from repro.kernels.sign_sim import sign_sim_pallas
from repro.kernels.unify import unify_pallas

jax.config.update("jax_platform_name", "cpu")

SHAPES_KD = [(1, 7), (2, 100), (3, 2048), (8, 5000), (16, 7777), (5, 4096)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("k,d", SHAPES_KD)
@pytest.mark.parametrize("dtype", DTYPES)
def test_unify_sweep(k, d, dtype):
    key = jax.random.PRNGKey(k * 1000 + d)
    tv = jax.random.normal(key, (k, d)).astype(dtype)
    got = unify_pallas(tv, interpret=True)
    want = ref.unify_ref(tv)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n,d", [(2, 64), (4, 333), (10, 4096), (30, 9999)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_masked_agg_sweep(n, d, dtype):
    key = jax.random.PRNGKey(n * 7 + d)
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.normal(k1, (n, d)).astype(dtype)
    m = (jax.random.uniform(k2, (n, d)) > 0.5).astype(dtype)
    lam = (jax.random.uniform(k3, (n,)) + 0.5).astype(jnp.float32)
    n_mem = max(1, n // 2)
    gam = jnp.where(jnp.arange(n) < n_mem, 1.0 / n_mem, 0.0)
    t1, m1 = masked_agg_pallas(u, m, lam, gam, rho=0.4, interpret=True)
    t2, m2 = ref.masked_agg_ref(u, m, lam, gam, 0.4)
    np.testing.assert_allclose(t1, t2, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(m1, m2, rtol=1e-6)


@pytest.mark.parametrize("t,d", [(2, 50), (8, 4096), (16, 2048), (30, 10000)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_sign_sim_sweep(t, d, dtype):
    key = jax.random.PRNGKey(t + d)
    x = jax.random.normal(key, (t, d)).astype(dtype)
    got = sign_sim_pallas(x, interpret=True)
    want = ref.sign_sim_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@hypothesis.given(
    hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                            min_side=1, max_side=40),
               elements=st.floats(-100, 100, width=32)))
@hypothesis.settings(max_examples=30, deadline=None)
def test_unify_property_matches_ref(arr):
    tv = jnp.asarray(arr)
    np.testing.assert_allclose(unify_pallas(tv, interpret=True),
                               ref.unify_ref(tv), rtol=1e-5, atol=1e-5)


def test_sign_sim_padding_invariance():
    """d-padding must not change S (sgn(0)=0 contributes nothing)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 1000))
    s1 = sign_sim_pallas(x, block_d=512, interpret=True)
    s2 = sign_sim_pallas(x, block_d=2048, interpret=True)  # heavy padding
    np.testing.assert_allclose(s1, s2, rtol=1e-6)


def test_kernels_match_core_semantics():
    """Kernel outputs agree with repro.core (the algorithm actually used)."""
    from repro.core.aggregation import sign_similarity, task_aggregate
    from repro.core.unify import unify

    key = jax.random.PRNGKey(3)
    tv = jax.random.normal(key, (4, 3000))
    np.testing.assert_allclose(unify_pallas(tv, interpret=True), unify(tv),
                               rtol=1e-5, atol=1e-6)

    u = jax.random.normal(key, (6, 3000))
    m = jax.random.uniform(jax.random.PRNGKey(4), (6, 3000)) > 0.5
    lam = jax.random.uniform(jax.random.PRNGKey(5), (6,)) + 0.5
    member = jnp.arange(6) < 4
    sizes = jnp.where(member, 25.0, 0.0)
    tau_core, m_core = task_aggregate(u, m, lam, member, sizes, 0.4)
    gam = jnp.where(member, 0.25, 0.0)
    tau_k, m_k = masked_agg_pallas(u, m.astype(u.dtype), lam, gam,
                                   rho=0.4, interpret=True)
    np.testing.assert_allclose(tau_k, tau_core, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(m_k, m_core, rtol=1e-6)

    np.testing.assert_allclose(sign_sim_pallas(tv, interpret=True),
                               sign_similarity(tv), rtol=1e-5)
