"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
plus hypothesis property checks.  Kernels run in interpret mode on CPU —
bit-identical semantics to the TPU lowering path.

Hypothesis is optional: property checks are skipped (not errored at
collection) in environments without it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels import bitpack, ref
from repro.kernels.fused_unify import (fused_unify_packed_pallas,
                                       fused_unify_pallas)
from repro.kernels.masked_agg import (masked_agg_batched_pallas,
                                      masked_agg_pallas)
from repro.kernels.sign_sim import sign_sim_packed_pallas, sign_sim_pallas
from repro.kernels.unify import unify_pallas

jax.config.update("jax_platform_name", "cpu")

SHAPES_KD = [(1, 7), (2, 100), (3, 2048), (8, 5000), (16, 7777), (5, 4096)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("k,d", SHAPES_KD)
@pytest.mark.parametrize("dtype", DTYPES)
def test_unify_sweep(k, d, dtype):
    key = jax.random.PRNGKey(k * 1000 + d)
    tv = jax.random.normal(key, (k, d)).astype(dtype)
    got = unify_pallas(tv, interpret=True)
    want = ref.unify_ref(tv)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n,d", [(2, 64), (4, 333), (10, 4096), (30, 9999)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_masked_agg_sweep(n, d, dtype):
    key = jax.random.PRNGKey(n * 7 + d)
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.normal(k1, (n, d)).astype(dtype)
    m = (jax.random.uniform(k2, (n, d)) > 0.5).astype(dtype)
    lam = (jax.random.uniform(k3, (n,)) + 0.5).astype(jnp.float32)
    n_mem = max(1, n // 2)
    gam = jnp.where(jnp.arange(n) < n_mem, 1.0 / n_mem, 0.0)
    t1, m1 = masked_agg_pallas(u, m, lam, gam, rho=0.4, interpret=True)
    t2, m2 = ref.masked_agg_ref(u, m, lam, gam, 0.4)
    np.testing.assert_allclose(t1, t2, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(m1, m2, rtol=1e-6)


@pytest.mark.parametrize("t,d", [(2, 50), (8, 4096), (16, 2048), (30, 10000)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_sign_sim_sweep(t, d, dtype):
    key = jax.random.PRNGKey(t + d)
    x = jax.random.normal(key, (t, d)).astype(dtype)
    got = sign_sim_pallas(x, interpret=True)
    want = ref.sign_sim_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


if HAVE_HYPOTHESIS:
    @hypothesis.given(
        hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                                min_side=1, max_side=40),
                   elements=st.floats(-100, 100, width=32)))
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_unify_property_matches_ref(arr):
        tv = jnp.asarray(arr)
        np.testing.assert_allclose(unify_pallas(tv, interpret=True),
                                   ref.unify_ref(tv), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,t,d", [(3, 2, 100), (5, 4, 2048), (8, 6, 3333)])
def test_masked_agg_batched_sweep(n, t, d):
    """Whole-round kernel vs its oracle and vs T single-task launches."""
    key = jax.random.PRNGKey(n * 13 + t * 7 + d)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    u = jax.random.normal(k1, (n, d), jnp.float32)
    member = jax.random.uniform(k2, (n, t)) > 0.4
    m = ((jax.random.uniform(k3, (n, t, d)) > 0.5)
         & member[:, :, None]).astype(jnp.float32)
    lam = jax.random.uniform(k4, (n, t)) + 0.5
    sizes = jnp.where(member, 50.0, 0.0)
    gam = sizes / jnp.maximum(jnp.sum(sizes, 0, keepdims=True), 1e-12)

    tau_k, mh_k = masked_agg_batched_pallas(u, m, lam, gam, member,
                                            rho=0.4, interpret=True)
    tau_r, mh_r = ref.masked_agg_batched_ref(u, m, lam, gam, member, 0.4)
    np.testing.assert_allclose(tau_k, tau_r, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(mh_k, mh_r, rtol=1e-6)
    for ti in range(t):
        tau_1, mh_1 = masked_agg_pallas(u, m[:, ti], lam[:, ti], gam[:, ti],
                                        rho=0.4, interpret=True)
        np.testing.assert_allclose(tau_k[ti], tau_1, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(mh_k[ti], mh_1, rtol=1e-6)


@pytest.mark.parametrize("b,k,d", [(2, 1, 64), (4, 3, 2048), (6, 4, 5000)])
def test_fused_unify_sweep(b, k, d):
    """Fused unify+mask+λ kernel vs oracle, with ragged validity."""
    key = jax.random.PRNGKey(b * 31 + k * 17 + d)
    k1, k2 = jax.random.split(key)
    valid = jax.random.uniform(k1, (b, k)) > 0.3
    valid = valid.at[:, 0].set(True)            # every client holds ≥ 1 task
    tvs = jax.random.normal(k2, (b, k, d), jnp.float32)
    tvs = jnp.where(valid[:, :, None], tvs, 0.0)

    u_k, m_k, num_k, den_k = fused_unify_pallas(tvs, valid, interpret=True)
    u_r, m_r, num_r, den_r = ref.fused_unify_ref(tvs, valid)
    np.testing.assert_allclose(u_k, u_r, rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(m_k > 0.5), np.asarray(m_r))
    np.testing.assert_allclose(num_k, num_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(den_k, den_r, rtol=1e-5, atol=1e-6)


# -- packed (wire-format) kernels ------------------------------------------

@pytest.mark.parametrize("n,t,d", [(3, 2, 100), (5, 4, 4096), (8, 6, 3333)])
def test_masked_agg_batched_packed_matches_bool(n, t, d):
    """Packed-mask kernel ≡ bool kernel: same τ̂, and m̂ re-derived from
    the emitted agreement numerator matches bit for bit."""
    key = jax.random.PRNGKey(n * 13 + t * 7 + d)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    u = jax.random.normal(k1, (n, d), jnp.float32)
    member = jax.random.uniform(k2, (n, t)) > 0.4
    m = ((jax.random.uniform(k3, (n, t, d)) > 0.5)
         & member[:, :, None])
    lam = jax.random.uniform(k4, (n, t)) + 0.5
    sizes = jnp.where(member, 50.0, 0.0)
    gam = sizes / jnp.maximum(jnp.sum(sizes, 0, keepdims=True), 1e-12)

    from repro.kernels import ops

    words = bitpack.pack_bits(m)
    # both dispatch modes of the packed op, through the ops contract
    tau_p, anum = ops.masked_agg_batched_packed(
        u.astype(jnp.bfloat16), words, lam, gam, member, d, rho=0.4,
        mode="pallas_interpret")
    tau_r, anum_r = ops.masked_agg_batched_packed(
        u.astype(jnp.bfloat16), words, lam, gam, member, d, rho=0.4,
        mode="ref")
    np.testing.assert_allclose(tau_p, tau_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(anum), np.asarray(anum_r))
    # the bool comparator consumes the identical bf16-quantised values
    tau_b, mh_b = masked_agg_batched_pallas(
        u.astype(jnp.bfloat16).astype(jnp.float32), m.astype(jnp.float32),
        lam, gam, member, rho=0.4, interpret=True)
    np.testing.assert_allclose(tau_p, tau_b, rtol=1e-5, atol=1e-6)
    n_t = jnp.maximum(jnp.sum(member.astype(jnp.float32), 0), 1.0)
    alpha = anum / n_t[:, None]
    mh_p = jnp.where(alpha >= 0.4, 1.0, alpha)
    np.testing.assert_array_equal(np.asarray(mh_p), np.asarray(mh_b))
    # the numerator is an exact integer ≤ N_t
    a = np.asarray(anum)
    np.testing.assert_array_equal(a, np.round(a))
    assert (a <= np.asarray(n_t)[:, None]).all()


@pytest.mark.parametrize("b,k,d", [(2, 1, 64), (4, 3, 2048), (6, 4, 5000)])
def test_fused_unify_packed_matches_bool(b, k, d):
    """Packed fused unify emits exactly pack(bool masks) and
    bf16(fp32 unified) of the bool kernel, with identical num/den."""
    key = jax.random.PRNGKey(b * 31 + k * 17 + d)
    k1, k2 = jax.random.split(key)
    valid = jax.random.uniform(k1, (b, k)) > 0.3
    valid = valid.at[:, 0].set(True)
    tvs = jax.random.normal(k2, (b, k, d), jnp.float32)
    tvs = jnp.where(valid[:, :, None], tvs, 0.0)

    u_p, words, num_p, den_p = fused_unify_packed_pallas(tvs, valid,
                                                         interpret=True)
    u_b, m_b, num_b, den_b = fused_unify_pallas(tvs, valid, interpret=True)
    assert u_p.dtype == jnp.bfloat16 and words.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(u_b.astype(jnp.bfloat16)),
                                  np.asarray(u_p))
    np.testing.assert_array_equal(np.asarray(bitpack.pack_bits(m_b > 0.5)),
                                  np.asarray(words))
    np.testing.assert_allclose(num_p, num_b, rtol=1e-6)
    np.testing.assert_allclose(den_p, den_b, rtol=1e-6)
    # ref packed oracle agrees too
    u_r, w_r, num_r, den_r = ref.fused_unify_packed_ref(tvs, valid)
    np.testing.assert_array_equal(np.asarray(u_r), np.asarray(u_p))
    np.testing.assert_array_equal(np.asarray(w_r), np.asarray(words))


@pytest.mark.parametrize("t,d", [(2, 50), (8, 4096), (16, 2048), (30, 10000)])
def test_sign_sim_packed_matches_dense(t, d):
    """Popcount sign-sim on bit-planes == the fp32 sgn·sgnᵀ matmul —
    exact integers, so equality is bitwise."""
    key = jax.random.PRNGKey(t + d)
    x = jax.random.normal(key, (t, d), jnp.float32)
    x = jnp.where(jnp.abs(x) < 0.05, 0.0, x)     # exercise sgn = 0
    pos, nz = bitpack.sign_planes(x)
    dots = sign_sim_packed_pallas(pos, nz, interpret=True)
    want = jnp.sign(x) @ jnp.sign(x).T
    np.testing.assert_array_equal(np.asarray(dots), np.asarray(want))
    # and via the dispatch op, normalised: ≡ sign_sim_ref
    from repro.kernels import ops
    sim = ops.sign_sim_packed(pos, nz, d, mode="pallas_interpret")
    np.testing.assert_allclose(sim, ref.sign_sim_ref(x), rtol=1e-6)
    sim_ref = ops.sign_sim_packed(pos, nz, d, mode="ref")
    np.testing.assert_allclose(sim_ref, ref.sign_sim_ref(x), rtol=1e-6)


@pytest.mark.parametrize("d", [100, 250, 4096])
def test_packed_kernel_tail_bits_zero(d):
    """Packed kernel outputs honour the wire convention: tail bits of
    the last mask word are zero for ragged d."""
    key = jax.random.PRNGKey(d)
    tvs = jax.random.normal(key, (2, 3, d), jnp.float32)
    valid = jnp.ones((2, 3), bool)
    _, words, _, _ = fused_unify_packed_pallas(tvs, valid, interpret=True)
    tail = bitpack.packed_width(d) * 32 - d
    if tail:
        np.testing.assert_array_equal(
            np.asarray(words[..., -1] >> jnp.uint32(32 - tail)), 0)


if HAVE_HYPOTHESIS:
    @hypothesis.given(
        hnp.arrays(np.bool_, hnp.array_shapes(min_dims=2, max_dims=2,
                                              min_side=1, max_side=80)))
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_bitpack_roundtrip_property(mask):
        d = mask.shape[-1]
        w = bitpack.pack_bits(jnp.asarray(mask))
        np.testing.assert_array_equal(np.asarray(bitpack.unpack_bits(w, d)),
                                      mask)
        np.testing.assert_array_equal(np.asarray(w),
                                      bitpack.pack_bits_np(mask))


def test_sign_sim_padding_invariance():
    """d-padding must not change S (sgn(0)=0 contributes nothing)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 1000))
    s1 = sign_sim_pallas(x, block_d=512, interpret=True)
    s2 = sign_sim_pallas(x, block_d=2048, interpret=True)  # heavy padding
    np.testing.assert_allclose(s1, s2, rtol=1e-6)


def test_kernels_match_core_semantics():
    """Kernel outputs agree with repro.core (the algorithm actually used)."""
    from repro.core.aggregation import sign_similarity, task_aggregate
    from repro.core.unify import unify

    key = jax.random.PRNGKey(3)
    tv = jax.random.normal(key, (4, 3000))
    np.testing.assert_allclose(unify_pallas(tv, interpret=True), unify(tv),
                               rtol=1e-5, atol=1e-6)

    u = jax.random.normal(key, (6, 3000))
    m = jax.random.uniform(jax.random.PRNGKey(4), (6, 3000)) > 0.5
    lam = jax.random.uniform(jax.random.PRNGKey(5), (6,)) + 0.5
    member = jnp.arange(6) < 4
    sizes = jnp.where(member, 25.0, 0.0)
    tau_core, m_core = task_aggregate(u, m, lam, member, sizes, 0.4)
    gam = jnp.where(member, 0.25, 0.0)
    tau_k, m_k = masked_agg_pallas(u, m.astype(u.dtype), lam, gam,
                                   rho=0.4, interpret=True)
    np.testing.assert_allclose(tau_k, tau_core, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(m_k, m_core, rtol=1e-6)

    np.testing.assert_allclose(sign_sim_pallas(tv, interpret=True),
                               sign_similarity(tv), rtol=1e-5)
