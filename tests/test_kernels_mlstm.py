"""mLSTM chunkwise Pallas kernel vs jnp oracle (shape/chunk sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.mlstm_chunk import mlstm_chunkwise_pallas
from repro.nn.ssm import mlstm_chunkwise, mlstm_recurrent_step

jax.config.update("jax_platform_name", "cpu")


def _inputs(key, b, h, s, dk, dv):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, h, s, dk)) * dk ** -0.5
    k = jax.random.normal(ks[1], (b, h, s, dk)) * dk ** -0.5
    v = jax.random.normal(ks[2], (b, h, s, dv))
    i = jax.random.normal(ks[3], (b, h, s))
    f = jax.random.normal(ks[4], (b, h, s)) + 2.0
    return q, k, v, i, f


@pytest.mark.parametrize("s,chunk", [(8, 8), (40, 8), (33, 16), (64, 32)])
@pytest.mark.parametrize("dk,dv", [(4, 6), (8, 8)])
def test_kernel_matches_jnp_chunkwise(s, chunk, dk, dv):
    b, h = 2, 3
    q, k, v, i, f = _inputs(jax.random.PRNGKey(s * 7 + chunk), b, h, s, dk, dv)
    state = (jnp.zeros((b, h, dk, dv)), jnp.zeros((b, h, dk)),
             jnp.full((b, h), -1e30))
    want, _ = mlstm_chunkwise(q, k, v, i, f, state, chunk=chunk)
    got = mlstm_chunkwise_pallas(
        q.reshape(b * h, s, dk), k.reshape(b * h, s, dk),
        v.reshape(b * h, s, dv), i.reshape(b * h, s), f.reshape(b * h, s),
        chunk=chunk, interpret=True)
    np.testing.assert_allclose(got.reshape(b, h, s, dv), want,
                               rtol=1e-4, atol=1e-5)


def test_kernel_matches_step_recurrence():
    """Direct check against the per-step oracle (independent of the jnp
    chunkwise implementation)."""
    b, h, s, dk, dv = 1, 2, 12, 4, 4
    q, k, v, i, f = _inputs(jax.random.PRNGKey(0), b, h, s, dk, dv)
    st = (jnp.zeros((b, h, dk, dv)), jnp.zeros((b, h, dk)),
          jnp.full((b, h), -1e30))
    outs = []
    for t in range(s):
        st, ht = mlstm_recurrent_step(st, q[:, :, t], k[:, :, t],
                                      v[:, :, t], i[:, :, t], f[:, :, t])
        outs.append(ht)
    want = jnp.stack(outs, axis=2)
    got = mlstm_chunkwise_pallas(
        q.reshape(b * h, s, dk), k.reshape(b * h, s, dk),
        v.reshape(b * h, s, dv), i.reshape(b * h, s), f.reshape(b * h, s),
        chunk=4, interpret=True)
    np.testing.assert_allclose(got.reshape(b, h, s, dv), want,
                               rtol=1e-4, atol=1e-5)


def test_kernel_bf16_inputs():
    b, h, s, dk, dv = 1, 2, 16, 8, 8
    q, k, v, i, f = _inputs(jax.random.PRNGKey(1), b, h, s, dk, dv)
    got32 = mlstm_chunkwise_pallas(
        q.reshape(b * h, s, dk), k.reshape(b * h, s, dk),
        v.reshape(b * h, s, dv), i.reshape(b * h, s), f.reshape(b * h, s),
        chunk=8, interpret=True)
    got16 = mlstm_chunkwise_pallas(
        q.reshape(b * h, s, dk).astype(jnp.bfloat16),
        k.reshape(b * h, s, dk).astype(jnp.bfloat16),
        v.reshape(b * h, s, dv).astype(jnp.bfloat16),
        i.reshape(b * h, s), f.reshape(b * h, s),
        chunk=8, interpret=True)
    np.testing.assert_allclose(got16.astype(jnp.float32), got32,
                               rtol=5e-2, atol=5e-2)
