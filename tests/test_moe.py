"""MoE dispatch correctness: the scatter-based capacity dispatch must
equal a dense per-token expert evaluation when capacity is generous."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.moe import MoE

jax.config.update("jax_platform_name", "cpu")


def dense_reference(moe, params, x):
    """Evaluate every expert on every token, combine with top-k gates."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ params["router"]["w"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, moe.top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    ew = params["experts"]
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, ew["gate"]))
    h = h * jnp.einsum("td,edf->tef", xt, ew["up"])
    all_out = jnp.einsum("tef,efd->ted", h, ew["down"])  # (T, E, d)

    out = jnp.zeros_like(xt)
    for j in range(moe.top_k):
        sel = jnp.take_along_axis(all_out, gate_idx[:, j][:, None, None]
                                  .repeat(d, -1), axis=1)[:, 0]
        out = out + sel * gate_vals[:, j][:, None].astype(xt.dtype)
    return out.reshape(b, s, d)


@pytest.mark.parametrize("e,k", [(4, 2), (8, 3), (5, 2)])
def test_scatter_dispatch_matches_dense(e, k):
    moe = MoE(16, 32, e, k, capacity_factor=8.0)  # generous: no drops
    params = moe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16)) * 0.5
    got = moe(params, x)
    want = dense_reference(moe, params, x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_capacity_drops_tokens():
    """Tight capacity must drop overflow rows (outputs shrink toward 0)."""
    moe_tight = MoE(2, 8, 2, 1, capacity_factor=0.25)
    moe_loose = MoE(2, 8, 2, 1, capacity_factor=8.0)
    params = moe_loose.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 2))
    out_t = moe_tight(params, x)
    out_l = moe_loose(params, x)
    # tight capacity zeroes some token outputs
    zeros_t = int(jnp.sum(jnp.all(out_t == 0, axis=-1)))
    zeros_l = int(jnp.sum(jnp.all(out_l == 0, axis=-1)))
    assert zeros_t > zeros_l


def test_shared_expert_added():
    moe = MoE(16, 32, 4, 2, n_shared=1, shared_d_ff=8, capacity_factor=8.0)
    params = moe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 16))
    y_with = moe(params, x)
    # zero the shared expert -> output changes
    params2 = jax.tree_util.tree_map(jnp.zeros_like, params)
    params2 = {**params, "shared": jax.tree_util.tree_map(
        jnp.zeros_like, params["shared"])}
    y_without = moe(params2, x)
    assert not np.allclose(y_with, y_without)


def test_aux_loss_balanced_vs_collapsed():
    """A router that sends everything to one expert has higher aux loss."""
    moe = MoE(8, 16, 4, 1, capacity_factor=8.0)
    params = moe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 8))
    moe(params, x)
    aux_normal = float(moe.last_aux)
    # collapse the router to expert 0
    w = jnp.zeros_like(params["router"]["w"]).at[:, 0].set(10.0)
    collapsed = {**params, "router": {"w": w}}
    moe(collapsed, x)
    aux_collapsed = float(moe.last_aux)
    assert aux_collapsed > aux_normal
