"""Host-pipeline contract: pipelined ≡ sequential, bit for bit.

The two-deep ``RoundEngine.round_stream`` pipeline (pack/decode round
r+1 and encode round r−1's downlinks while round r's jitted step runs)
must be a pure reordering — every downlink byte and every engine output
identical to the ``pipeline=False`` escape hatch, across both slot
layouts (packed wire / bool A/B), raw and entropy-coded wires, and the
ref / pallas_interpret dispatch modes.  The simulator-level deferred
drain (``MaTUStrategy(pipeline=True)`` via ``FedConfig.pipeline``) gets
the same multi-round A/B, plus the per-phase timing plumbing the
pipeline makes observable (History.phase_us).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.client import ClientUpload
from repro.core.engine import EngineConfig, RoundEngine, SlotStage, pack_uploads
from repro.core.unify import unify_with_modulators
from repro.fed.compression import encode_mask_rows
from repro.kernels import bitpack

jax.config.update("jax_platform_name", "cpu")

N_TASKS = 5
D = 512


def _make_rounds(seed, n_rounds, *, coded=False, packed=True, n_clients=4):
    """n_rounds of ragged uploads (different clients/masks per round) in
    the requested wire layout."""
    rng = np.random.default_rng(seed)
    rounds = []
    for _ in range(n_rounds):
        ups = []
        for cid in range(n_clients):
            k = int(rng.integers(1, 4))
            tasks = sorted(rng.choice(N_TASKS, size=k, replace=False).tolist())
            tvs = jnp.asarray(rng.standard_normal((k, D)), jnp.float32)
            unified, masks, lams = unify_with_modulators(tvs)
            words = bitpack.pack_bits_np(np.asarray(masks))
            if coded:
                m = jnp.asarray(encode_mask_rows(words, D))
            elif packed:
                m = jnp.asarray(words)
            else:
                m = masks
            vec = unified.astype(jnp.bfloat16) if packed else unified
            ups.append(ClientUpload(cid, tasks, vec, m, lams,
                                    rng.integers(32, 256, size=k).tolist()))
        rounds.append(ups)
    return rounds


def _assert_rounds_equal(seq, pipe):
    assert len(seq) == len(pipe)
    for (downs_s, out_s, _), (downs_p, out_p, _) in zip(seq, pipe):
        np.testing.assert_array_equal(np.asarray(out_s.task_vectors),
                                      np.asarray(out_p.task_vectors))
        assert downs_s.keys() == downs_p.keys()
        for cid in downs_s:
            np.testing.assert_array_equal(np.asarray(downs_s[cid].masks),
                                          np.asarray(downs_p[cid].masks))
            np.testing.assert_array_equal(np.asarray(downs_s[cid].unified),
                                          np.asarray(downs_p[cid].unified))
            np.testing.assert_array_equal(np.asarray(downs_s[cid].lams),
                                          np.asarray(downs_p[cid].lams))


@pytest.mark.parametrize("mode", ["ref", "pallas_interpret"])
@pytest.mark.parametrize("layout", ["packed", "bool"])
@pytest.mark.parametrize("coded", [False, True])
def test_round_stream_pipelined_matches_sequential(mode, layout, coded):
    packed = layout == "packed"
    rounds = _make_rounds(0, 3, coded=coded, packed=packed)
    eng = RoundEngine(EngineConfig(n_tasks=N_TASKS))
    seq = list(eng.round_stream(rounds, mode=mode, packed=packed,
                                code_masks=coded, pipeline=False))
    pipe = list(eng.round_stream(rounds, mode=mode, packed=packed,
                                 code_masks=coded, pipeline=True))
    _assert_rounds_equal(seq, pipe)
    for _, _, phase in pipe:
        assert {"pack", "decode", "device"} <= set(phase)
        if coded:
            assert "encode" in phase and phase["encode"] > 0
    # coded downlinks are real uint8 streams in both paths
    if coded:
        for downs, _, _ in pipe:
            assert all(np.asarray(dl.masks).dtype == np.uint8
                       for dl in downs.values())


def test_round_stream_matches_round_api():
    """The streamed rounds equal one-shot ``RoundEngine.round`` calls —
    the pipeline is a scheduling layer, not a different computation."""
    rounds = _make_rounds(1, 3, coded=True)
    eng = RoundEngine(EngineConfig(n_tasks=N_TASKS))
    streamed = list(eng.round_stream(rounds, code_masks=True))
    for ups, (downs_s, out_s, _) in zip(rounds, streamed):
        downs, out = eng.round(ups, code_masks=True)
        np.testing.assert_array_equal(np.asarray(out.task_vectors),
                                      np.asarray(out_s.task_vectors))
        for cid in downs:
            np.testing.assert_array_equal(np.asarray(downs[cid].masks),
                                          np.asarray(downs_s[cid].masks))


def test_slot_stage_reuse_is_clean():
    """A stage refilled with a SMALLER round (fewer clients, fewer
    slots, different masks) must not leak the previous round's bytes
    through the padding — the explicit re-zeroing contract."""
    big = _make_rounds(2, 1, coded=False, n_clients=4)[0]
    small = _make_rounds(3, 1, coded=False, n_clients=3)[0]
    stage = SlotStage()
    pack_uploads(big, N_TASKS, stage=stage)
    reused = pack_uploads(small, N_TASKS, n_max=4, stage=stage)
    fresh = pack_uploads(small, N_TASKS, n_max=4)
    np.testing.assert_array_equal(np.asarray(reused.slot_masks),
                                  np.asarray(fresh.slot_masks))
    np.testing.assert_array_equal(np.asarray(reused.unified),
                                  np.asarray(fresh.unified))


def test_pack_uploads_batched_decode_parity():
    """Mixed coded/raw rounds: the single cross-client batched decode
    in pack_uploads equals packing the raw twins."""
    raw = _make_rounds(4, 1, coded=False)[0]
    coded = [ClientUpload(u.client_id, u.task_ids, u.unified,
                          jnp.asarray(encode_mask_rows(
                              np.asarray(u.masks), D)),
                          u.lams, u.data_sizes)
             for u in raw]
    mixed = [coded[i] if i % 2 else raw[i] for i in range(len(raw))]
    b_raw = pack_uploads(raw, N_TASKS)
    for ups in (coded, mixed):
        b = pack_uploads(ups, N_TASKS)
        np.testing.assert_array_equal(np.asarray(b.slot_masks),
                                      np.asarray(b_raw.slot_masks))


# -- simulator-level pipeline -------------------------------------------------

def _sim_setting():
    from repro.data.dirichlet import dirichlet_split
    from repro.data.synthetic import make_constellation
    from repro.fed.testbed import MLPBackbone
    con = make_constellation(n_tasks=N_TASKS, n_groups=2, feat_dim=16,
                             n_classes=4, seed=0)
    split = dirichlet_split(n_clients=5, n_tasks=N_TASKS, n_classes=4,
                            zeta_t=0.5, tasks_per_client=2, seed=0)
    bb = MLPBackbone(16, hidden=24, lora_rank=4)
    return con, split, bb


@pytest.mark.parametrize("mode_env", ["ref", "pallas_interpret"])
def test_simulator_pipeline_bit_parity(mode_env, monkeypatch):
    """FedConfig.pipeline=True (deferred strategy drain) reproduces the
    sequential run bit for bit: accuracies, measured wire bits, and
    per-client downlink streams — under both dispatch modes."""
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    if mode_env == "pallas_interpret":
        monkeypatch.delenv("REPRO_DISABLE_PALLAS", raising=False)
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    from repro.fed.simulator import FedConfig, FedSimulator
    from repro.fed.strategies import MaTUStrategy
    con, split, bb = _sim_setting()
    hists, strats = {}, {}
    for pipe in (False, True):
        cfg = FedConfig(rounds=3, local_steps=3, eval_every=1, seed=0,
                        pipeline=pipe)
        strat = MaTUStrategy(N_TASKS, bb.d, code_masks=True)
        hists[pipe] = FedSimulator(cfg, con, split, bb, strat).run()
        strats[pipe] = strat
    assert hists[True].mean_acc == hists[False].mean_acc
    assert hists[True].task_acc == hists[False].task_acc
    assert (hists[True].uplink_bits_per_round
            == hists[False].uplink_bits_per_round)
    assert (hists[True].downlink_bits_per_round
            == hists[False].downlink_bits_per_round)
    for cid, dl in strats[False].downlinks.items():
        dl_p = strats[True].downlinks[cid]
        np.testing.assert_array_equal(np.asarray(dl.masks),
                                      np.asarray(dl_p.masks))
        np.testing.assert_array_equal(np.asarray(dl.unified),
                                      np.asarray(dl_p.unified))


def test_simulator_phase_timings_recorded():
    """History.phase_us carries the codec/device split; under
    pipeline=True the first entry is empty (nothing completed yet) and
    later entries hold the previous round's completed phases."""
    from repro.fed.simulator import FedConfig, FedSimulator
    from repro.fed.strategies import MaTUStrategy
    con, split, bb = _sim_setting()
    cfg = FedConfig(rounds=3, local_steps=2, eval_every=3, seed=0)
    strat = MaTUStrategy(N_TASKS, bb.d, code_masks=True)
    hist = FedSimulator(cfg, con, split, bb, strat).run()
    assert len(hist.phase_us) == 3
    for ph in hist.phase_us:     # sequential: every round completed
        assert {"pack", "device", "encode"} <= set(ph)
        assert all(v >= 0 for v in ph.values())
    mean = hist.mean_phase_us
    assert mean["device"] > 0 and mean["pack"] > 0

    cfg_p = FedConfig(rounds=3, local_steps=2, eval_every=3, seed=0,
                      pipeline=True)
    strat_p = MaTUStrategy(N_TASKS, bb.d, code_masks=True)
    hist_p = FedSimulator(cfg_p, con, split, bb, strat_p).run()
    assert hist_p.phase_us[0] == {}          # round 0 still in flight
    assert {"pack", "device"} <= set(hist_p.phase_us[-1])
