"""Round-engine parity tests: the batched, kernel-dispatched engine
(repro.core.engine) against the legacy per-task Python-loop server, the
dense matu_round reference, and across kernel dispatch modes.

The legacy path (``MaTUServer.round_legacy``) is kept in-tree exactly
for these tests: the engine must reproduce it to fp tolerance on
randomized ragged uploads — varying client count, ragged k_n, and
partial task participation.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.aggregation import matu_round
from repro.core.client import ClientUpload
from repro.core.engine import (EngineConfig, RoundEngine,
                               batched_client_unify, pack_uploads)
from repro.core.server import MaTUServer, MaTUServerConfig
from repro.core.unify import unify_with_modulators
from repro.kernels import ops

jax.config.update("jax_platform_name", "cpu")


def random_uploads(rng, n, n_tasks, d, k_max, *, skew_sizes=True):
    """Ragged random round: each client holds 1..k_max distinct tasks.
    With n small vs n_tasks some tasks go unheld (partial participation)."""
    ups = []
    for cid in range(n):
        k = int(rng.integers(1, k_max + 1))
        tasks = sorted(rng.choice(n_tasks, size=k, replace=False).tolist())
        tvs = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
        unified, masks, lams = unify_with_modulators(tvs)
        sizes = (rng.integers(10, 200, size=k).tolist() if skew_sizes
                 else [100] * k)
        ups.append(ClientUpload(cid, tasks, unified, masks, lams, sizes))
    return ups


def assert_round_equal(server_a, server_b, downs_a, downs_b, uploads,
                       rtol=1e-5, atol=1e-6):
    np.testing.assert_allclose(server_a.last_task_vectors,
                               server_b.last_task_vectors, rtol=rtol, atol=atol)
    np.testing.assert_allclose(server_a.last_similarity,
                               server_b.last_similarity, rtol=rtol, atol=atol)
    for up in uploads:
        a, b = downs_a[up.client_id], downs_b[up.client_id]
        assert b.masks.shape == (len(up.task_ids), int(up.unified.shape[0]))
        np.testing.assert_allclose(a.unified, b.unified, rtol=rtol, atol=atol)
        np.testing.assert_array_equal(np.asarray(a.masks), np.asarray(b.masks))
        np.testing.assert_allclose(a.lams, b.lams, rtol=1e-4, atol=atol)


@pytest.mark.parametrize("seed,n,n_tasks,d,k_max", [
    (0, 4, 5, 128, 3),       # partial participation likely
    (1, 7, 6, 300, 3),
    (2, 3, 8, 64, 2),        # heavy partial participation
    (3, 12, 5, 200, 4),      # more clients than tasks
    (4, 1, 4, 96, 2),        # single-client round
])
def test_engine_matches_legacy_server(seed, n, n_tasks, d, k_max):
    """(a) engine output ≡ legacy MaTUServer.round on randomized ragged
    uploads: task vectors, similarity, and every client's downlink."""
    rng = np.random.default_rng(seed)
    ups = random_uploads(rng, n, n_tasks, d, k_max)
    legacy = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
    batched = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
    downs_legacy = legacy.round_legacy(ups)
    downs_engine = batched.round(ups)
    assert_round_equal(legacy, batched, downs_legacy, downs_engine, ups)


@pytest.mark.parametrize("cross_task,uniform_cross", [
    (True, False), (False, False), (True, True),
])
def test_engine_matches_legacy_ablations(cross_task, uniform_cross):
    """Ablation variants (Fig. 6b) agree too."""
    rng = np.random.default_rng(9)
    ups = random_uploads(rng, 6, 5, 160, 3)
    cfg = MaTUServerConfig(n_tasks=5, cross_task=cross_task,
                           uniform_cross=uniform_cross)
    legacy, batched = MaTUServer(cfg), MaTUServer(cfg)
    downs_l = legacy.round_legacy(ups)
    downs_e = batched.round(ups)
    assert_round_equal(legacy, batched, downs_l, downs_e, ups)


def test_engine_matches_matu_round_dense():
    """The dense reference (matu_round on the packed tensors) is the
    engine's semantics, including m̂ for unheld tasks."""
    rng = np.random.default_rng(5)
    ups = random_uploads(rng, 6, 5, 200, 3)
    packed = pack_uploads(ups, 5)
    masks, lams, member, sizes = packed.dense_tensors()
    dense = matu_round(packed.unified, masks, lams, member, sizes)
    engine = RoundEngine(EngineConfig(n_tasks=5))
    out = engine.run_packed(packed)
    np.testing.assert_allclose(out.task_vectors, dense.task_vectors,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out.similarity, dense.similarity,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out.tau_hats, dense.tau_hats,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out.m_hats, dense.m_hats, rtol=1e-5, atol=1e-6)


def test_unheld_tasks_never_transfer():
    """Satellite fix: an unheld task contributes nothing to (and receives
    nothing from) cross-task transfer, in matu_round AND the engine."""
    rng = np.random.default_rng(6)
    n_tasks, d = 5, 150
    # all clients hold tasks 0-2 only; tasks 3-4 unheld this round
    ups = []
    for cid in range(4):
        tasks = [0, 1, 2]
        tvs = jnp.asarray(rng.standard_normal((3, d)), jnp.float32)
        unified, masks, lams = unify_with_modulators(tvs)
        ups.append(ClientUpload(cid, tasks, unified, masks, lams, [100] * 3))
    packed = pack_uploads(ups, n_tasks)
    masks, lams, member, sizes = packed.dense_tensors()
    dense = matu_round(packed.unified, masks, lams, member, sizes, eps=-1.0)
    # unheld rows/cols of the (masked) similarity are exactly zero
    sim = np.asarray(dense.similarity)
    assert np.all(sim[3:] == 0) and np.all(sim[:, 3:] == 0)
    # unheld task vectors stay zero; held ones receive no zero-vector mix
    np.testing.assert_allclose(dense.task_vectors[3:], 0.0)
    engine = RoundEngine(EngineConfig(n_tasks=n_tasks, eps=-1.0))
    out = engine.run_packed(packed)
    np.testing.assert_allclose(out.task_vectors, dense.task_vectors,
                               rtol=1e-5, atol=1e-6)
    # uniform_cross ablation masks unheld tasks the same way
    uni = matu_round(packed.unified, masks, lams, member, sizes,
                     uniform_cross=True)
    np.testing.assert_allclose(uni.task_vectors[3:], 0.0)


def test_batched_reunify_matches_per_client():
    """(b) padded batched re-unification ≡ per-client
    unify_with_modulators on each valid slot subset."""
    rng = np.random.default_rng(3)
    b, k, d = 7, 4, 256
    valid = rng.random((b, k)) > 0.35
    valid[:, 0] = True
    tvs = rng.standard_normal((b, k, d)).astype(np.float32)
    tvs[~valid] = 0.0
    unified, masks, lams = batched_client_unify(jnp.asarray(tvs),
                                                jnp.asarray(valid))
    for i in range(b):
        sel = valid[i]
        tau, msk, lam = unify_with_modulators(jnp.asarray(tvs[i][sel]))
        np.testing.assert_allclose(unified[i], tau, rtol=1e-6, atol=1e-7)
        np.testing.assert_array_equal(np.asarray(masks[i])[sel],
                                      np.asarray(msk))
        np.testing.assert_allclose(np.asarray(lams[i])[sel], lam, rtol=1e-5)
        assert not np.any(np.asarray(masks[i])[~sel])
        np.testing.assert_allclose(np.asarray(lams[i])[~sel], 0.0)


def test_dispatch_modes_agree(monkeypatch):
    """(c) the pure-jnp path (REPRO_DISABLE_PALLAS=1) and the Pallas
    interpreter path agree to 1e-5 on the full round."""
    rng = np.random.default_rng(4)
    ups = random_uploads(rng, 5, 4, 180, 3)
    engine = RoundEngine(EngineConfig(n_tasks=4))
    packed = pack_uploads(ups, 4)

    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert ops.resolve_mode() == "ref"
    out_ref = engine.run_packed(packed)

    monkeypatch.delenv("REPRO_DISABLE_PALLAS", raising=False)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert ops.resolve_mode() == "pallas_interpret"
    out_pal = engine.run_packed(packed)

    for a, b in zip(out_ref, out_pal):
        if a.dtype == bool:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_static_signature_across_participation(monkeypatch):
    """Membership padding keeps the jit signature static: rounds with
    different client subsets of the same padded size hit one trace."""
    rng = np.random.default_rng(8)
    n_tasks, d = 5, 120
    engine = RoundEngine(EngineConfig(n_tasks=n_tasks))
    traces = {"n": 0}
    import repro.core.engine as engine_mod
    orig = engine_mod._round_impl

    def counting(*args, **kw):
        traces["n"] += 1
        return orig(*args, **kw)

    monkeypatch.setattr(engine_mod, "_round_impl", counting)
    engine._impls.clear()
    for trial in range(3):
        ups = random_uploads(rng, 3, n_tasks, d, 2)     # pads to n_max=4
        packed = pack_uploads(ups, n_tasks, n_max=4, k_max=2)
        engine.run_packed(packed)
    assert traces["n"] == 1, f"retraced {traces['n']}x for same padded shape"


def test_strategy_batched_aggregate_matches_legacy_loop():
    """MaTUStrategy's pre-packed batch path ≡ the legacy per-client
    unify + server.round_legacy composition."""
    from repro.fed.strategies import MaTUStrategy, RoundBatch, Upload

    rng = np.random.default_rng(11)
    n_tasks, d = 5, 140
    uploads = []
    for cid in range(6):
        k = int(rng.integers(1, 4))
        tasks = sorted(rng.choice(n_tasks, size=k, replace=False).tolist())
        tvs = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
        uploads.append(Upload(cid, tasks, tvs, rng.integers(10, 99, size=k).tolist()))

    strat = MaTUStrategy(n_tasks, d)
    strat.aggregate_batch(RoundBatch.from_uploads(uploads, n_tasks))

    legacy_server = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
    legacy_ups = []
    for u in uploads:
        unified, masks, lams = unify_with_modulators(u.task_vectors)
        legacy_ups.append(ClientUpload(u.client_id, u.task_ids, unified,
                                       masks, lams, u.data_sizes))
    legacy_downs = legacy_server.round_legacy(legacy_ups)

    np.testing.assert_allclose(strat.server.last_task_vectors,
                               legacy_server.last_task_vectors,
                               rtol=1e-5, atol=1e-6)
    for u in uploads:
        a, b = legacy_downs[u.client_id], strat.downlinks[u.client_id]
        np.testing.assert_allclose(a.unified, b.unified, rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(a.masks), np.asarray(b.masks))
        np.testing.assert_allclose(a.lams, b.lams, rtol=1e-4, atol=1e-6)
