"""Round-engine parity tests: the batched, kernel-dispatched engine
(repro.core.engine) against the legacy per-task Python-loop server, the
dense matu_round reference, and across kernel dispatch modes — plus the
wire-format guarantees of the bit-packed / bf16 slot layout.

The wire contract under test (see the engine docstring):

* uploads are quantised ONCE at the wire boundary — unified vectors to
  bf16, masks to uint32 words — and every path (legacy loop, bool A/B
  engine, packed engine) then consumes the identical values;
* on those identical inputs the packed engine's masks and λs are
  **bit-identical** to the bool/fp32 layout's (sign decisions are made
  on fp32 values before any bf16 rounding), and its bf16 vector
  outputs are exactly the bf16 rounding of the bool engine's fp32 ones.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.aggregation import matu_round, matu_round_packed
from repro.core.client import ClientUpload
from repro.core.engine import (EngineConfig, RoundEngine,
                               batched_client_unify, pack_uploads)
from repro.core.server import MaTUServer, MaTUServerConfig
from repro.core.unify import unify_with_modulators
from repro.fed.compression import quantize_bf16_transport
from repro.kernels import bitpack, ops

jax.config.update("jax_platform_name", "cpu")


def bf16(x):
    return jnp.asarray(x, jnp.float32).astype(jnp.bfloat16)


def random_uploads(rng, n, n_tasks, d, k_max, *, skew_sizes=True):
    """Ragged random round: each client holds 1..k_max distinct tasks.
    With n small vs n_tasks some tasks go unheld (partial participation).
    Unified vectors carry the bf16 wire quantisation (applied once, as
    the uplink does) so every server path consumes identical values."""
    ups = []
    for cid in range(n):
        k = int(rng.integers(1, k_max + 1))
        tasks = sorted(rng.choice(n_tasks, size=k, replace=False).tolist())
        tvs = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
        unified, masks, lams = unify_with_modulators(tvs)
        sizes = (rng.integers(10, 200, size=k).tolist() if skew_sizes
                 else [100] * k)
        ups.append(ClientUpload(cid, tasks, quantize_bf16_transport(unified),
                                masks, lams, sizes))
    return ups


def assert_round_equal(server_a, server_b, downs_a, downs_b, uploads,
                       rtol=1e-5, atol=1e-6):
    """a = fp32 reference (legacy), b = engine (wire outputs)."""
    np.testing.assert_allclose(server_a.last_task_vectors,
                               server_b.last_task_vectors, rtol=rtol, atol=atol)
    np.testing.assert_allclose(server_a.last_similarity,
                               server_b.last_similarity, rtol=rtol, atol=atol)
    for up in uploads:
        a, b = downs_a[up.client_id], downs_b[up.client_id]
        d = int(up.unified.shape[0])
        assert b.masks.dtype == jnp.uint32           # wire layout
        assert b.masks.shape == (len(up.task_ids), bitpack.packed_width(d))
        assert b.unified.dtype == jnp.bfloat16
        # mask bits are decided on fp32 values pre-rounding: bit-identical
        np.testing.assert_array_equal(np.asarray(a.masks),
                                      np.asarray(b.masks_dense()))
        # the bf16 wire vector is the rounding of the fp32 reference
        np.testing.assert_allclose(np.asarray(a.unified),
                                   np.asarray(b.unified, np.float32),
                                   rtol=1e-2, atol=1e-5)
        np.testing.assert_allclose(a.lams, b.lams, rtol=1e-4, atol=atol)


@pytest.mark.parametrize("seed,n,n_tasks,d,k_max", [
    (0, 4, 5, 128, 3),       # partial participation likely
    (1, 7, 6, 300, 3),       # d not divisible by 32 (ragged tail words)
    (2, 3, 8, 64, 2),        # heavy partial participation
    (3, 12, 5, 200, 4),      # more clients than tasks
    (4, 1, 4, 96, 2),        # single-client round
])
def test_engine_matches_legacy_server(seed, n, n_tasks, d, k_max):
    """(a) wire-format engine ≡ legacy MaTUServer.round on randomized
    ragged uploads: task vectors, similarity, every client's downlink."""
    rng = np.random.default_rng(seed)
    ups = random_uploads(rng, n, n_tasks, d, k_max)
    legacy = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
    batched = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
    downs_legacy = legacy.round_legacy(ups)
    downs_engine = batched.round(ups)
    assert_round_equal(legacy, batched, downs_legacy, downs_engine, ups)


@pytest.mark.parametrize("cross_task,uniform_cross", [
    (True, False), (False, False), (True, True),
])
def test_engine_matches_legacy_ablations(cross_task, uniform_cross):
    """Ablation variants (Fig. 6b) agree too."""
    rng = np.random.default_rng(9)
    ups = random_uploads(rng, 6, 5, 160, 3)
    cfg = MaTUServerConfig(n_tasks=5, cross_task=cross_task,
                           uniform_cross=uniform_cross)
    legacy, batched = MaTUServer(cfg), MaTUServer(cfg)
    downs_l = legacy.round_legacy(ups)
    downs_e = batched.round(ups)
    assert_round_equal(legacy, batched, downs_l, downs_e, ups)


def test_packed_engine_bit_identical_to_bool_engine():
    """THE wire-format parity guarantee (streaming ref round — the CPU
    default): on identical (bf16-quantised) inputs the packed engine's
    masks are bit-identical to the bool/fp32 engine's, fp32 outputs
    match exactly, and each bf16 output is exactly the bf16 rounding of
    the bool engine's fp32 value.  (On the Pallas paths masks/m̂/sim
    stay bit-identical but λ matches only to fp32 accumulation
    tolerance — the packed kernels tile d at 4096 vs 2048; see the
    engine docstring.)"""
    for seed, (n, n_tasks, d, k_max) in enumerate(
            [(5, 4, 300, 3), (8, 6, 1000, 3), (3, 5, 97, 2)]):
        ups = random_uploads(np.random.default_rng(seed), n, n_tasks, d, k_max)
        eng = RoundEngine(EngineConfig(n_tasks=n_tasks))
        downs_p, out_p = eng.round(ups)                      # wire layout
        downs_b, out_b = eng.round(ups, packed=False)        # bool A/B layout
        np.testing.assert_array_equal(np.asarray(out_b.task_vectors),
                                      np.asarray(out_p.task_vectors))
        np.testing.assert_array_equal(np.asarray(out_b.tau_hats),
                                      np.asarray(out_p.tau_hats))
        np.testing.assert_array_equal(np.asarray(out_b.similarity),
                                      np.asarray(out_p.similarity))
        # m̂ re-derived from the byte-wide agreement numerator is the
        # bit-identical value the bool path materialised in fp32
        np.testing.assert_array_equal(np.asarray(out_b.m_hats),
                                      np.asarray(out_p.m_hats))
        np.testing.assert_array_equal(np.asarray(out_b.down_lams),
                                      np.asarray(out_p.down_lams))
        np.testing.assert_array_equal(
            np.asarray(out_b.down_masks),
            np.asarray(ops.unpack_masks(out_p.down_masks, d)))
        np.testing.assert_array_equal(
            np.asarray(bf16(out_b.down_unified)),
            np.asarray(out_p.down_unified))
        for cid in downs_p:
            np.testing.assert_array_equal(
                np.asarray(downs_b[cid].masks),
                np.asarray(downs_p[cid].masks_dense()))


def test_pack_unpack_roundtrip_ragged():
    """ops.unpack_masks(pack_masks(m)) == m for d not divisible by 32,
    and tail bits of the last word are zero (the wire convention)."""
    rng = np.random.default_rng(2)
    for d in (1, 7, 31, 32, 33, 100, 257, 4096, 8191):
        m = jnp.asarray(rng.random((3, 2, d)) > 0.5)
        w = ops.pack_masks(m)
        assert w.dtype == jnp.uint32
        assert w.shape == (3, 2, bitpack.packed_width(d))
        np.testing.assert_array_equal(np.asarray(ops.unpack_masks(w, d)),
                                      np.asarray(m))
        tail = bitpack.packed_width(d) * 32 - d
        if tail:
            np.testing.assert_array_equal(
                np.asarray(w[..., -1] >> jnp.uint32(32 - tail)), 0)
        # host-side packer produces the identical bytes
        np.testing.assert_array_equal(np.asarray(w),
                                      bitpack.pack_bits_np(np.asarray(m)))


try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @hypothesis.given(
        hnp.arrays(np.bool_, hnp.array_shapes(min_dims=1, max_dims=3,
                                              min_side=1, max_side=70)))
    @hypothesis.settings(max_examples=40, deadline=None)
    def test_pack_unpack_roundtrip_property(mask):
        d = mask.shape[-1]
        w = ops.pack_masks(jnp.asarray(mask))
        np.testing.assert_array_equal(np.asarray(ops.unpack_masks(w, d)),
                                      mask)
        np.testing.assert_array_equal(np.asarray(w), bitpack.pack_bits_np(mask))


def test_legacy_oracle_accepts_wire_uploads():
    """round_legacy (the parity oracle) must treat wire-format uploads
    (uint32 mask words + bf16 vectors) identically to their dense
    twins, not silently stack raw words as masks."""
    rng = np.random.default_rng(12)
    n_tasks, d = 5, 200
    dense_ups, wire_ups = [], []
    for cid in range(4):
        k = int(rng.integers(1, 4))
        tasks = sorted(rng.choice(n_tasks, size=k, replace=False).tolist())
        tvs = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
        unified, masks, lams = unify_with_modulators(tvs)
        sizes = [100] * k
        dense_ups.append(ClientUpload(
            cid, tasks, quantize_bf16_transport(unified), masks, lams, sizes))
        wire_ups.append(ClientUpload(
            cid, tasks, unified.astype(jnp.bfloat16),
            ops.pack_masks(masks), lams, sizes))
    a = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
    b = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
    downs_a = a.round_legacy(dense_ups)
    downs_b = b.round_legacy(wire_ups)
    np.testing.assert_allclose(a.last_task_vectors, b.last_task_vectors,
                               rtol=1e-6, atol=1e-7)
    for cid in downs_a:
        np.testing.assert_array_equal(np.asarray(downs_a[cid].masks),
                                      np.asarray(downs_b[cid].masks))


def test_pack_uploads_empty_round_raises():
    """Satellite fix: an empty round used to die with IndexError on
    uploads[0]; it must raise a clear ValueError instead."""
    with pytest.raises(ValueError, match="empty round"):
        pack_uploads([], n_tasks=4)
    engine = RoundEngine(EngineConfig(n_tasks=4))
    with pytest.raises(ValueError, match="empty round"):
        engine.round([])


def test_engine_matches_matu_round_dense():
    """The dense reference (matu_round on the unpacked tensors, via the
    matu_round_packed wire adapter) is the engine's semantics."""
    rng = np.random.default_rng(5)
    ups = random_uploads(rng, 6, 5, 200, 3)
    packed = pack_uploads(ups, 5)
    assert packed.packed and packed.slot_masks.dtype == jnp.uint32
    masks, lams, member, sizes = packed.dense_tensors()
    dense = matu_round(packed.unified.astype(jnp.float32), masks, lams,
                       member, sizes)
    engine = RoundEngine(EngineConfig(n_tasks=5))
    out = engine.run_packed(packed)
    np.testing.assert_allclose(out.task_vectors, dense.task_vectors,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out.similarity, dense.similarity,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out.tau_hats, dense.tau_hats,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(out.m_hats, dense.m_hats, rtol=1e-5, atol=1e-6)
    # the wire adapter reproduces the same dense reference from the
    # packed tensors directly
    dense2 = matu_round_packed(
        packed.unified,
        ops.pack_masks(masks), lams, member, sizes, packed.d)
    np.testing.assert_allclose(dense2.task_vectors, dense.task_vectors,
                               rtol=1e-6, atol=1e-7)


def test_unheld_tasks_never_transfer():
    """An unheld task contributes nothing to (and receives nothing from)
    cross-task transfer, in matu_round AND the engine."""
    rng = np.random.default_rng(6)
    n_tasks, d = 5, 150
    # all clients hold tasks 0-2 only; tasks 3-4 unheld this round
    ups = []
    for cid in range(4):
        tasks = [0, 1, 2]
        tvs = jnp.asarray(rng.standard_normal((3, d)), jnp.float32)
        unified, masks, lams = unify_with_modulators(tvs)
        ups.append(ClientUpload(cid, tasks, quantize_bf16_transport(unified),
                                masks, lams, [100] * 3))
    packed = pack_uploads(ups, n_tasks)
    masks, lams, member, sizes = packed.dense_tensors()
    dense = matu_round(packed.unified.astype(jnp.float32), masks, lams,
                       member, sizes, eps=-1.0)
    # unheld rows/cols of the (masked) similarity are exactly zero
    sim = np.asarray(dense.similarity)
    assert np.all(sim[3:] == 0) and np.all(sim[:, 3:] == 0)
    # unheld task vectors stay zero; held ones receive no zero-vector mix
    np.testing.assert_allclose(dense.task_vectors[3:], 0.0)
    engine = RoundEngine(EngineConfig(n_tasks=n_tasks, eps=-1.0))
    out = engine.run_packed(packed)
    np.testing.assert_allclose(out.task_vectors, dense.task_vectors,
                               rtol=1e-5, atol=1e-6)
    # uniform_cross ablation masks unheld tasks the same way
    uni = matu_round(packed.unified.astype(jnp.float32), masks, lams,
                     member, sizes, uniform_cross=True)
    np.testing.assert_allclose(uni.task_vectors[3:], 0.0)


def test_batched_reunify_matches_per_client():
    """(b) padded batched re-unification ≡ per-client
    unify_with_modulators on each valid slot subset — with the batched
    path emitting the wire tensors (bf16 + packed words)."""
    rng = np.random.default_rng(3)
    b, k, d = 7, 4, 250                  # d % 32 != 0: ragged tail words
    valid = rng.random((b, k)) > 0.35
    valid[:, 0] = True
    tvs = rng.standard_normal((b, k, d)).astype(np.float32)
    tvs[~valid] = 0.0
    unified, words, lams = batched_client_unify(jnp.asarray(tvs),
                                                jnp.asarray(valid))
    assert unified.dtype == jnp.bfloat16
    assert words.dtype == jnp.uint32
    assert words.shape == (b, k, bitpack.packed_width(d))
    masks = np.asarray(ops.unpack_masks(words, d))
    for i in range(b):
        sel = valid[i]
        tau, msk, lam = unify_with_modulators(jnp.asarray(tvs[i][sel]))
        # masks/λ are computed from fp32 values pre-rounding: exact
        np.testing.assert_array_equal(masks[i][sel], np.asarray(msk))
        np.testing.assert_allclose(np.asarray(lams[i])[sel], lam, rtol=1e-5)
        # the unified wire row is exactly bf16(fp32 unify)
        np.testing.assert_array_equal(np.asarray(bf16(tau)),
                                      np.asarray(unified[i]))
        assert not masks[i][~sel].any()
        np.testing.assert_allclose(np.asarray(lams[i])[~sel], 0.0)


def test_dispatch_modes_agree(monkeypatch):
    """(c) the pure-jnp path (REPRO_DISABLE_PALLAS=1) and the Pallas
    interpreter path agree on the full packed round: exact on packed
    words / integer fields, 1e-5 on fp32, 1 bf16 ulp on wire vectors."""
    rng = np.random.default_rng(4)
    ups = random_uploads(rng, 5, 4, 180, 3)
    engine = RoundEngine(EngineConfig(n_tasks=4))
    packed = pack_uploads(ups, 4)

    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert ops.resolve_mode() == "ref"
    out_ref = engine.run_packed(packed)

    monkeypatch.delenv("REPRO_DISABLE_PALLAS", raising=False)
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert ops.resolve_mode() == "pallas_interpret"
    out_pal = engine.run_packed(packed)

    for name in ("task_vectors", "tau_hats", "similarity", "down_lams",
                 "n_held"):
        np.testing.assert_allclose(getattr(out_ref, name),
                                   getattr(out_pal, name),
                                   rtol=1e-5, atol=1e-5, err_msg=name)
    np.testing.assert_array_equal(np.asarray(out_ref.alpha_num),
                                  np.asarray(out_pal.alpha_num))
    np.testing.assert_array_equal(np.asarray(out_ref.down_masks),
                                  np.asarray(out_pal.down_masks))
    np.testing.assert_allclose(np.asarray(out_ref.down_unified, np.float32),
                               np.asarray(out_pal.down_unified, np.float32),
                               rtol=1e-2, atol=1e-5)


def test_static_signature_across_participation(monkeypatch):
    """Membership padding keeps the jit signature static: rounds with
    different client subsets of the same padded size hit one trace."""
    rng = np.random.default_rng(8)
    n_tasks, d = 5, 120
    engine = RoundEngine(EngineConfig(n_tasks=n_tasks))
    traces = {"n": 0}
    import repro.core.engine as engine_mod
    orig = engine_mod._round_impl

    def counting(*args, **kw):
        traces["n"] += 1
        return orig(*args, **kw)

    monkeypatch.setattr(engine_mod, "_round_impl", counting)
    engine._impls.clear()
    for trial in range(3):
        ups = random_uploads(rng, 3, n_tasks, d, 2)     # pads to n_max=4
        packed = pack_uploads(ups, n_tasks, n_max=4, k_max=2)
        engine.run_packed(packed)
    assert traces["n"] == 1, f"retraced {traces['n']}x for same padded shape"


def test_wire_bits_measured_from_buffers():
    """PackedRound.wire_bits / ClientUpload.uplink_bits report the bits
    of the actual wire tensors: 16d per bf16 vector, 32 per mask word,
    32 per scaler."""
    rng = np.random.default_rng(10)
    d = 100                                # dw = 4 words
    ups = random_uploads(rng, 3, 5, d, 2)
    packed = pack_uploads(ups, 5)
    dw = bitpack.packed_width(d)
    want = sum(16 * d + len(u.task_ids) * (32 * dw + 32) for u in ups)
    assert packed.wire_bits() == want
    wire_up = ClientUpload(0, [0, 1], bf16(np.zeros(d)),
                           jnp.zeros((2, dw), jnp.uint32), jnp.zeros(2),
                           [1, 1])
    assert wire_up.uplink_bits() == 16 * d + 2 * (32 * dw + 32)
    # the packed wire beats the paper's fp32+dense-bit scheme
    # (asymptotically (32+k)/(16+k) ≈ 1.9x at k=2)
    paper = 32 * d + 2 * (d + 32)
    assert paper / wire_up.uplink_bits() > 1.7


def test_strategy_batched_aggregate_matches_legacy_loop():
    """MaTUStrategy's pre-packed wire path ≡ the legacy per-client
    unify + server.round_legacy composition on the same bf16 wire
    values."""
    from repro.fed.strategies import MaTUStrategy, RoundBatch, Upload

    rng = np.random.default_rng(11)
    n_tasks, d = 5, 140
    uploads = []
    for cid in range(6):
        k = int(rng.integers(1, 4))
        tasks = sorted(rng.choice(n_tasks, size=k, replace=False).tolist())
        tvs = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
        uploads.append(Upload(cid, tasks, tvs, rng.integers(10, 99, size=k).tolist()))

    strat = MaTUStrategy(n_tasks, d)
    strat.aggregate_batch(RoundBatch.from_uploads(uploads, n_tasks))

    legacy_server = MaTUServer(MaTUServerConfig(n_tasks=n_tasks))
    legacy_ups = []
    for u in uploads:
        unified, masks, lams = unify_with_modulators(u.task_vectors)
        legacy_ups.append(ClientUpload(u.client_id, u.task_ids,
                                       quantize_bf16_transport(unified),
                                       masks, lams, u.data_sizes))
    legacy_downs = legacy_server.round_legacy(legacy_ups)

    np.testing.assert_allclose(strat.server.last_task_vectors,
                               legacy_server.last_task_vectors,
                               rtol=1e-5, atol=1e-6)
    for u in uploads:
        a, b = legacy_downs[u.client_id], strat.downlinks[u.client_id]
        assert b.packed
        np.testing.assert_allclose(np.asarray(a.unified),
                                   np.asarray(b.unified, np.float32),
                                   rtol=1e-2, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(a.masks),
                                      np.asarray(b.masks_dense()))
        np.testing.assert_allclose(a.lams, b.lams, rtol=1e-4, atol=1e-6)
