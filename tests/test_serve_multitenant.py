"""Multi-tenant serving subsystem: ModulatorStore + task routing +
fused modulated matmul.

The parity contracts under test (see repro/serve docstrings):

* dense-routed mixed-task decode is BITWISE identical to decoding each
  request single-tenant with the dense unpacked modulator — for packed
  AND bool downlink layouts;
* the fused ``modulated_matmul`` kernel is BITWISE identical to
  unpack-then-matmul within one compiled program (ref and
  pallas_interpret modes);
* the fused routed decode emits identical TOKENS to dense-routed, its
  weights within one rounding of the modulated delta (XLA contracts
  the in-jit ``base + λ·m⊙τ`` build into an fma — the product feeds
  the add unrounded — where the materialised adapter rounds it first;
  no barrier suppresses the contraction on CPU);
* ONE compiled decode program serves every task mix (task ids are
  data, not trace constants);
* the store refuses fingerprint-mismatched or unstamped downlinks,
  bounds its LRU, and holds ≥5x less resident than per-task
  checkpoints at T=30.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.tree import TaskVectorLayoutError, TaskVectorSpace, tree_add
from repro.configs.base import SHAPES, load_arch
from repro.core.client import ClientDownlink, ClientUpload
from repro.core.server import MaTUServer, MaTUServerConfig
from repro.core.unify import modulate
from repro.kernels import bitpack, ops
from repro.serve import (GenerationConfig, ModulatorStore, MultiTenantDecoder,
                         generate, route_batch)
from repro.serve.generate import _sample

jax.config.update("jax_platform_name", "cpu")

N_TASKS = 4
GEN_CFG = GenerationConfig(max_new_tokens=5, temperature=0.0)


# ---------------------------------------------------------------------------
# shared serving rig: reduced qwen2 + one REAL federated round
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _rig():
    cfg = load_arch("qwen2-0.5b").reduced()
    model = cfg.build(SHAPES["decode_32k"])
    params = model.init(jax.random.PRNGKey(0))
    lora0 = model.lora_init(jax.random.PRNGKey(1))
    space = TaskVectorSpace.from_tree(lora0)

    # one real server round: one single-task client per task
    rng = np.random.default_rng(7)
    uploads = []
    for t in range(N_TASKS):
        vec = jnp.asarray(0.05 * rng.standard_normal(space.d), jnp.float32)
        uploads.append(ClientUpload(
            client_id=t, task_ids=[t], unified=vec,
            masks=jnp.ones((1, space.d), bool),
            lams=jnp.ones((1,), jnp.float32), data_sizes=[64],
            fingerprint=space.fingerprint))
    server = MaTUServer(MaTUServerConfig(n_tasks=N_TASKS))
    server.round(uploads)

    prompts = jax.random.randint(jax.random.PRNGKey(3), (N_TASKS, 8),
                                 1, cfg.vocab)
    return cfg, model, params, lora0, space, server, prompts


def _store_from(server, space, lora0, *, packed, capacity=8):
    dl = server.serving_downlink(packed=packed,
                                 fingerprint=space.fingerprint)
    store = ModulatorStore(space, lora0, capacity=capacity)
    store.ingest(dl)
    return store, dl


def _oracle_adapter(dl, space, lora0, t):
    """The dense unpacked modulator path, independent of the store."""
    delta = modulate(dl.unified, dl.masks[t], dl.lams[t])
    return tree_add(lora0, space.unflatten(delta))


# ---------------------------------------------------------------------------
# kernel layer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("start,length", [(0, 992), (37, 129), (32, 64),
                                          (991, 1), (100, 0), (982, 10)])
def test_slice_bits_matches_unpack_oracle(start, length):
    rng = np.random.default_rng(start * 1000 + length)
    d = 992
    bits = rng.random((3, d)) < 0.5
    words = jnp.asarray(bitpack.pack_bits_np(bits))
    got = bitpack.slice_bits(words, start, length)
    want = bitpack.pack_bits_np(bits[:, start:start + length])
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("mode", ["ref", "pallas_interpret"])
def test_modulated_matmul_bitwise_vs_unpack_then_matmul(mode):
    """Fused kernel == unpack-then-matmul oracle, compared where the
    comparison is meaningful: inside jit, how serving actually runs."""
    rng = np.random.default_rng(0)
    B, S, K, N = 3, 5, 32, 16
    x = jnp.asarray(rng.standard_normal((B, S, K)), jnp.float32)
    base = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    tau = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    m = rng.random((B, K * N)) < 0.6
    words = jnp.asarray(bitpack.pack_bits_np(m))
    lam = jnp.asarray(rng.standard_normal(B), jnp.float32)

    def oracle(x, base, tau, words, lam):
        bits = bitpack.unpack_bits(words, K * N, jnp.float32).reshape(B, K, N)
        w_eff = base[None] + lam[:, None, None] * bits * tau[None]
        return jnp.einsum("bsk,bkn->bsn", x, w_eff)

    got = jax.jit(functools.partial(ops.modulated_matmul, mode=mode))(
        x, base, tau, words, lam)
    want = jax.jit(oracle)(x, base, tau, words, lam)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_modulated_matmul_rejects_misaligned():
    x = jnp.zeros((1, 2, 3))
    base = jnp.zeros((3, 5))          # 15 bits: not word-aligned
    with pytest.raises(ValueError, match="word-aligned"):
        ops.modulated_matmul(x, base, jnp.zeros((3, 5)),
                             jnp.zeros((1, 1), jnp.uint32),
                             jnp.zeros((1,)), mode="ref")


# ---------------------------------------------------------------------------
# store: ingest layouts, fingerprint handshake, LRU
# ---------------------------------------------------------------------------

def test_store_ingest_all_layouts_agree():
    _, _, _, lora0, space, server, _ = _rig()
    packed_dl = server.serving_downlink(packed=True,
                                        fingerprint=space.fingerprint)
    bool_dl = server.serving_downlink(packed=False,
                                      fingerprint=space.fingerprint)
    coded_dl = server.serving_downlink(code_masks=True,
                                       fingerprint=space.fingerprint)
    stores = []
    for dl in (packed_dl, bool_dl, coded_dl):
        s = ModulatorStore(space, lora0)
        assert s.ingest(dl) == list(range(N_TASKS))
        stores.append(s)
    for t in range(N_TASKS):
        ref_words = np.asarray(stores[0].mask_words(t))
        for s in stores[1:]:
            np.testing.assert_array_equal(np.asarray(s.mask_words(t)),
                                          ref_words)
        # packed + coded share the bf16 wire vector -> identical deltas
        np.testing.assert_array_equal(np.asarray(stores[0].delta(t)),
                                      np.asarray(stores[2].delta(t)))
    # masks stay packed in residence whatever the ingest layout
    for s in stores:
        assert all(s.mask_words(t).dtype == jnp.uint32
                   for t in range(N_TASKS))


def test_store_fingerprint_handshake():
    _, _, _, lora0, space, server, _ = _rig()
    store = ModulatorStore(space, lora0)
    bad = server.serving_downlink(fingerprint="0" * 16)
    with pytest.raises(TaskVectorLayoutError):
        store.ingest(bad)
    unstamped = server.serving_downlink()        # fingerprint=None
    with pytest.raises(TaskVectorLayoutError, match="unstamped"):
        store.ingest(unstamped)
    assert store.ingest(unstamped, unchecked=True) == list(range(N_TASKS))


def test_store_lru_eviction_and_rebuild():
    _, _, _, lora0, space, server, _ = _rig()
    store, _ = _store_from(server, space, lora0, packed=True, capacity=2)
    a0 = store.adapter(0)
    store.adapter(1)
    assert store.cached_task_ids() == [0, 1]
    store.adapter(0)                             # touch: 0 now MRU
    assert store.cached_task_ids() == [1, 0]
    store.adapter(2)                             # evicts 1
    assert store.cached_task_ids() == [0, 2]
    assert store.hits == 1 and store.misses == 3
    # eviction loses nothing: rebuild from packed state is bitwise
    store.adapter(0)
    a0_again = store.adapter(1)                  # rebuilt after eviction
    rebuilt = store.adapter(1)
    assert store.materializations == 4 and store.hits == 3
    for l1, l2 in zip(jax.tree_util.tree_leaves(a0_again),
                      jax.tree_util.tree_leaves(rebuilt)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    for l1, l2 in zip(jax.tree_util.tree_leaves(a0),
                      jax.tree_util.tree_leaves(store.adapter(0))):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_store_capacity_validation():
    _, _, _, lora0, space, _, _ = _rig()
    with pytest.raises(ValueError):
        ModulatorStore(space, lora0, capacity=0)


# ---------------------------------------------------------------------------
# routing parity: the acceptance contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("packed", [True, False],
                         ids=["packed-wire", "bool-wire"])
def test_mixed_batch_bitwise_equals_single_tenant(packed):
    """A mixed decode batch over >=4 tasks through the ModulatorStore
    is bit-identical to decoding each request single-tenant with the
    dense unpacked modulator — for both downlink mask layouts."""
    _, model, params, lora0, space, server, prompts = _rig()
    store, dl = _store_from(server, space, lora0, packed=packed)
    dec = MultiTenantDecoder(model, params, store, cfg=GEN_CFG)
    ids = list(range(N_TASKS))
    mixed = dec.generate(prompts, ids)
    assert mixed.shape == (N_TASKS, prompts.shape[1] + GEN_CFG.max_new_tokens)
    for r, t in enumerate(ids):
        lora_t = _oracle_adapter(dl, space, lora0, t)
        single = generate(model, params, lora_t, prompts[r:r + 1], GEN_CFG,
                          max_len=int(prompts.shape[1])
                          + GEN_CFG.max_new_tokens + 8)
        np.testing.assert_array_equal(np.asarray(mixed[r]),
                                      np.asarray(single[0]))


def test_uniform_mix_equals_classic_batch():
    """All-rows-one-task routed decode == the classic (2-D lora)
    uniform batch, bitwise."""
    _, model, params, lora0, space, server, prompts = _rig()
    store, dl = _store_from(server, space, lora0, packed=True)
    dec = MultiTenantDecoder(model, params, store, cfg=GEN_CFG)
    routed = dec.generate(prompts, [2] * N_TASKS)
    classic = generate(model, params, _oracle_adapter(dl, space, lora0, 2),
                       prompts, GEN_CFG,
                       max_len=int(prompts.shape[1])
                       + GEN_CFG.max_new_tokens + 8)
    np.testing.assert_array_equal(np.asarray(routed), np.asarray(classic))


def test_fused_routing_matches_dense_routed():
    """Fused (packed-mask, in-kernel modulation) decode: identical
    tokens to dense-routed; word-aligned sites carry packed words."""
    _, model, params, lora0, space, server, prompts = _rig()
    store, _ = _store_from(server, space, lora0, packed=True)
    ids = [0, 3, 1, 2]
    dense = MultiTenantDecoder(model, params, store, cfg=GEN_CFG)
    fused = MultiTenantDecoder(model, params, store, fused=True, cfg=GEN_CFG)
    np.testing.assert_array_equal(
        np.asarray(dense.generate(prompts, ids)),
        np.asarray(fused.generate(prompts, ids)))

    # the routed tree really is fused where word-aligned: packed uint32
    # words present, no materialised per-request weight
    tree = route_batch(store, ids, fused=True)
    fused_sites = [s for _, s in _iter_sites(tree) if "words" in s.get("a", {})]
    assert fused_sites, "no site took the fused path"
    for site in fused_sites:
        assert site["a"]["words"].dtype == jnp.uint32
        assert site["lam"].shape[-1] == len(ids)


def _iter_sites(node, prefix=""):
    if not isinstance(node, dict):
        return
    if "a" in node and "b" in node:
        yield prefix, node
        return
    for k in node:
        yield from _iter_sites(node[k], f"{prefix}/{k}")


def test_fused_weight_build_within_one_product_rounding():
    """The in-jit ``base + λ·m⊙τ`` build differs from the eagerly
    materialised adapter by at most one rounding of the modulated
    delta per element (XLA fma-contracts the add — the product feeds
    in unrounded — where the adapter rounds it first), and the prefill
    logits of the two routed forms stay within the amplified tolerance
    through the full depth."""
    _, model, params, lora0, space, server, prompts = _rig()
    store, _ = _store_from(server, space, lora0, packed=True)
    ids = [0, 1, 2, 3]
    dense_lora = route_batch(store, ids, fused=False)
    fused_lora = route_batch(store, ids, fused=True)

    # weight level: reconstruct one fused site's effective "a" factor
    # in-jit and ulp-compare against the dense-routed leaf
    site_path, fused_site = next((p, s) for p, s in _iter_sites(fused_lora)
                                 if "words" in s.get("a", {}))
    dense_site = dense_lora
    for k in site_path.strip("/").split("/"):
        dense_site = dense_site[k]

    def build_a(site):
        a = site["a"]
        L, B, W = a["words"].shape
        k, n = a["base"].shape[-2:]
        bits = bitpack.unpack_bits(a["words"].reshape(L * B, W), k * n,
                                   jnp.float32).reshape(L, B, k, n)
        lam = site["lam"][:, :, None, None]
        return a["base"][:, None] + lam * bits * a["tau"][:, None]

    built = np.asarray(jax.jit(build_a)(fused_site))
    want = np.asarray(dense_site["a"])
    base = np.asarray(fused_site["a"]["base"])[:, None]
    delta = want - base                   # the adapter's rounded product
    tol = 2.0 * np.spacing(np.maximum(np.abs(delta), np.abs(want))
                           .astype(np.float32))
    diff = np.abs(built - want)
    assert np.all(diff <= tol), \
        f"weight build off by {np.max(diff / np.maximum(tol, 1e-45)):.1f}x " \
        "the one-product-rounding bound"

    # logits level: the 1-ulp weight wiggle amplifies through L layers
    # to ~1e-4 relative at the head — tokens are identical regardless
    # (test_fused_routing_matches_dense_routed)
    def prefill(lora):
        cache = model.init_cache(N_TASKS, 32)
        logits, _ = model.prefill_step(params, lora, {"tokens": prompts},
                                       cache)
        return logits

    ld = np.asarray(jax.jit(prefill)(dense_lora))
    lf = np.asarray(jax.jit(prefill)(fused_lora))
    np.testing.assert_allclose(lf, ld, rtol=5e-4, atol=1e-5)


def test_one_compiled_program_across_mixes():
    """Task ids are data: one jitted decode program serves every mix."""
    _, model, params, lora0, space, server, prompts = _rig()
    store, _ = _store_from(server, space, lora0, packed=True)
    for fused in (False, True):
        dec = MultiTenantDecoder(model, params, store, fused=fused,
                                 cfg=GEN_CFG)
        for ids in ([0, 1, 2, 3], [3, 2, 1, 0], [1, 1, 2, 2], [0, 0, 0, 0]):
            dec.generate(prompts, ids)
        assert dec.compile_count() == 1, \
            f"fused={fused}: decode recompiled across task mixes"


def test_decoder_validates_batch():
    _, model, params, lora0, space, server, prompts = _rig()
    store, _ = _store_from(server, space, lora0, packed=True)
    dec = MultiTenantDecoder(model, params, store, cfg=GEN_CFG)
    with pytest.raises(ValueError, match="task ids"):
        dec.generate(prompts, [0, 1])
    with pytest.raises(KeyError, match="no resident modulator"):
        dec.generate(prompts, [0, 1, 2, 99])


# ---------------------------------------------------------------------------
# storage accounting: the >=5x headline
# ---------------------------------------------------------------------------

def test_resident_bytes_ratio_at_t30():
    _, _, _, lora0, space, _, _ = _rig()
    T = 30
    rng = np.random.default_rng(0)
    W = bitpack.packed_width(space.d)
    dl = ClientDownlink(
        jnp.asarray(rng.standard_normal(space.d), jnp.float32)
        .astype(jnp.bfloat16),
        jnp.asarray(rng.integers(0, 2**32, (T, W), dtype=np.uint32)),
        jnp.ones((T,), jnp.float32), fingerprint=space.fingerprint)
    store = ModulatorStore(space, lora0)
    store.ingest(dl)
    rep = store.storage_report()
    assert rep["tasks"] == T
    assert rep["checkpoint_bytes"] == T * 4 * space.d
    assert rep["ratio"] >= 5.0, \
        f"resident-bytes win {rep['ratio']:.2f}x < 5x at T={T}"


# ---------------------------------------------------------------------------
# generate() RNG regression
# ---------------------------------------------------------------------------

class _FakeModel:
    """Duck-typed decode stack with constant logits: isolates the
    sampling-loop RNG wiring from any real architecture."""

    def __init__(self, vocab=101):
        self.logits = jax.random.normal(jax.random.PRNGKey(9), (1, vocab))

    def init_cache(self, b, max_len):
        return {"pos": jnp.zeros((b,), jnp.int32)}

    def prefill_step(self, params, lora, batch, cache):
        b = batch["tokens"].shape[0]
        return jnp.broadcast_to(self.logits, (b,) + self.logits.shape[1:]), cache

    def decode_fn(self, params, lora, batch, cache, pos):
        b = batch["tokens"].shape[0]
        return jnp.broadcast_to(self.logits, (b,) + self.logits.shape[1:]), cache


def test_generate_splits_rng_before_first_sample():
    """Regression: the prefill sample must consume a key SPLIT from the
    caller's rng, not the rng itself (which also seeds the scan carry —
    reusing it correlated the first token with step 0)."""
    model = _FakeModel()
    cfg = GenerationConfig(max_new_tokens=8, temperature=1.0)
    rng = jax.random.PRNGKey(42)
    prompt = jnp.ones((1, 4), jnp.int32)
    out = generate(model, {}, {}, prompt, cfg, rng=rng)
    first = int(out[0, 4])

    _, first_key = jax.random.split(rng)
    assert first == int(_sample(model.logits, cfg, first_key)[0])
    # the old behaviour (sampling with the unsplit rng) must NOT match
    assert first != int(_sample(model.logits, cfg, rng)[0])


def test_generate_draws_differ_at_temperature():
    """Two draws from the same (constant-logits) distribution must
    differ at temperature > 0 — any key reuse across steps collapses
    the stream."""
    model = _FakeModel()
    cfg = GenerationConfig(max_new_tokens=12, temperature=1.0)
    out = generate(model, {}, {}, jnp.ones((1, 4), jnp.int32), cfg,
                   rng=jax.random.PRNGKey(0))
    draws = np.asarray(out[0, 4:])
    assert len(set(draws.tolist())) > 1, \
        f"all {len(draws)} draws identical: RNG stream collapsed"
