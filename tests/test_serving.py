"""Serving-path consistency: prefill + decode must reproduce the full
forward's last-token logits for every architecture family, including
sliding-window ring caches and the MLA absorbed-latent decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, load_arch

jax.config.update("jax_platform_name", "cpu")

DECODE_TOL = 5e-5


def _setup(arch):
    cfg = load_arch(arch).reduced()
    model = cfg.build(SHAPES["decode_32k"])
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    lora = model.lora_init(jax.random.PRNGKey(1))
    return cfg, model, params, lora


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_full(arch):
    cfg, model, params, lora = _setup(arch)
    key = jax.random.PRNGKey(2)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 1, cfg.vocab)

    if cfg.family == "audio":
        ae = jax.random.normal(key, (B, cfg.enc_frames, cfg.d_model)) * 0.1
        full = model.model.forward(params, toks, ae, lora=lora)
        cache = model.init_cache(B, 64)
        _pl, cache = model.prefill_step(
            params, lora, {"tokens": toks[:, : S - 1], "audio_embeds": ae}, cache)
    else:
        full, _ = model.model.forward(params, toks, lora=lora)
        cache = model.init_cache(B, 64)
        _pl, cache = model.prefill_step(params, lora, {"tokens": toks[:, : S - 1]}, cache)

    dl, _cache = model.decode_fn(params, lora, {"tokens": toks[:, S - 1 : S]},
                                 cache, jnp.int32(S - 1))
    np.testing.assert_allclose(dl, full[:, -1], rtol=1e-3, atol=DECODE_TOL * 100)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "xlstm-1.3b", "hymba-1.5b"])
def test_multi_step_decode(arch):
    """Greedy decode 4 tokens via cache == recomputing full forward."""
    cfg, model, params, lora = _setup(arch)
    key = jax.random.PRNGKey(3)
    B, S = 1, 8
    toks = jax.random.randint(key, (B, S), 1, cfg.vocab)

    cache = model.init_cache(B, 64)
    pl, cache = model.prefill_step(params, lora, {"tokens": toks}, cache)
    out = list(np.asarray(toks[0]))
    out.append(int(jnp.argmax(pl[0])))          # prediction from prefill
    for _step in range(3):
        # feed the newly generated token at its own position
        logits, cache = model.decode_fn(
            params, lora, {"tokens": jnp.asarray([[out[-1]]], jnp.int32)},
            cache, jnp.int32(len(out) - 1))
        out.append(int(jnp.argmax(logits[0])))

    # reference: argmax over full forward at each step
    ref = list(np.asarray(toks[0]))
    for _step in range(4):
        full, _ = model.model.forward(params, jnp.asarray([ref], jnp.int32), lora=lora)
        ref.append(int(jnp.argmax(full[0, -1])))
    assert out == ref


def test_sliding_window_ring_cache_matches_windowed_forward():
    """SWA ring buffer: decode at pos > window must equal the full
    forward of a model with the same window."""
    cfg = load_arch("qwen2-0.5b").reduced()
    cfg = type(cfg)(**{**cfg.__dict__})
    from dataclasses import replace
    cfg = replace(cfg, sliding_window_long=8)
    model = cfg.build(SHAPES["long_500k"])  # builds with window=8
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    lora = model.lora_init(jax.random.PRNGKey(1))
    B, S = 1, 20
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 1, cfg.vocab)

    full, _ = model.model.forward(params, toks, lora=lora)  # windowed full

    cache = model.init_cache(B, S)  # ring buffer of 8 slots
    _, cache = model.prefill_step(params, lora, {"tokens": toks[:, : S - 1]}, cache)
    dl, _ = model.decode_fn(params, lora, {"tokens": toks[:, S - 1 :]},
                            cache, jnp.int32(S - 1))
    np.testing.assert_allclose(dl, full[:, -1], rtol=1e-3, atol=1e-3)


def test_mla_absorbed_decode_equals_naive():
    from repro.nn.mla import MLAttention
    m = MLAttention(64, 4, q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                    qk_rope_dim=8, v_head_dim=16)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, 64)) * 0.5
    full = m(p, x)
    cache = m.init_cache(B, 32)
    y_pre, cache = m.prefill(p, x[:, : S - 1], cache)
    np.testing.assert_allclose(y_pre, full[:, : S - 1], rtol=1e-4, atol=1e-5)
    y_dec, _ = m.decode_step(p, x[:, S - 1 :], cache, jnp.int32(S - 1))
    np.testing.assert_allclose(y_dec[:, 0], full[:, -1], rtol=1e-4, atol=1e-5)


def test_mla_cache_is_compressed():
    """The latent cache must be (kv_lora + rope)-sized, not H*(nope+v)."""
    from repro.nn.mla import MLAttention
    m = MLAttention(64, 4, q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                    qk_rope_dim=8, v_head_dim=16)
    cache = m.init_cache(2, 10)
    per_token = cache["c_kv"].shape[-1] + cache["k_rope"].shape[-1]
    assert per_token == 16 + 8
    assert per_token < 4 * (16 + 16)  # vs naive per-head K/V
