"""Taskvec-sharded round-engine tests (the engine's sharding contract).

The multi-device cases run in subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main test
process stays at 1 device), on the (4, 2) debug mesh — the d axis
shards 8 ways over ("data", "model").

Contract under test:

* **bit parity** — in "ref" mode the sharded round is bit-identical to
  the single-device round for BOTH slot layouts (packed wire + bool
  A/B), on ragged rounds and on d not divisible by devices·32: the λ
  reductions run on the fixed 256-coord block grid with the
  shard-invariant tree, the Eq. 5 dots psum is integer-exact, and all
  other math is per-coordinate.
* **padding** — ``pad_d_for_shards`` gives every shard a power-of-two
  multiple of 256 coords (= 8 whole uint32 words: packed mask words
  never split mid-word).
* **collectives** — the traced HLO contains exactly two all-reduces
  (the (T, T) similarity dots + the fused λ block-tree roots) and no
  other collective kind.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.engine import pad_d_for_shards
from repro.kernels.ref import LAMBDA_BLOCK

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(script: str, timeout: int = 600) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_pad_d_for_shards_contract():
    """Each shard's slice is a pow2 multiple of 256 coords (8 words);
    no padding when unsharded; idempotent on already-aligned d."""
    assert pad_d_for_shards(1000, 1) == 1000
    for d in (1, 31, 300, 1000, 4096, 1 << 20, (1 << 20) + 5):
        for shards in (2, 4, 8, 256, 512):
            dp = pad_d_for_shards(d, shards)
            assert dp >= d
            per = dp // shards
            assert per * shards == dp
            assert per % LAMBDA_BLOCK == 0
            assert per % 32 == 0                       # word boundary
            blocks = per // LAMBDA_BLOCK
            assert blocks & (blocks - 1) == 0          # pow2 blocks
    assert pad_d_for_shards(8 * LAMBDA_BLOCK, 8) == 8 * LAMBDA_BLOCK


def test_pack_uploads_without_mesh_unchanged():
    """mesh=None keeps the exact PR 2 layout: no padding, no d_pad."""
    import jax.numpy as jnp
    from repro.core.client import ClientUpload
    from repro.core.engine import pack_uploads

    rng = np.random.default_rng(0)
    d = 300
    ups = [ClientUpload(0, [0, 1],
                        jnp.asarray(rng.standard_normal(d), jnp.float32),
                        jnp.asarray(rng.random((2, d)) > 0.5),
                        jnp.ones(2), [10, 20])]
    batch = pack_uploads(ups, 4)
    assert batch.d_pad is None and batch.padded_d == d
    assert batch.unified.shape == (1, d)
    assert batch.slot_masks.shape == (1, 2, -(-d // 32))


_PARITY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["REPRO_DISABLE_PALLAS"] = "1"
    import json
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.client import ClientUpload
    from repro.core.engine import EngineConfig, RoundEngine
    from repro.core.unify import unify_with_modulators
    from repro.fed.compression import quantize_bf16_transport
    from repro.launch.mesh import make_debug_mesh

    def uploads(rng, n, n_tasks, d, k_max):
        ups = []
        for cid in range(n):
            k = int(rng.integers(1, k_max + 1))
            tasks = sorted(rng.choice(n_tasks, size=k,
                                      replace=False).tolist())
            tvs = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
            uni, masks, lams = unify_with_modulators(tvs)
            ups.append(ClientUpload(cid, tasks, quantize_bf16_transport(uni),
                                    masks, lams,
                                    rng.integers(10, 200, size=k).tolist()))
        return ups

    mesh = make_debug_mesh((4, 2))
    FIELDS = ("task_vectors", "tau_hats", "similarity", "down_lams",
              "down_unified", "down_masks", "m_hats")
    report = {"devices": len(jax.devices())}
    # ragged rounds (n not a power of two → padding rows), d not
    # divisible by devices*32 = 256 (1000, 300), and an aligned case
    for seed, (n, T, d, km) in enumerate(
            [(5, 6, 1000, 3), (3, 4, 300, 2), (4, 5, 4096, 2)]):
        ups = uploads(np.random.default_rng(seed), n, T, d, km)
        single = RoundEngine(EngineConfig(n_tasks=T))
        shard = RoundEngine(EngineConfig(n_tasks=T), mesh=mesh)
        for packed in (True, False):
            _, out_s = single.round(ups, packed=packed)
            downs_h, out_h = shard.round(ups, packed=packed)
            for f in FIELDS:
                a = np.asarray(getattr(out_s, f))
                b = np.asarray(getattr(out_h, f))
                key = f"{d}/{'packed' if packed else 'bool'}/{f}"
                report[key] = bool(a.shape == b.shape
                                   and np.array_equal(a, b))
            # downlink slicing keeps the wire dtypes per client
            dl = downs_h[ups[0].client_id]
            if packed:
                report[f"{d}/packed/dl_dtype"] = (
                    str(dl.masks.dtype) == "uint32"
                    and str(dl.unified.dtype) == "bfloat16")
    print(json.dumps(report))
""")


def test_sharded_round_bit_identical_ref():
    """8-way sharded round ≡ single-device round, bit for bit, packed
    and bool layouts, ragged rounds, d % (devices·32) != 0."""
    report = _run_sub(_PARITY)
    assert report.pop("devices") == 8
    bad = [k for k, v in report.items() if v is not True]
    assert not bad, f"sharded round diverged on: {bad}"


_HLO = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["REPRO_DISABLE_PALLAS"] = "1"
    import json, re
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.engine import (EngineConfig, RoundEngine,
                                   pad_d_for_shards)
    from repro.launch.mesh import make_debug_mesh
    from repro.nn.sharding import taskvec_sharding

    mesh = make_debug_mesh((4, 2))
    T, n_max, k_max, d = 6, 8, 4, 1 << 18
    eng = RoundEngine(EngineConfig(n_tasks=T), mesh=mesh)
    d_pad = pad_d_for_shards(d, eng.n_shards)
    rep = NamedSharding(mesh, P())
    args = (
        jax.ShapeDtypeStruct((n_max, d_pad), jnp.bfloat16,
                             sharding=taskvec_sharding(mesh, 2)),
        jax.ShapeDtypeStruct((n_max, k_max, d_pad // 32), jnp.uint32,
                             sharding=taskvec_sharding(mesh, 3)),
        jax.ShapeDtypeStruct((n_max, k_max), jnp.float32, sharding=rep),
        jax.ShapeDtypeStruct((n_max, k_max), jnp.float32, sharding=rep),
        jax.ShapeDtypeStruct((n_max, k_max), jnp.bool_, sharding=rep),
        jax.ShapeDtypeStruct((n_max, k_max), jnp.int32, sharding=rep),
    )
    txt = eng._impl("ref", d).lower(*args).compile().as_text()
    kinds = {}
    for kind in ("all-gather", "all-reduce", "reduce-scatter",
                 "all-to-all", "collective-permute"):
        n = len(re.findall(r"= \\S+ %?" + kind + r"\\(", txt))
        if n:
            kinds[kind] = n
    sim_ar = len(re.findall(r"s32\\[" + f"{T},{T}" + r"\\]\\S* %?all-reduce\\(",
                            txt))
    print(json.dumps({"kinds": kinds, "sim_allreduce": sim_ar}))
""")


def test_sharded_round_collectives():
    """The round HLO carries exactly two all-reduces — the (T, T)
    similarity dots and the λ roots — and no other collective kind."""
    report = _run_sub(_HLO)
    assert set(report["kinds"]) == {"all-reduce"}, report
    assert report["kinds"]["all-reduce"] == 2, report
    assert report["sim_allreduce"] == 1, report


_STACK = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax, jax.numpy as jnp
    from repro.data.dirichlet import dirichlet_split
    from repro.data.synthetic import make_constellation
    from repro.fed.simulator import FedConfig, FedSimulator
    from repro.fed.strategies import MaTUStrategy, RoundBatch, Upload
    from repro.fed.testbed import MLPBackbone
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh((4, 2))
    report = {}

    # strategy level: sharded batched path vs single-device batched path
    rng = np.random.default_rng(7)
    n_tasks, d = 5, 1000
    uploads = []
    for cid in range(6):
        k = int(rng.integers(1, 4))
        tasks = sorted(rng.choice(n_tasks, size=k, replace=False).tolist())
        tvs = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
        uploads.append(Upload(cid, tasks, tvs,
                              rng.integers(10, 99, size=k).tolist()))
    plain = MaTUStrategy(n_tasks, d)
    plain.aggregate_batch(RoundBatch.from_uploads(uploads, n_tasks))
    shard = MaTUStrategy(n_tasks, d, mesh=mesh)
    shard.aggregate_batch(RoundBatch.from_uploads(uploads, n_tasks))
    a = np.asarray(plain.server.last_task_vectors)
    b = np.asarray(shard.server.last_task_vectors)
    # client unify λ crosses shards through one psum whose grouping
    # differs from the single-device accumulation → fp32 tolerance here
    # (engine-level parity is bitwise; see the parity test)
    report["tv_close"] = bool(np.allclose(a, b, rtol=1e-4, atol=1e-5))
    report["masks_equal"] = all(
        bool(np.array_equal(np.asarray(plain.downlinks[u.client_id].masks),
                            np.asarray(shard.downlinks[u.client_id].masks)))
        for u in uploads)
    # wire accounting must be identical: padding is traffic, not bits
    report["uplink_bits"] = (plain.uplink_bits(uploads)
                             == shard.uplink_bits(uploads))
    report["downlink_bits"] = (plain.downlink_bits()
                               == shard.downlink_bits()
                               and plain.downlink_bits() > 0)

    # simulator level: same FedSimulator script, mesh threaded through
    con = make_constellation(n_tasks=4, n_groups=2, feat_dim=16,
                             n_classes=4, conflict_pairs=[(0, 1)], seed=0)
    split = dirichlet_split(n_clients=5, n_tasks=4, n_classes=4,
                            zeta_t=0.0, seed=0)
    bb = MLPBackbone(16, hidden=24, lora_rank=4)
    cfg = FedConfig(rounds=2, local_steps=4, eval_every=2, seed=0)
    hist = FedSimulator(cfg, con, split, bb,
                        MaTUStrategy(4, bb.d), mesh=mesh).run()
    report["sim_ran"] = len(hist.mean_acc) > 0
    report["sim_downlink_mean"] = hist.mean_downlink_bits > 0
    print(json.dumps(report))
""")


def test_strategy_and_simulator_sharded():
    """MaTUStrategy/FedSimulator with a mesh: same results (fp32
    tolerance through the client-unify psum), identical measured wire
    bits, and the untouched simulator loop runs end to end."""
    report = _run_sub(_STACK)
    bad = [k for k, v in report.items() if v is not True]
    assert not bad, f"sharded strategy/simulator diverged on: {bad}"
