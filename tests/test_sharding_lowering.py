"""Sharding/lowering tests on a small host-device mesh (subprocess keeps
the main test process at 1 device).  Verifies that the dry-run machinery
lowers a reduced arch on a real multi-device mesh end to end."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from repro.configs.base import SHAPES, input_specs, load_arch
    from repro.launch.dryrun import (batch_shardings, collective_bytes,
                                     cost_analysis_dict, opt_state_shardings)
    from repro.launch.mesh import arch_rules, make_debug_mesh
    from repro.nn.sharding import logical_to_sharding, mesh_context
    from repro.optim import adamw
    from repro.train.trainer import make_train_step

    mesh = make_debug_mesh((4, 2))
    cfg = load_arch("{arch}").reduced()
    shape = SHAPES["train_4k"]
    with mesh_context(mesh, arch_rules(cfg, mesh)):
        model = cfg.build(shape)
        params_struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        lora_struct = jax.eval_shape(lambda: model.lora_init(jax.random.PRNGKey(1)))
        params_sh = logical_to_sharding(model.axes(), params_struct, mesh=mesh)
        lora_sh = logical_to_sharding(model.lora_axes(), lora_struct, mesh=mesh)
        batch_struct = input_specs(cfg, shape, batch_override=8, seq_override=64)
        batch_sh = batch_shardings(batch_struct, mesh)
        train_step, opt = make_train_step(model, adamw(1e-4))
        opt_struct = jax.eval_shape(opt.init, lora_struct)
        opt_sh = opt_state_shardings(opt_struct, lora_sh, mesh)
        fn = jax.jit(train_step, in_shardings=(params_sh, lora_sh, opt_sh, batch_sh))
        with mesh:
            compiled = fn.lower(params_struct, lora_struct, opt_struct,
                                batch_struct).compile()
        cost = cost_analysis_dict(compiled)
        print(json.dumps({{"flops": cost.get("flops", -1),
                          "coll": collective_bytes(compiled.as_text())}}))
""")


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "deepseek-v2-236b", "xlstm-1.3b"])
def test_reduced_arch_lowers_on_mesh(arch):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT.format(arch=arch)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["flops"] > 0
