"""SSM math: chunkwise mLSTM vs the step-recurrent oracle, conv cache,
and mamba forward/decode state consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.ssm import (Mamba, MLSTMBlock, SLSTMBlock, causal_conv1d,
                          mlstm_chunkwise, mlstm_recurrent_step)

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("chunk", [1, 3, 8, 16])
@pytest.mark.parametrize("s", [1, 5, 16, 33])
def test_mlstm_chunkwise_matches_recurrent(chunk, s):
    key = jax.random.PRNGKey(chunk * 100 + s)
    b, h, dk, dv = 2, 3, 4, 6
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, h, s, dk)) * dk ** -0.5
    k = jax.random.normal(ks[1], (b, h, s, dk)) * dk ** -0.5
    v = jax.random.normal(ks[2], (b, h, s, dv))
    i_pre = jax.random.normal(ks[3], (b, h, s))
    f_pre = jax.random.normal(ks[4], (b, h, s)) + 2.0

    state0 = (jnp.zeros((b, h, dk, dv)), jnp.zeros((b, h, dk)),
              jnp.full((b, h), -1e30))

    h_chunk, (C1, n1, m1) = mlstm_chunkwise(q, k, v, i_pre, f_pre, state0,
                                            chunk=chunk)

    st = state0
    outs = []
    for t in range(s):
        st, ht = mlstm_recurrent_step(st, q[:, :, t], k[:, :, t], v[:, :, t],
                                      i_pre[:, :, t], f_pre[:, :, t])
    # rebuild sequentially to collect outputs
    st = state0
    outs = []
    for t in range(s):
        st, ht = mlstm_recurrent_step(st, q[:, :, t], k[:, :, t], v[:, :, t],
                                      i_pre[:, :, t], f_pre[:, :, t])
        outs.append(ht)
    h_rec = jnp.stack(outs, axis=2)

    np.testing.assert_allclose(h_chunk, h_rec, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(C1, st[0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(n1, st[1], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(m1, st[2], rtol=1e-4, atol=1e-5)


def test_mlstm_block_prefill_then_decode_matches_forward():
    blk = MLSTMBlock(16, 2, chunk=4)
    p = blk.init(jax.random.PRNGKey(0))
    b, s = 2, 9
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, 16)) * 0.5
    y_full, _ = blk.forward(p, x)
    y_pre, state = blk.forward(p, x[:, : s - 1])
    y_dec, _ = blk.decode_step(p, x[:, s - 1 :], state)
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, -1], rtol=1e-4, atol=1e-5)


def test_slstm_sequential_state():
    blk = SLSTMBlock(16, 2)
    p = blk.init(jax.random.PRNGKey(0))
    b, s = 2, 7
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, 16)) * 0.5
    y_full, _ = blk.forward(p, x)
    y_pre, state = blk.forward(p, x[:, : s - 1])
    y_dec, _ = blk.decode_step(p, x[:, s - 1 :], state)
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, -1], rtol=1e-4, atol=1e-5)


def test_mamba_forward_decode_consistency():
    m = Mamba(16, d_state=4, expand=2)
    p = m.init(jax.random.PRNGKey(0))
    b, s = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, 16)) * 0.5
    y_full, _ = m.forward(p, x)
    y_pre, state = m.forward(p, x[:, : s - 1])
    y_dec, _ = m.decode_step(p, x[:, s - 1 :], state)
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, -1], rtol=1e-4, atol=1e-5)


def test_causal_conv_state_carrying():
    """Splitting a sequence across two calls must equal one call."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 10, 5))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 5)) * 0.3
    y_full, _ = causal_conv1d(x, w)
    y1, st = causal_conv1d(x[:, :6], w)
    y2, _ = causal_conv1d(x[:, 6:], w, state=st)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=1e-5, atol=1e-6)


def test_mlstm_stability_extreme_gates():
    """Log-space stabilisation: extreme gate pre-activations stay finite."""
    b, h, s, dk, dv = 1, 1, 12, 4, 4
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, h, s, dk))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, dk))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, h, s, dv))
    i_pre = jnp.asarray([[[-50, 50, 0, 30, -30, 10, 50, -50, 0, 5, -5, 20.0]]])
    f_pre = jnp.asarray([[[50, -50, 0, 30, -30, 50, -50, 10, 0, -5, 5, -20.0]]])
    state0 = (jnp.zeros((b, h, dk, dv)), jnp.zeros((b, h, dk)),
              jnp.full((b, h), -1e30))
    h_out, (C, n, m) = mlstm_chunkwise(q, k, v, i_pre, f_pre, state0, chunk=4)
    assert jnp.all(jnp.isfinite(h_out))
    assert jnp.all(jnp.isfinite(C)) and jnp.all(jnp.isfinite(n))
