"""Substrate tests: optimizers, schedules, checkpointing, sharding rules,
data pipeline invariants."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import load, save
from repro.data.dirichlet import dirichlet_split
from repro.data.synthetic import eval_batch, make_constellation, sample_task_batch
from repro.nn.sharding import DEFAULT_RULES, resolve_spec
from repro.optim import adamw, cosine_decay, linear_warmup_cosine, sgd

jax.config.update("jax_platform_name", "cpu")


# -- optimizers ---------------------------------------------------------------

def _minimize(opt, steps=300):
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    for _ in range(steps):
        grads = {"w": 2 * (params["w"] - target)}
        params, state = opt.update(grads, state, params)
    return params["w"], target


def test_adamw_converges_quadratic():
    w, target = _minimize(adamw(5e-2))
    np.testing.assert_allclose(w, target, atol=1e-2)


def test_sgd_momentum_converges():
    w, target = _minimize(sgd(5e-2, momentum=0.9))
    np.testing.assert_allclose(w, target, atol=1e-2)


def test_schedules_monotone_decay():
    sch = cosine_decay(1.0, 100)
    vals = [float(sch(jnp.asarray(s))) for s in range(0, 101, 10)]
    assert vals[0] == pytest.approx(1.0)
    assert all(a >= b - 1e-6 for a, b in zip(vals, vals[1:]))
    warm = linear_warmup_cosine(1.0, 10, 100)
    assert float(warm(jnp.asarray(5))) == pytest.approx(0.5)


# -- checkpointing -------------------------------------------------------------

def test_ckpt_round_trip():
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32),
                  "d": jnp.asarray(2.5)}}
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ck")
        save(path, tree, metadata={"round": 7})
        loaded, meta = load(path, jax.tree_util.tree_map(jnp.zeros_like, tree))
    assert meta["round"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(loaded)):
        np.testing.assert_array_equal(a, b)


def test_ckpt_shape_mismatch_raises():
    tree = {"a": jnp.zeros((2, 3))}
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ck")
        save(path, tree)
        with pytest.raises(ValueError):
            load(path, {"a": jnp.zeros((3, 2))})


# -- sharding rules -------------------------------------------------------------

def _mesh(shape=(4, 2), axes=("data", "model")):
    if not hasattr(jax.sharding, "AxisType"):
        pytest.skip("jax.sharding.AxisType unavailable in this jax version")
    devs = jax.devices("cpu")
    if len(devs) < int(np.prod(shape)):
        pytest.skip("not enough host devices")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def test_resolve_spec_divisibility_fallback():
    mesh = _mesh((1, 1))
    # with a trivial mesh everything resolves to size-1 axes: still legal
    spec = resolve_spec(("batch", None, "mlp"), (8, 4, 16), mesh=mesh,
                        rules=dict(DEFAULT_RULES))
    assert spec is not None


def test_resolve_spec_used_axes_not_reused():
    """batch takes data; cache_seq then falls to model only."""
    import jax.numpy as _j
    mesh = None
    try:
        mesh = _mesh((2, 2))
    except Exception:
        pytest.skip("mesh unavailable")
    spec = resolve_spec(("batch", "cache_seq", None, None), (4, 8, 2, 4),
                        mesh=mesh, rules=dict(DEFAULT_RULES))
    flat = []
    for entry in spec:
        if entry is None:
            continue
        flat.extend(entry if isinstance(entry, tuple) else (entry,))
    assert len(flat) == len(set(flat)), f"mesh axis reused: {spec}"


def test_resolve_spec_non_divisible_replicates():
    mesh = _mesh((2, 2))
    spec = resolve_spec(("heads",), (5,), mesh=mesh, rules=dict(DEFAULT_RULES))
    assert spec == jax.sharding.PartitionSpec() or spec[0] is None


# -- data pipeline ---------------------------------------------------------------

def test_constellation_structure():
    con = make_constellation(n_tasks=6, n_groups=3, feat_dim=16, n_classes=4,
                             conflict_pairs=[(0, 1)], seed=0)
    oracle = con.oracle_similarity()
    # conflicting groups anti-correlated, same group highly correlated
    g = [con.group_of(t) for t in range(6)]
    for a in range(6):
        for b in range(6):
            if a == b:
                continue
            if g[a] == g[b]:
                assert oracle[a, b] > 0.8
            elif {g[a], g[b]} == {0, 1}:
                assert oracle[a, b] < -0.8


def test_sample_batch_labels_derivable():
    con = make_constellation(n_tasks=2, n_groups=1, feat_dim=16, n_classes=4, seed=0)
    x, y = sample_task_batch(con.tasks[0], jax.random.PRNGKey(0), 128)
    assert x.shape == (128, 16) and y.shape == (128,)
    assert int(y.min()) >= 0 and int(y.max()) < 4
    # labels recoverable from de-rotated latents with the true map
    z = x @ jnp.asarray(con.tasks[0].r)  # R^T inverse of orthogonal R
    pred = jnp.argmax(z @ jnp.asarray(con.tasks[0].w.T), -1)
    assert float(jnp.mean(pred == y)) > 0.9


def test_eval_batch_deterministic():
    con = make_constellation(n_tasks=2, n_groups=1, feat_dim=8, n_classes=4, seed=0)
    x1, y1 = eval_batch(con.tasks[1])
    x2, y2 = eval_batch(con.tasks[1])
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_dirichlet_split_coverage_and_single_task_mode():
    split = dirichlet_split(n_clients=10, n_tasks=8, n_classes=4, zeta_t=0.0)
    assert all(len(t) == 1 for t in split.tasks)
    assert set(t for ts in split.tasks for t in ts) == set(range(8))

    split2 = dirichlet_split(n_clients=12, n_tasks=8, n_classes=4,
                             zeta_t=0.3, tasks_per_client=2, seed=3)
    held = set(t for ts in split2.tasks for t in ts)
    assert held == set(range(8))  # coverage guaranteed
    for (c, t), p in split2.class_probs.items():
        np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-6)
