"""End-to-end system tests: a full MaTU federated LoRA fine-tuning run on
the real model zoo (reduced qwen2 LM + ViT backbone), exercising the
entire stack: model zoo → LoRA flat space → client unification →
stateless server (Eq. 3–6) → downlink modulate → next round → eval."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.dirichlet import dirichlet_split
from repro.data.synthetic import make_constellation
from repro.fed.simulator import FedConfig, FedSimulator
from repro.fed.strategies import MaTUStrategy
from repro.fed.testbed import ViTBackbone

jax.config.update("jax_platform_name", "cpu")


def test_matu_on_vit_backbone_end_to_end():
    """The paper's actual setup at reduced scale: ViT + LoRA, 4 tasks,
    6 clients, a handful of rounds — accuracy must rise above chance
    and the round must produce valid modulators for every client."""
    n_tasks, n_classes = 4, 4
    bb = ViTBackbone(seed=0, reduced=True)
    # patch-aligned rotation tasks (see ViTBackbone.features tiling)
    con = make_constellation(n_tasks=n_tasks, n_groups=2,
                             feat_dim=bb.cfg.patch_dim, n_classes=n_classes,
                             seed=0)
    split = dirichlet_split(n_clients=6, n_tasks=n_tasks, n_classes=n_classes,
                            zeta_t=0.0, seed=0)
    cfg = FedConfig(rounds=5, local_steps=30, batch_size=32, local_data=128,
                    lr=1e-2, eval_every=5, seed=0)
    strat = MaTUStrategy(n_tasks, bb.d)
    sim = FedSimulator(cfg, con, split, bb, strat)
    hist = sim.run()

    assert hist.final_mean_acc > 1.0 / n_classes + 0.05, hist.final_mean_acc
    # downlinks exist for all participating clients, in the wire format:
    # bf16 unified vector + bit-packed uint32 mask words
    for cid, dl in strat.downlinks.items():
        assert dl.unified.shape == (bb.d,)
        assert dl.unified.dtype == jnp.bfloat16
        assert dl.packed and dl.masks.dtype == jnp.uint32
        assert dl.masks_dense().dtype == jnp.bool_
        assert dl.masks_dense().shape[-1] == bb.d
        assert np.all(np.asarray(dl.lams) >= 0)
    # similarity matrix is a valid [0,1] symmetric matrix
    s = np.asarray(strat.server.last_similarity)
    assert s.shape == (n_tasks, n_tasks)
    assert (s >= -1e-6).all() and (s <= 1 + 1e-6).all()


def test_matu_round_is_jittable_and_shardable():
    """The dense matu_round (used for the on-mesh lowering) jits."""
    from repro.core.aggregation import matu_round
    rng = np.random.default_rng(0)
    n, t, d = 8, 5, 4096
    unified = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    masks = jnp.asarray(rng.random((n, t, d)) > 0.5)
    lams = jnp.asarray(rng.random((n, t)) + 0.5, jnp.float32)
    alloc = jnp.asarray(rng.random((n, t)) > 0.3)
    sizes = jnp.where(alloc, 64.0, 0.0)
    f = jax.jit(lambda *a: matu_round(*a).task_vectors)
    out = f(unified, masks, lams, alloc, sizes)
    assert out.shape == (t, d)
    assert np.isfinite(np.asarray(out)).all()


def test_checkpoint_resume_matches(tmp_path):
    """Saving and restoring LoRA + optimizer state mid-training resumes
    bit-identically."""
    from repro.ckpt.checkpoint import load, save
    from repro.configs.base import SHAPES, input_specs, load_arch
    from repro.optim import adamw
    from repro.train.trainer import make_train_step

    cfg = load_arch("qwen2-0.5b").reduced()
    model = cfg.build(SHAPES["train_4k"])
    params = model.init(jax.random.PRNGKey(0))
    lora = model.lora_init(jax.random.PRNGKey(1))
    batch = input_specs(cfg, SHAPES["train_4k"], concrete=True,
                        batch_override=2, seq_override=16)
    batch["tokens"] = jax.random.randint(jax.random.PRNGKey(2),
                                         batch["tokens"].shape, 0, cfg.vocab)
    batch["labels"] = batch["tokens"]

    step, opt = make_train_step(model, adamw(1e-3))
    state = opt.init(lora)
    lora1, state1, _ = step(params, lora, state, batch)

    save(str(tmp_path / "ck"), {"lora": lora1, "opt": state1}, {"step": 1})
    restored, meta = load(str(tmp_path / "ck"), {"lora": lora1, "opt": state1})
    assert meta["step"] == 1

    lora2a, _, m_a = step(params, lora1, state1, batch)
    lora2b, _, m_b = step(params, restored["lora"], restored["opt"], batch)
    np.testing.assert_allclose(float(m_a["loss"]), float(m_b["loss"]), rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(lora2a),
                    jax.tree_util.tree_leaves(lora2b)):
        np.testing.assert_allclose(a, b, rtol=1e-6)
