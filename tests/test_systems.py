"""Event-clock client-system layer (repro.fed.systems).

Determinism contract (replayable, failure-invariant draws), the crash
availability window, CRC wire framing, 100% tamper detection through
the validating decode, admission-queue drain order, and the
simulator-level fold_in RNG regression: survivors' uploads are
bit-identical with and without a targeted fault injection.
"""

import jax
import numpy as np
import pytest

from repro.fed.compression import CodedStreamError, decode_mask_rows, \
    encode_mask_rows
from repro.fed.systems import (AdmissionQueue, ClientSystems, FaultModel,
                               WireFrameError, blank_fault_counters,
                               unwrap_stream, wrap_stream)
from repro.kernels import bitpack

jax.config.update("jax_platform_name", "cpu")


# -- stateless draws ----------------------------------------------------------

def test_draws_replayable_and_instance_independent():
    """Every (client, round) draw is a pure function of (seed, channel,
    client, round): repeated calls and fresh instances agree."""
    fm = FaultModel(dropout=0.4, straggler_frac=0.4, crash_prob=0.2,
                    corrupt_prob=0.4, seed=11)
    a = ClientSystems(8, fm)
    b = ClientSystems(8, fm)
    for c in range(8):
        for r in range(6):
            for fn in ("available", "dropout", "is_straggler", "delay",
                       "corrupt"):
                assert getattr(a, fn)(c, r) == getattr(a, fn)(c, r)
                assert getattr(a, fn)(c, r) == getattr(b, fn)(c, r)


def test_draws_failure_invariant_across_clients():
    """Forcing faults for one client perturbs no other client's draws
    (each (channel, client, round) cell owns its own generator)."""
    fm = FaultModel(dropout=0.3, straggler_frac=0.3, corrupt_prob=0.3,
                    seed=4)
    plain = ClientSystems(6, fm)
    forced = ClientSystems(6, fm,
                           forced_dropouts={(0, r) for r in range(10)})
    for c in range(1, 6):
        for r in range(10):
            assert plain.dropout(c, r) == forced.dropout(c, r)
            assert plain.delay(c, r) == forced.delay(c, r)
            assert plain.corrupt(c, r) == forced.corrupt(c, r)
    assert all(forced.dropout(0, r) for r in range(10))


def test_crash_covers_rejoin_window():
    """A crash at round q makes the client unavailable for rounds
    q .. q + crash_rounds − 1 and available again after."""
    sys_always = ClientSystems(2, FaultModel(crash_prob=1.0, crash_rounds=3))
    assert not sys_always.available(0, 0)
    sys_never = ClientSystems(2, FaultModel(crash_prob=0.0))
    assert all(sys_never.available(0, r) for r in range(5))

    fm = FaultModel(crash_prob=0.25, crash_rounds=3, seed=9)
    s = ClientSystems(4, fm)
    crashes = [(c, r) for c in range(4) for r in range(12)
               if s._crashed_at(c, r)]
    assert crashes, "seed should produce at least one crash"
    for c, q in crashes:
        for r in range(q, q + fm.crash_rounds):
            assert not s.available(c, r)
    # rejoin: some crash is followed by availability after the window
    assert any(s.available(c, q + fm.crash_rounds) for c, q in crashes
               if not any(s._crashed_at(c, x)
                          for x in range(q + 1, q + 2 * fm.crash_rounds)))


def test_ideal_trace_is_faultless():
    s = ClientSystems.ideal(5)
    for c in range(5):
        for r in range(8):
            assert s.available(c, r)
            assert not s.dropout(c, r)
            assert s.delay(c, r) == 0
            assert not s.corrupt(c, r)
    assert not s.injects_corruption


def test_base_delay_heterogeneity():
    s = ClientSystems(3, FaultModel(straggler_frac=1.0, straggler_delay=2),
                      base_delay=[0, 1, 3])
    assert [s.delay(c, 0) for c in range(3)] == [2, 3, 5]
    with pytest.raises(ValueError):
        ClientSystems(3, base_delay=[0, 1])


# -- wire framing -------------------------------------------------------------

def test_frame_roundtrip_and_rejections():
    payload = np.arange(40, dtype=np.uint8)
    framed = wrap_stream(payload)
    np.testing.assert_array_equal(unwrap_stream(framed), payload)
    with pytest.raises(WireFrameError):
        unwrap_stream(framed[:4])                       # short header
    bad = framed.copy(); bad[0] ^= 0xFF
    with pytest.raises(WireFrameError):
        unwrap_stream(bad)                              # bad magic
    with pytest.raises(WireFrameError):
        unwrap_stream(framed[:-1])                      # truncated payload
    with pytest.raises(WireFrameError):
        unwrap_stream(np.concatenate([framed, framed[-1:]]))  # trailing
    flip = framed.copy(); flip[-1] ^= 0x01
    with pytest.raises(WireFrameError):
        unwrap_stream(flip)                             # CRC mismatch


def test_tamper_detected_100_percent():
    """Every injected tamper (truncation or distinct-bit flips) of a
    framed coded stream is caught by the validating decode — the basis
    of the 100%-quarantine acceptance criterion.  The entropy coder
    alone cannot promise this (near-bijective), the CRC frame can."""
    rng = np.random.default_rng(1)
    d = 769
    s = ClientSystems(1, FaultModel(corrupt_prob=1.0, truncate_frac=0.5,
                                    seed=2))
    caught = 0
    trials = 120
    for trial in range(trials):
        k = int(rng.integers(1, 4))
        words = bitpack.pack_bits_np(
            np.stack([rng.random(d) < float(rng.choice([0.1, 0.5, 0.85]))
                      for _ in range(k)]))
        framed = wrap_stream(encode_mask_rows(words, d))
        tampered = s.tamper(framed, 0, trial)
        assert tampered.size != framed.size or \
            (tampered != framed).any(), "tamper must change the stream"
        try:
            decode_mask_rows(unwrap_stream(tampered), d, k)
        except (WireFrameError, CodedStreamError):
            caught += 1
    assert caught == trials


def test_tamper_is_deterministic():
    s = ClientSystems(2, FaultModel(corrupt_prob=1.0, seed=3))
    stream = np.arange(64, dtype=np.uint8)
    np.testing.assert_array_equal(s.tamper(stream, 1, 5),
                                  s.tamper(stream, 1, 5))
    a, b = s.tamper(stream, 0, 5), s.tamper(stream, 1, 5)
    assert a.size != b.size or (a != b).any()


# -- admission queue ----------------------------------------------------------

def test_queue_drain_order_and_buffering():
    q = AdmissionQueue()
    q.push(2, 0, "late")          # arrives at tick 2, dispatched tick 0
    q.push(0, 0, "a")
    q.push(0, 0, "b")             # same tick: push order preserved
    q.push(1, 1, "c")
    assert [i.payload for i in q.pop_ready(0)] == ["a", "b"]
    assert len(q) == 2
    assert [i.payload for i in q.pop_ready(1)] == ["c"]
    got = q.pop_ready(5)
    assert [i.payload for i in got] == ["late"]
    assert got[0].dispatch == 0 and got[0].arrival == 2
    assert len(q) == 0 and q.pop_ready(9) == []


def test_blank_fault_counters_keys():
    c = blank_fault_counters()
    assert set(c) == {"sampled", "dropped", "crashed", "stragglers",
                      "stale", "quarantined", "buffered", "admitted",
                      "skipped"}
    assert all(v == 0 for v in c.values())


# -- simulator RNG regression -------------------------------------------------

def _setting():
    from repro.data.dirichlet import dirichlet_split
    from repro.data.synthetic import make_constellation
    from repro.fed.testbed import MLPBackbone
    con = make_constellation(n_tasks=5, n_groups=2, feat_dim=16,
                             n_classes=4, seed=0)
    split = dirichlet_split(n_clients=5, n_tasks=5, n_classes=4,
                            zeta_t=0.5, tasks_per_client=2, seed=0)
    bb = MLPBackbone(16, hidden=24, lora_rank=4)
    return con, split, bb


def test_simulator_rng_failure_invariant(monkeypatch):
    """fold_in key schedule regression: dropping ONE client at the
    final round leaves every survivor's upload of that round
    bit-identical to the fault-free run (selection, training keys, and
    all pre-fault state are untouched by the injected fault)."""
    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    from repro.fed.simulator import FedConfig, FedSimulator
    from repro.fed.strategies import AsyncMaTUStrategy
    con, split, bb = _setting()
    cfg = FedConfig(rounds=3, participation=1.0, local_steps=2,
                    batch_size=16, local_data=64, eval_every=3)
    runs = {}
    for fault in (False, True):
        strat = AsyncMaTUStrategy(con.n_tasks, bb.d)
        forced = {(0, cfg.rounds - 1)} if fault else None
        sim = FedSimulator(cfg, con, split, bb, strat,
                           systems=ClientSystems(5, forced_dropouts=forced))
        sim.run()
        runs[fault] = {u.client_id: u for u in strat._last_uploads}
    assert 0 in runs[False] and 0 not in runs[True]
    survivors = set(runs[True])
    assert survivors == set(runs[False]) - {0}
    for c in survivors:
        a, b = runs[False][c], runs[True][c]
        np.testing.assert_array_equal(np.asarray(a.unified),
                                      np.asarray(b.unified))
        np.testing.assert_array_equal(np.asarray(a.masks),
                                      np.asarray(b.masks))
        np.testing.assert_array_equal(np.asarray(a.lams),
                                      np.asarray(b.lams))
