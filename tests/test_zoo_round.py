"""Cross-architecture federated rounds over the reduced model zoo.

The task-vector layout contract end to end (see ``repro.fed.testbed``):

* every zoo family's :class:`TaskVectorSpace` manifest flattens and
  unflattens its LoRA delta pytree losslessly (the d-axis IS the
  manifest);
* a manifest-fingerprint mismatch between client and server aborts
  BEFORE aggregation (both at the strategy and at simulator
  construction);
* a mixed-architecture round over REAL per-task fine-tune deltas is
  bit-identical between the packed uint32 wire and the bool/fp32
  reference layout — zero-padding each family to the common d (the
  256-coord word boundary) never perturbs the engine;
* a 30-task round over >= 4 distinct families completes end-to-end
  through ``MaTUStrategy`` with measured wire bits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.tree import (TaskVectorLayoutError, TaskVectorSpace,
                               pad_vector, tree_zeros_like)
from repro.configs.base import ZOO_FAMILIES
from repro.core.client import ClientUpload
from repro.core.engine import EngineConfig, RoundEngine
from repro.core.unify import unify_with_modulators
from repro.data.dirichlet import FedSplit
from repro.data.synthetic import make_constellation, sample_task_batch
from repro.fed.compression import quantize_bf16_transport
from repro.fed.local import make_head, make_local_trainer
from repro.fed.simulator import FedConfig, FedSimulator
from repro.fed.strategies import MaTUStrategy, RoundBatch, Upload
from repro.fed.testbed import (ArchBackbone, make_zoo_backbones, round_up_d,
                               D_BOUNDARY)

jax.config.update("jax_platform_name", "cpu")

FEAT_DIM = 32  # == reduced vit patch_dim: one constellation feeds all


@pytest.fixture(scope="module")
def zoo():
    return make_zoo_backbones(FEAT_DIM, seed=0)


# -- layout manifest ---------------------------------------------------------

def test_flatten_unflatten_roundtrip_per_family(zoo):
    """Every family's manifest is lossless: a random model-space delta
    survives flatten -> unflatten bit-exactly, leaf by leaf."""
    for fam, bb in zoo.items():
        key = jax.random.PRNGKey(hash(fam) % (2**31))
        delta = jax.tree_util.tree_map(
            lambda l, key=key: jax.random.normal(
                jax.random.fold_in(key, l.size % 9973), l.shape, l.dtype),
            bb.lora0)
        flat = bb.space.flatten(delta)
        assert flat.shape == (bb.d,) and flat.dtype == jnp.float32
        back = bb.space.unflatten(flat)
        leaves_a = jax.tree_util.tree_leaves(delta)
        leaves_b = jax.tree_util.tree_leaves(back)
        assert len(leaves_a) == len(leaves_b) == len(bb.space.leaves)
        for a, b in zip(leaves_a, leaves_b):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # zero-padding to the round's common d is invisible on unflatten
        padded = pad_vector(flat, round_up_d(bb.d))
        again = bb.space.unflatten(padded)
        for a, b in zip(leaves_a, jax.tree_util.tree_leaves(again)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manifest_fingerprints_distinct_and_stable(zoo):
    """Fingerprints identify layouts: distinct across families, stable
    across independent constructions, round-trip through JSON."""
    fps = {fam: bb.fingerprint for fam, bb in zoo.items()}
    assert len(set(fps.values())) == len(fps)
    rebuilt = ArchBackbone(ZOO_FAMILIES["lm"], feat_dim=FEAT_DIM, seed=7)
    assert rebuilt.fingerprint == zoo["lm"].fingerprint  # seed-independent
    space2 = TaskVectorSpace.from_json(zoo["ssm"].space.to_json())
    assert space2.fingerprint == zoo["ssm"].fingerprint


def test_fingerprint_mismatch_aborts_before_aggregation(zoo):
    """The server refuses to aggregate an upload whose manifest
    disagrees with the installed per-task expectation — and the round
    state is untouched afterwards (abort BEFORE, not during)."""
    d = round_up_d(zoo["lm"].d)
    strat = MaTUStrategy(2, d)
    strat.use_layouts({0: zoo["lm"].fingerprint, 1: zoo["lm"].fingerprint})
    tvs = jnp.asarray(np.random.default_rng(0).standard_normal((1, d)),
                      jnp.float32)
    bad = Upload(0, [1], tvs, [64], fingerprint=zoo["vit"].fingerprint)
    with pytest.raises(TaskVectorLayoutError, match="refusing to aggregate"):
        strat.aggregate_batch(RoundBatch.from_uploads([bad], 2))
    assert strat.downlinks == {}  # nothing aggregated
    # matching fingerprint passes the same gate
    ok = Upload(0, [1], tvs, [64], fingerprint=zoo["lm"].fingerprint)
    strat.aggregate_batch(RoundBatch.from_uploads([ok], 2))
    assert 0 in strat.downlinks


def test_simulator_rejects_split_brain_holders(zoo):
    """Holders of one task with different manifests are refused at
    simulator construction (before any training happens)."""
    con = make_constellation(n_tasks=2, n_groups=2, feat_dim=FEAT_DIM,
                             n_classes=4, seed=0)
    split = FedSplit([[0], [0]], {(0, 0): None, (1, 0): None},
                     {(0, 0): 64, (1, 0): 64})
    d = round_up_d(max(zoo["lm"].d, zoo["vit"].d))
    with pytest.raises(TaskVectorLayoutError, match="different"):
        FedSimulator(FedConfig(rounds=1), con, split,
                     {0: zoo["lm"], 1: zoo["vit"]}, MaTUStrategy(2, d))


# -- cross-architecture wire parity ------------------------------------------

def real_finetune_uploads(zoo, families, n_tasks, d):
    """One upload per family, each row a REAL local fine-tune delta
    (3 AdamW steps through the family's actual forward), zero-padded to
    the common d."""
    con = make_constellation(n_tasks=n_tasks, n_groups=2, feat_dim=FEAT_DIM,
                             n_classes=4, seed=3)
    ups = []
    for cid, fam in enumerate(families):
        bb = zoo[fam]
        trainer = make_local_trainer(bb, steps=3, batch_size=8, lr=1e-2)
        rng = jax.random.PRNGKey(100 + cid)
        tasks = [(2 * cid) % n_tasks, (2 * cid + 1) % n_tasks]
        tvs = []
        for t in tasks:
            rng, k1, k2, k3 = jax.random.split(rng, 4)
            x, y = sample_task_batch(con.tasks[t], k1, 32)
            head = make_head(k2, bb.feat_out, con.n_classes)
            tv, _, _ = trainer(jnp.zeros((bb.d,), jnp.float32), head,
                               x, y, k3)
            assert float(jnp.linalg.norm(tv)) > 0  # training moved it
            tvs.append(pad_vector(tv, d))
        unified, masks, lams = unify_with_modulators(jnp.stack(tvs))
        # bf16-quantise ONCE at the wire boundary (as the uplink does)
        # so the packed and bool layouts consume identical values
        ups.append(ClientUpload(cid, tasks, quantize_bf16_transport(unified),
                                masks, lams, [64, 64]))
    return ups


def test_cross_arch_round_packed_bool_bit_parity(zoo, monkeypatch):
    """Packed uint32 wire == bool/fp32 layout, bit for bit, on a round
    of real fine-tune deltas from different architectures padded to one
    common d (the acceptance-criteria parity check).  Pinned to the
    streaming ref round: full bitwise parity (incl. λ) is the REF
    contract — on the Pallas paths the packed kernels tile d at 4096
    vs the bool kernels' 2048, and this round's d spans multiple
    tiles, so λ there matches only to fp32 accumulation tolerance
    (see the engine docstring)."""
    monkeypatch.setenv("REPRO_DISABLE_PALLAS", "1")
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    families = ["lm", "vit", "ssm", "moe"]
    d = round_up_d(max(zoo[f].d for f in families))
    assert d % D_BOUNDARY == 0
    n_tasks = 4
    ups = real_finetune_uploads(zoo, families, n_tasks, d)
    eng = RoundEngine(EngineConfig(n_tasks=n_tasks))
    downs_p, out_p = eng.round(ups)                 # packed wire
    downs_b, out_b = eng.round(ups, packed=False)   # bool A/B reference
    np.testing.assert_array_equal(np.asarray(out_b.task_vectors),
                                  np.asarray(out_p.task_vectors))
    np.testing.assert_array_equal(np.asarray(out_b.m_hats),
                                  np.asarray(out_p.m_hats))
    np.testing.assert_array_equal(np.asarray(out_b.similarity),
                                  np.asarray(out_p.similarity))
    np.testing.assert_array_equal(np.asarray(out_b.down_lams),
                                  np.asarray(out_p.down_lams))
    for cid in downs_p:
        np.testing.assert_array_equal(
            np.asarray(downs_p[cid].masks_dense()),
            np.asarray(downs_b[cid].masks_dense()))
    # wire accounting is measured off the packed buffers
    bits = sum(u.uplink_bits() for u in ups)
    assert bits > 0


# -- the 30-task reduced-zoo round -------------------------------------------

def test_thirty_task_zoo_round_end_to_end(zoo):
    """30 tasks across 4 distinct families, one full MaTUStrategy round
    through the simulator: per-client manifests flatten into the shared
    slot layout, wire bits are measured, downlinks are packed, and the
    layout expectations are installed per task."""
    families = ["lm", "vit", "ssm", "moe"]
    n_tasks, n_classes = 30, 4
    con = make_constellation(n_tasks=n_tasks, n_groups=4, feat_dim=FEAT_DIM,
                             n_classes=n_classes, seed=5)
    # client c holds task c; family rotates -> holders trivially agree
    tasks = [[t] for t in range(n_tasks)]
    split = FedSplit(tasks,
                     {(c, c): None for c in range(n_tasks)},
                     {(c, c): 64 for c in range(n_tasks)})
    bbs = {c: zoo[families[c % len(families)]] for c in range(n_tasks)}
    d = round_up_d(max(b.d for b in bbs.values()))
    cfg = FedConfig(rounds=1, local_steps=2, batch_size=8, local_data=32,
                    eval_every=1, seed=0)
    strat = MaTUStrategy(n_tasks, d)
    sim = FedSimulator(cfg, con, split, bbs, strat)
    assert sim.d == d and sim.d % D_BOUNDARY == 0
    assert set(strat.expected_layouts) == set(range(n_tasks))
    assert len(set(strat.expected_layouts.values())) == len(families)
    hist = sim.run()
    assert hist.rounds == [1]
    assert hist.uplink_bits_per_round[0] > 0
    assert hist.downlink_bits_per_round[0] > 0
    assert len(hist.task_acc[0]) == n_tasks
    for dl in strat.downlinks.values():
        assert dl.packed and dl.unified.shape == (d,)
